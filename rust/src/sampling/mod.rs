//! Sampling substrate: alias tables, random walks, GraphVite's parallel
//! online augmentation (paper §3.1) and the restricted negative sampler
//! (paper §3.2).
//!
//! **Online augmentation (§3.1).** Plain edge sampling starves the GPUs
//! on sparse graphs, so each CPU sampler thread runs random walks of
//! `walk_length` edges and emits every node pair within
//! `augmentation_distance` hops along the walk as an *augmented* positive
//! sample ([`OnlineAugmenter`]). Departure nodes are drawn with
//! probability proportional to degree through an [`AliasTable`] (O(1)
//! weighted draws), and the walk itself steps through per-node alias
//! tables ([`RandomWalker`]). Nothing is materialized: augmentation
//! happens online while filling the pool, which is what lets the sampler
//! threads keep up with the device workers in the §3.3 collaboration
//! strategy.
//!
//! **Restricted (parallel) negative sampling (§3.2).** Classic SGNS draws
//! negatives from all of `V`, which would force every worker to hold the
//! whole context matrix. GraphVite's observation is that negatives only
//! need to be *approximately* uniform: each worker instead draws
//! negatives from the context partition resident on it
//! ([`NegativeSampler::sample_local`]), so an episode's block trains
//! entirely against device-resident rows — no transfer, no cross-worker
//! synchronization. Over a pool pass every (vertex, context) partition
//! pair is visited, so the union of restricted distributions covers `V`.
//!
//! The [`EdgeSampler`] is the un-augmented fallback behind the
//! `online_augmentation = false` ablation (Table 6 row 2).

mod alias;
mod augment;
mod edge;
mod negative;
mod walk;

pub use alias::AliasTable;
pub use augment::{AugmentConfig, OnlineAugmenter};
pub use edge::EdgeSampler;
pub use negative::NegativeSampler;
pub use walk::{RandomWalker, WalkScratch};
