"""Layer-2 correctness: scan/gather/scatter train_block vs loop reference,
padding invariants, optimization behaviour."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.model import make_train_block, example_args, NEG_WEIGHT
from compile.kernels.ref import train_block_ref


def _setup(P, D, B, S, K, seed=0, nmax=None):
    """Random partitions + sample indices bounded by nmax (default P)."""
    nmax = nmax or P
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    vertex = jax.random.normal(ks[0], (P, D)) * 0.1
    context = jax.random.normal(ks[1], (P, D)) * 0.1
    pu = jax.random.randint(ks[2], (S, B), 0, nmax)
    pv = jax.random.randint(ks[3], (S, B), 0, nmax)
    nv = jax.random.randint(ks[4], (S, B, K), 0, nmax)
    return vertex, context, pu, pv, nv


class TestTrainBlockVsRef:
    @pytest.mark.parametrize(
        "P,D,B,S,K",
        [(256, 16, 64, 4, 1), (128, 8, 32, 2, 2), (512, 32, 64, 3, 1)],
    )
    def test_matches_loop_reference(self, P, D, B, S, K):
        fn = jax.jit(make_train_block(P, D, B, S, K))
        vertex, context, pu, pv, nv = _setup(P, D, B, S, K)
        v2, c2, loss = fn(vertex, context, pu, pv, nv, 0.025)
        rv2, rc2, rloss = train_block_ref(vertex, context, pu, pv, nv, 0.025)
        np.testing.assert_allclose(v2, rv2, rtol=1e-4, atol=1e-5)
        np.testing.assert_allclose(c2, rc2, rtol=1e-4, atol=1e-5)
        np.testing.assert_allclose(loss, rloss, rtol=1e-4)

    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(0, 2**16), k=st.sampled_from([1, 2, 4]))
    def test_hypothesis_seeds(self, seed, k):
        P, D, B, S = 128, 8, 32, 2
        fn = jax.jit(make_train_block(P, D, B, S, k))
        vertex, context, pu, pv, nv = _setup(P, D, B, S, k, seed=seed)
        v2, c2, loss = fn(vertex, context, pu, pv, nv, 0.025)
        rv2, rc2, rloss = train_block_ref(vertex, context, pu, pv, nv, 0.025)
        np.testing.assert_allclose(v2, rv2, rtol=1e-4, atol=1e-5)
        np.testing.assert_allclose(c2, rc2, rtol=1e-4, atol=1e-5)

    def test_pallas_vs_jnp_path(self):
        """use_pallas=True and use_pallas=False must agree exactly-ish."""
        P, D, B, S, K = 256, 16, 64, 4, 1
        vertex, context, pu, pv, nv = _setup(P, D, B, S, K)
        a = jax.jit(make_train_block(P, D, B, S, K, use_pallas=True))
        b = jax.jit(make_train_block(P, D, B, S, K, use_pallas=False))
        va, ca, la = a(vertex, context, pu, pv, nv, 0.025)
        vb, cb, lb = b(vertex, context, pu, pv, nv, 0.025)
        np.testing.assert_allclose(va, vb, rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(ca, cb, rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(la, lb, rtol=1e-5)


class TestPaddingInvariant:
    def test_pad_rows_untouched(self):
        """Rows >= nmax are padding: the trainer must never write them."""
        P, D, B, S, K = 256, 16, 64, 4, 1
        nmax = 100  # only rows [0, 100) are real
        fn = jax.jit(make_train_block(P, D, B, S, K))
        vertex, context, pu, pv, nv = _setup(P, D, B, S, K, nmax=nmax)
        v2, c2, _ = fn(vertex, context, pu, pv, nv, 0.025)
        np.testing.assert_array_equal(v2[nmax:], vertex[nmax:])
        np.testing.assert_array_equal(c2[nmax:], context[nmax:])
        # and the real region did change
        assert not np.allclose(v2[:nmax], vertex[:nmax])


class TestOptimization:
    def test_loss_decreases_over_blocks(self):
        """Repeated training on a fixed positive structure reduces loss."""
        P, D, B, S, K = 128, 16, 32, 4, 1
        fn = jax.jit(make_train_block(P, D, B, S, K))
        key = jax.random.PRNGKey(42)
        ks = jax.random.split(key, 5)
        vertex = jax.random.normal(ks[0], (P, D)) * 0.1
        context = jax.random.normal(ks[1], (P, D)) * 0.1
        # fixed "graph": node i positively linked to (i+1) mod P
        pu = jax.random.randint(ks[2], (S, B), 0, P)
        pv = (pu + 1) % P
        nv = jax.random.randint(ks[3], (S, B, K), 0, P)
        losses = []
        for _ in range(8):
            vertex, context, loss = fn(vertex, context, pu, pv, nv, 0.05)
            losses.append(float(loss))
        assert losses[-1] < losses[0], losses

    def test_lr_zero_is_identity(self):
        P, D, B, S, K = 128, 8, 32, 2, 1
        fn = jax.jit(make_train_block(P, D, B, S, K))
        vertex, context, pu, pv, nv = _setup(P, D, B, S, K)
        v2, c2, _ = fn(vertex, context, pu, pv, nv, 0.0)
        np.testing.assert_array_equal(v2, vertex)
        np.testing.assert_array_equal(c2, context)

    def test_duplicate_indices_accumulate(self):
        """Scatter-add must sum gradients for repeated rows in one batch."""
        P, D, B, S, K = 64, 8, 32, 1, 1
        fn = jax.jit(make_train_block(P, D, B, S, K))
        vertex = jnp.ones((P, D)) * 0.1
        context = jnp.ones((P, D)) * 0.1
        # every positive sample is the same pair (0, 1), negatives all row 2
        pu = jnp.zeros((S, B), jnp.int32)
        pv = jnp.ones((S, B), jnp.int32)
        nv = jnp.full((S, B, K), 2, jnp.int32)
        v2, _, _ = fn(vertex, context, pu, pv, nv, 0.01)
        # row 0 of vertex moved ~B times as far as a single-sample update
        single = jax.jit(make_train_block(P, D, 1, 1, K))
        v1, _, _ = single(
            vertex,
            context,
            jnp.zeros((1, 1), jnp.int32),
            jnp.ones((1, 1), jnp.int32),
            jnp.full((1, 1, K), 2, jnp.int32),
            0.01,
        )
        moved_b = v2[0] - vertex[0]
        moved_1 = v1[0] - vertex[0]
        np.testing.assert_allclose(moved_b, B * moved_1, rtol=1e-4)


class TestRustParityFixture:
    """Pins the exact numbers `rust/tests/hlo_runtime.rs` asserts against.

    If the model changes, this test fails first and tells you to update the
    rust-side constants (and vice versa) — the two suites share one fixture.
    """

    def test_reference_values(self):
        P, D, B, S, K = 256, 16, 64, 4, 1
        fn = jax.jit(make_train_block(P, D, B, S, K))
        vertex = ((np.arange(P * D) % 97 - 48) / 100.0).astype(np.float32).reshape(P, D)
        context = ((np.arange(P * D) % 89 - 44) / 100.0).astype(np.float32).reshape(P, D)
        pu = (np.arange(S * B) % 100).astype(np.int32).reshape(S, B)
        pv = ((np.arange(S * B) * 7 + 3) % 100).astype(np.int32).reshape(S, B)
        nv = ((np.arange(S * B * K) * 13 + 5) % 100).astype(np.int32).reshape(S, B, K)
        v2, c2, loss = fn(vertex, context, pu, pv, nv, jnp.float32(0.025))
        assert abs(float(loss) - 2.172836) < 1e-3
        assert abs(float(np.abs(v2 - vertex).sum()) - 53.03366) < 0.05
        assert abs(float(np.abs(c2 - context).sum()) - 59.299427) < 0.05


class TestAotTextFormat:
    """Regression tests for the HLO-text interchange gotchas."""

    def test_no_elided_constants(self):
        # The default printer turns >16-element constants into `{...}`,
        # which XLA 0.5.1's parser silently zeroes. to_hlo_text must print
        # them in full (this killed the whole train step once).
        from compile.aot import lower_train

        text = lower_train(dict(p=256, d=16, b=64, s=4, k=1))
        assert "{...}" not in text
        assert "constant({1, 1, 1" in text or "constant({5, 5, 5" in text

    def test_no_unparseable_metadata(self):
        from compile.aot import lower_train

        text = lower_train(dict(p=256, d=16, b=64, s=4, k=1))
        # XLA 0.5.1 rejects newer metadata attributes like source_end_line
        assert "source_end_line" not in text


class TestExampleArgs:
    def test_shapes_match_manifest_contract(self):
        args = example_args(256, 16, 64, 4, 1)
        assert args[0].shape == (256, 16)
        assert args[2].shape == (4, 64)
        assert args[4].shape == (4, 64, 1)
        assert args[5].shape == ()

    def test_neg_weight_constant(self):
        assert NEG_WEIGHT == 5.0  # paper section 4.3
