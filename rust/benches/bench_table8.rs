//! Regenerates paper Table 8 — training time under the "fast server" vs
//! "economic server" hardware-analogue configurations.
//!
//! Run with `cargo bench --bench bench_table8`; set
//! GRAPHVITE_BENCH_SCALE=tiny|small|full to change the workload size
//! (default tiny so `cargo bench` completes quickly; EXPERIMENTS.md
//! records the `small` runs).

fn main() {
    graphvite::experiments::run("table8", graphvite::experiments::Scale::from_env())
        .expect("table8 experiment");
}
