//! Table 7 — shuffle-algorithm comparison on a single worker: none /
//! random / index-mapping / pseudo. Shape to reproduce: every shuffle
//! beats no-shuffle on F1 by about a point; random & index-mapping cost
//! several times the training time; pseudo costs almost nothing.

use anyhow::Result;

use crate::coordinator::Trainer;
use crate::experiments::presets::{classify, Scale, Workload};
use crate::pool::shuffle::{adjacent_correlation, shuffle, ShuffleKind};
use crate::util::bench::Table;
use crate::util::human_secs;
use crate::util::rng::Rng;

pub fn run(scale: Scale) -> Result<()> {
    let w = Workload::youtube_like(scale);
    let mut table = Table::new(
        "Table 7 — shuffle algorithms (single worker)",
        &["shuffle", "micro-F1@2%", "train time", "pool decorrelation"],
    );

    for kind in [
        ShuffleKind::None,
        ShuffleKind::Random,
        ShuffleKind::IndexMapping,
        ShuffleKind::Pseudo,
    ] {
        let mut cfg = w.config.clone();
        cfg.shuffle = kind;
        cfg.num_workers = 1;
        cfg.num_samplers = 2;
        let mut trainer = Trainer::new(w.graph.clone(), cfg)?;
        let r = trainer.train()?;
        let rep = classify(&r.embeddings, &w.graph, 0.02, 7);

        // decorrelation metric on a fresh pool processed by this shuffle
        let corr = {
            let mut pool: Vec<(u32, u32)> = (0..20_000u32)
                .map(|i| ((i / 4) % 1000, i % 4 + 2000))
                .collect();
            let mut rng = Rng::new(1);
            shuffle(kind, &mut pool, w.config.augmentation_distance.max(2), &mut rng);
            adjacent_correlation(&pool)
        };

        table.row(&[
            kind.name().into(),
            format!("{:.2}", rep.micro_f1 * 100.0),
            human_secs(r.stats.train_secs),
            format!("{:.4}", corr),
        ]);
    }
    table.print();
    Ok(())
}
