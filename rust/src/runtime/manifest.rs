//! `artifacts/manifest.txt` parser — the contract between `aot.py` and the
//! rust runtime. One artifact per line, `key=value` pairs separated by
//! whitespace; keys: kind, file, and the static shape parameters.

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

/// Static shape parameters of one AOT artifact.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ArtifactMeta {
    pub kind: ArtifactKind,
    /// Path to the HLO text file (absolute once parsed).
    pub file: PathBuf,
    /// train: partition row capacity.
    pub p: usize,
    /// embedding dim.
    pub d: usize,
    /// train: batch size per scan step.
    pub b: usize,
    /// train: scan steps per execute.
    pub s: usize,
    /// train: negatives per positive.
    pub k: usize,
    /// kernel: pair count.
    pub n: usize,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ArtifactKind {
    Train,
    Kernel,
}

/// All artifacts listed in a manifest.
#[derive(Debug, Clone, Default)]
pub struct Manifest {
    pub artifacts: Vec<ArtifactMeta>,
}

impl Manifest {
    /// Parse `<dir>/manifest.txt`; `file=` entries resolve relative to dir.
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref();
        let path = dir.join("manifest.txt");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("read {} (run `make artifacts`)", path.display()))?;
        Self::parse(&text, dir)
    }

    pub fn parse(text: &str, dir: &Path) -> Result<Self> {
        let mut artifacts = Vec::new();
        for (lineno, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let mut kv: HashMap<&str, &str> = HashMap::new();
            for tok in line.split_whitespace() {
                let Some(eq) = tok.find('=') else {
                    bail!("manifest line {}: token '{}' is not key=value", lineno + 1, tok);
                };
                kv.insert(&tok[..eq], &tok[eq + 1..]);
            }
            let kind = match kv.get("kind") {
                Some(&"train") => ArtifactKind::Train,
                Some(&"kernel") => ArtifactKind::Kernel,
                other => bail!("manifest line {}: bad kind {:?}", lineno + 1, other),
            };
            let file = dir.join(
                kv.get("file")
                    .ok_or_else(|| anyhow::anyhow!("manifest line {}: missing file", lineno + 1))?,
            );
            let num = |key: &str| -> Result<usize> {
                kv.get(key)
                    .map(|v| v.parse().map_err(|_| anyhow::anyhow!("bad {key}")))
                    .unwrap_or(Ok(0))
            };
            artifacts.push(ArtifactMeta {
                kind,
                file,
                p: num("p")?,
                d: num("d")?,
                b: num("b")?,
                s: num("s")?,
                k: num("k")?,
                n: num("n")?,
            });
        }
        Ok(Manifest { artifacts })
    }

    /// Smallest train artifact with matching dim whose capacity fits
    /// `rows` (the partition size). Errors list available variants.
    pub fn find_train(&self, rows: usize, dim: usize) -> Result<&ArtifactMeta> {
        self.artifacts
            .iter()
            .filter(|a| a.kind == ArtifactKind::Train && a.d == dim && a.p >= rows)
            .min_by_key(|a| a.p)
            .ok_or_else(|| {
                let avail: Vec<String> = self
                    .artifacts
                    .iter()
                    .filter(|a| a.kind == ArtifactKind::Train)
                    .map(|a| format!("(p={}, d={})", a.p, a.d))
                    .collect();
                anyhow::anyhow!(
                    "no train artifact with d={dim} and capacity >= {rows}; \
                     available: {} — add a variant to python/compile/aot.py \
                     TRAIN_VARIANTS and re-run `make artifacts`",
                    avail.join(", ")
                )
            })
    }

    /// First kernel artifact matching (n, d) exactly.
    pub fn find_kernel(&self, n: usize, d: usize) -> Option<&ArtifactMeta> {
        self.artifacts
            .iter()
            .find(|a| a.kind == ArtifactKind::Kernel && a.n == n && a.d == d)
    }

    /// All artifacts (CLI listing).
    pub fn all(&self) -> &[ArtifactMeta] {
        &self.artifacts
    }
}

impl std::fmt::Display for ArtifactMeta {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self.kind {
            ArtifactKind::Train => write!(
                f,
                "train  {}  (P={} rows, d={}, batch={}, scan={}, k={})",
                self.file.file_name().and_then(|s| s.to_str()).unwrap_or("?"),
                self.p,
                self.d,
                self.b,
                self.s,
                self.k
            ),
            ArtifactKind::Kernel => write!(
                f,
                "kernel {}  (n={}, d={})",
                self.file.file_name().and_then(|s| s.to_str()).unwrap_or("?"),
                self.n,
                self.d
            ),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "\
kind=train file=train_p256_d16.hlo.txt p=256 d=16 b=64 s=4 k=1
kind=train file=train_p4096_d16.hlo.txt p=4096 d=16 b=256 s=8 k=1
kind=kernel file=kernel_n512_d64.hlo.txt n=512 d=64
";

    #[test]
    fn parses_and_resolves() {
        let m = Manifest::parse(SAMPLE, Path::new("/art")).unwrap();
        assert_eq!(m.artifacts.len(), 3);
        assert_eq!(m.artifacts[0].p, 256);
        assert_eq!(m.artifacts[0].file, Path::new("/art/train_p256_d16.hlo.txt"));
        assert_eq!(m.artifacts[2].kind, ArtifactKind::Kernel);
        assert_eq!(m.artifacts[2].n, 512);
    }

    #[test]
    fn find_train_picks_smallest_fit() {
        let m = Manifest::parse(SAMPLE, Path::new("/a")).unwrap();
        assert_eq!(m.find_train(100, 16).unwrap().p, 256);
        assert_eq!(m.find_train(300, 16).unwrap().p, 4096);
        assert!(m.find_train(100, 999).is_err());
        assert!(m.find_train(10_000, 16).is_err());
    }

    #[test]
    fn find_kernel_exact() {
        let m = Manifest::parse(SAMPLE, Path::new("/a")).unwrap();
        assert!(m.find_kernel(512, 64).is_some());
        assert!(m.find_kernel(512, 65).is_none());
    }

    #[test]
    fn rejects_malformed() {
        assert!(Manifest::parse("kind=???", Path::new("/a")).is_err());
        assert!(Manifest::parse("notkv", Path::new("/a")).is_err());
    }
}
