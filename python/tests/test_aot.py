"""AOT pipeline contract tests: the manifest the rust runtime parses must
exactly describe the variants aot.py lowers, and the artifact inventory
must cover the capacity/dim combinations the experiments need."""

from compile.aot import KERNEL_VARIANTS, TRAIN_VARIANTS


class TestVariantMatrix:
    def test_unique_names(self):
        names = [f"train_p{v['p']}_d{v['d']}" for v in TRAIN_VARIANTS]
        assert len(names) == len(set(names)), "duplicate train variant"
        knames = [f"kernel_n{v['n']}_d{v['d']}" for v in KERNEL_VARIANTS]
        assert len(knames) == len(set(knames))

    def test_shapes_are_consistent(self):
        for v in TRAIN_VARIANTS:
            # the runtime's padding invariant needs P >= any index the
            # coordinator can emit, and the scan shape must be non-empty
            assert v["p"] >= v["b"], v
            assert v["s"] >= 1 and v["b"] >= 1 and v["k"] >= 1, v
            # chunk samples per execute must divide reasonably into the
            # partition capacity so wrap-padding stays bounded (< p)
            assert v["s"] * v["b"] <= v["p"] * 4, v

    def test_experiment_coverage(self):
        """Every (rows, dim) the experiment presets request must resolve."""
        need = [
            (256, 16),     # unit tests / karate quickstart (2 workers)
            (2_000, 32),   # tiny youtube-like, 1 worker
            (5_000, 32),   # small youtube-like, 4 workers
            (20_000, 32),  # small youtube-like, 1 worker
            (37_500, 32),  # friendster-like (150k nodes, 4 workers)
            (16_384, 128), # paper-dim medium runs
        ]
        for rows, dim in need:
            fits = [
                v for v in TRAIN_VARIANTS if v["d"] == dim and v["p"] >= rows
            ]
            assert fits, f"no artifact covers rows={rows} dim={dim}"

    def test_deep_scans_only_on_large_capacities(self):
        # wrap-around padding must not dominate small blocks: shallow
        # scans at small P, deep scans allowed only at P >= 16384
        for v in TRAIN_VARIANTS:
            if v["p"] < 16384:
                assert v["s"] <= 8, f"scan too deep for small variant {v}"


class TestManifestRoundTrip:
    def test_manifest_lines_match_rust_grammar(self):
        # mirror of rust/src/runtime/manifest.rs parsing rules
        for v in TRAIN_VARIANTS:
            line = (
                f"kind=train file=train_p{v['p']}_d{v['d']}.hlo.txt "
                f"p={v['p']} d={v['d']} b={v['b']} s={v['s']} k={v['k']}"
            )
            kv = dict(tok.split("=", 1) for tok in line.split())
            assert kv["kind"] == "train"
            assert int(kv["p"]) == v["p"]
            assert int(kv["s"]) == v["s"]
