//! Table 4 — node classification micro/macro-F1 across 1%..10% labelled
//! nodes: LINE (with augmentation), DeepWalk and GraphVite on the
//! YouTube-substitute. Shape to reproduce: GraphVite best-or-competitive
//! everywhere, DeepWalk slightly ahead at the smallest label fractions.

use anyhow::Result;

use crate::baselines::{deepwalk::DeepWalkConfig, line::LineConfig, DeepWalkBaseline, LineBaseline};
use crate::coordinator::Trainer;
use crate::embedding::EmbeddingStore;
use crate::experiments::presets::{classify, Scale, Workload};
use crate::util::bench::Table;

const FRACS: [f64; 10] = [0.01, 0.02, 0.03, 0.04, 0.05, 0.06, 0.07, 0.08, 0.09, 0.10];

pub fn run(scale: Scale) -> Result<()> {
    let w = Workload::youtube_like(scale);

    let line = LineBaseline::train(
        &w.graph,
        &LineConfig {
            dim: w.config.dim,
            epochs: w.config.epochs,
            threads: 4,
            walk_length: w.config.walk_length,
            augmentation_distance: w.config.augmentation_distance,
            ..Default::default()
        },
    )?;
    let dw = DeepWalkBaseline::train(
        &w.graph,
        &DeepWalkConfig {
            dim: w.config.dim,
            // budget-matched to epochs * |E| trained pairs (same formula
            // as the Table 3 harness); a fixed small corpus underfits
            walks_per_node: (w.config.epochs * w.graph.num_edges()
                / (w.graph.num_nodes() * 20).max(1))
            .clamp(2, 40),
            walk_length: 20,
            window: w.config.augmentation_distance,
            threads: 4,
            ..Default::default()
        },
    )?;
    let mut trainer = Trainer::new(w.graph.clone(), w.config.clone())?;
    let gv = trainer.train()?;

    let systems: Vec<(&str, &EmbeddingStore)> = vec![
        ("LINE+augmentation", &line.embeddings),
        ("DeepWalk", &dw.embeddings),
        ("GraphVite", &gv.embeddings),
    ];

    for metric in ["Micro-F1(%)", "Macro-F1(%)"] {
        let mut headers: Vec<String> = vec!["system".into()];
        headers.extend(FRACS.iter().map(|f| format!("{:.0}%", f * 100.0)));
        let headers_ref: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
        let mut table = Table::new(
            &format!("Table 4 ({metric}) — node classification on youtube-like"),
            &headers_ref,
        );
        for (name, emb) in &systems {
            let mut row = vec![name.to_string()];
            for (i, &frac) in FRACS.iter().enumerate() {
                let rep = classify(emb, &w.graph, frac, 100 + i as u64);
                let v = if metric.starts_with("Micro") {
                    rep.micro_f1
                } else {
                    rep.macro_f1
                };
                row.push(format!("{:.2}", v * 100.0));
            }
            table.row(&row);
        }
        table.print();
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    // covered by the integration suite at tiny scale (slow-ish: trains 3 systems)
}
