"""AOT pipeline: lower the Layer-2 train-block (with the Layer-1 Pallas
kernel inlined) to HLO **text** artifacts the rust runtime loads via PJRT.

HLO text -- NOT ``lowered.compiler_ir("hlo").as_hlo_proto().SerializeToString()``
-- is the interchange format: jax >= 0.5 emits HloModuleProto with 64-bit
instruction ids which the image's xla_extension 0.5.1 (used by the rust
``xla`` crate) rejects (``proto.id() <= INT_MAX``). The HLO *text* parser
reassigns ids and round-trips cleanly. See /opt/xla-example/README.md.

Outputs (under --out, default ../artifacts):
    train_p{P}_d{D}.hlo.txt     episode-block trainer variants
    kernel_n{N}_d{D}.hlo.txt    standalone Layer-1 kernel (micro-bench)
    manifest.txt                one `key=value ...` line per artifact,
                                parsed by rust/src/runtime/manifest.rs

Usage: cd python && python -m compile.aot [--out DIR] [--only NAME]
"""

from __future__ import annotations

import argparse
import os
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from .model import make_train_block, make_kernel_only, example_args

# (P, D, B, S, K) variants. P = padded partition capacity, D = embedding
# dim, B = batch, S = scan steps per execute, K = negatives per positive.
# The rust runtime picks the smallest P >= its partition size with
# matching D. Keep the matrix small: each entry costs a jax lowering.
# S (scan steps per execute) amortizes the fixed PJRT execute overhead
# (~2.4 ms on this CPU plugin): s=8 -> 0.64 M samples/s, s=32 -> 1.47 on
# the p4096/d64 variant (EXPERIMENTS.md §Perf). Large-capacity variants
# use deep scans because their blocks hold >> s*b samples; the small ones
# stay shallow so wrap-around padding does not dominate tiny blocks.
TRAIN_VARIANTS = [
    # tiny: unit tests / CI
    dict(p=256, d=16, b=64, s=4, k=1),
    # small graphs (quickstart, karate-scale)
    dict(p=4096, d=16, b=256, s=8, k=1),
    dict(p=4096, d=32, b=256, s=8, k=1),
    dict(p=4096, d=64, b=256, s=8, k=1),
    # medium graphs (youtube-mini scale experiments)
    dict(p=16384, d=32, b=512, s=16, k=1),
    dict(p=16384, d=64, b=512, s=16, k=1),
    dict(p=16384, d=128, b=512, s=16, k=1),
    # large runs (table5-scale)
    dict(p=65536, d=32, b=1024, s=16, k=1),
    dict(p=65536, d=128, b=1024, s=16, k=1),
]

KERNEL_VARIANTS = [
    dict(n=512, d=64),
    dict(n=2048, d=128),
]


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (return_tuple=True).

    CRITICAL: print with ``print_large_constants=True``. The default HLO
    printer elides constants over ~16 elements as ``constant({...})``,
    which XLA 0.5.1's text *parser* silently reads back as zeros — the
    model's label/weight vectors become 0 and the compiled train step is a
    perfect no-op (zero loss, zero gradients). Found the hard way.
    """
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    opts = xc._xla.HloPrintOptions()
    opts.print_large_constants = True
    # jax's printer emits metadata attributes (e.g. source_end_line) that
    # XLA 0.5.1's text parser does not know; strip metadata entirely.
    opts.print_metadata = False
    text = comp.get_hlo_module().to_string(opts)
    assert "{...}" not in text, "HLO printer elided a large constant"
    return text


def lower_train(v):
    fn = make_train_block(v["p"], v["d"], v["b"], v["s"], v["k"])
    args = example_args(v["p"], v["d"], v["b"], v["s"], v["k"])
    return to_hlo_text(jax.jit(fn).lower(*args))


def lower_kernel(v):
    fn = make_kernel_only(v["n"], v["d"])
    f32 = jnp.float32
    args = (
        jax.ShapeDtypeStruct((v["n"], v["d"]), f32),
        jax.ShapeDtypeStruct((v["n"], v["d"]), f32),
        jax.ShapeDtypeStruct((v["n"],), f32),
        jax.ShapeDtypeStruct((v["n"],), f32),
    )
    return to_hlo_text(jax.jit(fn).lower(*args))


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--only", default=None, help="only build artifacts whose name contains this substring")
    ns = ap.parse_args()
    os.makedirs(ns.out, exist_ok=True)

    manifest_lines = []
    for v in TRAIN_VARIANTS:
        name = f"train_p{v['p']}_d{v['d']}"
        fname = f"{name}.hlo.txt"
        line = (
            f"kind=train file={fname} p={v['p']} d={v['d']} "
            f"b={v['b']} s={v['s']} k={v['k']}"
        )
        manifest_lines.append(line)
        if ns.only and ns.only not in name:
            continue
        text = lower_train(v)
        with open(os.path.join(ns.out, fname), "w") as f:
            f.write(text)
        print(f"wrote {fname} ({len(text)} chars)", file=sys.stderr)

    for v in KERNEL_VARIANTS:
        name = f"kernel_n{v['n']}_d{v['d']}"
        fname = f"{name}.hlo.txt"
        manifest_lines.append(f"kind=kernel file={fname} n={v['n']} d={v['d']}")
        if ns.only and ns.only not in name:
            continue
        text = lower_kernel(v)
        with open(os.path.join(ns.out, fname), "w") as f:
            f.write(text)
        print(f"wrote {fname} ({len(text)} chars)", file=sys.stderr)

    with open(os.path.join(ns.out, "manifest.txt"), "w") as f:
        f.write("\n".join(manifest_lines) + "\n")
    print(f"wrote manifest.txt ({len(manifest_lines)} artifacts)", file=sys.stderr)


if __name__ == "__main__":
    main()
