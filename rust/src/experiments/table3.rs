//! Table 3 — training time of the node-embedding systems on the
//! YouTube-substitute graph, same number of epochs for every system.
//!
//! Paper shape to reproduce: GraphVite(4 GPU) < GraphVite(1 GPU) ≪
//! LINE < DeepWalk, with the mini-batch "GPU" system slowest of all
//! (bus-bound); speedups are reported relative to LINE.

use anyhow::Result;

use crate::baselines::{
    deepwalk::DeepWalkConfig, line::LineConfig, minibatch::MinibatchConfig,
    node2vec::Node2VecConfig, DeepWalkBaseline, LineBaseline, MinibatchGpuBaseline,
    Node2VecBaseline,
};
use crate::coordinator::Trainer;
use crate::experiments::presets::{classify, Scale, Workload};
use crate::util::bench::Table;
use crate::util::human_secs;

pub fn run(scale: Scale) -> Result<()> {
    let w = Workload::youtube_like(scale);
    let epochs = w.config.epochs;
    let dim = w.config.dim;
    let mut table = Table::new(
        &format!(
            "Table 3 — training time on youtube-like ({} nodes, {} edges, d={dim}, {epochs} epochs)",
            w.graph.num_nodes(),
            w.graph.num_edges()
        ),
        &[
            "system",
            "CPU threads",
            "workers",
            "train time",
            "preprocess",
            "speedup vs LINE",
            "micro-F1@2%",
        ],
    );
    // Single-core testbed: the paper's GPU-parallel speedups appear in the
    // projected column (critical-path model over measured per-stage times;
    // see metrics::TrainStats::projected_parallel_secs).

    // LINE (the speedup denominator)
    let line_cfg = LineConfig {
        dim,
        epochs,
        threads: 4,
        walk_length: w.config.walk_length,
        augmentation_distance: w.config.augmentation_distance,
        ..Default::default()
    };
    let line = LineBaseline::train(&w.graph, &line_cfg)?;
    let line_secs = line.stats.train_secs;
    let f1 = classify(&line.embeddings, &w.graph, 0.02, 7).micro_f1;
    table.row(&[
        "LINE".into(),
        "4".into(),
        "-".into(),
        human_secs(line_secs),
        human_secs(line.stats.preprocess_secs),
        "1.0x".into(),
        format!("{:.1}%", f1 * 100.0),
    ]);

    // DeepWalk
    let dw_cfg = DeepWalkConfig {
        dim,
        walks_per_node: (epochs * w.graph.num_edges()
            / (w.graph.num_nodes() * 20).max(1))
        .clamp(2, 40),
        walk_length: 20,
        window: w.config.augmentation_distance,
        threads: 4,
        ..Default::default()
    };
    let dw = DeepWalkBaseline::train(&w.graph, &dw_cfg)?;
    let f1 = classify(&dw.embeddings, &w.graph, 0.02, 7).micro_f1;
    table.row(&[
        "DeepWalk".into(),
        "4".into(),
        "-".into(),
        human_secs(dw.stats.train_secs),
        human_secs(dw.stats.preprocess_secs),
        format!("{:.1}x", line_secs / dw.stats.train_secs),
        format!("{:.1}%", f1 * 100.0),
    ]);

    // node2vec — per-edge alias preprocessing dominates, like the paper's
    // 25.9 hrs preprocessing row; walk budget matched to the epoch budget.
    let n2v_cfg = Node2VecConfig {
        dim,
        walks_per_node: (epochs * w.graph.num_edges()
            / (w.graph.num_nodes() * 20).max(1))
        .clamp(2, 40),
        walk_length: 20,
        window: w.config.augmentation_distance,
        threads: 4,
        ..Default::default()
    };
    let n2v = Node2VecBaseline::train(&w.graph, &n2v_cfg)?;
    let f1 = classify(&n2v.embeddings, &w.graph, 0.02, 7).micro_f1;
    table.row(&[
        "node2vec".into(),
        "4".into(),
        "-".into(),
        human_secs(n2v.stats.train_secs),
        human_secs(n2v.stats.preprocess_secs),
        format!("{:.1}x", line_secs / n2v.stats.train_secs),
        format!("{:.1}%", f1 * 100.0),
    ]);

    // Mini-batch "GPU" (OpenNE-like) — cap its budget at tiny scale or it
    // runs forever, exactly like the paper's "> 1 day" row.
    let mb_epochs = if scale == Scale::Tiny { epochs } else { epochs.min(5) };
    let mb_cfg = MinibatchConfig { dim, epochs: mb_epochs, ..Default::default() };
    let mb = MinibatchGpuBaseline::train(&w.graph, &mb_cfg)?;
    let mb_secs_scaled = mb.stats.train_secs * epochs as f64 / mb_epochs as f64;
    table.row(&[
        "LINE in OpenNE (mini-batch GPU)".into(),
        "1".into(),
        "1".into(),
        format!("{} (extrapolated)", human_secs(mb_secs_scaled)),
        human_secs(mb.stats.preprocess_secs),
        format!("{:.2}x", line_secs / mb_secs_scaled),
        "-".into(),
    ]);

    // GraphVite, 1 worker and 4 workers — measured single-core wall clock
    // plus the parallel-hardware projection.
    for workers in [1usize, 4] {
        let mut cfg = w.config.clone();
        cfg.num_workers = workers;
        cfg.num_samplers = workers + 1;
        let collab = cfg.collaboration;
        let mut trainer = Trainer::new(w.graph.clone(), cfg)?;
        let r = trainer.train()?;
        let f1 = classify(&r.embeddings, &w.graph, 0.02, 7).micro_f1;
        let projected = r.stats.projected_parallel_secs(workers, collab);
        table.row(&[
            format!("GraphVite ({workers} worker{})", if workers > 1 { "s" } else { "" }),
            format!("{}", workers + 1),
            format!("{workers}"),
            format!(
                "{} ({} projected)",
                human_secs(r.stats.train_secs),
                human_secs(projected)
            ),
            human_secs(r.stats.preprocess_secs),
            format!(
                "{:.1}x ({:.1}x projected)",
                line_secs / r.stats.train_secs,
                line_secs / projected.max(1e-9)
            ),
            format!("{:.1}%", f1 * 100.0),
        ]);
    }

    table.print();
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tiny_scale_runs() {
        run(Scale::Tiny).unwrap();
    }
}
