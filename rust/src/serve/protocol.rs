//! Wire protocol for `graphvite serve`: length-prefixed frames over TCP.
//!
//! Every message is one frame: a `u32` little-endian payload length
//! followed by the payload (framing shared with the training transport
//! via [`crate::net`]). Payloads are flat little-endian structs —
//! no self-describing encoding, so every decode path bounds-checks
//! against the declared limits *and* the actual payload length before
//! allocating (the same fail-loud discipline as the file loaders: a
//! hostile length field must produce `Err`, never an over-allocation).
//!
//! ```text
//! request  payload: [op u8]
//!   op=1 TOPK: [1][flags u8 = 0][k u16][nq u32][nq × node-id u32]
//!   op=2 INFO: [2]
//! response payload: [status u8]
//!   status=0 ok TOPK: [0][nq u32] then per query [m u32][m × (id u32, score f32)]
//!   status=0 ok INFO: [0][num_nodes u64][dim u32][generation u64]
//!   status=1 error:   [1][len u32][len × utf8 byte]
//! ```

use std::io::{Read, Write};

use anyhow::{bail, Result};

use crate::net::{self, Cursor};

/// Frame payload cap: a full response for `MAX_QUERIES × MAX_K` results
/// fits well under this, and no handshake can make a peer allocate more.
pub const MAX_FRAME: usize = 16 << 20;
/// Per-query top-k cap.
pub const MAX_K: usize = 1024;
/// Batched queries per request cap.
pub const MAX_QUERIES: usize = 8192;

const OP_TOPK: u8 = 1;
const OP_INFO: u8 = 2;
const STATUS_OK: u8 = 0;
const STATUS_ERR: u8 = 1;

/// A client request.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Request {
    /// Batched "top-k neighbors of each node" query.
    TopK { k: usize, nodes: Vec<u32> },
    /// Server/index metadata (also surfaces the hot-reload generation).
    Info,
}

/// A server response.
#[derive(Debug, Clone, PartialEq)]
pub enum Response {
    /// Per-query ranked `(node, score)` lists, parallel to the request's
    /// `nodes`.
    TopK { results: Vec<Vec<(u32, f32)>> },
    Info { num_nodes: u64, dim: u32, generation: u64 },
    Error(String),
}

/// Write one frame (length prefix + payload) under this protocol's cap.
pub fn write_frame(w: &mut impl Write, payload: &[u8]) -> Result<()> {
    net::write_frame(w, payload, MAX_FRAME)
}

/// Read one frame; `Ok(None)` on clean EOF at a frame boundary.
pub fn read_frame(r: &mut impl Read) -> Result<Option<Vec<u8>>> {
    net::read_frame(r, MAX_FRAME)
}

pub fn encode_request(req: &Request) -> Vec<u8> {
    match req {
        Request::TopK { k, nodes } => {
            let mut out = Vec::with_capacity(8 + nodes.len() * 4);
            out.push(OP_TOPK);
            out.push(0); // flags
            out.extend_from_slice(&(*k as u16).to_le_bytes());
            out.extend_from_slice(&(nodes.len() as u32).to_le_bytes());
            for v in nodes {
                out.extend_from_slice(&v.to_le_bytes());
            }
            out
        }
        Request::Info => vec![OP_INFO],
    }
}

pub fn decode_request(payload: &[u8]) -> Result<Request> {
    let mut c = Cursor::new(payload);
    let req = match c.u8()? {
        OP_TOPK => {
            let flags = c.u8()?;
            if flags != 0 {
                bail!("unknown topk request flags {flags:#x}");
            }
            let k = c.u16()? as usize;
            if k == 0 || k > MAX_K {
                bail!("k={k} out of range 1..={MAX_K}");
            }
            let nq = c.u32()? as usize;
            if nq == 0 || nq > MAX_QUERIES {
                bail!("query count {nq} out of range 1..={MAX_QUERIES}");
            }
            // exact-length check before allocating for the id list
            c.expect_remaining(nq * 4)?;
            let mut nodes = Vec::with_capacity(nq);
            for _ in 0..nq {
                nodes.push(c.u32()?);
            }
            Request::TopK { k, nodes }
        }
        OP_INFO => Request::Info,
        op => bail!("unknown request opcode {op}"),
    };
    c.finish()?;
    Ok(req)
}

pub fn encode_response(resp: &Response) -> Vec<u8> {
    match resp {
        Response::TopK { results } => {
            let mut out = vec![STATUS_OK];
            out.extend_from_slice(&(results.len() as u32).to_le_bytes());
            for r in results {
                out.extend_from_slice(&(r.len() as u32).to_le_bytes());
                for (id, score) in r {
                    out.extend_from_slice(&id.to_le_bytes());
                    out.extend_from_slice(&score.to_le_bytes());
                }
            }
            out
        }
        Response::Info { num_nodes, dim, generation } => {
            let mut out = vec![STATUS_OK];
            out.extend_from_slice(&num_nodes.to_le_bytes());
            out.extend_from_slice(&dim.to_le_bytes());
            out.extend_from_slice(&generation.to_le_bytes());
            out
        }
        Response::Error(msg) => {
            let bytes = msg.as_bytes();
            let mut out = vec![STATUS_ERR];
            out.extend_from_slice(&(bytes.len() as u32).to_le_bytes());
            out.extend_from_slice(bytes);
            out
        }
    }
}

/// Decode a response. The caller says which request it sent (`topk`),
/// since ok-payloads are not self-describing.
pub fn decode_response(payload: &[u8], topk: bool) -> Result<Response> {
    let mut c = Cursor::new(payload);
    let resp = match c.u8()? {
        STATUS_OK if topk => {
            let nq = c.u32()? as usize;
            if nq > MAX_QUERIES {
                bail!("response declares {nq} queries (cap {MAX_QUERIES})");
            }
            let mut results = Vec::with_capacity(nq);
            for _ in 0..nq {
                let m = c.u32()? as usize;
                if m > MAX_K {
                    bail!("response declares {m} results for one query (cap {MAX_K})");
                }
                c.expect_remaining(m * 8)?;
                let mut row = Vec::with_capacity(m);
                for _ in 0..m {
                    let id = c.u32()?;
                    let score = f32::from_le_bytes(c.bytes(4)?.try_into().unwrap());
                    row.push((id, score));
                }
                results.push(row);
            }
            Response::TopK { results }
        }
        STATUS_OK => {
            let num_nodes = c.u64()?;
            let dim = c.u32()?;
            let generation = c.u64()?;
            Response::Info { num_nodes, dim, generation }
        }
        STATUS_ERR => {
            let len = c.u32()? as usize;
            let bytes = c.bytes(len)?;
            Response::Error(String::from_utf8_lossy(bytes).into_owned())
        }
        s => bail!("unknown response status {s}"),
    };
    c.finish()?;
    Ok(resp)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_roundtrip() {
        for req in [
            Request::TopK { k: 10, nodes: vec![1, 2, 3, 0xFFFF_FFFF] },
            Request::Info,
        ] {
            assert_eq!(decode_request(&encode_request(&req)).unwrap(), req);
        }
    }

    #[test]
    fn response_roundtrip() {
        let resp = Response::TopK {
            results: vec![vec![(7, 0.5), (3, 0.25)], vec![], vec![(0, -1.0)]],
        };
        assert_eq!(decode_response(&encode_response(&resp), true).unwrap(), resp);
        let info = Response::Info { num_nodes: 9, dim: 8, generation: 3 };
        assert_eq!(decode_response(&encode_response(&info), false).unwrap(), info);
        let err = Response::Error("node 99 out of range".into());
        assert_eq!(decode_response(&encode_response(&err), true).unwrap(), err);
    }

    #[test]
    fn frame_roundtrip_and_eof() {
        let mut buf = Vec::new();
        write_frame(&mut buf, b"hello").unwrap();
        write_frame(&mut buf, b"").unwrap();
        let mut r = &buf[..];
        assert_eq!(read_frame(&mut r).unwrap().unwrap(), b"hello");
        assert_eq!(read_frame(&mut r).unwrap().unwrap(), b"");
        assert!(read_frame(&mut r).unwrap().is_none(), "clean EOF");
    }

    #[test]
    fn corrupt_messages_fail_loudly() {
        // oversized frame length cannot over-allocate
        let mut huge = Vec::new();
        huge.extend_from_slice(&(u32::MAX).to_le_bytes());
        assert!(read_frame(&mut &huge[..]).is_err());
        // truncated id list
        let mut req = encode_request(&Request::TopK { k: 5, nodes: vec![1, 2, 3] });
        req.truncate(req.len() - 2);
        assert!(decode_request(&req).is_err());
        // trailing garbage
        let mut req = encode_request(&Request::Info);
        req.push(0);
        assert!(decode_request(&req).is_err());
        // k and nq range checks
        assert!(decode_request(&encode_request(&Request::TopK { k: 0, nodes: vec![1] })).is_err());
        let huge_nq = {
            let mut p = vec![1u8, 0, 5, 0];
            p.extend_from_slice(&(u32::MAX).to_le_bytes());
            p
        };
        assert!(decode_request(&huge_nq).is_err());
        // unknown opcode
        assert!(decode_request(&[9]).is_err());
    }
}
