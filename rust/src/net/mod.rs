//! Length-prefixed TCP framing shared by every wire surface of the
//! binary (`graphvite serve` and the coordinator↔worker transport).
//!
//! Every message is one *frame*: a `u32` little-endian payload length
//! followed by the payload. Payloads are flat little-endian structs — no
//! self-describing encoding — so every decoder bounds-checks against its
//! declared limits *and* the actual payload length before allocating
//! (the same fail-loud discipline as the file loaders: a hostile length
//! field must produce `Err`, never an over-allocation, and a decoded
//! message must consume its whole payload).
//!
//! Two frame caps cover the two traffic classes:
//! * [`MAX_CONTROL_FRAME`] — handshakes and other small control
//!   messages. A peer that has not authenticated itself as a worker yet
//!   can never make us allocate more than this.
//! * [`MAX_DATA_FRAME`] — partition shipments and results, which carry
//!   whole padded partitions of f32 rows.
//!
//! `graphvite serve` keeps its own historical cap
//! ([`crate::serve::protocol::MAX_FRAME`]) and delegates to the generic
//! reader/writer here.

pub mod compress;

use std::io::{Read, Write};

use anyhow::{bail, Result};

/// Frame cap for handshake/control messages (1 MiB): an unauthenticated
/// peer cannot make either side allocate more than this.
pub const MAX_CONTROL_FRAME: usize = 1 << 20;

/// Frame cap for data messages (1 GiB): bounds one shipped partition
/// (padded rows × dim × 4 bytes) with room to spare.
pub const MAX_DATA_FRAME: usize = 1 << 30;

/// Write one frame (length prefix + payload), bounded by `cap`.
pub fn write_frame(w: &mut impl Write, payload: &[u8], cap: usize) -> Result<()> {
    if payload.len() > cap {
        bail!("frame payload {} exceeds cap {cap}", payload.len());
    }
    w.write_all(&(payload.len() as u32).to_le_bytes())?;
    w.write_all(payload)?;
    w.flush()?;
    Ok(())
}

/// Read one frame bounded by `cap`; `Ok(None)` on clean EOF at a frame
/// boundary. A declared length past the cap is rejected *before* any
/// allocation.
pub fn read_frame(r: &mut impl Read, cap: usize) -> Result<Option<Vec<u8>>> {
    let mut len_buf = [0u8; 4];
    match r.read_exact(&mut len_buf) {
        Ok(()) => {}
        Err(e) if e.kind() == std::io::ErrorKind::UnexpectedEof => return Ok(None),
        Err(e) => return Err(e.into()),
    }
    let len = u32::from_le_bytes(len_buf) as usize;
    if len > cap {
        bail!("peer declared a {len}-byte frame (cap {cap})");
    }
    let mut payload = vec![0u8; len];
    r.read_exact(&mut payload)?;
    Ok(Some(payload))
}

/// Bounds-checked little-endian reader over a payload slice. Decoders
/// call [`Self::finish`] last so trailing garbage is rejected, and
/// [`Self::expect_remaining`] before any length-driven allocation.
pub struct Cursor<'a> {
    buf: &'a [u8],
    at: usize,
}

impl<'a> Cursor<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        Cursor { buf, at: 0 }
    }

    pub fn bytes(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.buf.len() - self.at < n {
            bail!("message truncated: wanted {n} more bytes, have {}", self.buf.len() - self.at);
        }
        let out = &self.buf[self.at..self.at + n];
        self.at += n;
        Ok(out)
    }

    pub fn u8(&mut self) -> Result<u8> {
        Ok(self.bytes(1)?[0])
    }

    pub fn u16(&mut self) -> Result<u16> {
        Ok(u16::from_le_bytes(self.bytes(2)?.try_into().unwrap()))
    }

    pub fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.bytes(4)?.try_into().unwrap()))
    }

    pub fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.bytes(8)?.try_into().unwrap()))
    }

    pub fn i32(&mut self) -> Result<i32> {
        Ok(i32::from_le_bytes(self.bytes(4)?.try_into().unwrap()))
    }

    pub fn f32(&mut self) -> Result<f32> {
        Ok(f32::from_le_bytes(self.bytes(4)?.try_into().unwrap()))
    }

    /// Require exactly-`n`-more bytes *without* consuming them (the
    /// pre-allocation guard for variable-length sections).
    pub fn expect_remaining(&self, n: usize) -> Result<()> {
        let have = self.buf.len() - self.at;
        if have < n {
            bail!("message truncated: section needs {n} bytes, have {have}");
        }
        Ok(())
    }

    /// Reject trailing garbage — a decoded message must consume its
    /// whole payload.
    pub fn finish(self) -> Result<()> {
        if self.at != self.buf.len() {
            bail!("{} trailing bytes after message", self.buf.len() - self.at);
        }
        Ok(())
    }
}

/// Append `xs` as a `u32` length prefix plus raw little-endian f32s.
pub fn put_f32s(out: &mut Vec<u8>, xs: &[f32]) {
    out.extend_from_slice(&(xs.len() as u32).to_le_bytes());
    for &x in xs {
        out.extend_from_slice(&x.to_le_bytes());
    }
}

/// Decode a [`put_f32s`] section into `out` (cleared first; the existing
/// allocation is reused). Exact-length checked before reserving.
pub fn get_f32s(c: &mut Cursor<'_>, out: &mut Vec<f32>) -> Result<()> {
    let n = c.u32()? as usize;
    c.expect_remaining(n * 4)?;
    out.clear();
    out.reserve(n);
    for _ in 0..n {
        out.push(c.f32()?);
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn frame_roundtrip_eof_and_caps() {
        let mut buf = Vec::new();
        write_frame(&mut buf, b"abc", MAX_CONTROL_FRAME).unwrap();
        write_frame(&mut buf, b"", MAX_CONTROL_FRAME).unwrap();
        let mut r = &buf[..];
        assert_eq!(read_frame(&mut r, MAX_CONTROL_FRAME).unwrap().unwrap(), b"abc");
        assert_eq!(read_frame(&mut r, MAX_CONTROL_FRAME).unwrap().unwrap(), b"");
        assert!(read_frame(&mut r, MAX_CONTROL_FRAME).unwrap().is_none(), "clean EOF");
        // a declared length past the cap is rejected before allocation
        let mut huge = Vec::new();
        huge.extend_from_slice(&u32::MAX.to_le_bytes());
        assert!(read_frame(&mut &huge[..], MAX_DATA_FRAME).is_err());
        // the writer enforces the same cap
        assert!(write_frame(&mut Vec::new(), &[0u8; 8], 4).is_err());
        // a frame legal under one cap is rejected under a smaller one
        let mut mid = Vec::new();
        write_frame(&mut mid, &[7u8; 64], MAX_DATA_FRAME).unwrap();
        assert!(read_frame(&mut &mid[..], 16).is_err());
    }

    /// Yields at most `chunk` bytes per `read` call — models a TCP stream
    /// delivering a frame across many partial reads.
    struct Fragmented<'a> {
        buf: &'a [u8],
        at: usize,
        chunk: usize,
    }

    impl Read for Fragmented<'_> {
        fn read(&mut self, out: &mut [u8]) -> std::io::Result<usize> {
            let n = out.len().min(self.chunk).min(self.buf.len() - self.at);
            out[..n].copy_from_slice(&self.buf[self.at..self.at + n]);
            self.at += n;
            Ok(n)
        }
    }

    /// Serves a 4-byte length header declaring `declared` bytes, then
    /// fails the first payload read with a sentinel error. Lets boundary
    /// tests prove the cap check *passed* (the sentinel surfaces, not the
    /// cap bail) without materializing a gigabyte of payload.
    struct HeaderThenBail {
        header: Vec<u8>,
        at: usize,
    }

    impl HeaderThenBail {
        fn declaring(declared: u32) -> Self {
            HeaderThenBail { header: declared.to_le_bytes().to_vec(), at: 0 }
        }
    }

    impl Read for HeaderThenBail {
        fn read(&mut self, out: &mut [u8]) -> std::io::Result<usize> {
            if self.at == self.header.len() {
                return Err(std::io::Error::other("payload read reached"));
            }
            let n = out.len().min(self.header.len() - self.at);
            out[..n].copy_from_slice(&self.header[self.at..self.at + n]);
            self.at += n;
            Ok(n)
        }
    }

    #[test]
    fn frames_survive_fragmented_and_coalesced_reads() {
        // deterministic pseudo-random frame sizes/contents (LCG — no
        // external rand dependency) written back-to-back into one buffer,
        // i.e. maximally coalesced on the wire
        let mut state = 0x2545_f491_4f6c_dd1du64;
        let mut next = move || {
            state = state.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            (state >> 33) as u32
        };
        let frames: Vec<Vec<u8>> = (0..32)
            .map(|i| {
                let len = if i == 0 { 0 } else { (next() % 4096) as usize };
                (0..len).map(|_| next() as u8).collect()
            })
            .collect();
        let mut wire = Vec::new();
        for f in &frames {
            write_frame(&mut wire, f, MAX_CONTROL_FRAME).unwrap();
        }
        // decode the coalesced buffer once whole, then again through
        // pathological fragmentation (1- and 3-byte reads split length
        // prefixes and payloads alike)
        for chunk in [usize::MAX, 1, 3] {
            let mut r = Fragmented { buf: &wire, at: 0, chunk };
            for f in &frames {
                assert_eq!(read_frame(&mut r, MAX_CONTROL_FRAME).unwrap().unwrap(), *f);
            }
            assert!(read_frame(&mut r, MAX_CONTROL_FRAME).unwrap().is_none(), "clean EOF");
        }
        // EOF mid-prefix and mid-payload are hard errors, not Ok(None)
        assert!(read_frame(&mut &wire[..2], MAX_CONTROL_FRAME).is_err(), "EOF inside prefix");
        // walk to the first non-empty frame and truncate its final byte
        let mut at = 0;
        for f in &frames {
            if !f.is_empty() {
                assert!(
                    read_frame(&mut &wire[at..at + 4 + f.len() - 1], MAX_CONTROL_FRAME).is_err(),
                    "EOF inside payload"
                );
                break;
            }
            at += 4;
        }
    }

    #[test]
    fn control_cap_boundary_is_exact() {
        // a frame of exactly MAX_CONTROL_FRAME bytes round-trips...
        let payload = vec![0xA5u8; MAX_CONTROL_FRAME];
        let mut wire = Vec::new();
        write_frame(&mut wire, &payload, MAX_CONTROL_FRAME).unwrap();
        let got = read_frame(&mut &wire[..], MAX_CONTROL_FRAME).unwrap().unwrap();
        assert_eq!(got, payload);
        // ...while one byte more is refused by the writer and the reader
        assert!(write_frame(&mut Vec::new(), &vec![0u8; MAX_CONTROL_FRAME + 1], MAX_CONTROL_FRAME).is_err());
        let mut r = HeaderThenBail::declaring(MAX_CONTROL_FRAME as u32 + 1);
        let err = read_frame(&mut r, MAX_CONTROL_FRAME).unwrap_err();
        assert!(err.to_string().contains("cap"), "cap bail, not a payload read: {err}");
    }

    #[test]
    fn data_cap_boundary_is_exact() {
        // declared == MAX_DATA_FRAME passes the cap check: the sentinel
        // I/O error from the first payload read surfaces, proving we got
        // past the length validation without shipping a real gigabyte
        let mut r = HeaderThenBail::declaring(MAX_DATA_FRAME as u32);
        let err = read_frame(&mut r, MAX_DATA_FRAME).unwrap_err();
        assert!(err.to_string().contains("payload read reached"), "boundary accepted: {err}");
        // declared == MAX_DATA_FRAME + 1 is rejected *before* any payload
        // read (HeaderThenBail would convert a read attempt into a
        // different error) and before any allocation
        let mut r = HeaderThenBail::declaring(MAX_DATA_FRAME as u32 + 1);
        let err = read_frame(&mut r, MAX_DATA_FRAME).unwrap_err();
        assert!(err.to_string().contains("cap"), "cap bail, not a payload read: {err}");
    }

    #[test]
    fn cursor_bounds_and_trailing_garbage() {
        let mut c = Cursor::new(&[1, 0, 0, 0, 9]);
        assert_eq!(c.u32().unwrap(), 1);
        assert!(c.expect_remaining(2).is_err());
        assert_eq!(c.u8().unwrap(), 9);
        assert!(c.u8().is_err(), "reading past the end fails");
        let c = Cursor::new(&[1, 2]);
        assert!(c.finish().is_err(), "unconsumed bytes are rejected");
    }

    #[test]
    fn f32_sections_roundtrip_bitwise() {
        let xs = [1.5f32, -0.0, f32::MIN_POSITIVE, 3.25e7];
        let mut buf = Vec::new();
        put_f32s(&mut buf, &xs);
        let mut c = Cursor::new(&buf);
        let mut out = Vec::new();
        get_f32s(&mut c, &mut out).unwrap();
        c.finish().unwrap();
        assert_eq!(
            out.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            xs.iter().map(|x| x.to_bits()).collect::<Vec<_>>()
        );
        // truncated section cannot over-allocate
        let mut c = Cursor::new(&buf[..buf.len() - 2]);
        assert!(get_f32s(&mut c, &mut out).is_err());
    }
}
