//! Figure 4 — performance curves over training epochs: node
//! classification F1 on the labelled graph and link-prediction AUC on a
//! held-out edge split. Shape: monotone-ish convergence, AUC well above
//! 0.9 by the end.

use anyhow::Result;

use crate::coordinator::Trainer;
use crate::eval::{link_prediction_auc, LinkSplit};
use crate::experiments::presets::{classify, Scale, Workload};
use crate::util::bench::Table;

pub fn run(scale: Scale) -> Result<()> {
    // ---- classification curve (Friendster-small analogue) ----
    let w = Workload::youtube_like(scale);
    let mut cfg = w.config.clone();
    // smaller pools => more checkpoints along the curve
    cfg.episode_size = (w.graph.num_edges() * cfg.epochs / (8 * cfg.num_workers)).max(2_000);
    let mut trainer = Trainer::new(w.graph.clone(), cfg)?;
    let mut points: Vec<(u64, f64, f64)> = Vec::new();
    {
        let graph = &w.graph;
        let mut cb = |done: u64, store: &crate::embedding::EmbeddingStore| {
            let rep = classify(store, graph, 0.02, 7);
            points.push((done, rep.micro_f1, rep.macro_f1));
        };
        trainer.train_with_callback(Some(&mut cb))?;
    }
    let total = points.last().map(|p| p.0).unwrap_or(1);
    let mut t = Table::new(
        "Figure 4a — classification F1 vs training progress (youtube-like)",
        &["% of training", "micro-F1@2%", "macro-F1@2%"],
    );
    for (done, micro, macro_) in &points {
        t.row(&[
            format!("{:.0}%", 100.0 * *done as f64 / total as f64),
            format!("{:.2}", micro * 100.0),
            format!("{:.2}", macro_ * 100.0),
        ]);
    }
    t.print();

    // ---- link prediction curve (Hyperlink-PLD analogue) ----
    // NOTE: a pure BA graph has no homophily, so cosine link prediction
    // saturates at 0.5 on it; the web-graph analogue needs the community
    // overlay for edges to be predictable (like Hyperlink-PLD's locality).
    let full = crate::graph::generators::youtube_like(scale.youtube_nodes(), 10, 0xAB);
    let split = LinkSplit::new(&full, 0.01, 3);
    let mut cfg = w.config.clone();
    // full epoch budget: link structure needs ~1k updates/node before the
    // AUC curve lifts off (see EXPERIMENTS.md on sample budgets)
    cfg.episode_size =
        (split.train_graph.num_edges() * cfg.epochs / (8 * cfg.num_workers)).max(2_000);
    let mut trainer = Trainer::new(split.train_graph.clone(), cfg)?;
    let mut points: Vec<(u64, f64)> = Vec::new();
    {
        let split = &split;
        let mut cb = |done: u64, store: &crate::embedding::EmbeddingStore| {
            points.push((done, link_prediction_auc(store, split)));
        };
        trainer.train_with_callback(Some(&mut cb))?;
    }
    let total = points.last().map(|p| p.0).unwrap_or(1);
    let mut t = Table::new(
        "Figure 4b — link prediction AUC vs training progress (hyperlink-like)",
        &["% of training", "AUC"],
    );
    for (done, auc) in &points {
        t.row(&[
            format!("{:.0}%", 100.0 * *done as f64 / total as f64),
            format!("{:.4}", auc),
        ]);
    }
    t.print();
    Ok(())
}
