//! The four shuffle algorithms of Table 7.
//!
//! Samples emitted by the same random walk are heavily correlated (they
//! share source/target nodes); training on them consecutively degrades
//! ASGD. The paper compares:
//!
//! * **None** — train in generation order (what DeepWalk/node2vec do),
//! * **Random** — full Fisher–Yates after generation (best decorrelation,
//!   but random access over the whole pool thrashes the cache),
//! * **IndexMapping** — precomputed random permutation applied at append
//!   time (saves RNG work, still random writes),
//! * **Pseudo** — GraphVite's contribution: split the pool into `s`
//!   blocks (s = augmentation distance), append sample `i` to block
//!   `i mod s` *sequentially*, concatenate. Correlated samples (which
//!   appear within a window of ~s) land in different blocks, writes stay
//!   sequential and cache-friendly.

use crate::util::rng::Rng;

/// Which shuffle to run on a filled pool.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ShuffleKind {
    None,
    Random,
    IndexMapping,
    Pseudo,
}

impl ShuffleKind {
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "none" => Some(Self::None),
            "random" => Some(Self::Random),
            "index-mapping" | "index_mapping" | "indexmap" => Some(Self::IndexMapping),
            "pseudo" => Some(Self::Pseudo),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Self::None => "none",
            Self::Random => "random",
            Self::IndexMapping => "index-mapping",
            Self::Pseudo => "pseudo",
        }
    }
}

/// Apply `kind` to `pool` in place. `stride` is the pseudo-shuffle block
/// count (GraphVite uses the augmentation distance s).
pub fn shuffle(kind: ShuffleKind, pool: &mut Vec<(u32, u32)>, stride: usize, rng: &mut Rng) {
    match kind {
        ShuffleKind::None => {}
        ShuffleKind::Random => rng.shuffle(pool),
        ShuffleKind::IndexMapping => index_mapping_shuffle(pool, rng),
        ShuffleKind::Pseudo => pseudo_shuffle(pool, stride.max(2)),
    }
}

/// Index-mapping baseline: apply a precomputed random permutation with
/// random-access writes into a fresh buffer (models the paper's
/// "preprocesses a random mapping on the indexes" algorithm — same memory
/// access pattern as a gather by permutation).
pub fn index_mapping_shuffle(pool: &mut Vec<(u32, u32)>, rng: &mut Rng) {
    let perm = rng.permutation(pool.len());
    let mut out = vec![(0u32, 0u32); pool.len()];
    for (i, &p) in perm.iter().enumerate() {
        out[p as usize] = pool[i]; // scattered writes — cache-hostile
    }
    *pool = out;
}

/// GraphVite's pseudo shuffle: deal samples round-robin into `s`
/// sequential-append blocks, then concatenate the blocks.
///
/// Sample `i` goes to block `i % s` at position `i / s`; the final pool is
/// `block_0 ++ block_1 ++ … ++ block_{s-1}`. Consecutive (correlated)
/// samples end up ~pool_len/s apart. All writes are sequential appends —
/// this is the cache-friendliness the paper's Table 7 speed win comes from.
pub fn pseudo_shuffle(pool: &mut Vec<(u32, u32)>, s: usize) {
    if pool.len() < 2 || s < 2 {
        return;
    }
    let n = pool.len();
    let mut blocks: Vec<Vec<(u32, u32)>> = (0..s)
        .map(|b| Vec::with_capacity(n / s + 1 + usize::from(b == 0)))
        .collect();
    for (i, &sample) in pool.iter().enumerate() {
        blocks[i % s].push(sample); // sequential append per block
    }
    pool.clear();
    for b in blocks {
        pool.extend_from_slice(&b);
    }
}

/// Decorrelation metric used by tests & the Table 7 harness: the fraction
/// of adjacent pool entries that share an endpoint. Lower is better.
pub fn adjacent_correlation(pool: &[(u32, u32)]) -> f64 {
    if pool.len() < 2 {
        return 0.0;
    }
    let shared = pool
        .windows(2)
        .filter(|w| {
            let (a, b) = (w[0], w[1]);
            a.0 == b.0 || a.0 == b.1 || a.1 == b.0 || a.1 == b.1
        })
        .count();
    shared as f64 / (pool.len() - 1) as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    fn correlated_pool(n: usize) -> Vec<(u32, u32)> {
        // Runs of s=4 samples sharing a source (like walk output). Targets
        // are hashed to be diverse — real walks visit ~distinct nodes, and
        // a periodic target pattern (e.g. i % 4) would alias with the
        // round-robin stride and make any dealing look correlated.
        (0..n)
            .map(|i| {
                let t = (i as u32).wrapping_mul(2654435761) >> 16;
                ((i / 4) as u32, t + 1_000_000)
            })
            .collect()
    }

    fn is_permutation(a: &[(u32, u32)], b: &[(u32, u32)]) -> bool {
        let mut x = a.to_vec();
        let mut y = b.to_vec();
        x.sort_unstable();
        y.sort_unstable();
        x == y
    }

    #[test]
    fn all_shuffles_are_permutations() {
        for kind in [
            ShuffleKind::None,
            ShuffleKind::Random,
            ShuffleKind::IndexMapping,
            ShuffleKind::Pseudo,
        ] {
            let orig = correlated_pool(1000);
            let mut pool = orig.clone();
            let mut rng = Rng::new(1);
            shuffle(kind, &mut pool, 4, &mut rng);
            assert!(is_permutation(&orig, &pool), "{kind:?} lost samples");
        }
    }

    #[test]
    fn pseudo_shuffle_exact_layout() {
        let mut pool: Vec<(u32, u32)> = (0..6).map(|i| (i, i)).collect();
        pseudo_shuffle(&mut pool, 2);
        let ids: Vec<u32> = pool.iter().map(|&(u, _)| u).collect();
        assert_eq!(ids, vec![0, 2, 4, 1, 3, 5]);
    }

    #[test]
    fn pseudo_decorrelates_walk_runs() {
        let orig = correlated_pool(4000);
        let before = adjacent_correlation(&orig);
        let mut pool = orig.clone();
        pseudo_shuffle(&mut pool, 4);
        let after = adjacent_correlation(&pool);
        assert!(before > 0.7, "before={before}");
        assert!(after < 0.1 * before, "after={after} before={before}");
    }

    #[test]
    fn random_decorrelates_too() {
        let orig = correlated_pool(4000);
        let mut pool = orig.clone();
        let mut rng = Rng::new(2);
        shuffle(ShuffleKind::Random, &mut pool, 4, &mut rng);
        assert!(adjacent_correlation(&pool) < 0.05);
    }

    #[test]
    fn none_is_identity() {
        let orig = correlated_pool(100);
        let mut pool = orig.clone();
        let mut rng = Rng::new(3);
        shuffle(ShuffleKind::None, &mut pool, 4, &mut rng);
        assert_eq!(pool, orig);
    }

    #[test]
    fn small_pools_safe() {
        for kind in [ShuffleKind::Random, ShuffleKind::Pseudo, ShuffleKind::IndexMapping] {
            for n in 0..3 {
                let mut pool: Vec<(u32, u32)> = (0..n).map(|i| (i, i)).collect();
                let mut rng = Rng::new(4);
                shuffle(kind, &mut pool, 4, &mut rng);
                assert_eq!(pool.len(), n as usize);
            }
        }
    }

    #[test]
    fn parse_names_roundtrip() {
        for kind in [
            ShuffleKind::None,
            ShuffleKind::Random,
            ShuffleKind::IndexMapping,
            ShuffleKind::Pseudo,
        ] {
            assert_eq!(ShuffleKind::parse(kind.name()), Some(kind));
        }
        assert_eq!(ShuffleKind::parse("bogus"), None);
    }
}
