//! Embedding I/O corruption suite, mirroring `tests/ondisk.rs` for the
//! `.gvpk` graph format: every loader (`GRVITE01` binary, `.gvemb`
//! packed, word2vec text, and the magic-sniffing auto loader) must treat
//! its input as hostile. A corrupt or truncated file returns `Err` —
//! never a panic, an out-of-bounds write, or a header-driven
//! multi-gigabyte allocation — and the error names what went wrong.

use graphvite::embedding::{
    load_embeddings, load_embeddings_auto, load_embeddings_gvemb, load_embeddings_text,
    save_embeddings, save_embeddings_binary, save_embeddings_gvemb, save_embeddings_text,
    EmbeddingStore, OutputFormat,
};

fn tmp(name: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join("graphvite_emb_io_tests");
    std::fs::create_dir_all(&dir).unwrap();
    dir.join(name)
}

fn store() -> EmbeddingStore {
    EmbeddingStore::init(40, 6, 13)
}

// ------------------------------------------------------------- binary --

#[test]
fn binary_truncation_and_trailing_garbage_fail_loud() {
    let p = tmp("base.bin");
    save_embeddings_binary(&store(), &p).unwrap();
    let bytes = std::fs::read(&p).unwrap();
    assert_eq!(bytes.len(), 24 + 2 * 40 * 6 * 4, "writer layout drifted");

    // bad magic
    let mut bad = bytes.clone();
    bad[0] ^= 0xFF;
    let q = tmp("magic.bin");
    std::fs::write(&q, &bad).unwrap();
    let err = load_embeddings(&q).unwrap_err().to_string();
    assert!(err.contains("magic"), "{err}");

    // shorter than the header
    let q = tmp("tiny.bin");
    std::fs::write(&q, &bytes[..10]).unwrap();
    let err = load_embeddings(&q).unwrap_err().to_string();
    assert!(err.contains("truncated"), "{err}");

    // truncated matrix payload
    let q = tmp("trunc.bin");
    std::fs::write(&q, &bytes[..bytes.len() - 7]).unwrap();
    let err = load_embeddings(&q).unwrap_err().to_string();
    assert!(err.contains("mismatch"), "{err}");

    // trailing garbage is as loud as truncation
    let mut bad = bytes.clone();
    bad.extend_from_slice(b"junk");
    let q = tmp("trail.bin");
    std::fs::write(&q, &bad).unwrap();
    let err = load_embeddings(&q).unwrap_err().to_string();
    assert!(err.contains("mismatch"), "{err}");
}

#[test]
fn binary_hostile_header_cannot_over_allocate() {
    let p = tmp("hostile.bin");
    save_embeddings_binary(&store(), &p).unwrap();
    let mut bytes = std::fs::read(&p).unwrap();

    // a huge node count is rejected against the real file length before
    // any allocation (n sits at offset 8)
    bytes[8..16].copy_from_slice(&(1u64 << 40).to_le_bytes());
    let q = tmp("huge_n.bin");
    std::fs::write(&q, &bytes).unwrap();
    assert!(load_embeddings(&q).is_err());

    // n*d*4 overflowing u64 is caught by the checked arithmetic, not a
    // wrapped length that happens to match
    bytes[8..16].copy_from_slice(&u64::MAX.to_le_bytes());
    bytes[16..24].copy_from_slice(&u64::MAX.to_le_bytes());
    let q = tmp("overflow.bin");
    std::fs::write(&q, &bytes).unwrap();
    let err = load_embeddings(&q).unwrap_err().to_string();
    assert!(err.contains("overflow"), "{err}");
}

// -------------------------------------------------------------- gvemb --

#[test]
fn gvemb_roundtrip_is_exact() {
    let e = store();
    let p = tmp("rt.gvemb");
    save_embeddings_gvemb(&e, &p).unwrap();
    let e2 = load_embeddings_gvemb(&p).unwrap();
    assert_eq!(e.vertex_matrix(), e2.vertex_matrix());
    assert_eq!(e.context_matrix(), e2.context_matrix());
    // saving again over the same path (the checkpoint hot-reload path)
    // replaces the file atomically and re-reads identically
    save_embeddings_gvemb(&e, &p).unwrap();
    let e3 = load_embeddings_gvemb(&p).unwrap();
    assert_eq!(e.vertex_matrix(), e3.vertex_matrix());
}

#[test]
fn gvemb_corruption_gauntlet() {
    let p = tmp("base.gvemb");
    save_embeddings_gvemb(&store(), &p).unwrap();
    let bytes = std::fs::read(&p).unwrap();
    assert_eq!(bytes.len(), 32 + 2 * 40 * 6 * 4, "writer layout drifted");

    let case = |name: &str, mutate: &dyn Fn(&mut Vec<u8>), needle: &str| {
        let mut b = bytes.clone();
        mutate(&mut b);
        let q = tmp(name);
        std::fs::write(&q, &b).unwrap();
        let err = load_embeddings_gvemb(&q).unwrap_err().to_string();
        assert!(err.contains(needle), "{name}: {err}");
    };

    case("magic.gvemb", &|b| b[0] = b'X', "magic");
    case("version.gvemb", &|b| b[4] = 0xFE, "version");
    case("flags.gvemb", &|b| b[24] |= 0x80, "flag");
    case("reserved.gvemb", &|b| b[28] = 1, "reserved");
    case("trunc.gvemb", &|b| b.truncate(b.len() - 5), "mismatch");
    case("trail.gvemb", &|b| b.extend_from_slice(b"xx"), "mismatch");
    case(
        "huge.gvemb",
        &|b| b[8..16].copy_from_slice(&(1u64 << 40).to_le_bytes()),
        "mismatch",
    );
    case(
        "overflow.gvemb",
        &|b| {
            b[8..16].copy_from_slice(&u64::MAX.to_le_bytes());
            b[16..24].copy_from_slice(&u64::MAX.to_le_bytes());
        },
        "overflow",
    );

    // vertex-only files (context flag clear) are valid at the shorter
    // exact length — and only at that length
    let mut vertex_only = bytes.clone();
    vertex_only[24..28].copy_from_slice(&0u32.to_le_bytes());
    vertex_only.truncate(32 + 40 * 6 * 4);
    let q = tmp("vertex_only.gvemb");
    std::fs::write(&q, &vertex_only).unwrap();
    let e = load_embeddings_gvemb(&q).unwrap();
    assert_eq!(e.num_nodes(), 40);
    assert!(e.context_matrix().iter().all(|&x| x == 0.0));

    let mut wrong_len = bytes;
    wrong_len[24..28].copy_from_slice(&0u32.to_le_bytes());
    let q = tmp("vertex_only_long.gvemb");
    std::fs::write(&q, &wrong_len).unwrap();
    let err = load_embeddings_gvemb(&q).unwrap_err().to_string();
    assert!(err.contains("mismatch"), "{err}");
}

// --------------------------------------------------------------- text --

#[test]
fn text_loader_rejects_malformed_rows() {
    // row id past the declared node count
    let p = tmp("oob.txt");
    std::fs::write(&p, "2 3\n0 1 2 3\n5 4 5 6\n").unwrap();
    let err = load_embeddings_text(&p).unwrap_err().to_string();
    assert!(err.contains("out of range"), "{err}");

    // short row
    let p = tmp("short.txt");
    std::fs::write(&p, "2 3\n0 1 2 3\n1 4 5\n").unwrap();
    let err = load_embeddings_text(&p).unwrap_err().to_string();
    assert!(err.contains("expected 3"), "{err}");

    // long row
    let p = tmp("long.txt");
    std::fs::write(&p, "2 3\n0 1 2 3 4\n1 4 5 6\n").unwrap();
    let err = load_embeddings_text(&p).unwrap_err().to_string();
    assert!(err.contains("more than"), "{err}");

    // duplicate row
    let p = tmp("dup.txt");
    std::fs::write(&p, "2 3\n0 1 2 3\n0 4 5 6\n").unwrap();
    let err = load_embeddings_text(&p).unwrap_err().to_string();
    assert!(err.contains("duplicate"), "{err}");

    // missing rows (values long enough to clear the min-size bound, so
    // this exercises the row count check specifically)
    let p = tmp("missing.txt");
    std::fs::write(&p, "3 3\n0 1.25 2.5 3.75\n1 4.25 5.5 6.75\n").unwrap();
    let err = load_embeddings_text(&p).unwrap_err().to_string();
    assert!(err.contains("2 rows"), "{err}");

    // unparseable id / value / header — Err, not panic
    for (name, body) in [
        ("badid.txt", "1 2\nx 1 2\n"),
        ("badval.txt", "1 2\n0 1 nope\n"),
        ("badhdr.txt", "one two\n"),
        ("widehdr.txt", "1 2 3\n0 1 2\n"),
        ("empty.txt", ""),
    ] {
        let p = tmp(name);
        std::fs::write(&p, body).unwrap();
        assert!(load_embeddings_text(&p).is_err(), "{name} must be rejected");
    }
}

#[test]
fn text_hostile_header_cannot_over_allocate() {
    // declares 10^12 × 10^3 floats in a 30-byte file: the pre-allocation
    // bound rejects it instead of trying to reserve terabytes
    let p = tmp("hostile.txt");
    std::fs::write(&p, "1000000000000 1000\n0 1 2\n").unwrap();
    let err = load_embeddings_text(&p).unwrap_err().to_string();
    assert!(err.contains("too small"), "{err}");
}

// --------------------------------------------------- auto + dispatcher --

#[test]
fn auto_loader_routes_by_magic_and_rejects_garbage() {
    let e = store();
    for (name, fmt) in [
        ("auto.bin", OutputFormat::Binary),
        ("auto.txt", OutputFormat::Text),
        ("auto.gvemb", OutputFormat::Gvemb),
    ] {
        let p = tmp(name);
        save_embeddings(&e, p.to_str().unwrap(), fmt).unwrap();
        let got = load_embeddings_auto(&p).unwrap();
        assert_eq!(got.num_nodes(), 40, "{name}");
        assert_eq!(got.dim(), 6, "{name}");
        assert_eq!(e.vertex_matrix(), got.vertex_matrix(), "{name}");
    }

    // gvemb bytes behind a .txt name still load as gvemb (magic wins)
    let p = tmp("disguised.txt");
    save_embeddings_gvemb(&e, &p).unwrap();
    assert_eq!(load_embeddings_auto(&p).unwrap().vertex_matrix(), e.vertex_matrix());

    // raw garbage fails through all three loaders with an Err
    let p = tmp("garbage.bin");
    std::fs::write(&p, &[0x7Fu8; 64]).unwrap();
    assert!(load_embeddings_auto(&p).is_err());
}

#[test]
fn format_resolution_is_case_insensitive_and_strict() {
    assert_eq!(OutputFormat::from_path("out/E.TXT").unwrap(), OutputFormat::Text);
    assert_eq!(OutputFormat::from_path("e.GVEMB").unwrap(), OutputFormat::Gvemb);
    assert_eq!(OutputFormat::from_path("e.Bin").unwrap(), OutputFormat::Binary);
    assert!(OutputFormat::from_path("e.npy").is_err());
    assert!(OutputFormat::from_path("no_extension").is_err());
    assert_eq!(OutputFormat::parse("GvEmb").unwrap(), OutputFormat::Gvemb);
    assert_eq!(OutputFormat::parse("BIN").unwrap(), OutputFormat::Binary);
    assert!(OutputFormat::parse("hdf5").is_err());
}
