//! Hierarchical softmax (Morin & Bengio; the word2vec/DeepWalk variant):
//! a Huffman tree over node frequencies replaces the output softmax. Each
//! leaf (node) is reached by a path of inner nodes; predicting `v` from
//! `u` costs O(log |V|) sigmoid updates along `v`'s path instead of a
//! negative-sampling draw.
//!
//! The original DeepWalk trains with hierarchical softmax; the GraphVite
//! paper singles it out ("DeepWalk uses both hierarchical softmax and
//! negative sampling, which could be more robust to few labeled data",
//! §4.4) as the reason DeepWalk edges ahead at 1–2% label fractions in
//! Table 4. This module lets the DeepWalk baseline reproduce that row
//! faithfully.

use crate::util::rng::Rng;

/// Huffman coding tree over `n` leaves with the given frequencies.
///
/// Inner nodes are numbered `0..n-1` and own one `dim`-sized parameter
/// row each (the `inner` matrix replaces the SGNS `context` matrix).
#[derive(Debug, Clone)]
pub struct HuffmanTree {
    /// codes[v] = left/right bits from root to leaf v (LSB-first order
    /// matches points[v]).
    codes: Vec<Vec<bool>>,
    /// points[v] = inner-node ids from root towards leaf v.
    points: Vec<Vec<u32>>,
    num_inner: usize,
}

impl HuffmanTree {
    /// Build from (positive) leaf frequencies — O(n log n).
    pub fn build(freqs: &[f32]) -> Self {
        let n = freqs.len();
        assert!(n >= 2, "huffman tree needs at least 2 leaves");
        // classic two-queue construction over nodes sorted by frequency
        let mut order: Vec<u32> = (0..n as u32).collect();
        order.sort_unstable_by(|&a, &b| {
            freqs[a as usize]
                .partial_cmp(&freqs[b as usize])
                .unwrap()
                .then(a.cmp(&b))
        });
        // node ids: 0..n = leaves (by sorted order), n.. = merges
        let mut weight: Vec<f64> = order.iter().map(|&v| freqs[v as usize] as f64).collect();
        weight.reserve(n - 1);
        let mut parent = vec![0usize; 2 * n - 1];
        let mut is_right = vec![false; 2 * n - 1];
        let (mut leaf_i, mut merge_i) = (0usize, n);
        let mut next = n;
        // pick the two smallest among remaining leaves and merges
        for _ in 0..n - 1 {
            let mut pick = |leaf_i: &mut usize, merge_i: &mut usize| -> usize {
                if *leaf_i < n && (*merge_i >= next || weight[*leaf_i] <= weight[*merge_i]) {
                    *leaf_i += 1;
                    *leaf_i - 1
                } else {
                    *merge_i += 1;
                    *merge_i - 1
                }
            };
            let a = pick(&mut leaf_i, &mut merge_i);
            let b = pick(&mut leaf_i, &mut merge_i);
            weight.push(weight[a] + weight[b]);
            parent[a] = next;
            parent[b] = next;
            is_right[b] = true;
            next += 1;
        }

        // read codes/points back from each leaf to the root (node 2n-2)
        let root = 2 * n - 2;
        let mut codes = vec![Vec::new(); n];
        let mut points = vec![Vec::new(); n];
        for (sorted_pos, &v) in order.iter().enumerate() {
            let mut code = Vec::new();
            let mut point = Vec::new();
            let mut node = sorted_pos;
            while node != root {
                code.push(is_right[node]);
                // inner-node parameter row id: merge id - n
                point.push((parent[node] - n) as u32);
                node = parent[node];
            }
            code.reverse();
            point.reverse();
            codes[v as usize] = code;
            points[v as usize] = point;
        }
        HuffmanTree { codes, points, num_inner: n - 1 }
    }

    pub fn num_leaves(&self) -> usize {
        self.codes.len()
    }

    /// Inner parameter rows needed (n - 1).
    pub fn num_inner(&self) -> usize {
        self.num_inner
    }

    /// Code length (path depth) of leaf `v`.
    pub fn depth(&self, v: u32) -> usize {
        self.codes[v as usize].len()
    }

    /// Root-to-leaf path of leaf `v`: (inner row, branch-right bit).
    pub fn path(&self, v: u32) -> impl Iterator<Item = (u32, bool)> + '_ {
        self.points[v as usize]
            .iter()
            .copied()
            .zip(self.codes[v as usize].iter().copied())
    }

    /// Mean code length weighted by frequency (≈ entropy; compactness
    /// diagnostic used by tests).
    pub fn mean_depth(&self, freqs: &[f32]) -> f64 {
        let total: f64 = freqs.iter().map(|&f| f as f64).sum();
        self.codes
            .iter()
            .zip(freqs)
            .map(|(c, &f)| c.len() as f64 * f as f64)
            .sum::<f64>()
            / total
    }
}

#[inline]
fn sigmoid(s: f32) -> f32 {
    1.0 / (1.0 + (-s).exp())
}

/// One hierarchical-softmax update for the pair (u -> v): walk v's
/// Huffman path, at each inner node push the branch decision towards the
/// observed bit. Returns the pair's negative log-likelihood.
#[allow(clippy::too_many_arguments)]
pub fn hs_update(
    vertex: &mut [f32],
    inner: &mut [f32],
    dim: usize,
    tree: &HuffmanTree,
    u: u32,
    v: u32,
    lr: f32,
    grad_buf: &mut Vec<f32>,
) -> f32 {
    grad_buf.clear();
    grad_buf.resize(dim, 0.0);
    let uo = u as usize * dim;
    let mut nll = 0.0f32;
    for (point, right) in tree.path(v) {
        let io = point as usize * dim;
        let s: f32 = vertex[uo..uo + dim]
            .iter()
            .zip(&inner[io..io + dim])
            .map(|(a, b)| a * b)
            .sum();
        let p = sigmoid(s);
        // label: going right = 1
        let label = if right { 1.0 } else { 0.0 };
        nll -= if right { p.max(1e-12).ln() } else { (1.0 - p).max(1e-12).ln() };
        let g = p - label;
        let urow = &vertex[uo..uo + dim];
        let irow = &mut inner[io..io + dim];
        for j in 0..dim {
            grad_buf[j] += g * irow[j];
            irow[j] -= lr * g * urow[j];
        }
    }
    let urow = &mut vertex[uo..uo + dim];
    for j in 0..dim {
        urow[j] -= lr * grad_buf[j];
    }
    nll
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tree_paths_are_prefix_free_and_complete() {
        let freqs = [5.0f32, 1.0, 3.0, 2.0, 8.0, 1.0];
        let t = HuffmanTree::build(&freqs);
        assert_eq!(t.num_leaves(), 6);
        assert_eq!(t.num_inner(), 5);
        // decode: every leaf's (code, points) must be non-empty and
        // distinct as a bitstring (prefix-free by construction)
        let codes: Vec<String> = (0..6u32)
            .map(|v| {
                t.path(v)
                    .map(|(_, b)| if b { '1' } else { '0' })
                    .collect()
            })
            .collect();
        for (i, a) in codes.iter().enumerate() {
            assert!(!a.is_empty());
            for (j, b) in codes.iter().enumerate() {
                if i != j {
                    assert!(!b.starts_with(a), "code {a} is a prefix of {b}");
                }
            }
        }
    }

    #[test]
    fn frequent_leaves_get_short_codes() {
        // Zipf-ish frequencies: the most frequent leaf should sit at or
        // near the minimum depth.
        let freqs: Vec<f32> = (1..=64).map(|i| 1.0 / i as f32).collect();
        let t = HuffmanTree::build(&freqs);
        let dmax = (0..64u32).map(|v| t.depth(v)).max().unwrap();
        assert!(t.depth(0) < dmax, "most frequent leaf not shorter than max");
        // mean depth must beat the balanced-tree depth for skewed input
        assert!(t.mean_depth(&freqs) < 6.0_f64, "mean {}", t.mean_depth(&freqs));
    }

    #[test]
    fn uniform_frequencies_give_balanced_tree() {
        let freqs = vec![1.0f32; 16];
        let t = HuffmanTree::build(&freqs);
        for v in 0..16u32 {
            assert_eq!(t.depth(v), 4, "leaf {v} depth {}", t.depth(v));
        }
    }

    #[test]
    fn kraft_inequality_holds_with_equality() {
        // complete binary code: sum of 2^-len == 1
        let freqs = [3.0f32, 1.0, 4.0, 1.0, 5.0, 9.0, 2.0];
        let t = HuffmanTree::build(&freqs);
        let kraft: f64 = (0..7u32).map(|v| 0.5f64.powi(t.depth(v) as i32)).sum();
        assert!((kraft - 1.0).abs() < 1e-12, "kraft {kraft}");
    }

    #[test]
    fn hs_update_reduces_nll() {
        let freqs = vec![1.0f32; 32];
        let t = HuffmanTree::build(&freqs);
        let dim = 8;
        let mut rng = Rng::new(1);
        let mut vertex: Vec<f32> = (0..32 * dim).map(|_| rng.range_f32(-0.1, 0.1)).collect();
        let mut inner = vec![0.0f32; t.num_inner() * dim];
        let mut buf = Vec::new();
        let first = hs_update(&mut vertex, &mut inner, dim, &t, 0, 7, 0.3, &mut buf);
        let mut last = first;
        for _ in 0..40 {
            last = hs_update(&mut vertex, &mut inner, dim, &t, 0, 7, 0.3, &mut buf);
        }
        assert!(last < first, "nll {first} -> {last}");
        assert!(last < 0.2, "nll should approach 0, got {last}");
    }

    #[test]
    fn hs_update_discriminates_targets() {
        // training (0 -> 7) must raise P(7 | 0) without raising P(9 | 0)
        let freqs = vec![1.0f32; 32];
        let t = HuffmanTree::build(&freqs);
        let dim = 8;
        let mut rng = Rng::new(2);
        let mut vertex: Vec<f32> = (0..32 * dim).map(|_| rng.range_f32(-0.1, 0.1)).collect();
        let mut inner = vec![0.0f32; t.num_inner() * dim];
        let mut buf = Vec::new();
        let nll = |vertex: &mut [f32], inner: &mut [f32], v: u32, buf: &mut Vec<f32>| {
            // lr=0 probe: returns NLL without updating
            hs_update(vertex, inner, dim, &t, 0, v, 0.0, buf)
        };
        for _ in 0..60 {
            hs_update(&mut vertex, &mut inner, dim, &t, 0, 7, 0.2, &mut buf);
        }
        let p7 = nll(&mut vertex, &mut inner, 7, &mut buf);
        let p9 = nll(&mut vertex, &mut inner, 9, &mut buf);
        assert!(p7 < p9, "target nll {p7} not below non-target {p9}");
    }

    #[test]
    #[should_panic]
    fn single_leaf_rejected() {
        HuffmanTree::build(&[1.0]);
    }
}
