//! Link prediction evaluation (paper §4.5, Hyperlink-PLD): hold out a
//! fraction of edges, pair them with an equal number of non-edge
//! negatives, score each pair by embedding cosine similarity and report
//! ROC-AUC via the rank statistic.

use crate::embedding::EmbeddingStore;
use crate::graph::{Graph, GraphBuilder};
use crate::util::rng::Rng;

/// A held-out link-prediction split.
pub struct LinkSplit {
    /// The training graph with test edges removed.
    pub train_graph: Graph,
    /// Held-out positive edges.
    pub positives: Vec<(u32, u32)>,
    /// Sampled non-edges, same count as positives.
    pub negatives: Vec<(u32, u32)>,
}

impl LinkSplit {
    /// Hold out `frac` of edges (paper: 0.01%) and sample matching
    /// uniform negatives that are not edges of the *original* graph.
    pub fn new(graph: &Graph, frac: f64, seed: u64) -> Self {
        assert!(frac > 0.0 && frac < 1.0);
        let mut rng = Rng::new(seed);
        let edges: Vec<(u32, u32, f32)> = graph.edges().collect();
        let num_test = ((edges.len() as f64 * frac).round() as usize).clamp(1, edges.len() - 1);
        let mut idx: Vec<u32> = (0..edges.len() as u32).collect();
        rng.shuffle(&mut idx);
        let test_set: std::collections::HashSet<u32> =
            idx[..num_test].iter().copied().collect();

        let mut builder = GraphBuilder::new().with_num_nodes(graph.num_nodes());
        let mut positives = Vec::with_capacity(num_test);
        for (i, &(u, v, w)) in edges.iter().enumerate() {
            if test_set.contains(&(i as u32)) {
                positives.push((u, v));
            } else {
                builder.push_edge(u, v, w);
            }
        }
        // same draw sequence as the original inline loop (one (u, v) pair
        // per attempt from the split's own rng stream)
        let negatives = sample_non_edges(graph, num_test, &mut rng);
        if let Some(labels) = graph.labels() {
            let mut g = builder.build();
            g.set_labels(labels.to_vec());
            return LinkSplit { train_graph: g, positives, negatives };
        }
        LinkSplit { train_graph: builder.build(), positives, negatives }
    }
}

/// Cosine similarity between two vectors.
fn cosine(a: &[f32], b: &[f32]) -> f64 {
    let (mut dot, mut na, mut nb) = (0f64, 0f64, 0f64);
    for (x, y) in a.iter().zip(b) {
        dot += (*x as f64) * (*y as f64);
        na += (*x as f64) * (*x as f64);
        nb += (*y as f64) * (*y as f64);
    }
    if na == 0.0 || nb == 0.0 {
        return 0.0;
    }
    dot / (na.sqrt() * nb.sqrt())
}

/// ROC-AUC from positive/negative score lists via the Mann–Whitney rank
/// statistic (ties get half credit).
pub fn auc_from_scores(pos: &[f64], neg: &[f64]) -> f64 {
    assert!(!pos.is_empty() && !neg.is_empty());
    // sort all scores, compute rank-sum of positives
    let mut all: Vec<(f64, bool)> = pos
        .iter()
        .map(|&s| (s, true))
        .chain(neg.iter().map(|&s| (s, false)))
        .collect();
    all.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
    // average ranks over ties
    let mut rank_sum_pos = 0.0f64;
    let mut i = 0usize;
    while i < all.len() {
        let mut j = i;
        while j + 1 < all.len() && all[j + 1].0 == all[i].0 {
            j += 1;
        }
        let avg_rank = (i + j) as f64 / 2.0 + 1.0; // 1-based
        for item in &all[i..=j] {
            if item.1 {
                rank_sum_pos += avg_rank;
            }
        }
        i = j + 1;
    }
    let np = pos.len() as f64;
    let nn = neg.len() as f64;
    (rank_sum_pos - np * (np + 1.0) / 2.0) / (np * nn)
}

/// Score a link split with cosine similarity of vertex embeddings and
/// return the AUC (the paper's Hyperlink-PLD metric). Embeddings are
/// mean-centered before scoring — the SGNS common-drift component
/// otherwise dominates every cosine and masks neighborhood structure
/// (see [`EmbeddingStore::centered_normalized_vertex`]).
pub fn link_prediction_auc(store: &EmbeddingStore, split: &LinkSplit) -> f64 {
    let d = store.dim();
    let feats = store.centered_normalized_vertex();
    let row = |v: u32| &feats[v as usize * d..(v as usize + 1) * d];
    let score = |pairs: &[(u32, u32)]| -> Vec<f64> {
        pairs
            .iter()
            .map(|&(u, v)| cosine(row(u), row(v)))
            .collect()
    };
    auc_from_scores(&score(&split.positives), &score(&split.negatives))
}

/// Graph-reconstruction AUC: every *observed* edge scored against an equal
/// number of sampled non-edges, by cosine over the centered normalized
/// vertex embeddings (same feature space as [`link_prediction_auc`]).
///
/// Unlike held-out link prediction this measures how well training
/// reproduced the edges it actually saw — the right guard metric on
/// graphs with near-zero clustering (pure Barabási–Albert), where
/// held-out cosine AUC sits at chance regardless of trainer health.
/// Healthy SGNS training scores well above 0.8; a corrupted trainer
/// collapses to ~0.5.
pub fn graph_reconstruction_auc(store: &EmbeddingStore, graph: &Graph, seed: u64) -> f64 {
    let d = store.dim();
    let feats = store.centered_normalized_vertex();
    let row = |v: u32| &feats[v as usize * d..(v as usize + 1) * d];
    let positives: Vec<f64> = graph.edges().map(|(u, v, _)| cosine(row(u), row(v))).collect();
    let mut rng = Rng::new(seed);
    let negatives: Vec<f64> = sample_non_edges(graph, positives.len(), &mut rng)
        .into_iter()
        .map(|(u, v)| cosine(row(u), row(v)))
        .collect();
    auc_from_scores(&positives, &negatives)
}

/// Rejection-sample `count` distinct-endpoint non-edges. Panics (loudly,
/// instead of spinning forever) when the graph is too dense to yield
/// enough non-edges within a generous attempt budget.
fn sample_non_edges(graph: &Graph, count: usize, rng: &mut Rng) -> Vec<(u32, u32)> {
    let n = graph.num_nodes();
    let mut out = Vec::with_capacity(count);
    let max_attempts = 1000 * count.max(1);
    let mut attempts = 0usize;
    while out.len() < count {
        attempts += 1;
        assert!(
            attempts <= max_attempts,
            "could not sample {count} non-edges in {max_attempts} attempts — \
             graph too dense (or too small) for negative sampling"
        );
        let u = rng.below_usize(n) as u32;
        let v = rng.below_usize(n) as u32;
        if u != v && !graph.has_edge(u, v) {
            out.push((u, v));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;

    #[test]
    fn auc_perfect_and_random() {
        assert_eq!(auc_from_scores(&[0.9, 0.8], &[0.1, 0.2]), 1.0);
        assert_eq!(auc_from_scores(&[0.1, 0.2], &[0.9, 0.8]), 0.0);
        // identical scores -> 0.5 by tie handling
        assert!((auc_from_scores(&[0.5; 10], &[0.5; 10]) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn auc_interleaved() {
        // pos {3, 1}, neg {2, 0}: pairs (3>2),(3>0),(1<2),(1>0) -> 3/4
        assert!((auc_from_scores(&[3.0, 1.0], &[2.0, 0.0]) - 0.75).abs() < 1e-12);
    }

    #[test]
    fn split_shapes_and_disjointness() {
        let g = generators::barabasi_albert(500, 3, 1);
        let split = LinkSplit::new(&g, 0.05, 2);
        assert_eq!(split.positives.len(), split.negatives.len());
        assert_eq!(
            split.train_graph.num_edges() + split.positives.len(),
            g.num_edges()
        );
        for &(u, v) in &split.negatives {
            assert!(!g.has_edge(u, v));
        }
        for &(u, v) in &split.positives {
            assert!(g.has_edge(u, v));
            assert!(!split.train_graph.has_edge(u, v));
        }
    }

    #[test]
    fn oracle_embeddings_get_high_auc() {
        // Embed nodes such that linked nodes share a cluster coordinate.
        // The AUC ceiling is set by negatives that happen to fall inside
        // one community (cosine ≈ 1, tied with positives): with k
        // communities that is ~1/k of negatives, giving
        // AUC ≈ (1-mix)·(1-1/k) + ½·((1-mix)/k + mix·(1-1/k)).
        // k=8, mix=0.02 → ≈ 0.93; assert comfortably above chance and
        // consistent with the analytic value.
        let k = 8usize;
        let g = generators::planted_partition(400, k, 12.0, 0.02, 3);
        let split = LinkSplit::new(&g, 0.05, 4);
        let labels = g.labels().unwrap();
        let dim = k + 1;
        let n = g.num_nodes();
        let mut vertex = vec![0f32; n * dim];
        let mut rng = Rng::new(5);
        for i in 0..n {
            vertex[i * dim + labels[i] as usize] = 1.0;
            vertex[i * dim + k] = rng.f32() * 0.1;
        }
        let store =
            EmbeddingStore::from_raw(n, dim, vertex, vec![0.0; n * dim]);
        let auc = link_prediction_auc(&store, &split);
        assert!(auc > 0.85, "auc {auc}");
        assert!(auc <= 1.0);
    }
}
