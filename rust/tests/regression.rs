//! Deterministic end-to-end regression guard for the coordinator /
//! scheduler: the same seed trained with `num_workers = 1` and
//! `num_workers = 2` on the native backend must both produce embeddings
//! whose link-prediction (graph-reconstruction) AUC clears a fixed floor,
//! and the two runs must agree on quality. Silent corruption anywhere in
//! the pipeline — block routing, orthogonal scheduling, partition
//! gather/scatter, the fix-context residency cache — collapses the AUC to
//! ~0.5 and trips this test long before it would show up in timing.
//!
//! Reconstruction (observed edges vs non-edges, see
//! `eval::graph_reconstruction_auc`) rather than a held-out split: pure
//! Barabási–Albert graphs have near-zero clustering, so held-out cosine
//! AUC sits at chance regardless of trainer health (see the workload
//! notes in `rust/examples/link_prediction.rs` and `experiments/fig4.rs`).

use graphvite::config::{BackendKind, TrainConfig};
use graphvite::coordinator::Trainer;
use graphvite::embedding::EmbeddingStore;
use graphvite::eval::graph_reconstruction_auc;
use graphvite::graph::{generators, Graph};
use graphvite::pool::ShuffleKind;

fn train_auc(graph: &Graph, num_workers: usize, seed: u64) -> f64 {
    let cfg = TrainConfig {
        dim: 16,
        epochs: 150,
        num_workers,
        num_samplers: num_workers,
        episode_size: 4_000,
        batch_size: 128,
        backend: BackendKind::Native,
        shuffle: ShuffleKind::Pseudo,
        seed,
        ..TrainConfig::default()
    };
    let mut trainer = Trainer::new(graph.clone(), cfg).unwrap();
    let r = trainer.train().unwrap();
    assert!(
        r.embeddings.vertex_matrix().iter().all(|x| x.is_finite()),
        "{num_workers}-worker run produced non-finite embeddings"
    );
    assert!(
        r.stats.counters.samples_trained >= 150 * graph.num_edges() as u64,
        "{num_workers}-worker run under-trained: {} samples",
        r.stats.counters.samples_trained
    );
    graph_reconstruction_auc(&r.embeddings, graph, 0xA0C ^ seed)
}

// Deliberately loose: a healthy run reconstructs trained edges at AUC
// well above 0.8 while any corruption collapses to ~0.5, so the floor
// only needs to split those regimes. (These thresholds are empirical —
// see ROADMAP "Flaky-threshold audit".)
const AUC_FLOOR: f64 = 0.65;

#[test]
fn worker_counts_clear_auc_floor_and_agree() {
    let graph = generators::barabasi_albert(600, 3, 42);
    let auc_1 = train_auc(&graph, 1, 7);
    let auc_2 = train_auc(&graph, 2, 7);
    assert!(auc_1 > AUC_FLOOR, "1-worker AUC {auc_1} below floor {AUC_FLOOR}");
    assert!(auc_2 > AUC_FLOOR, "2-worker AUC {auc_2} below floor {AUC_FLOOR}");
    // Parallel negative sampling over orthogonal blocks must not cost
    // quality (paper Table 6): the two runs see the same sample budget
    // and seed, so their AUCs should land in the same band.
    assert!(
        (auc_1 - auc_2).abs() < 0.15,
        "worker counts disagree: 1w {auc_1} vs 2w {auc_2}"
    );
}

#[test]
fn untrained_embeddings_sit_at_chance() {
    // Sanity-check the metric itself: random init must NOT clear the
    // floor, otherwise the regression test can't detect corruption.
    let graph = generators::barabasi_albert(600, 3, 42);
    let store = EmbeddingStore::init(graph.num_nodes(), 16, 1);
    let auc = graph_reconstruction_auc(&store, &graph, 3);
    assert!(
        (auc - 0.5).abs() < 0.1,
        "untrained AUC {auc} should be near chance"
    );
}
