//! Table 6 — ablation of GraphVite's three components: parallel online
//! augmentation, parallel negative sampling (4 workers), collaboration
//! strategy. The baseline is a single worker with plain edge sampling,
//! sequential stages — the paper's "very strong" single-GPU baseline.
//!
//! Shape to reproduce: augmentation lifts F1 (more connectivity);
//! parallel negative sampling cuts time ~#workers; collaboration cuts
//! time further without hurting F1.
//!
//! TESTBED NOTE: this machine has a single CPU core, so measured wall
//! clock cannot show thread-level parallelism. The "projected time"
//! column applies the critical-path model from
//! [`TrainStats::projected_parallel_secs`](crate::metrics::TrainStats::projected_parallel_secs):
//! device compute divides across workers and sampling hides behind
//! training when collaboration is on — the quantities the paper's rows
//! measure directly on multi-GPU hardware.

use anyhow::Result;

use crate::coordinator::Trainer;
use crate::experiments::presets::{classify, Scale, Workload};
use crate::util::bench::Table;
use crate::util::human_secs;

pub fn run(scale: Scale) -> Result<()> {
    let w = Workload::youtube_like(scale);
    let mut table = Table::new(
        "Table 6 — ablation of main components (youtube-like)",
        &[
            "row",
            "online aug",
            "parallel neg sampling",
            "collaboration",
            "micro-F1@2%",
            "macro-F1@2%",
            "train time",
            "projected (parallel hw)",
        ],
    );

    // (augmentation, multi-worker, collaboration)
    let rows: Vec<(&str, bool, bool, bool)> = vec![
        ("single-worker baseline", false, false, false),
        ("+ online augmentation", true, false, false),
        ("+ parallel neg sampling", false, true, false),
        ("+ aug + PNS", true, true, false),
        ("GraphVite (all)", true, true, true),
    ];

    for (name, aug, pns, collab) in rows {
        let mut cfg = w.config.clone();
        cfg.online_augmentation = aug;
        cfg.num_workers = if pns { 4 } else { 1 };
        cfg.num_samplers = cfg.num_workers + 1;
        cfg.collaboration = collab;
        let workers = cfg.num_workers;
        let mut trainer = Trainer::new(w.graph.clone(), cfg)?;
        let r = trainer.train()?;
        let rep = classify(&r.embeddings, &w.graph, 0.02, 7);
        table.row(&[
            name.into(),
            tick(aug),
            tick(pns),
            tick(collab),
            format!("{:.2}", rep.micro_f1 * 100.0),
            format!("{:.2}", rep.macro_f1 * 100.0),
            human_secs(r.stats.train_secs),
            human_secs(r.stats.projected_parallel_secs(workers, collab)),
        ]);
    }
    table.print();
    Ok(())
}

fn tick(b: bool) -> String {
    if b { "yes".into() } else { "".into() }
}
