//! Simulated GPU device backends behind the [`Backend`] trait.
//!
//! Each GraphVite worker ("GPU") trains SGNS on its resident vertex /
//! context partitions. Backends are interchangeable implementations of
//! [`Backend`], constructed per worker thread by [`create_backend`]:
//!
//! * [`NativeWorker`] (always compiled, the default) — pure-rust SGNS with
//!   the same mini-batch semantics the HLO artifact has (gather → gradient
//!   at pre-update values → scatter-add), so the backends agree
//!   numerically (see `rust/tests/hlo_runtime.rs`). Used by the CPU
//!   baselines, CI, and large parameter sweeps.
//! * [`SimdWorker`] (always compiled) — the same skeleton with the
//!   `dim`-wide inner loops hand-unrolled 8 lanes at a time
//!   ([`UnrolledKernels`]), on stable Rust with no external crates.
//!   Element-wise updates are bit-identical to the native worker; only
//!   dot-product reduction order differs (reassociation ULPs), so the
//!   regression quality gates carry over — see
//!   `rust/tests/simd_kernels.rs` and the scalar-vs-simd comparison in
//!   `bench_micro`.
//! * `HloWorker` (`pjrt` cargo feature — the type is only compiled, and
//!   so only linkable, in that configuration) — the production three-layer
//!   path: executes the AOT-compiled JAX+Pallas train step via PJRT.
//!   Partitions are uploaded once per block, chained across execute
//!   calls, downloaded once — the paper's per-episode transfer pattern.
//!
//! The coordinator prepares [`ChunkPlan`]s (sample indices already
//! translated to partition-local rows, negatives drawn from the resident
//! context partition per paper section 3.2) and hands them to
//! [`Backend::train_chunks`]. This trait is the seam device backends plug
//! into without touching the coordinator — adding the SIMD backend
//! changed no coordinator code, and multi-device sharding / alternative
//! runtimes slot in the same way. The mini-batch math itself is also a
//! seam one level down: [`minibatch_step`] is generic over [`Kernels`]
//! (the three `dim`-wide inner loops), which is how the scalar and
//! unrolled paths share one gradient/update skeleton.

mod native;
mod simd;

pub use native::{
    minibatch_step, native_minibatch_step, Kernels, NativeWorker, ScalarKernels, Worker,
};
pub use simd::{simd_minibatch_step, SimdWorker, UnrolledKernels, LANES};

use anyhow::Result;

use crate::config::{BackendKind, TrainConfig};
use crate::metrics::Counters;
use crate::runtime::ArtifactMeta;

#[cfg(feature = "pjrt")]
use crate::runtime::Device;

/// One device-ready chunk of training work: `real` positive samples
/// (padded by wrap-around up to the backend's chunk size), each with `k`
/// negatives, trained at learning rate `lr`.
#[derive(Debug, Clone, Default)]
pub struct ChunkPlan {
    pub pos_u: Vec<i32>,
    pub pos_v: Vec<i32>,
    pub neg_v: Vec<i32>,
    pub lr: f32,
    pub real: usize,
}

/// A device worker backend (one instance per simulated GPU, owned by its
/// worker thread — implementations need not be `Send`; PJRT handles are
/// raw pointers and are constructed on the owning thread, like one CUDA
/// context per device).
pub trait Backend {
    /// Positive samples per chunk this backend consumes. For the
    /// pure-rust workers this is the worker's effective batch size —
    /// `batch_size × capacity` under heterogeneous sharding (the
    /// coordinator scales each worker's config by its declared capacity
    /// before construction, so a bigger device trains proportionally
    /// bigger device-side mini-batches).
    fn chunk_samples(&self) -> usize;

    /// Negatives per positive.
    fn k(&self) -> usize;

    /// True when the backend pays a per-call upload/download cost and the
    /// worker should hand it all chunks of a block in ONE
    /// [`Backend::train_chunks`] call (the paper's once-per-episode
    /// transfer pattern). Streaming backends (native) return false and
    /// receive chunks one at a time through a reusable scratch plan.
    fn batched_upload(&self) -> bool {
        false
    }

    /// Train all chunks against the padded partitions in place.
    /// Returns the mean loss over chunks.
    fn train_chunks(
        &mut self,
        vertex: &mut [f32],
        context: &mut [f32],
        chunks: &[ChunkPlan],
        counters: &Counters,
    ) -> Result<f32>;
}

/// Row capacity the coordinator must pad partition buffers to for the
/// backend `cfg` selects, for a partition of `part_rows` rows. This is
/// the single source of the padding rule: it is computable without
/// constructing a backend (backends are built on their worker threads,
/// after the coordinator has already gathered the padded partitions),
/// and backends receive buffers sized by it.
///
/// The transfer engine's residency protocol additionally relies on this
/// being a pure function of its arguments: a partition's capacity never
/// changes between episodes, so a worker-resident buffer is always the
/// exact size the partition's next job (and the final sync scatter)
/// expects.
pub fn planned_capacity(
    cfg: &TrainConfig,
    artifact: Option<&ArtifactMeta>,
    part_rows: usize,
) -> usize {
    match cfg.backend {
        BackendKind::Native | BackendKind::Simd => part_rows,
        // artifact is always Some for a validated pjrt run; fall back to
        // the raw partition size so a missing artifact fails later with
        // the descriptive create_backend error instead of a bad index.
        BackendKind::Pjrt => artifact.map(|m| m.p).unwrap_or(part_rows),
    }
}

/// Construct the backend selected by `cfg` for one worker thread.
///
/// `artifact` carries the AOT artifact chosen by the coordinator's
/// capacity planning (None for the native backend). Must be called on the
/// worker's own thread: PJRT handles are not `Send`.
pub fn create_backend(
    cfg: &TrainConfig,
    artifact: Option<&ArtifactMeta>,
) -> Result<Box<dyn Backend>> {
    match cfg.backend {
        BackendKind::Native => {
            let _ = artifact;
            Ok(Box::new(NativeWorker::new(
                cfg.dim,
                cfg.batch_size,
                cfg.negatives,
                cfg.neg_weight,
            )))
        }
        BackendKind::Simd => Ok(Box::new(SimdWorker::new(
            cfg.dim,
            cfg.batch_size,
            cfg.negatives,
            cfg.neg_weight,
        ))),
        #[cfg(feature = "pjrt")]
        BackendKind::Pjrt => {
            let meta = artifact
                .ok_or_else(|| anyhow::anyhow!("pjrt backend needs an AOT artifact"))?;
            Ok(Box::new(HloWorker::new(meta)?))
        }
        #[cfg(not(feature = "pjrt"))]
        BackendKind::Pjrt => {
            // Unreachable through Trainer (TrainConfig::validate rejects
            // this combination first) but kept as a descriptive error for
            // direct callers.
            anyhow::bail!(
                "backend 'pjrt' is not compiled into this binary; rebuild with \
                 `cargo build --features pjrt`"
            )
        }
    }
}

/// One impl covers every kernel instantiation of the pure-rust worker
/// ([`NativeWorker`], [`SimdWorker`], and any future [`Kernels`] impl).
impl<K: Kernels> Backend for Worker<K> {
    fn chunk_samples(&self) -> usize {
        self.batch_size
    }

    fn k(&self) -> usize {
        self.negatives
    }

    fn train_chunks(
        &mut self,
        vertex: &mut [f32],
        context: &mut [f32],
        chunks: &[ChunkPlan],
        counters: &Counters,
    ) -> Result<f32> {
        Ok(self.train_chunks_in_place(vertex, context, chunks, counters))
    }
}

/// PJRT-backed worker (Layer 1+2 compute via the AOT artifact).
#[cfg(feature = "pjrt")]
pub struct HloWorker {
    pub device: Device,
}

#[cfg(feature = "pjrt")]
impl HloWorker {
    pub fn new(meta: &ArtifactMeta) -> Result<Self> {
        Ok(HloWorker { device: Device::load(meta)? })
    }
}

#[cfg(feature = "pjrt")]
impl Backend for HloWorker {
    fn chunk_samples(&self) -> usize {
        self.device.meta().s * self.device.meta().b
    }

    fn k(&self) -> usize {
        self.device.meta().k
    }

    fn batched_upload(&self) -> bool {
        true
    }

    fn train_chunks(
        &mut self,
        vertex: &mut [f32],
        context: &mut [f32],
        chunks: &[ChunkPlan],
        counters: &Counters,
    ) -> Result<f32> {
        if chunks.is_empty() {
            return Ok(0.0);
        }
        let meta = self.device.meta().clone();
        let mat_bytes = (meta.p * meta.d * 4) as u64;
        // upload once per block (the paper's episode-boundary transfer)
        let (mut v_lit, mut c_lit) = self.device.upload_partitions(vertex, context)?;
        counters.add(&counters.bytes_to_device, 2 * mat_bytes);
        let mut loss_sum = 0.0f64;
        for ch in chunks {
            let (nv, nc, loss) =
                self.device
                    .train_step(v_lit, c_lit, &ch.pos_u, &ch.pos_v, &ch.neg_v, ch.lr)?;
            v_lit = nv;
            c_lit = nc;
            loss_sum += loss as f64;
            counters.add(
                &counters.bytes_to_device,
                ((ch.pos_u.len() + ch.pos_v.len() + ch.neg_v.len()) * 4) as u64,
            );
            counters.add(&counters.device_steps, 1);
        }
        let (v_host, c_host) = self.device.download_partitions(&v_lit, &c_lit)?;
        counters.add(&counters.bytes_from_device, 2 * mat_bytes);
        let vlen = vertex.len();
        let clen = context.len();
        vertex.copy_from_slice(&v_host[..vlen]);
        context.copy_from_slice(&c_host[..clen]);
        Ok((loss_sum / chunks.len() as f64) as f32)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chunk_plan_default_empty() {
        let c = ChunkPlan::default();
        assert_eq!(c.real, 0);
        assert!(c.pos_u.is_empty());
    }

    #[test]
    fn native_backend_via_factory() {
        let cfg = TrainConfig {
            dim: 8,
            batch_size: 32,
            negatives: 2,
            backend: BackendKind::Native,
            ..TrainConfig::default()
        };
        let b = create_backend(&cfg, None).unwrap();
        assert_eq!(b.chunk_samples(), 32);
        assert_eq!(b.k(), 2);
        assert!(!b.batched_upload());
        // native backends get buffers sized exactly to the partition
        assert_eq!(planned_capacity(&cfg, None, 100), 100);
        assert_eq!(planned_capacity(&cfg, None, 7), 7);
    }

    #[test]
    fn simd_backend_via_factory() {
        let cfg = TrainConfig {
            dim: 12, // not a multiple of 8: the worker must handle remainder lanes
            batch_size: 64,
            negatives: 3,
            backend: BackendKind::Simd,
            ..TrainConfig::default()
        };
        let b = create_backend(&cfg, None).unwrap();
        assert_eq!(b.chunk_samples(), 64);
        assert_eq!(b.k(), 3);
        // same streaming contract and padding rule as the native worker:
        // the coordinator cannot tell the two apart
        assert!(!b.batched_upload());
        assert_eq!(planned_capacity(&cfg, None, 100), 100);
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn pjrt_factory_errors_without_feature() {
        let cfg = TrainConfig { backend: BackendKind::Pjrt, ..TrainConfig::default() };
        let err = create_backend(&cfg, None).unwrap_err().to_string();
        assert!(err.contains("--features pjrt"), "unhelpful error: {err}");
    }

    #[test]
    fn trait_object_trains_a_chunk() {
        let cfg = TrainConfig {
            dim: 4,
            batch_size: 2,
            negatives: 1,
            backend: BackendKind::Native,
            ..TrainConfig::default()
        };
        let mut b = create_backend(&cfg, None).unwrap();
        let mut vertex = vec![0.01f32; 4 * 4];
        let mut context = vec![0.02f32; 4 * 4];
        let chunk = ChunkPlan {
            pos_u: vec![0, 1],
            pos_v: vec![1, 2],
            neg_v: vec![2, 3],
            lr: 0.1,
            real: 2,
        };
        let counters = Counters::default();
        let loss = b
            .train_chunks(&mut vertex, &mut context, std::slice::from_ref(&chunk), &counters)
            .unwrap();
        assert!(loss.is_finite());
        assert_eq!(counters.snapshot().device_steps, 1);
    }
}
