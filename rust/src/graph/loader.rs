//! Edge-list file I/O.
//!
//! Format (the same one LINE/DeepWalk consume): one edge per line,
//! `src dst [weight]`, whitespace-separated, `#`-prefixed comments
//! ignored. An optional companion `<path>.labels` file carries
//! `node label` lines.

use std::fs::File;
use std::io::{BufRead, BufReader, BufWriter, Write};
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use super::{Graph, GraphBuilder};

/// Companion label-file path for an edge list: `g.txt` → `g.txt.labels`.
pub(crate) fn labels_path(path: &Path) -> PathBuf {
    path.with_extension(format!(
        "{}labels",
        path.extension().map(|e| format!("{}.", e.to_string_lossy())).unwrap_or_default()
    ))
}

/// Load the `<path>.labels` companion for an `n`-node graph, if present.
/// Shared between [`load_edge_list`] and the external packer
/// ([`super::ondisk::pack_edge_list`]) so both apply the identical
/// semantics: missing nodes default to label 0, out-of-range node ids
/// are ignored.
pub(crate) fn load_labels_for(path: &Path, n: usize) -> Result<Option<Vec<u16>>> {
    let label_path = labels_path(path);
    if !label_path.exists() {
        return Ok(None);
    }
    let mut labels = vec![0u16; n];
    let file = File::open(&label_path)?;
    for line in BufReader::new(file).lines() {
        let line = line?;
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let mut it = line.split_whitespace();
        let node: usize = it.next().unwrap().parse()?;
        let label: u16 = it
            .next()
            .ok_or_else(|| anyhow::anyhow!("missing label for node {node}"))?
            .parse()?;
        if node < labels.len() {
            labels[node] = label;
        }
    }
    Ok(Some(labels))
}

/// Load an edge list (and `<path>.labels` if present) into a [`Graph`].
pub fn load_edge_list(path: impl AsRef<Path>) -> Result<Graph> {
    let path = path.as_ref();
    let file = File::open(path).with_context(|| format!("open {}", path.display()))?;
    let mut builder = GraphBuilder::new();
    for (lineno, line) in BufReader::new(file).lines().enumerate() {
        let line = line?;
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let mut it = line.split_whitespace();
        let u: u32 = it
            .next()
            .ok_or_else(|| anyhow::anyhow!("line {}: missing src", lineno + 1))?
            .parse()
            .with_context(|| format!("line {}: bad src", lineno + 1))?;
        let v: u32 = match it.next() {
            Some(tok) => tok
                .parse()
                .with_context(|| format!("line {}: bad dst", lineno + 1))?,
            None => bail!("line {}: missing dst", lineno + 1),
        };
        let w: f32 = match it.next() {
            Some(tok) => tok
                .parse()
                .with_context(|| format!("line {}: bad weight", lineno + 1))?,
            None => 1.0,
        };
        builder.push_edge(u, v, w);
    }
    let mut graph = builder.build();
    if let Some(labels) = load_labels_for(path, graph.num_nodes())? {
        graph.set_labels(labels);
    }
    Ok(graph)
}

/// Save a graph as an edge list (each undirected edge once) plus a
/// `.labels` companion when labels exist.
pub fn save_edge_list(graph: &Graph, path: impl AsRef<Path>) -> Result<()> {
    let path = path.as_ref();
    let mut w = BufWriter::new(File::create(path)?);
    writeln!(w, "# graphvite edge list: src dst weight")?;
    for (u, v, wt) in graph.edges() {
        if wt == 1.0 {
            writeln!(w, "{u} {v}")?;
        } else {
            writeln!(w, "{u} {v} {wt}")?;
        }
    }
    if let Some(labels) = graph.labels() {
        let mut lw = BufWriter::new(File::create(labels_path(path))?);
        for (node, label) in labels.iter().enumerate() {
            writeln!(lw, "{node} {label}")?;
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;

    #[test]
    fn roundtrip() {
        let dir = std::env::temp_dir().join("graphvite_loader_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("g.txt");
        let g = generators::karate_club();
        save_edge_list(&g, &path).unwrap();
        let g2 = load_edge_list(&path).unwrap();
        assert_eq!(g2.num_nodes(), g.num_nodes());
        assert_eq!(g2.num_edges(), g.num_edges());
        assert_eq!(g2.labels().unwrap(), g.labels().unwrap());
    }

    #[test]
    fn parses_comments_weights_blank_lines() {
        let dir = std::env::temp_dir().join("graphvite_loader_test2");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("g.txt");
        std::fs::write(&path, "# comment\n0 1\n\n1 2 2.5\n").unwrap();
        let g = load_edge_list(&path).unwrap();
        assert_eq!(g.num_nodes(), 3);
        assert_eq!(g.num_edges(), 2);
        assert_eq!(g.neighbor_weights(2), &[2.5]);
    }

    #[test]
    fn bad_line_is_error() {
        let dir = std::env::temp_dir().join("graphvite_loader_test3");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.txt");
        std::fs::write(&path, "0 x\n").unwrap();
        assert!(load_edge_list(&path).is_err());
    }

    #[test]
    fn missing_file_is_error() {
        assert!(load_edge_list("/nonexistent/nope.txt").is_err());
    }
}
