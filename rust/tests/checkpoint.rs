//! Checkpoint/resume suite — the PR's headline acceptance assertion:
//! training straight through and training-to-a-checkpoint + resume
//! produce **bitwise-identical** embeddings. That only holds because a
//! checkpoint captures every stateful input to the trajectory (synced
//! matrices, per-worker RNG streams, the LR schedule position, the pool
//! cursor) and everything else — pools, grids, transfer-engine residency
//! — rebuilds deterministically from `seed` + pool index. A resumed run
//! that diverged by one bit would mean some hidden state escaped the
//! checkpoint; these tests are the tripwire.

use graphvite::config::{BackendKind, TrainConfig};
use graphvite::coordinator::{
    load_checkpoint, save_checkpoint, CheckpointState, TrainFlow, Trainer,
};
use graphvite::graph::{generators, Graph};
use graphvite::pool::ShuffleKind;

fn tmp(name: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join("graphvite_ckpt_tests");
    std::fs::create_dir_all(&dir).unwrap();
    dir.join(name)
}

/// Deterministic test graph; regenerated wherever a fresh copy is needed
/// (same seed, same bytes).
fn graph() -> Graph {
    generators::barabasi_albert(300, 3, 5)
}

fn cfg(seed: u64) -> TrainConfig {
    TrainConfig {
        dim: 8,
        epochs: 8,
        num_workers: 2,
        num_samplers: 2,
        episode_size: 500,
        batch_size: 64,
        backend: BackendKind::test_backend(),
        shuffle: ShuffleKind::Pseudo,
        seed,
        ..TrainConfig::default()
    }
}

/// Train to completion with no observer (the reference trajectory).
fn straight_run(cfg: TrainConfig) -> graphvite::coordinator::TrainResult {
    Trainer::new(graph(), cfg).unwrap().train().unwrap()
}

/// Train until `stop_after` pool passes, saving a checkpoint at the stop
/// boundary; returns the early-stopped result.
fn run_until(
    cfg: TrainConfig,
    stop_after: u64,
    ckpt: &std::path::Path,
) -> graphvite::coordinator::TrainResult {
    let mut trainer = Trainer::new(graph(), cfg).unwrap();
    let mut observer = |state: &CheckpointState<'_>| -> anyhow::Result<TrainFlow> {
        if state.pools_done >= stop_after {
            save_checkpoint(state, ckpt)?;
            return Ok(TrainFlow::Stop);
        }
        Ok(TrainFlow::Continue)
    };
    trainer.train_resumable(None, Some(&mut observer)).unwrap()
}

#[test]
fn resume_is_bitwise_identical() {
    let full = straight_run(cfg(9));

    let p = tmp("resume.gvck");
    let stopped = run_until(cfg(9), 3, &p);
    let ck = load_checkpoint(&p).unwrap();
    assert_eq!(ck.pools_done, 3, "checkpoint taken at the requested boundary");
    // the early-stopped result and the checkpoint hold the same synced state
    assert_eq!(stopped.embeddings.vertex_matrix(), ck.store.vertex_matrix());
    assert_eq!(stopped.embeddings.context_matrix(), ck.store.context_matrix());
    let done_at_ckpt = ck.samples_done;

    let resumed = Trainer::new(graph(), cfg(9))
        .unwrap()
        .train_resumable(Some(ck), None)
        .unwrap();

    assert_eq!(
        full.embeddings.vertex_matrix(),
        resumed.embeddings.vertex_matrix(),
        "vertex matrices diverged between straight and resumed runs"
    );
    assert_eq!(
        full.embeddings.context_matrix(),
        resumed.embeddings.context_matrix(),
        "context matrices diverged between straight and resumed runs"
    );
    // the two sessions together trained exactly the straight run's samples
    assert_eq!(
        done_at_ckpt + resumed.stats.counters.samples_trained,
        full.stats.counters.samples_trained
    );
}

#[test]
fn chained_resume_is_bitwise_identical() {
    // interrupt twice: 0..2 pools, 2..5 pools, 5..end — still the exact
    // bytes of the uninterrupted run
    let full = straight_run(cfg(21));

    let p1 = tmp("chain1.gvck");
    run_until(cfg(21), 2, &p1);
    let ck1 = load_checkpoint(&p1).unwrap();

    let p2 = tmp("chain2.gvck");
    let mut trainer = Trainer::new(graph(), cfg(21)).unwrap();
    let mut observer = |state: &CheckpointState<'_>| -> anyhow::Result<TrainFlow> {
        if state.pools_done >= 5 {
            save_checkpoint(state, &p2)?;
            return Ok(TrainFlow::Stop);
        }
        Ok(TrainFlow::Continue)
    };
    trainer.train_resumable(Some(ck1), Some(&mut observer)).unwrap();
    let ck2 = load_checkpoint(&p2).unwrap();
    assert_eq!(ck2.pools_done, 5);

    let resumed = Trainer::new(graph(), cfg(21))
        .unwrap()
        .train_resumable(Some(ck2), None)
        .unwrap();
    assert_eq!(full.embeddings.vertex_matrix(), resumed.embeddings.vertex_matrix());
    assert_eq!(full.embeddings.context_matrix(), resumed.embeddings.context_matrix());
}

#[test]
fn resume_matches_with_more_partitions_than_workers() {
    // the re-transfer configuration (partitions > workers needs
    // fix_context off): different residency/transfer pattern, same
    // bitwise-resume contract
    let mk = || TrainConfig { num_partitions: 4, fix_context: false, ..cfg(33) };
    let full = straight_run(mk());

    let p = tmp("parts.gvck");
    run_until(mk(), 2, &p);
    let ck = load_checkpoint(&p).unwrap();
    let resumed = Trainer::new(graph(), mk())
        .unwrap()
        .train_resumable(Some(ck), None)
        .unwrap();
    assert_eq!(full.embeddings.vertex_matrix(), resumed.embeddings.vertex_matrix());
    assert_eq!(full.embeddings.context_matrix(), resumed.embeddings.context_matrix());
}

#[test]
fn resume_matches_without_collaboration_or_pipeline() {
    // serial everything: no producer thread, no pipelined dispatch —
    // the checkpoint contract is mode-independent
    let mk = || TrainConfig {
        collaboration: false,
        pipeline_transfers: false,
        ..cfg(47)
    };
    let full = straight_run(mk());

    let p = tmp("serial.gvck");
    run_until(mk(), 3, &p);
    let ck = load_checkpoint(&p).unwrap();
    let resumed = Trainer::new(graph(), mk())
        .unwrap()
        .train_resumable(Some(ck), None)
        .unwrap();
    assert_eq!(full.embeddings.vertex_matrix(), resumed.embeddings.vertex_matrix());
    assert_eq!(full.embeddings.context_matrix(), resumed.embeddings.context_matrix());
}

#[test]
fn resume_rejects_mismatched_runs() {
    let p = tmp("mismatch.gvck");
    run_until(cfg(60), 2, &p);

    // different seed: the RNG streams would not line up
    let err = Trainer::new(graph(), cfg(61))
        .unwrap()
        .train_resumable(Some(load_checkpoint(&p).unwrap()), None)
        .unwrap_err()
        .to_string();
    assert!(err.contains("seed"), "{err}");

    // different --epochs: the LR schedule (total sample budget) changes
    let err = Trainer::new(graph(), TrainConfig { epochs: 4, ..cfg(60) })
        .unwrap()
        .train_resumable(Some(load_checkpoint(&p).unwrap()), None)
        .unwrap_err()
        .to_string();
    assert!(err.contains("--epochs"), "{err}");

    // fewer workers over the same partitions: there'd be no RNG stream
    // alignment (partitions pinned to 2 so the earlier check passes)
    let one_worker =
        TrainConfig { num_workers: 1, num_partitions: 2, fix_context: false, ..cfg(60) };
    let err = Trainer::new(graph(), one_worker)
        .unwrap()
        .train_resumable(Some(load_checkpoint(&p).unwrap()), None)
        .unwrap_err()
        .to_string();
    assert!(err.contains("workers"), "{err}");

    // different graph: edge count is part of the fingerprint
    let other = generators::barabasi_albert(300, 4, 5);
    let err = Trainer::new(other, cfg(60))
        .unwrap()
        .train_resumable(Some(load_checkpoint(&p).unwrap()), None)
        .unwrap_err()
        .to_string();
    assert!(err.contains("edges"), "{err}");
}

#[test]
fn checkpoint_survives_a_disk_roundtrip_exactly() {
    // the .gvck writer/loader round-trips every field bit-for-bit (the
    // loader's validation gauntlet lives in coordinator::checkpoint's
    // unit tests; this covers a real training state end to end)
    let p = tmp("roundtrip.gvck");
    run_until(cfg(73), 2, &p);
    let ck = load_checkpoint(&p).unwrap();
    let p2 = tmp("roundtrip2.gvck");
    save_checkpoint(&ck.state(), &p2).unwrap();
    assert_eq!(
        std::fs::read(&p).unwrap(),
        std::fs::read(&p2).unwrap(),
        "re-saving a loaded checkpoint must reproduce the file"
    );
}
