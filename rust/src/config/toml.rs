//! Minimal TOML-subset parser (flat tables, scalar and array values).
//!
//! Supports exactly what the config files need: `[section]` headers,
//! `key = value` with integers, floats, booleans, quoted strings and
//! single-line arrays of those scalars (`worker_capacities = [2, 1]`),
//! comments (`#`), and blank lines. Keys inside a section are exposed as
//! `"section.key"`. Nested arrays/dates/multi-line strings are out of
//! scope.
//!
//! This layer is untyped: interpretation of individual keys (e.g. mapping
//! the `backend` string through [`crate::config::BackendKind::parse`],
//! whose accepted names/aliases come from the table next to that enum)
//! happens in [`crate::config::TrainConfig::from_toml_str`], and the
//! round-trip of every backend variant through this parser is covered by
//! `rust/tests/config.rs`.

use std::collections::BTreeMap;

use anyhow::{bail, Result};

/// A parsed value: a scalar, or a single-line array of scalars.
#[derive(Debug, Clone, PartialEq)]
pub enum TomlValue {
    Int(i64),
    Float(f64),
    Bool(bool),
    Str(String),
    Array(Vec<TomlValue>),
}

impl TomlValue {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            TomlValue::Int(i) => Some(*i as f64),
            TomlValue::Float(f) => Some(*f),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            TomlValue::Int(i) => Some(*i),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            TomlValue::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            TomlValue::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_array(&self) -> Option<&[TomlValue]> {
        match self {
            TomlValue::Array(items) => Some(items),
            _ => None,
        }
    }
}

/// Parse a TOML-subset document into a flat `section.key -> value` map.
pub fn parse_toml(text: &str) -> Result<BTreeMap<String, TomlValue>> {
    let mut out = BTreeMap::new();
    let mut section = String::new();
    for (lineno, raw) in text.lines().enumerate() {
        let line = strip_comment(raw).trim();
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix('[') {
            let Some(name) = rest.strip_suffix(']') else {
                bail!("line {}: unterminated section header", lineno + 1);
            };
            let name = name.trim();
            if name.is_empty() {
                bail!("line {}: empty section name", lineno + 1);
            }
            section = name.to_string();
            continue;
        }
        let Some(eq) = line.find('=') else {
            bail!("line {}: expected 'key = value'", lineno + 1);
        };
        let key = line[..eq].trim();
        let val = line[eq + 1..].trim();
        if key.is_empty() {
            bail!("line {}: empty key", lineno + 1);
        }
        let full_key = if section.is_empty() {
            key.to_string()
        } else {
            format!("{section}.{key}")
        };
        out.insert(full_key, parse_value(val, lineno + 1)?);
    }
    Ok(out)
}

fn strip_comment(line: &str) -> &str {
    // respect '#' inside quoted strings
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str, lineno: usize) -> Result<TomlValue> {
    if s.is_empty() {
        bail!("line {lineno}: missing value");
    }
    if let Some(inner) = s.strip_prefix('[') {
        let Some(inner) = inner.strip_suffix(']') else {
            bail!("line {lineno}: unterminated array (arrays must be single-line)");
        };
        let mut items = Vec::new();
        for elem in split_array_elements(inner) {
            let elem = elem.trim();
            if elem.is_empty() {
                bail!("line {lineno}: empty array element");
            }
            match parse_value(elem, lineno)? {
                TomlValue::Array(_) => bail!("line {lineno}: nested arrays are not supported"),
                v => items.push(v),
            }
        }
        return Ok(TomlValue::Array(items));
    }
    if let Some(stripped) = s.strip_prefix('"') {
        let Some(inner) = stripped.strip_suffix('"') else {
            bail!("line {lineno}: unterminated string");
        };
        return Ok(TomlValue::Str(inner.to_string()));
    }
    match s {
        "true" => return Ok(TomlValue::Bool(true)),
        "false" => return Ok(TomlValue::Bool(false)),
        _ => {}
    }
    let cleaned = s.replace('_', "");
    if let Ok(i) = cleaned.parse::<i64>() {
        return Ok(TomlValue::Int(i));
    }
    if let Ok(f) = cleaned.parse::<f64>() {
        return Ok(TomlValue::Float(f));
    }
    bail!("line {lineno}: cannot parse value '{s}'");
}

/// Split the interior of a single-line array on commas, respecting quoted
/// strings. An empty/whitespace interior yields no elements.
fn split_array_elements(inner: &str) -> Vec<&str> {
    let mut out = Vec::new();
    let mut start = 0;
    let mut in_str = false;
    for (i, c) in inner.char_indices() {
        match c {
            '"' => in_str = !in_str,
            ',' if !in_str => {
                out.push(&inner[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    // a whitespace-only tail is a trailing comma (or an empty array): ok
    if !inner[start..].trim().is_empty() {
        out.push(&inner[start..]);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalars_and_sections() {
        let doc = parse_toml(
            r#"
            top = 1
            [train]
            dim = 128          # comment
            lr = 0.025
            name = "gv # not a comment"
            flag = true
            big = 1_000_000
            "#,
        )
        .unwrap();
        assert_eq!(doc["top"], TomlValue::Int(1));
        assert_eq!(doc["train.dim"], TomlValue::Int(128));
        assert_eq!(doc["train.lr"], TomlValue::Float(0.025));
        assert_eq!(
            doc["train.name"],
            TomlValue::Str("gv # not a comment".into())
        );
        assert_eq!(doc["train.flag"], TomlValue::Bool(true));
        assert_eq!(doc["train.big"], TomlValue::Int(1_000_000));
    }

    #[test]
    fn arrays() {
        let doc = parse_toml(
            r#"
            caps = [2, 1]          # comment after an array
            trailing = [1, 2,]
            empty = []
            mixed = [1, 2.5, "x,y", true]
            "#,
        )
        .unwrap();
        assert_eq!(
            doc["caps"],
            TomlValue::Array(vec![TomlValue::Int(2), TomlValue::Int(1)])
        );
        assert_eq!(
            doc["trailing"],
            TomlValue::Array(vec![TomlValue::Int(1), TomlValue::Int(2)])
        );
        assert_eq!(doc["empty"], TomlValue::Array(vec![]));
        // commas inside quoted strings do not split elements
        assert_eq!(
            doc["mixed"].as_array().unwrap()[2],
            TomlValue::Str("x,y".into())
        );
        assert_eq!(doc["mixed"].as_array().unwrap().len(), 4);
        assert!(doc["caps"].as_f64().is_none(), "arrays are not scalars");
        assert!(TomlValue::Int(1).as_array().is_none());
    }

    #[test]
    fn errors() {
        assert!(parse_toml("[unterminated\n").is_err());
        assert!(parse_toml("keyonly\n").is_err());
        assert!(parse_toml("k = \n").is_err());
        assert!(parse_toml("k = \"open\n").is_err());
        assert!(parse_toml("k = 12abc\n").is_err());
        assert!(parse_toml("k = [1, 2\n").is_err(), "unterminated array");
        assert!(parse_toml("k = [1, , 2]\n").is_err(), "empty element");
        assert!(parse_toml("k = [[1], [2]]\n").is_err(), "nested arrays");
    }

    #[test]
    fn accessors() {
        assert_eq!(TomlValue::Int(3).as_f64(), Some(3.0));
        assert_eq!(TomlValue::Float(2.5).as_f64(), Some(2.5));
        assert_eq!(TomlValue::Bool(true).as_bool(), Some(true));
        assert_eq!(TomlValue::Str("x".into()).as_str(), Some("x"));
        assert_eq!(TomlValue::Str("x".into()).as_f64(), None);
    }
}
