//! Wall-clock timing helpers used by the coordinator's stage metrics and
//! the benchmark harness.

use std::time::Instant;

/// A simple start/stop stopwatch that accumulates across intervals.
#[derive(Debug)]
pub struct Stopwatch {
    accumulated: f64,
    started: Option<Instant>,
}

impl Default for Stopwatch {
    fn default() -> Self {
        Self::new()
    }
}

impl Stopwatch {
    pub fn new() -> Self {
        Stopwatch { accumulated: 0.0, started: None }
    }

    /// Create and immediately start.
    pub fn started() -> Self {
        let mut s = Self::new();
        s.start();
        s
    }

    pub fn start(&mut self) {
        if self.started.is_none() {
            self.started = Some(Instant::now());
        }
    }

    pub fn stop(&mut self) {
        if let Some(t0) = self.started.take() {
            self.accumulated += t0.elapsed().as_secs_f64();
        }
    }

    /// Total accumulated seconds (includes the running interval, if any).
    pub fn secs(&self) -> f64 {
        self.accumulated
            + self
                .started
                .map(|t0| t0.elapsed().as_secs_f64())
                .unwrap_or(0.0)
    }
}

/// Time a closure, returning (result, seconds).
pub fn time<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t0 = Instant::now();
    let out = f();
    (out, t0.elapsed().as_secs_f64())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stopwatch_accumulates() {
        let mut sw = Stopwatch::new();
        sw.start();
        std::thread::sleep(std::time::Duration::from_millis(10));
        sw.stop();
        let t1 = sw.secs();
        assert!(t1 >= 0.009, "t1={t1}");
        sw.start();
        std::thread::sleep(std::time::Duration::from_millis(10));
        sw.stop();
        assert!(sw.secs() > t1);
    }

    #[test]
    fn stopwatch_idempotent_stop() {
        let mut sw = Stopwatch::new();
        sw.stop(); // no-op
        assert_eq!(sw.secs(), 0.0);
    }

    #[test]
    fn time_reports_duration() {
        let (v, secs) = time(|| {
            std::thread::sleep(std::time::Duration::from_millis(5));
            42
        });
        assert_eq!(v, 42);
        assert!(secs >= 0.004);
    }
}
