//! Embedding serving: the train→serve production loop (ROADMAP item 1).
//!
//! Three pieces, each its own submodule:
//!
//! * [`index`] — a pure-Rust IVF-flat approximate-nearest-neighbor index
//!   over the L2-normalized vertex matrix (spherical k-means coarse
//!   quantizer, `nprobe` inverted-list probing, exact dot products over
//!   the candidates). Deterministic: same embeddings + seed build the
//!   same index, and probing every list reproduces brute force bitwise.
//! * [`protocol`] — the length-prefixed TCP wire format for batched
//!   top-k queries (all limits enforced on decode, fail-loud like the
//!   file loaders).
//! * [`server`] — the accept loop behind `graphvite serve`: one thread
//!   per connection, a shared read-locked index, and an optional
//!   hot-reload watcher that rebuilds the index whenever training
//!   atomically rewrites the embedding file at a checkpoint.

pub mod index;
pub mod protocol;
pub mod server;

pub use index::{AnnIndex, IndexConfig};
pub use protocol::{Request, Response};
pub use server::{Server, ServeConfig};
