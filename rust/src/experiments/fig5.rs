//! Figure 5 — episode-size sweep on 4 workers: samples/second and
//! micro-F1 vs episode size. Shape: speed rises with episode size
//! (amortized transfers) then flattens/drops when only a few episodes
//! remain; F1 is insensitive across the sweep.

use anyhow::Result;

use crate::coordinator::Trainer;
use crate::experiments::presets::{classify, Scale, Workload};
use crate::util::bench::Table;

pub fn run(scale: Scale) -> Result<()> {
    let w = Workload::youtube_like(scale);
    let total = w.graph.num_edges() * w.config.epochs;
    // sweep episode sizes as fractions of the total budget
    let sizes: Vec<usize> = [256usize, 64, 16, 4, 1]
        .iter()
        .map(|div| (total / (div * w.config.num_workers)).max(512))
        .collect();

    let mut table = Table::new(
        "Figure 5 — speed & performance vs episode size (4 workers)",
        &["episode size", "episodes", "samples/s", "micro-F1@2%"],
    );
    for episode_size in sizes {
        let mut cfg = w.config.clone();
        cfg.episode_size = episode_size;
        let mut trainer = Trainer::new(w.graph.clone(), cfg)?;
        let r = trainer.train()?;
        let rep = classify(&r.embeddings, &w.graph, 0.02, 7);
        table.row(&[
            format!("{episode_size}"),
            format!("{}", r.stats.counters.episodes),
            format!("{:.0}", r.stats.throughput()),
            format!("{:.2}", rep.micro_f1 * 100.0),
        ]);
    }
    table.print();
    Ok(())
}
