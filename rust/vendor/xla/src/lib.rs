//! Compile-only stub of the `xla` crate (PJRT C-API bindings).
//!
//! The `pjrt` cargo feature of `graphvite` routes device execution through
//! the real `xla` crate, which needs the PJRT shared library and cannot be
//! fetched or built on offline hosts. This stub mirrors the exact API
//! surface `graphvite::runtime` uses so that `cargo check --features pjrt`
//! (and full builds of the PJRT code path) succeed everywhere:
//!
//! * host-side [`Literal`] construction/inspection works for real — it is
//!   plain host memory, no PJRT involved;
//! * every operation that would touch a PJRT device
//!   ([`PjRtClient::cpu`], [`HloModuleProto::from_text_file`]) returns a
//!   descriptive [`Error`] at run time.
//!
//! On a host with the real bindings, replace the `xla` path dependency in
//! `rust/Cargo.toml` (or add a `[patch]` entry) — `graphvite` itself does
//! not change.

use std::borrow::BorrowMut;
use std::fmt;
use std::path::Path;

/// Error type matching the real crate's `xla::Error` usage (`Display`).
pub struct Error(String);

impl Error {
    fn stub(what: &str) -> Self {
        Error(format!(
            "{what}: PJRT runtime unavailable — this binary was built against the \
             offline `xla` stub; rebuild against the real xla/PJRT bindings to run \
             the pjrt backend"
        ))
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "xla::Error({})", self.0)
    }
}

impl std::error::Error for Error {}

type Result<T> = std::result::Result<T, Error>;

/// Element types a [`Literal`] can hold.
pub trait NativeType: Copy + Sized {
    fn wrap(data: Vec<Self>) -> Storage;
    fn unwrap(storage: &Storage) -> Option<&[Self]>;
}

/// Backing storage of a literal (exposed only through [`Literal`]).
#[derive(Debug, Clone)]
pub enum Storage {
    F32(Vec<f32>),
    F64(Vec<f64>),
    I32(Vec<i32>),
    U32(Vec<u32>),
    Tuple(Vec<Literal>),
}

macro_rules! native_type {
    ($ty:ty, $variant:ident) => {
        impl NativeType for $ty {
            fn wrap(data: Vec<Self>) -> Storage {
                Storage::$variant(data)
            }
            fn unwrap(storage: &Storage) -> Option<&[Self]> {
                match storage {
                    Storage::$variant(v) => Some(v),
                    _ => None,
                }
            }
        }
    };
}

native_type!(f32, F32);
native_type!(f64, F64);
native_type!(i32, I32);
native_type!(u32, U32);

/// A host-side tensor value (shape + flat data), as in the real crate.
#[derive(Debug, Clone)]
pub struct Literal {
    storage: Storage,
    dims: Vec<i64>,
}

impl Literal {
    /// Rank-1 literal from a flat slice.
    pub fn vec1<T: NativeType>(data: &[T]) -> Literal {
        Literal { dims: vec![data.len() as i64], storage: T::wrap(data.to_vec()) }
    }

    /// Rank-0 literal.
    pub fn scalar<T: NativeType>(value: T) -> Literal {
        Literal { dims: Vec::new(), storage: T::wrap(vec![value]) }
    }

    /// Reinterpret with new dimensions; element count must match.
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let want: i64 = dims.iter().product();
        if want as usize != self.element_count() {
            return Err(Error(format!(
                "reshape: {} elements into shape {:?}",
                self.element_count(),
                dims
            )));
        }
        Ok(Literal { storage: self.storage.clone(), dims: dims.to_vec() })
    }

    /// Total element count.
    pub fn element_count(&self) -> usize {
        match &self.storage {
            Storage::F32(v) => v.len(),
            Storage::F64(v) => v.len(),
            Storage::I32(v) => v.len(),
            Storage::U32(v) => v.len(),
            Storage::Tuple(v) => v.iter().map(Literal::element_count).sum(),
        }
    }

    /// Copy the flat data out as `Vec<T>`.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::unwrap(&self.storage)
            .map(|v| v.to_vec())
            .ok_or_else(|| Error("to_vec: element type mismatch".to_string()))
    }

    /// First element of the flat data.
    pub fn get_first_element<T: NativeType>(&self) -> Result<T> {
        T::unwrap(&self.storage)
            .and_then(|v| v.first().copied())
            .ok_or_else(|| Error("get_first_element: type mismatch or empty".to_string()))
    }

    /// Destructure a 3-tuple literal.
    pub fn to_tuple3(self) -> Result<(Literal, Literal, Literal)> {
        match self.storage {
            Storage::Tuple(mut elems) if elems.len() == 3 => {
                let c = elems.pop().unwrap();
                let b = elems.pop().unwrap();
                let a = elems.pop().unwrap();
                Ok((a, b, c))
            }
            _ => Err(Error("to_tuple3: literal is not a 3-tuple".to_string())),
        }
    }
}

/// Parsed HLO module (stub: construction always fails at run time).
pub struct HloModuleProto(());

impl HloModuleProto {
    pub fn from_text_file(path: impl AsRef<Path>) -> Result<HloModuleProto> {
        Err(Error::stub(&format!(
            "HloModuleProto::from_text_file({})",
            path.as_ref().display()
        )))
    }
}

/// An XLA computation wrapping a parsed module.
pub struct XlaComputation(());

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation(())
    }
}

/// A PJRT client (stub: `cpu()` always fails at run time).
pub struct PjRtClient(());

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(Error::stub("PjRtClient::cpu"))
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::stub("PjRtClient::compile"))
    }
}

/// A compiled executable (unreachable through the stub client).
pub struct PjRtLoadedExecutable(());

impl PjRtLoadedExecutable {
    /// Mirrors the real signature: one buffer list per device.
    pub fn execute<L: BorrowMut<Literal>>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::stub("PjRtLoadedExecutable::execute"))
    }
}

/// A device buffer handle (unreachable through the stub client).
pub struct PjRtBuffer(());

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::stub("PjRtBuffer::to_literal_sync"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]).reshape(&[2, 2]).unwrap();
        assert_eq!(l.element_count(), 4);
        assert_eq!(l.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(l.to_vec::<i32>().is_err());
        assert_eq!(Literal::scalar(0.5f32).get_first_element::<f32>().unwrap(), 0.5);
    }

    #[test]
    fn reshape_checks_element_count() {
        assert!(Literal::vec1(&[1i32, 2, 3]).reshape(&[2, 2]).is_err());
    }

    #[test]
    fn device_entry_points_fail_cleanly() {
        let e = PjRtClient::cpu().err().unwrap();
        assert!(e.to_string().contains("stub"));
        assert!(HloModuleProto::from_text_file("/tmp/x.hlo").is_err());
    }
}
