//! Embedding persistence: the legacy binary format (magic + header + raw
//! f32 rows), the word2vec text format other toolchains consume, and the
//! packed `.gvemb` format the serving layer mmaps/streams.
//!
//! Every loader here follows the fail-loud discipline `graph/ondisk.rs`
//! established for `.gvpk`: validate magic, version and geometry against
//! the *actual file length* before allocating anything, and reject both
//! truncation and trailing garbage with an exact-length check. A corrupt
//! or hostile header must produce `Err`, never a panic, an out-of-bounds
//! write, or a multi-gigabyte allocation.

use std::fs::File;
use std::io::{BufRead, BufReader, BufWriter, Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use super::EmbeddingStore;

const MAGIC: &[u8; 8] = b"GRVITE01";

/// `.gvemb` packed embedding file: 4-byte magic + fixed 32-byte header,
/// then raw little-endian f32 matrices at a 32-byte-aligned offset (so
/// the file can be mapped and the matrices used in place).
///
/// ```text
/// offset  size  field
///      0     4  magic  b"GVEM"
///      4     4  format version (u32 LE) = 1
///      8     8  num_nodes (u64 LE)
///     16     8  dim (u64 LE)
///     24     4  flags (u32 LE): bit 0 = context matrix present
///     28     4  reserved, must be 0
///     32   n*d*4  vertex matrix (f32 LE, row-major)
///      +   n*d*4  context matrix (iff flags bit 0)
/// ```
pub const GVEMB_MAGIC: &[u8; 4] = b"GVEM";
pub const GVEMB_VERSION: u32 = 1;
const GVEMB_HEADER_LEN: u64 = 32;
const GVEMB_FLAG_CONTEXT: u32 = 1;

/// On-disk formats an embedding store can be written as.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OutputFormat {
    /// Legacy `GRVITE01` binary (vertex + context).
    Binary,
    /// word2vec text (`n d` header, vertex rows only).
    Text,
    /// Packed `.gvemb` (header-validated, serving-layer format).
    Gvemb,
}

impl OutputFormat {
    pub fn name(&self) -> &'static str {
        match self {
            OutputFormat::Binary => "binary",
            OutputFormat::Text => "text",
            OutputFormat::Gvemb => "gvemb",
        }
    }

    /// Parse an explicit `--output-format` value (case-insensitive).
    pub fn parse(s: &str) -> Result<Self> {
        match s.to_ascii_lowercase().as_str() {
            "binary" | "bin" => Ok(OutputFormat::Binary),
            "text" | "txt" => Ok(OutputFormat::Text),
            "gvemb" => Ok(OutputFormat::Gvemb),
            other => bail!("unknown output format '{other}' (expected binary|text|gvemb)"),
        }
    }

    /// Infer the format from a path's extension (case-insensitive).
    /// Unknown extensions are an error — silently defaulting to binary is
    /// how embeddings end up unreadable by the tool that expects text.
    pub fn from_path(path: &str) -> Result<Self> {
        let ext = Path::new(path)
            .extension()
            .and_then(|e| e.to_str())
            .map(|e| e.to_ascii_lowercase());
        match ext.as_deref() {
            Some("txt") => Ok(OutputFormat::Text),
            Some("gvemb") => Ok(OutputFormat::Gvemb),
            Some("bin") | Some("emb") => Ok(OutputFormat::Binary),
            _ => bail!(
                "cannot infer embedding format from '{path}' \
                 (known extensions: .bin/.emb, .txt, .gvemb; \
                 or pass --output-format binary|text|gvemb)"
            ),
        }
    }
}

/// Write `store` to `path` in the given format. `.gvemb` writes are
/// atomic (tmp file + rename) so a concurrently-watching server never
/// observes a half-written file.
pub fn save_embeddings(store: &EmbeddingStore, path: &str, format: OutputFormat) -> Result<()> {
    match format {
        OutputFormat::Binary => save_embeddings_binary(store, path),
        OutputFormat::Text => save_embeddings_text(store, path),
        OutputFormat::Gvemb => save_embeddings_gvemb(store, path),
    }
}

/// Save both matrices in the binary format.
pub fn save_embeddings_binary(store: &EmbeddingStore, path: impl AsRef<Path>) -> Result<()> {
    let mut w = BufWriter::new(File::create(path)?);
    w.write_all(MAGIC)?;
    w.write_all(&(store.num_nodes() as u64).to_le_bytes())?;
    w.write_all(&(store.dim() as u64).to_le_bytes())?;
    for mat in [store.vertex_matrix(), store.context_matrix()] {
        // SAFETY-free path: write f32s via to_le_bytes chunks
        let mut buf = Vec::with_capacity(mat.len() * 4);
        for &x in mat {
            buf.extend_from_slice(&x.to_le_bytes());
        }
        w.write_all(&buf)?;
    }
    Ok(())
}

/// Load a binary embedding file.
///
/// The header's `n`/`d` are untrusted: the expected size is computed with
/// checked arithmetic and compared against the actual file length before
/// any allocation, so a corrupt header can neither over-allocate nor hide
/// truncation / trailing garbage.
pub fn load_embeddings(path: impl AsRef<Path>) -> Result<EmbeddingStore> {
    let file = File::open(path.as_ref())
        .with_context(|| format!("open {}", path.as_ref().display()))?;
    let file_len = file.metadata()?.len();
    let mut r = BufReader::new(file);
    if file_len < 24 {
        bail!("embedding file truncated: {file_len} bytes is shorter than the 24-byte header");
    }
    let mut magic = [0u8; 8];
    r.read_exact(&mut magic)?;
    if &magic != MAGIC {
        bail!("not a graphvite embedding file (bad magic)");
    }
    let mut u64buf = [0u8; 8];
    r.read_exact(&mut u64buf)?;
    let n = u64::from_le_bytes(u64buf);
    r.read_exact(&mut u64buf)?;
    let d = u64::from_le_bytes(u64buf);
    let matrix_bytes = checked_matrix_bytes(n, d)?;
    let expected = 24u64
        .checked_add(matrix_bytes.checked_mul(2).ok_or_else(size_overflow)?)
        .ok_or_else(size_overflow)?;
    if file_len != expected {
        bail!(
            "embedding file length mismatch: header declares {n}\u{d7}{d} \
             ({expected} bytes expected) but the file is {file_len} bytes"
        );
    }
    let nd = (n as usize) * (d as usize);
    let vertex = read_f32_matrix(&mut r, nd)?;
    let context = read_f32_matrix(&mut r, nd)?;
    Ok(EmbeddingStore::from_raw(n as usize, d as usize, vertex, context))
}

/// Save both matrices as packed `.gvemb`, atomically (tmp + rename).
pub fn save_embeddings_gvemb(store: &EmbeddingStore, path: impl AsRef<Path>) -> Result<()> {
    let path = path.as_ref();
    let tmp = tmp_sibling(path);
    {
        let mut w = BufWriter::new(
            File::create(&tmp).with_context(|| format!("create {}", tmp.display()))?,
        );
        w.write_all(GVEMB_MAGIC)?;
        w.write_all(&GVEMB_VERSION.to_le_bytes())?;
        w.write_all(&(store.num_nodes() as u64).to_le_bytes())?;
        w.write_all(&(store.dim() as u64).to_le_bytes())?;
        w.write_all(&GVEMB_FLAG_CONTEXT.to_le_bytes())?;
        w.write_all(&0u32.to_le_bytes())?;
        for mat in [store.vertex_matrix(), store.context_matrix()] {
            let mut buf = Vec::with_capacity(mat.len() * 4);
            for &x in mat {
                buf.extend_from_slice(&x.to_le_bytes());
            }
            w.write_all(&buf)?;
        }
        w.flush()?;
    }
    std::fs::rename(&tmp, path)
        .with_context(|| format!("rename {} -> {}", tmp.display(), path.display()))?;
    Ok(())
}

/// Load a `.gvemb` file with the full `.gvpk`-style validation sequence:
/// magic, version, geometry bounded by the file length, exact total size.
pub fn load_embeddings_gvemb(path: impl AsRef<Path>) -> Result<EmbeddingStore> {
    let file = File::open(path.as_ref())
        .with_context(|| format!("open {}", path.as_ref().display()))?;
    let file_len = file.metadata()?.len();
    let mut r = BufReader::new(file);
    if file_len < GVEMB_HEADER_LEN {
        bail!(
            "gvemb file truncated: {file_len} bytes is shorter than the \
             {GVEMB_HEADER_LEN}-byte header"
        );
    }
    let mut magic = [0u8; 4];
    r.read_exact(&mut magic)?;
    if &magic != GVEMB_MAGIC {
        bail!("not a gvemb embedding file (bad magic)");
    }
    let mut u32buf = [0u8; 4];
    let mut u64buf = [0u8; 8];
    r.read_exact(&mut u32buf)?;
    let version = u32::from_le_bytes(u32buf);
    if version != GVEMB_VERSION {
        bail!("unsupported gvemb format version {version} (this build reads {GVEMB_VERSION})");
    }
    r.read_exact(&mut u64buf)?;
    let n = u64::from_le_bytes(u64buf);
    r.read_exact(&mut u64buf)?;
    let d = u64::from_le_bytes(u64buf);
    r.read_exact(&mut u32buf)?;
    let flags = u32::from_le_bytes(u32buf);
    if flags & !GVEMB_FLAG_CONTEXT != 0 {
        bail!("gvemb header has unknown flag bits {flags:#x}");
    }
    r.read_exact(&mut u32buf)?;
    if u32::from_le_bytes(u32buf) != 0 {
        bail!("gvemb header reserved field is not zero");
    }
    let matrices = if flags & GVEMB_FLAG_CONTEXT != 0 { 2 } else { 1 };
    let matrix_bytes = checked_matrix_bytes(n, d)?;
    let expected = GVEMB_HEADER_LEN
        .checked_add(matrix_bytes.checked_mul(matrices).ok_or_else(size_overflow)?)
        .ok_or_else(size_overflow)?;
    if file_len != expected {
        bail!(
            "gvemb file length mismatch: header declares {n}\u{d7}{d} with \
             {matrices} matrix(es) ({expected} bytes expected) but the file \
             is {file_len} bytes"
        );
    }
    let nd = (n as usize) * (d as usize);
    let vertex = read_f32_matrix(&mut r, nd)?;
    let context = if matrices == 2 { read_f32_matrix(&mut r, nd)? } else { vec![0.0; nd] };
    Ok(EmbeddingStore::from_raw(n as usize, d as usize, vertex, context))
}

/// Save the vertex matrix in word2vec text format (`n d` header, then
/// `node x1 x2 …` per line).
pub fn save_embeddings_text(store: &EmbeddingStore, path: impl AsRef<Path>) -> Result<()> {
    let mut w = BufWriter::new(File::create(path)?);
    writeln!(w, "{} {}", store.num_nodes(), store.dim())?;
    for v in 0..store.num_nodes() as u32 {
        write!(w, "{v}")?;
        for x in store.vertex(v) {
            write!(w, " {x}")?;
        }
        writeln!(w)?;
    }
    Ok(())
}

/// Load word2vec text format (vertex matrix only; context zeroed).
///
/// Malformed input returns `Err`, never panics: the header is parsed with
/// explicit errors, the declared geometry is sanity-bounded against the
/// file length before allocating (a complete `n×d` text file needs at
/// least `n*(2d+2)` bytes), row ids must satisfy `v < n`, rows must carry
/// exactly `d` values, and every row must appear exactly once.
pub fn load_embeddings_text(path: impl AsRef<Path>) -> Result<EmbeddingStore> {
    let file = File::open(path.as_ref())
        .with_context(|| format!("open {}", path.as_ref().display()))?;
    let file_len = file.metadata()?.len();
    let r = BufReader::new(file);
    let mut lines = r.lines();
    let header = lines.next().ok_or_else(|| anyhow::anyhow!("empty embedding text file"))??;
    let mut it = header.split_whitespace();
    let n: usize = it
        .next()
        .ok_or_else(|| anyhow::anyhow!("bad text header (missing node count)"))?
        .parse()
        .context("bad text header (node count)")?;
    let d: usize = it
        .next()
        .ok_or_else(|| anyhow::anyhow!("bad text header (missing dimension)"))?
        .parse()
        .context("bad text header (dimension)")?;
    if it.next().is_some() {
        bail!("bad text header (expected exactly 'n d')");
    }
    // Lower bound on a complete file: each row is an id (>= 1 byte), d
    // values (>= 2 bytes each with separator) and a newline. Rejecting
    // here keeps a hostile header from driving a huge allocation.
    let min_len = (n as u128) * (2 * d as u128 + 2);
    if min_len > file_len as u128 {
        bail!(
            "text header declares {n}\u{d7}{d} but the file is only {file_len} \
             bytes — too small to hold that many rows"
        );
    }
    let mut vertex = vec![0f32; n * d];
    let mut seen = vec![false; n];
    let mut rows = 0usize;
    for line in lines {
        let line = line?;
        let mut it = line.split_whitespace();
        let v: usize = match it.next() {
            Some(tok) => tok.parse().with_context(|| format!("bad row id '{tok}'"))?,
            None => continue, // blank line
        };
        if v >= n {
            bail!("row id {v} out of range (header declares {n} nodes)");
        }
        if seen[v] {
            bail!("duplicate row for node {v}");
        }
        seen[v] = true;
        rows += 1;
        let mut j = 0usize;
        for tok in it {
            if j >= d {
                bail!("row {v} has more than {d} values");
            }
            vertex[v * d + j] = tok.parse().with_context(|| format!("row {v}: bad value"))?;
            j += 1;
        }
        if j != d {
            bail!("row {v} has {j} values, expected {d}");
        }
    }
    if rows != n {
        bail!("text file has {rows} rows but the header declares {n}");
    }
    Ok(EmbeddingStore::from_raw(n, d, vertex, vec![0.0; n * d]))
}

/// Load an embedding file of any supported format by sniffing its leading
/// magic bytes: `.gvemb`, the legacy binary format, or (failing both)
/// word2vec text. Extension spoofing therefore cannot misroute a file.
pub fn load_embeddings_auto(path: impl AsRef<Path>) -> Result<EmbeddingStore> {
    let path = path.as_ref();
    let mut head = [0u8; 8];
    let got = {
        let mut f =
            File::open(path).with_context(|| format!("open {}", path.display()))?;
        read_head(&mut f, &mut head)?
    };
    if got >= 4 && &head[..4] == GVEMB_MAGIC {
        load_embeddings_gvemb(path)
    } else if got >= 8 && &head == MAGIC {
        load_embeddings(path)
    } else {
        load_embeddings_text(path)
            .with_context(|| format!("{}: not gvemb/binary; text parse failed", path.display()))
    }
}

fn read_head(f: &mut File, buf: &mut [u8; 8]) -> Result<usize> {
    let mut got = 0;
    while got < buf.len() {
        let k = f.read(&mut buf[got..])?;
        if k == 0 {
            break;
        }
        got += k;
    }
    Ok(got)
}

fn size_overflow() -> anyhow::Error {
    anyhow::anyhow!("embedding header geometry overflows u64")
}

/// `n * d * 4` with overflow checks — the untrusted-header guard shared
/// by both binary loaders.
fn checked_matrix_bytes(n: u64, d: u64) -> Result<u64> {
    n.checked_mul(d)
        .and_then(|nd| nd.checked_mul(4))
        .ok_or_else(size_overflow)
}

/// Read exactly `len` f32s. Callers have already proven the file holds
/// them (exact-length check), so the allocation is bounded by file size.
fn read_f32_matrix(r: &mut impl Read, len: usize) -> Result<Vec<f32>> {
    let mut bytes = vec![0u8; len * 4];
    r.read_exact(&mut bytes)?;
    Ok(bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

fn tmp_sibling(path: &Path) -> std::path::PathBuf {
    let mut name = path.file_name().map(|n| n.to_os_string()).unwrap_or_default();
    name.push(".tmp");
    path.with_file_name(name)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("graphvite_emb_io");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn binary_roundtrip() {
        let e = EmbeddingStore::init(37, 9, 1);
        let p = tmp("emb.bin");
        save_embeddings_binary(&e, &p).unwrap();
        let e2 = load_embeddings(&p).unwrap();
        assert_eq!(e2.num_nodes(), 37);
        assert_eq!(e2.dim(), 9);
        assert_eq!(e.vertex_matrix(), e2.vertex_matrix());
        assert_eq!(e.context_matrix(), e2.context_matrix());
    }

    #[test]
    fn gvemb_roundtrip() {
        let e = EmbeddingStore::init(21, 6, 3);
        let p = tmp("emb.gvemb");
        save_embeddings_gvemb(&e, &p).unwrap();
        let e2 = load_embeddings_gvemb(&p).unwrap();
        assert_eq!(e.vertex_matrix(), e2.vertex_matrix());
        assert_eq!(e.context_matrix(), e2.context_matrix());
        // atomic write leaves no tmp file behind
        assert!(!tmp_sibling(&p).exists());
    }

    #[test]
    fn text_roundtrip_vertex() {
        let e = EmbeddingStore::init(7, 3, 2);
        let p = tmp("emb.txt");
        save_embeddings_text(&e, &p).unwrap();
        let e2 = load_embeddings_text(&p).unwrap();
        for v in 0..7u32 {
            for (a, b) in e.vertex(v).iter().zip(e2.vertex(v)) {
                assert!((a - b).abs() < 1e-6);
            }
        }
    }

    #[test]
    fn auto_loader_sniffs_magic_not_extension() {
        let e = EmbeddingStore::init(5, 4, 7);
        // gvemb bytes behind a misleading extension
        let p = tmp("actually_gvemb.bin");
        save_embeddings_gvemb(&e, &p).unwrap();
        let e2 = load_embeddings_auto(&p).unwrap();
        assert_eq!(e.vertex_matrix(), e2.vertex_matrix());
        let p = tmp("auto.txt");
        save_embeddings_text(&e, &p).unwrap();
        assert_eq!(load_embeddings_auto(&p).unwrap().num_nodes(), 5);
    }

    #[test]
    fn bad_magic_rejected() {
        let p = tmp("bad.bin");
        std::fs::write(&p, b"NOTMAGIC__________").unwrap();
        assert!(load_embeddings(&p).is_err());
    }

    #[test]
    fn output_format_dispatch() {
        assert_eq!(OutputFormat::from_path("x/y/E.TXT").unwrap(), OutputFormat::Text);
        assert_eq!(OutputFormat::from_path("a.GvEmb").unwrap(), OutputFormat::Gvemb);
        assert_eq!(OutputFormat::from_path("a.bin").unwrap(), OutputFormat::Binary);
        assert_eq!(OutputFormat::from_path("a.emb").unwrap(), OutputFormat::Binary);
        assert!(OutputFormat::from_path("a.npz").is_err());
        assert!(OutputFormat::from_path("noext").is_err());
        assert_eq!(OutputFormat::parse("TEXT").unwrap(), OutputFormat::Text);
        assert!(OutputFormat::parse("parquet").is_err());
    }
}
