"""Pure-jnp oracle for the SGNS gradient kernel.

This is the correctness reference the Pallas kernel (``sgns.py``) is tested
against (pytest + hypothesis in ``python/tests/test_kernel.py``).

The skip-gram-negative-sampling (SGNS) objective used by GraphVite /
LINE / DeepWalk for one (u, v, label) pair is the weighted binary
cross-entropy on the embedding dot product:

    s      = <u, v>
    loss   = weight * BCE(sigmoid(s), label)
           = weight * (softplus(s) - label * s)        (stable form)
    dL/ds  = weight * (sigmoid(s) - label)
    dL/du  = dL/ds * v ,   dL/dv = dL/ds * u

Positive edges carry label=1 / weight=1; negative samples carry label=0 /
weight=5 (GraphVite scales the single negative's gradient by 5 to match
LINE's gradient scale, paper section 4.3).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def sgns_loss_ref(u, v, label, weight):
    """Per-sample SGNS loss. u, v: [N, D]; label, weight: [N]."""
    s = jnp.sum(u * v, axis=-1)
    # softplus(s) - label*s, computed stably:
    #   softplus(s) = max(s, 0) + log1p(exp(-|s|))
    sp = jnp.maximum(s, 0.0) + jnp.log1p(jnp.exp(-jnp.abs(s)))
    return weight * (sp - label * s)


def sgns_grad_ref(u, v, label, weight):
    """Closed-form gradients of ``sgns_loss_ref`` w.r.t. u and v.

    Returns (grad_u [N,D], grad_v [N,D], loss [N]).
    """
    s = jnp.sum(u * v, axis=-1)
    g = (jax.nn.sigmoid(s) - label) * weight  # dL/ds, [N]
    grad_u = g[:, None] * v
    grad_v = g[:, None] * u
    return grad_u, grad_v, sgns_loss_ref(u, v, label, weight)


def train_block_ref(vertex, context, pos_u, pos_v, neg_v, lr, neg_weight=5.0):
    """Reference (non-Pallas, non-scan) implementation of one train block.

    Mirrors ``model.make_train_block`` batch-for-batch using plain Python
    loops + closed-form gradients; used to validate the scan/scatter logic.

    vertex, context : [P, D] float32
    pos_u, pos_v    : [S, B] int32 (rows into vertex / context)
    neg_v           : [S, B, K] int32 (rows into context)
    """
    S, B = pos_u.shape
    K = neg_v.shape[-1]
    losses = []
    for step in range(S):
        u, v, nv = pos_u[step], pos_v[step], neg_v[step]
        vu = vertex[u]
        cv = context[v]
        cn = context[nv.reshape(-1)]
        ue = jnp.concatenate([vu, jnp.repeat(vu, K, axis=0)], axis=0)
        ve = jnp.concatenate([cv, cn], axis=0)
        label = jnp.concatenate([jnp.ones(B), jnp.zeros(B * K)])
        weight = jnp.concatenate([jnp.ones(B), jnp.full(B * K, neg_weight)])
        gu, gv, loss = sgns_grad_ref(ue, ve, label, weight)
        gu_total = gu[:B] + gu[B:].reshape(B, K, -1).sum(axis=1)
        vertex = vertex.at[u].add(-lr * gu_total)
        context = context.at[v].add(-lr * gv[:B])
        context = context.at[nv.reshape(-1)].add(-lr * gv[B:])
        losses.append(loss.mean())
    return vertex, context, jnp.stack(losses).mean()
