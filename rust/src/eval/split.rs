//! Deterministic train/test splits for labelled nodes.

use crate::util::rng::Rng;

/// Split node ids `0..n` into (train, test) with `train_frac` of nodes in
/// the training set, shuffled by `seed`. Matches the paper's
/// "% labeled nodes" protocol (Table 4's 1%..10% sweep).
pub fn train_test_split(n: usize, train_frac: f64, seed: u64) -> (Vec<u32>, Vec<u32>) {
    assert!((0.0..=1.0).contains(&train_frac));
    let mut rng = Rng::new(seed);
    let mut ids: Vec<u32> = (0..n as u32).collect();
    rng.shuffle(&mut ids);
    let cut = ((n as f64) * train_frac).round() as usize;
    let cut = cut.clamp(1, n.saturating_sub(1).max(1));
    let train = ids[..cut].to_vec();
    let test = ids[cut..].to_vec();
    (train, test)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn split_sizes_and_disjoint() {
        let (tr, te) = train_test_split(1000, 0.1, 1);
        assert_eq!(tr.len(), 100);
        assert_eq!(te.len(), 900);
        let mut all: Vec<u32> = tr.iter().chain(te.iter()).copied().collect();
        all.sort_unstable();
        assert_eq!(all, (0..1000).collect::<Vec<_>>());
    }

    #[test]
    fn deterministic_per_seed() {
        let a = train_test_split(100, 0.3, 7);
        let b = train_test_split(100, 0.3, 7);
        assert_eq!(a, b);
        let c = train_test_split(100, 0.3, 8);
        assert_ne!(a.0, c.0);
    }

    #[test]
    fn tiny_fractions_keep_at_least_one() {
        let (tr, te) = train_test_split(50, 0.001, 3);
        assert_eq!(tr.len(), 1);
        assert_eq!(te.len(), 49);
    }
}
