//! Property-based tests on coordinator invariants (routing, batching,
//! state), using the hand-rolled `util::prop` mini-framework (proptest is
//! not in the offline crate set). Each `forall` runs a body over many
//! generated cases and shrinks failures by reporting the seed.

use graphvite::embedding::{EmbeddingStore, Matrix};
use graphvite::graph::{generators, GraphBuilder};
use graphvite::partition::Partitioner;
use graphvite::pool::{shuffle, BlockGrid, ShuffleKind};
use graphvite::sampling::{
    AliasTable, AugmentConfig, NegativeSampler, OnlineAugmenter, RandomWalker,
};
use graphvite::scheduler::EpisodeSchedule;
use graphvite::util::prop::forall;
use graphvite::util::rng::Rng;

// ------------------------------------------------------------ routing --

#[test]
fn prop_schedule_covers_grid_orthogonally() {
    forall("schedule", 50, |g| {
        let workers = g.usize_in(1..5);
        let parts = workers * g.usize_in(1..4);
        let fix_context = parts == workers && g.bool(0.5);
        let s = EpisodeSchedule::new(parts, workers, fix_context);
        let mut seen = vec![false; parts * parts];
        for group in s.full_pass() {
            let mut rows = vec![false; parts];
            let mut cols = vec![false; parts];
            for a in &group {
                assert!(a.worker < workers);
                assert!(!rows[a.vid] && !cols[a.cid], "group not orthogonal");
                rows[a.vid] = true;
                cols[a.cid] = true;
                assert!(!seen[a.vid * parts + a.cid], "block visited twice");
                seen[a.vid * parts + a.cid] = true;
            }
        }
        assert!(seen.iter().all(|&x| x), "grid not covered");
    });
}

#[test]
fn prop_partitioner_is_a_bijection() {
    forall("partition", 40, |g| {
        let n = g.usize_in(10..2000);
        let parts_n = g.usize_in(1..8).min(n);
        let graph = generators::barabasi_albert(n, g.usize_in(1..4), g.usize_in(0..1000) as u64);
        let parts = if g.bool(0.5) {
            Partitioner::degree_zigzag(&graph, parts_n)
        } else {
            Partitioner::round_robin(&graph, parts_n)
        };
        // every node appears in exactly one partition at its local row
        let mut seen = vec![false; n];
        for p in 0..parts_n {
            for (r, &v) in parts.nodes_of_part(p).iter().enumerate() {
                assert!(!seen[v as usize]);
                seen[v as usize] = true;
                assert_eq!(parts.part_of(v), p);
                assert_eq!(parts.local_row(v) as usize, r);
            }
        }
        assert!(seen.iter().all(|&x| x));
        // sizes balanced within one
        let sizes: Vec<usize> = (0..parts_n).map(|p| parts.part_size(p)).collect();
        assert!(sizes.iter().max().unwrap() - sizes.iter().min().unwrap() <= 1);
    });
}

#[test]
fn prop_redistribute_conserves_and_routes_correctly() {
    forall("redistribute", 40, |g| {
        let n = g.usize_in(10..500);
        let graph = generators::barabasi_albert(n, 2, g.usize_in(0..1000) as u64);
        let parts_n = g.usize_in(1..5).min(n);
        let parts = Partitioner::degree_zigzag(&graph, parts_n);
        let pool: Vec<(u32, u32)> = (0..g.usize_in(0..2000))
            .map(|_| (g.u32_in(0..n as u32), g.u32_in(0..n as u32)))
            .collect();
        let grid = BlockGrid::redistribute(&pool, &parts);
        assert_eq!(grid.total_samples(), pool.len());
        for i in 0..parts_n {
            for j in 0..parts_n {
                for &(lu, lv) in grid.block(i, j) {
                    assert!((lu as usize) < parts.part_size(i));
                    assert!((lv as usize) < parts.part_size(j));
                }
            }
        }
    });
}

// ----------------------------------------------------------- batching --

#[test]
fn prop_shuffles_are_permutations() {
    forall("shuffles", 60, |g| {
        let n = g.usize_in(0..5000);
        let pool: Vec<(u32, u32)> = (0..n)
            .map(|i| (g.u32_in(0..1000), i as u32))
            .collect();
        let kind = *g.choose(&[
            ShuffleKind::None,
            ShuffleKind::Random,
            ShuffleKind::IndexMapping,
            ShuffleKind::Pseudo,
        ]);
        let stride = g.usize_in(2..8);
        let mut rng = Rng::new(g.usize_in(0..10000) as u64);
        let mut shuffled = pool.clone();
        shuffle::shuffle(kind, &mut shuffled, stride, &mut rng);
        let mut a = pool;
        let mut b = shuffled;
        a.sort_unstable();
        b.sort_unstable();
        assert_eq!(a, b, "{kind:?} lost/duplicated samples");
    });
}

#[test]
fn prop_pseudo_shuffle_block_structure() {
    // pseudo shuffle = deal round-robin into s blocks, concatenate:
    // element at pool index i lands in block (i % s) at offset (i / s).
    forall("pseudo-layout", 40, |g| {
        let n = g.usize_in(2..3000);
        let s = g.usize_in(2..7);
        let mut pool: Vec<(u32, u32)> = (0..n as u32).map(|i| (i, i)).collect();
        shuffle::pseudo_shuffle(&mut pool, s);
        let block_len = |b: usize| n / s + usize::from(b < n % s);
        let mut expect = Vec::with_capacity(n);
        for b in 0..s {
            for off in 0..block_len(b) {
                expect.push((off * s + b) as u32);
            }
        }
        let got: Vec<u32> = pool.iter().map(|&(u, _)| u).collect();
        assert_eq!(got, expect);
    });
}

#[test]
fn prop_augmenter_emits_walk_neighbors() {
    forall("augment", 25, |g| {
        let n = g.usize_in(20..300);
        let graph = generators::barabasi_albert(n, 2, g.usize_in(0..100) as u64);
        let cfg = AugmentConfig {
            walk_length: g.usize_in(1..10),
            augmentation_distance: g.usize_in(1..6),
        };
        let dep = OnlineAugmenter::departure_table(&graph);
        let walker = RandomWalker::new(&graph);
        let mut aug =
            OnlineAugmenter::new(&walker, &dep, cfg, Rng::new(g.usize_in(0..1000) as u64));
        let mut out = Vec::new();
        aug.fill(&mut out, 500);
        assert_eq!(out.len(), 500);
        for &(u, v) in &out {
            assert!((u as usize) < n && (v as usize) < n);
            assert_ne!(u, v, "self-pair emitted");
        }
    });
}

#[test]
fn prop_negative_sampler_stays_in_partition() {
    forall("negatives", 30, |g| {
        let n = g.usize_in(20..1000);
        let graph = generators::barabasi_albert(n, 2, g.usize_in(0..100) as u64);
        let parts_n = g.usize_in(1..5).min(n);
        let parts = Partitioner::degree_zigzag(&graph, parts_n);
        let neg = NegativeSampler::new(&graph, &parts);
        let mut rng = Rng::new(g.usize_in(0..1000) as u64);
        for p in 0..parts_n {
            for _ in 0..200 {
                let local = neg.sample_local(p, &mut rng);
                assert!(
                    (local as usize) < parts.part_size(p),
                    "negative row {local} outside partition {p}"
                );
            }
        }
    });
}

#[test]
fn prop_alias_table_matches_weights() {
    forall("alias", 20, |g| {
        let k = g.usize_in(2..50);
        let weights: Vec<f32> = (0..k).map(|_| g.f32_in(0.0..10.0)).collect();
        let total: f32 = weights.iter().sum();
        if total <= 0.0 {
            return; // all-zero weight vectors are rejected by construction
        }
        let t = AliasTable::new(&weights);
        let mut rng = Rng::new(g.usize_in(0..10000) as u64);
        let draws = 60_000;
        let mut counts = vec![0usize; k];
        for _ in 0..draws {
            counts[t.sample(&mut rng) as usize] += 1;
        }
        for i in 0..k {
            let expect = (weights[i] / total) as f64;
            let got = counts[i] as f64 / draws as f64;
            assert!(
                (got - expect).abs() < 0.02 + 0.1 * expect,
                "outcome {i}: got {got:.4} expect {expect:.4}"
            );
        }
    });
}

#[test]
fn prop_alias_empirical_frequencies_chi_square() {
    // Vose alias tables must reproduce their weight vector: a chi-square
    // goodness-of-fit statistic over the empirical draw counts stays
    // within a generous bound of its expectation (df = k-1, E[X2] = k-1,
    // sd = sqrt(2(k-1))). Zero-weight outcomes must never be drawn.
    forall("alias-chi-square", 25, |g| {
        let k = g.usize_in(2..40);
        let mut weights: Vec<f32> = (0..k).map(|_| g.f32_in(0.1..10.0)).collect();
        // sprinkle in some exact zeros (kept off index 0 so the total stays positive)
        for i in 1..k {
            if g.bool(0.2) {
                weights[i] = 0.0;
            }
        }
        let total: f64 = weights.iter().map(|&w| w as f64).sum();
        let t = AliasTable::new(&weights);
        let draws = 60_000usize;
        let mut rng = Rng::new(g.usize_in(0..100_000) as u64);
        let mut counts = vec![0u64; k];
        for _ in 0..draws {
            counts[t.sample(&mut rng) as usize] += 1;
        }
        let mut chi2 = 0.0f64;
        let mut df = 0usize;
        for i in 0..k {
            let expect = draws as f64 * weights[i] as f64 / total;
            if weights[i] == 0.0 {
                assert_eq!(counts[i], 0, "zero-weight outcome {i} was drawn");
                continue;
            }
            chi2 += (counts[i] as f64 - expect) * (counts[i] as f64 - expect) / expect;
            df += 1;
        }
        let df = df.saturating_sub(1).max(1) as f64;
        // mean + 6 sigma + slack: astronomically unlikely to trip on a
        // correct sampler, catches any systematic bias immediately
        let bound = df + 6.0 * (2.0 * df).sqrt() + 12.0;
        assert!(chi2 < bound, "chi2 {chi2:.1} over bound {bound:.1} (df {df})");
    });
}

#[test]
fn prop_pseudo_shuffle_is_exact_permutation() {
    // The pseudo shuffle must lose/duplicate nothing for any pool length
    // (including lengths not divisible by the stride) and any stride —
    // checked as an exact multiset equality over unique payloads.
    forall("pseudo-permutation", 50, |g| {
        let n = g.usize_in(0..4000);
        let s = g.usize_in(2..9);
        let orig: Vec<(u32, u32)> =
            (0..n as u32).map(|i| (i, i.wrapping_mul(2654435761))).collect();
        let mut pool = orig.clone();
        shuffle::pseudo_shuffle(&mut pool, s);
        assert_eq!(pool.len(), orig.len());
        let mut a = orig;
        let mut b = pool;
        a.sort_unstable();
        b.sort_unstable();
        assert_eq!(a, b, "pseudo shuffle (n={n}, s={s}) is not a permutation");
    });
}

#[test]
fn prop_block_grid_conserves_every_sample_exactly_once() {
    // Redistribute must conserve the pool as a multiset: translating each
    // block's local rows back through nodes_of_part reproduces exactly
    // the original (u, v) pool — nothing dropped, duplicated, or
    // misrouted into the wrong block.
    forall("grid-conservation", 40, |g| {
        let n = g.usize_in(10..600);
        let graph = generators::barabasi_albert(n, 2, g.usize_in(0..1000) as u64);
        let parts_n = g.usize_in(1..6).min(n);
        let parts = Partitioner::degree_zigzag(&graph, parts_n);
        // duplicates on purpose: the grid must keep every copy
        let pool: Vec<(u32, u32)> = (0..g.usize_in(1..3000))
            .map(|_| (g.u32_in(0..n as u32), g.u32_in(0..n as u32)))
            .collect();
        let grid = BlockGrid::redistribute(&pool, &parts);
        let mut recovered: Vec<(u32, u32)> = Vec::with_capacity(pool.len());
        for i in 0..parts_n {
            for j in 0..parts_n {
                for &(lu, lv) in grid.block(i, j) {
                    let u = parts.nodes_of_part(i)[lu as usize];
                    let v = parts.nodes_of_part(j)[lv as usize];
                    // routed into the right block
                    assert_eq!(parts.part_of(u), i);
                    assert_eq!(parts.part_of(v), j);
                    recovered.push((u, v));
                }
            }
        }
        let mut a = pool;
        let mut b = recovered;
        a.sort_unstable();
        b.sort_unstable();
        assert_eq!(a, b, "redistribute did not conserve the sample multiset");
    });
}

// ---------------------------------------------------------------- state --

#[test]
fn prop_gather_scatter_roundtrip_any_partitioning() {
    forall("gather-scatter", 30, |g| {
        let n = g.usize_in(5..500);
        let d = *g.choose(&[1usize, 3, 8, 17, 64]);
        let graph = generators::barabasi_albert(n, 2, g.usize_in(0..100) as u64);
        let parts_n = g.usize_in(1..5).min(n);
        let parts = Partitioner::degree_zigzag(&graph, parts_n);
        let mut store = EmbeddingStore::init(n, d, g.usize_in(0..1000) as u64);
        let orig_v = store.vertex_matrix().to_vec();
        let orig_c = store.context_matrix().to_vec();
        let cap = parts.max_part_size() + g.usize_in(0..10);
        let mut buf = Vec::new();
        for p in 0..parts_n {
            for which in [Matrix::Vertex, Matrix::Context] {
                store.gather_partition(&parts, p, cap, which, &mut buf);
                assert_eq!(buf.len(), cap * d);
                store.scatter_partition(&parts, p, which, &buf);
            }
        }
        assert_eq!(store.vertex_matrix(), &orig_v[..]);
        assert_eq!(store.context_matrix(), &orig_c[..]);
    });
}

#[test]
fn prop_graph_builder_degree_symmetry() {
    // undirected graphs: degree counts both directions; total degree = 2|E|
    forall("graph-build", 30, |g| {
        let n = g.usize_in(2..300);
        let edges = g.edges(n, 1500);
        let mut b = GraphBuilder::new().with_num_nodes(n);
        for &(u, v) in &edges {
            if u != v {
                b.push_edge(u, v, 1.0);
            }
        }
        let graph = b.build();
        let total: usize = (0..n as u32).map(|v| graph.degree(v)).sum();
        assert_eq!(total, 2 * graph.num_edges());
        // every reported edge must be queryable in both directions
        for &(u, v) in edges.iter().take(50) {
            if u != v {
                assert!(graph.has_edge(u, v));
                assert!(graph.has_edge(v, u));
            }
        }
    });
}

#[test]
fn prop_rng_below_is_unbiased_across_ranges() {
    forall("rng-below", 15, |g| {
        let n = g.usize_in(2..64) as u64;
        let mut rng = Rng::new(g.usize_in(0..100000) as u64);
        let draws = 50_000;
        let mut counts = vec![0usize; n as usize];
        for _ in 0..draws {
            counts[rng.below(n) as usize] += 1;
        }
        let expect = draws as f64 / n as f64;
        for (i, &c) in counts.iter().enumerate() {
            assert!(
                (c as f64 - expect).abs() < 6.0 * expect.sqrt() + 10.0,
                "bucket {i}: {c} vs {expect}"
            );
        }
    });
}
