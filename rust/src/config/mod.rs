//! Training configuration + a TOML-subset parser (serde/toml are not in
//! the offline crate set, so the config substrate is built from scratch).

mod builder;
mod toml;

pub use builder::TrainConfigBuilder;
pub use toml::{parse_toml, TomlValue};

use anyhow::{bail, Result};

use crate::pool::ShuffleKind;

pub use crate::graph::GraphFormat;

/// Which device backend the simulated GPUs run. Every variant corresponds
/// to an implementation of [`crate::gpu::Backend`]; the PJRT one is only
/// compiled in with the `pjrt` cargo feature (see [`TrainConfig::validate`]).
///
/// Per-variant names, aliases and descriptions live in [`Self::name`],
/// [`Self::aliases`] and [`Self::summary`] next to this enum — the CLI
/// `--backend` help, the TOML error messages and the round-trip tests are
/// all generated from them (via [`Self::ALL`]), so a new variant cannot
/// drift out of the user-facing docs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BackendKind {
    /// AOT-compiled HLO (JAX Layer-2 + Pallas Layer-1) executed through
    /// PJRT — the three-layer production path. Requires building with
    /// `--features pjrt`.
    Pjrt,
    /// Pure-rust SGNS trainer with straight-line scalar kernels —
    /// bit-compatible math, always available. Used by the baselines, CI,
    /// and large sweeps where PJRT compile time dominates.
    Native,
    /// Pure-rust SGNS trainer with hand-unrolled f32x8 kernels
    /// ([`crate::gpu::SimdWorker`]) — always available, same scheduling
    /// behavior as `Native`, dot products agree within reassociation ULPs
    /// (enforced by `rust/tests/simd_kernels.rs`).
    Simd,
}

impl BackendKind {
    /// Every backend this crate knows about, in display order. This table
    /// plus [`Self::name`] / [`Self::aliases`] / [`Self::summary`] is the
    /// single source of truth for [`Self::parse`], the CLI help block
    /// ([`Self::help_text`]) and the config round-trip tests.
    pub const ALL: &'static [BackendKind] = &[Self::Native, Self::Simd, Self::Pjrt];

    /// Parse a backend name or alias (see [`Self::aliases`]).
    pub fn parse(s: &str) -> Option<Self> {
        Self::ALL
            .iter()
            .copied()
            .find(|b| b.name() == s || b.aliases().contains(&s))
    }

    pub fn name(&self) -> &'static str {
        match self {
            Self::Pjrt => "pjrt",
            Self::Native => "native",
            Self::Simd => "simd",
        }
    }

    /// Legacy / alternate spellings accepted by [`Self::parse`].
    pub fn aliases(&self) -> &'static [&'static str] {
        match self {
            // "hlo" kept as a legacy alias for existing configs/scripts.
            Self::Pjrt => &["hlo"],
            Self::Native | Self::Simd => &[],
        }
    }

    /// One-line description used by the CLI help and the README table.
    pub fn summary(&self) -> &'static str {
        match self {
            Self::Pjrt => "AOT HLO artifacts via the PJRT C API (build with --features pjrt)",
            Self::Native => "pure-rust scalar SGNS kernels (always available; the default)",
            Self::Simd => "pure-rust hand-unrolled f32x8 SGNS kernels (always available)",
        }
    }

    /// `"native|simd|pjrt"` — for usage one-liners and error messages.
    pub fn names_joined() -> String {
        let names: Vec<&str> = Self::ALL.iter().map(|b| b.name()).collect();
        names.join("|")
    }

    /// Indented per-backend help block (one line per variant, aliases
    /// included), rendered into `graphvite help`.
    pub fn help_text() -> String {
        let mut out = String::new();
        for b in Self::ALL {
            let alias = if b.aliases().is_empty() {
                String::new()
            } else {
                format!(" (alias: {})", b.aliases().join(", "))
            };
            out.push_str(&format!("  {:<8}{}{}\n", b.name(), b.summary(), alias));
        }
        out.pop(); // drop the trailing newline for clean embedding
        out
    }

    /// True when this binary can actually construct the backend.
    pub fn available(&self) -> bool {
        match self {
            Self::Native | Self::Simd => true,
            Self::Pjrt => cfg!(feature = "pjrt"),
        }
    }

    /// The most capable backend compiled into this binary: [`Self::Pjrt`]
    /// with the `pjrt` feature, the unrolled [`Self::Simd`] otherwise
    /// (it beats [`Self::Native`] on kernel throughput and agrees with it
    /// numerically). Examples and experiment drivers use this so the same
    /// source runs everywhere.
    pub fn best_available() -> Self {
        if cfg!(feature = "pjrt") {
            Self::Pjrt
        } else {
            Self::Simd
        }
    }

    /// The backend the integration suites should drive trainer tests
    /// through: `GRAPHVITE_TEST_BACKEND` (set by CI's backend-matrix job
    /// to `native` / `simd`) or [`Self::Native`] when unset. An unknown
    /// value panics so a typo'd matrix entry cannot silently re-test the
    /// default backend.
    pub fn test_backend() -> Self {
        match std::env::var("GRAPHVITE_TEST_BACKEND") {
            Ok(s) => Self::parse(&s).unwrap_or_else(|| {
                panic!(
                    "GRAPHVITE_TEST_BACKEND='{s}' is not a backend (expected one of: {})",
                    Self::names_joined()
                )
            }),
            Err(_) => Self::Native,
        }
    }
}

/// Where device workers live (the transport seam,
/// [`crate::coordinator::transport`]).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum WorkerMode {
    /// In-process worker threads over mpsc channels — the PRs-1-6
    /// topology, bitwise-pinned. The default.
    Local,
    /// Workers are separate `graphvite worker --connect ADDR` processes;
    /// the coordinator listens on this address (`host:port`) and speaks
    /// the same protocol over length-prefixed TCP frames. Bitwise
    /// equivalent to local mode (`rust/tests/transport.rs`).
    Tcp(String),
}

impl WorkerMode {
    /// Parse the `workers` config spelling: `"local"` or
    /// `"tcp://HOST:PORT"`.
    pub fn parse(s: &str) -> Result<Self> {
        if s == "local" {
            return Ok(WorkerMode::Local);
        }
        if let Some(addr) = s.strip_prefix("tcp://") {
            if addr.is_empty() {
                bail!("workers = \"tcp://...\" needs an address (e.g. \"tcp://127.0.0.1:7077\")");
            }
            return Ok(WorkerMode::Tcp(addr.to_string()));
        }
        bail!("unknown workers mode '{s}' (expected \"local\" or \"tcp://HOST:PORT\")");
    }

    /// The config-file spelling of this mode (round-trips [`Self::parse`]).
    pub fn spelling(&self) -> String {
        match self {
            WorkerMode::Local => "local".to_string(),
            WorkerMode::Tcp(addr) => format!("tcp://{addr}"),
        }
    }
}

/// Full GraphVite training configuration (defaults follow paper §4.3).
#[derive(Debug, Clone)]
pub struct TrainConfig {
    /// Embedding dimension (paper: 128, 96 on Friendster).
    pub dim: usize,
    /// Training epochs; one epoch = |E| positive samples (paper §4.3).
    pub epochs: usize,
    /// Initial learning rate with linear decay (paper: 0.025).
    pub lr: f32,
    /// Negatives per positive (paper: 1).
    pub negatives: usize,
    /// Gradient scale on negatives (paper: 5).
    pub neg_weight: f32,
    /// Random-walk length in edges (paper: 5 on YouTube, 2 on dense nets).
    pub walk_length: usize,
    /// Augmentation distance s.
    pub augmentation_distance: usize,
    /// Number of simulated GPUs (device workers).
    pub num_workers: usize,
    /// Per-worker device capacities for heterogeneous pools (empty =
    /// every worker has capacity 1 with the unbounded PR-3 residency
    /// cache — today-behavior). Declaring capacities opts into
    /// capacity-aware sharding: worker `i` takes `worker_capacities[i]`
    /// row/column-disjoint blocks per schedule wave (proportionally more
    /// of each episode group), trains device chunks of
    /// `batch_size × capacity` samples, and has its residency cache
    /// capped at `2 × capacity` resident partitions (fail-loud on
    /// violation). `partitions()` must be a multiple of the total
    /// capacity. TOML key `worker_capacities = [..]`, CLI
    /// `--capacities 2,1`.
    pub worker_capacities: Vec<usize>,
    /// Matrix partitions (0 = same as `num_workers`). The paper's §3.2
    /// "any number of partitions greater than n" generalization: must be
    /// a multiple of the total worker capacity
    /// ([`TrainConfig::total_capacity`], = `num_workers` for a
    /// homogeneous pool); each episode group is
    /// processed in `partitions / total_capacity` orthogonal waves. More
    /// partitions shrink the per-device resident set (Table 1 sizing) at
    /// the cost of more transfers.
    pub num_partitions: usize,
    /// CPU sampler threads feeding the pool.
    pub num_samplers: usize,
    /// Episode size: positive samples trained per set of n orthogonal
    /// blocks (paper fig 5; tuned proportional to |V|). The pool holds
    /// `episode_size` samples and one pool pass = `num_workers` episodes.
    pub episode_size: usize,
    /// Pool shuffle algorithm (paper: pseudo).
    pub shuffle: ShuffleKind,
    /// Device backend the simulated GPUs run ([`BackendKind::ALL`] lists
    /// the choices; TOML key `backend`, CLI flag `--backend`).
    pub backend: BackendKind,
    /// Collaboration strategy (double-buffered pools, §3.3). Off = the
    /// sequential ablation row of Table 6.
    pub collaboration: bool,
    /// Parallel online augmentation (§3.1). Off = plain edge sampling
    /// (the Table 6 ablation baseline).
    pub online_augmentation: bool,
    /// Bus usage optimization (§3.4): pin context partitions to workers
    /// and rotate only vertex partitions.
    pub fix_context: bool,
    /// Pipelined wave dispatch: gather and dispatch every wave of an
    /// episode group without waiting for the previous wave's results
    /// (waves within a group are mutually row/column-disjoint), scattering
    /// results as they arrive and fencing only at group boundaries. Off =
    /// the PR-2 serial dispatch (one wave in flight at a time). Bitwise
    /// equivalent embeddings either way — see `rust/tests/pipeline_equivalence.rs`.
    pub pipeline_transfers: bool,
    /// Generalized partition residency: workers keep a partition resident
    /// (vertex *or* context) whenever the schedule routes its next block
    /// to the same worker, eliding the re-upload, with a residency-aware
    /// episode-group ordering that maximizes those reuses. Off = the PR-2
    /// transfer pattern (everything re-shipped each episode except the
    /// `fix_context` context cache). The data movement itself never
    /// changes trained values — but toggling this flag also switches the
    /// episode-group *execution order* (on `partitions > workers`
    /// configs), which is a different, equally valid training trajectory:
    /// residency on/off runs are not bitwise comparable, unlike
    /// `pipeline_transfers` on/off runs, which are.
    pub residency: bool,
    /// Which loader a graph path goes through: `auto` sniffs the packed
    /// magic, `edgelist` forces the text loader (in-RAM CSR), `packed`
    /// forces the out-of-core [`PagedCsr`](crate::graph::PagedCsr)
    /// reader and rejects anything else. TOML key `graph_format`, CLI
    /// `--graph-format`. Synthetic graphs (`--synthetic`) are built in
    /// RAM and ignore this.
    pub graph_format: GraphFormat,
    /// Byte budget of the packed reader's LRU page cache (the resident
    /// successor-page working set; clamped up to one page at open). TOML
    /// key `graph_cache_bytes`, CLI `--graph-cache-bytes`. Unused by the
    /// in-RAM loader.
    pub graph_cache_bytes: usize,
    /// Mini-batch size fed to the device per step (HLO artifacts fix this
    /// per variant; native backend uses it directly).
    pub batch_size: usize,
    /// RNG seed.
    pub seed: u64,
    /// Print progress every N episodes (0 = quiet).
    pub log_every: usize,
    /// Where the device workers run ([`WorkerMode`]): in-process threads
    /// (the default) or remote `graphvite worker` processes over TCP.
    /// TOML key `workers` (`"local"` / `"tcp://HOST:PORT"`), CLI
    /// `--transport tcp://HOST:PORT`.
    pub worker_mode: WorkerMode,
    /// Seconds the coordinator waits for any worker result on a tcp run
    /// before failing loud (0 = wait forever; a closed connection still
    /// errors immediately either way). TOML key `worker_timeout_secs`,
    /// CLI `--worker-timeout-secs`. Ignored in local mode.
    pub worker_timeout_secs: u64,
    /// Heartbeat interval for tcp runs: the coordinator PINGs every idle
    /// worker this often and the reader tracks the last frame heard per
    /// slot, so a silent worker is named precisely when a timeout fires
    /// (0 = no heartbeats). TOML key `heartbeat_secs`, CLI
    /// `--heartbeat-secs`. Ignored in local mode.
    pub heartbeat_secs: u64,
    /// Worker-failure recovery switch: when > 0, the coordinator journals
    /// every dispatched job (RNG at dispatch + source shipment payloads),
    /// retries transient transport errors with capped exponential backoff
    /// up to this many times, and on a dead worker re-dispatches the
    /// slot's journaled jobs to a rejoined replacement — or folds them
    /// onto survivors — instead of killing the run. Recovered runs are
    /// bitwise-identical to fault-free runs. 0 (the default) keeps the
    /// PR-7 fail-loud behavior. TOML key `max_worker_retries`, CLI
    /// `--max-worker-retries`.
    pub max_worker_retries: u64,
    /// How long a recovering coordinator holds a dead slot open for a
    /// replacement `graphvite worker` to rejoin before folding the
    /// slot's work onto the surviving workers (0 = fold immediately).
    /// Only meaningful with `max_worker_retries > 0`. TOML key
    /// `rejoin_window_secs`, CLI `--rejoin-window-secs`.
    pub rejoin_window_secs: u64,
    /// Lossless shipment compression on tcp runs: partition payloads are
    /// delta-encoded against the copy the receiver already holds and the
    /// residual packed Gorilla-style ([`crate::net::compress`]) —
    /// bit-exact reconstruction, negotiated in the HELLO/ASSIGN
    /// handshake, counted by the `wire_bytes_saved` side of the wire
    /// ledger. A no-op for local (in-process) workers. TOML key
    /// `wire_compression`, CLI `--wire-compression` /
    /// `--no-wire-compression`.
    pub wire_compression: bool,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            dim: 64,
            epochs: 10,
            lr: 0.025,
            negatives: 1,
            neg_weight: 5.0,
            walk_length: 5,
            augmentation_distance: 2,
            num_workers: 4,
            worker_capacities: Vec::new(),
            num_partitions: 0,
            num_samplers: 4,
            episode_size: 200_000,
            shuffle: ShuffleKind::Pseudo,
            backend: BackendKind::Native,
            collaboration: true,
            online_augmentation: true,
            fix_context: true,
            pipeline_transfers: true,
            residency: true,
            graph_format: GraphFormat::Auto,
            graph_cache_bytes: crate::graph::ondisk::DEFAULT_CACHE_BYTES,
            batch_size: 256,
            seed: 42,
            log_every: 0,
            worker_mode: WorkerMode::Local,
            worker_timeout_secs: 0,
            heartbeat_secs: 0,
            max_worker_retries: 0,
            rejoin_window_secs: 0,
            wire_compression: true,
        }
    }
}

/// A validation failure that knows which config field it is about, so
/// [`TrainConfigBuilder`] can append where that field's value came from.
pub(crate) struct FieldError {
    pub field: &'static str,
    pub message: String,
}

macro_rules! field_bail {
    ($field:expr, $($arg:tt)*) => {
        return Err(FieldError { field: $field, message: format!($($arg)*) })
    };
}

impl TrainConfig {
    /// Validate invariants; call before training.
    pub fn validate(&self) -> Result<()> {
        self.validate_fields().map_err(|e| anyhow::anyhow!("{}", e.message))
    }

    /// The checks behind [`Self::validate`], each tagged with the config
    /// field it is about. [`TrainConfigBuilder::build`] uses the tag to
    /// report *where* the offending value came from (CLI flag, config
    /// file, or default).
    pub(crate) fn validate_fields(&self) -> std::result::Result<(), FieldError> {
        if !self.backend.available() {
            field_bail!(
                "backend",
                "backend '{}' is not compiled into this binary: rebuild with \
                 `cargo build --features pjrt` (the default feature set ships \
                 the pure-rust 'native' and 'simd' backends)",
                self.backend.name()
            );
        }
        if self.dim == 0 {
            field_bail!("dim", "dim must be positive");
        }
        if self.num_workers == 0 {
            field_bail!("num_workers", "num_workers must be positive");
        }
        if self.num_samplers == 0 {
            field_bail!("num_samplers", "num_samplers must be positive");
        }
        if !self.worker_capacities.is_empty() {
            if self.worker_capacities.len() != self.num_workers {
                field_bail!(
                    "worker_capacities",
                    "worker_capacities has {} entries but num_workers is {}",
                    self.worker_capacities.len(),
                    self.num_workers
                );
            }
            if self.worker_capacities.iter().any(|&c| c == 0) {
                field_bail!(
                    "worker_capacities",
                    "worker capacities must be >= 1, got {:?}",
                    self.worker_capacities
                );
            }
        }
        let parts = self.partitions();
        let total = self.total_capacity();
        if parts % total != 0 {
            field_bail!(
                "num_partitions",
                "num_partitions ({parts}) must be a multiple of the total worker \
                 capacity ({total}: {} workers with capacities {:?})",
                self.num_workers,
                self.capacities()
            );
        }
        if self.fix_context && parts != self.num_workers {
            field_bail!(
                "fix_context",
                "fix_context requires num_partitions == num_workers (paper section 3.4)"
            );
        }
        if self.walk_length == 0 {
            field_bail!("walk_length", "walk_length must be positive");
        }
        if self.augmentation_distance == 0 {
            field_bail!("augmentation_distance", "augmentation_distance must be positive");
        }
        if self.episode_size == 0 {
            field_bail!("episode_size", "episode_size must be positive");
        }
        if self.batch_size == 0 {
            field_bail!("batch_size", "batch_size must be positive");
        }
        if self.graph_cache_bytes == 0 {
            field_bail!(
                "graph_cache_bytes",
                "graph_cache_bytes must be positive — it is the page-cache byte \
                 budget for graph_format = \"packed\"/\"auto\" graphs"
            );
        }
        if !(self.lr > 0.0) {
            field_bail!("lr", "lr must be positive");
        }
        if self.negatives == 0 {
            field_bail!("negatives", "negatives must be >= 1");
        }
        if self.rejoin_window_secs > 0 && self.max_worker_retries == 0 {
            field_bail!(
                "rejoin_window_secs",
                "rejoin_window_secs needs max_worker_retries > 0 — the rejoin window \
                 only opens when worker-failure recovery is enabled"
            );
        }
        if matches!(self.worker_mode, WorkerMode::Tcp(_)) && self.backend == BackendKind::Pjrt {
            field_bail!(
                "backend",
                "workers = \"tcp://...\" cannot run the pjrt backend (HLO artifacts are \
                 host-local); use native or simd for multi-process training"
            );
        }
        Ok(())
    }

    /// Whether worker-failure recovery (job journaling, re-dispatch,
    /// rejoin/fold) is active. See [`TrainConfig::max_worker_retries`].
    pub fn recovery_enabled(&self) -> bool {
        self.max_worker_retries > 0
    }

    /// Load from a TOML file's `[train]` table (missing keys keep defaults).
    pub fn from_toml_file(path: impl AsRef<std::path::Path>) -> Result<Self> {
        let text = std::fs::read_to_string(path)?;
        Self::from_toml_str(&text)
    }

    /// Parse + validate in one step. The typed key mapping lives in
    /// [`TrainConfigBuilder::apply_toml_str`]; this entry point keeps
    /// the historical one-shot signature.
    pub fn from_toml_str(text: &str) -> Result<Self> {
        let mut b = TrainConfigBuilder::new();
        b.apply_toml_str(text, "config file")?;
        b.build()
    }

    /// Total positive samples this config trains (epochs × |E|).
    pub fn total_samples(&self, num_edges: usize) -> u64 {
        self.epochs as u64 * num_edges as u64
    }

    /// Effective partition count (defaults to the worker count).
    pub fn partitions(&self) -> usize {
        if self.num_partitions == 0 { self.num_workers } else { self.num_partitions }
    }

    /// Effective per-worker capacities: `worker_capacities`, or `[1; n]`
    /// for the homogeneous default.
    pub fn capacities(&self) -> Vec<usize> {
        if self.worker_capacities.is_empty() {
            vec![1; self.num_workers]
        } else {
            self.worker_capacities.clone()
        }
    }

    /// Capacity of one worker (1 unless declared).
    pub fn worker_capacity(&self, worker: usize) -> usize {
        self.worker_capacities.get(worker).copied().unwrap_or(1)
    }

    /// Total worker capacity = blocks per schedule wave. `partitions()`
    /// must be a multiple of this.
    pub fn total_capacity(&self) -> usize {
        if self.worker_capacities.is_empty() {
            self.num_workers
        } else {
            self.worker_capacities.iter().sum()
        }
    }

    /// Per-worker residency-cache limits (max resident partitions), or
    /// `None` for the unbounded homogeneous default. `2 × capacity`: the
    /// vertex + context working set of the worker's concurrent blocks —
    /// declaring capacities is what opts a run into bounded residency
    /// (ROADMAP "cap the worker residency cache").
    pub fn residency_limits(&self) -> Option<Vec<usize>> {
        if self.worker_capacities.is_empty() {
            None
        } else {
            Some(self.worker_capacities.iter().map(|&c| 2 * c).collect())
        }
    }

    /// Parse a CLI-style comma-separated capacity list (`"2,1"` →
    /// `[2, 1]`) — the `--capacities` flag.
    pub fn parse_capacity_list(s: &str) -> Result<Vec<usize>> {
        s.split(',')
            .map(|t| {
                let t = t.trim();
                t.parse::<usize>().ok().filter(|&c| c > 0).ok_or_else(|| {
                    anyhow::anyhow!(
                        "capacity '{t}' is not a positive integer (expected e.g. \"2,1\")"
                    )
                })
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_are_valid() {
        TrainConfig::default().validate().unwrap();
    }

    #[test]
    fn toml_overrides() {
        let cfg = TrainConfig::from_toml_str(
            r#"
            [train]
            dim = 32
            epochs = 7
            lr = 0.05
            shuffle = "random"
            backend = "native"
            collaboration = false
            "#,
        )
        .unwrap();
        assert_eq!(cfg.dim, 32);
        assert_eq!(cfg.epochs, 7);
        assert!((cfg.lr - 0.05).abs() < 1e-9);
        assert_eq!(cfg.shuffle, ShuffleKind::Random);
        assert_eq!(cfg.backend, BackendKind::Native);
        assert!(!cfg.collaboration);
        // untouched keys keep defaults
        assert_eq!(cfg.negatives, 1);
        assert!(cfg.pipeline_transfers);
        assert!(cfg.residency);
    }

    #[test]
    fn transfer_engine_flags_round_trip() {
        let cfg = TrainConfig::from_toml_str(
            "[train]\npipeline_transfers = false\nresidency = false\n",
        )
        .unwrap();
        assert!(!cfg.pipeline_transfers);
        assert!(!cfg.residency);
        assert!(TrainConfig::from_toml_str("residency = 3\n").is_err());
    }

    #[test]
    fn backend_names_and_aliases() {
        assert_eq!(BackendKind::parse("pjrt"), Some(BackendKind::Pjrt));
        assert_eq!(BackendKind::parse("hlo"), Some(BackendKind::Pjrt)); // legacy
        assert_eq!(BackendKind::parse("native"), Some(BackendKind::Native));
        assert_eq!(BackendKind::parse("simd"), Some(BackendKind::Simd));
        assert_eq!(BackendKind::parse("cuda"), None);
        assert_eq!(BackendKind::Pjrt.name(), "pjrt");
        assert!(BackendKind::Native.available());
        assert!(BackendKind::Simd.available());
    }

    #[test]
    fn backend_surfaces_derive_from_the_table() {
        // name -> parse round-trips for every variant and every alias
        for &b in BackendKind::ALL {
            assert_eq!(BackendKind::parse(b.name()), Some(b));
            for alias in b.aliases() {
                assert_eq!(BackendKind::parse(alias), Some(b), "alias '{alias}'");
            }
            // every variant shows up in the generated help surfaces
            assert!(BackendKind::names_joined().contains(b.name()));
            assert!(BackendKind::help_text().contains(b.name()));
            assert!(BackendKind::help_text().contains(b.summary()));
        }
        // aliases render in the help block too (the "hlo" line regression)
        assert!(BackendKind::help_text().contains("alias: hlo"));
        // and the unknown-backend error names the valid spellings
        let err = TrainConfig::from_toml_str("backend = \"cuda\"\n")
            .unwrap_err()
            .to_string();
        for &b in BackendKind::ALL {
            assert!(err.contains(b.name()), "error '{err}' misses '{}'", b.name());
        }
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn pjrt_backend_rejected_without_feature() {
        let cfg = TrainConfig { backend: BackendKind::Pjrt, ..TrainConfig::default() };
        let err = cfg.validate().unwrap_err().to_string();
        assert!(err.contains("--features pjrt"), "unhelpful error: {err}");
        // the TOML path surfaces the same error
        assert!(TrainConfig::from_toml_str("backend = \"pjrt\"\n").is_err());
        // without pjrt the unrolled pure-rust backend is the best available
        assert_eq!(BackendKind::best_available(), BackendKind::Simd);
    }

    #[cfg(feature = "pjrt")]
    #[test]
    fn pjrt_backend_accepted_with_feature() {
        let cfg = TrainConfig { backend: BackendKind::Pjrt, ..TrainConfig::default() };
        cfg.validate().unwrap();
        assert_eq!(BackendKind::best_available(), BackendKind::Pjrt);
    }

    #[test]
    fn graph_format_round_trips() {
        let cfg = TrainConfig::from_toml_str(
            "[train]\ngraph_format = \"packed\"\ngraph_cache_bytes = 1048576\n",
        )
        .unwrap();
        assert_eq!(cfg.graph_format, GraphFormat::Packed);
        assert_eq!(cfg.graph_cache_bytes, 1 << 20);
        // defaults: sniffing loader, 64 MiB page budget
        let d = TrainConfig::default();
        assert_eq!(d.graph_format, GraphFormat::Auto);
        assert_eq!(d.graph_cache_bytes, crate::graph::ondisk::DEFAULT_CACHE_BYTES);
        // bad values are rejected with the valid spellings in the error
        let err = TrainConfig::from_toml_str("graph_format = \"mmap\"\n")
            .unwrap_err()
            .to_string();
        for &f in GraphFormat::ALL {
            assert!(err.contains(f.name()), "error '{err}' misses '{}'", f.name());
        }
        assert!(TrainConfig::from_toml_str("graph_format = 3\n").is_err());
        // a zero page budget cannot load any packed graph — validate refuses
        let err = TrainConfig::from_toml_str("graph_cache_bytes = 0\n")
            .unwrap_err()
            .to_string();
        assert!(err.contains("graph_cache_bytes"), "{err}");
    }

    #[test]
    fn toml_without_section_works() {
        let cfg = TrainConfig::from_toml_str("dim = 16\n").unwrap();
        assert_eq!(cfg.dim, 16);
    }

    #[test]
    fn bad_values_rejected() {
        assert!(TrainConfig::from_toml_str("dim = \"big\"\n").is_err());
        assert!(TrainConfig::from_toml_str("shuffle = \"sorted\"\n").is_err());
        assert!(TrainConfig::from_toml_str("dim = 0\n").is_err());
    }

    #[test]
    fn total_samples() {
        let cfg = TrainConfig { epochs: 3, ..Default::default() };
        assert_eq!(cfg.total_samples(100), 300);
    }

    #[test]
    fn capacity_accessors_default_to_homogeneous() {
        let cfg = TrainConfig { num_workers: 3, ..Default::default() };
        assert_eq!(cfg.capacities(), vec![1, 1, 1]);
        assert_eq!(cfg.total_capacity(), 3);
        assert_eq!(cfg.worker_capacity(1), 1);
        assert_eq!(cfg.residency_limits(), None, "default residency is unbounded");
    }

    #[test]
    fn declared_capacities_validate_and_bound_residency() {
        let cfg = TrainConfig {
            num_workers: 2,
            num_partitions: 4,
            fix_context: false,
            worker_capacities: vec![1, 3],
            ..Default::default()
        };
        cfg.validate().unwrap();
        assert_eq!(cfg.capacities(), vec![1, 3]);
        assert_eq!(cfg.total_capacity(), 4);
        assert_eq!(cfg.worker_capacity(1), 3);
        assert_eq!(cfg.residency_limits(), Some(vec![2, 6]));

        // wrong arity
        let bad = TrainConfig { worker_capacities: vec![1], ..cfg.clone() };
        assert!(bad.validate().unwrap_err().to_string().contains("num_workers"));
        // zero capacity
        let bad = TrainConfig { worker_capacities: vec![0, 4], ..cfg.clone() };
        assert!(bad.validate().is_err());
        // partitions not a multiple of the total capacity (4 % 3)
        let bad = TrainConfig { worker_capacities: vec![2, 1], ..cfg.clone() };
        assert!(bad.validate().unwrap_err().to_string().contains("multiple"));
        // declared capacities with the default partition count (2 % 4)
        let bad = TrainConfig { num_partitions: 0, ..cfg };
        assert!(bad.validate().is_err());
    }

    #[test]
    fn worker_capacities_toml_round_trip() {
        let cfg = TrainConfig::from_toml_str(
            "[train]\nnum_workers = 2\nnum_partitions = 4\nfix_context = false\n\
             worker_capacities = [1, 3]\n",
        )
        .unwrap();
        assert_eq!(cfg.worker_capacities, vec![1, 3]);
        assert_eq!(cfg.total_capacity(), 4);
        // scalars, floats, zeros and negatives are all rejected
        assert!(TrainConfig::from_toml_str("worker_capacities = 2\n").is_err());
        assert!(TrainConfig::from_toml_str("worker_capacities = [1.5, 1]\n").is_err());
        assert!(TrainConfig::from_toml_str("worker_capacities = [0, 1]\n").is_err());
        assert!(TrainConfig::from_toml_str("worker_capacities = [-1, 1]\n").is_err());
    }

    #[test]
    fn capacity_list_parses_cli_spelling() {
        assert_eq!(TrainConfig::parse_capacity_list("2,1").unwrap(), vec![2, 1]);
        assert_eq!(TrainConfig::parse_capacity_list(" 1, 3 ").unwrap(), vec![1, 3]);
        assert!(TrainConfig::parse_capacity_list("2,zero").is_err());
        assert!(TrainConfig::parse_capacity_list("2,,1").is_err());
        assert!(TrainConfig::parse_capacity_list("0").is_err());
    }

    #[test]
    fn worker_mode_parses_and_round_trips() {
        assert_eq!(WorkerMode::parse("local").unwrap(), WorkerMode::Local);
        assert_eq!(
            WorkerMode::parse("tcp://127.0.0.1:7077").unwrap(),
            WorkerMode::Tcp("127.0.0.1:7077".to_string())
        );
        for s in ["local", "tcp://127.0.0.1:7077"] {
            assert_eq!(WorkerMode::parse(s).unwrap().spelling(), s);
        }
        assert!(WorkerMode::parse("tcp://").is_err());
        assert!(WorkerMode::parse("udp://1.2.3.4:5").is_err());
        assert!(WorkerMode::parse("remote").is_err());
    }

    #[test]
    fn worker_mode_toml_and_validation() {
        let cfg = TrainConfig::from_toml_str(
            "[train]\nworkers = \"tcp://127.0.0.1:7077\"\nworker_timeout_secs = 30\n",
        )
        .unwrap();
        assert_eq!(cfg.worker_mode, WorkerMode::Tcp("127.0.0.1:7077".to_string()));
        assert_eq!(cfg.worker_timeout_secs, 30);
        // defaults: in-process workers, no timeout
        let d = TrainConfig::default();
        assert_eq!(d.worker_mode, WorkerMode::Local);
        assert_eq!(d.worker_timeout_secs, 0);
        // bad spellings are rejected with the valid ones in the error
        let err = TrainConfig::from_toml_str("workers = \"remote\"\n").unwrap_err().to_string();
        assert!(err.contains("local") && err.contains("tcp://"), "{err}");
        assert!(TrainConfig::from_toml_str("workers = 3\n").is_err());
        // pjrt cannot serve remote workers: artifacts are host-local
        let cfg = TrainConfig {
            backend: BackendKind::Pjrt,
            worker_mode: WorkerMode::Tcp("127.0.0.1:0".to_string()),
            ..TrainConfig::default()
        };
        let err = cfg.validate().unwrap_err().to_string();
        assert!(err.contains("pjrt"), "{err}");
    }

    #[test]
    fn recovery_keys_toml_defaults_and_validation() {
        let cfg = TrainConfig::from_toml_str(
            "[train]\nheartbeat_secs = 5\nmax_worker_retries = 3\nrejoin_window_secs = 10\n",
        )
        .unwrap();
        assert_eq!(cfg.heartbeat_secs, 5);
        assert_eq!(cfg.max_worker_retries, 3);
        assert_eq!(cfg.rejoin_window_secs, 10);
        assert!(cfg.recovery_enabled());
        // defaults: recovery off, no heartbeats — PR-7 fail-loud behavior
        let d = TrainConfig::default();
        assert_eq!(d.heartbeat_secs, 0);
        assert_eq!(d.max_worker_retries, 0);
        assert_eq!(d.rejoin_window_secs, 0);
        assert!(!d.recovery_enabled());
        // a rejoin window without recovery enabled is a config error
        let cfg = TrainConfig { rejoin_window_secs: 4, ..TrainConfig::default() };
        let err = cfg.validate().unwrap_err().to_string();
        assert!(err.contains("max_worker_retries"), "{err}");
    }

    #[test]
    fn test_backend_defaults_to_native() {
        // CI's backend matrix overrides via GRAPHVITE_TEST_BACKEND; the
        // bare environment must resolve to the reference backend. (Only
        // meaningful when the var is unset — skip silently otherwise.)
        if std::env::var("GRAPHVITE_TEST_BACKEND").is_err() {
            assert_eq!(BackendKind::test_backend(), BackendKind::Native);
        } else {
            assert!(BackendKind::test_backend().available());
        }
    }
}
