//! Lossless f32 shipment compression for the socket transport.
//!
//! A packed section is self-describing and bit-exact: the decoder
//! reconstructs the *identical* f32 bit patterns the encoder saw
//! (including NaN payloads, signed zeros, and subnormals), or fails with
//! a pointed error — never a silent approximation and never a panic.
//!
//! Three modes, one byte on the wire:
//! * [`MODE_STORED`] — raw little-endian bits. Emitted when compression
//!   is off, for empty sections, and as the fallback whenever the
//!   compressed bitstream would not beat raw (so on-wire payload bytes
//!   never exceed raw payload bytes).
//! * [`MODE_XOR`] — Gorilla-style chain coding: each value is XORed
//!   with its predecessor (the first with `0.0`) and the residual packed
//!   with leading/trailing-zero windows.
//! * [`MODE_DELTA`] — the same residual coding, but the predictor for
//!   element `i` is `base[i]`: the copy of this partition the receiver
//!   already holds (tracked per connection by the transport's wire
//!   cache). A 32-bit FNV-1a fingerprint of the base travels with the
//!   section so a cache divergence between the two ends is a pointed
//!   decode error instead of silent corruption.
//!
//! Residual coding (per value, after XOR with the predictor):
//! * residual == 0 → control bit `0`.
//! * else → control bit `1`, then either `0` + the meaningful bits
//!   inside the previous value's leading/trailing window (if they fit),
//!   or `1` + 5-bit leading-zero count + 5-bit (length−1) + the
//!   meaningful bits, which becomes the new window.
//!
//! Everything here is pure std; the module owns no I/O.

use anyhow::{bail, ensure, Result};

use super::Cursor;

/// Raw little-endian f32 bits; no compression.
pub const MODE_STORED: u8 = 0;
/// Gorilla chain coding (predictor = previous value).
pub const MODE_XOR: u8 = 1;
/// Delta coding against a receiver-resident base (predictor = `base[i]`).
pub const MODE_DELTA: u8 = 2;

/// Byte accounting for one packed section: `raw` is what the values
/// occupy uncompressed (`4 × count`), `wire` is what the payload
/// actually occupies on the wire (headers excluded on both sides, so
/// `wire <= raw` always and `raw - wire` is the bytes saved).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PackedLens {
    pub raw: u64,
    pub wire: u64,
}

impl PackedLens {
    pub fn saved(&self) -> u64 {
        self.raw - self.wire
    }
}

impl std::ops::AddAssign for PackedLens {
    fn add_assign(&mut self, rhs: PackedLens) {
        self.raw += rhs.raw;
        self.wire += rhs.wire;
    }
}

/// 32-bit FNV-1a over the little-endian bytes of `xs` — the base
/// fingerprint carried by [`MODE_DELTA`] sections.
pub fn fingerprint(xs: &[f32]) -> u32 {
    let mut h: u32 = 0x811c_9dc5;
    for &x in xs {
        for b in x.to_le_bytes() {
            h ^= b as u32;
            h = h.wrapping_mul(0x0100_0193);
        }
    }
    h
}

/// MSB-first bit sink backing the compressed stream.
struct BitWriter {
    buf: Vec<u8>,
    used: u32, // bits used in the last byte, 0 == byte boundary
}

impl BitWriter {
    fn new() -> Self {
        BitWriter { buf: Vec::new(), used: 0 }
    }

    fn push(&mut self, value: u32, mut n: u32) {
        debug_assert!(n <= 32);
        while n > 0 {
            if self.used == 0 {
                self.buf.push(0);
            }
            let room = 8 - self.used;
            let take = room.min(n); // take <= 8, so the mask below never overflows
            let chunk = (value >> (n - take)) & ((1u32 << take) - 1);
            let last = self.buf.len() - 1;
            self.buf[last] |= (chunk as u8) << (room - take);
            self.used = (self.used + take) % 8;
            n -= take;
        }
    }

    fn into_bytes(self) -> Vec<u8> {
        self.buf
    }
}

/// MSB-first bit source; every read is bounds-checked.
struct BitReader<'a> {
    buf: &'a [u8],
    at: usize, // bit index
}

impl<'a> BitReader<'a> {
    fn new(buf: &'a [u8]) -> Self {
        BitReader { buf, at: 0 }
    }

    fn bits(&mut self, n: u32) -> Result<u32> {
        debug_assert!(n <= 32);
        let mut out: u32 = 0;
        for _ in 0..n {
            let byte = self.at / 8;
            if byte >= self.buf.len() {
                bail!("compressed stream truncated at bit {}", self.at);
            }
            let bit = (self.buf[byte] >> (7 - (self.at % 8))) & 1;
            out = (out << 1) | bit as u32;
            self.at += 1;
        }
        Ok(out)
    }

    /// All bits consumed, modulo a zero-padded tail in the final byte.
    fn finish(self) -> Result<()> {
        let whole = self.at.div_ceil(8);
        ensure!(
            whole == self.buf.len(),
            "compressed stream has {} trailing bytes",
            self.buf.len() - whole
        );
        let pad = whole * 8 - self.at;
        if pad > 0 {
            let tail = self.buf[self.buf.len() - 1] & ((1u8 << pad) - 1);
            ensure!(tail == 0, "compressed stream has nonzero padding bits");
        }
        Ok(())
    }
}

/// Gorilla residual coding of `xs` against `predict(i)`.
fn encode_stream(xs: &[f32], predict: impl Fn(usize) -> u32) -> Vec<u8> {
    let mut w = BitWriter::new();
    let mut window: Option<(u32, u32)> = None; // (leading zeros, length)
    for (i, &x) in xs.iter().enumerate() {
        let residual = x.to_bits() ^ predict(i);
        if residual == 0 {
            w.push(0, 1);
            continue;
        }
        w.push(1, 1);
        let lead = residual.leading_zeros();
        let trail = residual.trailing_zeros();
        let len = 32 - lead - trail;
        if let Some((wl, wn)) = window {
            let wtrail = 32 - wl - wn;
            if lead >= wl && trail >= wtrail {
                w.push(0, 1);
                w.push(residual >> wtrail, wn);
                continue;
            }
        }
        w.push(1, 1);
        w.push(lead, 5);
        w.push(len - 1, 5);
        w.push(residual >> trail, len);
        window = Some((lead, len));
    }
    w.into_bytes()
}

/// Inverse of [`encode_stream`]: `count` values, same predictor.
fn decode_stream(
    bytes: &[u8],
    count: usize,
    out: &mut Vec<f32>,
    predict: impl Fn(usize, &[f32]) -> u32,
) -> Result<()> {
    let mut r = BitReader::new(bytes);
    let mut window: Option<(u32, u32)> = None;
    for i in 0..count {
        let pred = predict(i, out);
        let residual = if r.bits(1)? == 0 {
            0
        } else if r.bits(1)? == 0 {
            let (wl, wn) =
                window.ok_or_else(|| anyhow::anyhow!("compressed stream reuses a window before defining one"))?;
            r.bits(wn)? << (32 - wl - wn)
        } else {
            let lead = r.bits(5)?;
            let len = r.bits(5)? + 1;
            ensure!(lead + len <= 32, "compressed stream window {lead}+{len} exceeds 32 bits");
            let v = r.bits(len)? << (32 - lead - len);
            window = Some((lead, len));
            v
        };
        out.push(f32::from_bits(pred ^ residual));
    }
    r.finish()
}

/// Append one packed section for `xs` to `out`.
///
/// `base` is the receiver's cached copy of this partition (delta
/// predictor) if the caller's wire cache has one of matching length;
/// `compress` false forces [`MODE_STORED`] (the negotiated-off path).
/// Returns the raw/on-wire byte accounting for the section.
pub fn pack_f32s(out: &mut Vec<u8>, xs: &[f32], base: Option<&[f32]>, compress: bool) -> PackedLens {
    let raw = 4 * xs.len() as u64;
    let stored = |out: &mut Vec<u8>| {
        out.push(MODE_STORED);
        out.extend_from_slice(&(xs.len() as u32).to_le_bytes());
        for &x in xs {
            out.extend_from_slice(&x.to_le_bytes());
        }
    };
    if !compress || xs.is_empty() {
        stored(out);
        return PackedLens { raw, wire: raw };
    }
    let base = base.filter(|b| b.len() == xs.len());
    let stream = match base {
        Some(b) => encode_stream(xs, |i| b[i].to_bits()),
        None => encode_stream(xs, |i| if i == 0 { 0 } else { xs[i - 1].to_bits() }),
    };
    if stream.len() as u64 >= raw {
        stored(out);
        return PackedLens { raw, wire: raw };
    }
    match base {
        Some(b) => {
            out.push(MODE_DELTA);
            out.extend_from_slice(&(xs.len() as u32).to_le_bytes());
            out.extend_from_slice(&fingerprint(b).to_le_bytes());
        }
        None => {
            out.push(MODE_XOR);
            out.extend_from_slice(&(xs.len() as u32).to_le_bytes());
        }
    }
    out.extend_from_slice(&(stream.len() as u32).to_le_bytes());
    let wire = stream.len() as u64;
    out.extend_from_slice(&stream);
    PackedLens { raw, wire }
}

/// Decode one [`pack_f32s`] section into `out` (cleared first).
///
/// `base` is the receiver's cached copy for this partition, consulted
/// only for [`MODE_DELTA`] sections — a missing, wrong-length, or
/// wrong-fingerprint base is a pointed error, never silent corruption.
pub fn unpack_f32s(c: &mut Cursor<'_>, base: Option<&[f32]>, out: &mut Vec<f32>) -> Result<PackedLens> {
    let mode = c.u8()?;
    let count = c.u32()? as usize;
    let raw = 4 * count as u64;
    out.clear();
    match mode {
        MODE_STORED => {
            c.expect_remaining(count * 4)?;
            out.reserve(count);
            for _ in 0..count {
                out.push(c.f32()?);
            }
            Ok(PackedLens { raw, wire: raw })
        }
        MODE_XOR | MODE_DELTA => {
            let fp = if mode == MODE_DELTA { Some(c.u32()?) } else { None };
            let nbytes = c.u32()? as usize;
            c.expect_remaining(nbytes)?;
            ensure!(
                count <= nbytes.saturating_mul(8),
                "compressed section declares {count} values in {nbytes} bytes"
            );
            let stream = c.bytes(nbytes)?;
            out.reserve(count);
            if let Some(fp) = fp {
                let base = match base {
                    Some(b) if b.len() == count => b,
                    Some(b) => bail!(
                        "delta section expects a {count}-value base, wire cache holds {} values",
                        b.len()
                    ),
                    None => bail!("delta section without a wire-cached base ({count} values)"),
                };
                ensure!(
                    fingerprint(base) == fp,
                    "delta base fingerprint mismatch: wire caches diverged ({count} values)"
                );
                decode_stream(stream, count, out, |i, _| base[i].to_bits())?;
            } else {
                decode_stream(stream, count, out, |i, got| {
                    if i == 0 {
                        0
                    } else {
                        got[i - 1].to_bits()
                    }
                })?;
            }
            Ok(PackedLens { raw, wire: nbytes as u64 })
        }
        other => bail!("unknown compression mode {other:#x}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Deterministic pseudo-random generator (LCG) — no rand dependency.
    struct Lcg(u64);

    impl Lcg {
        fn next(&mut self) -> u32 {
            self.0 = self.0.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            (self.0 >> 33) as u32
        }

        fn f32(&mut self) -> f32 {
            f32::from_bits(self.next())
        }
    }

    fn roundtrip(xs: &[f32], base: Option<&[f32]>, compress: bool) -> (Vec<f32>, PackedLens, PackedLens) {
        let mut buf = Vec::new();
        let enc = pack_f32s(&mut buf, xs, base, compress);
        let mut c = Cursor::new(&buf);
        let mut out = Vec::new();
        let dec = unpack_f32s(&mut c, base, &mut out).unwrap();
        c.finish().unwrap();
        (out, enc, dec)
    }

    fn assert_bits(a: &[f32], b: &[f32]) {
        assert_eq!(
            a.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            b.iter().map(|x| x.to_bits()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn special_values_roundtrip_bit_exact() {
        let xs = [
            0.0f32,
            -0.0,
            f32::NAN,
            f32::from_bits(0x7fc0_dead), // NaN with payload
            f32::from_bits(0xffc0_0001), // negative NaN
            f32::INFINITY,
            f32::NEG_INFINITY,
            f32::MIN_POSITIVE,
            f32::from_bits(1),          // smallest subnormal
            f32::from_bits(0x8000_0001), // negative subnormal
            f32::MAX,
            f32::MIN,
            1.0,
            -1.0,
        ];
        for compress in [false, true] {
            let (out, enc, dec) = roundtrip(&xs, None, compress);
            assert_bits(&out, &xs);
            assert_eq!(enc, dec);
        }
        // delta against a shifted copy of itself
        let base: Vec<f32> = xs.iter().map(|x| f32::from_bits(x.to_bits() ^ 0x3)).collect();
        let (out, enc, dec) = roundtrip(&xs, Some(&base), true);
        assert_bits(&out, &xs);
        assert_eq!(enc, dec);
    }

    #[test]
    fn random_matrices_roundtrip_bit_exact() {
        let mut rng = Lcg(0x1234_5678_9abc_def0);
        for round in 0..40 {
            let n = (rng.next() % 300) as usize;
            // mix fully random bit patterns (worst case: NaNs, infs,
            // subnormals) with trained-looking small perturbations
            let xs: Vec<f32> = (0..n)
                .map(|i| {
                    if round % 2 == 0 {
                        rng.f32()
                    } else {
                        (i as f32 * 0.01).sin() * 0.1
                    }
                })
                .collect();
            let base: Vec<f32> = xs
                .iter()
                .map(|x| {
                    if rng.next() % 4 == 0 {
                        *x // unchanged element: residual 0
                    } else {
                        f32::from_bits(x.to_bits() ^ (rng.next() & 0xff))
                    }
                })
                .collect();
            for (b, compress) in [(None, false), (None, true), (Some(&base), true)] {
                let (out, enc, dec) = roundtrip(&xs, b.map(|v| &v[..]), compress);
                assert_bits(&out, &xs);
                assert_eq!(enc, dec);
                assert_eq!(enc.raw, 4 * n as u64);
                assert!(enc.wire <= enc.raw, "on-wire never exceeds raw");
            }
        }
    }

    #[test]
    fn near_base_shipments_actually_shrink() {
        // a trained partition differs from the shipped copy by small
        // mantissa updates — exactly the delta-mode sweet spot
        let mut rng = Lcg(7);
        let base: Vec<f32> = (0..512).map(|i| (i as f32 * 0.02).cos()).collect();
        let xs: Vec<f32> =
            base.iter().map(|x| f32::from_bits(x.to_bits() ^ (rng.next() & 0x1f))).collect();
        let (out, enc, _) = roundtrip(&xs, Some(&base), true);
        assert_bits(&out, &xs);
        assert!(enc.wire < enc.raw / 2, "delta mode saves >2x here, got {enc:?}");
        assert_eq!(enc.saved(), enc.raw - enc.wire);
    }

    #[test]
    fn incompressible_data_falls_back_to_stored() {
        let mut rng = Lcg(99);
        let xs: Vec<f32> = (0..256).map(|_| rng.f32()).collect();
        let mut buf = Vec::new();
        let enc = pack_f32s(&mut buf, &xs, None, true);
        assert_eq!(enc.wire, enc.raw, "random bits must not expand on the wire");
        assert_eq!(buf[0], MODE_STORED);
    }

    #[test]
    fn empty_section_roundtrips() {
        let (out, enc, dec) = roundtrip(&[], None, true);
        assert!(out.is_empty());
        assert_eq!(enc, PackedLens { raw: 0, wire: 0 });
        assert_eq!(enc, dec);
    }

    #[test]
    fn corrupt_and_truncated_sections_fail_pointed() {
        let mut rng = Lcg(42);
        let base: Vec<f32> = (0..64).map(|_| rng.f32()).collect();
        let xs: Vec<f32> =
            base.iter().map(|x| f32::from_bits(x.to_bits() ^ 0x7)).collect();
        let mut buf = Vec::new();
        pack_f32s(&mut buf, &xs, Some(&base), true);
        assert_eq!(buf[0], MODE_DELTA);

        let decode = |bytes: &[u8], b: Option<&[f32]>| {
            let mut c = Cursor::new(bytes);
            let mut out = Vec::new();
            unpack_f32s(&mut c, b, &mut out).and_then(|l| c.finish().map(|_| l))
        };

        // every truncation point errors, never panics
        for cut in 0..buf.len() {
            assert!(decode(&buf[..cut], Some(&base)).is_err(), "truncated at {cut}");
        }
        // unknown mode byte
        let mut bad = buf.clone();
        bad[0] = 9;
        let err = decode(&bad, Some(&base)).unwrap_err();
        assert!(err.to_string().contains("unknown compression mode"), "{err}");
        // delta without a base is pointed
        let err = decode(&buf, None).unwrap_err();
        assert!(err.to_string().contains("without a wire-cached base"), "{err}");
        // delta against a diverged base is pointed
        let mut other = base.clone();
        other[0] = f32::from_bits(other[0].to_bits() ^ 1);
        let err = decode(&buf, Some(&other)).unwrap_err();
        assert!(err.to_string().contains("fingerprint mismatch"), "{err}");
        // wrong-length base is pointed
        let err = decode(&buf, Some(&base[..10])).unwrap_err();
        assert!(err.to_string().contains("wire cache holds"), "{err}");
        // flipped bitstream bits either fail or decode to *something*,
        // but must never panic; padding corruption is always caught
        let mut padded = buf.clone();
        let last = padded.len() - 1;
        padded[last] ^= 0xff;
        let _ = decode(&padded, Some(&base));
        // a count that outruns its bitstream is rejected before allocation
        let mut hostile = vec![MODE_XOR];
        hostile.extend_from_slice(&u32::MAX.to_le_bytes()); // count
        hostile.extend_from_slice(&2u32.to_le_bytes()); // nbytes
        hostile.extend_from_slice(&[0, 0]);
        let err = decode(&hostile, None).unwrap_err();
        assert!(err.to_string().contains("declares"), "{err}");
    }

    #[test]
    fn disabled_compression_is_pure_stored() {
        let xs = [1.0f32, 2.0, 3.0];
        let mut buf = Vec::new();
        let lens = pack_f32s(&mut buf, &xs, Some(&xs[..]), false);
        assert_eq!(buf[0], MODE_STORED);
        assert_eq!(lens.saved(), 0);
    }
}
