//! Out-of-core graph storage: a versioned on-disk CSR format
//! (gap + varint successor compression, the webgraph idiom) plus the
//! [`PagedCsr`] reader that streams it through a bounded LRU page cache.
//!
//! GraphVite's headline claim is scale — 66M nodes / 1.8B edges on one
//! machine — and this module is what removes RAM from that equation:
//! per-node scalars (offsets, degrees, weighted degrees, labels, the
//! reorder permutation, alias ledger) stay resident (O(V)), while both
//! O(E) payloads — successor lists *and* the weighted walker's alias
//! tables — are read on demand with
//! `std::os::unix::fs::FileExt::read_exact_at` into fixed-size pages
//! recycled through one LRU cache bounded by a configurable byte budget.
//! Packing itself is external sort-merge under a `--pack-mem-bytes`
//! budget, so neither writing nor reading a packed graph ever
//! materializes its CSR.
//!
//! # File layout (`.gvpk` version 2, little-endian throughout)
//!
//! ```text
//! ┌──────────────────────── header, 96 bytes ────────────────────────┐
//! │ 0   magic             [u8;4]  = "GVPK"                           │
//! │ 4   version           u32     = 2                                │
//! │ 8   num_nodes         u64                                        │
//! │ 16  num_arcs          u64     (adjacency entries = 2 × edges)    │
//! │ 24  page_size         u32     (bytes per cached page)            │
//! │ 28  flags             u32     (bit 0 unit-weights, bit 1 labels, │
//! │                                bit 2 perm, bit 3 alias sidecar)  │
//! │ 32  offsets_pos       u64 ┐                                      │
//! │ 40  degrees_pos       u64 │                                      │
//! │ 48  wdegrees_pos      u64 │  absolute byte positions of the      │
//! │ 56  labels_pos        u64 │  sections below                      │
//! │ 64  perm_pos          u64 │  (0 when the section is absent)      │
//! │ 72  alias_offsets_pos u64 │                                      │
//! │ 80  pages_pos         u64 │                                      │
//! │ 88  alias_pages_pos   u64 ┘                                      │
//! ├─ offsets        (num_nodes + 1) × u64  byte offsets into `pages` ┤
//! ├─ degrees         num_nodes × u32       adjacency counts          │
//! ├─ wdegrees        num_nodes × f32       weighted degrees          │
//! ├─ labels         [num_nodes × u16]      only with flag bit 1      │
//! ├─ perm           [num_nodes × u32]      only with flag bit 2:     │
//! │                   perm[new_id] = external (pre-reorder) id,      │
//! │                   a bijection over 0..num_nodes                  │
//! ├─ alias_offsets  [(num_nodes + 1) × u64] only with flag bit 3:    │
//! │                   byte offsets into `alias_pages`; node v spans  │
//! │                   8 × degree(v) bytes when degree(v) ≥ 2, else 0 │
//! ├─ pages           offsets[num_nodes] bytes of per-node records:   │
//! │                    varint(first target),                         │
//! │                    varint(zigzag(gap)) × (degree − 1),           │
//! │                    [f32 × degree weights]  only without bit 0    │
//! ├─ alias_pages    [alias_offsets[num_nodes] bytes]: per node with  │
//! │                   degree ≥ 2, its Vose table as                  │
//! │                   f32 × degree probs then u32 × degree aliases   │
//! └──────────────────────────────────────────────────────────────────┘
//! ```
//!
//! Gaps are zigzag-encoded signed deltas, **not** sorted-ascending
//! unsigned gaps: the record must reproduce the builder's adjacency
//! order byte-exactly (neighbor order feeds the walker's RNG indexing,
//! and training off a packed file must be bitwise-identical to training
//! off the in-RAM loader). Builder rows are sorted, so the deltas are
//! small and the compression is the same in practice.
//!
//! The alias sidecar (flag bit 3) is present **iff** the graph is
//! weighted (`has_alias == !unit_weights`, enforced at open): it holds
//! the exact tables [`AliasTable::new`] would build from each row's
//! weights, so the walker streams them through the page cache instead of
//! keeping O(E) tables resident — and samples through
//! [`AliasTable::sample_slices`], drawing the identical RNG sequence.
//!
//! Fail-loud policy: `open` validates magic, version, section geometry,
//! offset monotonicity, the degree/arc ledger, the per-node alias
//! ledger, the perm bijection and the exact file length (truncation and
//! trailing garbage are both errors). After open, a record that decodes
//! to the wrong length (corrupt page), an alias entry out of range, or
//! an I/O error panics — never train on garbage.

use std::cell::RefCell;
use std::cmp::Reverse;
use std::collections::{BinaryHeap, HashMap};
use std::fs::File;
use std::io::{BufRead, BufReader, BufWriter, Read, Write};
use std::os::unix::fs::FileExt;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use anyhow::{bail, ensure, Context, Result};

use super::reorder::{bfs_order, invert_order, ReorderKind};
use super::{Graph, GraphStore};
use crate::sampling::AliasTable;

/// File magic: "GraphVite PacKed".
pub const MAGIC: [u8; 4] = *b"GVPK";
/// On-disk format version this binary reads and writes. Version 2 added
/// the reorder permutation and streamed-alias sidecars (and grew the
/// header to 96 bytes); version-1 files must be repacked.
pub const FORMAT_VERSION: u32 = 2;
/// Default successor-page size (64 KiB — a few thousand records per page
/// on typical degree distributions).
pub const DEFAULT_PAGE_SIZE: u32 = 64 * 1024;
/// Default page-cache byte budget ([`crate::config::TrainConfig::graph_cache_bytes`]).
pub const DEFAULT_CACHE_BYTES: usize = 64 * 1024 * 1024;
/// Default packing memory budget (`--pack-mem-bytes`): spillable-run +
/// merge-buffer bytes during [`pack_edge_list`].
pub const DEFAULT_PACK_MEM_BYTES: usize = 256 * 1024 * 1024;

const HEADER_LEN: usize = 96;
const FLAG_UNIT_WEIGHTS: u32 = 1;
const FLAG_HAS_LABELS: u32 = 2;
const FLAG_HAS_PERM: u32 = 4;
const FLAG_HAS_ALIAS: u32 = 8;
const KNOWN_FLAGS: u32 = FLAG_UNIT_WEIGHTS | FLAG_HAS_LABELS | FLAG_HAS_PERM | FLAG_HAS_ALIAS;

// ------------------------------------------------------------- format --

/// Which loader a graph path goes through
/// (`TrainConfig.graph_format` / `--graph-format`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum GraphFormat {
    /// Sniff the file: packed magic → [`PagedCsr`], anything else → the
    /// edge-list loader. The default.
    Auto,
    /// Force the text edge-list loader (in-RAM CSR).
    Edgelist,
    /// Force the packed on-disk reader; non-packed input is an error.
    Packed,
}

impl GraphFormat {
    /// Every format, in display order (mirrors `BackendKind::ALL`).
    pub const ALL: &'static [GraphFormat] = &[Self::Auto, Self::Edgelist, Self::Packed];

    pub fn parse(s: &str) -> Option<Self> {
        Self::ALL.iter().copied().find(|f| f.name() == s)
    }

    /// [`Self::parse`] with the one canonical unknown-format error — the
    /// CLI flags and the TOML key all fail through here so the message
    /// cannot drift between surfaces.
    pub fn parse_or_err(s: &str) -> Result<Self> {
        Self::parse(s).ok_or_else(|| {
            anyhow::anyhow!(
                "unknown graph format '{s}' (expected one of: {})",
                Self::names_joined()
            )
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            Self::Auto => "auto",
            Self::Edgelist => "edgelist",
            Self::Packed => "packed",
        }
    }

    /// `"auto|edgelist|packed"` — for usage lines and error messages.
    pub fn names_joined() -> String {
        let names: Vec<&str> = Self::ALL.iter().map(|f| f.name()).collect();
        names.join("|")
    }
}

/// `pack` tunables.
#[derive(Debug, Clone, Copy)]
pub struct PackOptions {
    /// Successor-page size in bytes (the cache granularity of readers).
    pub page_size: u32,
    /// Packing memory budget in bytes (`--pack-mem-bytes`): bounds the
    /// in-RAM run buffer and merge read-buffers of [`pack_edge_list`].
    pub mem_bytes: usize,
    /// Node renumbering applied while packing (`--reorder`).
    pub reorder: ReorderKind,
}

impl Default for PackOptions {
    fn default() -> Self {
        PackOptions {
            page_size: DEFAULT_PAGE_SIZE,
            mem_bytes: DEFAULT_PACK_MEM_BYTES,
            reorder: ReorderKind::None,
        }
    }
}

/// What `pack` wrote (CLI reporting + tests).
#[derive(Debug, Clone, Copy)]
pub struct PackStats {
    pub num_nodes: usize,
    pub num_arcs: usize,
    /// Bytes of the compressed successor section.
    pub payload_bytes: u64,
    /// Bytes of the streamed alias sidecar (0 for unit-weight graphs).
    pub alias_bytes: u64,
    /// Total file size.
    pub file_bytes: u64,
}

impl PackStats {
    /// Compressed successor bytes per adjacency entry (raw in-RAM CSR
    /// spends 8: u32 target + f32 weight).
    pub fn bytes_per_arc(&self) -> f64 {
        if self.num_arcs == 0 {
            0.0
        } else {
            self.payload_bytes as f64 / self.num_arcs as f64
        }
    }
}

#[inline]
fn zigzag(x: i64) -> u64 {
    ((x << 1) ^ (x >> 63)) as u64
}

#[inline]
fn unzigzag(u: u64) -> i64 {
    ((u >> 1) as i64) ^ -((u & 1) as i64)
}

fn put_varint(out: &mut Vec<u8>, mut x: u64) {
    while x >= 0x80 {
        out.push((x as u8) | 0x80);
        x >>= 7;
    }
    out.push(x as u8);
}

fn read_varint(bytes: &[u8], cur: &mut usize) -> Result<u64> {
    let mut x = 0u64;
    let mut shift = 0u32;
    loop {
        let Some(&b) = bytes.get(*cur) else {
            bail!("varint overruns record (corrupt or truncated page)");
        };
        *cur += 1;
        ensure!(shift < 64, "varint longer than 64 bits (corrupt page)");
        x |= ((b & 0x7f) as u64) << shift;
        if b & 0x80 == 0 {
            return Ok(x);
        }
        shift += 7;
    }
}

/// Decode one node record. `weights: Some` also parses the weight tail;
/// either way the record must be consumed exactly (fail-loud on corrupt
/// pages).
fn decode_record(
    bytes: &[u8],
    deg: usize,
    unit_weights: bool,
    targets: &mut Vec<u32>,
    mut weights: Option<&mut Vec<f32>>,
) -> Result<()> {
    targets.clear();
    if let Some(w) = weights.as_deref_mut() {
        w.clear();
    }
    let mut cur = 0usize;
    if deg > 0 {
        let first = read_varint(bytes, &mut cur)?;
        ensure!(first <= u32::MAX as u64, "target id out of range (corrupt page)");
        targets.push(first as u32);
        let mut prev = first as i64;
        for _ in 1..deg {
            let t = prev + unzigzag(read_varint(bytes, &mut cur)?);
            ensure!(
                (0..=u32::MAX as i64).contains(&t),
                "gap walks outside the id range (corrupt page)"
            );
            targets.push(t as u32);
            prev = t;
        }
    }
    if unit_weights {
        if let Some(w) = weights {
            w.resize(deg, 1.0);
        }
    } else if let Some(w) = weights {
        for _ in 0..deg {
            ensure!(cur + 4 <= bytes.len(), "weight tail truncated (corrupt page)");
            w.push(f32::from_le_bytes(bytes[cur..cur + 4].try_into().unwrap()));
            cur += 4;
        }
    } else {
        ensure!(
            bytes.len() >= cur && bytes.len() - cur == 4 * deg,
            "weight tail has the wrong length (corrupt page)"
        );
        cur += 4 * deg;
    }
    ensure!(cur == bytes.len(), "record length mismatch (corrupt page)");
    Ok(())
}

// --------------------------------------------------------------- pack --

/// Sibling temp-file path for pack-time spools (same directory as the
/// output so the final copy never crosses filesystems).
fn spool_path(output: &Path, tag: &str) -> PathBuf {
    let mut name = output.file_name().map(|s| s.to_os_string()).unwrap_or_default();
    name.push(format!(".{tag}.tmp"));
    output.with_file_name(name)
}

/// Append-only temp-file writer for an O(E) section; `copy_into` streams
/// it into the final file and removes it (Drop removes it on error
/// paths).
struct Spool {
    path: PathBuf,
    w: BufWriter<File>,
    len: u64,
}

impl Spool {
    fn create(path: PathBuf) -> Result<Self> {
        let file =
            File::create(&path).with_context(|| format!("create spool {}", path.display()))?;
        Ok(Spool { path, w: BufWriter::new(file), len: 0 })
    }

    fn write(&mut self, bytes: &[u8]) -> Result<()> {
        self.w.write_all(bytes)?;
        self.len += bytes.len() as u64;
        Ok(())
    }

    fn len(&self) -> u64 {
        self.len
    }

    fn copy_into<W: Write>(mut self, out: &mut W) -> Result<()> {
        self.w.flush()?;
        let mut f = File::open(&self.path)
            .with_context(|| format!("reopen spool {}", self.path.display()))?;
        std::io::copy(&mut f, out)?;
        Ok(())
    }
}

impl Drop for Spool {
    fn drop(&mut self) {
        let _ = std::fs::remove_file(&self.path);
    }
}

/// Streaming low-level `.gvpk` writer shared by every pack path
/// ([`pack_store`] and the external-sort [`pack_edge_list`]): resident
/// state is O(V) (offsets/degrees/wdegrees/labels/perm/alias ledger);
/// both O(E) payloads go straight to disk spools. Equivalent inputs
/// produce byte-identical files regardless of which path fed them.
struct PackWriter {
    path: PathBuf,
    page_size: u32,
    unit: bool,
    offsets: Vec<u64>,
    degrees: Vec<u32>,
    wdegrees: Vec<f32>,
    labels: Option<Vec<u16>>,
    external_ids: Option<Vec<u32>>,
    /// `Some` iff `!unit` (the `has_alias == !unit_weights` invariant is
    /// decided here, at write time).
    alias_offsets: Option<Vec<u64>>,
    pages: Spool,
    alias_pages: Spool,
    buf: Vec<u8>,
}

impl PackWriter {
    fn new(
        path: &Path,
        num_nodes: usize,
        page_size: u32,
        unit: bool,
        labels: Option<Vec<u16>>,
        external_ids: Option<Vec<u32>>,
    ) -> Result<Self> {
        ensure!(
            (16..=1 << 30).contains(&page_size),
            "page_size {page_size} out of range (16 bytes .. 1 GiB)"
        );
        if let Some(l) = &labels {
            ensure!(l.len() == num_nodes, "label vector length must match node count");
        }
        if let Some(p) = &external_ids {
            ensure!(p.len() == num_nodes, "perm vector length must match node count");
        }
        // pre-reserve: n is known, so resident sections never pay vec
        // doubling-growth transients (the pack-memory bound counts on it)
        let mut offsets = Vec::with_capacity(num_nodes + 1);
        offsets.push(0u64);
        let alias_offsets = if unit {
            None
        } else {
            let mut ao = Vec::with_capacity(num_nodes + 1);
            ao.push(0u64);
            Some(ao)
        };
        Ok(PackWriter {
            path: path.to_path_buf(),
            page_size,
            unit,
            offsets,
            degrees: Vec::with_capacity(num_nodes),
            wdegrees: Vec::with_capacity(num_nodes),
            labels,
            external_ids,
            alias_offsets,
            pages: Spool::create(spool_path(path, "pages"))?,
            alias_pages: Spool::create(spool_path(path, "alias"))?,
            buf: Vec::new(),
        })
    }

    /// Append the next node's row (targets in final adjacency order,
    /// weights parallel — all 1.0 for unit graphs). Nodes must be pushed
    /// exactly in id order.
    fn push_node(&mut self, targets: &[u32], weights: &[f32]) -> Result<()> {
        debug_assert_eq!(targets.len(), weights.len());
        let deg = targets.len();
        self.buf.clear();
        if let Some((&first, rest)) = targets.split_first() {
            put_varint(&mut self.buf, first as u64);
            let mut prev = first as i64;
            for &t in rest {
                put_varint(&mut self.buf, zigzag(t as i64 - prev));
                prev = t as i64;
            }
        }
        if !self.unit {
            for &w in weights {
                self.buf.extend_from_slice(&w.to_le_bytes());
            }
        }
        self.pages.write(&self.buf)?;
        self.offsets.push(self.pages.len());
        self.degrees.push(deg as u32);
        // sequential f32 sum — the exact bits `Graph::from_parts` computes
        self.wdegrees.push(weights.iter().sum());
        if let Some(ao) = &mut self.alias_offsets {
            if deg >= 2 {
                // the identical table the walker would build resident:
                // AliasTable::new over the row weights, serialized raw
                let table = AliasTable::new(weights);
                self.buf.clear();
                for &p in table.probs() {
                    self.buf.extend_from_slice(&p.to_le_bytes());
                }
                for &a in table.aliases() {
                    self.buf.extend_from_slice(&a.to_le_bytes());
                }
                self.alias_pages.write(&self.buf)?;
            }
            ao.push(self.alias_pages.len());
        }
        Ok(())
    }

    fn finish(self, num_arcs: u64) -> Result<PackStats> {
        let PackWriter {
            path,
            page_size,
            unit,
            offsets,
            degrees,
            wdegrees,
            labels,
            external_ids,
            alias_offsets,
            pages,
            alias_pages,
            ..
        } = self;
        let n = degrees.len() as u64;
        debug_assert_eq!(offsets.len() as u64, n + 1);
        debug_assert_eq!(
            degrees.iter().map(|&d| d as u64).sum::<u64>(),
            num_arcs,
            "pushed rows disagree with the declared arc count"
        );

        let mut flags = 0u32;
        if unit {
            flags |= FLAG_UNIT_WEIGHTS;
        } else {
            flags |= FLAG_HAS_ALIAS;
        }
        if labels.is_some() {
            flags |= FLAG_HAS_LABELS;
        }
        if external_ids.is_some() {
            flags |= FLAG_HAS_PERM;
        }

        let offsets_pos = HEADER_LEN as u64;
        let degrees_pos = offsets_pos + 8 * (n + 1);
        let wdegrees_pos = degrees_pos + 4 * n;
        let mut at = wdegrees_pos + 4 * n;
        let labels_pos = if labels.is_some() {
            let p = at;
            at += 2 * n;
            p
        } else {
            0
        };
        let perm_pos = if external_ids.is_some() {
            let p = at;
            at += 4 * n;
            p
        } else {
            0
        };
        let alias_offsets_pos = if alias_offsets.is_some() {
            let p = at;
            at += 8 * (n + 1);
            p
        } else {
            0
        };
        let pages_pos = at;
        let payload_bytes = pages.len();
        let alias_bytes = alias_pages.len();
        let alias_pages_pos = if alias_offsets.is_some() { pages_pos + payload_bytes } else { 0 };
        let file_bytes = pages_pos + payload_bytes + alias_bytes;

        let mut w = BufWriter::new(
            File::create(&path).with_context(|| format!("create {}", path.display()))?,
        );
        w.write_all(&MAGIC)?;
        w.write_all(&FORMAT_VERSION.to_le_bytes())?;
        w.write_all(&n.to_le_bytes())?;
        w.write_all(&num_arcs.to_le_bytes())?;
        w.write_all(&page_size.to_le_bytes())?;
        w.write_all(&flags.to_le_bytes())?;
        for pos in [
            offsets_pos,
            degrees_pos,
            wdegrees_pos,
            labels_pos,
            perm_pos,
            alias_offsets_pos,
            pages_pos,
            alias_pages_pos,
        ] {
            w.write_all(&pos.to_le_bytes())?;
        }
        for &off in &offsets {
            w.write_all(&off.to_le_bytes())?;
        }
        for &d in &degrees {
            w.write_all(&d.to_le_bytes())?;
        }
        for &wd in &wdegrees {
            w.write_all(&wd.to_le_bytes())?;
        }
        if let Some(labels) = &labels {
            for &l in labels {
                w.write_all(&l.to_le_bytes())?;
            }
        }
        if let Some(perm) = &external_ids {
            for &p in perm {
                w.write_all(&p.to_le_bytes())?;
            }
        }
        if let Some(ao) = &alias_offsets {
            for &off in ao {
                w.write_all(&off.to_le_bytes())?;
            }
        }
        pages.copy_into(&mut w)?;
        if alias_offsets.is_some() {
            alias_pages.copy_into(&mut w)?;
        } else {
            drop(alias_pages);
        }
        w.flush()?;

        Ok(PackStats {
            num_nodes: n as usize,
            num_arcs: num_arcs as usize,
            payload_bytes,
            alias_bytes,
            file_bytes,
        })
    }
}

/// Pack any [`GraphStore`] — in-RAM or already-paged — applying
/// `opts.reorder`. This is the single reorder-capable packing
/// implementation: `graphvite reorder` opens a packed file and runs it
/// through here; [`pack_graph`] is the in-RAM wrapper. Resident cost is
/// O(V) (the permutation and writer ledgers); rows stream through
/// [`GraphStore::neighborhood_into`].
///
/// With reordering, node `order[new]` of the input becomes node `new`
/// of the output and every target id is mapped + row re-sorted —
/// byte-identical to packing [`super::reorder::relabel`]`(g, order)`
/// without the O(E) intermediate. External ids compose across repeated
/// reorders: the stored perm always maps back to the *original* input
/// ids.
pub fn pack_store(
    store: &dyn GraphStore,
    path: impl AsRef<Path>,
    opts: &PackOptions,
) -> Result<PackStats> {
    let path = path.as_ref();
    let n = store.num_nodes();
    let unit = store.unit_weights();
    let order: Option<Vec<u32>> = match opts.reorder {
        ReorderKind::None => None,
        ReorderKind::Bfs => Some(bfs_order(store)),
    };
    let old_to_new = order.as_deref().map(invert_order);
    let prior = store.external_ids();
    let external_ids: Option<Vec<u32>> = match (&order, prior) {
        (Some(ord), prior) => {
            Some(ord.iter().map(|&old| prior.map_or(old, |p| p[old as usize])).collect())
        }
        (None, Some(p)) => Some(p.to_vec()),
        (None, None) => None,
    };
    let labels: Option<Vec<u16>> = store.labels().map(|l| match &order {
        Some(ord) => ord.iter().map(|&old| l[old as usize]).collect(),
        None => l.to_vec(),
    });

    let mut w = PackWriter::new(path, n, opts.page_size, unit, labels, external_ids)?;
    let mut targets: Vec<u32> = Vec::new();
    let mut weights: Vec<f32> = Vec::new();
    let mut row: Vec<(u32, f32)> = Vec::new();
    for new in 0..n as u32 {
        let old = order.as_ref().map_or(new, |o| o[new as usize]);
        store.neighborhood_into(old, &mut targets, &mut weights);
        if let Some(map) = &old_to_new {
            row.clear();
            row.extend(targets.iter().map(|&t| map[t as usize]).zip(weights.iter().copied()));
            // mapped ids are unique within a row (the order is a
            // bijection), so the unstable sort is deterministic
            row.sort_unstable_by_key(|&(t, _)| t);
            targets.clear();
            weights.clear();
            for &(t, wt) in &row {
                targets.push(t);
                weights.push(wt);
            }
        }
        w.push_node(&targets, &weights)?;
    }
    w.finish(store.num_arcs() as u64)
}

/// Write `graph` as a packed on-disk file (the `graphvite pack` core for
/// in-RAM sources).
pub fn pack_graph(graph: &Graph, path: impl AsRef<Path>, opts: &PackOptions) -> Result<PackStats> {
    pack_store(graph, path, opts)
}

/// One 12-byte spill-run record read; `Ok(None)` at clean EOF,
/// fail-loud on a partial record.
fn read_arc_record(r: &mut impl Read) -> Result<Option<(u32, u32, f32)>> {
    let mut b = [0u8; 12];
    let mut got = 0usize;
    while got < 12 {
        let k = r.read(&mut b[got..])?;
        if k == 0 {
            ensure!(got == 0, "spill run truncated mid-record");
            return Ok(None);
        }
        got += k;
    }
    Ok(Some((
        u32::from_le_bytes(b[0..4].try_into().unwrap()),
        u32::from_le_bytes(b[4..8].try_into().unwrap()),
        f32::from_le_bytes(b[8..12].try_into().unwrap()),
    )))
}

fn spill_run(
    buf: &mut Vec<(u32, u32, f32)>,
    output: &Path,
    runs: &mut Vec<PathBuf>,
) -> Result<()> {
    buf.sort_unstable_by_key(|&(s, t, _)| (s, t));
    let rp = spool_path(output, &format!("run{}", runs.len()));
    let mut w = BufWriter::new(
        File::create(&rp).with_context(|| format!("create spill run {}", rp.display()))?,
    );
    for &(s, t, wt) in buf.iter() {
        w.write_all(&s.to_le_bytes())?;
        w.write_all(&t.to_le_bytes())?;
        w.write_all(&wt.to_le_bytes())?;
    }
    w.flush()?;
    runs.push(rp);
    buf.clear();
    Ok(())
}

/// Removes its files on drop — keeps spill runs from leaking when a
/// pack errors out halfway.
struct RemoveOnDrop(Vec<PathBuf>);

impl Drop for RemoveOnDrop {
    fn drop(&mut self) {
        for p in &self.0 {
            let _ = std::fs::remove_file(p);
        }
    }
}

/// Pack a text edge list without ever holding its CSR in RAM: external
/// sort-merge under `opts.mem_bytes` (the `graphvite pack` subcommand
/// body).
///
/// Phase A parses lines exactly like the in-RAM loader (self-loops
/// dropped, each surviving edge symmetrized into two arcs), buffering at
/// most `mem_bytes / 12` arcs before sorting the buffer by (src, tgt)
/// and spilling it as a run. Phase B k-way-merges the runs — duplicate
/// (src, tgt) pairs have their weights summed in run order, which also
/// decides the unit-weights flag *post*-dedup (two 1.0 duplicates sum to
/// 2.0) — into a merged spool, then streams that spool row-by-row
/// through the same [`PackWriter`] as every other pack path. Resident
/// peak is the run buffer + O(V) writer ledgers + bounded merge buffers,
/// asserted by the allocation-counting test in `rust/tests/pack_mem.rs`.
///
/// With `opts.reorder` set this runs twice: an unordered pack to a
/// sibling temp `.gvpk`, then a [`pack_store`] reorder pass over it
/// (the page cache reusing `mem_bytes` as its budget).
pub fn pack_edge_list(
    input: impl AsRef<Path>,
    output: impl AsRef<Path>,
    opts: &PackOptions,
) -> Result<PackStats> {
    let input = input.as_ref();
    let output = output.as_ref();
    ensure!(
        opts.mem_bytes >= 4096,
        "pack_mem_bytes {} too small (minimum 4 KiB)",
        opts.mem_bytes
    );

    if opts.reorder != ReorderKind::None {
        let tmp = spool_path(output, "unordered");
        let _guard = RemoveOnDrop(vec![tmp.clone()]);
        let base = PackOptions { reorder: ReorderKind::None, ..*opts };
        pack_edge_list(input, &tmp, &base)?;
        let paged = PagedCsr::open(&tmp, opts.mem_bytes)?;
        return pack_store(&paged, output, opts);
    }

    // ---- Phase A: parse, symmetrize, spill sorted runs ----
    let file = File::open(input).with_context(|| format!("open {}", input.display()))?;
    let max_run = (opts.mem_bytes / 12).max(1024);
    let mut run_buf: Vec<(u32, u32, f32)> = Vec::with_capacity(max_run);
    let mut runs: Vec<PathBuf> = Vec::new();
    let mut num_nodes = 0usize;
    let mut parse_ok = || -> Result<()> {
        for (lineno, line) in BufReader::new(file).lines().enumerate() {
            let line = line?;
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let mut it = line.split_whitespace();
            let u: u32 = it
                .next()
                .ok_or_else(|| anyhow::anyhow!("line {}: missing src", lineno + 1))?
                .parse()
                .with_context(|| format!("line {}: bad src", lineno + 1))?;
            let v: u32 = match it.next() {
                Some(tok) => {
                    tok.parse().with_context(|| format!("line {}: bad dst", lineno + 1))?
                }
                None => bail!("line {}: missing dst", lineno + 1),
            };
            let w: f32 = match it.next() {
                Some(tok) => {
                    tok.parse().with_context(|| format!("line {}: bad weight", lineno + 1))?
                }
                None => 1.0,
            };
            if u == v {
                continue; // drop self loops (matches GraphBuilder)
            }
            num_nodes = num_nodes.max(u.max(v) as usize + 1);
            for arc in [(u, v, w), (v, u, w)] {
                run_buf.push(arc);
                if run_buf.len() >= max_run {
                    spill_run(&mut run_buf, output, &mut runs)?;
                }
            }
        }
        if !run_buf.is_empty() || runs.is_empty() {
            spill_run(&mut run_buf, output, &mut runs)?;
        }
        Ok(())
    };
    let parsed = parse_ok();
    let _run_guard = RemoveOnDrop(runs.clone());
    parsed?;
    drop(run_buf);

    // ---- Phase B1: k-way merge, dedup-sum, decide unit flag ----
    let k = runs.len();
    let read_cap = (opts.mem_bytes / (k + 1)).clamp(4096, 64 * 1024);
    let mut readers: Vec<BufReader<File>> = Vec::with_capacity(k);
    for rp in &runs {
        let f = File::open(rp).with_context(|| format!("reopen spill run {}", rp.display()))?;
        readers.push(BufReader::with_capacity(read_cap, f));
    }
    let mut heap: BinaryHeap<Reverse<(u32, u32, usize)>> = BinaryHeap::with_capacity(k);
    let mut pending_w = vec![0f32; k];
    for (i, r) in readers.iter_mut().enumerate() {
        if let Some((s, t, w)) = read_arc_record(r)? {
            pending_w[i] = w;
            heap.push(Reverse((s, t, i)));
        }
    }
    let merged_path = spool_path(output, "merged");
    let _merged_guard = RemoveOnDrop(vec![merged_path.clone()]);
    let mut merged = Spool::create(merged_path.clone())?;
    let mut unit = true;
    let mut num_arcs = 0u64;
    let mut rec = [0u8; 12];
    let mut cur: Option<(u32, u32, f32)> = None;
    macro_rules! emit {
        ($s:expr, $t:expr, $w:expr) => {{
            rec[0..4].copy_from_slice(&$s.to_le_bytes());
            rec[4..8].copy_from_slice(&$t.to_le_bytes());
            rec[8..12].copy_from_slice(&$w.to_le_bytes());
            merged.write(&rec)?;
            unit &= $w == 1.0;
            num_arcs += 1;
        }};
    }
    while let Some(Reverse((s, t, i))) = heap.pop() {
        let w = pending_w[i];
        match &mut cur {
            Some((cs, ct, cw)) if *cs == s && *ct == t => *cw += w,
            Some((cs, ct, cw)) => {
                let (es, et, ew) = (*cs, *ct, *cw);
                emit!(es, et, ew);
                cur = Some((s, t, w));
            }
            None => cur = Some((s, t, w)),
        }
        if let Some((ns, nt, nw)) = read_arc_record(&mut readers[i])? {
            pending_w[i] = nw;
            heap.push(Reverse((ns, nt, i)));
        }
    }
    if let Some((cs, ct, cw)) = cur {
        emit!(cs, ct, cw);
    }
    drop(readers);

    // ---- Phase B2: stream merged arcs into the writer, row by row ----
    let labels = super::loader::load_labels_for(input, num_nodes)?;
    merged.w.flush()?;
    let mut mr = BufReader::with_capacity(
        64 * 1024,
        File::open(&merged_path)
            .with_context(|| format!("reopen merge spool {}", merged_path.display()))?,
    );
    let mut pw = PackWriter::new(output, num_nodes, opts.page_size, unit, labels, None)?;
    let mut targets: Vec<u32> = Vec::new();
    let mut weights: Vec<f32> = Vec::new();
    let mut cur_src: Option<u32> = None;
    while let Some((s, t, w)) = read_arc_record(&mut mr)? {
        if cur_src != Some(s) {
            let fill_from = match cur_src {
                Some(cs) => {
                    pw.push_node(&targets, &weights)?;
                    cs + 1
                }
                None => 0,
            };
            for _ in fill_from..s {
                pw.push_node(&[], &[])?; // isolated / gap node
            }
            targets.clear();
            weights.clear();
            cur_src = Some(s);
        }
        targets.push(t);
        weights.push(w);
    }
    let fill_from = match cur_src {
        Some(cs) => {
            pw.push_node(&targets, &weights)?;
            cs as usize + 1
        }
        None => 0,
    };
    for _ in fill_from..num_nodes {
        pw.push_node(&[], &[])?;
    }
    drop(merged);
    pw.finish(num_arcs)
}

/// True when `path` starts with the packed magic (the `auto` sniff).
pub fn is_packed(path: impl AsRef<Path>) -> bool {
    let Ok(mut f) = File::open(path) else {
        return false;
    };
    let mut m = [0u8; 4];
    f.read_exact(&mut m).is_ok() && m == MAGIC
}

// ------------------------------------------------------------- reader --

/// Snapshot of the reader's page-cache counters (CI's `ondisk-smoke` job
/// greps the line `cmd_train` prints from these). One cache — and one
/// budget — covers both the successor pages and the alias sidecar pages.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CacheStats {
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
    /// Reads served lock-free from a thread's page cursor (these never
    /// touch the LRU, so they are counted separately from `hits`).
    pub cursor_hits: u64,
    /// Bytes of page data currently cached (≤ `budget_bytes`, except
    /// when a single page exceeds the budget — one page is always
    /// admitted).
    pub resident_bytes: usize,
    pub budget_bytes: usize,
    pub page_size: usize,
}

const NIL: usize = usize::MAX;

/// High bit of a cache key selects the on-disk region the page belongs
/// to (successor pages vs alias-sidecar pages); the low 63 bits are the
/// page index within that region. Both regions share one cache, one
/// budget and one set of counters.
const REGION_BIT: u64 = 1 << 63;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Region {
    Successors,
    Alias,
}

struct Slot {
    /// Tagged cache key (region bit | page index).
    page: u64,
    /// Page bytes behind an `Arc` so thread cursors can hold a page
    /// lock-free after its slot is evicted. `ensure` recycles a slot's
    /// buffer with [`Arc::make_mut`]: unshared buffers are reused in
    /// place, while a buffer some cursor still references is left
    /// untouched (the cursor keeps the old page's bytes) and the slot
    /// gets a fresh allocation.
    data: Arc<Vec<u8>>,
    prev: usize,
    next: usize,
}

/// Intrusive-list LRU over fixed-size pages, bounded by a byte budget.
struct PageCache {
    budget: usize,
    bytes: usize,
    map: HashMap<u64, usize>,
    slots: Vec<Slot>,
    free: Vec<usize>,
    head: usize,
    tail: usize,
    /// Reassembly buffer for records that straddle a page boundary.
    span_buf: Vec<u8>,
    hits: u64,
    misses: u64,
    evictions: u64,
}

impl PageCache {
    fn new(budget: usize) -> Self {
        PageCache {
            budget,
            bytes: 0,
            map: HashMap::new(),
            slots: Vec::new(),
            free: Vec::new(),
            head: NIL,
            tail: NIL,
            span_buf: Vec::new(),
            hits: 0,
            misses: 0,
            evictions: 0,
        }
    }

    fn detach(&mut self, i: usize) {
        let (prev, next) = (self.slots[i].prev, self.slots[i].next);
        if prev == NIL {
            self.head = next;
        } else {
            self.slots[prev].next = next;
        }
        if next == NIL {
            self.tail = prev;
        } else {
            self.slots[next].prev = prev;
        }
        self.slots[i].prev = NIL;
        self.slots[i].next = NIL;
    }

    fn push_front(&mut self, i: usize) {
        self.slots[i].prev = NIL;
        self.slots[i].next = self.head;
        if self.head != NIL {
            self.slots[self.head].prev = i;
        }
        self.head = i;
        if self.tail == NIL {
            self.tail = i;
        }
    }

    /// Return the slot of tagged key `key`, loading (and evicting) as
    /// needed. `io` must be the geometry of the key's region.
    fn ensure(&mut self, key: u64, io: &PageIo<'_>) -> Result<usize> {
        if let Some(&i) = self.map.get(&key) {
            self.hits += 1;
            if self.head != i {
                self.detach(i);
                self.push_front(i);
            }
            return Ok(i);
        }
        self.misses += 1;
        let len = io.page_len(key);
        // evict from the cold tail until the new page fits (the budget
        // always admits at least this one page)
        while self.bytes + len > self.budget && self.tail != NIL {
            let t = self.tail;
            self.detach(t);
            self.map.remove(&self.slots[t].page);
            self.bytes -= self.slots[t].data.len();
            self.evictions += 1;
            self.free.push(t);
        }
        let i = match self.free.pop() {
            Some(i) => i,
            None => {
                self.slots.push(Slot { page: 0, data: Arc::new(Vec::new()), prev: NIL, next: NIL });
                self.slots.len() - 1
            }
        };
        self.slots[i].page = key;
        // reuse the buffer when unshared; when a thread cursor still holds
        // the evicted page it contains, leave that allocation to the
        // cursor and start fresh (make_mut would clone the stale bytes)
        if Arc::get_mut(&mut self.slots[i].data).is_none() {
            self.slots[i].data = Arc::new(Vec::new());
        }
        let buf = Arc::make_mut(&mut self.slots[i].data);
        buf.resize(len, 0);
        if let Err(e) = io.read_page(key, buf) {
            self.free.push(i);
            return Err(e);
        }
        self.map.insert(key, i);
        self.bytes += len;
        self.push_front(i);
        Ok(i)
    }
}

/// The read-side geometry of one on-disk region (successor pages or
/// alias pages) that `PageCache::ensure` loads through. `tag` is OR'd
/// into cache keys so the two regions never collide in the shared cache.
struct PageIo<'a> {
    file: &'a File,
    pages_pos: u64,
    pages_len: u64,
    page_size: usize,
    tag: u64,
}

impl PageIo<'_> {
    fn page_len(&self, key: u64) -> usize {
        let start = (key & !REGION_BIT) * self.page_size as u64;
        (self.pages_len - start).min(self.page_size as u64) as usize
    }

    fn read_page(&self, key: u64, buf: &mut [u8]) -> Result<()> {
        let page = key & !REGION_BIT;
        let start = page * self.page_size as u64;
        self.file
            .read_exact_at(buf, self.pages_pos + start)
            .with_context(|| format!("read page {page} (file shrank after open?)"))
    }
}

/// Out-of-core CSR reader over a packed file: O(V) resident scalars, the
/// O(E) successor payload — and, for weighted graphs, the O(E) alias
/// sidecar — streamed through one byte-bounded LRU page cache.
///
/// Thread-safe (`GraphStore: Send + Sync`): the shared cache sits behind
/// one mutex, but each thread also keeps a lock-free *cursor* — an `Arc`
/// to the last page it read. Sampler threads walk successor lists in
/// node order, so consecutive reads overwhelmingly land on the cursor
/// page and never touch the lock; the mutex is only taken on a page
/// change (and for boundary-straddling records). Page bytes are
/// immutable after load, so a cursor that outlives its slot's eviction
/// still reads correct data (see [`Slot::data`] for the recycling rule).
pub struct PagedCsr {
    file: File,
    /// Distinguishes this store's pages in the thread-local cursor (two
    /// open stores must never serve each other's pages).
    store_id: u64,
    page_size: usize,
    pages_pos: u64,
    pages_len: u64,
    alias_pos: u64,
    alias_len: u64,
    num_arcs: u64,
    unit_weights: bool,
    offsets: Vec<u64>,
    degrees: Vec<u32>,
    wdegrees: Vec<f32>,
    labels: Option<Vec<u16>>,
    /// `perm[internal_id] = external (pre-reorder) id` — present when
    /// the file was packed with `--reorder` (or repacked from a store
    /// that had one). Training output is mapped back through this.
    external_ids: Option<Vec<u32>>,
    /// Byte offsets into the alias sidecar; `Some` iff the graph is
    /// weighted (`has_alias == !unit_weights`, validated at open).
    alias_offsets: Option<Vec<u64>>,
    cache: Mutex<PageCache>,
    cursor_hits: AtomicU64,
}

/// Store-id allocator for [`PagedCsr::store_id`]. Starts at 1 so 0 can
/// never match (an empty cursor is `None`, but belt and braces).
static NEXT_STORE_ID: AtomicU64 = AtomicU64::new(1);

thread_local! {
    /// The calling thread's page cursor: `(store_id, tagged key, bytes)`
    /// of the last single-page record it read. One entry is enough —
    /// samplers stream nodes in order, so the win is consecutive records
    /// on one page, not a working set.
    static PAGE_CURSOR: RefCell<Option<(u64, u64, Arc<Vec<u8>>)>> = const { RefCell::new(None) };
}

impl PagedCsr {
    /// Open a packed graph with a page cache bounded at `cache_bytes`
    /// (clamped up to one page so progress is always possible).
    /// Validates the whole resident geometry before returning — a file
    /// this accepts either reads cleanly or is corrupt at page level
    /// (which then fails loudly at access time).
    pub fn open(path: impl AsRef<Path>, cache_bytes: usize) -> Result<Self> {
        let path = path.as_ref();
        let mut file = File::open(path).with_context(|| format!("open {}", path.display()))?;
        let mut hdr = [0u8; HEADER_LEN];
        file.read_exact(&mut hdr)
            .map_err(|_| anyhow::anyhow!("{}: truncated header", path.display()))?;
        ensure!(
            hdr[..4] == MAGIC,
            "{}: not a packed graphvite graph (bad magic; produce one with \
             `graphvite pack`)",
            path.display()
        );
        let u32_at = |at: usize| u32::from_le_bytes(hdr[at..at + 4].try_into().unwrap());
        let u64_at = |at: usize| u64::from_le_bytes(hdr[at..at + 8].try_into().unwrap());
        let version = u32_at(4);
        ensure!(
            version != 1,
            "{}: packed-graph version 1 predates the reorder/alias sidecars \
             (this binary reads version {FORMAT_VERSION}); repack the source \
             edge list with `graphvite pack`",
            path.display()
        );
        ensure!(
            version == FORMAT_VERSION,
            "{}: unsupported packed-graph version {version} (this binary reads \
             version {FORMAT_VERSION})",
            path.display()
        );
        let n = u64_at(8) as usize;
        let num_arcs = u64_at(16);
        let page_size = u32_at(24);
        let flags = u32_at(28);
        let offsets_pos = u64_at(32);
        let degrees_pos = u64_at(40);
        let wdegrees_pos = u64_at(48);
        let labels_pos = u64_at(56);
        let perm_pos = u64_at(64);
        let alias_offsets_pos = u64_at(72);
        let pages_pos = u64_at(80);
        let alias_pages_pos = u64_at(88);
        ensure!(
            (16..=1 << 30).contains(&page_size),
            "{}: page_size {page_size} out of range",
            path.display()
        );
        ensure!(
            flags & !KNOWN_FLAGS == 0,
            "{}: unknown flag bits {:#x} (corrupt header or a newer format)",
            path.display(),
            flags & !KNOWN_FLAGS
        );
        let unit_weights = flags & FLAG_UNIT_WEIGHTS != 0;
        let has_labels = flags & FLAG_HAS_LABELS != 0;
        let has_perm = flags & FLAG_HAS_PERM != 0;
        let has_alias = flags & FLAG_HAS_ALIAS != 0;
        ensure!(
            has_alias == !unit_weights,
            "{}: alias-sidecar flag disagrees with the unit-weights flag \
             (weighted graphs must carry the sidecar — corrupt header)",
            path.display()
        );
        // Bound the node count by the file size FIRST: the resident
        // sections alone need > 16 bytes/node, so any real file has
        // n < file_len / 16 — and with n bounded, none of the section
        // arithmetic below can overflow (a corrupt 2^61 node count must
        // neither wrap the geometry checks nor become a huge alloc).
        let file_len = file.metadata()?.len();
        ensure!(
            (n as u64) < file_len / 16,
            "{}: node count {n} exceeds what a {file_len}-byte file can hold \
             (corrupt header)",
            path.display()
        );
        let mut expect = HEADER_LEN as u64;
        let mut take = |present: bool, len: u64| {
            if present {
                let p = expect;
                expect += len;
                p
            } else {
                0
            }
        };
        let want_offsets = take(true, 8 * (n as u64 + 1));
        let want_degrees = take(true, 4 * n as u64);
        let want_wdegrees = take(true, 4 * n as u64);
        let want_labels = take(has_labels, 2 * n as u64);
        let want_perm = take(has_perm, 4 * n as u64);
        let want_alias_offsets = take(has_alias, 8 * (n as u64 + 1));
        let want_pages = expect;
        ensure!(
            offsets_pos == want_offsets
                && degrees_pos == want_degrees
                && wdegrees_pos == want_wdegrees
                && labels_pos == want_labels
                && perm_pos == want_perm
                && alias_offsets_pos == want_alias_offsets
                && pages_pos == want_pages,
            "{}: section table does not match the declared node count (corrupt header)",
            path.display()
        );
        ensure!(
            pages_pos <= file_len,
            "{}: sections overrun the file — truncated or corrupt header",
            path.display()
        );

        let read_section = |file: &mut File, len: usize, what: &str| -> Result<Vec<u8>> {
            let mut buf = vec![0u8; len];
            file.read_exact(&mut buf)
                .map_err(|_| anyhow::anyhow!("{}: truncated {what} section", path.display()))?;
            Ok(buf)
        };
        let raw = read_section(&mut file, 8 * (n + 1), "offsets")?;
        let offsets: Vec<u64> =
            raw.chunks_exact(8).map(|c| u64::from_le_bytes(c.try_into().unwrap())).collect();
        let raw = read_section(&mut file, 4 * n, "degrees")?;
        let degrees: Vec<u32> =
            raw.chunks_exact(4).map(|c| u32::from_le_bytes(c.try_into().unwrap())).collect();
        let raw = read_section(&mut file, 4 * n, "weighted-degrees")?;
        let wdegrees: Vec<f32> =
            raw.chunks_exact(4).map(|c| f32::from_le_bytes(c.try_into().unwrap())).collect();
        let labels = if has_labels {
            let raw = read_section(&mut file, 2 * n, "labels")?;
            Some(raw.chunks_exact(2).map(|c| u16::from_le_bytes(c.try_into().unwrap())).collect())
        } else {
            None
        };
        let external_ids: Option<Vec<u32>> = if has_perm {
            let raw = read_section(&mut file, 4 * n, "perm")?;
            let perm: Vec<u32> =
                raw.chunks_exact(4).map(|c| u32::from_le_bytes(c.try_into().unwrap())).collect();
            let mut seen = vec![false; n];
            for &p in &perm {
                ensure!(
                    (p as usize) < n && !seen[p as usize],
                    "{}: perm sidecar is not a bijection over 0..{n} (corrupt file)",
                    path.display()
                );
                seen[p as usize] = true;
            }
            Some(perm)
        } else {
            None
        };
        let alias_offsets: Option<Vec<u64>> = if has_alias {
            let raw = read_section(&mut file, 8 * (n + 1), "alias-offsets")?;
            let ao: Vec<u64> =
                raw.chunks_exact(8).map(|c| u64::from_le_bytes(c.try_into().unwrap())).collect();
            ensure!(
                ao[0] == 0,
                "{}: alias offsets must start at 0 (corrupt header)",
                path.display()
            );
            for v in 0..n {
                let want = if degrees[v] >= 2 { 8 * degrees[v] as u64 } else { 0 };
                ensure!(
                    ao[v + 1] >= ao[v] && ao[v + 1] - ao[v] == want,
                    "{}: alias ledger disagrees with the degree table at node {v} \
                     (corrupt file)",
                    path.display()
                );
            }
            Some(ao)
        } else {
            None
        };

        ensure!(offsets[0] == 0, "{}: offsets must start at 0 (corrupt header)", path.display());
        ensure!(
            offsets.windows(2).all(|w| w[0] <= w[1]),
            "{}: non-monotone offset table (corrupt header)",
            path.display()
        );
        ensure!(
            degrees.iter().map(|&d| d as u64).sum::<u64>() == num_arcs,
            "{}: degree table disagrees with the declared arc count (corrupt header)",
            path.display()
        );
        let pages_len = *offsets.last().unwrap();
        let alias_len = alias_offsets.as_ref().map_or(0, |ao| *ao.last().unwrap());
        let want_alias_pages_pos =
            if has_alias { pages_pos + pages_len } else { 0 };
        ensure!(
            alias_pages_pos == want_alias_pages_pos,
            "{}: alias section position disagrees with the successor payload \
             length (corrupt header)",
            path.display()
        );
        ensure!(
            file_len == pages_pos + pages_len + alias_len,
            "{}: file is {file_len} bytes but the header implies {} — truncated \
             or trailing garbage",
            path.display(),
            pages_pos + pages_len + alias_len
        );

        // the budget must admit at least one page or no record is readable
        let budget = cache_bytes.max(page_size as usize);
        Ok(PagedCsr {
            file,
            store_id: NEXT_STORE_ID.fetch_add(1, Ordering::Relaxed),
            page_size: page_size as usize,
            pages_pos,
            pages_len,
            alias_pos: alias_pages_pos,
            alias_len,
            num_arcs,
            unit_weights,
            offsets,
            degrees,
            wdegrees,
            labels,
            external_ids,
            alias_offsets,
            cache: Mutex::new(PageCache::new(budget)),
            cursor_hits: AtomicU64::new(0),
        })
    }

    /// Page-cache counters (hits/misses/evictions + residency).
    pub fn cache_stats(&self) -> CacheStats {
        let c = self.cache.lock().unwrap();
        CacheStats {
            hits: c.hits,
            misses: c.misses,
            evictions: c.evictions,
            cursor_hits: self.cursor_hits.load(Ordering::Relaxed),
            resident_bytes: c.bytes,
            budget_bytes: c.budget,
            page_size: self.page_size,
        }
    }

    fn io(&self, region: Region) -> PageIo<'_> {
        match region {
            Region::Successors => PageIo {
                file: &self.file,
                pages_pos: self.pages_pos,
                pages_len: self.pages_len,
                page_size: self.page_size,
                tag: 0,
            },
            Region::Alias => PageIo {
                file: &self.file,
                pages_pos: self.alias_pos,
                pages_len: self.alias_len,
                page_size: self.page_size,
                tag: REGION_BIT,
            },
        }
    }

    /// Run `f` over the raw bytes `[start, end)` of `region`, served
    /// from the shared page cache (single-page spans decode in place;
    /// boundary-straddling ones reassemble through the cache's span
    /// buffer).
    fn with_span<R>(
        &self,
        region: Region,
        start: u64,
        end: u64,
        f: impl FnOnce(&[u8]) -> Result<R>,
    ) -> Result<R> {
        debug_assert!(start < end, "with_span on an empty span");
        let ps = self.page_size as u64;
        let io = self.io(region);
        let first_page = start / ps;
        let last_page = (end - 1) / ps;
        if first_page == last_page {
            let key = io.tag | first_page;
            let lo = (start - first_page * ps) as usize;
            let hi = (end - first_page * ps) as usize;
            // lock-free fast path: the span lives on the page this
            // thread read last time
            let held = PAGE_CURSOR.with(|c| match &*c.borrow() {
                Some((sid, k, data)) if *sid == self.store_id && *k == key => {
                    Some(Arc::clone(data))
                }
                _ => None,
            });
            let data = match held {
                Some(data) => {
                    self.cursor_hits.fetch_add(1, Ordering::Relaxed);
                    data
                }
                None => {
                    let mut cache = self.cache.lock().unwrap();
                    let i = cache.ensure(key, &io)?;
                    let data = Arc::clone(&cache.slots[i].data);
                    drop(cache);
                    PAGE_CURSOR.with(|c| {
                        *c.borrow_mut() = Some((self.store_id, key, Arc::clone(&data)));
                    });
                    data
                }
            };
            f(&data[lo..hi])
        } else {
            let mut cache = self.cache.lock().unwrap();
            let mut buf = std::mem::take(&mut cache.span_buf);
            buf.clear();
            for page in first_page..=last_page {
                let i = cache.ensure(io.tag | page, &io)?;
                let data = &cache.slots[i].data;
                let lo = if page == first_page { (start - page * ps) as usize } else { 0 };
                let hi = if page == last_page { (end - page * ps) as usize } else { data.len() };
                buf.extend_from_slice(&data[lo..hi]);
            }
            let r = f(&buf);
            cache.span_buf = buf;
            r
        }
    }

    fn record<R>(&self, v: u32, f: impl FnOnce(&[u8]) -> Result<R>) -> R {
        let start = self.offsets[v as usize];
        let end = self.offsets[v as usize + 1];
        self.with_span(Region::Successors, start, end, f)
            .unwrap_or_else(|e| panic!("paged graph: reading node {v} failed: {e:#}"))
    }
}

impl GraphStore for PagedCsr {
    fn num_nodes(&self) -> usize {
        self.degrees.len()
    }

    fn num_edges(&self) -> usize {
        (self.num_arcs / 2) as usize
    }

    fn num_arcs(&self) -> usize {
        self.num_arcs as usize
    }

    fn degree(&self, v: u32) -> usize {
        self.degrees[v as usize] as usize
    }

    fn weighted_degree(&self, v: u32) -> f32 {
        self.wdegrees[v as usize]
    }

    fn weighted_degrees(&self) -> &[f32] {
        &self.wdegrees
    }

    fn unit_weights(&self) -> bool {
        self.unit_weights
    }

    fn labels(&self) -> Option<&[u16]> {
        self.labels.as_deref()
    }

    fn successors_into(&self, v: u32, targets: &mut Vec<u32>) {
        let deg = self.degrees[v as usize] as usize;
        if deg == 0 {
            targets.clear();
            return;
        }
        self.record(v, |b| decode_record(b, deg, self.unit_weights, targets, None));
    }

    fn neighborhood_into(&self, v: u32, targets: &mut Vec<u32>, weights: &mut Vec<f32>) {
        let deg = self.degrees[v as usize] as usize;
        if deg == 0 {
            targets.clear();
            weights.clear();
            return;
        }
        self.record(v, |b| decode_record(b, deg, self.unit_weights, targets, Some(weights)));
    }

    fn for_each_arc(&self, f: &mut dyn FnMut(u32, u32, f32)) {
        let mut t = Vec::new();
        let mut w = Vec::new();
        for v in 0..self.num_nodes() as u32 {
            self.neighborhood_into(v, &mut t, &mut w);
            for (&tt, &ww) in t.iter().zip(&w) {
                f(v, tt, ww);
            }
        }
    }

    fn alias_tables_streamed(&self) -> bool {
        self.alias_offsets.is_some()
    }

    fn alias_into(&self, v: u32, prob: &mut Vec<f32>, alias: &mut Vec<u32>) {
        let Some(ao) = &self.alias_offsets else {
            unreachable!("alias_into on a unit-weight packed graph (walker bug)");
        };
        let deg = self.degrees[v as usize] as usize;
        debug_assert!(deg >= 2, "alias_into for degree-{deg} node {v}");
        let (start, end) = (ao[v as usize], ao[v as usize + 1]);
        self.with_span(Region::Alias, start, end, |b| {
            ensure!(b.len() == 8 * deg, "alias record length mismatch (corrupt page)");
            prob.clear();
            alias.clear();
            for i in 0..deg {
                prob.push(f32::from_le_bytes(b[4 * i..4 * i + 4].try_into().unwrap()));
            }
            let abase = 4 * deg;
            for i in 0..deg {
                let a = u32::from_le_bytes(b[abase + 4 * i..abase + 4 * i + 4].try_into().unwrap());
                ensure!((a as usize) < deg, "alias entry out of range (corrupt page)");
                alias.push(a);
            }
            Ok(())
        })
        .unwrap_or_else(|e| panic!("paged graph: reading alias table of node {v} failed: {e:#}"))
    }

    fn external_ids(&self) -> Option<&[u32]> {
        self.external_ids.as_deref()
    }
}

// ------------------------------------------------------------- loader --

/// A graph loaded through [`load_graph`]: the trait object for the
/// trainer plus the concrete paged handle when the source was packed
/// (for page-cache reporting).
pub enum LoadedGraph {
    InMemory(Arc<Graph>),
    Paged(Arc<PagedCsr>),
}

impl LoadedGraph {
    /// The store handle training runs on.
    pub fn store(&self) -> Arc<dyn GraphStore> {
        match self {
            LoadedGraph::InMemory(g) => Arc::clone(g) as Arc<dyn GraphStore>,
            LoadedGraph::Paged(p) => Arc::clone(p) as Arc<dyn GraphStore>,
        }
    }

    /// The paged reader, when the graph is out-of-core.
    pub fn paged(&self) -> Option<&Arc<PagedCsr>> {
        match self {
            LoadedGraph::Paged(p) => Some(p),
            LoadedGraph::InMemory(_) => None,
        }
    }
}

/// Load `path` according to `format` (`cache_bytes` bounds the page
/// cache of the packed path). Bad combinations fail loudly: `packed` on
/// a non-packed file dies on the reader's bad-magic check (and a
/// missing file on its real I/O error), `edgelist` on a packed file is
/// rejected here with a pointer at the right invocation.
pub fn load_graph(
    path: impl AsRef<Path>,
    format: GraphFormat,
    cache_bytes: usize,
) -> Result<LoadedGraph> {
    let path = path.as_ref();
    let packed = is_packed(path);
    match format {
        GraphFormat::Auto => {
            if packed {
                Ok(LoadedGraph::Paged(Arc::new(PagedCsr::open(path, cache_bytes)?)))
            } else {
                Ok(LoadedGraph::InMemory(Arc::new(super::load_edge_list(path)?)))
            }
        }
        GraphFormat::Packed => {
            // open directly rather than pre-sniffing: a missing file
            // surfaces its real I/O error and a non-packed file fails
            // open's own bad-magic check, instead of both collapsing
            // into one misleading "not packed" message
            Ok(LoadedGraph::Paged(Arc::new(PagedCsr::open(path, cache_bytes)?)))
        }
        GraphFormat::Edgelist => {
            ensure!(
                !packed,
                "{}: graph_format = \"edgelist\" but the file is a packed graph \
                 (use --graph-format packed or auto)",
                path.display()
            );
            Ok(LoadedGraph::InMemory(Arc::new(super::load_edge_list(path)?)))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{generators, GraphBuilder};

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("graphvite_ondisk_unit");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn varint_roundtrip() {
        let mut buf = Vec::new();
        let values = [0u64, 1, 127, 128, 300, 16_383, 16_384, u32::MAX as u64, u64::MAX];
        for &v in &values {
            buf.clear();
            put_varint(&mut buf, v);
            let mut cur = 0;
            assert_eq!(read_varint(&buf, &mut cur).unwrap(), v);
            assert_eq!(cur, buf.len());
        }
        // truncated varint fails loudly
        buf.clear();
        put_varint(&mut buf, 10_000);
        buf.pop();
        let mut cur = 0;
        assert!(read_varint(&buf, &mut cur).is_err());
    }

    #[test]
    fn zigzag_roundtrip() {
        for x in [0i64, 1, -1, 2, -2, 63, -64, i64::from(u32::MAX), -i64::from(u32::MAX)] {
            assert_eq!(unzigzag(zigzag(x)), x, "x={x}");
        }
    }

    #[test]
    fn pack_open_roundtrip_karate() {
        let g = generators::karate_club();
        let path = tmp("karate.gvpk");
        let stats = pack_graph(&g, &path, &PackOptions::default()).unwrap();
        assert_eq!(stats.num_nodes, 34);
        assert_eq!(stats.num_arcs, 156);
        assert!(stats.bytes_per_arc() < 8.0, "no compression: {}", stats.bytes_per_arc());
        assert_eq!(stats.alias_bytes, 0, "unit graphs carry no alias sidecar");
        let p = PagedCsr::open(&path, DEFAULT_CACHE_BYTES).unwrap();
        assert_eq!(GraphStore::num_nodes(&p), 34);
        assert_eq!(GraphStore::num_edges(&p), 78);
        assert!(p.unit_weights());
        assert!(!p.alias_tables_streamed());
        assert!(GraphStore::external_ids(&p).is_none());
        assert_eq!(p.labels(), g.labels());
        let mut t = Vec::new();
        for v in 0..34u32 {
            p.successors_into(v, &mut t);
            assert_eq!(t, g.neighbors(v), "node {v}");
        }
    }

    #[test]
    fn weighted_graph_roundtrips_exact_bits() {
        let mut b = GraphBuilder::new().with_num_nodes(6);
        b.push_edge(0, 1, 0.1);
        b.push_edge(0, 2, 2.5);
        b.push_edge(3, 4, 1.0e-7);
        let g = b.build();
        let path = tmp("weighted.gvpk");
        let stats =
            pack_graph(&g, &path, &PackOptions { page_size: 16, ..Default::default() }).unwrap();
        // node 0 has degree 2 → one 16-byte alias record
        assert_eq!(stats.alias_bytes, 16);
        let p = PagedCsr::open(&path, 64).unwrap();
        assert!(!p.unit_weights());
        assert!(p.alias_tables_streamed());
        let (mut t, mut w) = (Vec::new(), Vec::new());
        for v in 0..6u32 {
            p.neighborhood_into(v, &mut t, &mut w);
            assert_eq!(t, g.neighbors(v));
            // exact f32 bits, not approximate equality
            let got: Vec<u32> = w.iter().map(|x| x.to_bits()).collect();
            let want: Vec<u32> = g.neighbor_weights(v).iter().map(|x| x.to_bits()).collect();
            assert_eq!(got, want, "node {v}");
            assert_eq!(p.weighted_degree(v).to_bits(), g.weighted_degree(v).to_bits());
        }
    }

    #[test]
    fn streamed_alias_tables_match_resident_builds_bitwise() {
        // every deg>=2 node's sidecar record must hold the exact bits of
        // AliasTable::new over that row — the walker equivalence rests
        // on this
        let mut b = GraphBuilder::new();
        for i in 0..40u32 {
            for j in 0..4u32 {
                b.push_edge(i, (i + j + 1) % 40, ((i + j) % 7 + 1) as f32 * 0.5);
            }
        }
        let g = b.build();
        let path = tmp("alias_bits.gvpk");
        pack_graph(&g, &path, &PackOptions { page_size: 32, ..Default::default() }).unwrap();
        let p = PagedCsr::open(&path, 128).unwrap();
        let (mut prob, mut alias) = (Vec::new(), Vec::new());
        for v in 0..40u32 {
            if g.degree(v) < 2 {
                continue;
            }
            GraphStore::alias_into(&p, v, &mut prob, &mut alias);
            let want = AliasTable::new(g.neighbor_weights(v));
            let got_bits: Vec<u32> = prob.iter().map(|x| x.to_bits()).collect();
            let want_bits: Vec<u32> = want.probs().iter().map(|x| x.to_bits()).collect();
            assert_eq!(got_bits, want_bits, "probs of node {v}");
            assert_eq!(alias, want.aliases(), "aliases of node {v}");
        }
    }

    #[test]
    fn tiny_pages_force_boundary_straddling_records() {
        // page_size 16 guarantees multi-page records on any real degree
        let g = generators::barabasi_albert(200, 4, 5);
        let path = tmp("straddle.gvpk");
        pack_graph(&g, &path, &PackOptions { page_size: 16, ..Default::default() }).unwrap();
        let p = PagedCsr::open(&path, 16 * 4).unwrap(); // 4 resident pages
        let mut t = Vec::new();
        for v in 0..200u32 {
            p.successors_into(v, &mut t);
            assert_eq!(t, g.neighbors(v), "node {v}");
        }
        let s = p.cache_stats();
        assert!(s.evictions > 0, "tiny budget must evict: {s:?}");
        assert!(s.resident_bytes <= s.budget_bytes);
    }

    #[test]
    fn cursor_serves_rescan_without_touching_the_cache() {
        let g = generators::karate_club();
        let path = tmp("hits.gvpk");
        pack_graph(&g, &path, &PackOptions::default()).unwrap();
        let p = PagedCsr::open(&path, DEFAULT_CACHE_BYTES).unwrap();
        let mut t = Vec::new();
        p.successors_into(0, &mut t);
        let cold = p.cache_stats();
        p.successors_into(1, &mut t);
        let warm = p.cache_stats();
        assert_eq!(warm.misses, cold.misses, "second read within the same page");
        // same page again → served by this thread's cursor, lock-free
        assert_eq!(warm.hits, cold.hits);
        assert!(warm.cursor_hits > cold.cursor_hits);
    }

    #[test]
    fn cursors_do_not_leak_across_stores() {
        // two stores open at once: the thread cursor must key on the
        // store id, or store B would read store A's page bytes
        let ga = generators::karate_club();
        let gb = generators::barabasi_albert(100, 3, 9);
        let (pa, pb) = (tmp("cur_a.gvpk"), tmp("cur_b.gvpk"));
        pack_graph(&ga, &pa, &PackOptions::default()).unwrap();
        pack_graph(&gb, &pb, &PackOptions::default()).unwrap();
        let a = PagedCsr::open(&pa, DEFAULT_CACHE_BYTES).unwrap();
        let b = PagedCsr::open(&pb, DEFAULT_CACHE_BYTES).unwrap();
        let mut t = Vec::new();
        for v in 0..34u32 {
            a.successors_into(v, &mut t);
            assert_eq!(t, ga.neighbors(v), "store A node {v}");
            b.successors_into(v, &mut t);
            assert_eq!(t, gb.neighbors(v), "store B node {v}");
        }
    }

    #[test]
    fn external_pack_matches_in_ram_pack_byte_for_byte() {
        // pack_edge_list (external sort-merge) and pack_graph (in-RAM)
        // must write identical files for duplicate-free inputs — same
        // rows, same alias tables, same header
        for (name, g) in [
            ("ba", generators::barabasi_albert(300, 4, 77)),
            ("weighted", {
                let mut b = GraphBuilder::new();
                for i in 0..60u32 {
                    b.push_edge(i, (i * 7 + 3) % 60, ((i % 5) + 1) as f32 * 0.25);
                    b.push_edge(i, (i * 3 + 1) % 60, 1.0);
                }
                b.build()
            }),
        ] {
            let text = tmp(&format!("ext_{name}.txt"));
            crate::graph::save_edge_list(&g, &text).unwrap();
            let via_ram = tmp(&format!("ext_{name}_ram.gvpk"));
            let via_ext = tmp(&format!("ext_{name}_ext.gvpk"));
            let opts = PackOptions { page_size: 256, ..Default::default() };
            pack_graph(&crate::graph::load_edge_list(&text).unwrap(), &via_ram, &opts).unwrap();
            // a tiny budget forces many spill runs through the merge
            let tiny = PackOptions { mem_bytes: 4096, ..opts };
            pack_edge_list(&text, &via_ext, &tiny).unwrap();
            let a = std::fs::read(&via_ram).unwrap();
            let b = std::fs::read(&via_ext).unwrap();
            assert_eq!(a, b, "{name}: external pack diverged from in-RAM pack");
        }
    }

    #[test]
    fn external_pack_dedups_and_unflags_unit_like_the_builder() {
        // duplicate 1.0 edges sum to 2.0 → the file must NOT claim unit
        // weights even though every input token was 1.0
        let text = tmp("dedup.txt");
        std::fs::write(&text, "0 1\n1 0\n1 2\n").unwrap();
        let packed = tmp("dedup.gvpk");
        let stats = pack_edge_list(&text, &packed, &PackOptions::default()).unwrap();
        assert_eq!(stats.num_nodes, 3);
        assert_eq!(stats.num_arcs, 4);
        let p = PagedCsr::open(&packed, DEFAULT_CACHE_BYTES).unwrap();
        assert!(!p.unit_weights(), "summed duplicates are not unit weights");
        let (mut t, mut w) = (Vec::new(), Vec::new());
        p.neighborhood_into(0, &mut t, &mut w);
        assert_eq!(t, vec![1]);
        assert_eq!(w, vec![2.0]);
    }

    #[test]
    fn version_1_files_are_rejected_with_a_repack_pointer() {
        let g = generators::karate_club();
        let path = tmp("v1.gvpk");
        pack_graph(&g, &path, &PackOptions::default()).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        bytes[4..8].copy_from_slice(&1u32.to_le_bytes());
        std::fs::write(&path, &bytes).unwrap();
        let err = PagedCsr::open(&path, 1 << 20).unwrap_err().to_string();
        assert!(err.contains("version 1"), "{err}");
        assert!(err.contains("repack"), "{err}");
    }

    #[test]
    fn reorder_pack_stores_a_valid_perm() {
        let g = generators::barabasi_albert(120, 3, 21);
        let path = tmp("reordered.gvpk");
        let opts = PackOptions { reorder: ReorderKind::Bfs, ..Default::default() };
        let stats = pack_graph(&g, &path, &opts).unwrap();
        assert_eq!(stats.num_nodes, 120);
        let p = PagedCsr::open(&path, DEFAULT_CACHE_BYTES).unwrap();
        let ext = GraphStore::external_ids(&p).expect("reordered pack must store a perm");
        let mut seen = vec![false; 120];
        for &e in ext {
            assert!(!seen[e as usize]);
            seen[e as usize] = true;
        }
        // the degree multiset survives the relabeling
        let mut got: Vec<usize> = (0..120u32).map(|v| GraphStore::degree(&p, v)).collect();
        let mut want: Vec<usize> = (0..120u32).map(|v| g.degree(v)).collect();
        got.sort_unstable();
        want.sort_unstable();
        assert_eq!(got, want);
    }

    #[test]
    fn loader_format_combinations() {
        let g = generators::karate_club();
        let packed = tmp("combo.gvpk");
        pack_graph(&g, &packed, &PackOptions::default()).unwrap();
        let text = tmp("combo.txt");
        crate::graph::save_edge_list(&g, &text).unwrap();

        assert!(load_graph(&packed, GraphFormat::Auto, 1 << 20).unwrap().paged().is_some());
        assert!(load_graph(&text, GraphFormat::Auto, 1 << 20).unwrap().paged().is_none());
        assert!(load_graph(&packed, GraphFormat::Packed, 1 << 20).is_ok());
        assert!(load_graph(&text, GraphFormat::Edgelist, 1 << 20).is_ok());
        // the bad combinations fail with pointed errors
        let err = load_graph(&text, GraphFormat::Packed, 1 << 20).unwrap_err().to_string();
        assert!(err.contains("bad magic"), "{err}");
        let err = load_graph(&packed, GraphFormat::Edgelist, 1 << 20).unwrap_err().to_string();
        assert!(err.contains("is a packed graph"), "{err}");
        // a missing file under `packed` surfaces the real I/O error, not
        // a misleading "not packed" hint
        let err = load_graph(tmp("nope.gvpk"), GraphFormat::Packed, 1 << 20)
            .unwrap_err()
            .to_string();
        assert!(err.contains("open"), "{err}");
    }

    #[test]
    fn graph_format_parses() {
        for &f in GraphFormat::ALL {
            assert_eq!(GraphFormat::parse(f.name()), Some(f));
            assert_eq!(GraphFormat::parse_or_err(f.name()).unwrap(), f);
            assert!(GraphFormat::names_joined().contains(f.name()));
        }
        assert_eq!(GraphFormat::parse("mmap"), None);
        // the shared error (CLI flags + TOML key) names every valid spelling
        let err = GraphFormat::parse_or_err("mmap").unwrap_err().to_string();
        for &f in GraphFormat::ALL {
            assert!(err.contains(f.name()), "error '{err}' misses '{}'", f.name());
        }
    }
}
