//! Regenerates paper Table 1 — the analytic memory-cost model.
//!
//! Run with `cargo bench --bench bench_table1`; set
//! GRAPHVITE_BENCH_SCALE=tiny|small|full to change the workload size
//! (default tiny so `cargo bench` completes quickly; EXPERIMENTS.md
//! records the `small` runs).

fn main() {
    graphvite::experiments::run("table1", graphvite::experiments::Scale::from_env())
        .expect("table1 experiment");
}
