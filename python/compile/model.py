"""Layer-2 JAX model: one GraphVite episode-block training step.

``make_train_block(P, D, B, S, K)`` builds the jax function that a single
simulated GPU worker executes during an episode: a ``lax.scan`` over S
batches of B positive samples (each with K restricted negatives), where
each scan step

    1. gathers the embedding rows for the batch from the worker-resident
       vertex/context partitions,
    2. calls the Layer-1 Pallas SGNS kernel on the flattened
       ``[B*(1+K), D]`` pair tile,
    3. applies scatter-add SGD updates back into the partitions.

All shapes are static (AOT requirement): P is the padded partition-row
capacity, D the embedding dim. The rust coordinator pads partitions up to
the artifact's P and only ever indexes real rows, so padding rows receive
no gradient and stay bit-identical.

Within one scan step the scatter-add resolves duplicate indices
deterministically (proper mini-batch SGD); the paper's asynchronous hogwild
behaviour lives *between* blocks at Layer 3, exactly where its
epsilon-gradient-exchangeability argument applies.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels.sgns import sgns_grad
from .kernels.ref import sgns_grad_ref

NEG_WEIGHT = 5.0  # paper section 4.3: scale the 1 negative's gradient by 5


def make_train_block(P, D, B, S, K, *, neg_weight=NEG_WEIGHT, use_pallas=True):
    """Build the episode-block train function with static shapes.

    Signature of the returned function:
        train_block(vertex[P,D] f32, context[P,D] f32,
                    pos_u[S,B] i32, pos_v[S,B] i32, neg_v[S,B,K] i32,
                    lr[] f32)
            -> (vertex'[P,D], context'[P,D], mean_loss[] f32)
    """
    grad_fn = sgns_grad if use_pallas else sgns_grad_ref

    def train_block(vertex, context, pos_u, pos_v, neg_v, lr):
        def body(carry, batch):
            vtx, ctx = carry
            u, v, nv = batch  # u, v: [B] i32; nv: [B, K] i32
            nvf = nv.reshape(-1)  # [B*K], row-major (b0k0, b0k1, ...)

            vu = vtx[u]  # [B, D] gather
            cv = ctx[v]  # [B, D]
            cn = ctx[nvf]  # [B*K, D]

            # Flatten positives + negatives into one kernel tile so the
            # Pallas kernel sees a single [B*(1+K), D] workload.
            ue = jnp.concatenate([vu, jnp.repeat(vu, K, axis=0)], axis=0)
            ve = jnp.concatenate([cv, cn], axis=0)
            label = jnp.concatenate(
                [jnp.ones((B,), vtx.dtype), jnp.zeros((B * K,), vtx.dtype)]
            )
            weight = jnp.concatenate(
                [jnp.ones((B,), vtx.dtype), jnp.full((B * K,), neg_weight, vtx.dtype)]
            )

            gu, gv, loss = grad_fn(ue, ve, label, weight)

            # u receives gradient from its positive pair and all K negatives.
            gu_total = gu[:B] + gu[B:].reshape(B, K, D).sum(axis=1)
            vtx = vtx.at[u].add(-lr * gu_total)
            ctx = ctx.at[v].add(-lr * gv[:B])
            ctx = ctx.at[nvf].add(-lr * gv[B:])
            return (vtx, ctx), loss.mean()

        (vertex, context), losses = jax.lax.scan(
            body, (vertex, context), (pos_u, pos_v, neg_v)
        )
        return vertex, context, losses.mean()

    return train_block


def make_kernel_only(N, D):
    """Standalone Layer-1 kernel entry point (for rust micro-benches/tests).

    kernel(u[N,D], v[N,D], label[N], weight[N])
        -> (grad_u[N,D], grad_v[N,D], loss[N])
    """

    def kernel(u, v, label, weight):
        return tuple(sgns_grad(u, v, label, weight))

    return kernel


def example_args(P, D, B, S, K):
    """ShapeDtypeStructs for AOT lowering of make_train_block(P,D,B,S,K)."""
    f32 = jnp.float32
    i32 = jnp.int32
    return (
        jax.ShapeDtypeStruct((P, D), f32),  # vertex
        jax.ShapeDtypeStruct((P, D), f32),  # context
        jax.ShapeDtypeStruct((S, B), i32),  # pos_u
        jax.ShapeDtypeStruct((S, B), i32),  # pos_v
        jax.ShapeDtypeStruct((S, B, K), i32),  # neg_v
        jax.ShapeDtypeStruct((), f32),  # lr
    )
