//! Device worker threads: each simulated GPU owns a [`Backend`] trait
//! object (PJRT executable or native trainer, chosen by
//! [`crate::gpu::create_backend`]), receives block jobs, draws its
//! restricted negatives (paper §3.2 — only from the resident context
//! partition), trains, and ships updated partitions back.
//!
//! **Residency protocol** (paper §3.4 generalized — see
//! [`crate::coordinator::transfer`] for the host side). Each partition a
//! job touches arrives as a [`Shipment`]: either the gathered rows
//! (`data: Some`) or an instruction to reuse the worker-resident copy
//! (`data: None` + the version that copy must carry; a mismatch is a
//! protocol bug and fails the run rather than training on stale rows).
//! After training, `keep` decides whether the updated buffer stays in the
//! worker's [`ResidencyCache`] (the coordinator knows the next block
//! touching it runs here) or ships back in the [`JobResult`]. A
//! [`JobMsg::Sync`] fence makes the worker reply with *clones* of every
//! resident partition without evicting, so the coordinator can
//! synchronize the host store at checkpoints and at end of training.

use std::sync::mpsc;
use std::sync::Arc;
use std::thread::{Scope, ScopedJoinHandle};

use anyhow::Result;

use crate::config::TrainConfig;
use crate::embedding::Matrix;
use crate::gpu::{create_backend, Backend, ChunkPlan};
use crate::metrics::Counters;
use crate::runtime::ArtifactMeta;
use crate::sampling::NegativeSampler;
use crate::util::rng::{streams, Rng};

/// One partition transfer of a [`Job`] (host side planned by
/// [`crate::coordinator::transfer::TransferEngine`]).
#[derive(Debug, Clone)]
pub struct Shipment {
    /// Gathered padded partition rows, or `None` = train on the resident
    /// copy (residency hit: the upload was elided).
    pub data: Option<Vec<f32>>,
    /// Version of the copy the worker trains on. For `data: None` the
    /// resident entry must carry exactly this version.
    pub src_version: u64,
    /// Keep the updated buffer resident (tagged `src_version + 1`)
    /// instead of returning it — the coordinator routes the partition's
    /// next block to this same worker.
    pub keep: bool,
}

/// Replay identity for a job re-dispatched to a *different* worker after
/// its original slot died (the fold path of worker-failure recovery).
/// The dead slot's RNG stream state at the job's dispatch and its device
/// chunk size travel with the job, so any surviving worker computes
/// bitwise the same result the dead worker would have — the worker's own
/// RNG stream is left untouched.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Takeover {
    /// The dead slot's RNG state as of this job's dispatch.
    pub rng: [u64; 4],
    /// The dead slot's device chunk size (`batch_size × capacity`), so
    /// chunk planning — and with it negative draw order — is unchanged.
    pub chunk_samples: u32,
}

/// A block-training job.
#[derive(Debug, Clone)]
pub struct Job {
    pub vid: usize,
    pub cid: usize,
    /// Partition-local (u, v) positive samples of block (vid, cid).
    pub block: Vec<(i32, i32)>,
    /// Vertex partition transfer.
    pub vertex: Shipment,
    /// Context partition transfer.
    pub context: Shipment,
    pub lr: f32,
    /// `Some` only when this job is another (dead) slot's work folded
    /// onto this worker by the recovery layer.
    pub takeover: Option<Takeover>,
}

/// Coordinator→worker message (one TCP frame each for the socket
/// transport; `Clone` exists for transport test doubles).
#[derive(Debug, Clone)]
pub enum JobMsg {
    Train(Job),
    /// Fence: reply with clones of all resident partitions (cache kept).
    Sync,
    /// Liveness probe; the worker answers [`Reply::Pong`] immediately.
    Ping,
    Stop,
}

/// One partition held in a worker's [`ResidencyCache`] (also the wire
/// format of a [`Reply::Synced`] entry).
#[derive(Debug, Clone)]
pub struct ResidentPart {
    pub matrix: Matrix,
    pub pid: usize,
    pub version: u64,
    pub data: Vec<f32>,
}

/// Worker response to one training job. (Version tags travel only
/// host→device: the worker verifies them in `resolve`, and a returned
/// buffer is by construction the partition's newest copy, so results
/// carry no version.)
#[derive(Debug, Clone)]
pub struct JobResult {
    /// Index of the worker slot that trained this job. Not a wire field —
    /// in-process workers stamp it directly and the socket transport's
    /// reader threads stamp it from the connection the frame arrived on —
    /// so a fault-injecting transport can drop a dead worker's replies by
    /// identity rather than by job key.
    pub worker: usize,
    pub vid: usize,
    pub cid: usize,
    /// Updated vertex rows, `None` when kept resident (`Shipment::keep`).
    pub vertex: Option<Vec<f32>>,
    /// Updated context rows, `None` when kept resident.
    pub context: Option<Vec<f32>>,
    /// The job's (emptied) block buffer, returned for the coordinator's
    /// free-list (zero-realloc block movement).
    pub block: Vec<(i32, i32)>,
    pub loss: f32,
    /// Real (unpadded) positive samples trained.
    pub trained: u64,
    /// The state of the RNG stream that trained this job, *after* the
    /// job (worker streams advance once per negative drawn). The
    /// recovery journal chains these so each outstanding job's RNG at
    /// dispatch is known and a lost job can be replayed bitwise.
    pub rng_state: [u64; 4],
}

/// A worker's answer to a [`JobMsg::Sync`] fence: clones of its resident
/// partitions plus the worker's identity and RNG snapshot. Replies arrive
/// unordered on the shared result channel, so the worker index travels in
/// the reply; the RNG state is what checkpoint/resume needs — the worker
/// streams are the only *stateful* RNGs in the system (they advance per
/// negative drawn), everything else rederives from `seed` + pool index.
#[derive(Debug, Clone)]
pub struct SyncReply {
    pub worker: usize,
    pub rng_state: [u64; 4],
    pub residents: Vec<ResidentPart>,
}

/// Everything a worker sends back on the shared result channel.
#[derive(Debug, Clone)]
pub enum Reply {
    Job(JobResult),
    Synced(SyncReply),
    /// Answer to [`JobMsg::Ping`]. On the socket transport the reader
    /// thread consumes pongs for liveness tracking; they never reach the
    /// episode runner.
    Pong,
}

type ResultTx = mpsc::Sender<Result<Reply>>;

/// Per-worker cache of partitions kept resident between jobs. At most one
/// entry per (matrix, pid); across the whole worker pool at most one
/// worker holds any partition (the coordinator only sets `keep` when it
/// routes the partition's next block to the same worker).
///
/// When the config declares worker capacities the cache is *bounded*
/// (`limit = 2 × capacity` — [`TrainConfig::residency_limits`]): the
/// transfer engine plans keeps against the same bound, so an insert past
/// it means the coordinator and this worker disagree about residency — a
/// protocol bug that must fail the run, not silently grow device memory.
#[derive(Debug, Default)]
struct ResidencyCache {
    entries: Vec<ResidentPart>,
    /// Max entries (`None` = unbounded, the homogeneous default).
    limit: Option<usize>,
}

impl ResidencyCache {
    fn new(limit: Option<usize>) -> Self {
        ResidencyCache { entries: Vec::new(), limit }
    }

    fn take(&mut self, matrix: Matrix, pid: usize) -> Option<ResidentPart> {
        let i = self
            .entries
            .iter()
            .position(|e| e.matrix == matrix && e.pid == pid)?;
        Some(self.entries.swap_remove(i))
    }

    fn insert(&mut self, part: ResidentPart) -> Result<()> {
        debug_assert!(
            !self
                .entries
                .iter()
                .any(|e| e.matrix == part.matrix && e.pid == part.pid),
            "duplicate residency entry for {:?} partition {}",
            part.matrix,
            part.pid
        );
        if let Some(limit) = self.limit {
            anyhow::ensure!(
                self.entries.len() < limit,
                "worker residency cache over capacity: {} resident, limit {} — \
                 refusing to pin {:?} partition {}",
                self.entries.len(),
                limit,
                part.matrix,
                part.pid
            );
        }
        self.entries.push(part);
        Ok(())
    }

    fn snapshot(&self) -> Vec<ResidentPart> {
        self.entries.clone()
    }
}

/// Spawn `num_workers` device threads inside `scope`. Returns join
/// handles, per-worker job senders, and the shared result receiver.
///
/// `resume_rngs`, when given (checkpoint resume), replaces the freshly
/// derived per-worker negative-sampling streams with the exact states the
/// checkpoint captured, so the resumed run draws the same negatives the
/// uninterrupted run would have.
pub fn spawn_workers<'scope, 'env>(
    scope: &'scope Scope<'scope, 'env>,
    cfg: &TrainConfig,
    artifact: Option<&ArtifactMeta>,
    neg: Arc<NegativeSampler>,
    counters: Arc<Counters>,
    base_rng: &Rng,
    resume_rngs: Option<&[[u64; 4]]>,
) -> Result<(
    Vec<ScopedJoinHandle<'scope, Result<()>>>,
    Vec<mpsc::Sender<JobMsg>>,
    mpsc::Receiver<Result<Reply>>,
)> {
    if let Some(states) = resume_rngs {
        anyhow::ensure!(
            states.len() == cfg.num_workers,
            "checkpoint has {} worker rng states but the config declares {} workers",
            states.len(),
            cfg.num_workers
        );
    }
    let (result_tx, result_rx) = mpsc::channel::<Result<Reply>>();
    let mut handles = Vec::with_capacity(cfg.num_workers);
    let mut job_txs = Vec::with_capacity(cfg.num_workers);
    let cache_limits = cfg.residency_limits();
    for i in 0..cfg.num_workers {
        let (tx, rx) = mpsc::channel::<JobMsg>();
        job_txs.push(tx);
        let result_tx = result_tx.clone();
        let neg = Arc::clone(&neg);
        let counters = Arc::clone(&counters);
        let rng = match resume_rngs {
            Some(states) => Rng::from_state(states[i])
                .map_err(|e| anyhow::anyhow!("resume worker {i} rng: {e}"))?,
            None => base_rng.stream(streams::WORKER, i as u64),
        };
        // Capacity-aware chunk sizing: a declared-capacity worker trains
        // device chunks of `batch_size × capacity` samples (a bigger
        // device takes proportionally bigger mini-batches as well as more
        // blocks per wave). The homogeneous default (capacity 1) leaves
        // batch_size untouched.
        let capacity = cfg.worker_capacity(i);
        let mut cfg = cfg.clone();
        cfg.batch_size *= capacity;
        let cache_limit = cache_limits.as_ref().map(|l| l[i]);
        let artifact = artifact.cloned();
        handles.push(scope.spawn(move || {
            worker_loop(i, cfg, cache_limit, artifact, neg, counters, rng, rx, result_tx)
        }));
    }
    Ok((handles, job_txs, result_rx))
}

#[allow(clippy::too_many_arguments)]
fn worker_loop(
    worker_idx: usize,
    cfg: TrainConfig,
    cache_limit: Option<usize>,
    artifact: Option<ArtifactMeta>,
    neg: Arc<NegativeSampler>,
    counters: Arc<Counters>,
    rng: Rng,
    rx: mpsc::Receiver<JobMsg>,
    tx: ResultTx,
) -> Result<()> {
    let mut core =
        WorkerCore::new(worker_idx, &cfg, cache_limit, artifact.as_ref(), neg, counters, rng)?;
    while let Ok(msg) = rx.recv() {
        match core.handle(msg) {
            Some(reply) => {
                if tx.send(reply).is_err() {
                    break; // coordinator gone
                }
            }
            None => break, // Stop
        }
    }
    Ok(())
}

/// The device-side half of the protocol, shared verbatim by in-process
/// worker threads ([`spawn_workers`]) and the remote worker runtime
/// (`graphvite worker`, [`crate::coordinator::transport::run_worker`]).
/// Holding the backend, residency cache, negative sampler and RNG in one
/// place is what makes local and socket runs bitwise-identical: both
/// paths execute exactly this code per message.
pub(crate) struct WorkerCore {
    worker_idx: usize,
    backend: Box<dyn Backend>,
    neg: Arc<NegativeSampler>,
    counters: Arc<Counters>,
    rng: Rng,
    // partitions pinned to this worker by the coordinator's keep flags,
    // capped at 2 × capacity when the config declares worker capacities
    cache: ResidencyCache,
    // reusable chunk scratch (avoids 3 Vec allocations per chunk)
    scratch: ChunkPlan,
}

impl WorkerCore {
    /// Build the device state. `cfg.batch_size` must already be scaled by
    /// this worker's capacity (the callers do it; remote workers receive
    /// their capacity in the handshake). Backend construction happens on
    /// the calling thread: PJRT handles are !Send, one client per
    /// simulated GPU (like one CUDA context per device).
    pub(crate) fn new(
        worker_idx: usize,
        cfg: &TrainConfig,
        cache_limit: Option<usize>,
        artifact: Option<&ArtifactMeta>,
        neg: Arc<NegativeSampler>,
        counters: Arc<Counters>,
        rng: Rng,
    ) -> Result<Self> {
        let backend = create_backend(cfg, artifact)?;
        Ok(WorkerCore {
            worker_idx,
            backend,
            neg,
            counters,
            rng,
            cache: ResidencyCache::new(cache_limit),
            scratch: ChunkPlan::default(),
        })
    }

    /// Handle one message; `None` means Stop (the caller exits its loop).
    pub(crate) fn handle(&mut self, msg: JobMsg) -> Option<Result<Reply>> {
        match msg {
            JobMsg::Train(job) => Some(
                run_job(
                    self.backend.as_mut(),
                    &self.neg,
                    &self.counters,
                    &mut self.rng,
                    &mut self.cache,
                    &mut self.scratch,
                    job,
                )
                .map(|mut r| {
                    r.worker = self.worker_idx;
                    Reply::Job(r)
                }),
            ),
            JobMsg::Sync => Some(Ok(Reply::Synced(SyncReply {
                worker: self.worker_idx,
                rng_state: self.rng.state(),
                residents: self.cache.snapshot(),
            }))),
            JobMsg::Ping => Some(Ok(Reply::Pong)),
            JobMsg::Stop => None,
        }
    }
}

/// Resolve a [`Shipment`] to the buffer the backend trains on, returning
/// `(out_version, buffer)` — `out_version` is what the buffer carries
/// after this job.
fn resolve(
    cache: &mut ResidencyCache,
    matrix: Matrix,
    pid: usize,
    ship: &mut Shipment,
) -> Result<(u64, Vec<f32>)> {
    let buf = match ship.data.take() {
        Some(d) => d,
        None => {
            let part = cache.take(matrix, pid).ok_or_else(|| {
                anyhow::anyhow!(
                    "worker asked to reuse non-resident {matrix:?} partition {pid}"
                )
            })?;
            anyhow::ensure!(
                part.version == ship.src_version,
                "resident {matrix:?} partition {pid} has version {} but the \
                 coordinator expected {}",
                part.version,
                ship.src_version
            );
            part.data
        }
    };
    Ok((ship.src_version + 1, buf))
}

/// Keep the trained buffer resident or hand it back for the result.
/// Fails when pinning would overflow a bounded cache (a planner/worker
/// residency disagreement).
fn stash(
    cache: &mut ResidencyCache,
    matrix: Matrix,
    pid: usize,
    version: u64,
    data: Vec<f32>,
    keep: bool,
) -> Result<Option<Vec<f32>>> {
    if keep {
        cache.insert(ResidentPart { matrix, pid, version, data })?;
        Ok(None)
    } else {
        Ok(Some(data))
    }
}

#[allow(clippy::too_many_arguments)]
fn run_job(
    backend: &mut dyn Backend,
    neg: &NegativeSampler,
    counters: &Counters,
    worker_rng: &mut Rng,
    cache: &mut ResidencyCache,
    scratch: &mut ChunkPlan,
    job: Job,
) -> Result<JobResult> {
    let Job { vid, cid, mut block, mut vertex, mut context, lr, takeover } = job;
    let keep_v = vertex.keep;
    let keep_c = context.keep;
    let (v_version, mut vbuf) = resolve(cache, Matrix::Vertex, vid, &mut vertex)?;
    let (c_version, mut cbuf) = resolve(cache, Matrix::Context, cid, &mut context)?;

    // A folded job trains with the dead slot's RNG stream and chunk
    // size; this worker's own stream must not advance for it.
    let mut takeover_rng = match takeover {
        Some(t) => Some(
            Rng::from_state(t.rng)
                .map_err(|e| anyhow::anyhow!("takeover job ({vid}, {cid}): {e}"))?,
        ),
        None => None,
    };
    let chunk_sz = match takeover {
        Some(t) => t.chunk_samples as usize,
        None => backend.chunk_samples(),
    };
    let rng: &mut Rng = match takeover_rng.as_mut() {
        Some(r) => r,
        None => worker_rng,
    };

    let trained = block.len() as u64;
    let loss = if backend.batched_upload() {
        // Batched backends (PJRT): one train_chunks call per block so
        // partitions are uploaded/downloaded once per episode (the
        // paper's transfer pattern), not per chunk.
        let chunks = plan_chunks(&*backend, chunk_sz, neg, cid, &block, lr, rng);
        let t0 = std::time::Instant::now();
        let loss = backend.train_chunks(&mut vbuf, &mut cbuf, &chunks, counters)?;
        counters.add(&counters.device_nanos, t0.elapsed().as_nanos() as u64);
        loss
    } else {
        // Streaming backends (native): feed chunks through one reusable
        // scratch plan (the collected-Vec variant allocated 3 vectors per
        // chunk and showed up as allocator churn — EXPERIMENTS.md §Perf).
        let k = backend.k();
        let mut loss_sum = 0.0f64;
        let mut chunks = 0usize;
        let mut at = 0usize;
        while at < block.len() {
            let real = plan_chunk_into(scratch, chunk_sz, k, neg, cid, &block, at, lr, rng);
            let t0 = std::time::Instant::now();
            let loss = backend.train_chunks(
                &mut vbuf,
                &mut cbuf,
                std::slice::from_ref(scratch),
                counters,
            )?;
            counters.add(&counters.device_nanos, t0.elapsed().as_nanos() as u64);
            loss_sum += loss as f64;
            chunks += 1;
            at += real;
        }
        if chunks > 0 { (loss_sum / chunks as f64) as f32 } else { 0.0 }
    };
    // `samples_trained` is counted by the coordinator when it absorbs the
    // result (from `JobResult::trained`), so the ledger is identical
    // whether this worker shares the process or sits behind a socket.

    let rng_state = rng.state();
    let vertex_out = stash(cache, Matrix::Vertex, vid, v_version, vbuf, keep_v)?;
    let context_out = stash(cache, Matrix::Context, cid, c_version, cbuf, keep_c)?;
    block.clear(); // contents are spent; the allocation rides back
    Ok(JobResult {
        worker: 0, // stamped by the caller (WorkerCore::handle / socket reader)
        vid,
        cid,
        vertex: vertex_out,
        context: context_out,
        block,
        loss,
        trained,
        rng_state,
    })
}

/// Fill `plan` with the chunk starting at `at`: `chunk_sz` positives
/// (wrap-around padded past the block end) and `chunk_sz * k` restricted
/// negatives from context partition `cid`. Returns the number of real
/// (unpadded) samples consumed.
#[allow(clippy::too_many_arguments)]
fn plan_chunk_into(
    plan: &mut ChunkPlan,
    chunk_sz: usize,
    k: usize,
    neg: &NegativeSampler,
    cid: usize,
    block: &[(i32, i32)],
    at: usize,
    lr: f32,
    rng: &mut Rng,
) -> usize {
    debug_assert!(at < block.len());
    let real = chunk_sz.min(block.len() - at);
    plan.pos_u.clear();
    plan.pos_v.clear();
    plan.neg_v.clear();
    for t in 0..chunk_sz {
        // wrap-around pad: reuse samples from the block start; the
        // duplicates are counted as padding (not in `real`).
        let (u, v) = block[(at + t) % block.len()];
        plan.pos_u.push(u);
        plan.pos_v.push(v);
    }
    for _ in 0..chunk_sz * k {
        plan.neg_v.push(neg.sample_local(cid, rng) as i32);
    }
    plan.lr = lr;
    plan.real = real;
    real
}

/// Collected-Vec chunk planning (used by batched backends and the HLO
/// parity harness; streaming backends go through `plan_chunk_into`).
fn plan_chunks(
    backend: &dyn Backend,
    chunk_sz: usize,
    neg: &NegativeSampler,
    cid: usize,
    block: &[(i32, i32)],
    lr: f32,
    rng: &mut Rng,
) -> Vec<ChunkPlan> {
    let k = backend.k();
    if block.is_empty() {
        return Vec::new();
    }
    let mut chunks = Vec::with_capacity(block.len().div_ceil(chunk_sz));
    let mut at = 0usize;
    while at < block.len() {
        let mut plan = ChunkPlan::default();
        let real = plan_chunk_into(&mut plan, chunk_sz, k, neg, cid, block, at, lr, rng);
        chunks.push(plan);
        at += real;
    }
    chunks
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpu::NativeWorker;
    use crate::graph::generators;
    use crate::partition::Partitioner;

    #[test]
    fn plan_chunks_covers_block_with_padding() {
        let g = generators::barabasi_albert(100, 3, 1);
        let parts = Partitioner::degree_zigzag(&g, 2);
        let neg = NegativeSampler::new(&g, &parts);
        let backend = NativeWorker::new(8, 32, 2, 5.0);
        let block: Vec<(i32, i32)> = (0..70).map(|i| (i % 50, (i + 1) % 50)).collect();
        let mut rng = Rng::new(1);
        let chunks = plan_chunks(&backend, backend.chunk_samples(), &neg, 0, &block, 0.025, &mut rng);
        assert_eq!(chunks.len(), 3); // ceil(70/32)
        assert_eq!(chunks.iter().map(|c| c.real).sum::<usize>(), 70);
        for c in &chunks {
            assert_eq!(c.pos_u.len(), 32);
            assert_eq!(c.neg_v.len(), 64); // k=2
            assert!(c.neg_v.iter().all(|&n| (n as usize) < parts.part_size(0)));
        }
        // final chunk wraps around to the beginning
        let last = chunks.last().unwrap();
        assert_eq!(last.real, 70 - 64);
        assert_eq!((last.pos_u[6], last.pos_v[6]), (block[0].0, block[0].1));
    }

    #[test]
    fn empty_block_no_chunks() {
        let g = generators::karate_club();
        let parts = Partitioner::degree_zigzag(&g, 2);
        let neg = NegativeSampler::new(&g, &parts);
        let backend = NativeWorker::new(4, 16, 1, 5.0);
        let mut rng = Rng::new(2);
        assert!(
            plan_chunks(&backend, backend.chunk_samples(), &neg, 1, &[], 0.1, &mut rng)
                .is_empty()
        );
    }

    #[test]
    fn residency_cache_take_insert_snapshot() {
        let mut cache = ResidencyCache::default();
        cache
            .insert(ResidentPart {
                matrix: Matrix::Context,
                pid: 1,
                version: 3,
                data: vec![1.0, 2.0],
            })
            .unwrap();
        assert!(cache.take(Matrix::Vertex, 1).is_none(), "matrices are distinct keys");
        let snap = cache.snapshot();
        assert_eq!(snap.len(), 1);
        assert_eq!(snap[0].version, 3);
        let part = cache.take(Matrix::Context, 1).unwrap();
        assert_eq!(part.data, vec![1.0, 2.0]);
        assert!(cache.take(Matrix::Context, 1).is_none(), "take evicts");
    }

    #[test]
    fn resolve_rejects_version_mismatch() {
        let mut cache = ResidencyCache::default();
        cache
            .insert(ResidentPart {
                matrix: Matrix::Vertex,
                pid: 0,
                version: 2,
                data: vec![0.0; 4],
            })
            .unwrap();
        let mut ship = Shipment { data: None, src_version: 5, keep: false };
        let err = resolve(&mut cache, Matrix::Vertex, 0, &mut ship).unwrap_err();
        assert!(err.to_string().contains("version"), "{err}");
        // and reuse of a partition that was never kept fails loudly
        let mut ship = Shipment { data: None, src_version: 0, keep: false };
        assert!(resolve(&mut cache, Matrix::Context, 3, &mut ship).is_err());
    }

    #[test]
    fn bounded_cache_fails_loudly_on_overflow() {
        let part = |pid: usize| ResidentPart {
            matrix: Matrix::Vertex,
            pid,
            version: 0,
            data: vec![0.0; 2],
        };
        let mut cache = ResidencyCache::new(Some(2));
        cache.insert(part(0)).unwrap();
        cache.insert(part(1)).unwrap();
        let err = cache.insert(part(2)).unwrap_err();
        assert!(err.to_string().contains("over capacity"), "{err}");
        // taking an entry frees a slot again
        assert!(cache.take(Matrix::Vertex, 0).is_some());
        cache.insert(part(2)).unwrap();
        // the unbounded default accepts arbitrarily many
        let mut cache = ResidencyCache::new(None);
        for pid in 0..64 {
            cache.insert(part(pid)).unwrap();
        }
        assert_eq!(cache.snapshot().len(), 64);
    }
}
