//! Out-of-core graph storage: a versioned on-disk CSR format
//! (gap + varint successor compression, the webgraph idiom) plus the
//! [`PagedCsr`] reader that streams it through a bounded LRU page cache.
//!
//! GraphVite's headline claim is scale — 66M nodes / 1.8B edges on one
//! machine — but the edge-list loader materializes the whole CSR in RAM.
//! This module moves the O(E) part to disk: per-node scalars (offsets,
//! degrees, weighted degrees, labels) stay resident (O(V), ~18 bytes per
//! node), while the successor lists are read on demand with
//! `std::os::unix::fs::FileExt::read_exact_at` — pure std, no mmap crate
//! needed — into fixed-size pages recycled through an LRU cache bounded
//! by a configurable byte budget.
//!
//! # File layout (`.gvpk`, little-endian throughout)
//!
//! ```text
//! ┌──────────────────────── header, 72 bytes ────────────────────────┐
//! │ 0   magic        [u8;4]  = "GVPK"                                │
//! │ 4   version      u32     = 1                                     │
//! │ 8   num_nodes    u64                                             │
//! │ 16  num_arcs     u64     (adjacency entries = 2 × edges)         │
//! │ 24  page_size    u32     (bytes per successor page)              │
//! │ 28  flags        u32     (bit 0 unit-weights, bit 1 has-labels)  │
//! │ 32  offsets_pos  u64 ┐                                           │
//! │ 40  degrees_pos  u64 │  absolute byte positions of the           │
//! │ 48  wdegrees_pos u64 │  sections below                           │
//! │ 56  labels_pos   u64 │  (0 when the section is absent)           │
//! │ 64  pages_pos    u64 ┘                                           │
//! ├── offsets   (num_nodes + 1) × u64  byte offsets into `pages` ────┤
//! ├── degrees    num_nodes × u32       adjacency counts              │
//! ├── wdegrees   num_nodes × f32       weighted degrees              │
//! ├── labels    [num_nodes × u16]      only with flag bit 1          │
//! ├── pages      offsets[num_nodes] bytes of per-node records:       │
//! │                varint(first target),                             │
//! │                varint(zigzag(gap)) × (degree − 1),               │
//! │                [f32 × degree weights]  only without flag bit 0   │
//! └──────────────────────────────────────────────────────────────────┘
//! ```
//!
//! Gaps are zigzag-encoded signed deltas, **not** sorted-ascending
//! unsigned gaps: the record must reproduce the builder's adjacency
//! order byte-exactly (neighbor order feeds the walker's RNG indexing,
//! and training off a packed file must be bitwise-identical to training
//! off the in-RAM loader). Builder rows are sorted, so the deltas are
//! small and the compression is the same in practice.
//!
//! Fail-loud policy: `open` validates magic, version, section geometry,
//! offset monotonicity, the degree/arc ledger and the exact file length
//! (truncation and trailing garbage are both errors). After open, a
//! record that decodes to the wrong length (corrupt page) or an I/O
//! error panics — never train on garbage.

use std::cell::RefCell;
use std::collections::HashMap;
use std::fs::File;
use std::io::{Read, Write};
use std::os::unix::fs::FileExt;
use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use anyhow::{bail, ensure, Context, Result};

use super::{Graph, GraphStore};

/// File magic: "GraphVite PacKed".
pub const MAGIC: [u8; 4] = *b"GVPK";
/// On-disk format version this binary reads and writes.
pub const FORMAT_VERSION: u32 = 1;
/// Default successor-page size (64 KiB — a few thousand records per page
/// on typical degree distributions).
pub const DEFAULT_PAGE_SIZE: u32 = 64 * 1024;
/// Default page-cache byte budget ([`crate::config::TrainConfig::graph_cache_bytes`]).
pub const DEFAULT_CACHE_BYTES: usize = 64 * 1024 * 1024;

const HEADER_LEN: usize = 72;
const FLAG_UNIT_WEIGHTS: u32 = 1;
const FLAG_HAS_LABELS: u32 = 2;

// ------------------------------------------------------------- format --

/// Which loader a graph path goes through
/// (`TrainConfig.graph_format` / `--graph-format`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum GraphFormat {
    /// Sniff the file: packed magic → [`PagedCsr`], anything else → the
    /// edge-list loader. The default.
    Auto,
    /// Force the text edge-list loader (in-RAM CSR).
    Edgelist,
    /// Force the packed on-disk reader; non-packed input is an error.
    Packed,
}

impl GraphFormat {
    /// Every format, in display order (mirrors `BackendKind::ALL`).
    pub const ALL: &'static [GraphFormat] = &[Self::Auto, Self::Edgelist, Self::Packed];

    pub fn parse(s: &str) -> Option<Self> {
        Self::ALL.iter().copied().find(|f| f.name() == s)
    }

    /// [`Self::parse`] with the one canonical unknown-format error — the
    /// CLI flags and the TOML key all fail through here so the message
    /// cannot drift between surfaces.
    pub fn parse_or_err(s: &str) -> Result<Self> {
        Self::parse(s).ok_or_else(|| {
            anyhow::anyhow!(
                "unknown graph format '{s}' (expected one of: {})",
                Self::names_joined()
            )
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            Self::Auto => "auto",
            Self::Edgelist => "edgelist",
            Self::Packed => "packed",
        }
    }

    /// `"auto|edgelist|packed"` — for usage lines and error messages.
    pub fn names_joined() -> String {
        let names: Vec<&str> = Self::ALL.iter().map(|f| f.name()).collect();
        names.join("|")
    }
}

/// `pack` tunables.
#[derive(Debug, Clone, Copy)]
pub struct PackOptions {
    /// Successor-page size in bytes (the cache granularity of readers).
    pub page_size: u32,
}

impl Default for PackOptions {
    fn default() -> Self {
        PackOptions { page_size: DEFAULT_PAGE_SIZE }
    }
}

/// What `pack` wrote (CLI reporting + tests).
#[derive(Debug, Clone, Copy)]
pub struct PackStats {
    pub num_nodes: usize,
    pub num_arcs: usize,
    /// Bytes of the compressed successor section.
    pub payload_bytes: u64,
    /// Total file size.
    pub file_bytes: u64,
}

impl PackStats {
    /// Compressed successor bytes per adjacency entry (raw in-RAM CSR
    /// spends 8: u32 target + f32 weight).
    pub fn bytes_per_arc(&self) -> f64 {
        if self.num_arcs == 0 {
            0.0
        } else {
            self.payload_bytes as f64 / self.num_arcs as f64
        }
    }
}

#[inline]
fn zigzag(x: i64) -> u64 {
    ((x << 1) ^ (x >> 63)) as u64
}

#[inline]
fn unzigzag(u: u64) -> i64 {
    ((u >> 1) as i64) ^ -((u & 1) as i64)
}

fn put_varint(out: &mut Vec<u8>, mut x: u64) {
    while x >= 0x80 {
        out.push((x as u8) | 0x80);
        x >>= 7;
    }
    out.push(x as u8);
}

fn read_varint(bytes: &[u8], cur: &mut usize) -> Result<u64> {
    let mut x = 0u64;
    let mut shift = 0u32;
    loop {
        let Some(&b) = bytes.get(*cur) else {
            bail!("varint overruns record (corrupt or truncated page)");
        };
        *cur += 1;
        ensure!(shift < 64, "varint longer than 64 bits (corrupt page)");
        x |= ((b & 0x7f) as u64) << shift;
        if b & 0x80 == 0 {
            return Ok(x);
        }
        shift += 7;
    }
}

/// Decode one node record. `weights: Some` also parses the weight tail;
/// either way the record must be consumed exactly (fail-loud on corrupt
/// pages).
fn decode_record(
    bytes: &[u8],
    deg: usize,
    unit_weights: bool,
    targets: &mut Vec<u32>,
    mut weights: Option<&mut Vec<f32>>,
) -> Result<()> {
    targets.clear();
    if let Some(w) = weights.as_deref_mut() {
        w.clear();
    }
    let mut cur = 0usize;
    if deg > 0 {
        let first = read_varint(bytes, &mut cur)?;
        ensure!(first <= u32::MAX as u64, "target id out of range (corrupt page)");
        targets.push(first as u32);
        let mut prev = first as i64;
        for _ in 1..deg {
            let t = prev + unzigzag(read_varint(bytes, &mut cur)?);
            ensure!(
                (0..=u32::MAX as i64).contains(&t),
                "gap walks outside the id range (corrupt page)"
            );
            targets.push(t as u32);
            prev = t;
        }
    }
    if unit_weights {
        if let Some(w) = weights {
            w.resize(deg, 1.0);
        }
    } else if let Some(w) = weights {
        for _ in 0..deg {
            ensure!(cur + 4 <= bytes.len(), "weight tail truncated (corrupt page)");
            w.push(f32::from_le_bytes(bytes[cur..cur + 4].try_into().unwrap()));
            cur += 4;
        }
    } else {
        ensure!(
            bytes.len() >= cur && bytes.len() - cur == 4 * deg,
            "weight tail has the wrong length (corrupt page)"
        );
        cur += 4 * deg;
    }
    ensure!(cur == bytes.len(), "record length mismatch (corrupt page)");
    Ok(())
}

// --------------------------------------------------------------- pack --

/// Write `graph` as a packed on-disk file (the `graphvite pack` core).
pub fn pack_graph(graph: &Graph, path: impl AsRef<Path>, opts: &PackOptions) -> Result<PackStats> {
    ensure!(
        (16..=1 << 30).contains(&opts.page_size),
        "page_size {} out of range (16 bytes .. 1 GiB)",
        opts.page_size
    );
    let path = path.as_ref();
    let n = graph.num_nodes();
    let unit = graph.unit_weights();

    // encode the successor payload (in RAM: pack is the one-shot step
    // that already holds the built CSR; readers never do this)
    let mut offsets: Vec<u64> = Vec::with_capacity(n + 1);
    let mut pages: Vec<u8> = Vec::with_capacity(graph.num_arcs() * 2);
    offsets.push(0);
    for v in 0..n as u32 {
        let nbrs = graph.neighbors(v);
        if let Some((&first, rest)) = nbrs.split_first() {
            put_varint(&mut pages, first as u64);
            let mut prev = first as i64;
            for &t in rest {
                put_varint(&mut pages, zigzag(t as i64 - prev));
                prev = t as i64;
            }
        }
        if !unit {
            for &w in graph.neighbor_weights(v) {
                pages.extend_from_slice(&w.to_le_bytes());
            }
        }
        offsets.push(pages.len() as u64);
    }

    let offsets_pos = HEADER_LEN as u64;
    let degrees_pos = offsets_pos + 8 * (n as u64 + 1);
    let wdegrees_pos = degrees_pos + 4 * n as u64;
    let labels_pos = if graph.labels().is_some() { wdegrees_pos + 4 * n as u64 } else { 0 };
    let pages_pos = if labels_pos != 0 {
        labels_pos + 2 * n as u64
    } else {
        wdegrees_pos + 4 * n as u64
    };

    let mut flags = 0u32;
    if unit {
        flags |= FLAG_UNIT_WEIGHTS;
    }
    if graph.labels().is_some() {
        flags |= FLAG_HAS_LABELS;
    }

    let mut w = std::io::BufWriter::new(
        File::create(path).with_context(|| format!("create {}", path.display()))?,
    );
    w.write_all(&MAGIC)?;
    w.write_all(&FORMAT_VERSION.to_le_bytes())?;
    w.write_all(&(n as u64).to_le_bytes())?;
    w.write_all(&(graph.num_arcs() as u64).to_le_bytes())?;
    w.write_all(&opts.page_size.to_le_bytes())?;
    w.write_all(&flags.to_le_bytes())?;
    for pos in [offsets_pos, degrees_pos, wdegrees_pos, labels_pos, pages_pos] {
        w.write_all(&pos.to_le_bytes())?;
    }
    for &off in &offsets {
        w.write_all(&off.to_le_bytes())?;
    }
    for v in 0..n as u32 {
        w.write_all(&(graph.degree(v) as u32).to_le_bytes())?;
    }
    for v in 0..n as u32 {
        w.write_all(&graph.weighted_degree(v).to_le_bytes())?;
    }
    if let Some(labels) = graph.labels() {
        for &l in labels {
            w.write_all(&l.to_le_bytes())?;
        }
    }
    w.write_all(&pages)?;
    w.flush()?;

    Ok(PackStats {
        num_nodes: n,
        num_arcs: graph.num_arcs(),
        payload_bytes: pages.len() as u64,
        file_bytes: pages_pos + pages.len() as u64,
    })
}

/// Load an edge list and pack it — the `graphvite pack` subcommand body.
pub fn pack_edge_list(
    input: impl AsRef<Path>,
    output: impl AsRef<Path>,
    opts: &PackOptions,
) -> Result<PackStats> {
    let graph = super::load_edge_list(input)?;
    pack_graph(&graph, output, opts)
}

/// True when `path` starts with the packed magic (the `auto` sniff).
pub fn is_packed(path: impl AsRef<Path>) -> bool {
    let Ok(mut f) = File::open(path) else {
        return false;
    };
    let mut m = [0u8; 4];
    f.read_exact(&mut m).is_ok() && m == MAGIC
}

// ------------------------------------------------------------- reader --

/// Snapshot of the reader's page-cache counters (CI's `ondisk-smoke` job
/// greps the line `cmd_train` prints from these).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CacheStats {
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
    /// Reads served lock-free from a thread's page cursor (these never
    /// touch the LRU, so they are counted separately from `hits`).
    pub cursor_hits: u64,
    /// Bytes of page data currently cached (≤ `budget_bytes`, except
    /// when a single page exceeds the budget — one page is always
    /// admitted).
    pub resident_bytes: usize,
    pub budget_bytes: usize,
    pub page_size: usize,
}

const NIL: usize = usize::MAX;

struct Slot {
    page: u64,
    /// Page bytes behind an `Arc` so thread cursors can hold a page
    /// lock-free after its slot is evicted. `ensure` recycles a slot's
    /// buffer with [`Arc::make_mut`]: unshared buffers are reused in
    /// place, while a buffer some cursor still references is left
    /// untouched (the cursor keeps the old page's bytes) and the slot
    /// gets a fresh allocation.
    data: Arc<Vec<u8>>,
    prev: usize,
    next: usize,
}

/// Intrusive-list LRU over fixed-size pages, bounded by a byte budget.
struct PageCache {
    budget: usize,
    bytes: usize,
    map: HashMap<u64, usize>,
    slots: Vec<Slot>,
    free: Vec<usize>,
    head: usize,
    tail: usize,
    /// Reassembly buffer for records that straddle a page boundary.
    span_buf: Vec<u8>,
    hits: u64,
    misses: u64,
    evictions: u64,
}

impl PageCache {
    fn new(budget: usize) -> Self {
        PageCache {
            budget,
            bytes: 0,
            map: HashMap::new(),
            slots: Vec::new(),
            free: Vec::new(),
            head: NIL,
            tail: NIL,
            span_buf: Vec::new(),
            hits: 0,
            misses: 0,
            evictions: 0,
        }
    }

    fn detach(&mut self, i: usize) {
        let (prev, next) = (self.slots[i].prev, self.slots[i].next);
        if prev == NIL {
            self.head = next;
        } else {
            self.slots[prev].next = next;
        }
        if next == NIL {
            self.tail = prev;
        } else {
            self.slots[next].prev = prev;
        }
        self.slots[i].prev = NIL;
        self.slots[i].next = NIL;
    }

    fn push_front(&mut self, i: usize) {
        self.slots[i].prev = NIL;
        self.slots[i].next = self.head;
        if self.head != NIL {
            self.slots[self.head].prev = i;
        }
        self.head = i;
        if self.tail == NIL {
            self.tail = i;
        }
    }

    /// Return the slot of `page`, loading (and evicting) as needed.
    fn ensure(&mut self, page: u64, io: &PageIo<'_>) -> Result<usize> {
        if let Some(&i) = self.map.get(&page) {
            self.hits += 1;
            if self.head != i {
                self.detach(i);
                self.push_front(i);
            }
            return Ok(i);
        }
        self.misses += 1;
        let len = io.page_len(page);
        // evict from the cold tail until the new page fits (the budget
        // always admits at least this one page)
        while self.bytes + len > self.budget && self.tail != NIL {
            let t = self.tail;
            self.detach(t);
            self.map.remove(&self.slots[t].page);
            self.bytes -= self.slots[t].data.len();
            self.evictions += 1;
            self.free.push(t);
        }
        let i = match self.free.pop() {
            Some(i) => i,
            None => {
                self.slots.push(Slot { page: 0, data: Arc::new(Vec::new()), prev: NIL, next: NIL });
                self.slots.len() - 1
            }
        };
        self.slots[i].page = page;
        // reuse the buffer when unshared; when a thread cursor still holds
        // the evicted page it contains, leave that allocation to the
        // cursor and start fresh (make_mut would clone the stale bytes)
        if Arc::get_mut(&mut self.slots[i].data).is_none() {
            self.slots[i].data = Arc::new(Vec::new());
        }
        let buf = Arc::make_mut(&mut self.slots[i].data);
        buf.resize(len, 0);
        if let Err(e) = io.read_page(page, buf) {
            self.free.push(i);
            return Err(e);
        }
        self.map.insert(page, i);
        self.bytes += len;
        self.push_front(i);
        Ok(i)
    }
}

/// The read-side file geometry `PageCache::ensure` loads through.
struct PageIo<'a> {
    file: &'a File,
    pages_pos: u64,
    pages_len: u64,
    page_size: usize,
}

impl PageIo<'_> {
    fn page_len(&self, page: u64) -> usize {
        let start = page * self.page_size as u64;
        (self.pages_len - start).min(self.page_size as u64) as usize
    }

    fn read_page(&self, page: u64, buf: &mut [u8]) -> Result<()> {
        let start = page * self.page_size as u64;
        self.file
            .read_exact_at(buf, self.pages_pos + start)
            .with_context(|| format!("read page {page} (file shrank after open?)"))
    }
}

/// Out-of-core CSR reader over a packed file: O(V) resident scalars, the
/// O(E) successor payload streamed through a byte-bounded LRU page cache.
///
/// Thread-safe (`GraphStore: Send + Sync`): the shared cache sits behind
/// one mutex, but each thread also keeps a lock-free *cursor* — an `Arc`
/// to the last page it read. Sampler threads walk successor lists in
/// node order, so consecutive reads overwhelmingly land on the cursor
/// page and never touch the lock; the mutex is only taken on a page
/// change (and for boundary-straddling records). Page bytes are
/// immutable after load, so a cursor that outlives its slot's eviction
/// still reads correct data (see [`Slot::data`] for the recycling rule).
pub struct PagedCsr {
    file: File,
    /// Distinguishes this store's pages in the thread-local cursor (two
    /// open stores must never serve each other's pages).
    store_id: u64,
    page_size: usize,
    pages_pos: u64,
    pages_len: u64,
    num_arcs: u64,
    unit_weights: bool,
    offsets: Vec<u64>,
    degrees: Vec<u32>,
    wdegrees: Vec<f32>,
    labels: Option<Vec<u16>>,
    cache: Mutex<PageCache>,
    cursor_hits: AtomicU64,
}

/// Store-id allocator for [`PagedCsr::store_id`]. Starts at 1 so 0 can
/// never match (an empty cursor is `None`, but belt and braces).
static NEXT_STORE_ID: AtomicU64 = AtomicU64::new(1);

thread_local! {
    /// The calling thread's page cursor: `(store_id, page, bytes)` of the
    /// last single-page record it read. One entry is enough — samplers
    /// stream nodes in order, so the win is consecutive records on one
    /// page, not a working set.
    static PAGE_CURSOR: RefCell<Option<(u64, u64, Arc<Vec<u8>>)>> = const { RefCell::new(None) };
}

impl PagedCsr {
    /// Open a packed graph with a page cache bounded at `cache_bytes`
    /// (clamped up to one page so progress is always possible).
    /// Validates the whole resident geometry before returning — a file
    /// this accepts either reads cleanly or is corrupt at page level
    /// (which then fails loudly at access time).
    pub fn open(path: impl AsRef<Path>, cache_bytes: usize) -> Result<Self> {
        let path = path.as_ref();
        let mut file =
            File::open(path).with_context(|| format!("open {}", path.display()))?;
        let mut hdr = [0u8; HEADER_LEN];
        file.read_exact(&mut hdr)
            .map_err(|_| anyhow::anyhow!("{}: truncated header", path.display()))?;
        ensure!(
            hdr[..4] == MAGIC,
            "{}: not a packed graphvite graph (bad magic; produce one with \
             `graphvite pack`)",
            path.display()
        );
        let u32_at = |at: usize| u32::from_le_bytes(hdr[at..at + 4].try_into().unwrap());
        let u64_at = |at: usize| u64::from_le_bytes(hdr[at..at + 8].try_into().unwrap());
        let version = u32_at(4);
        ensure!(
            version == FORMAT_VERSION,
            "{}: unsupported packed-graph version {version} (this binary reads \
             version {FORMAT_VERSION})",
            path.display()
        );
        let n = u64_at(8) as usize;
        let num_arcs = u64_at(16);
        let page_size = u32_at(24);
        let flags = u32_at(28);
        let offsets_pos = u64_at(32);
        let degrees_pos = u64_at(40);
        let wdegrees_pos = u64_at(48);
        let labels_pos = u64_at(56);
        let pages_pos = u64_at(64);
        ensure!(
            (16..=1 << 30).contains(&page_size),
            "{}: page_size {page_size} out of range",
            path.display()
        );
        // Bound the node count by the file size FIRST: the resident
        // sections alone need > 16 bytes/node, so any real file has
        // n < file_len / 16 — and with n bounded, none of the section
        // arithmetic below can overflow (a corrupt 2^61 node count must
        // neither wrap the geometry checks nor become a huge alloc).
        let file_len = file.metadata()?.len();
        ensure!(
            (n as u64) < file_len / 16,
            "{}: node count {n} exceeds what a {file_len}-byte file can hold \
             (corrupt header)",
            path.display()
        );
        let has_labels = flags & FLAG_HAS_LABELS != 0;
        let expected_labels_pos = if has_labels { wdegrees_pos + 4 * n as u64 } else { 0 };
        let expected_pages_pos =
            wdegrees_pos + 4 * n as u64 + if has_labels { 2 * n as u64 } else { 0 };
        ensure!(
            offsets_pos == HEADER_LEN as u64
                && degrees_pos == offsets_pos + 8 * (n as u64 + 1)
                && wdegrees_pos == degrees_pos + 4 * n as u64
                && labels_pos == expected_labels_pos
                && pages_pos == expected_pages_pos,
            "{}: section table does not match the declared node count (corrupt header)",
            path.display()
        );
        ensure!(
            pages_pos <= file_len,
            "{}: sections overrun the file — truncated or corrupt header",
            path.display()
        );

        let read_section = |file: &mut File, len: usize, what: &str| -> Result<Vec<u8>> {
            let mut buf = vec![0u8; len];
            file.read_exact(&mut buf)
                .map_err(|_| anyhow::anyhow!("{}: truncated {what} section", path.display()))?;
            Ok(buf)
        };
        let raw = read_section(&mut file, 8 * (n + 1), "offsets")?;
        let offsets: Vec<u64> = raw
            .chunks_exact(8)
            .map(|c| u64::from_le_bytes(c.try_into().unwrap()))
            .collect();
        let raw = read_section(&mut file, 4 * n, "degrees")?;
        let degrees: Vec<u32> = raw
            .chunks_exact(4)
            .map(|c| u32::from_le_bytes(c.try_into().unwrap()))
            .collect();
        let raw = read_section(&mut file, 4 * n, "weighted-degrees")?;
        let wdegrees: Vec<f32> = raw
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect();
        let labels = if has_labels {
            let raw = read_section(&mut file, 2 * n, "labels")?;
            Some(
                raw.chunks_exact(2)
                    .map(|c| u16::from_le_bytes(c.try_into().unwrap()))
                    .collect(),
            )
        } else {
            None
        };

        ensure!(offsets[0] == 0, "{}: offsets must start at 0 (corrupt header)", path.display());
        ensure!(
            offsets.windows(2).all(|w| w[0] <= w[1]),
            "{}: non-monotone offset table (corrupt header)",
            path.display()
        );
        ensure!(
            degrees.iter().map(|&d| d as u64).sum::<u64>() == num_arcs,
            "{}: degree table disagrees with the declared arc count (corrupt header)",
            path.display()
        );
        let pages_len = *offsets.last().unwrap();
        ensure!(
            file_len == pages_pos + pages_len,
            "{}: file is {file_len} bytes but the header implies {} — truncated \
             or trailing garbage",
            path.display(),
            pages_pos + pages_len
        );

        // the budget must admit at least one page or no record is readable
        let budget = cache_bytes.max(page_size as usize);
        Ok(PagedCsr {
            file,
            store_id: NEXT_STORE_ID.fetch_add(1, Ordering::Relaxed),
            page_size: page_size as usize,
            pages_pos,
            pages_len,
            num_arcs,
            unit_weights: flags & FLAG_UNIT_WEIGHTS != 0,
            offsets,
            degrees,
            wdegrees,
            labels,
            cache: Mutex::new(PageCache::new(budget)),
            cursor_hits: AtomicU64::new(0),
        })
    }

    /// Page-cache counters (hits/misses/evictions + residency).
    pub fn cache_stats(&self) -> CacheStats {
        let c = self.cache.lock().unwrap();
        CacheStats {
            hits: c.hits,
            misses: c.misses,
            evictions: c.evictions,
            cursor_hits: self.cursor_hits.load(Ordering::Relaxed),
            resident_bytes: c.bytes,
            budget_bytes: c.budget,
            page_size: self.page_size,
        }
    }

    /// Run `f` over node `v`'s raw record bytes, served from the page
    /// cache (single-page records decode in place; boundary-straddling
    /// ones reassemble through the cache's span buffer).
    fn with_record<R>(&self, v: u32, f: impl FnOnce(&[u8]) -> Result<R>) -> Result<R> {
        let start = self.offsets[v as usize];
        let end = self.offsets[v as usize + 1];
        debug_assert!(start < end, "with_record on an empty record");
        let ps = self.page_size as u64;
        let io = PageIo {
            file: &self.file,
            pages_pos: self.pages_pos,
            pages_len: self.pages_len,
            page_size: self.page_size,
        };
        let first_page = start / ps;
        let last_page = (end - 1) / ps;
        if first_page == last_page {
            let lo = (start - first_page * ps) as usize;
            let hi = (end - first_page * ps) as usize;
            // lock-free fast path: the record lives on the page this
            // thread read last time
            let held = PAGE_CURSOR.with(|c| match &*c.borrow() {
                Some((sid, page, data)) if *sid == self.store_id && *page == first_page => {
                    Some(Arc::clone(data))
                }
                _ => None,
            });
            let data = match held {
                Some(data) => {
                    self.cursor_hits.fetch_add(1, Ordering::Relaxed);
                    data
                }
                None => {
                    let mut cache = self.cache.lock().unwrap();
                    let i = cache.ensure(first_page, &io)?;
                    let data = Arc::clone(&cache.slots[i].data);
                    drop(cache);
                    PAGE_CURSOR.with(|c| {
                        *c.borrow_mut() = Some((self.store_id, first_page, Arc::clone(&data)));
                    });
                    data
                }
            };
            f(&data[lo..hi])
        } else {
            let mut cache = self.cache.lock().unwrap();
            let mut buf = std::mem::take(&mut cache.span_buf);
            buf.clear();
            for page in first_page..=last_page {
                let i = cache.ensure(page, &io)?;
                let data = &cache.slots[i].data;
                let lo = if page == first_page { (start - page * ps) as usize } else { 0 };
                let hi = if page == last_page { (end - page * ps) as usize } else { data.len() };
                buf.extend_from_slice(&data[lo..hi]);
            }
            let r = f(&buf);
            cache.span_buf = buf;
            r
        }
    }

    fn record<R>(&self, v: u32, f: impl FnOnce(&[u8]) -> Result<R>) -> R {
        self.with_record(v, f)
            .unwrap_or_else(|e| panic!("paged graph: reading node {v} failed: {e:#}"))
    }
}

impl GraphStore for PagedCsr {
    fn num_nodes(&self) -> usize {
        self.degrees.len()
    }

    fn num_edges(&self) -> usize {
        (self.num_arcs / 2) as usize
    }

    fn num_arcs(&self) -> usize {
        self.num_arcs as usize
    }

    fn degree(&self, v: u32) -> usize {
        self.degrees[v as usize] as usize
    }

    fn weighted_degree(&self, v: u32) -> f32 {
        self.wdegrees[v as usize]
    }

    fn weighted_degrees(&self) -> &[f32] {
        &self.wdegrees
    }

    fn unit_weights(&self) -> bool {
        self.unit_weights
    }

    fn labels(&self) -> Option<&[u16]> {
        self.labels.as_deref()
    }

    fn successors_into(&self, v: u32, targets: &mut Vec<u32>) {
        let deg = self.degrees[v as usize] as usize;
        if deg == 0 {
            targets.clear();
            return;
        }
        self.record(v, |b| decode_record(b, deg, self.unit_weights, targets, None));
    }

    fn neighborhood_into(&self, v: u32, targets: &mut Vec<u32>, weights: &mut Vec<f32>) {
        let deg = self.degrees[v as usize] as usize;
        if deg == 0 {
            targets.clear();
            weights.clear();
            return;
        }
        self.record(v, |b| decode_record(b, deg, self.unit_weights, targets, Some(weights)));
    }

    fn for_each_arc(&self, f: &mut dyn FnMut(u32, u32, f32)) {
        let mut t = Vec::new();
        let mut w = Vec::new();
        for v in 0..self.num_nodes() as u32 {
            self.neighborhood_into(v, &mut t, &mut w);
            for (&tt, &ww) in t.iter().zip(&w) {
                f(v, tt, ww);
            }
        }
    }
}

// ------------------------------------------------------------- loader --

/// A graph loaded through [`load_graph`]: the trait object for the
/// trainer plus the concrete paged handle when the source was packed
/// (for page-cache reporting).
pub enum LoadedGraph {
    InMemory(Arc<Graph>),
    Paged(Arc<PagedCsr>),
}

impl LoadedGraph {
    /// The store handle training runs on.
    pub fn store(&self) -> Arc<dyn GraphStore> {
        match self {
            LoadedGraph::InMemory(g) => Arc::clone(g) as Arc<dyn GraphStore>,
            LoadedGraph::Paged(p) => Arc::clone(p) as Arc<dyn GraphStore>,
        }
    }

    /// The paged reader, when the graph is out-of-core.
    pub fn paged(&self) -> Option<&Arc<PagedCsr>> {
        match self {
            LoadedGraph::Paged(p) => Some(p),
            LoadedGraph::InMemory(_) => None,
        }
    }
}

/// Load `path` according to `format` (`cache_bytes` bounds the page
/// cache of the packed path). Bad combinations fail loudly: `packed` on
/// a non-packed file dies on the reader's bad-magic check (and a
/// missing file on its real I/O error), `edgelist` on a packed file is
/// rejected here with a pointer at the right invocation.
pub fn load_graph(
    path: impl AsRef<Path>,
    format: GraphFormat,
    cache_bytes: usize,
) -> Result<LoadedGraph> {
    let path = path.as_ref();
    let packed = is_packed(path);
    match format {
        GraphFormat::Auto => {
            if packed {
                Ok(LoadedGraph::Paged(Arc::new(PagedCsr::open(path, cache_bytes)?)))
            } else {
                Ok(LoadedGraph::InMemory(Arc::new(super::load_edge_list(path)?)))
            }
        }
        GraphFormat::Packed => {
            // open directly rather than pre-sniffing: a missing file
            // surfaces its real I/O error and a non-packed file fails
            // open's own bad-magic check, instead of both collapsing
            // into one misleading "not packed" message
            Ok(LoadedGraph::Paged(Arc::new(PagedCsr::open(path, cache_bytes)?)))
        }
        GraphFormat::Edgelist => {
            ensure!(
                !packed,
                "{}: graph_format = \"edgelist\" but the file is a packed graph \
                 (use --graph-format packed or auto)",
                path.display()
            );
            Ok(LoadedGraph::InMemory(Arc::new(super::load_edge_list(path)?)))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{generators, GraphBuilder};

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("graphvite_ondisk_unit");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn varint_roundtrip() {
        let mut buf = Vec::new();
        let values = [0u64, 1, 127, 128, 300, 16_383, 16_384, u32::MAX as u64, u64::MAX];
        for &v in &values {
            buf.clear();
            put_varint(&mut buf, v);
            let mut cur = 0;
            assert_eq!(read_varint(&buf, &mut cur).unwrap(), v);
            assert_eq!(cur, buf.len());
        }
        // truncated varint fails loudly
        buf.clear();
        put_varint(&mut buf, 10_000);
        buf.pop();
        let mut cur = 0;
        assert!(read_varint(&buf, &mut cur).is_err());
    }

    #[test]
    fn zigzag_roundtrip() {
        for x in [0i64, 1, -1, 2, -2, 63, -64, i64::from(u32::MAX), -i64::from(u32::MAX)] {
            assert_eq!(unzigzag(zigzag(x)), x, "x={x}");
        }
    }

    #[test]
    fn pack_open_roundtrip_karate() {
        let g = generators::karate_club();
        let path = tmp("karate.gvpk");
        let stats = pack_graph(&g, &path, &PackOptions::default()).unwrap();
        assert_eq!(stats.num_nodes, 34);
        assert_eq!(stats.num_arcs, 156);
        assert!(stats.bytes_per_arc() < 8.0, "no compression: {}", stats.bytes_per_arc());
        let p = PagedCsr::open(&path, DEFAULT_CACHE_BYTES).unwrap();
        assert_eq!(GraphStore::num_nodes(&p), 34);
        assert_eq!(GraphStore::num_edges(&p), 78);
        assert!(p.unit_weights());
        assert_eq!(p.labels(), g.labels());
        let mut t = Vec::new();
        for v in 0..34u32 {
            p.successors_into(v, &mut t);
            assert_eq!(t, g.neighbors(v), "node {v}");
        }
    }

    #[test]
    fn weighted_graph_roundtrips_exact_bits() {
        let mut b = GraphBuilder::new().with_num_nodes(6);
        b.push_edge(0, 1, 0.1);
        b.push_edge(0, 2, 2.5);
        b.push_edge(3, 4, 1.0e-7);
        let g = b.build();
        let path = tmp("weighted.gvpk");
        pack_graph(&g, &path, &PackOptions { page_size: 16 }).unwrap();
        let p = PagedCsr::open(&path, 64).unwrap();
        assert!(!p.unit_weights());
        let (mut t, mut w) = (Vec::new(), Vec::new());
        for v in 0..6u32 {
            p.neighborhood_into(v, &mut t, &mut w);
            assert_eq!(t, g.neighbors(v));
            // exact f32 bits, not approximate equality
            let got: Vec<u32> = w.iter().map(|x| x.to_bits()).collect();
            let want: Vec<u32> = g.neighbor_weights(v).iter().map(|x| x.to_bits()).collect();
            assert_eq!(got, want, "node {v}");
            assert_eq!(p.weighted_degree(v).to_bits(), g.weighted_degree(v).to_bits());
        }
    }

    #[test]
    fn tiny_pages_force_boundary_straddling_records() {
        // page_size 16 guarantees multi-page records on any real degree
        let g = generators::barabasi_albert(200, 4, 5);
        let path = tmp("straddle.gvpk");
        pack_graph(&g, &path, &PackOptions { page_size: 16 }).unwrap();
        let p = PagedCsr::open(&path, 16 * 4).unwrap(); // 4 resident pages
        let mut t = Vec::new();
        for v in 0..200u32 {
            p.successors_into(v, &mut t);
            assert_eq!(t, g.neighbors(v), "node {v}");
        }
        let s = p.cache_stats();
        assert!(s.evictions > 0, "tiny budget must evict: {s:?}");
        assert!(s.resident_bytes <= s.budget_bytes);
    }

    #[test]
    fn cursor_serves_rescan_without_touching_the_cache() {
        let g = generators::karate_club();
        let path = tmp("hits.gvpk");
        pack_graph(&g, &path, &PackOptions::default()).unwrap();
        let p = PagedCsr::open(&path, DEFAULT_CACHE_BYTES).unwrap();
        let mut t = Vec::new();
        p.successors_into(0, &mut t);
        let cold = p.cache_stats();
        p.successors_into(1, &mut t);
        let warm = p.cache_stats();
        assert_eq!(warm.misses, cold.misses, "second read within the same page");
        // same page again → served by this thread's cursor, lock-free
        assert_eq!(warm.hits, cold.hits);
        assert!(warm.cursor_hits > cold.cursor_hits);
    }

    #[test]
    fn cursors_do_not_leak_across_stores() {
        // two stores open at once: the thread cursor must key on the
        // store id, or store B would read store A's page bytes
        let ga = generators::karate_club();
        let gb = generators::barabasi_albert(100, 3, 9);
        let (pa, pb) = (tmp("cur_a.gvpk"), tmp("cur_b.gvpk"));
        pack_graph(&ga, &pa, &PackOptions::default()).unwrap();
        pack_graph(&gb, &pb, &PackOptions::default()).unwrap();
        let a = PagedCsr::open(&pa, DEFAULT_CACHE_BYTES).unwrap();
        let b = PagedCsr::open(&pb, DEFAULT_CACHE_BYTES).unwrap();
        let mut t = Vec::new();
        for v in 0..34u32 {
            a.successors_into(v, &mut t);
            assert_eq!(t, ga.neighbors(v), "store A node {v}");
            b.successors_into(v, &mut t);
            assert_eq!(t, gb.neighbors(v), "store B node {v}");
        }
    }

    #[test]
    fn loader_format_combinations() {
        let g = generators::karate_club();
        let packed = tmp("combo.gvpk");
        pack_graph(&g, &packed, &PackOptions::default()).unwrap();
        let text = tmp("combo.txt");
        crate::graph::save_edge_list(&g, &text).unwrap();

        assert!(load_graph(&packed, GraphFormat::Auto, 1 << 20).unwrap().paged().is_some());
        assert!(load_graph(&text, GraphFormat::Auto, 1 << 20).unwrap().paged().is_none());
        assert!(load_graph(&packed, GraphFormat::Packed, 1 << 20).is_ok());
        assert!(load_graph(&text, GraphFormat::Edgelist, 1 << 20).is_ok());
        // the bad combinations fail with pointed errors
        let err = load_graph(&text, GraphFormat::Packed, 1 << 20).unwrap_err().to_string();
        assert!(err.contains("bad magic"), "{err}");
        let err = load_graph(&packed, GraphFormat::Edgelist, 1 << 20).unwrap_err().to_string();
        assert!(err.contains("is a packed graph"), "{err}");
        // a missing file under `packed` surfaces the real I/O error, not
        // a misleading "not packed" hint
        let err = load_graph(tmp("nope.gvpk"), GraphFormat::Packed, 1 << 20)
            .unwrap_err()
            .to_string();
        assert!(err.contains("open"), "{err}");
    }

    #[test]
    fn graph_format_parses() {
        for &f in GraphFormat::ALL {
            assert_eq!(GraphFormat::parse(f.name()), Some(f));
            assert_eq!(GraphFormat::parse_or_err(f.name()).unwrap(), f);
            assert!(GraphFormat::names_joined().contains(f.name()));
        }
        assert_eq!(GraphFormat::parse("mmap"), None);
        // the shared error (CLI flags + TOML key) names every valid spelling
        let err = GraphFormat::parse_or_err("mmap").unwrap_err().to_string();
        for &f in GraphFormat::ALL {
            assert!(err.contains(f.name()), "error '{err}' misses '{}'", f.name());
        }
    }
}
