//! Episode scheduling (paper §3.2, Algorithm 3), generalized to
//! heterogeneous capacity-aware worker pools.
//!
//! For `P` partitions the sample pool redistributes into a `P × P` block
//! grid. A *pool pass* visits every block exactly once, organized as `P`
//! *episode groups*; group `g` is the latin-square diagonal
//! `{(i, (i+g) mod P) | i}` — `P` mutually **orthogonal** blocks (no two
//! share a vertex-partition row or context-partition column), which is
//! what lets the workers run without any inter-worker synchronization.
//!
//! **Capacity-aware waves.** Each worker `i` declares a capacity `c_i`
//! (default 1): the number of diagonal blocks it takes per *wave*. A wave
//! covers `C = Σ c_i` consecutive slots of the diagonal — worker `i` owns
//! the `c_i`-slot run starting at its capacity prefix — so a group is
//! `P / C` waves and worker `i` trains `c_i · P / C` blocks per group,
//! proportional to its capacity. `P` must be a multiple of `C` (the
//! homogeneous `c_i = 1` case degenerates to the paper's "any number of
//! partitions greater than n … in subgroups of n": `C = n`, one block per
//! worker per wave, bitwise the PR-3 schedule). Orthogonality survives
//! the generalization unchanged: the blocks of a wave — indeed of the
//! whole group — are distinct slots of one diagonal, hence pairwise
//! row- and column-disjoint however many of them land on one worker.
//!
//! With the bus-usage optimization (§3.4, `fix_context`) the group is
//! transposed: worker `i` keeps context partition `i` resident and the
//! *vertex* partitions rotate — saving the context transfer entirely.
//!
//! **Residency-aware group ordering** ([`EpisodeSchedule::with_residency_order`]).
//! Groups are mutually independent (each covers a disjoint diagonal of
//! blocks), so any execution order is valid. The slot occupied by a
//! partition in group `g` is a function of `g`, and the slot → worker map
//! is periodic with period `C` (the capacity pattern repeats every wave) —
//! so executing groups in residue classes mod `C` (`0, C, 2C, …, 1,
//! C+1, …`) makes the rotating matrix's partitions return to the *same
//! worker* for every transition inside a class. The transfer engine then
//! keeps them resident and only re-uploads at the `C` class boundaries
//! per pass instead of every group: rotating-partition uploads drop from
//! `P` to `C` per partition per pass (the sticky matrix — `vid = slot`
//! without `fix_context` — never leaves its worker at all). For the
//! homogeneous pool `C = n`, the PR-3 ordering.

/// One block assignment inside an episode group.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Assignment {
    /// Worker (simulated GPU) index executing this block.
    pub worker: usize,
    /// Vertex partition id (row of the grid).
    pub vid: usize,
    /// Context partition id (column of the grid).
    pub cid: usize,
}

/// Static schedule for one pool pass.
#[derive(Debug, Clone)]
pub struct EpisodeSchedule {
    num_parts: usize,
    num_workers: usize,
    fix_context: bool,
    /// Per-worker capacities (blocks per wave); `[1; n]` when the pool is
    /// homogeneous.
    capacities: Vec<usize>,
    /// `Σ capacities` — slots per wave.
    total_capacity: usize,
    /// Owner of wave offset `o` (`slot → worker` is `slot_owner[slot % C]`).
    slot_owner: Vec<usize>,
    /// Group ids in execution order (identity unless residency-ordered).
    group_order: Vec<usize>,
}

impl EpisodeSchedule {
    /// Homogeneous pool: every worker has capacity 1. `num_parts` must be
    /// a multiple of `num_workers` (the paper's "any number of partitions
    /// greater than n … in subgroups of n").
    pub fn new(num_parts: usize, num_workers: usize, fix_context: bool) -> Self {
        assert!(num_workers >= 1);
        Self::with_capacities(num_parts, &vec![1; num_workers], fix_context)
    }

    /// Heterogeneous pool: worker `i` takes `capacities[i]` blocks per
    /// wave. `num_parts` must be a multiple of the total capacity.
    pub fn with_capacities(num_parts: usize, capacities: &[usize], fix_context: bool) -> Self {
        let num_workers = capacities.len();
        assert!(num_parts >= 1 && num_workers >= 1);
        assert!(
            capacities.iter().all(|&c| c >= 1),
            "worker capacities must be >= 1, got {capacities:?}"
        );
        let total_capacity: usize = capacities.iter().sum();
        assert!(
            num_parts % total_capacity == 0,
            "num_parts {num_parts} must be a multiple of the total worker \
             capacity {total_capacity} (capacities {capacities:?})"
        );
        assert!(
            !fix_context || num_parts == num_workers,
            "fix_context requires num_parts == num_workers (paper section 3.4)"
        );
        let slot_owner: Vec<usize> = capacities
            .iter()
            .enumerate()
            .flat_map(|(i, &c)| vec![i; c])
            .collect();
        EpisodeSchedule {
            num_parts,
            num_workers,
            fix_context,
            capacities: capacities.to_vec(),
            total_capacity,
            slot_owner,
            group_order: (0..num_parts).collect(),
        }
    }

    /// Reorder group execution into residue classes mod the total
    /// capacity `C` (`0, C, 2C, …, 1, C+1, …`) so the rotating matrix's
    /// partitions stay sticky to workers inside each class (see the
    /// module docs — the slot → worker map has period `C`). Coverage and
    /// per-group orthogonality are unchanged — groups are independent —
    /// but the training *order* differs, so runs with and without this
    /// ordering are distinct (equally valid) trajectories.
    pub fn with_residency_order(mut self) -> Self {
        let (p, c) = (self.num_parts, self.total_capacity);
        self.group_order = (0..c).flat_map(|r| (0..p / c).map(move |q| q * c + r)).collect();
        self
    }

    /// Group ids in execution order.
    pub fn ordered_groups(&self) -> &[usize] {
        &self.group_order
    }

    pub fn num_parts(&self) -> usize {
        self.num_parts
    }

    pub fn num_workers(&self) -> usize {
        self.num_workers
    }

    /// Per-worker capacities (blocks per wave).
    pub fn capacities(&self) -> &[usize] {
        &self.capacities
    }

    /// Blocks per wave (= `Σ capacities`).
    pub fn total_capacity(&self) -> usize {
        self.total_capacity
    }

    /// Episode groups per pool pass (= `num_parts`).
    pub fn num_groups(&self) -> usize {
        self.num_parts
    }

    /// Waves per group: the diagonal's slots processed `total_capacity`
    /// at a time.
    pub fn waves_per_group(&self) -> usize {
        self.num_parts / self.total_capacity
    }

    /// Blocks worker `i` trains per episode group (∝ its capacity).
    pub fn blocks_per_group(&self, worker: usize) -> usize {
        self.capacities[worker] * self.waves_per_group()
    }

    /// The assignments of episode group `g`, wave `w` — `total_capacity`
    /// blocks, `capacities[i]` of them on worker `i`, in slot order.
    pub fn wave(&self, g: usize, w: usize) -> Vec<Assignment> {
        assert!(g < self.num_groups() && w < self.waves_per_group());
        let p = self.num_parts;
        (0..self.total_capacity)
            .map(|o| {
                let slot = w * self.total_capacity + o; // position within the diagonal
                let worker = self.slot_owner[o];
                if self.fix_context {
                    // context pinned to its slot's worker: cid = slot, vertex rotates
                    let cid = slot;
                    let vid = (slot + g) % p;
                    Assignment { worker, vid, cid }
                } else {
                    let vid = slot;
                    let cid = (slot + g) % p;
                    Assignment { worker, vid, cid }
                }
            })
            .collect()
    }

    /// All waves of group `g` flattened.
    pub fn group(&self, g: usize) -> Vec<Assignment> {
        (0..self.waves_per_group())
            .flat_map(|w| self.wave(g, w))
            .collect()
    }

    /// Every assignment of a full pool pass, in execution order (one
    /// inner Vec per group, groups following [`Self::ordered_groups`]).
    pub fn full_pass(&self) -> Vec<Vec<Assignment>> {
        self.group_order.iter().map(|&g| self.group(g)).collect()
    }

    /// The full pass flattened into dispatch order — the sequence the
    /// coordinator walks every pool pass. The transfer engine derives its
    /// next-toucher (residency) tables from this.
    pub fn execution_sequence(&self) -> Vec<Assignment> {
        self.group_order.iter().flat_map(|&g| self.group(g)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn check_pass(sched: &EpisodeSchedule) {
        let parts = sched.num_parts();
        let mut seen = vec![false; parts * parts];
        for group in sched.full_pass() {
            // orthogonality within a group: distinct rows and columns
            let mut rows = vec![false; parts];
            let mut cols = vec![false; parts];
            for a in &group {
                assert!(!rows[a.vid], "row {} reused in group", a.vid);
                assert!(!cols[a.cid], "col {} reused in group", a.cid);
                rows[a.vid] = true;
                cols[a.cid] = true;
                assert!(!seen[a.vid * parts + a.cid], "block revisited");
                seen[a.vid * parts + a.cid] = true;
            }
            assert_eq!(group.len(), parts);
        }
        assert!(seen.iter().all(|&s| s), "not all blocks covered");
    }

    #[test]
    fn covers_all_blocks_orthogonally() {
        for (parts, workers, fix_context) in
            [(4, 4, false), (4, 4, true), (1, 1, false), (8, 4, false), (6, 2, false)]
        {
            check_pass(&EpisodeSchedule::new(parts, workers, fix_context));
        }
    }

    #[test]
    fn heterogeneous_capacities_cover_all_blocks_orthogonally() {
        for (parts, caps) in [
            (4, vec![1, 3]),
            (8, vec![1, 3]),
            (6, vec![1, 2]),
            (8, vec![2, 2]),
            (12, vec![1, 2, 3]),
            (4, vec![4]),
        ] {
            check_pass(&EpisodeSchedule::with_capacities(parts, &caps, false));
            check_pass(
                &EpisodeSchedule::with_capacities(parts, &caps, false).with_residency_order(),
            );
        }
    }

    #[test]
    fn waves_respect_declared_capacities() {
        let caps = [1usize, 3, 2];
        let s = EpisodeSchedule::with_capacities(12, &caps, false);
        assert_eq!(s.total_capacity(), 6);
        assert_eq!(s.waves_per_group(), 2);
        for g in 0..s.num_groups() {
            for w in 0..s.waves_per_group() {
                let wave = s.wave(g, w);
                assert_eq!(wave.len(), 6);
                for (i, &c) in caps.iter().enumerate() {
                    let got = wave.iter().filter(|a| a.worker == i).count();
                    assert_eq!(got, c, "worker {i} in group {g} wave {w}");
                }
            }
            for (i, &c) in caps.iter().enumerate() {
                assert_eq!(s.blocks_per_group(i), c * 2);
                let got = s.group(g).iter().filter(|a| a.worker == i).count();
                assert_eq!(got, c * 2, "worker {i} blocks in group {g}");
            }
        }
    }

    #[test]
    fn slot_owner_map_is_periodic_and_contiguous() {
        // worker i owns the run of c_i consecutive slots after its
        // capacity prefix, repeating every C slots — the periodicity the
        // residency ordering's stickiness proof relies on
        let s = EpisodeSchedule::with_capacities(10, &[2, 1, 2], false);
        let owners: Vec<usize> = s.wave(0, 0).iter().map(|a| a.worker).collect();
        assert_eq!(owners, vec![0, 0, 1, 2, 2]);
        let next: Vec<usize> = s.wave(0, 1).iter().map(|a| a.worker).collect();
        assert_eq!(next, owners, "owner pattern must repeat every wave");
    }

    #[test]
    fn homogeneous_capacities_match_default_schedule_bitwise() {
        for (p, n, fixc) in [(4, 4, false), (4, 4, true), (8, 4, false), (6, 2, false)] {
            let ones = vec![1usize; n];
            let a = EpisodeSchedule::new(p, n, fixc);
            let b = EpisodeSchedule::with_capacities(p, &ones, fixc);
            assert_eq!(a.execution_sequence(), b.execution_sequence(), "p={p} n={n}");
            let a = a.with_residency_order();
            let b = b.with_residency_order();
            assert_eq!(a.execution_sequence(), b.execution_sequence(), "p={p} n={n} ordered");
        }
    }

    #[test]
    fn fix_context_pins_cid_to_worker() {
        let s = EpisodeSchedule::new(4, 4, true);
        for g in 0..4 {
            for a in s.wave(g, 0) {
                assert_eq!(a.cid, a.worker);
            }
        }
    }

    #[test]
    fn rotating_vid_without_fix_context() {
        let s = EpisodeSchedule::new(4, 4, false);
        for g in 0..4 {
            for a in s.wave(g, 0) {
                assert_eq!(a.vid, a.worker);
                assert_eq!(a.cid, (a.worker + g) % 4);
            }
        }
    }

    #[test]
    fn residency_order_is_a_complete_permutation() {
        for (p, n) in [(4, 2), (6, 2), (8, 4), (4, 4), (1, 1)] {
            let s = EpisodeSchedule::new(p, n, false).with_residency_order();
            let mut seen = s.ordered_groups().to_vec();
            seen.sort_unstable();
            assert_eq!(seen, (0..p).collect::<Vec<_>>(), "p={p} n={n}");
            // coverage survives the reorder: every block visited once
            let mut blocks = vec![false; p * p];
            for a in s.execution_sequence() {
                assert!(!blocks[a.vid * p + a.cid], "block revisited");
                blocks[a.vid * p + a.cid] = true;
            }
            assert!(blocks.iter().all(|&b| b), "p={p} n={n}: not all blocks covered");
        }
        let s = EpisodeSchedule::new(4, 2, false).with_residency_order();
        assert_eq!(s.ordered_groups(), &[0, 2, 1, 3]);
        // square grids (P == n) have singleton residue classes: unchanged
        let s = EpisodeSchedule::new(4, 4, false).with_residency_order();
        assert_eq!(s.ordered_groups(), &[0, 1, 2, 3]);
        // heterogeneous pools order by residue mod the total capacity
        let s = EpisodeSchedule::with_capacities(8, &[1, 3], false).with_residency_order();
        assert_eq!(s.ordered_groups(), &[0, 4, 1, 5, 2, 6, 3, 7]);
    }

    #[test]
    fn residency_order_keeps_contexts_sticky_within_classes() {
        // p=4, n=2, standard schedule: order [0,2,1,3]. For the 0→2
        // transition every context partition must return to the worker
        // that just trained it (that is the whole point of the order).
        let s = EpisodeSchedule::new(4, 2, false).with_residency_order();
        let seq = s.execution_sequence();
        let worker_of = |group_pos: usize, cid: usize| {
            seq[group_pos * 4..(group_pos + 1) * 4]
                .iter()
                .find(|a| a.cid == cid)
                .map(|a| a.worker)
                .unwrap()
        };
        for cid in 0..4 {
            assert_eq!(worker_of(0, cid), worker_of(1, cid), "cid {cid} moved workers");
        }
    }

    #[test]
    fn residency_order_keeps_contexts_sticky_for_heterogeneous_pools() {
        // p=8, capacities [1,3] (C=4): transitions inside a residue class
        // (consecutive ordered groups g and g+C) must keep every context
        // partition on the worker that just trained it.
        let p = 8;
        let s = EpisodeSchedule::with_capacities(p, &[1, 3], false).with_residency_order();
        let seq = s.execution_sequence();
        let worker_of = |group_pos: usize, cid: usize| {
            seq[group_pos * p..(group_pos + 1) * p]
                .iter()
                .find(|a| a.cid == cid)
                .map(|a| a.worker)
                .unwrap()
        };
        // ordered groups: [0,4, 1,5, 2,6, 3,7] — positions (0,1), (2,3),
        // (4,5), (6,7) are the intra-class transitions
        for class in 0..4 {
            for cid in 0..p {
                assert_eq!(
                    worker_of(2 * class, cid),
                    worker_of(2 * class + 1, cid),
                    "class {class}: cid {cid} moved workers"
                );
            }
        }
    }

    #[test]
    fn execution_sequence_matches_full_pass() {
        let s = EpisodeSchedule::new(6, 2, false).with_residency_order();
        let flat: Vec<Assignment> = s.full_pass().into_iter().flatten().collect();
        assert_eq!(flat, s.execution_sequence());
        assert_eq!(flat.len(), 36);
    }

    #[test]
    #[should_panic(expected = "multiple")]
    fn rejects_nondivisible() {
        EpisodeSchedule::new(5, 2, false);
    }

    #[test]
    #[should_panic(expected = "multiple")]
    fn rejects_capacity_nondivisible() {
        // C = 3 does not divide P = 4
        EpisodeSchedule::with_capacities(4, &[2, 1], false);
    }

    #[test]
    #[should_panic(expected = "capacities must be >= 1")]
    fn rejects_zero_capacity() {
        EpisodeSchedule::with_capacities(4, &[0, 4], false);
    }

    #[test]
    #[should_panic(expected = "fix_context")]
    fn rejects_fix_context_with_subgroups() {
        EpisodeSchedule::new(8, 4, true);
    }
}
