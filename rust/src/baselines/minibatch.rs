//! The OpenNE-style mini-batch "GPU" baseline (Table 3's `LINE in OpenNE`
//! row): a deep-learning-framework port of LINE where the embedding
//! matrices live "on device" and every mini-batch round-trips data over
//! the bus. For node embedding the per-batch compute is tiny relative to
//! the parameter traffic, so the system is **bus-bound** — the paper's
//! motivating pathology (§2.2: "even worse than its CPU counterpart").
//!
//! We reproduce the pathology mechanically: each batch copies the full
//! vertex+context matrices into the device buffer, runs the batch update
//! there, and copies them back (what naive `tf.Variable` feeding did),
//! against a single "GPU" (one compute thread).

use anyhow::Result;

use crate::baselines::BaselineResult;
use crate::embedding::EmbeddingStore;
use crate::gpu::native_minibatch_step;
use crate::graph::Graph;
use crate::metrics::TrainStats;
use crate::sampling::{AliasTable, EdgeSampler};
use crate::util::rng::Rng;
use crate::util::timer::Stopwatch;

#[derive(Debug, Clone)]
pub struct MinibatchConfig {
    pub dim: usize,
    pub epochs: usize,
    pub batch_size: usize,
    pub lr: f32,
    pub negatives: usize,
    pub neg_weight: f32,
    pub seed: u64,
}

impl Default for MinibatchConfig {
    fn default() -> Self {
        MinibatchConfig {
            dim: 64,
            epochs: 10,
            batch_size: 256,
            lr: 0.025,
            negatives: 1,
            neg_weight: 5.0,
            seed: 42,
        }
    }
}

pub struct MinibatchGpuBaseline;

impl MinibatchGpuBaseline {
    pub fn train(graph: &Graph, cfg: &MinibatchConfig) -> Result<BaselineResult> {
        let mut prep = Stopwatch::started();
        let sampler = EdgeSampler::new(graph);
        let neg_weights: Vec<f32> = (0..graph.num_nodes() as u32)
            .map(|v| graph.weighted_degree(v).max(1e-12).powf(0.75))
            .collect();
        let neg_table = AliasTable::new(&neg_weights);
        prep.stop();

        let mut train_sw = Stopwatch::started();
        let n = graph.num_nodes();
        let dim = cfg.dim;
        let store = EmbeddingStore::init(n, dim, cfg.seed);
        // "host" copies of the parameters
        let mut host_vertex = store.vertex_matrix().to_vec();
        let mut host_context = store.context_matrix().to_vec();
        // "device" buffers
        let mut dev_vertex = vec![0f32; n * dim];
        let mut dev_context = vec![0f32; n * dim];
        let (mut grad_u, mut grad_c) = (Vec::new(), Vec::new());

        let total = (cfg.epochs * graph.num_edges()) as u64;
        let mut rng = Rng::new(cfg.seed);
        let mut done = 0u64;
        let mut bytes_moved = 0u64;
        let bsz = cfg.batch_size;
        let mut pos_u = vec![0i32; bsz];
        let mut pos_v = vec![0i32; bsz];
        let mut neg_v = vec![0i32; bsz * cfg.negatives];
        while done < total {
            for i in 0..bsz {
                let (u, v) = sampler.sample(&mut rng);
                pos_u[i] = u as i32;
                pos_v[i] = v as i32;
            }
            for nv in neg_v.iter_mut() {
                *nv = neg_table.sample(&mut rng) as i32;
            }
            // the pathological part: full-matrix bus transfer per batch
            dev_vertex.copy_from_slice(&host_vertex);
            dev_context.copy_from_slice(&host_context);
            bytes_moved += 2 * (n * dim * 4) as u64;

            let lr = cfg.lr * (1.0 - done as f32 / total as f32).max(1e-4);
            native_minibatch_step(
                &mut dev_vertex,
                &mut dev_context,
                dim,
                &pos_u,
                &pos_v,
                &neg_v,
                cfg.negatives,
                lr,
                cfg.neg_weight,
                &mut grad_u,
                &mut grad_c,
            );

            host_vertex.copy_from_slice(&dev_vertex);
            host_context.copy_from_slice(&dev_context);
            bytes_moved += 2 * (n * dim * 4) as u64;
            done += bsz as u64;
        }
        train_sw.stop();

        let mut stats = TrainStats {
            train_secs: train_sw.secs(),
            preprocess_secs: prep.secs(),
            ..Default::default()
        };
        stats.counters.samples_trained = done;
        stats.counters.bytes_to_device = bytes_moved / 2;
        stats.counters.bytes_from_device = bytes_moved / 2;
        Ok(BaselineResult {
            embeddings: EmbeddingStore::from_raw(n, dim, host_vertex, host_context),
            stats,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;

    #[test]
    fn minibatch_trains_but_moves_mountains_of_bytes() {
        let g = generators::barabasi_albert(200, 3, 1);
        let cfg = MinibatchConfig { dim: 8, epochs: 1, batch_size: 64, ..Default::default() };
        let r = MinibatchGpuBaseline::train(&g, &cfg).unwrap();
        assert!(r.stats.counters.samples_trained >= g.num_edges() as u64);
        // bytes moved per trained sample should dwarf the embedding size —
        // the bus-bound pathology
        let per_sample = (r.stats.counters.bytes_to_device
            + r.stats.counters.bytes_from_device) as f64
            / r.stats.counters.samples_trained as f64;
        assert!(per_sample > (8 * 4) as f64 * 10.0, "per_sample {per_sample}");
    }
}
