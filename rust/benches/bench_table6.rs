//! Regenerates paper Table 6 — ablation of online augmentation, parallel negative sampling and the collaboration strategy.
//!
//! Run with `cargo bench --bench bench_table6`; set
//! GRAPHVITE_BENCH_SCALE=tiny|small|full to change the workload size
//! (default tiny so `cargo bench` completes quickly; EXPERIMENTS.md
//! records the `small` runs).

fn scale() -> graphvite::experiments::Scale {
    std::env::var("GRAPHVITE_BENCH_SCALE")
        .ok()
        .and_then(|s| graphvite::experiments::Scale::parse(&s))
        .unwrap_or(graphvite::experiments::Scale::Tiny)
}

fn main() {
    graphvite::experiments::run("table6", scale()).expect("table6 experiment");
}
