//! Node classification — the paper's Table 4 workload shape: train
//! embeddings on a labelled scale-free community graph (the YouTube
//! substitute), then fit one-vs-rest logistic classifiers on 1%..10%
//! labelled nodes and report micro/macro F1 per row.
//!
//!     cargo run --release --example node_classification [nodes]

use graphvite::experiments::classify;
use graphvite::prelude::*;
use graphvite::util::bench::Table;

fn main() -> anyhow::Result<()> {
    let nodes: usize = std::env::args()
        .nth(1)
        .map(|s| s.parse())
        .transpose()?
        .unwrap_or(5_000);
    let num_labels = 10;
    let graph = generators::youtube_like(nodes, num_labels, 0xCAFE);
    println!(
        "youtube-like graph: {} nodes, {} edges, {} label classes",
        graph.num_nodes(),
        graph.num_edges(),
        num_labels
    );

    let config = TrainConfig {
        dim: 32,
        epochs: 200,
        num_workers: 4,
        num_samplers: 4,
        episode_size: (nodes / 2).max(4_000),
        backend: BackendKind::Native,
        ..TrainConfig::default()
    };
    let mut trainer = Trainer::new(graph.clone(), config)?;
    let result = trainer.train()?;
    println!(
        "trained in {:.2}s ({:.2}M samples/s)",
        result.stats.train_secs,
        result.stats.throughput() / 1e6
    );

    let mut table = Table::new(
        "node classification (paper Table 4 shape)",
        &["% labeled", "micro-F1", "macro-F1"],
    );
    for pct in [1, 2, 4, 6, 8, 10] {
        let frac = pct as f64 / 100.0;
        let report = classify(&result.embeddings, &graph, frac, 7 + pct as u64);
        table.row(&[
            format!("{pct}%"),
            format!("{:.2}%", 100.0 * report.micro_f1),
            format!("{:.2}%", 100.0 * report.macro_f1),
        ]);
    }
    table.print();
    println!(
        "(expect F1 to rise with % labeled and sit well above the 1/{num_labels} chance line)"
    );
    Ok(())
}
