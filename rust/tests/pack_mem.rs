//! Bounded-memory packing: `pack_edge_list` must never materialize the
//! CSR it is building. A counting global allocator measures the peak
//! resident heap across the pack and asserts it stays within the
//! configured `--pack-mem-bytes` budget plus the documented O(V)
//! ledgers. This file holds exactly ONE test: the allocator is
//! process-global, so any concurrently running test would pollute the
//! peak measurement.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicIsize, Ordering};

use graphvite::graph::{self, generators, PackOptions, ReorderKind};

struct CountingAlloc;

static CURRENT: AtomicIsize = AtomicIsize::new(0);
static PEAK: AtomicIsize = AtomicIsize::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        let p = System.alloc(layout);
        if !p.is_null() {
            let size = layout.size() as isize;
            let cur = CURRENT.fetch_add(size, Ordering::Relaxed) + size;
            PEAK.fetch_max(cur, Ordering::Relaxed);
        }
        p
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout);
        CURRENT.fetch_sub(layout.size() as isize, Ordering::Relaxed);
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        let p = System.realloc(ptr, layout, new_size);
        if !p.is_null() {
            let delta = new_size as isize - layout.size() as isize;
            let cur = CURRENT.fetch_add(delta, Ordering::Relaxed) + delta;
            PEAK.fetch_max(cur, Ordering::Relaxed);
        }
        p
    }
}

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc;

#[test]
fn pack_edge_list_peak_memory_is_bounded_by_the_budget() {
    // ~200k edges / ~400k arcs: a resident CSR would need several MiB,
    // an order of magnitude over the budget asserted below. The input is
    // written BEFORE the measured window.
    let n: usize = 10_000;
    let g = generators::barabasi_albert(n, 20, 42);
    let dir = std::env::temp_dir().join("graphvite_pack_mem_test");
    std::fs::create_dir_all(&dir).unwrap();
    let listing = dir.join("ba.txt");
    graph::save_edge_list(&g, &listing).unwrap();
    let arcs = g.num_arcs();
    drop(g);

    // allowance: the spill/merge budget itself, the writer's O(V)
    // ledgers (offsets u64 + degrees u32 + wdegrees f32 + sidecar
    // vectors, generously 64 B/node), and fixed allocator/buffer slack
    let budget = 256 * 1024usize;
    let ledgers = 64 * n;
    let slack = 1 << 20;

    let baseline = CURRENT.load(Ordering::Relaxed);
    PEAK.store(baseline, Ordering::Relaxed);
    let out = dir.join("ba.gvpk");
    let stats = graph::pack_edge_list(
        &listing,
        &out,
        &PackOptions { mem_bytes: budget, ..Default::default() },
    )
    .unwrap();
    let peak = PEAK.load(Ordering::Relaxed);
    assert_eq!(stats.num_arcs, arcs, "pack dropped arcs");
    let delta = (peak - baseline).max(0) as usize;
    assert!(
        delta <= budget + ledgers + slack,
        "pack peak {delta} B over budget {budget} + ledgers {ledgers} + slack {slack}"
    );

    // the two-pass reorder path must stay bounded as well: the unordered
    // intermediate is reopened as a *paged* store whose cache reuses the
    // budget, so the allowance is two budgets (merge buffers have been
    // freed by then, but the page cache and the BFS state coexist with
    // the second writer's ledgers)
    let baseline = CURRENT.load(Ordering::Relaxed);
    PEAK.store(baseline, Ordering::Relaxed);
    let out_bfs = dir.join("ba_bfs.gvpk");
    let stats = graph::pack_edge_list(
        &listing,
        &out_bfs,
        &PackOptions { mem_bytes: budget, reorder: ReorderKind::Bfs, ..Default::default() },
    )
    .unwrap();
    let peak = PEAK.load(Ordering::Relaxed);
    assert_eq!(stats.num_arcs, arcs, "reorder pack dropped arcs");
    let delta = (peak - baseline).max(0) as usize;
    assert!(
        delta <= 2 * budget + 2 * ledgers + slack,
        "reorder pack peak {delta} B over 2x budget {budget} + ledgers + slack"
    );
}
