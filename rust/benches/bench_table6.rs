//! Regenerates paper Table 6 — ablation of online augmentation, parallel negative sampling and the collaboration strategy.
//!
//! Run with `cargo bench --bench bench_table6`; set
//! GRAPHVITE_BENCH_SCALE=tiny|small|full to change the workload size
//! (default tiny so `cargo bench` completes quickly; EXPERIMENTS.md
//! records the `small` runs).

fn main() {
    graphvite::experiments::run("table6", graphvite::experiments::Scale::from_env())
        .expect("table6 experiment");
}
