//! Table 8 — hardware-configuration sensitivity. The paper contrasts a
//! Tesla-P100 server with an economic GTX-1080 server; our substitution
//! contrasts a "fast" device configuration (larger batch shapes, more
//! sampler threads — high-end GPU analogue) with an "economic" one
//! (smaller batches, half the samplers). Shape: the gap stays well under
//! 2x, i.e. the system is not tied to top-end hardware.

use anyhow::Result;

use crate::coordinator::Trainer;
use crate::experiments::presets::{Scale, Workload};
use crate::util::bench::Table;
use crate::util::human_secs;

pub fn run(scale: Scale) -> Result<()> {
    let w = Workload::youtube_like(scale);
    let mut table = Table::new(
        "Table 8 — training time under different hardware configurations",
        &["hardware analogue", "CPU threads", "workers", "train time"],
    );

    // (name, batch, samplers per worker)
    let configs: Vec<(&str, usize, usize)> =
        vec![("fast server (P100-like)", 1024, 2), ("economic server (GTX1080-like)", 128, 1)];
    for (name, batch, samplers_per) in configs {
        for workers in [1usize, 4] {
            let mut cfg = w.config.clone();
            cfg.num_workers = workers;
            cfg.num_samplers = (samplers_per * workers).max(1);
            cfg.batch_size = batch;
            let total_threads = cfg.num_samplers + workers;
            let mut trainer = Trainer::new(w.graph.clone(), cfg)?;
            let r = trainer.train()?;
            table.row(&[
                name.into(),
                format!("{total_threads}"),
                format!("{workers}"),
                human_secs(r.stats.train_secs),
            ]);
        }
    }
    table.print();
    Ok(())
}
