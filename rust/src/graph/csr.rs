//! Compressed-sparse-row graph storage.
//!
//! This is the in-memory network the CPU side (parallel online
//! augmentation) random-walks over: contiguous adjacency for cache-friendly
//! neighbor scans, plus weighted degrees for the departure-node and
//! negative-sampling distributions.

/// An undirected weighted graph in CSR form. Node ids are dense `u32`.
#[derive(Debug, Clone)]
pub struct Graph {
    /// CSR row offsets; length `n + 1`.
    offsets: Vec<u64>,
    /// Flattened neighbor lists; length = 2 * undirected edge count.
    targets: Vec<u32>,
    /// Per-adjacency edge weights, parallel to `targets`.
    weights: Vec<f32>,
    /// Weighted degree per node (sum of incident weights).
    degrees: Vec<f32>,
    /// Optional single-label community assignment (SBM generator / loader).
    labels: Option<Vec<u16>>,
    /// True if every weight is exactly 1.0 (enables uniform fast paths).
    unit_weights: bool,
}

impl Graph {
    pub(crate) fn from_parts(
        offsets: Vec<u64>,
        targets: Vec<u32>,
        weights: Vec<f32>,
        labels: Option<Vec<u16>>,
    ) -> Self {
        debug_assert_eq!(targets.len(), weights.len());
        let n = offsets.len() - 1;
        let mut degrees = vec![0.0f32; n];
        for v in 0..n {
            let (s, e) = (offsets[v] as usize, offsets[v + 1] as usize);
            degrees[v] = weights[s..e].iter().sum();
        }
        let unit_weights = weights.iter().all(|&w| w == 1.0);
        if let Some(l) = &labels {
            assert_eq!(l.len(), n, "label vector length must match node count");
        }
        Graph { offsets, targets, weights, degrees, labels, unit_weights }
    }

    /// Number of nodes.
    #[inline]
    pub fn num_nodes(&self) -> usize {
        self.offsets.len() - 1
    }

    /// Number of *undirected* edges (adjacency entries / 2).
    #[inline]
    pub fn num_edges(&self) -> usize {
        self.targets.len() / 2
    }

    /// Total adjacency entries (directed arc count).
    #[inline]
    pub fn num_arcs(&self) -> usize {
        self.targets.len()
    }

    /// Neighbors of `v` as a slice of target node ids.
    #[inline]
    pub fn neighbors(&self, v: u32) -> &[u32] {
        let (s, e) = self.span(v);
        &self.targets[s..e]
    }

    /// Weights parallel to [`Self::neighbors`].
    #[inline]
    pub fn neighbor_weights(&self, v: u32) -> &[f32] {
        let (s, e) = self.span(v);
        &self.weights[s..e]
    }

    #[inline]
    fn span(&self, v: u32) -> (usize, usize) {
        (self.offsets[v as usize] as usize, self.offsets[v as usize + 1] as usize)
    }

    /// Unweighted out-degree of `v`.
    #[inline]
    pub fn degree(&self, v: u32) -> usize {
        let (s, e) = self.span(v);
        e - s
    }

    /// Weighted degree of `v`.
    #[inline]
    pub fn weighted_degree(&self, v: u32) -> f32 {
        self.degrees[v as usize]
    }

    /// All weighted degrees.
    #[inline]
    pub fn weighted_degrees(&self) -> &[f32] {
        &self.degrees
    }

    /// True if all edge weights are 1.0.
    #[inline]
    pub fn unit_weights(&self) -> bool {
        self.unit_weights
    }

    /// Community labels, if the graph carries them.
    pub fn labels(&self) -> Option<&[u16]> {
        self.labels.as_deref()
    }

    pub fn set_labels(&mut self, labels: Vec<u16>) {
        assert_eq!(labels.len(), self.num_nodes());
        self.labels = Some(labels);
    }

    /// Iterate all arcs as (source, target, weight).
    pub fn arcs(&self) -> impl Iterator<Item = (u32, u32, f32)> + '_ {
        (0..self.num_nodes() as u32).flat_map(move |v| {
            let (s, e) = self.span(v);
            (s..e).map(move |i| (v, self.targets[i], self.weights[i]))
        })
    }

    /// Iterate each undirected edge once (u <= v ordering).
    pub fn edges(&self) -> impl Iterator<Item = (u32, u32, f32)> + '_ {
        self.arcs().filter(|&(u, v, _)| u <= v)
    }

    /// True if `u`–`v` are adjacent (linear scan; test/eval helper).
    pub fn has_edge(&self, u: u32, v: u32) -> bool {
        self.neighbors(u).contains(&v)
    }
}

#[cfg(test)]
mod tests {
    use crate::graph::GraphBuilder;

    #[test]
    fn csr_roundtrip_triangle() {
        let g = GraphBuilder::new()
            .add_edge(0, 1, 1.0)
            .add_edge(1, 2, 1.0)
            .add_edge(0, 2, 1.0)
            .build();
        assert_eq!(g.num_nodes(), 3);
        assert_eq!(g.num_edges(), 3);
        assert_eq!(g.num_arcs(), 6);
        for v in 0..3u32 {
            assert_eq!(g.degree(v), 2);
            assert_eq!(g.weighted_degree(v), 2.0);
        }
        assert!(g.has_edge(0, 1) && g.has_edge(1, 0));
        assert!(g.unit_weights());
    }

    #[test]
    fn weighted_degrees() {
        let g = GraphBuilder::new()
            .add_edge(0, 1, 2.0)
            .add_edge(0, 2, 3.0)
            .build();
        assert_eq!(g.weighted_degree(0), 5.0);
        assert_eq!(g.weighted_degree(1), 2.0);
        assert!(!g.unit_weights());
    }

    #[test]
    fn edges_iterates_each_once() {
        let g = GraphBuilder::new()
            .add_edge(0, 1, 1.0)
            .add_edge(1, 2, 1.0)
            .build();
        let edges: Vec<_> = g.edges().collect();
        assert_eq!(edges.len(), 2);
    }

    #[test]
    fn isolated_nodes_allowed() {
        let g = GraphBuilder::new().with_num_nodes(5).add_edge(0, 1, 1.0).build();
        assert_eq!(g.num_nodes(), 5);
        assert_eq!(g.degree(4), 0);
        assert_eq!(g.neighbors(4), &[] as &[u32]);
    }
}
