//! Graph substrate: CSR storage, edge-list I/O, synthetic generators and
//! degree statistics.
//!
//! GraphVite treats all networks as undirected weighted graphs
//! (paper section 4.3); [`GraphBuilder`] symmetrizes edges on construction.

mod builder;
mod csr;
pub mod generators;
mod loader;
mod stats;

pub use builder::GraphBuilder;
pub use csr::Graph;
pub use loader::{load_edge_list, save_edge_list};
pub use stats::{degree_histogram, GraphStats};
