//! Locality-aware node reordering — the webgraph BFS-permutation trick.
//!
//! Random walks step between neighbors; if neighbors sit on the same
//! successor page of a packed graph, the walk's page-cache hit rate
//! tracks the graph's *label locality* instead of whatever order the
//! edge list happened to arrive in. A BFS traversal renumbers nodes so
//! that each node's neighborhood occupies a contiguous id range, which
//! (a) shrinks the zigzag gaps the packer varint-encodes and (b) turns
//! walk steps into near-neighbor page accesses. `graphvite reorder` (or
//! `pack --reorder bfs`) computes the permutation and repacks; the
//! permutation is stored in the `.gvpk` itself (the `perm` sidecar, new
//! in format v2) so external node ids round-trip through `eval`/`serve`.
//!
//! Everything here is O(V) resident: the traversal streams successor
//! lists through the [`GraphStore`] seam, so reordering an out-of-core
//! graph never materializes its CSR.

use super::{Graph, GraphStore};

/// Which permutation `pack`/`reorder` apply (`--reorder`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ReorderKind {
    /// Keep the input ids (the default).
    #[default]
    None,
    /// Deterministic breadth-first renumbering (see [`bfs_order`]).
    Bfs,
}

impl ReorderKind {
    pub const ALL: &'static [ReorderKind] = &[Self::None, Self::Bfs];

    pub fn parse(s: &str) -> Option<Self> {
        Self::ALL.iter().copied().find(|k| k.name() == s)
    }

    pub fn parse_or_err(s: &str) -> anyhow::Result<Self> {
        Self::parse(s).ok_or_else(|| {
            anyhow::anyhow!(
                "unknown reorder kind '{s}' (expected one of: {})",
                Self::names_joined()
            )
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            Self::None => "none",
            Self::Bfs => "bfs",
        }
    }

    /// `"none|bfs"` — for usage lines and error messages.
    pub fn names_joined() -> String {
        let names: Vec<&str> = Self::ALL.iter().map(|k| k.name()).collect();
        names.join("|")
    }
}

/// Deterministic BFS permutation of `store`: returns `order`, where
/// `order[new_id] = old_id` (length `num_nodes`, a bijection).
///
/// The traversal starts at the highest-degree node (lowest id on ties) —
/// hubs and their neighborhoods get the smallest ids, which is where
/// degree-weighted walks spend their time — visits neighbors in
/// adjacency order, and restarts at the lowest-id unvisited node for
/// every further component (isolated nodes end up last, in id order).
/// Same graph, same order, always: the permutation feeds bitwise-
/// reproducible training.
pub fn bfs_order(store: &dyn GraphStore) -> Vec<u32> {
    let n = store.num_nodes();
    let mut order: Vec<u32> = Vec::with_capacity(n);
    let mut visited = vec![false; n];
    if n == 0 {
        return order;
    }
    let mut queue = std::collections::VecDeque::new();
    let mut nbrs: Vec<u32> = Vec::new();
    // primary root: max degree, ties to the lowest id
    let root = (0..n)
        .max_by_key(|&v| (store.degree(v as u32), std::cmp::Reverse(v)))
        .unwrap() as u32;
    visited[root as usize] = true;
    queue.push_back(root);
    let mut next_unvisited = 0usize;
    loop {
        while let Some(v) = queue.pop_front() {
            order.push(v);
            store.successors_into(v, &mut nbrs);
            for &t in &nbrs {
                if !visited[t as usize] {
                    visited[t as usize] = true;
                    queue.push_back(t);
                }
            }
        }
        while next_unvisited < n && visited[next_unvisited] {
            next_unvisited += 1;
        }
        if next_unvisited == n {
            break;
        }
        visited[next_unvisited] = true;
        queue.push_back(next_unvisited as u32);
    }
    debug_assert_eq!(order.len(), n);
    order
}

/// Invert a permutation: `inv[order[new]] = new` (`old_id -> new_id`).
pub fn invert_order(order: &[u32]) -> Vec<u32> {
    let mut inv = vec![0u32; order.len()];
    for (new, &old) in order.iter().enumerate() {
        inv[old as usize] = new as u32;
    }
    inv
}

/// Relabel `graph` through `order` (`order[new_id] = old_id`): node
/// `order[i]` of the input becomes node `i` of the output, every target
/// id is mapped, rows re-sorted by (new) target, labels permuted.
///
/// The in-RAM counterpart of the streaming repack in
/// [`super::ondisk::pack_store`] — both must produce identical rows
/// (asserted in `rust/tests/reorder.rs`), because the RAM-vs-paged
/// bitwise training equivalence extends to reordered graphs.
pub fn relabel(graph: &Graph, order: &[u32]) -> Graph {
    let n = graph.num_nodes();
    assert_eq!(order.len(), n, "permutation length must match node count");
    let old_to_new = invert_order(order);
    let mut offsets: Vec<u64> = Vec::with_capacity(n + 1);
    let mut targets: Vec<u32> = Vec::with_capacity(graph.num_arcs());
    let mut weights: Vec<f32> = Vec::with_capacity(graph.num_arcs());
    offsets.push(0);
    let mut row: Vec<(u32, f32)> = Vec::new();
    for &old in order {
        row.clear();
        row.extend(
            graph
                .neighbors(old)
                .iter()
                .map(|&t| old_to_new[t as usize])
                .zip(graph.neighbor_weights(old).iter().copied()),
        );
        // new target ids are unique within a row (order is a bijection),
        // so the unstable sort is deterministic
        row.sort_unstable_by_key(|&(t, _)| t);
        for &(t, w) in &row {
            targets.push(t);
            weights.push(w);
        }
        offsets.push(targets.len() as u64);
    }
    let labels = graph
        .labels()
        .map(|l| order.iter().map(|&old| l[old as usize]).collect());
    Graph::from_parts(offsets, targets, weights, labels)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{generators, GraphBuilder};

    #[test]
    fn reorder_kind_parses() {
        for &k in ReorderKind::ALL {
            assert_eq!(ReorderKind::parse(k.name()), Some(k));
        }
        assert_eq!(ReorderKind::parse("llp"), None);
        let err = ReorderKind::parse_or_err("llp").unwrap_err().to_string();
        for &k in ReorderKind::ALL {
            assert!(err.contains(k.name()), "error '{err}' misses '{}'", k.name());
        }
    }

    #[test]
    fn bfs_order_is_a_permutation_rooted_at_the_hub() {
        let g = generators::karate_club();
        let order = bfs_order(&g);
        assert_eq!(order.len(), 34);
        let mut seen = vec![false; 34];
        for &v in &order {
            assert!(!seen[v as usize], "duplicate {v}");
            seen[v as usize] = true;
        }
        // node 33 has the highest degree (17) in the karate club
        assert_eq!(order[0], 33);
        // deterministic
        assert_eq!(order, bfs_order(&g));
    }

    #[test]
    fn disconnected_components_and_isolated_nodes_are_covered() {
        // two triangles + trailing isolated nodes
        let mut b = GraphBuilder::new().with_num_nodes(9);
        for (u, v) in [(0, 1), (1, 2), (0, 2), (4, 5), (5, 6), (4, 6)] {
            b.push_edge(u, v, 1.0);
        }
        let g = b.build();
        let order = bfs_order(&g);
        assert_eq!(order.len(), 9);
        let inv = invert_order(&order);
        assert_eq!(inv.len(), 9);
        for (new, &old) in order.iter().enumerate() {
            assert_eq!(inv[old as usize] as usize, new);
        }
        // isolated nodes (3, 7, 8) come after both components, in id order
        assert_eq!(&order[6..], &[3, 7, 8]);
    }

    #[test]
    fn relabel_preserves_the_graph_up_to_renaming() {
        let g = generators::planted_partition(120, 3, 8.0, 0.1, 5);
        let order = bfs_order(&g);
        let inv = invert_order(&order);
        let r = relabel(&g, &order);
        assert_eq!(r.num_nodes(), g.num_nodes());
        assert_eq!(r.num_edges(), g.num_edges());
        assert_eq!(r.unit_weights(), g.unit_weights());
        for old in 0..g.num_nodes() as u32 {
            let new = inv[old as usize];
            assert_eq!(r.degree(new), g.degree(old), "degree of {old}");
            assert_eq!(
                r.weighted_degree(new).to_bits(),
                g.weighted_degree(old).to_bits(),
                "weighted degree of {old}"
            );
            // the relabeled neighbor set is the mapped original set
            let mut want: Vec<u32> =
                g.neighbors(old).iter().map(|&t| inv[t as usize]).collect();
            want.sort_unstable();
            assert_eq!(r.neighbors(new), want.as_slice(), "neighbors of {old}");
            assert_eq!(
                r.labels().unwrap()[new as usize],
                g.labels().unwrap()[old as usize],
                "label of {old}"
            );
        }
    }

    #[test]
    fn permute_then_unpermute_is_the_identity() {
        let g = generators::barabasi_albert(150, 3, 12);
        let order = bfs_order(&g);
        let forward = relabel(&g, &order);
        // undo: the inverse permutation's order vector is inv itself
        let back = relabel(&forward, &invert_order(&order));
        assert_eq!(back.num_nodes(), g.num_nodes());
        for v in 0..g.num_nodes() as u32 {
            assert_eq!(back.neighbors(v), g.neighbors(v), "node {v}");
            let got: Vec<u32> =
                back.neighbor_weights(v).iter().map(|x| x.to_bits()).collect();
            let want: Vec<u32> =
                g.neighbor_weights(v).iter().map(|x| x.to_bits()).collect();
            assert_eq!(got, want, "weights of {v}");
        }
    }
}
