//! Shared experiment workloads and scale presets.
//!
//! The paper's datasets are substituted by synthetic analogues
//! (DESIGN.md): a labelled "YouTube-like" graph for classification
//! experiments and BA scale-free graphs for timing/scaling. `Scale`
//! shrinks everything so the full suite runs on this machine: `Tiny` for
//! CI smoke, `Small` for the recorded EXPERIMENTS.md runs, `Full` for the
//! largest runs the box can take.

use crate::config::TrainConfig;
use crate::graph::{generators, Graph};
use crate::pool::ShuffleKind;

/// Workload scale preset.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Scale {
    Tiny,
    Small,
    Full,
}

impl Scale {
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "tiny" => Some(Scale::Tiny),
            "small" => Some(Scale::Small),
            "full" => Some(Scale::Full),
            _ => None,
        }
    }

    /// Inverse of [`Self::parse`] — the tag the self-recording bench
    /// targets put in `BENCH_<target>_<scale>.json` filenames.
    pub fn name(&self) -> &'static str {
        match self {
            Scale::Tiny => "tiny",
            Scale::Small => "small",
            Scale::Full => "full",
        }
    }

    /// Scale from the `GRAPHVITE_BENCH_SCALE` env var (`tiny` when unset
    /// or unrecognized) — the single parser shared by every bench target.
    pub fn from_env() -> Self {
        std::env::var("GRAPHVITE_BENCH_SCALE")
            .ok()
            .and_then(|s| Self::parse(&s))
            .unwrap_or(Scale::Tiny)
    }

    /// Nodes of the "YouTube-like" classification graph at this scale.
    pub fn youtube_nodes(&self) -> usize {
        match self {
            Scale::Tiny => 2_000,
            Scale::Small => 20_000,
            Scale::Full => 100_000,
        }
    }

    /// Epochs for classification-quality experiments. The paper trains
    /// 4000 epochs on YouTube (section 4.3); sparse graphs genuinely need a
    /// large multiple of |E| samples before communities crystallize —
    /// under ~100 epochs the embeddings sit at chance-level F1.
    pub fn epochs(&self) -> usize {
        match self {
            Scale::Tiny => 100,
            Scale::Small => 200,
            Scale::Full => 400,
        }
    }
}

/// A named experiment workload: graph + matched train config.
pub struct Workload {
    pub name: &'static str,
    pub graph: Graph,
    pub config: TrainConfig,
    pub num_labels: usize,
}

impl Workload {
    /// The YouTube substitute: scale-free + 47 planted communities
    /// (the paper's YouTube has 47 label classes).
    pub fn youtube_like(scale: Scale) -> Workload {
        let n = scale.youtube_nodes();
        let num_labels = 10; // enough classes for stable macro-F1 at our n
        let graph = generators::youtube_like(n, num_labels, 0xCAFE);
        let config = TrainConfig {
            dim: 32,
            epochs: scale.epochs(),
            walk_length: 5,
            augmentation_distance: 2,
            num_workers: 4,
            num_samplers: 4,
            episode_size: (n / 2).max(4_000),
            batch_size: 512,
            shuffle: ShuffleKind::Pseudo,
            ..TrainConfig::default()
        };
        Workload { name: "youtube-like", graph, config, num_labels }
    }

    /// Pure BA scale-free graph for timing experiments (no labels needed).
    pub fn scale_free(nodes: usize, edges_per_node: usize, seed: u64) -> Graph {
        generators::barabasi_albert(nodes, edges_per_node, seed)
    }
}

/// Evaluate node-classification micro/macro F1 at `frac` labelled nodes,
/// matching the paper's protocol (normalized embeddings, OvR logreg).
/// Features are mean-centered first — see
/// [`EmbeddingStore::centered_normalized_vertex`](crate::embedding::EmbeddingStore::centered_normalized_vertex)
/// for why.
pub fn classify(
    store: &crate::embedding::EmbeddingStore,
    graph: &Graph,
    frac: f64,
    seed: u64,
) -> crate::eval::NodeClassificationReport {
    let labels = graph.labels().expect("graph has labels");
    let num_classes = labels.iter().copied().max().unwrap_or(0) as usize + 1;
    let features = store.centered_normalized_vertex();
    let (train, test) = crate::eval::train_test_split(graph.num_nodes(), frac, seed);
    let model = crate::eval::LogisticOvR::fit(
        &features,
        store.dim(),
        labels,
        &train,
        num_classes,
        15,
        0.5,
        1e-4,
        seed ^ 0x5EED,
    );
    model.evaluate(&features, labels, &test)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scale_parse() {
        assert_eq!(Scale::parse("tiny"), Some(Scale::Tiny));
        assert_eq!(Scale::parse("bogus"), None);
    }

    #[test]
    fn youtube_like_workload_valid() {
        let w = Workload::youtube_like(Scale::Tiny);
        assert_eq!(w.graph.num_nodes(), 2_000);
        assert!(w.graph.labels().is_some());
        w.config.validate().unwrap();
    }
}
