//! Embedding storage: the `vertex` and `context` matrices living in main
//! memory (paper Table 1 — at 50M nodes they are 23.8 GB each, which is
//! why they cannot live on any single GPU and must be partitioned).
//!
//! Provides word2vec-style initialization, partition gather/scatter (the
//! host side of the per-episode transfers) and binary/text persistence.

mod io;

pub use io::{
    load_embeddings, load_embeddings_auto, load_embeddings_gvemb, load_embeddings_text,
    save_embeddings, save_embeddings_binary, save_embeddings_gvemb, save_embeddings_text,
    OutputFormat,
};

use crate::partition::Partitioning;
use crate::util::rng::Rng;

/// Dense row-major `num_nodes × dim` matrix pair.
#[derive(Debug, Clone)]
pub struct EmbeddingStore {
    num_nodes: usize,
    dim: usize,
    vertex: Vec<f32>,
    context: Vec<f32>,
}

impl EmbeddingStore {
    /// word2vec-style init: vertex ~ U[-0.5/d, 0.5/d), context = 0
    /// (LINE/DeepWalk both use this asymmetric init).
    pub fn init(num_nodes: usize, dim: usize, seed: u64) -> Self {
        let mut rng = Rng::new(seed);
        let bound = 0.5 / dim as f32;
        let vertex = (0..num_nodes * dim)
            .map(|_| rng.range_f32(-bound, bound))
            .collect();
        let context = vec![0.0; num_nodes * dim];
        EmbeddingStore { num_nodes, dim, vertex, context }
    }

    /// Construct from raw matrices (loader / tests).
    pub fn from_raw(num_nodes: usize, dim: usize, vertex: Vec<f32>, context: Vec<f32>) -> Self {
        assert_eq!(vertex.len(), num_nodes * dim);
        assert_eq!(context.len(), num_nodes * dim);
        EmbeddingStore { num_nodes, dim, vertex, context }
    }

    #[inline]
    pub fn num_nodes(&self) -> usize {
        self.num_nodes
    }

    #[inline]
    pub fn dim(&self) -> usize {
        self.dim
    }

    /// Vertex embedding of node `v`.
    #[inline]
    pub fn vertex(&self, v: u32) -> &[f32] {
        let d = self.dim;
        &self.vertex[v as usize * d..(v as usize + 1) * d]
    }

    #[inline]
    pub fn context(&self, v: u32) -> &[f32] {
        let d = self.dim;
        &self.context[v as usize * d..(v as usize + 1) * d]
    }

    /// Scatter rows back to external ids: row `i` holds internal node
    /// `i`, which a reordered graph's stored permutation maps to external
    /// id `external[i]` — the returned store is indexed by external id.
    /// (Checkpoints deliberately stay in internal order — resume must be
    /// bitwise-identical — only user-facing output is unpermuted.)
    pub fn unpermuted(&self, external: &[u32]) -> EmbeddingStore {
        let (n, d) = (self.num_nodes, self.dim);
        assert_eq!(external.len(), n, "permutation length must match embedding rows");
        let mut vertex = vec![0f32; n * d];
        let mut context = vec![0f32; n * d];
        for internal in 0..n {
            let ext = external[internal] as usize;
            vertex[ext * d..(ext + 1) * d].copy_from_slice(self.vertex(internal as u32));
            context[ext * d..(ext + 1) * d].copy_from_slice(self.context(internal as u32));
        }
        EmbeddingStore::from_raw(n, d, vertex, context)
    }

    pub fn vertex_matrix(&self) -> &[f32] {
        &self.vertex
    }

    pub fn context_matrix(&self) -> &[f32] {
        &self.context
    }

    pub fn vertex_matrix_mut(&mut self) -> &mut [f32] {
        &mut self.vertex
    }

    pub fn context_matrix_mut(&mut self) -> &mut [f32] {
        &mut self.context
    }

    /// Gather partition `p`'s rows into a zero-padded `capacity × dim`
    /// buffer (the "send vertex_partitions[vid] to GPU" transfer of
    /// Algorithm 3). `capacity >= part_size(p)`.
    pub fn gather_partition(
        &self,
        parts: &Partitioning,
        p: usize,
        capacity: usize,
        which: Matrix,
        out: &mut Vec<f32>,
    ) {
        let nodes = parts.nodes_of_part(p);
        assert!(capacity >= nodes.len(), "capacity {} < partition {}", capacity, nodes.len());
        let d = self.dim;
        let src = match which {
            Matrix::Vertex => &self.vertex,
            Matrix::Context => &self.context,
        };
        out.clear();
        out.resize(capacity * d, 0.0);
        for (row, &v) in nodes.iter().enumerate() {
            let s = v as usize * d;
            out[row * d..(row + 1) * d].copy_from_slice(&src[s..s + d]);
        }
    }

    /// Scatter a padded partition buffer back ("receive … from GPU i").
    pub fn scatter_partition(
        &mut self,
        parts: &Partitioning,
        p: usize,
        which: Matrix,
        data: &[f32],
    ) {
        let nodes = parts.nodes_of_part(p);
        let d = self.dim;
        assert!(data.len() >= nodes.len() * d);
        let dst = match which {
            Matrix::Vertex => &mut self.vertex,
            Matrix::Context => &mut self.context,
        };
        for (row, &v) in nodes.iter().enumerate() {
            let s = v as usize * d;
            dst[s..s + d].copy_from_slice(&data[row * d..(row + 1) * d]);
        }
    }

    /// L2-normalized copy of the vertex matrix (the paper normalizes
    /// embeddings before the YouTube classification eval, §4.4).
    pub fn normalized_vertex(&self) -> Vec<f32> {
        let d = self.dim;
        let mut out = self.vertex.clone();
        for row in out.chunks_mut(d) {
            let norm = row.iter().map(|x| x * x).sum::<f32>().sqrt();
            if norm > 1e-12 {
                for x in row {
                    *x /= norm;
                }
            }
        }
        out
    }

    /// Mean-centered then L2-normalized vertex matrix — the feature space
    /// all evaluations use.
    ///
    /// SGNS embeddings carry a large *common drift component* (the
    /// weighted negative gradient pushes every vertex away from the mean
    /// context direction). A fully converged linear classifier absorbs a
    /// shared direction into its bias, but it drowns cosine similarities
    /// and slows iterative solvers badly; centering removes it without
    /// touching relative structure. (The paper's eval uses liblinear,
    /// which converges to the same optimum either way.)
    pub fn centered_normalized_vertex(&self) -> Vec<f32> {
        let d = self.dim;
        let n = self.num_nodes;
        let mut out = self.vertex.clone();
        let mut mean = vec![0f32; d];
        for row in out.chunks(d) {
            for (m, x) in mean.iter_mut().zip(row) {
                *m += x;
            }
        }
        for m in &mut mean {
            *m /= n.max(1) as f32;
        }
        for row in out.chunks_mut(d) {
            for (x, m) in row.iter_mut().zip(&mean) {
                *x -= m;
            }
            let norm = row.iter().map(|x| x * x).sum::<f32>().sqrt();
            if norm > 1e-12 {
                for x in row {
                    *x /= norm;
                }
            }
        }
        out
    }

    /// Memory footprint of both matrices in bytes (Table 1 accounting).
    pub fn bytes(&self) -> u64 {
        (self.vertex.len() + self.context.len()) as u64 * 4
    }
}

/// Which matrix a partition transfer touches.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Matrix {
    Vertex,
    Context,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;
    use crate::partition::Partitioner;

    #[test]
    fn init_ranges() {
        let e = EmbeddingStore::init(10, 8, 1);
        let bound = 0.5 / 8.0;
        for &x in e.vertex_matrix() {
            assert!(x >= -bound && x < bound);
        }
        assert!(e.context_matrix().iter().all(|&x| x == 0.0));
    }

    #[test]
    fn gather_scatter_roundtrip() {
        let g = generators::barabasi_albert(100, 2, 1);
        let parts = Partitioner::degree_zigzag(&g, 3);
        let mut e = EmbeddingStore::init(100, 4, 2);
        let orig = e.vertex_matrix().to_vec();
        let cap = parts.max_part_size() + 5;
        let mut buf = Vec::new();
        for p in 0..3 {
            e.gather_partition(&parts, p, cap, Matrix::Vertex, &mut buf);
            assert_eq!(buf.len(), cap * 4);
            // padding rows are zero
            for row in parts.part_size(p)..cap {
                assert!(buf[row * 4..(row + 1) * 4].iter().all(|&x| x == 0.0));
            }
            e.scatter_partition(&parts, p, Matrix::Vertex, &buf);
        }
        assert_eq!(e.vertex_matrix(), &orig[..]);
    }

    #[test]
    fn scatter_applies_updates() {
        let g = generators::karate_club();
        let parts = Partitioner::degree_zigzag(&g, 2);
        let mut e = EmbeddingStore::init(34, 4, 3);
        let cap = parts.max_part_size();
        let mut buf = Vec::new();
        e.gather_partition(&parts, 0, cap, Matrix::Context, &mut buf);
        for x in buf.iter_mut() {
            *x += 1.0;
        }
        e.scatter_partition(&parts, 0, Matrix::Context, &buf);
        for &v in parts.nodes_of_part(0) {
            assert!(e.context(v).iter().all(|&x| x == 1.0));
        }
        for &v in parts.nodes_of_part(1) {
            assert!(e.context(v).iter().all(|&x| x == 0.0));
        }
    }

    #[test]
    fn normalization_unit_rows() {
        let mut e = EmbeddingStore::init(5, 4, 4);
        e.vertex_matrix_mut().iter_mut().for_each(|x| *x += 0.3);
        let n = e.normalized_vertex();
        for row in n.chunks(4) {
            let norm: f32 = row.iter().map(|x| x * x).sum::<f32>().sqrt();
            assert!((norm - 1.0).abs() < 1e-5);
        }
    }

    #[test]
    fn bytes_accounting() {
        let e = EmbeddingStore::init(1000, 128, 5);
        assert_eq!(e.bytes(), 2 * 1000 * 128 * 4);
    }
}
