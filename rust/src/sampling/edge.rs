//! Plain edge sampling (no augmentation): draw existing arcs with
//! p ∝ weight via a global alias table — what LINE does, and what the
//! Table 6 ablation baseline uses instead of parallel online augmentation.

use crate::graph::GraphStore;
use crate::sampling::AliasTable;
use crate::util::rng::Rng;

/// O(1) weighted arc sampler over the whole graph.
///
/// Construction materializes every arc (one sequential
/// [`GraphStore::for_each_arc`] scan — page-friendly on the out-of-core
/// store, but O(E) RAM afterwards either way): this is the
/// `online_augmentation = false` ablation path, not the streaming one.
pub struct EdgeSampler {
    table: AliasTable,
    arcs: Vec<(u32, u32)>,
}

impl EdgeSampler {
    pub fn new(graph: &dyn GraphStore) -> Self {
        let mut arcs = Vec::with_capacity(graph.num_arcs());
        let mut weights = Vec::with_capacity(graph.num_arcs());
        graph.for_each_arc(&mut |u, v, w| {
            arcs.push((u, v));
            weights.push(w);
        });
        EdgeSampler { table: AliasTable::new(&weights), arcs }
    }

    /// Draw one (source, target) sample.
    #[inline]
    pub fn sample(&self, rng: &mut Rng) -> (u32, u32) {
        self.arcs[self.table.sample(rng) as usize]
    }

    /// Fill `out` up to `target` samples.
    pub fn fill(&self, out: &mut Vec<(u32, u32)>, target: usize, rng: &mut Rng) {
        while out.len() < target {
            out.push(self.sample(rng));
        }
    }

    pub fn bytes(&self) -> usize {
        self.table.bytes() + self.arcs.len() * 8
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{generators, GraphBuilder};

    #[test]
    fn samples_are_arcs() {
        let g = generators::karate_club();
        let s = EdgeSampler::new(&g);
        let mut rng = Rng::new(1);
        for _ in 0..1000 {
            let (u, v) = s.sample(&mut rng);
            assert!(g.has_edge(u, v));
        }
    }

    #[test]
    fn weighted_arcs_preferred() {
        let g = GraphBuilder::new()
            .add_edge(0, 1, 9.0)
            .add_edge(2, 3, 1.0)
            .build();
        let s = EdgeSampler::new(&g);
        let mut rng = Rng::new(2);
        let mut heavy = 0;
        const N: usize = 20_000;
        for _ in 0..N {
            let (u, _) = s.sample(&mut rng);
            if u <= 1 {
                heavy += 1;
            }
        }
        let f = heavy as f64 / N as f64;
        assert!((f - 0.9).abs() < 0.02, "f={f}");
    }

    #[test]
    fn fill_reaches_target() {
        let g = generators::karate_club();
        let s = EdgeSampler::new(&g);
        let mut rng = Rng::new(3);
        let mut out = Vec::new();
        s.fill(&mut out, 500, &mut rng);
        assert_eq!(out.len(), 500);
    }
}
