//! Quickstart: train embeddings on the Zachary karate club (a tiny real
//! graph embedded in-source) through the best backend compiled into this
//! binary (the full three-layer PJRT path under `--features pjrt`, the
//! pure-rust f32x8 `simd` trainer otherwise), then sanity-check that the
//! two known factions separate in embedding space.
//!
//!     cargo run --release --example quickstart
//!     cargo run --release --features pjrt --example quickstart

use graphvite::prelude::*;

fn main() -> anyhow::Result<()> {
    let graph = generators::karate_club();
    println!(
        "karate club: {} nodes, {} edges",
        graph.num_nodes(),
        graph.num_edges()
    );

    let config = TrainConfig {
        dim: 16,
        epochs: 300, // tiny graph: |E| = 78, so this is ~23k samples
        num_workers: 2,
        num_samplers: 2,
        episode_size: 2_000,
        backend: BackendKind::best_available(), // pjrt when compiled in, else simd
        ..TrainConfig::default()
    };
    let mut trainer = Trainer::new(graph.clone(), config)?;
    let result = trainer.train()?;
    println!(
        "trained {} samples in {:.2}s (final loss {:.4})",
        result.stats.counters.samples_trained,
        result.stats.train_secs,
        result.stats.final_loss
    );

    // The karate club famously split into two factions (labels in the
    // generator). Check mean intra- vs inter-faction cosine similarity.
    let labels = graph.labels().expect("karate club has faction labels");
    let emb = result.embeddings.normalized_vertex();
    let d = result.embeddings.dim();
    let cos = |a: usize, b: usize| -> f32 {
        emb[a * d..(a + 1) * d]
            .iter()
            .zip(&emb[b * d..(b + 1) * d])
            .map(|(x, y)| x * y)
            .sum()
    };
    let (mut intra, mut inter, mut ni, mut nj) = (0.0f32, 0.0f32, 0u32, 0u32);
    for a in 0..graph.num_nodes() {
        for b in (a + 1)..graph.num_nodes() {
            if labels[a] == labels[b] {
                intra += cos(a, b);
                ni += 1;
            } else {
                inter += cos(a, b);
                nj += 1;
            }
        }
    }
    let (intra, inter) = (intra / ni as f32, inter / nj as f32);
    println!("faction separation: intra-cosine {intra:.3} vs inter-cosine {inter:.3}");
    anyhow::ensure!(intra > inter, "factions failed to separate");
    println!("quickstart OK");
    Ok(())
}
