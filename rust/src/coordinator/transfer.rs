//! Host side of the pipelined transfer engine: partition residency
//! planning and the zero-realloc buffer free-lists.
//!
//! The coordinator walks the episode schedule in a fixed dispatch order
//! (the same order every pool pass — [`EpisodeSchedule::execution_sequence`]).
//! That makes data movement *plannable*: for every block dispatch the
//! engine knows which worker touches each partition **next**, so it can
//! decide, deterministically and ahead of time,
//!
//! * **upload elision** — skip gathering/shipping a partition whose
//!   current version is already resident on the target worker (counted in
//!   `residency_hits` / `bytes_saved`), and
//! * **download elision** — tell the worker to keep the trained partition
//!   resident (`Shipment::keep`) exactly when the partition's next block
//!   runs on that same worker, so the buffer never crosses the bus at all.
//!
//! Correctness rests on two invariants. (1) *Versioning*: every touch of
//! a partition bumps its version; a worker may only train on a resident
//! copy whose version matches the coordinator's record (the worker
//! verifies this and fails loudly — no silent stale training). (2)
//! *Single holder*: `keep` is only set when the next toucher is the same
//! worker, so at any fence at most one worker holds a given partition and
//! that copy is the newest. Host-side staleness is repaired at sync
//! fences (the worker protocol's `JobMsg::Sync`): checkpoints and the
//! end of training pull clones of all resident partitions back into the
//! store.
//!
//! With `residency = false` the engine reproduces the PR-2 transfer
//! pattern exactly (everything re-shipped per episode, except the §3.4
//! `fix_context` context pinning), which is what the counter-based
//! regression test in `rust/tests/pipeline_equivalence.rs` compares
//! against.
//!
//! **Capacity-bounded residency.** When the config declares
//! heterogeneous worker capacities, each worker's residency cache is
//! capped at `2 × capacity` resident partitions (the vertex + context
//! working set of its concurrent blocks) so a small device can stream a
//! large grid without resident blow-up. The engine plans against that
//! bound: it tracks per-worker occupancy in dispatch order — exact,
//! because a worker executes its jobs FIFO — and when a `keep` would
//! overflow the cap it ships the newly trained partition home instead.
//! Entries already resident are all awaiting a strictly scheduled touch
//! on that worker (that is why they were kept, per the next-toucher
//! tables), so "evict the newcomer" is the cheapest deterministic
//! policy: any other eviction forces the same re-upload later. Keep
//! decisions never change trained values (versioned shipments guarantee
//! the bytes are identical either way), so bounded and unbounded runs of
//! the same schedule produce identical embeddings — only the transfer
//! ledger moves.
//!
//! The free-lists close the zero-realloc loop: gather buffers come from
//! `f32_spare` (fed by scattered results), block buffers return from
//! workers through `block_spare` into
//! [`BlockGrid::refill`](crate::pool::BlockGrid::refill), and the drained
//! sample pool itself is recycled through the
//! [`PoolPair`](crate::pool::PoolPair).

use crate::embedding::Matrix;
use crate::scheduler::{Assignment, EpisodeSchedule};

/// One partition transfer as the recovery journal remembers it: the
/// original [`ShipPlan`] plus, when needed for replay, a snapshot of the
/// exact payload that was (or would have been) shipped.
///
/// Snapshot policy — `data` is `Some` exactly when this shipment is the
/// journal's *first* touch of its partition on this worker within the
/// current group (whether the original upload was real or elided): later
/// touches chain off an in-journal predecessor whose `keep` held the
/// buffer on-device, so replaying the chain regenerates them, while a
/// first touch's input bytes can be destroyed in the host store by the
/// job's own scattered output (a `keep: false` result lands home before
/// the failure) and must be retained. Within one worker's journal a
/// predecessor touch always has `keep: true` — the planner keeps exactly
/// when the next toucher is the same worker — so every non-first touch
/// is reconstructible and carries `data: None`.
#[derive(Debug, Clone)]
pub struct JournalShipment {
    /// Payload to re-upload on replay (`None` = rebuilt by replaying the
    /// predecessor entries of the same journal).
    pub data: Option<Vec<f32>>,
    pub src_version: u64,
    pub keep: bool,
}

/// One dispatched job as retained by the in-flight journal: everything
/// needed to re-send the job verbatim — block samples, LR at dispatch,
/// shipment plans with first-touch payload snapshots — plus whether its
/// result was already absorbed. Entries live from dispatch until the
/// next group fence; `done` entries are retained (not popped) because a
/// completed job's `keep: true` outputs exist only on the worker that
/// trained it, and regenerating them after that worker dies requires
/// replaying the whole per-worker chain in order.
#[derive(Debug, Clone)]
pub struct JournalEntry {
    pub vid: usize,
    pub cid: usize,
    pub lr: f32,
    pub block: Vec<(i32, i32)>,
    pub vertex: JournalShipment,
    pub context: JournalShipment,
    /// The job's result was absorbed before the failure. On replay its
    /// re-computed result is either discarded (replacement rebuilt its
    /// own residency) or scatter-only (fold: the kept outputs the dead
    /// worker held must be regenerated into the host store).
    pub done: bool,
}

/// The engine's decision for one partition transfer of one job.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ShipPlan {
    /// Gather + ship the partition (false = residency hit, upload elided).
    pub upload: bool,
    /// Worker keeps the trained buffer resident instead of returning it.
    pub keep: bool,
    /// Version of the copy the worker trains on (its output is
    /// `src_version + 1`).
    pub src_version: u64,
}

/// Deterministic residency planner + buffer free-lists (one per training
/// run, owned by the coordinator's episode loop).
#[derive(Debug)]
pub struct TransferEngine {
    num_parts: usize,
    residency: bool,
    legacy_fix_context: bool,
    /// Current (newest) version per partition; index = `idx(matrix, pid)`.
    latest: Vec<u64>,
    /// resident[worker][idx] = version that worker holds, if any.
    resident: Vec<Vec<Option<u64>>>,
    /// Worker that touches the dispatched assignment's *vertex* partition
    /// next (cyclically, the schedule repeats every pass), per dispatch
    /// slot of one pass.
    next_worker_v: Vec<usize>,
    /// Same for the context partition.
    next_worker_c: Vec<usize>,
    cursor: usize,
    /// Per-worker residency-cache caps (max resident partitions), `None`
    /// = unbounded (the homogeneous default).
    limits: Option<Vec<usize>>,
    /// Resident partitions per worker right now (= `Some` entries in
    /// `resident[w]`), maintained incrementally.
    occupancy: Vec<usize>,
    /// Keeps denied by a full cache (diagnostic; see the module docs).
    pub capacity_evictions: u64,
    /// Recycled gather/result buffers (padded partition rows).
    pub f32_spare: Vec<Vec<f32>>,
    /// Recycled block buffers, fed back into `BlockGrid::refill`.
    pub block_spare: Vec<Vec<(i32, i32)>>,
}

impl TransferEngine {
    /// `cache_limits`: per-worker caps on resident partitions (`None` =
    /// unbounded), from
    /// [`TrainConfig::residency_limits`](crate::config::TrainConfig::residency_limits).
    pub fn new(
        sched: &EpisodeSchedule,
        residency: bool,
        fix_context: bool,
        cache_limits: Option<Vec<usize>>,
    ) -> Self {
        let num_workers = sched.num_workers();
        if let Some(limits) = &cache_limits {
            assert_eq!(limits.len(), num_workers, "one cache limit per worker");
        }
        let seq = sched.execution_sequence();
        let p = sched.num_parts();
        let mut next_worker_v = vec![0usize; seq.len()];
        let mut next_worker_c = vec![0usize; seq.len()];
        let fill = |next: &mut Vec<usize>, part_of: &dyn Fn(&Assignment) -> usize| {
            for pid in 0..p {
                let touches: Vec<usize> = seq
                    .iter()
                    .enumerate()
                    .filter(|(_, a)| part_of(a) == pid)
                    .map(|(t, _)| t)
                    .collect();
                for (k, &t) in touches.iter().enumerate() {
                    let succ = touches[(k + 1) % touches.len()];
                    next[t] = seq[succ].worker;
                }
            }
        };
        fill(&mut next_worker_v, &|a| a.vid);
        fill(&mut next_worker_c, &|a| a.cid);
        TransferEngine {
            num_parts: p,
            residency,
            legacy_fix_context: !residency && fix_context,
            latest: vec![0; 2 * p],
            resident: vec![vec![None; 2 * p]; num_workers],
            next_worker_v,
            next_worker_c,
            cursor: 0,
            limits: cache_limits,
            occupancy: vec![0; num_workers],
            capacity_evictions: 0,
            f32_spare: Vec::new(),
            block_spare: Vec::new(),
        }
    }

    /// Partitions currently planned resident on `worker` (exact at job
    /// boundaries: the worker drains its queue FIFO).
    pub fn resident_count(&self, worker: usize) -> usize {
        self.occupancy[worker]
    }

    #[inline]
    fn idx(&self, matrix: Matrix, pid: usize) -> usize {
        match matrix {
            Matrix::Vertex => pid,
            Matrix::Context => self.num_parts + pid,
        }
    }

    /// Plan the (vertex, context) transfers of the next assignment in
    /// dispatch order. Must be called exactly once per dispatched job, in
    /// schedule order — the cursor tracks the position in the pass.
    pub fn plan(&mut self, a: &Assignment) -> (ShipPlan, ShipPlan) {
        let t = self.cursor;
        self.cursor = (self.cursor + 1) % self.next_worker_v.len();
        let next_v = self.next_worker_v[t];
        let next_c = self.next_worker_c[t];
        let v = self.plan_part(Matrix::Vertex, a.vid, a.worker, next_v);
        let c = self.plan_part(Matrix::Context, a.cid, a.worker, next_c);
        (v, c)
    }

    fn plan_part(
        &mut self,
        matrix: Matrix,
        pid: usize,
        worker: usize,
        next_worker: usize,
    ) -> ShipPlan {
        let i = self.idx(matrix, pid);
        let cur = self.latest[i];
        let was_resident = self.resident[worker][i].is_some();
        let upload = self.resident[worker][i] != Some(cur);
        let mut keep = if self.residency {
            next_worker == worker
        } else {
            // PR-2 semantics: only the §3.4 context cache pins anything
            matrix == Matrix::Context && self.legacy_fix_context
        };
        // Capacity bound: a kept partition that is not already resident
        // grows the worker's cache; when that would exceed the cap, ship
        // the newly trained buffer home instead (see the module docs for
        // why the newcomer is the right eviction victim).
        if keep && !was_resident {
            if let Some(limits) = &self.limits {
                if self.occupancy[worker] >= limits[worker] {
                    keep = false;
                    self.capacity_evictions += 1;
                }
            }
        }
        self.latest[i] = cur + 1;
        match (was_resident, keep) {
            (false, true) => self.occupancy[worker] += 1,
            (true, false) => self.occupancy[worker] -= 1,
            _ => {}
        }
        self.resident[worker][i] = if keep { Some(cur + 1) } else { None };
        ShipPlan { upload, keep, src_version: cur }
    }

    // --- worker-failure recovery hooks -------------------------------

    /// Plan `a` for a worker slot that was folded onto survivors: the
    /// surviving executor gets fresh bytes and ships the result straight
    /// home (upload, no keep), but partition versions and the schedule
    /// cursor advance exactly as the fault-free plan would — so every
    /// later plan, on any worker, is unchanged.
    pub fn plan_folded(&mut self, a: &Assignment) -> (ShipPlan, ShipPlan) {
        let (v, c) = self.plan(a);
        // undo any keep the fault-free plan recorded for the dead slot
        self.drop_residency(a.worker, Matrix::Vertex, a.vid);
        self.drop_residency(a.worker, Matrix::Context, a.cid);
        (
            ShipPlan { upload: true, keep: false, ..v },
            ShipPlan { upload: true, keep: false, ..c },
        )
    }

    /// Forget one resident entry (recovery: its holder died, so a future
    /// plan must re-upload from the host store).
    pub fn drop_residency(&mut self, worker: usize, matrix: Matrix, pid: usize) {
        let i = self.idx(matrix, pid);
        if self.resident[worker][i].take().is_some() {
            self.occupancy[worker] -= 1;
        }
    }

    /// Record that `worker` holds `version` of a partition (recovery: a
    /// replacement rebuilt this entry by replaying the journal).
    pub fn set_resident(&mut self, worker: usize, matrix: Matrix, pid: usize, version: u64) {
        let i = self.idx(matrix, pid);
        if self.resident[worker][i].replace(version).is_none() {
            self.occupancy[worker] += 1;
        }
    }

    /// Forget everything resident on `worker` (recovery: it died; a
    /// replacement starts with an empty cache, a folded slot never gets
    /// another elided upload).
    pub fn forget_worker(&mut self, worker: usize) {
        for slot in self.resident[worker].iter_mut() {
            *slot = None;
        }
        self.occupancy[worker] = 0;
    }

    /// Take a recycled f32 buffer for a partition gather.
    pub fn take_f32(&mut self) -> Vec<f32> {
        self.f32_spare.pop().unwrap_or_default()
    }

    /// Return a scattered result buffer to the free-list.
    pub fn put_f32(&mut self, buf: Vec<f32>) {
        self.f32_spare.push(buf);
    }

    /// Return a spent block buffer to the free-list (fed to
    /// `BlockGrid::refill` on the next pool pass).
    pub fn put_block(&mut self, mut block: Vec<(i32, i32)>) {
        block.clear();
        self.block_spare.push(block);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Drive `passes` full pool passes through an engine, returning the
    /// per-pass count of uploads (vertex + context).
    fn uploads_per_pass(
        sched: &EpisodeSchedule,
        residency: bool,
        fix_context: bool,
        limits: Option<Vec<usize>>,
        passes: usize,
    ) -> Vec<usize> {
        let mut engine = TransferEngine::new(sched, residency, fix_context, limits);
        let seq = sched.execution_sequence();
        (0..passes)
            .map(|_| {
                seq.iter()
                    .map(|a| {
                        let (v, c) = engine.plan(a);
                        usize::from(v.upload) + usize::from(c.upload)
                    })
                    .sum()
            })
            .collect()
    }

    #[test]
    fn no_residency_ships_everything_every_pass() {
        let sched = EpisodeSchedule::new(4, 2, false);
        // 16 assignments per pass, 2 uploads each
        assert_eq!(uploads_per_pass(&sched, false, false, None, 3), vec![32, 32, 32]);
    }

    #[test]
    fn legacy_fix_context_uploads_context_once() {
        let sched = EpisodeSchedule::new(2, 2, true);
        // per pass: 4 assignments; vertex always shipped (4); context
        // shipped only on first-ever touch (2 in pass one, 0 after)
        assert_eq!(uploads_per_pass(&sched, false, true, None, 3), vec![6, 4, 4]);
    }

    #[test]
    fn residency_order_halves_context_and_pins_vertex() {
        let sched = EpisodeSchedule::new(4, 2, false).with_residency_order();
        // Vertex partitions are sticky to workers under the standard
        // schedule (vid = slot): 4 first-touch uploads in pass one, 0
        // after. Context partitions re-upload only at the 2 residue-class
        // boundaries per pass: 8 context uploads per pass (vs 16).
        assert_eq!(uploads_per_pass(&sched, true, false, None, 3), vec![12, 8, 8]);
    }

    #[test]
    fn keep_is_only_set_for_same_worker_successor() {
        let sched = EpisodeSchedule::new(4, 2, false).with_residency_order();
        let mut engine = TransferEngine::new(&sched, true, false, None);
        let seq = sched.execution_sequence();
        // simulate worker caches and verify the single-holder invariant
        let mut holder: Vec<Option<usize>> = vec![None; 8]; // (matrix, pid)
        for pass in 0..2 {
            for a in &seq {
                let (v, c) = engine.plan(a);
                for (plan, idx) in [(v, a.vid), (c, 4 + a.cid)] {
                    if !plan.upload {
                        assert_eq!(
                            holder[idx],
                            Some(a.worker),
                            "pass {pass}: elided upload but worker {} does not hold {idx}",
                            a.worker
                        );
                    }
                    holder[idx] = plan.keep.then_some(a.worker);
                }
            }
        }
    }

    /// Replay an engine over `passes` passes, checking after every single
    /// plan that the simulated per-worker cache (which `occupancy`
    /// mirrors) never exceeds its cap. Returns total upload count.
    fn check_bounded(
        sched: &EpisodeSchedule,
        limits: Vec<usize>,
        passes: usize,
    ) -> (usize, u64) {
        let mut engine = TransferEngine::new(sched, true, false, Some(limits.clone()));
        let seq = sched.execution_sequence();
        let mut uploads = 0usize;
        for _ in 0..passes {
            for a in &seq {
                let (v, c) = engine.plan(a);
                uploads += usize::from(v.upload) + usize::from(c.upload);
                for (w, &limit) in limits.iter().enumerate() {
                    assert!(
                        engine.resident_count(w) <= limit,
                        "worker {w} resident {} > cap {limit}",
                        engine.resident_count(w)
                    );
                }
            }
        }
        (uploads, engine.capacity_evictions)
    }

    #[test]
    fn capacity_caps_bound_residency_at_every_step() {
        // heterogeneous P=8 on capacities [1,3]: the small worker's cap
        // (2 resident partitions) is tighter than its sticky set (2 vids
        // + contexts), so some keeps must be denied — and the bound must
        // hold after every plan, not just at fences.
        let sched = EpisodeSchedule::with_capacities(8, &[1, 3], false).with_residency_order();
        let (bounded_uploads, evictions) = check_bounded(&sched, vec![2, 6], 3);
        assert!(evictions > 0, "tight caps should deny at least one keep");
        // unbounded planning of the same schedule elides strictly more
        let unbounded: usize =
            uploads_per_pass(&sched, true, false, None, 3).iter().sum();
        assert!(
            bounded_uploads > unbounded,
            "bounded {bounded_uploads} vs unbounded {unbounded}"
        );
        // a loose cap (every partition of both matrices) denies nothing
        let (loose_uploads, loose_evictions) = check_bounded(&sched, vec![16, 16], 3);
        assert_eq!(loose_evictions, 0);
        assert_eq!(loose_uploads, unbounded);
    }

    #[test]
    fn bounded_planning_keeps_the_single_holder_invariant() {
        let sched = EpisodeSchedule::with_capacities(8, &[1, 3], false).with_residency_order();
        let mut engine = TransferEngine::new(&sched, true, false, Some(vec![2, 6]));
        let seq = sched.execution_sequence();
        let mut holder: Vec<Option<usize>> = vec![None; 16]; // (matrix, pid)
        for pass in 0..3 {
            for a in &seq {
                let (v, c) = engine.plan(a);
                for (plan, idx) in [(v, a.vid), (c, 8 + a.cid)] {
                    if !plan.upload {
                        assert_eq!(
                            holder[idx],
                            Some(a.worker),
                            "pass {pass}: elided upload without a resident copy"
                        );
                    }
                    holder[idx] = plan.keep.then_some(a.worker);
                }
            }
        }
    }

    #[test]
    fn recovery_hooks_keep_versions_and_clear_residency() {
        let sched = EpisodeSchedule::new(4, 2, false).with_residency_order();
        let seq = sched.execution_sequence();
        let mut faulty = TransferEngine::new(&sched, true, false, None);
        let mut clean = TransferEngine::new(&sched, true, false, None);
        // fold worker 0 after the first pass: versions and the cursor
        // must advance identically to the fault-free engine, uploads for
        // the folded slot must be forced, and nothing stays resident
        for a in &seq {
            assert_eq!(faulty.plan(a), clean.plan(a));
        }
        faulty.forget_worker(0);
        assert_eq!(faulty.resident_count(0), 0);
        for a in &seq {
            let clean_plans = clean.plan(a);
            if a.worker == 0 {
                let (v, c) = faulty.plan_folded(a);
                assert!(v.upload && c.upload && !v.keep && !c.keep);
                assert_eq!(v.src_version, clean_plans.0.src_version);
                assert_eq!(c.src_version, clean_plans.1.src_version);
                assert_eq!(faulty.resident_count(0), 0, "folded slot never re-pins");
            } else {
                assert_eq!(faulty.plan(a), clean_plans, "survivor plans unchanged");
            }
        }
        // set_resident / drop_residency round-trip with occupancy
        let mut engine = TransferEngine::new(&sched, true, false, None);
        engine.set_resident(1, Matrix::Context, 2, 5);
        assert_eq!(engine.resident_count(1), 1);
        engine.set_resident(1, Matrix::Context, 2, 6); // overwrite, same slot
        assert_eq!(engine.resident_count(1), 1);
        engine.drop_residency(1, Matrix::Context, 2);
        assert_eq!(engine.resident_count(1), 0);
        engine.drop_residency(1, Matrix::Context, 2); // idempotent
        assert_eq!(engine.resident_count(1), 0);
    }

    #[test]
    fn free_lists_recycle() {
        let sched = EpisodeSchedule::new(2, 2, false);
        let mut engine = TransferEngine::new(&sched, true, false, None);
        assert!(engine.take_f32().is_empty());
        let mut buf = engine.take_f32();
        buf.resize(128, 1.0);
        engine.put_f32(buf);
        assert!(engine.take_f32().capacity() >= 128);
        engine.put_block(vec![(1, 2), (3, 4)]);
        let b = engine.block_spare.pop().unwrap();
        assert!(b.is_empty() && b.capacity() >= 2, "cleared but capacity kept");
    }
}
