//! IVF-flat ANN index over node embeddings.
//!
//! Queries and stored vectors are L2-normalized, so maximum inner product
//! equals cosine similarity. Build runs spherical k-means for a coarse
//! quantizer of `nlist` centroids and buckets every node into the
//! inverted list of its nearest centroid; a query scores all centroids,
//! probes the `nprobe` best lists, and ranks the candidates by exact dot
//! product. With `nprobe == nlist` every list is probed and the result is
//! bitwise-identical to [`AnnIndex::brute_force`] (pinned in tests) —
//! recall degrades gracefully as `nprobe` shrinks while query cost drops
//! by roughly `nlist / nprobe`.
//!
//! Everything is deterministic: centroid seeding uses the project RNG
//! ([`crate::util::rng::Rng`]), empty clusters keep their previous
//! centroid, and all top-k selections break score ties by node id.

use crate::embedding::EmbeddingStore;
use crate::util::rng::Rng;

/// Build-time knobs. Zeros mean "auto": `nlist ≈ √n`, `nprobe = nlist/8`.
#[derive(Debug, Clone)]
pub struct IndexConfig {
    pub nlist: usize,
    pub nprobe: usize,
    pub kmeans_iters: usize,
    pub seed: u64,
}

impl Default for IndexConfig {
    fn default() -> Self {
        IndexConfig { nlist: 0, nprobe: 0, kmeans_iters: 8, seed: 0x5EED }
    }
}

/// The built index: owns a normalized copy of the vertex matrix, the
/// centroids, and CSR-shaped inverted lists.
pub struct AnnIndex {
    dim: usize,
    nprobe: usize,
    /// `nlist × dim`, row-major, unit rows.
    centroids: Vec<f32>,
    /// CSR offsets into `list_ids`, length `nlist + 1`.
    list_offsets: Vec<u32>,
    /// Node ids grouped by nearest centroid.
    list_ids: Vec<u32>,
    /// `n × dim`, row-major, unit rows.
    vectors: Vec<f32>,
}

impl AnnIndex {
    /// Build from a store's vertex matrix.
    pub fn build(store: &EmbeddingStore, cfg: &IndexConfig) -> Self {
        let n = store.num_nodes();
        let d = store.dim();
        let vectors = store.normalized_vertex();
        let nlist = if cfg.nlist > 0 {
            cfg.nlist.min(n.max(1))
        } else {
            ((n as f64).sqrt().round() as usize).clamp(1, n.max(1))
        };
        let nprobe = if cfg.nprobe > 0 { cfg.nprobe.min(nlist) } else { (nlist / 8).max(1) };

        // seed centroids from a deterministic sample of distinct nodes
        let mut rng = Rng::new(cfg.seed);
        let perm = rng.permutation(n.max(1));
        let mut centroids = vec![0f32; nlist * d];
        for (c, &v) in perm.iter().take(nlist).enumerate() {
            centroids[c * d..(c + 1) * d]
                .copy_from_slice(&vectors[v as usize * d..(v as usize + 1) * d]);
        }

        // spherical k-means: assign by max dot, recenter, renormalize
        let mut assign = vec![0u32; n];
        for _ in 0..cfg.kmeans_iters.max(1) {
            for (v, a) in assign.iter_mut().enumerate() {
                *a = nearest_centroid(&centroids, nlist, d, &vectors[v * d..(v + 1) * d]);
            }
            let mut sums = vec![0f32; nlist * d];
            let mut counts = vec![0u32; nlist];
            for (v, &a) in assign.iter().enumerate() {
                let c = a as usize;
                counts[c] += 1;
                for (s, x) in sums[c * d..(c + 1) * d].iter_mut().zip(&vectors[v * d..(v + 1) * d])
                {
                    *s += x;
                }
            }
            for c in 0..nlist {
                // empty clusters keep their previous centroid (deterministic)
                if counts[c] == 0 {
                    continue;
                }
                let row = &mut sums[c * d..(c + 1) * d];
                let norm = row.iter().map(|x| x * x).sum::<f32>().sqrt();
                if norm > 1e-12 {
                    for x in row.iter_mut() {
                        *x /= norm;
                    }
                }
                centroids[c * d..(c + 1) * d].copy_from_slice(row);
            }
        }
        for (v, a) in assign.iter_mut().enumerate() {
            *a = nearest_centroid(&centroids, nlist, d, &vectors[v * d..(v + 1) * d]);
        }

        // bucket into CSR inverted lists (counting sort keeps id order)
        let mut counts = vec![0u32; nlist];
        for &a in &assign {
            counts[a as usize] += 1;
        }
        let mut list_offsets = vec![0u32; nlist + 1];
        for c in 0..nlist {
            list_offsets[c + 1] = list_offsets[c] + counts[c];
        }
        let mut cursor = list_offsets[..nlist].to_vec();
        let mut list_ids = vec![0u32; n];
        for (v, &a) in assign.iter().enumerate() {
            let c = a as usize;
            list_ids[cursor[c] as usize] = v as u32;
            cursor[c] += 1;
        }

        AnnIndex { dim: d, nprobe, centroids, list_offsets, list_ids, vectors }
    }

    pub fn num_nodes(&self) -> usize {
        self.vectors.len() / self.dim.max(1)
    }

    pub fn dim(&self) -> usize {
        self.dim
    }

    pub fn nlist(&self) -> usize {
        self.list_offsets.len() - 1
    }

    /// Default probe count chosen at build time.
    pub fn nprobe(&self) -> usize {
        self.nprobe
    }

    /// The stored (normalized) vector of node `v`.
    pub fn vector(&self, v: u32) -> &[f32] {
        &self.vectors[v as usize * self.dim..(v as usize + 1) * self.dim]
    }

    /// Top-`k` nodes by dot product with `query`, probing the `nprobe`
    /// nearest inverted lists. Pass `self.nprobe()` for the build-time
    /// default; `nprobe >= nlist` reproduces brute force exactly.
    pub fn search(&self, query: &[f32], k: usize, nprobe: usize) -> Vec<(u32, f32)> {
        assert_eq!(query.len(), self.dim);
        let nlist = self.nlist();
        let nprobe = nprobe.clamp(1, nlist);
        // rank centroids by score; ties by list id for determinism
        let mut probe = TopK::new(nprobe);
        for c in 0..nlist {
            probe.push(dot(&self.centroids[c * self.dim..(c + 1) * self.dim], query), c as u32);
        }
        let mut top = TopK::new(k);
        for (c, _) in probe.into_sorted() {
            let lo = self.list_offsets[c as usize] as usize;
            let hi = self.list_offsets[c as usize + 1] as usize;
            for &v in &self.list_ids[lo..hi] {
                top.push(dot(self.vector(v), query), v);
            }
        }
        top.into_sorted()
    }

    /// [`Self::search`] seeded by a node's own vector, excluding the node
    /// itself from the results (the "neighbors of X" query).
    pub fn search_node(&self, v: u32, k: usize, nprobe: usize) -> Vec<(u32, f32)> {
        let query = self.vector(v).to_vec();
        let mut out = self.search(&query, k + 1, nprobe);
        out.retain(|&(id, _)| id != v);
        out.truncate(k);
        out
    }

    /// Exact top-`k` by scanning every vector — the correctness reference
    /// and the baseline the ANN path must beat in `bench_micro`.
    pub fn brute_force(&self, query: &[f32], k: usize) -> Vec<(u32, f32)> {
        assert_eq!(query.len(), self.dim);
        let mut top = TopK::new(k);
        for v in 0..self.num_nodes() as u32 {
            top.push(dot(self.vector(v), query), v);
        }
        top.into_sorted()
    }
}

fn nearest_centroid(centroids: &[f32], nlist: usize, d: usize, v: &[f32]) -> u32 {
    let mut best = 0u32;
    let mut best_score = f32::NEG_INFINITY;
    for c in 0..nlist {
        let s = dot(&centroids[c * d..(c + 1) * d], v);
        if s > best_score {
            best_score = s;
            best = c as u32;
        }
    }
    best
}

#[inline]
fn dot(a: &[f32], b: &[f32]) -> f32 {
    a.iter().zip(b).map(|(x, y)| x * y).sum()
}

/// Bounded best-k accumulator over (score, id), kept sorted descending by
/// score with ties broken by ascending id — a strict total order, so the
/// result is independent of push order (which makes IVF-with-all-lists
/// bitwise-equal to the sequential brute-force scan).
struct TopK {
    k: usize,
    entries: Vec<(f32, u32)>,
}

impl TopK {
    fn new(k: usize) -> Self {
        TopK { k, entries: Vec::with_capacity(k + 1) }
    }

    fn push(&mut self, score: f32, id: u32) {
        if self.k == 0 {
            return;
        }
        if self.entries.len() == self.k {
            let &(ws, wid) = self.entries.last().unwrap();
            if !beats(score, id, ws, wid) {
                return;
            }
        }
        let pos = self
            .entries
            .iter()
            .position(|&(s, i)| beats(score, id, s, i))
            .unwrap_or(self.entries.len());
        self.entries.insert(pos, (score, id));
        self.entries.truncate(self.k);
    }

    fn into_sorted(self) -> Vec<(u32, f32)> {
        self.entries.into_iter().map(|(s, id)| (id, s)).collect()
    }
}

#[inline]
fn beats(s1: f32, id1: u32, s2: f32, id2: u32) -> bool {
    s1 > s2 || (s1 == s2 && id1 < id2)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Planted clusters: `n` nodes around `c` well-separated directions.
    fn clustered_store(n: usize, d: usize, c: usize, seed: u64) -> EmbeddingStore {
        let mut rng = Rng::new(seed);
        let centers: Vec<f32> = (0..c * d).map(|_| rng.normal() as f32).collect();
        let mut vertex = vec![0f32; n * d];
        for v in 0..n {
            let ctr = &centers[(v % c) * d..(v % c + 1) * d];
            for j in 0..d {
                vertex[v * d + j] = ctr[j] + 0.1 * rng.normal() as f32;
            }
        }
        EmbeddingStore::from_raw(n, d, vertex, vec![0.0; n * d])
    }

    #[test]
    fn full_probe_matches_brute_force_bitwise() {
        let store = clustered_store(500, 16, 8, 1);
        let idx = AnnIndex::build(&store, &IndexConfig::default());
        for v in [0u32, 17, 499] {
            let q = idx.vector(v).to_vec();
            assert_eq!(idx.search(&q, 10, idx.nlist()), idx.brute_force(&q, 10));
        }
    }

    #[test]
    fn ann_recall_on_clustered_data() {
        let store = clustered_store(2000, 24, 16, 2);
        let idx = AnnIndex::build(&store, &IndexConfig { nlist: 32, ..Default::default() });
        let mut hit = 0usize;
        let mut total = 0usize;
        for v in (0..2000u32).step_by(97) {
            let q = idx.vector(v).to_vec();
            let exact: Vec<u32> = idx.brute_force(&q, 10).into_iter().map(|(id, _)| id).collect();
            let approx: Vec<u32> =
                idx.search(&q, 10, idx.nprobe()).into_iter().map(|(id, _)| id).collect();
            total += exact.len();
            hit += exact.iter().filter(|id| approx.contains(id)).count();
        }
        let recall = hit as f64 / total as f64;
        assert!(recall >= 0.8, "recall@10 = {recall}");
    }

    #[test]
    fn search_node_excludes_self() {
        let store = clustered_store(300, 8, 4, 3);
        let idx = AnnIndex::build(&store, &IndexConfig::default());
        let res = idx.search_node(42, 5, idx.nlist());
        assert_eq!(res.len(), 5);
        assert!(res.iter().all(|&(id, _)| id != 42));
        // a unit query against itself scores ~1.0, so the top hit of the
        // same planted cluster should score high
        assert!(res[0].1 > 0.9, "{res:?}");
    }

    #[test]
    fn deterministic_build() {
        let store = clustered_store(400, 8, 4, 4);
        let a = AnnIndex::build(&store, &IndexConfig::default());
        let b = AnnIndex::build(&store, &IndexConfig::default());
        assert_eq!(a.centroids, b.centroids);
        assert_eq!(a.list_ids, b.list_ids);
    }

    #[test]
    fn topk_orders_and_bounds() {
        let mut t = TopK::new(3);
        for (s, id) in [(0.1, 5), (0.9, 2), (0.5, 9), (0.9, 1), (0.2, 0)] {
            t.push(s, id);
        }
        assert_eq!(t.into_sorted(), vec![(1, 0.9), (2, 0.9), (9, 0.5)]);
    }
}
