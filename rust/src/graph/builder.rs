//! Edge-list → CSR construction with symmetrization and dedup.

use super::Graph;

/// Accumulates undirected edges and builds a [`Graph`].
///
/// Duplicate (u, v) pairs have their weights summed; self-loops are
/// dropped (they carry no information for SGNS and break walk semantics).
#[derive(Debug, Default)]
pub struct GraphBuilder {
    edges: Vec<(u32, u32, f32)>,
    num_nodes: usize,
    labels: Option<Vec<u16>>,
    dedup: bool,
}

impl GraphBuilder {
    pub fn new() -> Self {
        GraphBuilder { edges: Vec::new(), num_nodes: 0, labels: None, dedup: true }
    }

    /// Pre-declare node count (otherwise inferred as max id + 1).
    pub fn with_num_nodes(mut self, n: usize) -> Self {
        self.num_nodes = n;
        self
    }

    /// Disable duplicate-edge merging (keeps parallel edges as extra weight
    /// entries — matches how LINE treats multigraphs).
    pub fn keep_duplicates(mut self) -> Self {
        self.dedup = false;
        self
    }

    pub fn with_labels(mut self, labels: Vec<u16>) -> Self {
        self.labels = Some(labels);
        self
    }

    pub fn add_edge(mut self, u: u32, v: u32, w: f32) -> Self {
        self.push_edge(u, v, w);
        self
    }

    /// Non-consuming variant for loops.
    pub fn push_edge(&mut self, u: u32, v: u32, w: f32) {
        if u == v {
            return; // drop self loops
        }
        debug_assert!(w > 0.0, "edge weights must be positive");
        self.edges.push((u, v, w));
        let hi = u.max(v) as usize + 1;
        if hi > self.num_nodes {
            self.num_nodes = hi;
        }
    }

    pub fn extend(&mut self, edges: impl IntoIterator<Item = (u32, u32, f32)>) {
        for (u, v, w) in edges {
            self.push_edge(u, v, w);
        }
    }

    pub fn num_pending_edges(&self) -> usize {
        self.edges.len()
    }

    /// Build the CSR graph (counting-sort by source; O(V + E)).
    pub fn build(self) -> Graph {
        let n = self.num_nodes;
        // Symmetrize: each undirected edge becomes two arcs.
        let mut arcs: Vec<(u32, u32, f32)> = Vec::with_capacity(self.edges.len() * 2);
        for &(u, v, w) in &self.edges {
            arcs.push((u, v, w));
            arcs.push((v, u, w));
        }

        // Counting sort by source.
        let mut counts = vec![0u64; n + 1];
        for &(u, _, _) in &arcs {
            counts[u as usize + 1] += 1;
        }
        for i in 0..n {
            counts[i + 1] += counts[i];
        }
        let offsets = counts.clone();
        let mut targets = vec![0u32; arcs.len()];
        let mut weights = vec![0f32; arcs.len()];
        let mut cursor = counts;
        for (u, v, w) in arcs {
            let at = cursor[u as usize] as usize;
            targets[at] = v;
            weights[at] = w;
            cursor[u as usize] += 1;
        }

        // Per-row sort by target + optional dedup (merge weights).
        let mut out_offsets = Vec::with_capacity(n + 1);
        let mut out_targets = Vec::with_capacity(targets.len());
        let mut out_weights = Vec::with_capacity(weights.len());
        out_offsets.push(0u64);
        let mut row: Vec<(u32, f32)> = Vec::new();
        for v in 0..n {
            let (s, e) = (offsets[v] as usize, offsets[v + 1] as usize);
            row.clear();
            row.extend(targets[s..e].iter().copied().zip(weights[s..e].iter().copied()));
            row.sort_unstable_by_key(|&(t, _)| t);
            if self.dedup {
                let mut i = 0;
                while i < row.len() {
                    let mut j = i + 1;
                    let mut w = row[i].1;
                    while j < row.len() && row[j].0 == row[i].0 {
                        w += row[j].1;
                        j += 1;
                    }
                    out_targets.push(row[i].0);
                    out_weights.push(w);
                    i = j;
                }
            } else {
                for &(t, w) in &row {
                    out_targets.push(t);
                    out_weights.push(w);
                }
            }
            out_offsets.push(out_targets.len() as u64);
        }

        Graph::from_parts(out_offsets, out_targets, out_weights, self.labels)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dedup_merges_weights() {
        let g = GraphBuilder::new()
            .add_edge(0, 1, 1.0)
            .add_edge(0, 1, 2.0)
            .build();
        assert_eq!(g.degree(0), 1);
        assert_eq!(g.neighbor_weights(0), &[3.0]);
    }

    #[test]
    fn keep_duplicates_keeps_arcs() {
        let g = GraphBuilder::new()
            .keep_duplicates()
            .add_edge(0, 1, 1.0)
            .add_edge(0, 1, 1.0)
            .build();
        assert_eq!(g.degree(0), 2);
    }

    #[test]
    fn self_loops_dropped() {
        let g = GraphBuilder::new().add_edge(0, 0, 1.0).add_edge(0, 1, 1.0).build();
        assert_eq!(g.num_edges(), 1);
        assert_eq!(g.degree(0), 1);
    }

    #[test]
    fn neighbors_sorted() {
        let g = GraphBuilder::new()
            .add_edge(0, 5, 1.0)
            .add_edge(0, 2, 1.0)
            .add_edge(0, 9, 1.0)
            .build();
        assert_eq!(g.neighbors(0), &[2, 5, 9]);
    }

    #[test]
    fn symmetrized() {
        let g = GraphBuilder::new().add_edge(3, 7, 1.5).build();
        assert_eq!(g.neighbors(7), &[3]);
        assert_eq!(g.neighbor_weights(7), &[1.5]);
    }
}
