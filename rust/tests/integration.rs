//! Cross-module integration tests over the native path: graph → sampler →
//! pool → partition → scheduler → trainer → eval, plus persistence and
//! the CLI-facing config surface. (The HLO path is covered by
//! `pipeline.rs` and `hlo_runtime.rs`.)

use graphvite::baselines::line::LineConfig;
use graphvite::baselines::{DeepWalkBaseline, LineBaseline, MinibatchGpuBaseline};
use graphvite::baselines::deepwalk::DeepWalkConfig;
use graphvite::baselines::minibatch::MinibatchConfig;
use graphvite::config::{BackendKind, TrainConfig};
use graphvite::coordinator::Trainer;
use graphvite::embedding::{self, EmbeddingStore};
use graphvite::eval::{link_prediction_auc, LinkSplit};
use graphvite::experiments::classify;
use graphvite::graph::{self, generators};
use graphvite::pool::ShuffleKind;

fn tmp(name: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join("graphvite_tests");
    std::fs::create_dir_all(&dir).unwrap();
    dir.join(name)
}

fn small_cfg() -> TrainConfig {
    TrainConfig {
        dim: 16,
        epochs: 100,
        num_workers: 2,
        num_samplers: 2,
        episode_size: 5_000,
        // CI's backend matrix re-runs this suite per backend via
        // GRAPHVITE_TEST_BACKEND (default: native)
        backend: BackendKind::test_backend(),
        shuffle: ShuffleKind::Pseudo,
        ..TrainConfig::default()
    }
}

// ---------------------------------------------------------------- train --

#[test]
fn trained_embeddings_classify_communities() {
    // Empirical F1 gate, swept over PINNED seeds and asserted on the
    // pass rate (ROADMAP "Flaky-threshold audit", final migrated gate):
    // pipeline corruption collapses every seed to ~chance, while a
    // single unlucky seed may dip below the floor. The swept score is
    // min(micro, macro) so minority-class collapse (macro tanks while
    // micro survives) still trips the gate, as it did pre-migration.
    let g = generators::planted_partition(1_000, 5, 16.0, 0.05, 3);
    let stats = graphvite::util::gate::seed_sweep(&[42, 43, 44], |seed| {
        let mut t =
            Trainer::new(g.clone(), TrainConfig { epochs: 200, seed, ..small_cfg() }).unwrap();
        let r = t.train().unwrap();
        let rep = classify(&r.embeddings, &g, 0.05, 7);
        rep.micro_f1.min(rep.macro_f1)
    });
    // floor tightened 0.60 -> 0.65: gate-sweep artifacts show all three
    // pinned seeds scoring well above 0.7, so 0.65 keeps the unlucky-seed
    // allowance while narrowing the band a soft regression can hide in
    eprintln!("{}", stats.report("integration.classify_min_f1", 0.65));
    assert!(stats.pass_rate(0.65) >= 2.0 / 3.0, "{:?}", stats.scores);
}

#[test]
fn trained_embeddings_predict_links() {
    let g = generators::planted_partition(1_000, 5, 16.0, 0.05, 5);
    let split = LinkSplit::new(&g, 0.02, 6);
    let mut t =
        Trainer::new(split.train_graph.clone(), TrainConfig { epochs: 200, ..small_cfg() })
            .unwrap();
    let r = t.train().unwrap();
    let auc = link_prediction_auc(&r.embeddings, &split);
    assert!(auc > 0.75, "auc {auc}");
}

#[test]
fn deterministic_given_seed() {
    let g = generators::barabasi_albert(300, 3, 9);
    let run = |seed: u64| {
        let mut cfg = small_cfg();
        cfg.epochs = 5;
        cfg.seed = seed;
        cfg.num_workers = 1; // multi-worker result order is nondeterministic
        cfg.num_samplers = 1;
        cfg.collaboration = false;
        let mut t = Trainer::new(g.clone(), cfg).unwrap();
        t.train().unwrap().embeddings.vertex_matrix().to_vec()
    };
    assert_eq!(run(7), run(7), "same seed must reproduce bit-identically");
    assert_ne!(run(7), run(8), "different seeds must differ");
}

#[test]
fn worker_counts_agree_on_quality() {
    // parallel negative sampling must not cost accuracy (Table 6 claim)
    let g = generators::planted_partition(800, 4, 16.0, 0.05, 11);
    let f1_for = |workers: usize| {
        let mut cfg = TrainConfig { epochs: 150, ..small_cfg() };
        cfg.num_workers = workers;
        let mut t = Trainer::new(g.clone(), cfg).unwrap();
        let r = t.train().unwrap();
        classify(&r.embeddings, &g, 0.05, 7).micro_f1
    };
    let one = f1_for(1);
    let four = f1_for(4);
    assert!(
        four > one - 0.1,
        "4-worker F1 {four} collapsed vs 1-worker {one}"
    );
}

// ---------------------------------------------------------- persistence --

#[test]
fn embeddings_binary_roundtrip() {
    let g = generators::karate_club();
    let mut t = Trainer::new(g, TrainConfig { epochs: 10, ..small_cfg() }).unwrap();
    let r = t.train().unwrap();
    let path = tmp("emb_roundtrip.bin");
    embedding::save_embeddings_binary(&r.embeddings, &path).unwrap();
    let loaded = embedding::load_embeddings(&path).unwrap();
    assert_eq!(loaded.num_nodes(), r.embeddings.num_nodes());
    assert_eq!(loaded.dim(), r.embeddings.dim());
    assert_eq!(loaded.vertex_matrix(), r.embeddings.vertex_matrix());
    assert_eq!(loaded.context_matrix(), r.embeddings.context_matrix());
}

#[test]
fn embeddings_text_roundtrip() {
    let store = EmbeddingStore::init(20, 8, 3);
    let path = tmp("emb_roundtrip.txt");
    embedding::save_embeddings_text(&store, &path).unwrap();
    let loaded = embedding::load_embeddings_text(&path).unwrap();
    assert_eq!(loaded.num_nodes(), 20);
    assert_eq!(loaded.dim(), 8);
    for (a, b) in loaded.vertex_matrix().iter().zip(store.vertex_matrix()) {
        assert!((a - b).abs() < 1e-5);
    }
}

#[test]
fn graph_edge_list_roundtrip_with_labels() {
    let g = generators::planted_partition(200, 4, 8.0, 0.1, 13);
    let path = tmp("graph_roundtrip.txt");
    graph::save_edge_list(&g, &path).unwrap();
    let loaded = graph::load_edge_list(&path).unwrap();
    assert_eq!(loaded.num_nodes(), g.num_nodes());
    assert_eq!(loaded.num_edges(), g.num_edges());
    assert_eq!(loaded.labels(), g.labels());
    for v in (0..200u32).step_by(17) {
        assert_eq!(loaded.degree(v), g.degree(v));
    }
}

// ------------------------------------------------------------ baselines --

#[test]
fn all_baselines_produce_finite_embeddings() {
    let g = generators::barabasi_albert(300, 3, 15);
    let line = LineBaseline::train(&g, &LineConfig { dim: 16, epochs: 5, ..Default::default() })
        .unwrap();
    let dw = DeepWalkBaseline::train(
        &g,
        &DeepWalkConfig { dim: 16, walks_per_node: 2, ..Default::default() },
    )
    .unwrap();
    let mb = MinibatchGpuBaseline::train(
        &g,
        &MinibatchConfig { dim: 16, epochs: 1, ..Default::default() },
    )
    .unwrap();
    for (name, r) in [("line", &line), ("deepwalk", &dw), ("minibatch", &mb)] {
        assert_eq!(r.embeddings.num_nodes(), 300, "{name}");
        assert!(
            r.embeddings.vertex_matrix().iter().all(|x| x.is_finite()),
            "{name} has non-finite values"
        );
        assert!(r.stats.counters.samples_trained > 0, "{name}");
    }
}

#[test]
fn minibatch_gpu_moves_far_more_bus_bytes_than_coordinator() {
    // The Table 3 pathology: mini-batch SGD round-trips the full matrices
    // every batch, while GraphVite transfers per episode.
    let g = generators::barabasi_albert(500, 4, 17);
    let mb = MinibatchGpuBaseline::train(
        &g,
        &MinibatchConfig { dim: 16, epochs: 2, ..Default::default() },
    )
    .unwrap();
    let mut t = Trainer::new(g, TrainConfig { epochs: 2, ..small_cfg() }).unwrap();
    let gv = t.train().unwrap();
    let mb_bytes = mb.stats.counters.bytes_to_device + mb.stats.counters.bytes_from_device;
    let gv_bytes = gv.stats.counters.bytes_to_device + gv.stats.counters.bytes_from_device;
    assert!(
        mb_bytes > 5 * gv_bytes,
        "mini-batch {mb_bytes} vs coordinator {gv_bytes}: bus pathology not visible"
    );
}

// ------------------------------------------------------------- config --

#[test]
fn toml_config_drives_trainer() {
    let text = r#"
[train]
dim = 8
epochs = 3
num_workers = 2
num_samplers = 2
episode_size = 2000
backend = "native"
shuffle = "pseudo"
"#;
    let cfg = TrainConfig::from_toml_str(text).unwrap();
    let g = generators::karate_club();
    let mut t = Trainer::new(g, cfg).unwrap();
    let r = t.train().unwrap();
    assert_eq!(r.embeddings.dim(), 8);
}

#[test]
fn cli_parse_roundtrip() {
    use graphvite::cli::Args;
    let argv: Vec<String> = "train graph.txt --dim 32 --backend=native --no-wire-compression"
        .split_whitespace()
        .map(String::from)
        .collect();
    let a = Args::parse(&argv).unwrap();
    assert_eq!(a.command, "train");
    assert_eq!(a.get("dim"), Some("32"));
    assert_eq!(a.get("backend"), Some("native"));
    assert!(a.flag("no-wire-compression"));
    assert_eq!(a.positional, vec!["graph.txt"]);
    // the spec table rejects typos with a suggestion
    let argv: Vec<String> =
        "train graph.txt --dims 32".split_whitespace().map(String::from).collect();
    let err = Args::parse(&argv).unwrap_err().to_string();
    assert!(err.contains("did you mean --dim?"), "{err}");
}

// ----------------------------------------------------------- ablations --

#[test]
fn every_ablation_combination_trains() {
    let g = generators::barabasi_albert(200, 3, 19);
    for aug in [false, true] {
        for collab in [false, true] {
            for fixc in [false, true] {
                for shuffle in [ShuffleKind::None, ShuffleKind::Pseudo] {
                    let cfg = TrainConfig {
                        online_augmentation: aug,
                        collaboration: collab,
                        fix_context: fixc,
                        shuffle,
                        epochs: 2,
                        ..small_cfg()
                    };
                    let mut t = Trainer::new(g.clone(), cfg).unwrap();
                    let r = t.train().unwrap();
                    assert!(
                        r.stats.counters.samples_trained > 0,
                        "aug={aug} collab={collab} fixc={fixc} {shuffle:?}"
                    );
                }
            }
        }
    }
}

#[test]
fn experiments_tiny_scale_all_run() {
    // The `exp` CLI surface: every harness must complete at Tiny scale.
    // (Individually they are also exercised by the bench targets; this
    // catches wiring regressions in experiments::run.)
    use graphvite::experiments::{run, Scale};
    for name in ["table1", "table7"] {
        run(name, Scale::Tiny).unwrap();
    }
}
