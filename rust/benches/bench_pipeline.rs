//! Transfer-engine comparison: serial dispatch vs pipelined waves vs
//! pipelined + partition residency (the PR-3 perf work; no paper table —
//! this tracks the repo's own host↔device data path).
//!
//! Run with `cargo bench --bench bench_pipeline`; set
//! `GRAPHVITE_BENCH_SCALE=tiny|small|full` for workload size and
//! `GRAPHVITE_BENCH_FAST=1` for the CI smoke run (single sample).
//!
//! Unlike the table/figure targets this bench **self-records**: besides
//! printing the usual `bench` lines + markdown table it writes
//! `BENCH_pipeline_<scale>.json` next to this file (the benches/README
//! convention), so every run extends the perf trajectory without the
//! shell capture one-liner.

use graphvite::config::{BackendKind, TrainConfig};
use graphvite::coordinator::Trainer;
use graphvite::experiments::{Scale, Workload};
use graphvite::graph::Graph;
use graphvite::metrics::TrainStats;
use graphvite::pool::ShuffleKind;
use graphvite::util::bench::{Bencher, Table};
use graphvite::util::human_bytes;

fn scale_name(scale: Scale) -> &'static str {
    match scale {
        Scale::Tiny => "tiny",
        Scale::Small => "small",
        Scale::Full => "full",
    }
}

fn workload(scale: Scale) -> (Graph, TrainConfig) {
    let nodes = match scale {
        Scale::Tiny => 2_000,
        Scale::Small => 20_000,
        Scale::Full => 100_000,
    };
    let graph = Workload::scale_free(nodes, 5, 0x717);
    let cfg = TrainConfig {
        dim: 64,
        epochs: if scale == Scale::Tiny { 2 } else { 4 },
        num_workers: 2,
        num_partitions: 4, // multi-wave groups: the pipelined case
        num_samplers: 2,
        episode_size: (nodes / 2).max(4_000),
        batch_size: 256,
        fix_context: false, // required for partitions > workers
        backend: BackendKind::best_available(),
        shuffle: ShuffleKind::Pseudo,
        seed: 11,
        ..TrainConfig::default()
    };
    (graph, cfg)
}

fn main() {
    let scale = Scale::from_env();
    let fast = std::env::var("GRAPHVITE_BENCH_FAST").is_ok();
    let mut b = if fast { Bencher::with_iters(0, 1) } else { Bencher::with_iters(1, 3) };

    let (graph, base) = workload(scale);
    let samples = base.total_samples(graph.num_edges()) as f64;
    println!(
        "bench_pipeline scale={} ({} nodes, {} edges, backend {})",
        scale_name(scale),
        graph.num_nodes(),
        graph.num_edges(),
        base.backend.name()
    );

    let variants: [(&str, bool, bool); 3] = [
        ("serial", false, false),
        ("pipelined", true, false),
        ("pipelined+residency", true, true),
    ];
    let mut table = Table::new(
        "Transfer engine: serial vs pipelined vs residency",
        &[
            "config",
            "train s",
            "Msamples/s",
            "to-device",
            "from-device",
            "hits",
            "saved",
            "gather+scatter ms",
        ],
    );
    let mut recorded: Vec<String> = Vec::new();

    for (name, pipeline, residency) in variants {
        let mut last: Option<TrainStats> = None;
        b.bench_items(&format!("train.{name}"), samples, || {
            let cfg = TrainConfig {
                pipeline_transfers: pipeline,
                residency,
                ..base.clone()
            };
            let mut t = Trainer::new(graph.clone(), cfg).unwrap();
            let r = t.train().unwrap();
            let trained = r.stats.counters.samples_trained;
            last = Some(r.stats);
            trained
        });
        let s = last.expect("bench ran at least once");
        let c = &s.counters;
        table.row(&[
            name.to_string(),
            format!("{:.3}", s.train_secs),
            format!("{:.3}", s.throughput() / 1e6),
            human_bytes(c.bytes_to_device),
            human_bytes(c.bytes_from_device),
            c.residency_hits.to_string(),
            human_bytes(c.bytes_saved),
            format!("{:.1}", s.transfer_secs() * 1e3),
        ]);
        recorded.push(format!(
            "counters {name}: train_secs {:.6} samples_trained {} bytes_to_device {} \
             bytes_from_device {} residency_hits {} bytes_saved {} gather_nanos {} \
             scatter_nanos {}",
            s.train_secs,
            c.samples_trained,
            c.bytes_to_device,
            c.bytes_from_device,
            c.residency_hits,
            c.bytes_saved,
            c.gather_nanos,
            c.scatter_nanos
        ));
    }

    table.print();
    for line in &recorded {
        println!("{line}");
    }

    // self-record per the benches/README BENCH_*.json convention
    let mut lines: Vec<String> = b
        .results()
        .iter()
        .map(|r| {
            format!(
                "bench {} {:.9} ± {:.9} min {:.9}",
                r.name, r.mean_secs, r.stddev_secs, r.min_secs
            )
        })
        .collect();
    lines.extend(table.to_markdown().lines().map(String::from));
    lines.extend(recorded.iter().cloned());
    let json = to_json(&format!("bench_pipeline scale={}", scale_name(scale)), &lines);
    let path = format!(
        "{}/benches/BENCH_pipeline_{}.json",
        env!("CARGO_MANIFEST_DIR"),
        scale_name(scale)
    );
    match std::fs::write(&path, json) {
        Ok(()) => println!("recorded {path}"),
        Err(e) => eprintln!("could not record {path}: {e}"),
    }
}

/// Minimal JSON emitter (the offline crate set has no serde): an object
/// of the benches/README shape `{"argv": ..., "lines": [...]}`.
fn to_json(argv: &str, lines: &[String]) -> String {
    fn esc(s: &str) -> String {
        let mut out = String::with_capacity(s.len() + 2);
        for ch in s.chars() {
            match ch {
                '"' => out.push_str("\\\""),
                '\\' => out.push_str("\\\\"),
                '\n' => out.push_str("\\n"),
                c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
                c => out.push(c),
            }
        }
        out
    }
    let mut json = String::from("{\n");
    json.push_str(&format!(" \"argv\": \"{}\",\n", esc(argv)));
    json.push_str(" \"lines\": [\n");
    for (i, line) in lines.iter().enumerate() {
        let comma = if i + 1 == lines.len() { "" } else { "," };
        json.push_str(&format!("  \"{}\"{comma}\n", esc(line)));
    }
    json.push_str(" ]\n}\n");
    json
}
