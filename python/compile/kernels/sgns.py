"""Layer-1 Pallas kernel: SGNS forward + gradient over a tile of pairs.

This is the compute hot-spot of GraphVite's embedding-training stage: for a
flattened tile of (u, v, label, weight) rows it computes the binary
cross-entropy on the embedding dot product plus the closed-form gradients.

Hardware adaptation (paper CUDA kernel -> Pallas, see DESIGN.md
section Hardware-Adaptation): the CUDA kernel stages embedding rows into
on-chip *shared memory* per thread-block; here the BlockSpec tiles the
sample axis so each grid step holds a ``[TB, D]`` tile in *VMEM*. The
warp-level dot product becomes a vectorized reduction on the VPU; the
rank-1 gradient outer products are dense ``[TB, D]`` elementwise work.

``interpret=True`` is mandatory in this image: real TPU lowering emits a
Mosaic custom-call that the CPU PJRT plugin cannot execute. Interpret mode
lowers the kernel to plain HLO ops, so the same artifact runs on the rust
CPU PJRT client with identical numerics.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default tile along the sample axis. 256 rows x 128 dims x 4 B x
# (2 inputs + 2 grads) ~= 1 MiB of VMEM per grid step -- comfortably under
# the ~16 MiB VMEM budget of a TPU core, leaving room for double buffering.
DEFAULT_TILE = 256


def _sgns_kernel(u_ref, v_ref, label_ref, weight_ref, gu_ref, gv_ref, loss_ref):
    """One grid step: SGNS loss + grads for a [TB, D] tile of pairs."""
    u = u_ref[...]
    v = v_ref[...]
    label = label_ref[...]
    weight = weight_ref[...]

    s = jnp.sum(u * v, axis=-1)  # [TB] dot products (VPU reduction)
    p = jax.nn.sigmoid(s)
    g = (p - label) * weight  # dL/ds

    gu_ref[...] = g[:, None] * v  # rank-1 updates
    gv_ref[...] = g[:, None] * u
    # stable: softplus(s) - label*s = max(s,0) + log1p(exp(-|s|)) - label*s
    sp = jnp.maximum(s, 0.0) + jnp.log1p(jnp.exp(-jnp.abs(s)))
    loss_ref[...] = weight * (sp - label * s)


def sgns_grad(u, v, label, weight, *, tile=None):
    """Pallas SGNS kernel over N pairs.

    u, v           : [N, D] float32 embedding rows (already gathered)
    label, weight  : [N] float32
    returns (grad_u [N,D], grad_v [N,D], loss [N])

    N must be divisible by the tile size; callers (model.py) choose shapes
    so this holds. Tile defaults to min(DEFAULT_TILE, N).
    """
    n, d = u.shape
    tb = tile if tile is not None else min(DEFAULT_TILE, n)
    if n % tb != 0:
        raise ValueError(f"sample count {n} not divisible by tile {tb}")

    grid = (n // tb,)
    row_spec = pl.BlockSpec((tb, d), lambda i: (i, 0))
    vec_spec = pl.BlockSpec((tb,), lambda i: (i,))

    return pl.pallas_call(
        _sgns_kernel,
        grid=grid,
        in_specs=[row_spec, row_spec, vec_spec, vec_spec],
        out_specs=[row_spec, row_spec, vec_spec],
        out_shape=[
            jax.ShapeDtypeStruct((n, d), u.dtype),
            jax.ShapeDtypeStruct((n, d), u.dtype),
            jax.ShapeDtypeStruct((n,), u.dtype),
        ],
        interpret=True,  # CPU-PJRT execution path; see module docstring
    )(u, v, label, weight)


@functools.partial(jax.jit, static_argnames=("tile",))
def sgns_grad_jit(u, v, label, weight, tile=None):
    """jit wrapper used by the pytest suite."""
    return sgns_grad(u, v, label, weight, tile=tile)
