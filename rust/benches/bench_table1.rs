//! Regenerates paper Table 1 — the analytic memory-cost model.
//!
//! Run with `cargo bench --bench bench_table1`; set
//! GRAPHVITE_BENCH_SCALE=tiny|small|full to change the workload size
//! (default tiny so `cargo bench` completes quickly; EXPERIMENTS.md
//! records the `small` runs).

fn scale() -> graphvite::experiments::Scale {
    std::env::var("GRAPHVITE_BENCH_SCALE")
        .ok()
        .and_then(|s| graphvite::experiments::Scale::parse(&s))
        .unwrap_or(graphvite::experiments::Scale::Tiny)
}

fn main() {
    graphvite::experiments::run("table1", scale()).expect("table1 experiment");
}
