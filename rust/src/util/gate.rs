//! Test support for empirical quality gates (classification F1 floors,
//! AUC floors, loss-decrease checks).
//!
//! Several integration tests assert that a stochastic training run clears
//! a fixed quality floor. A single-seed assertion conflates two distinct
//! failures — "the pipeline is corrupted" (score collapses for *every*
//! seed) and "this seed is unlucky" (score dips for *one* seed) — which
//! is why those thresholds have historically been set loose (see ROADMAP
//! "Flaky-threshold audit"). [`seed_sweep`] runs the gated metric over a
//! *pinned* list of seeds and reports per-seed scores plus aggregate
//! stats, so a gate can assert on the pass *rate* (robust to one unlucky
//! seed, still trips on corruption) and so CI logs accumulate the
//! pass-rate evidence needed to tighten a floor deliberately.

/// Per-seed scores of one gate sweep.
#[derive(Debug, Clone)]
pub struct SweepStats {
    /// `(seed, score)` in sweep order.
    pub scores: Vec<(u64, f64)>,
}

/// Run `metric` once per pinned seed and collect the scores.
pub fn seed_sweep(seeds: &[u64], mut metric: impl FnMut(u64) -> f64) -> SweepStats {
    SweepStats { scores: seeds.iter().map(|&s| (s, metric(s))).collect() }
}

impl SweepStats {
    /// Fraction of seeds whose score clears `floor`.
    pub fn pass_rate(&self, floor: f64) -> f64 {
        if self.scores.is_empty() {
            return 0.0;
        }
        let passed = self.scores.iter().filter(|(_, x)| *x > floor).count();
        passed as f64 / self.scores.len() as f64
    }

    pub fn min(&self) -> f64 {
        self.scores.iter().map(|(_, x)| *x).fold(f64::INFINITY, f64::min)
    }

    pub fn mean(&self) -> f64 {
        if self.scores.is_empty() {
            return 0.0;
        }
        self.scores.iter().map(|(_, x)| *x).sum::<f64>() / self.scores.len() as f64
    }

    /// One-line record for CI logs: grep for `gate-sweep` across runs to
    /// collect the pass-rate statistics the flaky-threshold audit needs.
    pub fn report(&self, name: &str, floor: f64) -> String {
        let per_seed: Vec<String> = self
            .scores
            .iter()
            .map(|(s, x)| format!("seed {s}: {x:.4}"))
            .collect();
        format!(
            "gate-sweep {name}: floor {floor} pass-rate {:.2} min {:.4} mean {:.4} [{}]",
            self.pass_rate(floor),
            self.min(),
            self.mean(),
            per_seed.join(", ")
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sweep_runs_every_seed_in_order() {
        let stats = seed_sweep(&[3, 1, 2], |s| s as f64);
        assert_eq!(stats.scores, vec![(3, 3.0), (1, 1.0), (2, 2.0)]);
    }

    #[test]
    fn aggregates() {
        let stats = seed_sweep(&[1, 2, 3, 4], |s| s as f64);
        assert_eq!(stats.pass_rate(2.5), 0.5);
        assert_eq!(stats.min(), 1.0);
        assert_eq!(stats.mean(), 2.5);
        // strictly-above semantics: a score exactly at the floor fails
        assert_eq!(stats.pass_rate(4.0), 0.0);
    }

    #[test]
    fn empty_sweep_is_a_failure_not_a_panic() {
        let stats = seed_sweep(&[], |_| unreachable!());
        assert_eq!(stats.pass_rate(0.0), 0.0);
        assert_eq!(stats.mean(), 0.0);
    }

    #[test]
    fn report_names_every_seed() {
        let stats = seed_sweep(&[7, 8], |s| s as f64 / 10.0);
        let r = stats.report("demo", 0.5);
        assert!(r.contains("gate-sweep demo"));
        assert!(r.contains("seed 7"));
        assert!(r.contains("seed 8"));
        assert!(r.contains("pass-rate"));
    }
}
