//! Episode scheduling (paper §3.2, Algorithm 3).
//!
//! For `P` partitions the sample pool redistributes into a `P × P` block
//! grid. A *pool pass* visits every block exactly once, organized as `P`
//! *episode groups*; group `g` is the latin-square diagonal
//! `{(i, (i+g) mod P) | i}` — `P` mutually **orthogonal** blocks (no two
//! share a vertex-partition row or context-partition column), which is
//! what lets the workers run without any inter-worker synchronization.
//!
//! With the bus-usage optimization (§3.4, `fix_context`) the group is
//! transposed: worker `i` keeps context partition `i` resident and the
//! *vertex* partitions rotate — saving the context transfer entirely.
//!
//! **Residency-aware group ordering** ([`EpisodeSchedule::with_residency_order`]).
//! Groups are mutually independent (each covers a disjoint diagonal of
//! blocks), so any execution order is valid. The slot occupied by a
//! partition in group `g` is a function of `g`, and slots with equal
//! residue mod `n` belong to the same worker — so executing groups in
//! residue classes mod `n` (`0, n, 2n, …, 1, n+1, …`) makes the rotating
//! matrix's partitions return to the *same worker* for every transition
//! inside a class. The transfer engine then keeps them resident and only
//! re-uploads at the `n` class boundaries per pass instead of every
//! group: rotating-partition uploads drop from `P` to `n` per partition
//! per pass (the sticky matrix — `vid = slot` without `fix_context` —
//! never leaves its worker at all).

/// One block assignment inside an episode group.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Assignment {
    /// Worker (simulated GPU) index executing this block.
    pub worker: usize,
    /// Vertex partition id (row of the grid).
    pub vid: usize,
    /// Context partition id (column of the grid).
    pub cid: usize,
}

/// Static schedule for one pool pass.
#[derive(Debug, Clone)]
pub struct EpisodeSchedule {
    num_parts: usize,
    num_workers: usize,
    fix_context: bool,
    /// Group ids in execution order (identity unless residency-ordered).
    group_order: Vec<usize>,
}

impl EpisodeSchedule {
    /// `num_parts` must be a multiple of `num_workers` (the paper's
    /// "any number of partitions greater than n … in subgroups of n").
    pub fn new(num_parts: usize, num_workers: usize, fix_context: bool) -> Self {
        assert!(num_parts >= 1 && num_workers >= 1);
        assert!(
            num_parts % num_workers == 0,
            "num_parts {num_parts} must be a multiple of num_workers {num_workers}"
        );
        assert!(
            !fix_context || num_parts == num_workers,
            "fix_context requires num_parts == num_workers (paper section 3.4)"
        );
        EpisodeSchedule {
            num_parts,
            num_workers,
            fix_context,
            group_order: (0..num_parts).collect(),
        }
    }

    /// Reorder group execution into residue classes mod `num_workers`
    /// (`0, n, 2n, …, 1, n+1, …`) so the rotating matrix's partitions
    /// stay sticky to workers inside each class (see the module docs).
    /// Coverage and per-group orthogonality are unchanged — groups are
    /// independent — but the training *order* differs, so runs with and
    /// without this ordering are distinct (equally valid) trajectories.
    pub fn with_residency_order(mut self) -> Self {
        let (p, n) = (self.num_parts, self.num_workers);
        self.group_order = (0..n).flat_map(|r| (0..p / n).map(move |q| q * n + r)).collect();
        self
    }

    /// Group ids in execution order.
    pub fn ordered_groups(&self) -> &[usize] {
        &self.group_order
    }

    pub fn num_parts(&self) -> usize {
        self.num_parts
    }

    /// Episode groups per pool pass (= `num_parts`).
    pub fn num_groups(&self) -> usize {
        self.num_parts
    }

    /// Waves per group: orthogonal blocks processed `num_workers` at a time.
    pub fn waves_per_group(&self) -> usize {
        self.num_parts / self.num_workers
    }

    /// The assignments of episode group `g`, wave `w`.
    pub fn wave(&self, g: usize, w: usize) -> Vec<Assignment> {
        assert!(g < self.num_groups() && w < self.waves_per_group());
        let p = self.num_parts;
        (0..self.num_workers)
            .map(|i| {
                let slot = w * self.num_workers + i; // position within the diagonal
                if self.fix_context {
                    // context pinned to worker: cid = i, vertex rotates
                    let cid = slot;
                    let vid = (slot + g) % p;
                    Assignment { worker: i, vid, cid }
                } else {
                    let vid = slot;
                    let cid = (slot + g) % p;
                    Assignment { worker: i, vid, cid }
                }
            })
            .collect()
    }

    /// All waves of group `g` flattened.
    pub fn group(&self, g: usize) -> Vec<Assignment> {
        (0..self.waves_per_group())
            .flat_map(|w| self.wave(g, w))
            .collect()
    }

    /// Every assignment of a full pool pass, in execution order (one
    /// inner Vec per group, groups following [`Self::ordered_groups`]).
    pub fn full_pass(&self) -> Vec<Vec<Assignment>> {
        self.group_order.iter().map(|&g| self.group(g)).collect()
    }

    /// The full pass flattened into dispatch order — the sequence the
    /// coordinator walks every pool pass. The transfer engine derives its
    /// next-toucher (residency) tables from this.
    pub fn execution_sequence(&self) -> Vec<Assignment> {
        self.group_order.iter().flat_map(|&g| self.group(g)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn check_pass(parts: usize, workers: usize, fix_context: bool) {
        let s = EpisodeSchedule::new(parts, workers, fix_context);
        let mut seen = vec![false; parts * parts];
        for group in s.full_pass() {
            // orthogonality within a group: distinct rows and columns
            let mut rows = vec![false; parts];
            let mut cols = vec![false; parts];
            for a in &group {
                assert!(!rows[a.vid], "row {} reused in group", a.vid);
                assert!(!cols[a.cid], "col {} reused in group", a.cid);
                rows[a.vid] = true;
                cols[a.cid] = true;
                assert!(!seen[a.vid * parts + a.cid], "block revisited");
                seen[a.vid * parts + a.cid] = true;
            }
            assert_eq!(group.len(), parts);
        }
        assert!(seen.iter().all(|&s| s), "not all blocks covered");
    }

    #[test]
    fn covers_all_blocks_orthogonally() {
        check_pass(4, 4, false);
        check_pass(4, 4, true);
        check_pass(1, 1, false);
        check_pass(8, 4, false);
        check_pass(6, 2, false);
    }

    #[test]
    fn fix_context_pins_cid_to_worker() {
        let s = EpisodeSchedule::new(4, 4, true);
        for g in 0..4 {
            for a in s.wave(g, 0) {
                assert_eq!(a.cid, a.worker);
            }
        }
    }

    #[test]
    fn rotating_vid_without_fix_context() {
        let s = EpisodeSchedule::new(4, 4, false);
        for g in 0..4 {
            for a in s.wave(g, 0) {
                assert_eq!(a.vid, a.worker);
                assert_eq!(a.cid, (a.worker + g) % 4);
            }
        }
    }

    #[test]
    fn residency_order_is_a_complete_permutation() {
        for (p, n) in [(4, 2), (6, 2), (8, 4), (4, 4), (1, 1)] {
            let s = EpisodeSchedule::new(p, n, false).with_residency_order();
            let mut seen = s.ordered_groups().to_vec();
            seen.sort_unstable();
            assert_eq!(seen, (0..p).collect::<Vec<_>>(), "p={p} n={n}");
            // coverage survives the reorder: every block visited once
            let mut blocks = vec![false; p * p];
            for a in s.execution_sequence() {
                assert!(!blocks[a.vid * p + a.cid], "block revisited");
                blocks[a.vid * p + a.cid] = true;
            }
            assert!(blocks.iter().all(|&b| b), "p={p} n={n}: not all blocks covered");
        }
        let s = EpisodeSchedule::new(4, 2, false).with_residency_order();
        assert_eq!(s.ordered_groups(), &[0, 2, 1, 3]);
        // square grids (P == n) have singleton residue classes: unchanged
        let s = EpisodeSchedule::new(4, 4, false).with_residency_order();
        assert_eq!(s.ordered_groups(), &[0, 1, 2, 3]);
    }

    #[test]
    fn residency_order_keeps_contexts_sticky_within_classes() {
        // p=4, n=2, standard schedule: order [0,2,1,3]. For the 0→2
        // transition every context partition must return to the worker
        // that just trained it (that is the whole point of the order).
        let s = EpisodeSchedule::new(4, 2, false).with_residency_order();
        let seq = s.execution_sequence();
        let worker_of = |group_pos: usize, cid: usize| {
            seq[group_pos * 4..(group_pos + 1) * 4]
                .iter()
                .find(|a| a.cid == cid)
                .map(|a| a.worker)
                .unwrap()
        };
        for cid in 0..4 {
            assert_eq!(worker_of(0, cid), worker_of(1, cid), "cid {cid} moved workers");
        }
    }

    #[test]
    fn execution_sequence_matches_full_pass() {
        let s = EpisodeSchedule::new(6, 2, false).with_residency_order();
        let flat: Vec<Assignment> = s.full_pass().into_iter().flatten().collect();
        assert_eq!(flat, s.execution_sequence());
        assert_eq!(flat.len(), 36);
    }

    #[test]
    #[should_panic(expected = "multiple")]
    fn rejects_nondivisible() {
        EpisodeSchedule::new(5, 2, false);
    }

    #[test]
    #[should_panic(expected = "fix_context")]
    fn rejects_fix_context_with_subgroups() {
        EpisodeSchedule::new(8, 4, true);
    }
}
