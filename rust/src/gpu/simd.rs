//! Hand-unrolled f32x8 SGNS kernels — the device backend selected by
//! [`crate::config::BackendKind::Simd`] (`backend = "simd"`).
//!
//! The scalar [`NativeWorker`](crate::gpu::NativeWorker) runs the SGNS
//! inner loops one lane at a time; most of a modern CPU's f32 throughput
//! sits in its vector units. This module supplies the same three
//! `dim`-wide inner loops ([`Kernels`]) in a portable 8-lane form that
//! stable Rust auto-vectorizes reliably:
//!
//! * fixed-width chunks via `split_at` / `chunks_exact` so the loop body
//!   has a compile-time trip count of 8 and no bounds checks
//!   (`try_into` to `&[f32; 8]` makes the length a type-level fact);
//! * eight independent accumulators in the [`Kernels::dot`] impl so the
//!   reduction has no loop-carried dependency — the shape LLVM turns
//!   into `mulps`/`fmadd` + a lane shuffle reduce on SSE/AVX/NEON;
//! * a scalar tail loop for the `dim % 8` remainder lanes, so every
//!   dimension is supported, not just multiples of 8.
//!
//! No `std::arch` intrinsics, no nightly `std::simd`, no external crates:
//! the unrolled form is plain stable Rust, portable to every target.
//!
//! **Numerics.** `axpy` and `apply_zero` are element-wise, so they are
//! bit-identical to the scalar kernels. `dot` reassociates its reduction
//! (8 partial sums + pairwise combine instead of one sequential sum),
//! which differs from the scalar result only by float reassociation
//! error — a few ULPs for embedding-scale values. The equivalence is
//! enforced by the property tests in `rust/tests/simd_kernels.rs`,
//! including remainder-lane dims; that is why the quality gates in
//! `rust/tests/regression.rs` carry over to this backend unchanged.

use crate::gpu::native::{minibatch_step, Kernels, Worker};

/// Lanes per unrolled block. Eight f32s = one AVX register (or two
/// NEON/SSE registers), and wide enough that the reduction tree in
/// the unrolled `dot` hides FMA latency.
pub const LANES: usize = 8;

/// Split a slice at the largest multiple of [`LANES`].
#[inline]
fn split_main_tail(a: &[f32]) -> (&[f32], &[f32]) {
    a.split_at(a.len() - a.len() % LANES)
}

/// Portable hand-unrolled 8-lane [`Kernels`] implementation.
pub struct UnrolledKernels;

impl Kernels for UnrolledKernels {
    #[inline]
    fn dot(a: &[f32], b: &[f32]) -> f32 {
        debug_assert_eq!(a.len(), b.len());
        let (am, at) = split_main_tail(a);
        let (bm, bt) = split_main_tail(b);
        let mut acc = [0.0f32; LANES];
        for (ca, cb) in am.chunks_exact(LANES).zip(bm.chunks_exact(LANES)) {
            let ca: &[f32; LANES] = ca.try_into().unwrap();
            let cb: &[f32; LANES] = cb.try_into().unwrap();
            for l in 0..LANES {
                acc[l] += ca[l] * cb[l];
            }
        }
        let mut tail = 0.0f32;
        for (x, y) in at.iter().zip(bt) {
            tail += x * y;
        }
        // pairwise lane reduce (matches the shuffle-reduce a vector ISA
        // would do; NOT the scalar left-to-right order — hence the
        // ULP-tolerance in the equivalence tests)
        (((acc[0] + acc[4]) + (acc[1] + acc[5])) + ((acc[2] + acc[6]) + (acc[3] + acc[7]))) + tail
    }

    #[inline]
    fn axpy(out: &mut [f32], g: f32, x: &[f32]) {
        debug_assert_eq!(out.len(), x.len());
        let split = out.len() - out.len() % LANES;
        let (om, ot) = out.split_at_mut(split);
        let (xm, xt) = x.split_at(split);
        for (co, cx) in om.chunks_exact_mut(LANES).zip(xm.chunks_exact(LANES)) {
            let co: &mut [f32; LANES] = co.try_into().unwrap();
            let cx: &[f32; LANES] = cx.try_into().unwrap();
            for l in 0..LANES {
                co[l] += g * cx[l];
            }
        }
        for (o, v) in ot.iter_mut().zip(xt) {
            *o += g * *v;
        }
    }

    #[inline]
    fn apply_zero(m: &mut [f32], g: &mut [f32], lr: f32) {
        debug_assert_eq!(m.len(), g.len());
        let split = m.len() - m.len() % LANES;
        let (mm, mt) = m.split_at_mut(split);
        let (gm, gt) = g.split_at_mut(split);
        for (cm, cg) in mm.chunks_exact_mut(LANES).zip(gm.chunks_exact_mut(LANES)) {
            let cm: &mut [f32; LANES] = cm.try_into().unwrap();
            let cg: &mut [f32; LANES] = cg.try_into().unwrap();
            for l in 0..LANES {
                cm[l] -= lr * cg[l];
                cg[l] = 0.0;
            }
        }
        for (mv, gv) in mt.iter_mut().zip(gt.iter_mut()) {
            *mv -= lr * *gv;
            *gv = 0.0;
        }
    }
}

/// One mini-batch step through the [`UnrolledKernels`] — the 8-lane twin
/// of [`native_minibatch_step`](crate::gpu::native_minibatch_step), with
/// identical semantics (same skeleton, same scatter-add accumulation) and
/// dot products that agree within reassociation error.
#[allow(clippy::too_many_arguments)]
pub fn simd_minibatch_step(
    vertex: &mut [f32],
    context: &mut [f32],
    dim: usize,
    pos_u: &[i32],
    pos_v: &[i32],
    neg_v: &[i32],
    k: usize,
    lr: f32,
    neg_weight: f32,
    grad_u_buf: &mut Vec<f32>,
    grad_c_buf: &mut Vec<f32>,
) -> f32 {
    minibatch_step::<UnrolledKernels>(
        vertex, context, dim, pos_u, pos_v, neg_v, k, lr, neg_weight, grad_u_buf, grad_c_buf,
    )
}

/// Pure-rust device worker running the hand-unrolled f32x8 kernels — the
/// [`crate::gpu::Backend`] behind `backend = "simd"`. An alias of the
/// same generic [`Worker`] as [`NativeWorker`](crate::gpu::NativeWorker),
/// so the two are identical in every scheduling-visible way (streaming
/// chunks, chunk size, negative count, gradient-buffer state) and the
/// coordinator cannot tell them apart — that is the point of the Backend
/// seam.
pub type SimdWorker = Worker<UnrolledKernels>;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpu::native::ScalarKernels;
    use crate::util::rng::Rng;

    fn vecs(n: usize, seed: u64) -> (Vec<f32>, Vec<f32>) {
        let mut rng = Rng::new(seed);
        let a = (0..n).map(|_| rng.range_f32(-1.0, 1.0)).collect();
        let b = (0..n).map(|_| rng.range_f32(-1.0, 1.0)).collect();
        (a, b)
    }

    #[test]
    fn dot_matches_scalar_all_remainders() {
        // every dim % 8 class, incl. 0 and sub-lane lengths
        for n in [0usize, 1, 3, 7, 8, 9, 15, 16, 17, 24, 31, 64, 100, 127, 128] {
            let (a, b) = vecs(n, n as u64 + 1);
            let s = ScalarKernels::dot(&a, &b);
            let u = UnrolledKernels::dot(&a, &b);
            // analytic reassociation bound: dim * eps * sum of |terms|
            let mag: f32 = a.iter().zip(&b).map(|(x, y)| (x * y).abs()).sum();
            let tol = 8.0 * n.max(1) as f32 * f32::EPSILON * mag + 1e-7;
            assert!((s - u).abs() <= tol, "dim {n}: scalar {s} vs unrolled {u} (tol {tol})");
        }
    }

    #[test]
    fn axpy_bitwise_identical_to_scalar() {
        for n in [0usize, 1, 7, 8, 9, 16, 17, 100] {
            let (x, base) = vecs(n, 1000 + n as u64);
            let (mut o1, mut o2) = (base.clone(), base);
            ScalarKernels::axpy(&mut o1, 0.37, &x);
            UnrolledKernels::axpy(&mut o2, 0.37, &x);
            assert_eq!(o1, o2, "dim {n}");
        }
    }

    #[test]
    fn apply_zero_bitwise_identical_and_clears() {
        for n in [0usize, 1, 7, 8, 9, 16, 17, 100] {
            let (m_base, g_base) = vecs(n, 2000 + n as u64);
            let (mut m1, mut g1) = (m_base.clone(), g_base.clone());
            let (mut m2, mut g2) = (m_base, g_base);
            ScalarKernels::apply_zero(&mut m1, &mut g1, 0.05);
            UnrolledKernels::apply_zero(&mut m2, &mut g2, 0.05);
            assert_eq!(m1, m2, "dim {n}");
            assert!(g1.iter().all(|&v| v == 0.0));
            assert!(g2.iter().all(|&v| v == 0.0));
        }
    }

    #[test]
    fn simd_step_trains_and_attracts() {
        // same shape as native.rs positive_pairs_attract, through the
        // unrolled path end-to-end (dim 12 exercises remainder lanes)
        let dim = 12;
        let mut rng = Rng::new(5);
        let mut v: Vec<f32> = (0..4 * dim).map(|_| rng.range_f32(-0.1, 0.1)).collect();
        let mut c: Vec<f32> = (0..4 * dim).map(|_| rng.range_f32(-0.1, 0.1)).collect();
        let dot_before = ScalarKernels::dot(&v[0..dim], &c[dim..2 * dim]);
        let (mut gu, mut gc) = (Vec::new(), Vec::new());
        for _ in 0..50 {
            simd_minibatch_step(
                &mut v, &mut c, dim, &[0], &[1], &[2], 1, 0.1, 5.0, &mut gu, &mut gc,
            );
        }
        let dot_after = ScalarKernels::dot(&v[0..dim], &c[dim..2 * dim]);
        assert!(dot_after > dot_before, "{dot_before} -> {dot_after}");
    }

    #[test]
    fn simd_worker_trains_chunks() {
        let mut w = SimdWorker::new(4, 2, 1, 5.0);
        let mut vertex = vec![0.01f32; 4 * 4];
        let mut context = vec![0.02f32; 4 * 4];
        let chunk = crate::gpu::ChunkPlan {
            pos_u: vec![0, 1],
            pos_v: vec![1, 2],
            neg_v: vec![2, 3],
            lr: 0.1,
            real: 2,
        };
        let counters = crate::metrics::Counters::default();
        let loss = w.train_chunks_in_place(
            &mut vertex,
            &mut context,
            std::slice::from_ref(&chunk),
            &counters,
        );
        assert!(loss.is_finite() && loss > 0.0);
        assert_eq!(counters.snapshot().device_steps, 1);
    }
}
