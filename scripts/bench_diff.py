#!/usr/bin/env python3
"""Diff freshly recorded BENCH_*.json files against committed baselines.

Usage:
    python3 scripts/bench_diff.py [--baseline-dir rust/benches/baselines] \
        rust/benches/BENCH_*.json

For every fresh file, looks for a baseline with the same basename under
the baseline directory and compares the `bench <name> <mean> ± <stddev>
min <min> ...` lines by name.  Regressions past the threshold (default
15%) on the *pipeline throughput* lines (names starting with `train.`)
emit a GitHub `::error` annotation and FAIL the run (non-zero exit);
everything else is informational.

The gate is armed: the committed baselines are real perf points, the
old `"provisional": true` grace period is over.  The 15% threshold
absorbs shared-runner noise (observed run-to-run jitter is well under
that); pass `--warn-only` to demote failures back to annotations for
local experiments.
"""

import argparse
import json
import os
import re
import sys

BENCH_RE = re.compile(
    r"^bench\s+(?P<name>.+?)\s+(?P<mean>[0-9.eE+-]+)\s+\xb1\s+(?P<std>[0-9.eE+-]+)"
    r"\s+min\s+(?P<min>[0-9.eE+-]+)"
)
# result_lines() writes a literal ± (U+00B1); accept a plain ASCII variant too
BENCH_RE_ASCII = re.compile(
    r"^bench\s+(?P<name>.+?)\s+(?P<mean>[0-9.eE+-]+)\s+\+/-\s+(?P<std>[0-9.eE+-]+)"
    r"\s+min\s+(?P<min>[0-9.eE+-]+)"
)


def parse_bench_lines(doc):
    out = {}
    for line in doc.get("lines", []):
        m = BENCH_RE.match(line) or BENCH_RE_ASCII.match(line)
        if m:
            out[m.group("name").strip()] = float(m.group("mean"))
    return out


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("fresh", nargs="+", help="freshly recorded BENCH_*.json files")
    ap.add_argument("--baseline-dir", default="rust/benches/baselines")
    ap.add_argument(
        "--threshold",
        type=float,
        default=0.15,
        help="fail when a train.* mean regresses past this fraction (default 0.15)",
    )
    ap.add_argument(
        "--warn-only",
        action="store_true",
        help="report regressions as ::warning and exit zero (local runs)",
    )
    args = ap.parse_args()

    regressions = 0
    for fresh_path in args.fresh:
        base_path = os.path.join(args.baseline_dir, os.path.basename(fresh_path))
        if not os.path.exists(base_path):
            print(f"bench-diff: no baseline for {os.path.basename(fresh_path)} — skipped "
                  f"(commit one under {args.baseline_dir}/ to start the trajectory)")
            continue
        with open(fresh_path) as f:
            fresh_doc = json.load(f)
        with open(base_path) as f:
            base_doc = json.load(f)
        fresh = parse_bench_lines(fresh_doc)
        base = parse_bench_lines(base_doc)
        print(f"bench-diff: {os.path.basename(fresh_path)} vs baseline")
        for name in sorted(base):
            if name not in fresh:
                print(f"  {name}: missing from the fresh run")
                continue
            b, f_ = base[name], fresh[name]
            if b <= 0:
                continue
            delta = (f_ - b) / b
            marker = ""
            gated = name.startswith("train.")
            if gated and delta > args.threshold:
                level = "warning" if args.warn_only else "error"
                print(f"::{level} title=bench regression::{name} mean {f_:.6g}s is "
                      f"{delta * 100:.1f}% over baseline {b:.6g}s (threshold "
                      f"{args.threshold * 100:.0f}%)")
                regressions += 1
                marker = "  <-- REGRESSION"
            print(f"  {name}: baseline {b:.6g}s -> fresh {f_:.6g}s ({delta * 100:+.1f}%)"
                  f"{marker}")
        for name in sorted(set(fresh) - set(base)):
            print(f"  {name}: new (no baseline entry)")

    print(f"bench-diff: {regressions} regression(s) past the "
          f"{args.threshold * 100:.0f}% threshold")
    if regressions and not args.warn_only:
        return 1  # the perf gate is armed: a train.* regression fails CI
    return 0


if __name__ == "__main__":
    sys.exit(main())
