//! Random-walk engine over any [`GraphStore`] — the in-RAM CSR or the
//! paged on-disk reader.
//!
//! Walks are uniform over neighbors for unit-weight graphs and
//! weight-proportional otherwise (per-node alias tables, built once —
//! the same O(E)-memory trick LINE/node2vec use). Resident stores serve
//! neighbor lists as borrowed slices ([`GraphStore::neighbors_slice`]),
//! so the in-RAM hot loop is unchanged; out-of-core stores stream each
//! step's neighborhood into a caller-owned scratch buffer instead.
//!
//! RNG discipline: a step consumes exactly the same draws regardless of
//! which store backs the graph — that is what makes training off a
//! packed file bitwise-identical to training off the loader (see
//! `rust/tests/ondisk.rs`). Note the weighted path still materializes
//! per-node alias tables (O(E) RAM) even over a paged store; the
//! unit-weight fast path — every synthetic workload and most real edge
//! lists — is fully out-of-core (tracked in ROADMAP).

use crate::graph::GraphStore;
use crate::sampling::AliasTable;
use crate::util::rng::Rng;

/// Neighbor-sampling strategy, chosen at construction from the graph.
enum NeighborChoice {
    /// Unit weights: sample neighbor index uniformly (no tables needed).
    Uniform,
    /// Weighted: one alias table per node with degree >= 2.
    Weighted(Vec<Option<AliasTable>>),
}

/// Reusable walk engine; cheap to share per thread (immutable — each
/// thread supplies its own scratch buffer for the streaming path).
pub struct RandomWalker<'g> {
    graph: &'g dyn GraphStore,
    choice: NeighborChoice,
}

impl<'g> RandomWalker<'g> {
    pub fn new(graph: &'g dyn GraphStore) -> Self {
        let choice = if graph.unit_weights() {
            NeighborChoice::Uniform
        } else {
            let mut targets = Vec::new();
            let mut weights = Vec::new();
            let tables = (0..graph.num_nodes() as u32)
                .map(|v| {
                    // resident stores lend the weights directly; only the
                    // out-of-core path decodes into the scratch buffers
                    let w: &[f32] = match graph.neighbor_weights_slice(v) {
                        Some(w) => w,
                        None => {
                            graph.neighborhood_into(v, &mut targets, &mut weights);
                            &weights
                        }
                    };
                    if w.len() >= 2 {
                        Some(AliasTable::new(w))
                    } else {
                        None
                    }
                })
                .collect();
            NeighborChoice::Weighted(tables)
        };
        RandomWalker { graph, choice }
    }

    /// One walk step from `v`; None if `v` has no neighbors. `scratch`
    /// holds the streamed neighbor list when the store is out-of-core
    /// (resident stores never touch it).
    #[inline]
    pub fn step(&self, v: u32, rng: &mut Rng, scratch: &mut Vec<u32>) -> Option<u32> {
        let nbrs: &[u32] = match self.graph.neighbors_slice(v) {
            Some(s) => s,
            None => {
                self.graph.successors_into(v, scratch);
                scratch.as_slice()
            }
        };
        match nbrs.len() {
            0 => None,
            1 => Some(nbrs[0]),
            n => {
                let idx = match &self.choice {
                    NeighborChoice::Uniform => rng.below_usize(n),
                    NeighborChoice::Weighted(tables) => {
                        tables[v as usize].as_ref().unwrap().sample(rng) as usize
                    }
                };
                Some(nbrs[idx])
            }
        }
    }

    /// Walk of up to `len` edges starting at `start`, writing nodes into
    /// `out` (cleared first; `out.len() <= len + 1`). Stops early at
    /// dead ends. Returns the number of nodes written.
    pub fn walk_into(
        &self,
        start: u32,
        len: usize,
        rng: &mut Rng,
        out: &mut Vec<u32>,
        scratch: &mut Vec<u32>,
    ) -> usize {
        out.clear();
        out.push(start);
        let mut cur = start;
        for _ in 0..len {
            match self.step(cur, rng, scratch) {
                Some(next) => {
                    out.push(next);
                    cur = next;
                }
                None => break,
            }
        }
        out.len()
    }

    /// Allocating convenience wrapper around [`Self::walk_into`].
    pub fn walk(&self, start: u32, len: usize, rng: &mut Rng) -> Vec<u32> {
        let mut out = Vec::with_capacity(len + 1);
        let mut scratch = Vec::new();
        self.walk_into(start, len, rng, &mut out, &mut scratch);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{generators, GraphBuilder};

    #[test]
    fn walk_stays_on_edges() {
        let g = generators::karate_club();
        let walker = RandomWalker::new(&g);
        let mut rng = Rng::new(1);
        for start in 0..34u32 {
            let path = walker.walk(start, 20, &mut rng);
            assert_eq!(path[0], start);
            for w in path.windows(2) {
                assert!(g.has_edge(w[0], w[1]), "{} -> {} not an edge", w[0], w[1]);
            }
        }
    }

    #[test]
    fn dead_end_stops_walk() {
        // path graph 0-1; node with single neighbor bounces back, fine;
        // isolated node 2 stops immediately.
        let g = GraphBuilder::new().with_num_nodes(3).add_edge(0, 1, 1.0).build();
        let walker = RandomWalker::new(&g);
        let mut rng = Rng::new(2);
        let path = walker.walk(2, 10, &mut rng);
        assert_eq!(path, vec![2]);
    }

    #[test]
    fn weighted_walk_prefers_heavy_edges() {
        // star: 0 connected to 1 (w=9) and 2 (w=1)
        let g = GraphBuilder::new()
            .add_edge(0, 1, 9.0)
            .add_edge(0, 2, 1.0)
            .build();
        let walker = RandomWalker::new(&g);
        let mut rng = Rng::new(3);
        let mut scratch = Vec::new();
        let mut count1 = 0;
        const N: usize = 20_000;
        for _ in 0..N {
            if walker.step(0, &mut rng, &mut scratch) == Some(1) {
                count1 += 1;
            }
        }
        let f = count1 as f64 / N as f64;
        assert!((f - 0.9).abs() < 0.02, "f={f}");
    }

    #[test]
    fn walk_into_reuses_buffer() {
        let g = generators::karate_club();
        let walker = RandomWalker::new(&g);
        let mut rng = Rng::new(4);
        let mut buf = Vec::new();
        let mut scratch = Vec::new();
        let n1 = walker.walk_into(0, 5, &mut rng, &mut buf, &mut scratch);
        assert_eq!(n1, buf.len());
        let n2 = walker.walk_into(1, 3, &mut rng, &mut buf, &mut scratch);
        assert_eq!(n2, buf.len());
        assert!(n2 <= 4);
    }

    #[test]
    fn identical_walks_over_ram_and_paged_stores() {
        // the step consumes identical RNG draws whether neighbors come
        // from the borrowed slice (in-RAM) or the streamed scratch
        // (paged) — the contract the packed/in-RAM bitwise training
        // equivalence rests on
        use crate::graph::ondisk::{pack_graph, PackOptions, PagedCsr};
        let g = generators::karate_club();
        let dir = std::env::temp_dir().join("graphvite_walk_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("karate.gvpk");
        pack_graph(&g, &path, &PackOptions { page_size: 64 }).unwrap();
        let p = PagedCsr::open(&path, 256).unwrap();
        let ram = RandomWalker::new(&g);
        let paged = RandomWalker::new(&p);
        let (mut r1, mut r2) = (Rng::new(9), Rng::new(9));
        for v in 0..34u32 {
            let a = ram.walk(v, 16, &mut r1);
            let b = paged.walk(v, 16, &mut r2);
            assert_eq!(a, b, "walks diverged from node {v}");
        }
    }
}
