//! AOT-artifact runtime.
//!
//! Two halves live here with very different portability:
//!
//! * **Manifest layer** (always compiled): locating the artifacts
//!   directory and parsing `manifest.txt` — the contract between
//!   `python/compile/aot.py` and the rust side. Pure std, needed even by
//!   native-only builds (the CLI `artifacts` command, capacity planning).
//! * **Device layer** (`pjrt` cargo feature): loading the HLO-text
//!   artifacts through the PJRT C API (`xla` crate) and executing them on
//!   a per-worker CPU PJRT client. This is the only code in the crate
//!   that touches `xla`; the default build omits it entirely and the
//!   [`crate::gpu::Backend`] seam falls back to the native trainer.
//!
//! Device-layer design notes (see `Device`):
//! * HLO **text** is the interchange format (`HloModuleProto::from_text_file`
//!   reassigns instruction ids; serialized protos from jax >= 0.5 are
//!   rejected by xla_extension 0.5.1).
//! * PJRT types hold raw pointers and are not `Send`; every device worker
//!   thread owns its *own* `Device` (client + compiled executable),
//!   mirroring one GPU per worker in the paper.
//! * The PJRT C wrapper returns a computation's outputs as a **single
//!   tuple buffer** (no untupling — verified empirically in
//!   `rust/tests/hlo_runtime.rs`), so device-resident chaining of the
//!   (vertex, context) state across executes is not possible through this
//!   API. The train loop instead chains host `Literal`s: each execute
//!   uploads the partitions and downloads them updated. This round-trip
//!   *is* the bus transfer the paper's episode design amortizes; the
//!   transfer counters in [`crate::metrics`] account for it, and the
//!   per-execute sample count (S x B) plays the role of the paper's
//!   batched-transfer granularity (section 3.4).

mod manifest;

pub use manifest::{ArtifactKind, ArtifactMeta, Manifest};

#[cfg(feature = "pjrt")]
mod device;

#[cfg(feature = "pjrt")]
pub use device::{literal_f32, literal_i32, Device, KernelDevice};

use anyhow::Result;

/// Locate the artifacts directory: `$GRAPHVITE_ARTIFACTS` or
/// `<repo>/artifacts` relative to the current dir / crate root.
pub fn artifacts_dir() -> std::path::PathBuf {
    if let Ok(dir) = std::env::var("GRAPHVITE_ARTIFACTS") {
        return dir.into();
    }
    for base in [
        std::path::PathBuf::from("artifacts"),
        std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts"),
    ] {
        if base.join("manifest.txt").exists() {
            return base;
        }
    }
    std::path::PathBuf::from("artifacts")
}

/// Load the manifest from [`artifacts_dir`].
pub fn default_manifest() -> Result<Manifest> {
    Manifest::load(artifacts_dir())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn artifacts_dir_env_override() {
        std::env::set_var("GRAPHVITE_ARTIFACTS", "/custom/path");
        assert_eq!(artifacts_dir(), std::path::PathBuf::from("/custom/path"));
        std::env::remove_var("GRAPHVITE_ARTIFACTS");
    }
}
