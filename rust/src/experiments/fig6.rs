//! Figure 6 — speedup vs number of workers ("GPUs") × samplers per
//! worker (CPU threads). Shape: near-planar speedup in both axes, around
//! half the theoretical maximum at the largest configuration.
//!
//! TESTBED NOTE: one CPU core — measured wall clock shows coordination
//! overhead only. The projected table applies the critical-path model
//! (device compute / workers, sampling / samplers, overlapped when the
//! double buffer is on) to the measured per-stage times; that is the
//! quantity the paper's Figure 6 plots.

use anyhow::Result;

use crate::coordinator::Trainer;
use crate::experiments::presets::{Scale, Workload};
use crate::util::bench::Table;

pub fn run(scale: Scale) -> Result<()> {
    let w = Workload::youtube_like(scale);
    let samplers_per: Vec<usize> = vec![1, 2, 3];
    let workers_axis: Vec<usize> = vec![1, 2, 4];

    // baseline: 1 worker, 1 sampler
    let mut base_cfg = w.config.clone();
    base_cfg.num_workers = 1;
    base_cfg.num_samplers = 1;
    let mut trainer = Trainer::new(w.graph.clone(), base_cfg)?;
    let base = trainer.train()?.stats.throughput();

    let mut headers: Vec<String> = vec!["workers \\ samplers/worker".into()];
    headers.extend(samplers_per.iter().map(|s| format!("{s}")));
    let headers_ref: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
    let mut table = Table::new(
        "Figure 6 — speedup over (1 worker, 1 sampler) baseline",
        &headers_ref,
    );
    let mut proj_table = Table::new(
        "Figure 6 (projected) — critical-path speedup on parallel hardware",
        &headers_ref,
    );
    // projected baseline: 1 worker, 1 sampler on dedicated cores
    let mut base_cfg = w.config.clone();
    base_cfg.num_workers = 1;
    base_cfg.num_samplers = 1;
    let mut trainer = Trainer::new(w.graph.clone(), base_cfg)?;
    let base_stats = trainer.train()?.stats;
    let proj_base = base_stats.projected_parallel_secs(1, true);
    let total_samples = base_stats.counters.samples_trained as f64;

    for &workers in &workers_axis {
        let mut row = vec![format!("{workers}")];
        let mut proj_row = vec![format!("{workers}")];
        for &sp in &samplers_per {
            let mut cfg = w.config.clone();
            cfg.num_workers = workers;
            cfg.num_samplers = (sp * workers).max(1);
            let num_samplers = cfg.num_samplers;
            let mut trainer = Trainer::new(w.graph.clone(), cfg)?;
            let stats = trainer.train()?.stats;
            row.push(format!("{:.2}x", stats.throughput() / base.max(1e-9)));
            // sampling divides across sampler threads on real hardware
            let device = stats.device_secs() / workers as f64;
            let sampling = stats.sampling_secs() / num_samplers as f64;
            let coordinator =
                (stats.train_secs - stats.device_secs() - stats.sampling_secs()).max(0.0);
            let projected = device.max(sampling) + coordinator;
            let scale_adj = stats.counters.samples_trained as f64 / total_samples;
            proj_row.push(format!("{:.2}x", proj_base * scale_adj / projected.max(1e-9)));
        }
        table.row(&row);
        proj_table.row(&proj_row);
    }
    table.print();
    proj_table.print();
    Ok(())
}
