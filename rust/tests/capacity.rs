//! Heterogeneous capacity-aware sharding suite (ISSUE 4 acceptance):
//!
//! * rectangular waves stay row/column-disjoint and a pass covers every
//!   block exactly once, with per-wave block counts matching the declared
//!   capacities (the restated orthogonality invariants);
//! * homogeneous capacities reproduce the PR-3 behavior — the schedule
//!   bitwise, and trained embeddings bitwise (declaring `[1, 1, …]` only
//!   bounds the residency cache, which is pure data movement);
//! * a 4-partition grid streams through 2 workers of unequal capacity to
//!   completion with bounded per-worker residency (the fail-loud
//!   worker-side cap makes completion itself the assertion; the planner
//!   bound is asserted step-by-step against the engine), and the
//!   transfer ledger still balances byte-for-byte;
//! * pipelined and serial dispatch stay bitwise-equivalent on
//!   heterogeneous waves (blocks of a wave are still slots of one
//!   diagonal, however many land on one worker).

use graphvite::config::{BackendKind, TrainConfig};
use graphvite::coordinator::transfer::TransferEngine;
use graphvite::coordinator::{TrainResult, Trainer};
use graphvite::graph::{generators, Graph};
use graphvite::pool::ShuffleKind;
use graphvite::scheduler::EpisodeSchedule;

fn base_cfg() -> TrainConfig {
    TrainConfig {
        dim: 8,
        epochs: 4,
        num_workers: 2,
        num_partitions: 4,
        num_samplers: 2,
        episode_size: 2_000,
        batch_size: 64,
        fix_context: false, // required for num_partitions > num_workers
        backend: BackendKind::test_backend(),
        shuffle: ShuffleKind::Pseudo,
        seed: 123,
        ..TrainConfig::default()
    }
}

fn graph() -> Graph {
    generators::planted_partition(400, 4, 12.0, 0.05, 17)
}

fn run(g: &Graph, cfg: TrainConfig) -> TrainResult {
    let mut t = Trainer::new(g.clone(), cfg).unwrap();
    t.train().unwrap()
}

// ------------------------------------------------- schedule properties --

#[test]
fn rectangular_waves_are_orthogonal_and_cover_every_block_once() {
    for (p, caps) in [
        (4, vec![1usize, 3]),
        (8, vec![1, 3]),
        (8, vec![2, 2]),
        (12, vec![1, 2, 3]),
        (6, vec![1, 2]),
    ] {
        for ordered in [false, true] {
            let mut s = EpisodeSchedule::with_capacities(p, &caps, false);
            if ordered {
                s = s.with_residency_order();
            }
            let mut seen = vec![false; p * p];
            for group in s.full_pass() {
                let mut rows = vec![false; p];
                let mut cols = vec![false; p];
                for a in &group {
                    assert!(!rows[a.vid], "row {} reused (p={p} caps={caps:?})", a.vid);
                    assert!(!cols[a.cid], "col {} reused (p={p} caps={caps:?})", a.cid);
                    rows[a.vid] = true;
                    cols[a.cid] = true;
                    assert!(!seen[a.vid * p + a.cid], "block revisited");
                    seen[a.vid * p + a.cid] = true;
                }
            }
            assert!(seen.iter().all(|&b| b), "p={p} caps={caps:?}: blocks missing");
        }
    }
}

#[test]
fn waves_respect_declared_capacities_proportionally() {
    let caps = [1usize, 3];
    let s = EpisodeSchedule::with_capacities(8, &caps, false);
    assert_eq!(s.total_capacity(), 4);
    assert_eq!(s.waves_per_group(), 2);
    for g in 0..s.num_groups() {
        for w in 0..s.waves_per_group() {
            let wave = s.wave(g, w);
            assert_eq!(wave.len(), 4, "a wave carries total_capacity blocks");
            for (i, &c) in caps.iter().enumerate() {
                assert_eq!(
                    wave.iter().filter(|a| a.worker == i).count(),
                    c,
                    "worker {i} share of group {g} wave {w}"
                );
            }
        }
    }
    // 3x the capacity => 3x the blocks per group
    assert_eq!(s.blocks_per_group(1), 3 * s.blocks_per_group(0));
}

#[test]
fn homogeneous_capacities_reproduce_the_default_schedule_bitwise() {
    for (p, n) in [(4, 2), (6, 2), (8, 4), (4, 4)] {
        let ones = vec![1usize; n];
        let a = EpisodeSchedule::new(p, n, false).with_residency_order();
        let b = EpisodeSchedule::with_capacities(p, &ones, false).with_residency_order();
        assert_eq!(a.execution_sequence(), b.execution_sequence(), "p={p} n={n}");
    }
}

// -------------------------------------------- end-to-end equivalences --

#[test]
fn homogeneous_capacities_train_bitwise_identical_embeddings() {
    // Declaring [1, 1] keeps the PR-3 schedule and only *bounds* the
    // residency caches (2 partitions per worker) — keep/elide decisions
    // are pure data movement under the versioned shipment protocol, so
    // the trained floats must not move by a single bit.
    let g = graph();
    let default_run = run(&g, base_cfg());
    let declared = run(&g, TrainConfig { worker_capacities: vec![1, 1], ..base_cfg() });
    assert_eq!(
        default_run.embeddings.vertex_matrix(),
        declared.embeddings.vertex_matrix(),
        "vertex matrices diverged"
    );
    assert_eq!(
        default_run.embeddings.context_matrix(),
        declared.embeddings.context_matrix(),
        "context matrices diverged"
    );
    let a = &default_run.stats.counters;
    let b = &declared.stats.counters;
    assert_eq!(a.samples_trained, b.samples_trained);
    // same job multiset => the would-ship byte total is conserved, the
    // bounded run just elides (potentially) fewer uploads
    assert_eq!(
        a.bytes_to_device + a.bytes_saved,
        b.bytes_to_device + b.bytes_saved,
        "transfer ledger totals diverged"
    );
    assert!(b.bytes_to_device >= a.bytes_to_device, "a cap cannot add elisions");
}

#[test]
fn unequal_capacity_pipelined_matches_serial_bitwise() {
    // The prefetch fence rule survives rectangular waves: every block of
    // a group is a distinct slot of one diagonal, so scatters of
    // in-flight blocks never overlap later gathers of the same group.
    let g = graph();
    for residency in [false, true] {
        let caps = TrainConfig {
            worker_capacities: vec![1, 3],
            residency,
            ..base_cfg()
        };
        let serial = run(&g, TrainConfig { pipeline_transfers: false, ..caps.clone() });
        let pipelined = run(&g, TrainConfig { pipeline_transfers: true, ..caps });
        assert_eq!(
            serial.embeddings.vertex_matrix(),
            pipelined.embeddings.vertex_matrix(),
            "vertex matrices diverged (residency={residency})"
        );
        assert_eq!(
            serial.embeddings.context_matrix(),
            pipelined.embeddings.context_matrix(),
            "context matrices diverged (residency={residency})"
        );
    }
}

// ----------------------------------------------- bounded residency ----

#[test]
fn unequal_capacity_trains_to_completion_with_bounded_residency() {
    // The ISSUE-4 acceptance scenario: P=4 through 2 workers of unequal
    // capacity. The worker-side residency caches are capped at 2×capacity
    // and fail the run loudly on violation, so `train()` succeeding *is*
    // the in-test capacity assertion; checkpoints force sync fences
    // mid-run to also exercise resident-partition clones under the cap.
    let g = graph();
    let mut cfg = TrainConfig { worker_capacities: vec![1, 3], ..base_cfg() };
    cfg.episode_size = 500; // several pools => several checkpoints
    let budget = cfg.total_samples(g.num_edges());
    let mut t = Trainer::new(g.clone(), cfg).unwrap();
    let mut checkpoints = 0u32;
    let mut cb = |done: u64, store: &graphvite::embedding::EmbeddingStore| {
        assert!(done > 0);
        assert!(store.vertex_matrix().iter().all(|x| x.is_finite()));
        assert!(store.context_matrix().iter().all(|x| x.is_finite()));
        checkpoints += 1;
    };
    let r = t.train_with_callback(Some(&mut cb)).unwrap();
    assert!(checkpoints >= 2, "expected several checkpoints, got {checkpoints}");
    assert!(r.stats.counters.samples_trained >= budget, "under-trained");
    assert!(r.stats.final_loss.is_finite());
    assert!(r.stats.counters.residency_hits > 0, "bounded residency still elides");
}

#[test]
fn bounded_residency_ledger_balances_against_no_residency() {
    // Residency on/off dispatches the same multiset of jobs (group order
    // differs, the set does not): every byte the bounded planner does not
    // ship must be a byte saved.
    let g = graph();
    let caps = TrainConfig { worker_capacities: vec![1, 3], ..base_cfg() };
    let baseline = run(&g, TrainConfig { residency: false, ..caps.clone() });
    let resident = run(&g, TrainConfig { residency: true, ..caps });
    let b = &baseline.stats.counters;
    let r = &resident.stats.counters;
    assert_eq!(b.residency_hits, 0);
    assert_eq!(b.samples_trained, r.samples_trained);
    assert!(r.residency_hits > 0);
    assert!(r.bytes_to_device < b.bytes_to_device);
    assert_eq!(
        r.bytes_to_device + r.bytes_saved,
        b.bytes_to_device,
        "saved-bytes accounting does not balance under capacity caps"
    );
}

#[test]
fn planner_never_exceeds_capacity_caps() {
    // White-box, on a *three*-tier pool (P=12, capacities [1, 2, 3] —
    // the two-worker shape is covered by the unit tests next to the
    // engine): replay 3 pool passes and assert the per-worker resident
    // count against the 2×capacity caps after every single plan — the
    // planner-side half of the fail-loud contract (the worker-side half
    // is `ResidencyCache::insert`).
    let limits = vec![2usize, 4, 6];
    let sched = EpisodeSchedule::with_capacities(12, &[1, 2, 3], false).with_residency_order();
    let mut engine = TransferEngine::new(&sched, true, false, Some(limits.clone()));
    let seq = sched.execution_sequence();
    for pass in 0..3 {
        for a in &seq {
            let _ = engine.plan(a);
            for (w, &limit) in limits.iter().enumerate() {
                assert!(
                    engine.resident_count(w) <= limit,
                    "pass {pass}: worker {w} resident {} > cap {limit}",
                    engine.resident_count(w)
                );
            }
        }
    }
    // every worker's cap equals its sticky vid set + nothing, so context
    // keeps must have been denied somewhere
    assert!(engine.capacity_evictions > 0);
}
