//! Random-walk engine over any [`GraphStore`] — the in-RAM CSR or the
//! paged on-disk reader.
//!
//! Walks are uniform over neighbors for unit-weight graphs and
//! weight-proportional otherwise. Weighted sampling has two equivalent
//! forms: resident stores build per-node alias tables once (the O(E)
//! LINE/node2vec trick), while packed graphs carry the *same* tables
//! pre-built in their alias sidecar
//! ([`GraphStore::alias_tables_streamed`]) and stream them through the
//! page cache per step — no O(E) structure stays resident for
//! out-of-core training. Resident stores serve neighbor lists as
//! borrowed slices ([`GraphStore::neighbors_slice`]), so the in-RAM hot
//! loop is unchanged; out-of-core stores stream each step's
//! neighborhood into the caller-owned [`WalkScratch`] instead.
//!
//! RNG discipline: a step consumes exactly the same draws regardless of
//! which store backs the graph — resident `sample` and streamed
//! [`AliasTable::sample_slices`] over sidecar bits draw identically.
//! That is what makes training off a packed file bitwise-identical to
//! training off the loader (see `rust/tests/ondisk.rs`), for unit and
//! weighted graphs alike.

use crate::graph::GraphStore;
use crate::sampling::AliasTable;
use crate::util::rng::Rng;

/// Neighbor-sampling strategy, chosen at construction from the graph.
enum NeighborChoice {
    /// Unit weights: sample neighbor index uniformly (no tables needed).
    Uniform,
    /// Weighted, resident store: one alias table per node with
    /// degree >= 2, built up front.
    Weighted(Vec<Option<AliasTable>>),
    /// Weighted, packed store with an alias sidecar: tables are decoded
    /// per step through the store's page cache
    /// ([`GraphStore::alias_into`]) — O(1) resident.
    Streamed,
}

/// Per-thread scratch buffers for one walker: the streamed neighbor
/// list plus the streamed alias-table columns. Resident stores never
/// touch it; out-of-core stores decode into it instead of allocating
/// per step.
#[derive(Default)]
pub struct WalkScratch {
    nbrs: Vec<u32>,
    prob: Vec<f32>,
    alias: Vec<u32>,
}

impl WalkScratch {
    pub fn new() -> Self {
        Self::default()
    }
}

/// Reusable walk engine; cheap to share per thread (immutable — each
/// thread supplies its own [`WalkScratch`] for the streaming path).
pub struct RandomWalker<'g> {
    graph: &'g dyn GraphStore,
    choice: NeighborChoice,
}

impl<'g> RandomWalker<'g> {
    pub fn new(graph: &'g dyn GraphStore) -> Self {
        let choice = if graph.unit_weights() {
            NeighborChoice::Uniform
        } else if graph.alias_tables_streamed() {
            NeighborChoice::Streamed
        } else {
            let mut targets = Vec::new();
            let mut weights = Vec::new();
            let tables = (0..graph.num_nodes() as u32)
                .map(|v| {
                    // resident stores lend the weights directly; only the
                    // out-of-core path decodes into the scratch buffers
                    let w: &[f32] = match graph.neighbor_weights_slice(v) {
                        Some(w) => w,
                        None => {
                            graph.neighborhood_into(v, &mut targets, &mut weights);
                            &weights
                        }
                    };
                    if w.len() >= 2 {
                        Some(AliasTable::new(w))
                    } else {
                        None
                    }
                })
                .collect();
            NeighborChoice::Weighted(tables)
        };
        RandomWalker { graph, choice }
    }

    /// One walk step from `v`; None if `v` has no neighbors. `scratch`
    /// holds the streamed neighbor list and alias columns when the store
    /// is out-of-core (resident stores never touch it).
    #[inline]
    pub fn step(&self, v: u32, rng: &mut Rng, scratch: &mut WalkScratch) -> Option<u32> {
        let WalkScratch { nbrs, prob, alias } = scratch;
        let nbrs: &[u32] = match self.graph.neighbors_slice(v) {
            Some(s) => s,
            None => {
                self.graph.successors_into(v, nbrs);
                nbrs.as_slice()
            }
        };
        match nbrs.len() {
            0 => None,
            1 => Some(nbrs[0]),
            n => {
                let idx = match &self.choice {
                    NeighborChoice::Uniform => rng.below_usize(n),
                    NeighborChoice::Weighted(tables) => {
                        tables[v as usize].as_ref().unwrap().sample(rng) as usize
                    }
                    NeighborChoice::Streamed => {
                        self.graph.alias_into(v, prob, alias);
                        AliasTable::sample_slices(prob, alias, rng) as usize
                    }
                };
                Some(nbrs[idx])
            }
        }
    }

    /// Walk of up to `len` edges starting at `start`, writing nodes into
    /// `out` (cleared first; `out.len() <= len + 1`). Stops early at
    /// dead ends. Returns the number of nodes written.
    pub fn walk_into(
        &self,
        start: u32,
        len: usize,
        rng: &mut Rng,
        out: &mut Vec<u32>,
        scratch: &mut WalkScratch,
    ) -> usize {
        out.clear();
        out.push(start);
        let mut cur = start;
        for _ in 0..len {
            match self.step(cur, rng, scratch) {
                Some(next) => {
                    out.push(next);
                    cur = next;
                }
                None => break,
            }
        }
        out.len()
    }

    /// Allocating convenience wrapper around [`Self::walk_into`].
    pub fn walk(&self, start: u32, len: usize, rng: &mut Rng) -> Vec<u32> {
        let mut out = Vec::with_capacity(len + 1);
        let mut scratch = WalkScratch::new();
        self.walk_into(start, len, rng, &mut out, &mut scratch);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{generators, GraphBuilder};

    #[test]
    fn walk_stays_on_edges() {
        let g = generators::karate_club();
        let walker = RandomWalker::new(&g);
        let mut rng = Rng::new(1);
        for start in 0..34u32 {
            let path = walker.walk(start, 20, &mut rng);
            assert_eq!(path[0], start);
            for w in path.windows(2) {
                assert!(g.has_edge(w[0], w[1]), "{} -> {} not an edge", w[0], w[1]);
            }
        }
    }

    #[test]
    fn dead_end_stops_walk() {
        // path graph 0-1; node with single neighbor bounces back, fine;
        // isolated node 2 stops immediately.
        let g = GraphBuilder::new().with_num_nodes(3).add_edge(0, 1, 1.0).build();
        let walker = RandomWalker::new(&g);
        let mut rng = Rng::new(2);
        let path = walker.walk(2, 10, &mut rng);
        assert_eq!(path, vec![2]);
    }

    #[test]
    fn weighted_walk_prefers_heavy_edges() {
        // star: 0 connected to 1 (w=9) and 2 (w=1)
        let g = GraphBuilder::new()
            .add_edge(0, 1, 9.0)
            .add_edge(0, 2, 1.0)
            .build();
        let walker = RandomWalker::new(&g);
        let mut rng = Rng::new(3);
        let mut scratch = WalkScratch::new();
        let mut count1 = 0;
        const N: usize = 20_000;
        for _ in 0..N {
            if walker.step(0, &mut rng, &mut scratch) == Some(1) {
                count1 += 1;
            }
        }
        let f = count1 as f64 / N as f64;
        assert!((f - 0.9).abs() < 0.02, "f={f}");
    }

    #[test]
    fn walk_into_reuses_buffer() {
        let g = generators::karate_club();
        let walker = RandomWalker::new(&g);
        let mut rng = Rng::new(4);
        let mut buf = Vec::new();
        let mut scratch = WalkScratch::new();
        let n1 = walker.walk_into(0, 5, &mut rng, &mut buf, &mut scratch);
        assert_eq!(n1, buf.len());
        let n2 = walker.walk_into(1, 3, &mut rng, &mut buf, &mut scratch);
        assert_eq!(n2, buf.len());
        assert!(n2 <= 4);
    }

    #[test]
    fn identical_walks_over_ram_and_paged_stores() {
        // the step consumes identical RNG draws whether neighbors come
        // from the borrowed slice (in-RAM) or the streamed scratch
        // (paged) — the contract the packed/in-RAM bitwise training
        // equivalence rests on
        use crate::graph::ondisk::{pack_graph, PackOptions, PagedCsr};
        let g = generators::karate_club();
        let dir = std::env::temp_dir().join("graphvite_walk_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("karate.gvpk");
        pack_graph(&g, &path, &PackOptions { page_size: 64, ..Default::default() }).unwrap();
        let p = PagedCsr::open(&path, 256).unwrap();
        let ram = RandomWalker::new(&g);
        let paged = RandomWalker::new(&p);
        let (mut r1, mut r2) = (Rng::new(9), Rng::new(9));
        for v in 0..34u32 {
            let a = ram.walk(v, 16, &mut r1);
            let b = paged.walk(v, 16, &mut r2);
            assert_eq!(a, b, "walks diverged from node {v}");
        }
    }

    #[test]
    fn weighted_walks_stream_alias_tables_and_stay_identical() {
        // weighted paged stores must take the Streamed path (no resident
        // O(E) tables) and still reproduce the resident walker's draws
        // exactly — the last piece of the out-of-core story
        use crate::graph::ondisk::{pack_graph, PackOptions, PagedCsr};
        let mut b = GraphBuilder::new();
        for i in 0..50u32 {
            for j in 1..5u32 {
                b.push_edge(i, (i + j * 7) % 50, ((i + j) % 9 + 1) as f32 * 0.5);
            }
        }
        let g = b.build();
        assert!(!g.unit_weights());
        let dir = std::env::temp_dir().join("graphvite_walk_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("weighted.gvpk");
        pack_graph(&g, &path, &PackOptions { page_size: 128, ..Default::default() }).unwrap();
        let p = PagedCsr::open(&path, 1024).unwrap();
        assert!(p.alias_tables_streamed());
        let ram = RandomWalker::new(&g);
        let paged = RandomWalker::new(&p);
        assert!(matches!(paged.choice, NeighborChoice::Streamed));
        let (mut r1, mut r2) = (Rng::new(31), Rng::new(31));
        for v in 0..50u32 {
            let a = ram.walk(v, 24, &mut r1);
            let b = paged.walk(v, 24, &mut r2);
            assert_eq!(a, b, "weighted walks diverged from node {v}");
        }
    }
}
