//! Regenerates paper Table 3 — training time of LINE, DeepWalk, mini-batch-GPU and GraphVite (1 and 4 workers) on the YouTube substitute.
//!
//! Run with `cargo bench --bench bench_table3`; set
//! GRAPHVITE_BENCH_SCALE=tiny|small|full to change the workload size
//! (default tiny so `cargo bench` completes quickly; EXPERIMENTS.md
//! records the `small` runs).

fn main() {
    graphvite::experiments::run("table3", graphvite::experiments::Scale::from_env())
        .expect("table3 experiment");
}
