//! Simulated GPU device backends.
//!
//! Each GraphVite worker ("GPU") trains SGNS on its resident vertex /
//! context partitions. Two interchangeable backends exist:
//!
//! * [`HloWorker`] — the production three-layer path: executes the
//!   AOT-compiled JAX+Pallas train step via PJRT. Partitions are uploaded
//!   once per block, chained across execute calls, downloaded once — the
//!   paper's per-episode transfer pattern.
//! * [`NativeWorker`] — pure-rust SGNS with *identical mini-batch
//!   semantics* (gather → gradient at pre-update values → scatter-add), so
//!   the two backends agree numerically (see `rust/tests/hlo_runtime.rs`).
//!   Used by the CPU baselines and large parameter sweeps.
//!
//! The coordinator prepares [`ChunkPlan`]s (sample indices already
//! translated to partition-local rows, negatives drawn from the resident
//! context partition per paper section 3.2) and hands them to
//! [`WorkerBackend::train_chunks`].

mod native;

pub use native::{native_minibatch_step, NativeWorker};

use anyhow::Result;

use crate::metrics::Counters;
use crate::runtime::{ArtifactMeta, Device};

/// One device-ready chunk of training work: `real` positive samples
/// (padded by wrap-around up to the backend's chunk size), each with `k`
/// negatives, trained at learning rate `lr`.
#[derive(Debug, Clone, Default)]
pub struct ChunkPlan {
    pub pos_u: Vec<i32>,
    pub pos_v: Vec<i32>,
    pub neg_v: Vec<i32>,
    pub lr: f32,
    pub real: usize,
}

/// A device worker backend (one per simulated GPU).
pub enum WorkerBackend {
    Hlo(HloWorker),
    Native(NativeWorker),
}

impl WorkerBackend {
    /// Positive samples per chunk this backend consumes.
    pub fn chunk_samples(&self) -> usize {
        match self {
            WorkerBackend::Hlo(w) => w.device.meta().s * w.device.meta().b,
            WorkerBackend::Native(w) => w.batch_size,
        }
    }

    /// Negatives per positive.
    pub fn k(&self) -> usize {
        match self {
            WorkerBackend::Hlo(w) => w.device.meta().k,
            WorkerBackend::Native(w) => w.negatives,
        }
    }

    /// Row capacity the padded partition buffers must have.
    pub fn capacity(&self, part_rows: usize) -> usize {
        match self {
            WorkerBackend::Hlo(w) => w.device.meta().p,
            WorkerBackend::Native(_) => part_rows,
        }
    }

    /// Train all chunks against the padded partitions in place.
    /// Returns the mean loss over chunks.
    pub fn train_chunks(
        &mut self,
        vertex: &mut Vec<f32>,
        context: &mut Vec<f32>,
        chunks: &[ChunkPlan],
        counters: &Counters,
    ) -> Result<f32> {
        match self {
            WorkerBackend::Hlo(w) => w.train_chunks(vertex, context, chunks, counters),
            WorkerBackend::Native(w) => Ok(w.train_chunks(vertex, context, chunks, counters)),
        }
    }
}

/// PJRT-backed worker (Layer 1+2 compute via the AOT artifact).
pub struct HloWorker {
    pub device: Device,
}

impl HloWorker {
    pub fn new(meta: &ArtifactMeta) -> Result<Self> {
        Ok(HloWorker { device: Device::load(meta)? })
    }

    fn train_chunks(
        &mut self,
        vertex: &mut Vec<f32>,
        context: &mut Vec<f32>,
        chunks: &[ChunkPlan],
        counters: &Counters,
    ) -> Result<f32> {
        if chunks.is_empty() {
            return Ok(0.0);
        }
        let meta = self.device.meta().clone();
        let mat_bytes = (meta.p * meta.d * 4) as u64;
        // upload once per block (the paper's episode-boundary transfer)
        let (mut v_lit, mut c_lit) = self.device.upload_partitions(vertex, context)?;
        counters.add(&counters.bytes_to_device, 2 * mat_bytes);
        let mut loss_sum = 0.0f64;
        for ch in chunks {
            let (nv, nc, loss) =
                self.device
                    .train_step(v_lit, c_lit, &ch.pos_u, &ch.pos_v, &ch.neg_v, ch.lr)?;
            v_lit = nv;
            c_lit = nc;
            loss_sum += loss as f64;
            counters.add(
                &counters.bytes_to_device,
                ((ch.pos_u.len() + ch.pos_v.len() + ch.neg_v.len()) * 4) as u64,
            );
            counters.add(&counters.device_steps, 1);
        }
        let (v_host, c_host) = self.device.download_partitions(&v_lit, &c_lit)?;
        counters.add(&counters.bytes_from_device, 2 * mat_bytes);
        let vlen = vertex.len();
        let clen = context.len();
        vertex.copy_from_slice(&v_host[..vlen]);
        context.copy_from_slice(&c_host[..clen]);
        Ok((loss_sum / chunks.len() as f64) as f32)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chunk_plan_default_empty() {
        let c = ChunkPlan::default();
        assert_eq!(c.real, 0);
        assert!(c.pos_u.is_empty());
    }
}
