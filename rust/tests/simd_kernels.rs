//! Exact-equivalence strategy for the hand-unrolled f32x8 backend: the
//! unrolled kernels must match the scalar reference within a tight,
//! derivable tolerance on randomized batches and dims — including
//! remainder lanes when `dim % 8 != 0` — so every quality gate proven
//! against the native backend (`rust/tests/regression.rs`,
//! `rust/tests/properties.rs`) carries over to `backend = "simd"`
//! unchanged.
//!
//! Error budget: `axpy` and `apply_zero` are element-wise and required to
//! be *bitwise* identical. Only `dot` reassociates (8 partial sums +
//! pairwise reduce), so a single dot differs from the sequential scalar
//! sum by at most ~`dim * EPSILON * Σ|aᵢbᵢ|`. Downstream of a dot, the
//! divergence is smoothed through sigmoid (Lipschitz ¼) and scaled by
//! `lr`, which is why whole-step embedding deltas stay orders of
//! magnitude below the asserted bounds.

use graphvite::gpu::{
    native_minibatch_step, simd_minibatch_step, Kernels, ScalarKernels, UnrolledKernels,
};
use graphvite::util::prop::forall;

#[test]
fn prop_unrolled_dot_matches_scalar_within_ulps() {
    forall("unrolled dot vs scalar", 300, |g| {
        // 0..67 covers every remainder class mod 8 several times over
        let n = g.usize_in(0..67);
        let a: Vec<f32> = (0..n).map(|_| g.f32_in(-2.0..2.0)).collect();
        let b: Vec<f32> = (0..n).map(|_| g.f32_in(-2.0..2.0)).collect();
        let s = ScalarKernels::dot(&a, &b);
        let u = UnrolledKernels::dot(&a, &b);
        // reassociation bound: dim * eps * sum of |terms|, with slack for
        // the scalar sum's own rounding; exact zero when n == 0
        let mag: f32 = a.iter().zip(&b).map(|(x, y)| (x * y).abs()).sum();
        let tol = 8.0 * n.max(1) as f32 * f32::EPSILON * mag + 1e-7;
        assert!(
            (s - u).abs() <= tol,
            "dim {n}: scalar {s} vs unrolled {u} (tol {tol})"
        );
    });
}

#[test]
fn prop_unrolled_axpy_bitwise_identical() {
    forall("unrolled axpy vs scalar", 200, |g| {
        let n = g.usize_in(0..67);
        let x: Vec<f32> = (0..n).map(|_| g.f32_in(-2.0..2.0)).collect();
        let base: Vec<f32> = (0..n).map(|_| g.f32_in(-2.0..2.0)).collect();
        let scale = g.f32_in(-3.0..3.0);
        let (mut o1, mut o2) = (base.clone(), base);
        ScalarKernels::axpy(&mut o1, scale, &x);
        UnrolledKernels::axpy(&mut o2, scale, &x);
        // element-wise op: no reassociation, so bitwise equality holds
        assert_eq!(o1, o2, "dim {n}, scale {scale}");
    });
}

#[test]
fn prop_unrolled_apply_zero_bitwise_identical() {
    forall("unrolled apply_zero vs scalar", 200, |g| {
        let n = g.usize_in(0..67);
        let m_base: Vec<f32> = (0..n).map(|_| g.f32_in(-2.0..2.0)).collect();
        let g_base: Vec<f32> = (0..n).map(|_| g.f32_in(-2.0..2.0)).collect();
        let lr = g.f32_in(0.001..0.5);
        let (mut m1, mut g1) = (m_base.clone(), g_base.clone());
        let (mut m2, mut g2) = (m_base, g_base);
        ScalarKernels::apply_zero(&mut m1, &mut g1, lr);
        UnrolledKernels::apply_zero(&mut m2, &mut g2, lr);
        assert_eq!(m1, m2, "dim {n}");
        // both must also restore the dense-accumulator invariant
        assert!(g1.iter().all(|&v| v == 0.0));
        assert!(g2.iter().all(|&v| v == 0.0));
    });
}

/// One full mini-batch step on randomized shapes: same indices (with
/// duplicates — small `p` makes row collisions frequent, exercising the
/// scatter-add dedup on both paths), same data, scalar vs unrolled.
#[test]
fn prop_simd_minibatch_step_matches_scalar() {
    forall("simd step vs scalar step", 50, |g| {
        let dim = g.usize_in(1..40); // dense coverage of dim % 8 != 0
        let p = g.usize_in(4..64);
        let bsz = g.usize_in(1..24);
        let k = g.usize_in(1..4);
        let lr = g.f32_in(0.01..0.2);

        let base_v: Vec<f32> = (0..p * dim).map(|_| g.f32_in(-0.25..0.25)).collect();
        let base_c: Vec<f32> = (0..p * dim).map(|_| g.f32_in(-0.25..0.25)).collect();
        let pos_u: Vec<i32> = (0..bsz).map(|_| g.usize_in(0..p) as i32).collect();
        let pos_v: Vec<i32> = (0..bsz).map(|_| g.usize_in(0..p) as i32).collect();
        let neg_v: Vec<i32> = (0..bsz * k).map(|_| g.usize_in(0..p) as i32).collect();

        let (mut v1, mut c1) = (base_v.clone(), base_c.clone());
        let (mut v2, mut c2) = (base_v, base_c);
        let (mut gu1, mut gc1) = (Vec::new(), Vec::new());
        let (mut gu2, mut gc2) = (Vec::new(), Vec::new());
        let l1 = native_minibatch_step(
            &mut v1, &mut c1, dim, &pos_u, &pos_v, &neg_v, k, lr, 5.0, &mut gu1, &mut gc1,
        );
        let l2 = simd_minibatch_step(
            &mut v2, &mut c2, dim, &pos_u, &pos_v, &neg_v, k, lr, 5.0, &mut gu2, &mut gc2,
        );

        assert!(
            (l1 - l2).abs() <= 1e-5 + 1e-4 * l1.abs(),
            "loss diverged: scalar {l1} vs simd {l2} (dim {dim} p {p} bsz {bsz} k {k})"
        );
        for (i, (a, b)) in v1.iter().zip(&v2).enumerate() {
            assert!(
                (a - b).abs() <= 2e-4,
                "vertex[{i}] diverged: {a} vs {b} (dim {dim} p {p} bsz {bsz} k {k})"
            );
        }
        for (i, (a, b)) in c1.iter().zip(&c2).enumerate() {
            assert!(
                (a - b).abs() <= 2e-4,
                "context[{i}] diverged: {a} vs {b} (dim {dim} p {p} bsz {bsz} k {k})"
            );
        }
    });
}

/// Reassociation error must not amplify across successive steps on the
/// same buffers (the divergence is damped by sigmoid saturation, not
/// compounded) — 20 chained steps at a remainder-lane dim stay close.
#[test]
fn chained_steps_stay_close() {
    let dim = 20; // 20 % 8 == 4: main lanes + remainder every step
    let p = 64;
    let bsz = 32;
    let k = 2;
    let mut g = graphvite::util::rng::Rng::new(4242);
    let base_v: Vec<f32> = (0..p * dim).map(|_| g.range_f32(-0.25, 0.25)).collect();
    let base_c: Vec<f32> = (0..p * dim).map(|_| g.range_f32(-0.25, 0.25)).collect();
    let (mut v1, mut c1) = (base_v.clone(), base_c.clone());
    let (mut v2, mut c2) = (base_v, base_c);
    let (mut gu1, mut gc1) = (Vec::new(), Vec::new());
    let (mut gu2, mut gc2) = (Vec::new(), Vec::new());
    for step in 0..20 {
        let pos_u: Vec<i32> = (0..bsz).map(|_| g.below(p as u64) as i32).collect();
        let pos_v: Vec<i32> = (0..bsz).map(|_| g.below(p as u64) as i32).collect();
        let neg_v: Vec<i32> = (0..bsz * k).map(|_| g.below(p as u64) as i32).collect();
        let l1 = native_minibatch_step(
            &mut v1, &mut c1, dim, &pos_u, &pos_v, &neg_v, k, 0.1, 5.0, &mut gu1, &mut gc1,
        );
        let l2 = simd_minibatch_step(
            &mut v2, &mut c2, dim, &pos_u, &pos_v, &neg_v, k, 0.1, 5.0, &mut gu2, &mut gc2,
        );
        assert!(
            (l1 - l2).abs() <= 1e-4 + 1e-3 * l1.abs(),
            "loss diverged at step {step}: {l1} vs {l2}"
        );
    }
    let max_diff = v1
        .iter()
        .zip(&v2)
        .chain(c1.iter().zip(&c2))
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    assert!(max_diff <= 5e-3, "chained divergence {max_diff}");
}
