//! Device worker threads: each simulated GPU owns a [`Backend`] trait
//! object (PJRT executable or native trainer, chosen by
//! [`crate::gpu::create_backend`]), receives block jobs, draws its
//! restricted negatives (paper §3.2 — only from the resident context
//! partition), trains, and ships updated partitions back.

use std::sync::mpsc;
use std::sync::Arc;
use std::thread::{Scope, ScopedJoinHandle};

use anyhow::Result;

use crate::config::TrainConfig;
use crate::gpu::{create_backend, Backend, ChunkPlan};
use crate::metrics::Counters;
use crate::runtime::ArtifactMeta;
use crate::sampling::NegativeSampler;
use crate::util::rng::Rng;

/// A block-training job.
pub struct Job {
    pub vid: usize,
    pub cid: usize,
    /// Partition-local (u, v) positive samples of block (vid, cid).
    pub block: Vec<(i32, i32)>,
    /// Padded vertex partition rows.
    pub vertex: Vec<f32>,
    /// Padded context partition rows; `None` = reuse the worker-resident
    /// copy (bus-usage optimization, §3.4).
    pub context: Option<Vec<f32>>,
    /// Ship the context partition back with the result (off while the
    /// context stays pinned to this worker).
    pub return_context: bool,
    pub lr: f32,
}

pub enum JobMsg {
    Train(Job),
    Stop,
}

/// Worker response to one job.
pub struct JobResult {
    pub vid: usize,
    pub cid: usize,
    pub vertex: Vec<f32>,
    pub context: Option<Vec<f32>>,
    pub loss: f32,
    /// Real (unpadded) positive samples trained.
    pub trained: u64,
}

type ResultTx = mpsc::Sender<Result<JobResult>>;

/// Spawn `num_workers` device threads inside `scope`. Returns join
/// handles, per-worker job senders, and the shared result receiver.
pub fn spawn_workers<'scope, 'env>(
    scope: &'scope Scope<'scope, 'env>,
    cfg: &TrainConfig,
    artifact: Option<&ArtifactMeta>,
    neg: Arc<NegativeSampler>,
    counters: Arc<Counters>,
    base_rng: &Rng,
) -> (
    Vec<ScopedJoinHandle<'scope, Result<()>>>,
    Vec<mpsc::Sender<JobMsg>>,
    mpsc::Receiver<Result<JobResult>>,
) {
    let (result_tx, result_rx) = mpsc::channel::<Result<JobResult>>();
    let mut handles = Vec::with_capacity(cfg.num_workers);
    let mut job_txs = Vec::with_capacity(cfg.num_workers);
    for i in 0..cfg.num_workers {
        let (tx, rx) = mpsc::channel::<JobMsg>();
        job_txs.push(tx);
        let result_tx = result_tx.clone();
        let neg = Arc::clone(&neg);
        let counters = Arc::clone(&counters);
        let rng = base_rng.split(0xBEEF ^ (i as u64));
        let cfg = cfg.clone();
        let artifact = artifact.cloned();
        handles.push(scope.spawn(move || {
            worker_loop(i, cfg, artifact, neg, counters, rng, rx, result_tx)
        }));
    }
    (handles, job_txs, result_rx)
}

#[allow(clippy::too_many_arguments)]
fn worker_loop(
    _worker_idx: usize,
    cfg: TrainConfig,
    artifact: Option<ArtifactMeta>,
    neg: Arc<NegativeSampler>,
    counters: Arc<Counters>,
    mut rng: Rng,
    rx: mpsc::Receiver<JobMsg>,
    tx: ResultTx,
) -> Result<()> {
    // Backend construction happens on this thread: PJRT handles are !Send,
    // one client per simulated GPU (like one CUDA context per device).
    let mut backend = create_backend(&cfg, artifact.as_ref())?;

    // fix_context residency: (cid, padded context rows)
    let mut ctx_cache: Option<(usize, Vec<f32>)> = None;
    // reusable chunk scratch (avoids 3 Vec allocations per chunk)
    let mut scratch = ChunkPlan::default();

    while let Ok(msg) = rx.recv() {
        let job = match msg {
            JobMsg::Train(job) => job,
            JobMsg::Stop => break,
        };
        let out = run_job(
            backend.as_mut(),
            &neg,
            &counters,
            &mut rng,
            &mut ctx_cache,
            &mut scratch,
            job,
        );
        if tx.send(out).is_err() {
            break; // coordinator gone
        }
    }
    Ok(())
}

#[allow(clippy::too_many_arguments)]
fn run_job(
    backend: &mut dyn Backend,
    neg: &NegativeSampler,
    counters: &Counters,
    rng: &mut Rng,
    ctx_cache: &mut Option<(usize, Vec<f32>)>,
    scratch: &mut ChunkPlan,
    job: Job,
) -> Result<JobResult> {
    let Job { vid, cid, block, mut vertex, context, return_context, lr } = job;
    // resolve the context partition: shipped with the job or resident
    let mut ctx = match context {
        Some(c) => c,
        None => match ctx_cache.take() {
            Some((cached_cid, c)) if cached_cid == cid => c,
            other => {
                anyhow::bail!(
                    "worker asked to reuse context {cid} but cache holds {:?}",
                    other.map(|(c, _)| c)
                )
            }
        },
    };

    let trained = block.len() as u64;
    let loss = if backend.batched_upload() {
        // Batched backends (PJRT): one train_chunks call per block so
        // partitions are uploaded/downloaded once per episode (the
        // paper's transfer pattern), not per chunk.
        let chunks = plan_chunks(&*backend, neg, cid, &block, lr, rng);
        let t0 = std::time::Instant::now();
        let loss = backend.train_chunks(&mut vertex, &mut ctx, &chunks, counters)?;
        counters.add(&counters.device_nanos, t0.elapsed().as_nanos() as u64);
        loss
    } else {
        // Streaming backends (native): feed chunks through one reusable
        // scratch plan (the collected-Vec variant allocated 3 vectors per
        // chunk and showed up as allocator churn — EXPERIMENTS.md §Perf).
        let chunk_sz = backend.chunk_samples();
        let k = backend.k();
        let mut loss_sum = 0.0f64;
        let mut chunks = 0usize;
        let mut at = 0usize;
        while at < block.len() {
            let real = plan_chunk_into(scratch, chunk_sz, k, neg, cid, &block, at, lr, rng);
            let t0 = std::time::Instant::now();
            let loss = backend.train_chunks(
                &mut vertex,
                &mut ctx,
                std::slice::from_ref(scratch),
                counters,
            )?;
            counters.add(&counters.device_nanos, t0.elapsed().as_nanos() as u64);
            loss_sum += loss as f64;
            chunks += 1;
            at += real;
        }
        if chunks > 0 { (loss_sum / chunks as f64) as f32 } else { 0.0 }
    };
    counters.add(&counters.samples_trained, trained);

    let context_out = if return_context {
        Some(ctx)
    } else {
        *ctx_cache = Some((cid, ctx));
        None
    };
    Ok(JobResult { vid, cid, vertex, context: context_out, loss, trained })
}

/// Fill `plan` with the chunk starting at `at`: `chunk_sz` positives
/// (wrap-around padded past the block end) and `chunk_sz * k` restricted
/// negatives from context partition `cid`. Returns the number of real
/// (unpadded) samples consumed.
#[allow(clippy::too_many_arguments)]
fn plan_chunk_into(
    plan: &mut ChunkPlan,
    chunk_sz: usize,
    k: usize,
    neg: &NegativeSampler,
    cid: usize,
    block: &[(i32, i32)],
    at: usize,
    lr: f32,
    rng: &mut Rng,
) -> usize {
    debug_assert!(at < block.len());
    let real = chunk_sz.min(block.len() - at);
    plan.pos_u.clear();
    plan.pos_v.clear();
    plan.neg_v.clear();
    for t in 0..chunk_sz {
        // wrap-around pad: reuse samples from the block start; the
        // duplicates are counted as padding (not in `real`).
        let (u, v) = block[(at + t) % block.len()];
        plan.pos_u.push(u);
        plan.pos_v.push(v);
    }
    for _ in 0..chunk_sz * k {
        plan.neg_v.push(neg.sample_local(cid, rng) as i32);
    }
    plan.lr = lr;
    plan.real = real;
    real
}

/// Collected-Vec chunk planning (used by batched backends and the HLO
/// parity harness; streaming backends go through `plan_chunk_into`).
fn plan_chunks(
    backend: &dyn Backend,
    neg: &NegativeSampler,
    cid: usize,
    block: &[(i32, i32)],
    lr: f32,
    rng: &mut Rng,
) -> Vec<ChunkPlan> {
    let chunk_sz = backend.chunk_samples();
    let k = backend.k();
    if block.is_empty() {
        return Vec::new();
    }
    let mut chunks = Vec::with_capacity(block.len().div_ceil(chunk_sz));
    let mut at = 0usize;
    while at < block.len() {
        let mut plan = ChunkPlan::default();
        let real = plan_chunk_into(&mut plan, chunk_sz, k, neg, cid, block, at, lr, rng);
        chunks.push(plan);
        at += real;
    }
    chunks
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpu::NativeWorker;
    use crate::graph::generators;
    use crate::partition::Partitioner;

    #[test]
    fn plan_chunks_covers_block_with_padding() {
        let g = generators::barabasi_albert(100, 3, 1);
        let parts = Partitioner::degree_zigzag(&g, 2);
        let neg = NegativeSampler::new(&g, &parts);
        let backend = NativeWorker::new(8, 32, 2, 5.0);
        let block: Vec<(i32, i32)> = (0..70).map(|i| (i % 50, (i + 1) % 50)).collect();
        let mut rng = Rng::new(1);
        let chunks = plan_chunks(&backend, &neg, 0, &block, 0.025, &mut rng);
        assert_eq!(chunks.len(), 3); // ceil(70/32)
        assert_eq!(chunks.iter().map(|c| c.real).sum::<usize>(), 70);
        for c in &chunks {
            assert_eq!(c.pos_u.len(), 32);
            assert_eq!(c.neg_v.len(), 64); // k=2
            assert!(c.neg_v.iter().all(|&n| (n as usize) < parts.part_size(0)));
        }
        // final chunk wraps around to the beginning
        let last = chunks.last().unwrap();
        assert_eq!(last.real, 70 - 64);
        assert_eq!((last.pos_u[6], last.pos_v[6]), (block[0].0, block[0].1));
    }

    #[test]
    fn empty_block_no_chunks() {
        let g = generators::karate_club();
        let parts = Partitioner::degree_zigzag(&g, 2);
        let neg = NegativeSampler::new(&g, &parts);
        let backend = NativeWorker::new(4, 16, 1, 5.0);
        let mut rng = Rng::new(2);
        assert!(plan_chunks(&backend, &neg, 1, &[], 0.1, &mut rng).is_empty());
    }
}
