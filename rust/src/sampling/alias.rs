//! Vose alias method: O(n) build, O(1) sampling from any discrete
//! distribution. The "alias table trick" the paper borrows from
//! LINE/node2vec (§4.3) — used for departure-node sampling (p ∝ degree),
//! weighted neighbor choice, edge sampling and negative sampling
//! (p ∝ degree^0.75).

use crate::util::rng::Rng;

/// Immutable alias table over `n` outcomes.
#[derive(Debug, Clone)]
pub struct AliasTable {
    prob: Vec<f32>,
    alias: Vec<u32>,
}

impl AliasTable {
    /// Build from (unnormalized, non-negative) weights. At least one
    /// weight must be positive.
    pub fn new(weights: &[f32]) -> Self {
        #[cfg(feature = "count-alias-builds")]
        {
            use std::sync::atomic::{AtomicU64, Ordering};
            static BUILDS: AtomicU64 = AtomicU64::new(0);
            static ENTRIES: AtomicU64 = AtomicU64::new(0);
            let b = BUILDS.fetch_add(1, Ordering::Relaxed) + 1;
            let e = ENTRIES.fetch_add(weights.len() as u64, Ordering::Relaxed);
            if b % 100_000 == 0 {
                eprintln!("[alias] builds={b} entries={e}");
            }
        }
        let n = weights.len();
        assert!(n > 0, "alias table needs at least one outcome");
        let total: f64 = weights.iter().map(|&w| w as f64).sum();
        assert!(total > 0.0, "alias table needs positive total weight");

        let mut prob = vec![0f32; n];
        let mut alias = vec![0u32; n];
        // scaled probabilities: p_i * n
        let mut scaled: Vec<f64> = weights
            .iter()
            .map(|&w| (w as f64) * n as f64 / total)
            .collect();
        let mut small: Vec<u32> = Vec::with_capacity(n);
        let mut large: Vec<u32> = Vec::with_capacity(n);
        for (i, &p) in scaled.iter().enumerate() {
            if p < 1.0 {
                small.push(i as u32);
            } else {
                large.push(i as u32);
            }
        }
        // NOTE: do not use `while let (Some(s), Some(l)) = (small.pop(),
        // large.pop())` here — both pops evaluate before the match, so the
        // exit iteration silently drops one element from the non-empty
        // stack, leaving its prob at 0.
        while !small.is_empty() && !large.is_empty() {
            let s = small.pop().unwrap();
            let l = large.pop().unwrap();
            prob[s as usize] = scaled[s as usize] as f32;
            alias[s as usize] = l;
            scaled[l as usize] = (scaled[l as usize] + scaled[s as usize]) - 1.0;
            if scaled[l as usize] < 1.0 {
                small.push(l);
            } else {
                large.push(l);
            }
        }
        for i in large.into_iter().chain(small) {
            prob[i as usize] = 1.0;
        }
        AliasTable { prob, alias }
    }

    /// Number of outcomes.
    #[inline]
    pub fn len(&self) -> usize {
        self.prob.len()
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.prob.is_empty()
    }

    /// Draw one outcome in O(1).
    #[inline]
    pub fn sample(&self, rng: &mut Rng) -> u32 {
        Self::sample_slices(&self.prob, &self.alias, rng)
    }

    /// [`Self::sample`] over borrowed table columns — the walker's
    /// streamed path draws from sidecar-decoded slices without owning an
    /// `AliasTable`. Consumes exactly the draws `sample` does (one index,
    /// one f32), so streamed and resident sampling stay bitwise-aligned.
    #[inline]
    pub fn sample_slices(prob: &[f32], alias: &[u32], rng: &mut Rng) -> u32 {
        let i = rng.below_usize(prob.len());
        if rng.f32() < prob[i] {
            i as u32
        } else {
            alias[i]
        }
    }

    /// The acceptance-probability column (serialized into the `.gvpk`
    /// alias sidecar).
    #[inline]
    pub fn probs(&self) -> &[f32] {
        &self.prob
    }

    /// The alias column (parallel to [`Self::probs`]).
    #[inline]
    pub fn aliases(&self) -> &[u32] {
        &self.alias
    }

    /// Memory footprint in bytes (for the Table 1 memory model).
    pub fn bytes(&self) -> usize {
        self.prob.len() * (std::mem::size_of::<f32>() + std::mem::size_of::<u32>())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn empirical(table: &AliasTable, draws: usize, seed: u64) -> Vec<f64> {
        let mut rng = Rng::new(seed);
        let mut counts = vec![0usize; table.len()];
        for _ in 0..draws {
            counts[table.sample(&mut rng) as usize] += 1;
        }
        counts.iter().map(|&c| c as f64 / draws as f64).collect()
    }

    #[test]
    fn uniform_distribution() {
        let t = AliasTable::new(&[1.0; 8]);
        let freqs = empirical(&t, 80_000, 1);
        for f in freqs {
            assert!((f - 0.125).abs() < 0.01, "f={f}");
        }
    }

    #[test]
    fn skewed_distribution() {
        let t = AliasTable::new(&[1.0, 2.0, 3.0, 4.0]);
        let freqs = empirical(&t, 100_000, 2);
        for (i, f) in freqs.iter().enumerate() {
            let expect = (i + 1) as f64 / 10.0;
            assert!((f - expect).abs() < 0.01, "i={i} f={f} expect={expect}");
        }
    }

    #[test]
    fn zero_weights_never_sampled() {
        let t = AliasTable::new(&[0.0, 1.0, 0.0, 1.0]);
        let freqs = empirical(&t, 20_000, 3);
        assert_eq!(freqs[0], 0.0);
        assert_eq!(freqs[2], 0.0);
    }

    #[test]
    fn single_outcome() {
        let t = AliasTable::new(&[5.0]);
        let mut rng = Rng::new(4);
        for _ in 0..10 {
            assert_eq!(t.sample(&mut rng), 0);
        }
    }

    #[test]
    fn degree_power_distribution() {
        // negative sampling weights deg^0.75
        let degs = [1.0f32, 16.0, 81.0];
        let weights: Vec<f32> = degs.iter().map(|d| d.powf(0.75)).collect();
        let t = AliasTable::new(&weights);
        let freqs = empirical(&t, 100_000, 5);
        let total: f32 = weights.iter().sum();
        for (f, w) in freqs.iter().zip(&weights) {
            assert!((f - (*w / total) as f64).abs() < 0.01);
        }
    }

    #[test]
    #[should_panic]
    fn all_zero_weights_panics() {
        AliasTable::new(&[0.0, 0.0]);
    }

    #[test]
    fn sample_slices_matches_sample_draw_for_draw() {
        let t = AliasTable::new(&[0.5, 3.0, 1.25, 0.25, 7.0]);
        let (mut r1, mut r2) = (Rng::new(11), Rng::new(11));
        for _ in 0..1000 {
            assert_eq!(
                t.sample(&mut r1),
                AliasTable::sample_slices(t.probs(), t.aliases(), &mut r2)
            );
        }
    }
}
