//! Episode-boundary training checkpoints (`.gvck`).
//!
//! A checkpoint captures *everything* the trajectory depends on at a pool
//! boundary — not just the weights (the Tencent multi-GPU lesson: resume
//! must restore sampler/optimizer state or the resumed run diverges):
//!
//! - both embedding matrices, fully synced from worker residency via the
//!   [`JobMsg::Sync`](super::worker::JobMsg) fence;
//! - the per-worker negative-sampling RNG states — the only *stateful*
//!   streams in the system (they advance per negative drawn; sampler and
//!   shuffle streams are pure functions of `seed` + pool index and are
//!   rederived on resume);
//! - the LR-schedule position (`samples_planned`) and the pool cursor
//!   (`pools_done`).
//!
//! What a checkpoint deliberately does **not** capture: transfer-engine
//! residency/version ledgers (keep/upload decisions never change trained
//! values — a resumed run starts with a cold residency plan and produces
//! bitwise-identical embeddings; see `transfer.rs`), block grids, and
//! sample pools (rebuilt deterministically from the pool index). Training
//! `2N` epochs straight and `N` + checkpoint + resume + `N` therefore
//! produce identical bytes — pinned in `rust/tests/checkpoint.rs`.
//!
//! On-disk layout (all integers little-endian), validated like `.gvpk`:
//! magic, version, geometry bounded by the actual file length, exact
//! total size (rejects truncation *and* trailing garbage):
//!
//! ```text
//! offset    size   field
//!      0       4   magic b"GVCK"
//!      4       4   format version (u32) = 1
//!      8       8   seed
//!     16       8   num_nodes
//!     24       8   dim
//!     32       8   num_edges
//!     40       8   partitions
//!     48       8   num_workers (W)
//!     56       8   total_samples
//!     64       8   pool_size
//!     72       8   pools_done
//!     80       8   samples_planned
//!     88       8   samples_done
//!     96    32*W   worker RNG states (4 × u64 each, xoshiro256**)
//!      +   n*d*4   vertex matrix (f32)
//!      +   n*d*4   context matrix (f32)
//! ```

use std::fs::File;
use std::io::{BufReader, BufWriter, Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::embedding::EmbeddingStore;

pub const CKPT_MAGIC: &[u8; 4] = b"GVCK";
pub const CKPT_VERSION: u32 = 1;
const CKPT_HEADER_LEN: u64 = 96;

/// Borrowed view of the resumable training state at a pool boundary —
/// what the checkpoint observer receives and [`save_checkpoint`] writes.
/// No clones: the store and RNG states are borrowed from the live run.
pub struct CheckpointState<'a> {
    pub seed: u64,
    pub num_edges: u64,
    pub partitions: u64,
    pub total_samples: u64,
    pub pool_size: u64,
    pub pools_done: u64,
    pub samples_planned: u64,
    pub samples_done: u64,
    pub worker_rngs: &'a [[u64; 4]],
    pub store: &'a EmbeddingStore,
}

/// An owned, loaded checkpoint — pass to
/// [`Trainer::train_resumable`](super::Trainer::train_resumable) to
/// continue the run it captured.
#[derive(Debug, Clone)]
pub struct TrainCheckpoint {
    pub seed: u64,
    pub num_edges: u64,
    pub partitions: u64,
    pub total_samples: u64,
    pub pool_size: u64,
    pub pools_done: u64,
    pub samples_planned: u64,
    pub samples_done: u64,
    pub worker_rngs: Vec<[u64; 4]>,
    pub store: EmbeddingStore,
}

impl CheckpointState<'_> {
    /// Clone into an owned [`TrainCheckpoint`]. The borrowed state only
    /// lives for one observer call, but checkpoint-on-fault must hold
    /// the last pool boundary until the training scope unwinds.
    pub fn to_owned(&self) -> TrainCheckpoint {
        TrainCheckpoint {
            seed: self.seed,
            num_edges: self.num_edges,
            partitions: self.partitions,
            total_samples: self.total_samples,
            pool_size: self.pool_size,
            pools_done: self.pools_done,
            samples_planned: self.samples_planned,
            samples_done: self.samples_done,
            worker_rngs: self.worker_rngs.to_vec(),
            store: self.store.clone(),
        }
    }
}

impl TrainCheckpoint {
    pub fn state(&self) -> CheckpointState<'_> {
        CheckpointState {
            seed: self.seed,
            num_edges: self.num_edges,
            partitions: self.partitions,
            total_samples: self.total_samples,
            pool_size: self.pool_size,
            pools_done: self.pools_done,
            samples_planned: self.samples_planned,
            samples_done: self.samples_done,
            worker_rngs: &self.worker_rngs,
            store: &self.store,
        }
    }
}

/// Write a checkpoint atomically (tmp sibling + rename), so a crash
/// mid-write never destroys the previous checkpoint and a concurrent
/// reader never sees a torn file.
pub fn save_checkpoint(state: &CheckpointState<'_>, path: impl AsRef<Path>) -> Result<()> {
    let path = path.as_ref();
    let mut name = path.file_name().map(|n| n.to_os_string()).unwrap_or_default();
    name.push(".tmp");
    let tmp = path.with_file_name(name);
    {
        let mut w = BufWriter::new(
            File::create(&tmp).with_context(|| format!("create {}", tmp.display()))?,
        );
        w.write_all(CKPT_MAGIC)?;
        w.write_all(&CKPT_VERSION.to_le_bytes())?;
        for x in [
            state.seed,
            state.store.num_nodes() as u64,
            state.store.dim() as u64,
            state.num_edges,
            state.partitions,
            state.worker_rngs.len() as u64,
            state.total_samples,
            state.pool_size,
            state.pools_done,
            state.samples_planned,
            state.samples_done,
        ] {
            w.write_all(&x.to_le_bytes())?;
        }
        for rng in state.worker_rngs {
            for x in rng {
                w.write_all(&x.to_le_bytes())?;
            }
        }
        for mat in [state.store.vertex_matrix(), state.store.context_matrix()] {
            let mut buf = Vec::with_capacity(mat.len() * 4);
            for &x in mat {
                buf.extend_from_slice(&x.to_le_bytes());
            }
            w.write_all(&buf)?;
        }
        w.flush()?;
    }
    std::fs::rename(&tmp, path)
        .with_context(|| format!("rename {} -> {}", tmp.display(), path.display()))?;
    Ok(())
}

/// Load and fully validate a checkpoint. Every geometry field is checked
/// against the actual file length *before* any allocation; truncation,
/// trailing garbage, and degenerate RNG states all return `Err`.
pub fn load_checkpoint(path: impl AsRef<Path>) -> Result<TrainCheckpoint> {
    let file = File::open(path.as_ref())
        .with_context(|| format!("open {}", path.as_ref().display()))?;
    let file_len = file.metadata()?.len();
    let mut r = BufReader::new(file);
    if file_len < CKPT_HEADER_LEN {
        bail!(
            "checkpoint truncated: {file_len} bytes is shorter than the \
             {CKPT_HEADER_LEN}-byte header"
        );
    }
    let mut magic = [0u8; 4];
    r.read_exact(&mut magic)?;
    if &magic != CKPT_MAGIC {
        bail!("not a graphvite checkpoint (bad magic)");
    }
    let mut u32buf = [0u8; 4];
    r.read_exact(&mut u32buf)?;
    let version = u32::from_le_bytes(u32buf);
    if version != CKPT_VERSION {
        bail!("unsupported checkpoint version {version} (this build reads {CKPT_VERSION})");
    }
    let mut u64buf = [0u8; 8];
    let mut next = |r: &mut BufReader<File>| -> Result<u64> {
        r.read_exact(&mut u64buf)?;
        Ok(u64::from_le_bytes(u64buf))
    };
    let seed = next(&mut r)?;
    let num_nodes = next(&mut r)?;
    let dim = next(&mut r)?;
    let num_edges = next(&mut r)?;
    let partitions = next(&mut r)?;
    let num_workers = next(&mut r)?;
    let total_samples = next(&mut r)?;
    let pool_size = next(&mut r)?;
    let pools_done = next(&mut r)?;
    let samples_planned = next(&mut r)?;
    let samples_done = next(&mut r)?;

    let overflow = || anyhow::anyhow!("checkpoint header geometry overflows u64");
    let rng_bytes = num_workers.checked_mul(32).ok_or_else(overflow)?;
    let matrix_bytes = num_nodes
        .checked_mul(dim)
        .and_then(|nd| nd.checked_mul(4))
        .ok_or_else(overflow)?;
    let expected = CKPT_HEADER_LEN
        .checked_add(rng_bytes)
        .and_then(|x| x.checked_add(matrix_bytes.checked_mul(2)?))
        .ok_or_else(overflow)?;
    if file_len != expected {
        bail!(
            "checkpoint length mismatch: header declares {num_nodes}\u{d7}{dim}, \
             {num_workers} workers ({expected} bytes expected) but the file is \
             {file_len} bytes"
        );
    }
    if samples_planned > total_samples {
        bail!("checkpoint samples_planned {samples_planned} exceeds total {total_samples}");
    }

    let mut worker_rngs = Vec::with_capacity(num_workers as usize);
    for w in 0..num_workers {
        let mut s = [0u64; 4];
        for x in &mut s {
            *x = next(&mut r)?;
        }
        if s.iter().all(|&x| x == 0) {
            bail!("checkpoint worker {w} has an all-zero rng state");
        }
        worker_rngs.push(s);
    }
    let nd = (num_nodes as usize) * (dim as usize);
    let mut read_matrix = |r: &mut BufReader<File>| -> Result<Vec<f32>> {
        let mut bytes = vec![0u8; nd * 4];
        r.read_exact(&mut bytes)?;
        Ok(bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    };
    let vertex = read_matrix(&mut r)?;
    let context = read_matrix(&mut r)?;
    Ok(TrainCheckpoint {
        seed,
        num_edges,
        partitions,
        total_samples,
        pool_size,
        pools_done,
        samples_planned,
        samples_done,
        worker_rngs,
        store: EmbeddingStore::from_raw(num_nodes as usize, dim as usize, vertex, context),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("graphvite_ckpt");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    fn sample() -> TrainCheckpoint {
        TrainCheckpoint {
            seed: 42,
            num_edges: 900,
            partitions: 4,
            total_samples: 3600,
            pool_size: 2000,
            pools_done: 1,
            samples_planned: 2000,
            samples_done: 2000,
            worker_rngs: vec![[1, 2, 3, 4], [5, 6, 7, 8]],
            store: EmbeddingStore::init(30, 8, 42),
        }
    }

    #[test]
    fn roundtrip() {
        let ck = sample();
        let p = tmp("ok.gvck");
        save_checkpoint(&ck.state(), &p).unwrap();
        let l = load_checkpoint(&p).unwrap();
        assert_eq!(l.seed, 42);
        assert_eq!(l.pools_done, 1);
        assert_eq!(l.samples_planned, 2000);
        assert_eq!(l.worker_rngs, ck.worker_rngs);
        assert_eq!(l.store.vertex_matrix(), ck.store.vertex_matrix());
        assert_eq!(l.store.context_matrix(), ck.store.context_matrix());
    }

    #[test]
    fn corrupt_inputs_fail_loudly() {
        let ck = sample();
        let p = tmp("base.gvck");
        save_checkpoint(&ck.state(), &p).unwrap();
        let bytes = std::fs::read(&p).unwrap();

        let bad = tmp("magic.gvck");
        let mut b = bytes.clone();
        b[0] ^= 0xFF;
        std::fs::write(&bad, &b).unwrap();
        assert!(load_checkpoint(&bad).unwrap_err().to_string().contains("magic"));

        let bad = tmp("trunc.gvck");
        std::fs::write(&bad, &bytes[..bytes.len() - 10]).unwrap();
        assert!(load_checkpoint(&bad).unwrap_err().to_string().contains("mismatch"));

        let bad = tmp("trail.gvck");
        let mut b = bytes.clone();
        b.extend_from_slice(b"junk");
        std::fs::write(&bad, &b).unwrap();
        assert!(load_checkpoint(&bad).unwrap_err().to_string().contains("mismatch"));

        // oversized node count cannot over-allocate: rejected against the
        // real file length before any matrix is read
        let bad = tmp("huge.gvck");
        let mut b = bytes.clone();
        b[16..24].copy_from_slice(&u64::MAX.to_le_bytes());
        std::fs::write(&bad, &b).unwrap();
        assert!(load_checkpoint(&bad).is_err());
    }
}
