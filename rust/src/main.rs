//! `graphvite` — the CLI launcher for the GraphVite (WWW'19) reproduction.
//!
//! Subcommands:
//!
//! * `train`      — train node embeddings on an edge-list file, a packed
//!                  on-disk graph (`--graph-format`), or a synthetic
//!                  graph through the full hybrid system.
//! * `pack`       — convert an edge list into the packed on-disk format
//!                  (`graph::ondisk`) that trains out-of-core, under a
//!                  bounded `--pack-mem-bytes` budget (external
//!                  sort-merge), optionally BFS-reordered for locality.
//! * `reorder`    — repack an existing graph under a locality-aware
//!                  node permutation (the external ids are stored in the
//!                  file, so saved embeddings still line up).
//! * `generate`   — write a synthetic benchmark graph to an edge list.
//! * `eval`       — evaluate saved embeddings (node classification or
//!                  link prediction).
//! * `worker`     — host training workers in this process and serve a
//!                  remote coordinator (`train --transport tcp://...`).
//! * `exp`        — regenerate a paper table/figure (table1..table8,
//!                  fig4..fig6, or `all`).
//! * `stats`      — print graph statistics and the Table-1 memory model
//!                  for a given graph size.
//! * `artifacts`  — list the AOT HLO artifacts the runtime can load.
//!
//! Run `graphvite help` for usage.

use std::sync::Arc;

use anyhow::{bail, Context, Result};

use graphvite::cli::{self, Args};
use graphvite::config::{BackendKind, TrainConfig, TrainConfigBuilder};
use graphvite::coordinator::{
    load_checkpoint, save_checkpoint, transport, CheckpointState, TrainFlow, Trainer,
};
use graphvite::embedding::{self, EmbeddingStore, OutputFormat};
use graphvite::eval;
use graphvite::experiments::{self, Scale};
use graphvite::graph::{
    self, generators, GraphFormat, GraphStats, LoadedGraph, PackOptions, ReorderKind,
};
use graphvite::metrics::memory::MemoryModel;
use graphvite::serve::{IndexConfig, ServeConfig, Server};
use graphvite::util::{human_bytes, human_secs};

fn main() {
    let args = match Args::from_env() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(2);
        }
    };
    let code = match run(&args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    };
    std::process::exit(code);
}

fn run(args: &Args) -> Result<()> {
    if args.command.is_empty() {
        print_usage();
        return Ok(());
    }
    // `graphvite <cmd> --help`: the per-subcommand screen generated
    // from its flag-spec table
    if args.flag("help") {
        if let Some(spec) = cli::command_spec(&args.command) {
            print!("{}", spec.help());
            return Ok(());
        }
    }
    match args.command.as_str() {
        "train" => cmd_train(args),
        "pack" => cmd_pack(args),
        "reorder" => cmd_reorder(args),
        "generate" => cmd_generate(args),
        "eval" => cmd_eval(args),
        "serve" => cmd_serve(args),
        "worker" => cmd_worker(args),
        "exp" => cmd_exp(args),
        "stats" => cmd_stats(args),
        "artifacts" => cmd_artifacts(),
        "help" => {
            print_usage();
            Ok(())
        }
        other => bail!("unknown command '{other}' (see `graphvite help`)"),
    }
}

fn print_usage() {
    // The backend list and descriptions are generated from
    // `BackendKind::ALL` so this text cannot drift from the enum.
    println!(
        "graphvite — CPU/'GPU' hybrid node embedding (GraphVite, WWW'19)

USAGE:
  graphvite train [GRAPH] [options]         train embeddings (edge list
                                            or packed graph)
  graphvite pack GRAPH.txt --out F.gvpk     pack an edge list for
                                            out-of-core training
  graphvite reorder GRAPH --out F.gvpk      repack under a locality-aware
                                            node permutation
  graphvite generate --kind K [options]     write a synthetic graph
  graphvite eval TASK [options]             evaluate saved embeddings
  graphvite serve EMB [options]             serve top-k queries over TCP
  graphvite worker --connect HOST:PORT      host a training worker for a
                                            remote coordinator
  graphvite exp NAME [--scale S]            regenerate a paper table/figure
  graphvite stats [GRAPH] [options]         graph stats + memory model
  graphvite artifacts                       list loadable AOT artifacts
  graphvite <command> --help                per-command flag reference

TRAIN OPTIONS (defaults follow paper section 4.3):
  --config FILE.toml    load a [train] config table
  --synthetic KIND      ba | youtube | sbm | karate (instead of GRAPH.txt)
  --nodes N             synthetic graph size            [10000]
  --dim D               embedding dimension             [64]
  --epochs E            |E| positive samples per epoch  [10]
  --workers N           simulated GPUs                  [4]
  --capacities LIST     per-worker capacities, e.g. 2,1 (heterogeneous
                        devices: blocks per wave, chunk scale, residency
                        cap; partitions must be a multiple of the sum)
  --partitions N        matrix partitions (0 = workers; multiple of the
                        total worker capacity; needs --no-fix-context
                        when > workers)
  --samplers N          CPU sampler threads             [4]
  --episode-size N      samples per episode x workers   [200000]
  --backend B           device backend: {names}  [native]
  --shuffle S           none|random|index-mapping|pseudo [pseudo]
  --walk-length L       random walk length (edges)      [5]
  --aug-distance S      augmentation distance           [2]
  --graph-format F      {formats}: how GRAPH is loaded
                        (packed graphs train out-of-core)   [auto]
  --graph-cache-bytes N page-cache budget for packed graphs [64 MiB]
  --lr X, --negatives K, --neg-weight W, --seed N, --batch-size B
  --transport MODE      local | tcp://HOST:PORT — where workers live.
                        tcp listens on HOST:PORT and waits for one
                        `graphvite worker --connect` per worker  [local]
  --no-wire-compression ship raw f32 tcp frames. Compression is on by
                        default: lossless delta/XOR packing, negotiated
                        in the handshake, bitwise-identical results
                        (--wire-compression turns it back on over a
                        config file that disabled it)
  --worker-timeout-secs N  fail if a remote worker goes silent for N
                        seconds mid-training (0 = wait forever)     [0]
  --heartbeat-secs N    PING idle tcp workers every N seconds so a
                        silent slot is named precisely (0 = off)    [0]
  --max-worker-retries N  recover up to N worker failures by replaying
                        the dead slot's journaled jobs to a rejoined
                        replacement or folding them onto survivors —
                        bitwise-identical either way (0 = fail loud) [0]
  --rejoin-window-secs N  hold a dead slot open N seconds for a
                        replacement `graphvite worker` before folding
                        its work onto the survivors (0 = fold now)  [0]
  --fault-checkpoint F  if recovery is exhausted and the run dies, cut
                        a .gvck of the last completed pool boundary
                        at F first (resumes bitwise-identically)
  --no-collaboration    disable the double-buffered pools
  --no-augmentation     plain edge sampling instead of online augmentation
  --no-fix-context      re-transfer context partitions every episode
  --no-pipeline         serial wave dispatch (wait for each wave's results)
  --no-residency        re-ship partitions every episode (no worker pinning)
  --output FILE         save embeddings (format from the extension:
                        .bin/.emb binary, .txt text, .gvemb packed)
  --output-format F     binary | text | gvemb (overrides the extension)
  --checkpoint FILE     write a resumable .gvck checkpoint at every pool
                        boundary (also refreshes --output for `serve
                        --watch` hot reload)
  --checkpoint-every K  checkpoint every K-th pool boundary        [1]
  --resume FILE.gvck    continue a checkpointed run; pass the same graph,
                        seed and --epochs as the full target run (the
                        resumed run is bitwise-identical to training
                        straight through)
  --stop-after-pools K  end the run cleanly after K pool passes (0 = off)

PACK OPTIONS:
  --out FILE.gvpk       output path (required)
  --page-size BYTES     successor-page granularity          [65536]
  --pack-mem-bytes N    packing memory budget; edges are externally
                        sort-merged through spill files, so packing
                        never holds the CSR in RAM       [268435456]
  --reorder KIND        {reorders}: renumber nodes while packing
                        (bfs = hub-rooted breadth-first locality
                        order; external ids are stored in the file
                        and saved embeddings are mapped back) [none]

REORDER OPTIONS (input may be an edge list or an existing .gvpk):
  --out FILE.gvpk       output path (required)
  --reorder KIND        permutation to apply                  [bfs]
  --page-size BYTES  --pack-mem-bytes N    as for pack

GENERATE OPTIONS:
  --kind ba|youtube|sbm|er  --nodes N  --edges-per-node M  --labels K
  --mixing X  --seed N  --out FILE

EVAL TASKS:
  classify  --embeddings F --graph G [--train-frac X] [--seed N]
  linkpred  --embeddings F --graph G [--holdout X] [--seed N]

WORKER OPTIONS (multi-process training; see --transport):
  --connect HOST:PORT   coordinator address (required)
  --connect-timeout-secs N  give up connecting after N seconds      [30]

SERVE OPTIONS (batched top-k over length-prefixed TCP frames):
  --addr HOST:PORT      bind address                  [127.0.0.1:7654]
  --nlist N             IVF inverted lists (0 = ~sqrt(n))          [0]
  --nprobe N            lists probed per query (0 = nlist/8)       [0]
  --watch               hot-reload the embedding file when training
                        rewrites it (pair with train --checkpoint)
  --poll-ms MS          watcher poll interval                    [500]

EXPERIMENTS: table1 table3 table4 table5 table6 table7 table8
             fig4 fig5 fig6 all       (--scale tiny|small|full)

BACKENDS (--backend on the CLI, `backend = \"...\"` in [train] TOML):
{backends}",
        names = BackendKind::names_joined(),
        formats = GraphFormat::names_joined(),
        reorders = ReorderKind::names_joined(),
        backends = BackendKind::help_text()
    );
}

// ---------------------------------------------------------------- train --

/// Load the graph a subcommand operates on: a synthetic generator
/// (always in RAM), or a file routed through `format` — edge list into
/// the in-RAM CSR, packed file into the out-of-core paged reader.
fn load_or_generate_graph(
    args: &Args,
    format: GraphFormat,
    cache_bytes: usize,
) -> Result<LoadedGraph> {
    if let Some(kind) = args.get("synthetic") {
        let n = args.get_parse("nodes", 10_000usize)?;
        let m = args.get_parse("edges-per-node", 5usize)?;
        let labels = args.get_parse("labels", 10usize)?;
        let seed = args.get_parse("seed", 42u64)?;
        let g = match kind {
            "ba" => generators::barabasi_albert(n, m, seed),
            "youtube" => generators::youtube_like(n, labels, seed),
            "sbm" => {
                let mixing = args.get_parse("mixing", 0.05f64)?;
                generators::planted_partition(n, labels, 2.0 * m as f64, mixing, seed)
            }
            "karate" => generators::karate_club(),
            other => bail!("unknown synthetic graph kind '{other}'"),
        };
        return Ok(LoadedGraph::InMemory(Arc::new(g)));
    }
    let path = args
        .positional
        .first()
        .ok_or_else(|| anyhow::anyhow!("need a GRAPH path or --synthetic KIND"))?;
    graph::load_graph(path, format, cache_bytes).with_context(|| format!("loading {path}"))
}

/// The `--graph-format` / `--graph-cache-bytes` flags for subcommands
/// that take them outside a full [`TrainConfig`] (`stats`).
fn graph_flags(args: &Args) -> Result<(GraphFormat, usize)> {
    let defaults = TrainConfig::default();
    let format = match args.get("graph-format") {
        Some(s) => GraphFormat::parse_or_err(s)?,
        None => defaults.graph_format,
    };
    let cache = args.get_parse("graph-cache-bytes", defaults.graph_cache_bytes)?;
    Ok((format, cache))
}

/// Build the train config in layers — defaults, then `--config`'s TOML,
/// then every config-bound CLI flag in the [`cli::spec::TRAIN`] table —
/// and validate once at the end. A failed check names the layer that
/// set the offending value (`... (dim from --dim)` vs `(dim from
/// config.toml)`).
fn config_from_args(args: &Args) -> Result<TrainConfig> {
    let mut b = TrainConfigBuilder::new();
    if let Some(path) = args.get("config") {
        let text =
            std::fs::read_to_string(path).with_context(|| format!("reading {path}"))?;
        b.apply_toml_str(&text, path)?;
    }
    cli::spec::TRAIN.apply_to_builder(args, &mut b)?;
    b.build()
}

fn cmd_train(args: &Args) -> Result<()> {
    let cfg = config_from_args(args)?;
    // resolve the output format up front so a bad --output/--output-format
    // combination fails before hours of training, not after
    let output = args.get("output");
    let out_format = match (args.get("output-format"), output) {
        (Some(f), _) => Some(OutputFormat::parse(f)?),
        (None, Some(path)) => Some(OutputFormat::from_path(path)?),
        (None, None) => None,
    };
    let resume = match args.get("resume") {
        Some(p) => {
            let ck = load_checkpoint(p).with_context(|| format!("loading checkpoint {p}"))?;
            eprintln!(
                "resume: {p} at {} pools, {} samples done",
                ck.pools_done, ck.samples_done
            );
            Some(ck)
        }
        None => None,
    };
    let ckpt_path = args.get("checkpoint").map(str::to_string);
    let ckpt_every = args.get_parse("checkpoint-every", 1u64)?.max(1);
    let stop_after = args.get_parse("stop-after-pools", 0u64)?; // 0 = run to completion
    let loaded = load_or_generate_graph(args, cfg.graph_format, cfg.graph_cache_bytes)?;
    let store = loaded.store();
    // a reordered packed graph trains on internal (locality) ids; saved
    // embedding rows are mapped back through the stored permutation so
    // `eval`/`serve` see the original edge-list ids
    let external: Option<Vec<u32>> = store.external_ids().map(|e| e.to_vec());
    if external.is_some() {
        eprintln!("reorder: graph is node-reordered; saved embeddings use external ids");
    }
    let stats = GraphStats::compute(&*store);
    eprintln!(
        "graph: {} nodes, {} edges (mean degree {:.1}{})",
        stats.num_nodes,
        stats.num_edges,
        stats.mean_degree,
        if loaded.paged().is_some() { ", out-of-core" } else { "" }
    );
    eprintln!(
        "config: dim={} epochs={} workers={} samplers={} backend={} shuffle={}",
        cfg.dim,
        cfg.epochs,
        cfg.num_workers,
        cfg.num_samplers,
        cfg.backend.name(),
        cfg.shuffle.name()
    );

    let mut trainer = Trainer::from_store(store, cfg)?;
    if let Some(p) = args.get("fault-checkpoint") {
        trainer.set_fault_checkpoint(p);
    }
    let result = if resume.is_some() || ckpt_path.is_some() || stop_after > 0 {
        // the observer runs at every pool boundary on fully-synced state:
        // persist a .gvck (and refresh --output so `serve --watch` can
        // hot-reload it), then optionally end the run at this boundary
        let out_path = output.map(str::to_string);
        let mut observer = |state: &CheckpointState<'_>| -> Result<TrainFlow> {
            let stop = stop_after > 0 && state.pools_done >= stop_after;
            if state.pools_done % ckpt_every == 0 || stop {
                if let Some(ck) = &ckpt_path {
                    save_checkpoint(state, ck)?;
                    eprintln!(
                        "checkpoint: {} pools, {} samples -> {ck}",
                        state.pools_done, state.samples_done
                    );
                    if let (Some(out), Some(fmt)) = (&out_path, out_format) {
                        match &external {
                            Some(e) => {
                                embedding::save_embeddings(&state.store.unpermuted(e), out, fmt)?
                            }
                            None => embedding::save_embeddings(state.store, out, fmt)?,
                        }
                    }
                }
            }
            Ok(if stop { TrainFlow::Stop } else { TrainFlow::Continue })
        };
        trainer.train_resumable(resume, Some(&mut observer))?
    } else {
        trainer.train()?
    };
    let s = &result.stats;
    eprintln!(
        "trained {} samples in {} (preprocess {}), {:.2}M samples/s, final loss {:.4}",
        s.counters.samples_trained,
        human_secs(s.train_secs),
        human_secs(s.preprocess_secs),
        s.throughput() / 1e6,
        s.final_loss
    );
    eprintln!(
        "bus: {} to device, {} from device over {} episodes \
         ({} residency hits saved {})",
        human_bytes(s.counters.bytes_to_device),
        human_bytes(s.counters.bytes_from_device),
        s.counters.episodes,
        s.counters.residency_hits,
        human_bytes(s.counters.bytes_saved)
    );
    if let Some(r) = trainer.transport_report() {
        // the transport-smoke CI job greps this line into its artifact
        eprintln!(
            "transport: {} remote workers, {} up, {} down (ledger asserted both \
             sides, {} saved on the wire)",
            r.workers,
            human_bytes(r.bytes_up),
            human_bytes(r.bytes_down),
            human_bytes(r.wire_bytes_saved())
        );
    }
    if let Some(paged) = loaded.paged() {
        // the ondisk-smoke CI job greps this line into its artifact
        let c = paged.cache_stats();
        eprintln!(
            "page-cache: {} hits, {} misses, {} evictions ({} resident of {} budget, \
             {} pages), {} lock-free cursor hits",
            c.hits,
            c.misses,
            c.evictions,
            human_bytes(c.resident_bytes as u64),
            human_bytes(c.budget_bytes as u64),
            human_bytes(c.page_size as u64),
            c.cursor_hits
        );
    }

    if let (Some(out), Some(fmt)) = (output, out_format) {
        match &external {
            Some(e) => embedding::save_embeddings(&result.embeddings.unpermuted(e), out, fmt)?,
            None => embedding::save_embeddings(&result.embeddings, out, fmt)?,
        }
        eprintln!("embeddings saved to {out} ({} format)", fmt.name());
    }
    Ok(())
}

// --------------------------------------------------------------- worker --

fn cmd_worker(args: &Args) -> Result<()> {
    let addr = args
        .get("connect")
        .or_else(|| args.positional.first().map(String::as_str))
        .ok_or_else(|| anyhow::anyhow!("worker needs --connect HOST:PORT (the coordinator)"))?;
    let timeout = args.get_parse("connect-timeout-secs", 30u64)?;
    let summary = transport::run_worker(addr, std::time::Duration::from_secs(timeout))?;
    // the transport-smoke CI job greps this line from each worker log
    eprintln!(
        "worker: slot {} done, {} jobs, {} received ({} on the wire), {} sent \
         ({} on the wire)",
        summary.worker_index,
        summary.jobs,
        human_bytes(summary.bytes_received),
        human_bytes(summary.wire_received),
        human_bytes(summary.bytes_sent),
        human_bytes(summary.wire_sent)
    );
    Ok(())
}

// ---------------------------------------------------------------- serve --

fn cmd_serve(args: &Args) -> Result<()> {
    let emb = args
        .positional
        .first()
        .map(String::as_str)
        .or_else(|| args.get("embeddings"))
        .ok_or_else(|| anyhow::anyhow!("serve needs an embedding file (see `graphvite help`)"))?;
    let index = IndexConfig {
        nlist: args.get_parse("nlist", 0usize)?,
        nprobe: args.get_parse("nprobe", 0usize)?,
        seed: args.get_parse("seed", IndexConfig::default().seed)?,
        ..IndexConfig::default()
    };
    let cfg = ServeConfig {
        addr: args.get("addr").unwrap_or("127.0.0.1:7654").to_string(),
        index,
        watch: args.flag("watch"),
        poll_ms: args.get_parse("poll-ms", 500u64)?,
    };
    Server::start(emb, cfg)?.run()
}

// ----------------------------------------------------------------- pack --

/// The shared `--page-size`/`--pack-mem-bytes`/`--reorder` triple of
/// `pack` and `reorder` (the latter defaults to a BFS permutation — a
/// reorder pass that doesn't reorder is an explicit `--reorder none`).
fn pack_options(args: &Args, default_reorder: ReorderKind) -> Result<PackOptions> {
    let d = PackOptions::default();
    Ok(PackOptions {
        page_size: args.get_parse("page-size", d.page_size)?,
        mem_bytes: args.get_parse("pack-mem-bytes", d.mem_bytes)?,
        reorder: match args.get("reorder") {
            Some(s) => ReorderKind::parse_or_err(s)?,
            None => default_reorder,
        },
    })
}

fn report_pack(input: &str, out: &str, stats: &graph::PackStats, reorder: ReorderKind) {
    eprintln!(
        "packed {input} -> {out}: {} nodes, {} arcs, {} payload \
         ({:.2} bytes/arc vs 8 raw), {} alias sidecar, {} total{}",
        stats.num_nodes,
        stats.num_arcs,
        human_bytes(stats.payload_bytes),
        stats.bytes_per_arc(),
        human_bytes(stats.alias_bytes),
        human_bytes(stats.file_bytes),
        match reorder {
            ReorderKind::None => String::new(),
            k => format!(", {} node order", k.name()),
        }
    );
    eprintln!("train it out-of-core with: graphvite train {out} --graph-format packed");
}

fn cmd_pack(args: &Args) -> Result<()> {
    let input = args
        .positional
        .first()
        .ok_or_else(|| anyhow::anyhow!("pack needs an edge-list path (see `graphvite help`)"))?;
    let out = args
        .get("out")
        .ok_or_else(|| anyhow::anyhow!("--out FILE.gvpk is required"))?;
    let opts = pack_options(args, ReorderKind::None)?;
    let stats = graph::pack_edge_list(input, out, &opts)
        .with_context(|| format!("packing {input}"))?;
    report_pack(input, out, &stats, opts.reorder);
    Ok(())
}

// -------------------------------------------------------------- reorder --

fn cmd_reorder(args: &Args) -> Result<()> {
    let input = args.positional.first().ok_or_else(|| {
        anyhow::anyhow!("reorder needs a graph path (edge list or .gvpk; see `graphvite help`)")
    })?;
    let out = args
        .get("out")
        .ok_or_else(|| anyhow::anyhow!("--out FILE.gvpk is required"))?;
    let opts = pack_options(args, ReorderKind::Bfs)?;
    let stats = if graph::ondisk::is_packed(input) {
        // repack an existing packed graph through the streaming reorder
        // path; its page cache reuses the pack budget
        let paged = graph::PagedCsr::open(input, opts.mem_bytes)
            .with_context(|| format!("opening {input}"))?;
        graph::pack_store(&paged, out, &opts).with_context(|| format!("reordering {input}"))?
    } else {
        graph::pack_edge_list(input, out, &opts).with_context(|| format!("reordering {input}"))?
    };
    report_pack(input, out, &stats, opts.reorder);
    Ok(())
}

// ------------------------------------------------------------- generate --

fn cmd_generate(args: &Args) -> Result<()> {
    let kind = args.get("kind").unwrap_or("ba");
    let n = args.get_parse("nodes", 10_000usize)?;
    let m = args.get_parse("edges-per-node", 5usize)?;
    let labels = args.get_parse("labels", 10usize)?;
    let mixing = args.get_parse("mixing", 0.05f64)?;
    let seed = args.get_parse("seed", 42u64)?;
    let out = args
        .get("out")
        .ok_or_else(|| anyhow::anyhow!("--out FILE is required"))?;
    let g = match kind {
        "ba" => generators::barabasi_albert(n, m, seed),
        "youtube" => generators::youtube_like(n, labels, seed),
        "sbm" => generators::planted_partition(n, labels, 2.0 * m as f64, mixing, seed),
        "er" => generators::erdos_renyi(n, n * m, seed),
        other => bail!("unknown graph kind '{other}'"),
    };
    graph::save_edge_list(&g, out)?;
    let s = GraphStats::compute(&g);
    eprintln!(
        "wrote {}: {} nodes, {} edges, mean degree {:.1}, top-1% degree share {:.2}",
        out, s.num_nodes, s.num_edges, s.mean_degree, s.top1pct_degree_share
    );
    Ok(())
}

// ----------------------------------------------------------------- eval --

fn cmd_eval(args: &Args) -> Result<()> {
    let task = args
        .positional
        .first()
        .map(String::as_str)
        .ok_or_else(|| anyhow::anyhow!("eval needs a task: classify | linkpred"))?;
    let emb_path = args
        .get("embeddings")
        .ok_or_else(|| anyhow::anyhow!("--embeddings FILE is required"))?;
    let graph_path = args
        .get("graph")
        .ok_or_else(|| anyhow::anyhow!("--graph FILE is required"))?;
    let store = load_embeddings_any(emb_path)?;
    let graph = graph::load_edge_list(graph_path)?;
    anyhow::ensure!(
        store.num_nodes() == graph.num_nodes(),
        "embeddings ({}) and graph ({}) disagree on node count",
        store.num_nodes(),
        graph.num_nodes()
    );
    let seed = args.get_parse("seed", 7u64)?;
    match task {
        "classify" => {
            anyhow::ensure!(graph.labels().is_some(), "graph has no labels");
            let frac = args.get_parse("train-frac", 0.02f64)?;
            let report = experiments::classify(&store, &graph, frac, seed);
            println!(
                "micro-F1 {:.2}%  macro-F1 {:.2}%  ({}% labeled)",
                100.0 * report.micro_f1,
                100.0 * report.macro_f1,
                100.0 * frac
            );
        }
        "linkpred" => {
            let holdout = args.get_parse("holdout", 0.01f64)?;
            let split = eval::LinkSplit::new(&graph, holdout, seed);
            let auc = eval::link_prediction_auc(&store, &split);
            println!(
                "link prediction AUC {:.4} over {} held-out edges",
                auc,
                split.positives.len()
            );
        }
        other => bail!("unknown eval task '{other}'"),
    }
    Ok(())
}

fn load_embeddings_any(path: &str) -> Result<EmbeddingStore> {
    // sniff the magic instead of trusting the extension — a renamed or
    // mislabeled file loads correctly or fails loudly, never half-parses
    embedding::load_embeddings_auto(path)
}

// ------------------------------------------------------------------ exp --

fn cmd_exp(args: &Args) -> Result<()> {
    let name = args
        .positional
        .first()
        .map(String::as_str)
        .ok_or_else(|| anyhow::anyhow!("exp needs a name (table1..table8, fig4..fig6, all)"))?;
    let scale = match args.get("scale") {
        Some(s) => Scale::parse(s).ok_or_else(|| anyhow::anyhow!("unknown scale '{s}'"))?,
        None => Scale::Small,
    };
    experiments::run(name, scale)
}

// ---------------------------------------------------------------- stats --

fn cmd_stats(args: &Args) -> Result<()> {
    if args.positional.is_empty() && args.get("synthetic").is_none() {
        // no graph: print the paper's Table-1 memory model
        MemoryModel::paper_example().table().print();
        return Ok(());
    }
    let (format, cache_bytes) = graph_flags(args)?;
    let loaded = load_or_generate_graph(args, format, cache_bytes)?;
    let store = loaded.store();
    let s = GraphStats::compute(&*store);
    println!("nodes            {}", s.num_nodes);
    println!("edges            {}", s.num_edges);
    println!(
        "degree           min {} / mean {:.2} / max {}",
        s.min_degree, s.mean_degree, s.max_degree
    );
    println!("top-1% share     {:.3}", s.top1pct_degree_share);
    let dim = args.get_parse("dim", 128u64)?;
    let model = MemoryModel {
        num_nodes: s.num_nodes as u64,
        num_edges: s.num_edges as u64,
        dim,
        walk_length: args.get_parse("walk-length", 5u64)?,
        augmentation_distance: args.get_parse("aug-distance", 2u64)?,
    };
    model.table().print();
    Ok(())
}

// ------------------------------------------------------------ artifacts --

fn cmd_artifacts() -> Result<()> {
    let dir = graphvite::runtime::artifacts_dir();
    let manifest = graphvite::runtime::default_manifest()
        .with_context(|| format!("no manifest under {} — run `make artifacts`", dir.display()))?;
    println!("artifacts dir: {}", dir.display());
    for meta in manifest.all() {
        println!("  {meta}");
    }
    Ok(())
}
