//! Embedding persistence: a compact binary format (magic + header + raw
//! f32 rows) and the word2vec text format other toolchains consume.

use std::fs::File;
use std::io::{BufRead, BufReader, BufWriter, Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use super::EmbeddingStore;

const MAGIC: &[u8; 8] = b"GRVITE01";

/// Save both matrices in the binary format.
pub fn save_embeddings_binary(store: &EmbeddingStore, path: impl AsRef<Path>) -> Result<()> {
    let mut w = BufWriter::new(File::create(path)?);
    w.write_all(MAGIC)?;
    w.write_all(&(store.num_nodes() as u64).to_le_bytes())?;
    w.write_all(&(store.dim() as u64).to_le_bytes())?;
    for mat in [store.vertex_matrix(), store.context_matrix()] {
        // SAFETY-free path: write f32s via to_le_bytes chunks
        let mut buf = Vec::with_capacity(mat.len() * 4);
        for &x in mat {
            buf.extend_from_slice(&x.to_le_bytes());
        }
        w.write_all(&buf)?;
    }
    Ok(())
}

/// Load a binary embedding file.
pub fn load_embeddings(path: impl AsRef<Path>) -> Result<EmbeddingStore> {
    let mut r = BufReader::new(File::open(path.as_ref()).with_context(|| {
        format!("open {}", path.as_ref().display())
    })?);
    let mut magic = [0u8; 8];
    r.read_exact(&mut magic)?;
    if &magic != MAGIC {
        bail!("not a graphvite embedding file (bad magic)");
    }
    let mut u64buf = [0u8; 8];
    r.read_exact(&mut u64buf)?;
    let n = u64::from_le_bytes(u64buf) as usize;
    r.read_exact(&mut u64buf)?;
    let d = u64::from_le_bytes(u64buf) as usize;
    let mut read_matrix = |len: usize| -> Result<Vec<f32>> {
        let mut bytes = vec![0u8; len * 4];
        r.read_exact(&mut bytes)?;
        Ok(bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    };
    let vertex = read_matrix(n * d)?;
    let context = read_matrix(n * d)?;
    Ok(EmbeddingStore::from_raw(n, d, vertex, context))
}

/// Save the vertex matrix in word2vec text format (`n d` header, then
/// `node x1 x2 …` per line).
pub fn save_embeddings_text(store: &EmbeddingStore, path: impl AsRef<Path>) -> Result<()> {
    let mut w = BufWriter::new(File::create(path)?);
    writeln!(w, "{} {}", store.num_nodes(), store.dim())?;
    for v in 0..store.num_nodes() as u32 {
        write!(w, "{v}")?;
        for x in store.vertex(v) {
            write!(w, " {x}")?;
        }
        writeln!(w)?;
    }
    Ok(())
}

/// Load word2vec text format (vertex matrix only; context zeroed).
pub fn load_embeddings_text(path: impl AsRef<Path>) -> Result<EmbeddingStore> {
    let r = BufReader::new(File::open(path)?);
    let mut lines = r.lines();
    let header = lines.next().ok_or_else(|| anyhow::anyhow!("empty file"))??;
    let mut it = header.split_whitespace();
    let n: usize = it.next().unwrap().parse()?;
    let d: usize = it
        .next()
        .ok_or_else(|| anyhow::anyhow!("bad header"))?
        .parse()?;
    let mut vertex = vec![0f32; n * d];
    for line in lines {
        let line = line?;
        let mut it = line.split_whitespace();
        let v: usize = match it.next() {
            Some(tok) => tok.parse()?,
            None => continue,
        };
        for (j, tok) in it.enumerate() {
            if j >= d {
                bail!("row {v} has more than {d} values");
            }
            vertex[v * d + j] = tok.parse()?;
        }
    }
    Ok(EmbeddingStore::from_raw(n, d, vertex, vec![0.0; n * d]))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("graphvite_emb_io");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn binary_roundtrip() {
        let e = EmbeddingStore::init(37, 9, 1);
        let p = tmp("emb.bin");
        save_embeddings_binary(&e, &p).unwrap();
        let e2 = load_embeddings(&p).unwrap();
        assert_eq!(e2.num_nodes(), 37);
        assert_eq!(e2.dim(), 9);
        assert_eq!(e.vertex_matrix(), e2.vertex_matrix());
        assert_eq!(e.context_matrix(), e2.context_matrix());
    }

    #[test]
    fn text_roundtrip_vertex() {
        let e = EmbeddingStore::init(7, 3, 2);
        let p = tmp("emb.txt");
        save_embeddings_text(&e, &p).unwrap();
        let e2 = load_embeddings_text(&p).unwrap();
        for v in 0..7u32 {
            for (a, b) in e.vertex(v).iter().zip(e2.vertex(v)) {
                assert!((a - b).abs() < 1e-6);
            }
        }
    }

    #[test]
    fn bad_magic_rejected() {
        let p = tmp("bad.bin");
        std::fs::write(&p, b"NOTMAGIC__________").unwrap();
        assert!(load_embeddings(&p).is_err());
    }
}
