//! Out-of-core graph storage suite: the packed on-disk format and the
//! [`PagedCsr`] reader must be *observation-equivalent* to the in-RAM
//! CSR — same degrees, same successor sequences (same order!), same
//! weights to the bit, same stats — because every RNG draw in the
//! sampling stack indexes into those observations. That equivalence is
//! what makes the headline assertion here hold: training off a packed
//! file is bitwise-identical to training off the loader, while the page
//! cache stays bounded at its configured byte budget.

use std::sync::Arc;

use graphvite::config::{BackendKind, TrainConfig};
use graphvite::coordinator::Trainer;
use graphvite::graph::{
    self, generators, Graph, GraphBuilder, GraphStats, GraphStore, PackOptions, PagedCsr,
};
use graphvite::partition::Partitioner;
use graphvite::pool::ShuffleKind;
use graphvite::util::prop::{forall, Gen};

fn tmp(name: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join("graphvite_ondisk_tests");
    std::fs::create_dir_all(&dir).unwrap();
    dir.join(name)
}

/// Pack `g`, reopen it paged, and assert every observation the sampling
/// stack can make agrees with the in-RAM store.
fn assert_observation_equivalent(g: &Graph, page_size: u32, cache_bytes: usize, tag: &str) {
    let path = tmp(&format!("equiv_{tag}.gvpk"));
    graph::pack_graph(g, &path, &PackOptions { page_size, ..Default::default() }).unwrap();
    let p = PagedCsr::open(&path, cache_bytes).unwrap();

    assert_eq!(GraphStore::num_nodes(&p), g.num_nodes(), "{tag}: nodes");
    assert_eq!(GraphStore::num_edges(&p), g.num_edges(), "{tag}: edges");
    assert_eq!(GraphStore::num_arcs(&p), g.num_arcs(), "{tag}: arcs");
    assert_eq!(p.unit_weights(), g.unit_weights(), "{tag}: unit flag");
    assert_eq!(GraphStore::labels(&p), g.labels(), "{tag}: labels");

    let (mut t, mut w) = (Vec::new(), Vec::new());
    for v in 0..g.num_nodes() as u32 {
        assert_eq!(GraphStore::degree(&p, v), g.degree(v), "{tag}: degree({v})");
        assert_eq!(
            GraphStore::weighted_degree(&p, v).to_bits(),
            g.weighted_degree(v).to_bits(),
            "{tag}: weighted_degree({v})"
        );
        p.successors_into(v, &mut t);
        assert_eq!(t, g.neighbors(v), "{tag}: successors({v})");
        p.neighborhood_into(v, &mut t, &mut w);
        assert_eq!(t, g.neighbors(v), "{tag}: neighborhood targets({v})");
        let got: Vec<u32> = w.iter().map(|x| x.to_bits()).collect();
        let want: Vec<u32> = g.neighbor_weights(v).iter().map(|x| x.to_bits()).collect();
        assert_eq!(got, want, "{tag}: neighborhood weights({v})");
    }

    // aggregate observations: stats and the full arc scan
    assert_eq!(GraphStats::compute(&p), GraphStats::compute(g), "{tag}: stats");
    let mut paged_arcs = Vec::new();
    p.for_each_arc(&mut |u, v, wt| paged_arcs.push((u, v, wt.to_bits())));
    let ram_arcs: Vec<(u32, u32, u32)> = {
        let mut out = Vec::new();
        GraphStore::for_each_arc(g, &mut |u, v, wt| out.push((u, v, wt.to_bits())));
        out
    };
    assert_eq!(paged_arcs, ram_arcs, "{tag}: arc scan");

    // the cache never exceeds its (page-clamped) budget
    let s = p.cache_stats();
    assert!(
        s.resident_bytes <= s.budget_bytes,
        "{tag}: cache {} over budget {}",
        s.resident_bytes,
        s.budget_bytes
    );
}

// ------------------------------------------------------- property tests --

#[test]
fn paged_equals_ram_on_random_graphs() {
    forall("paged csr == ram csr", 40, |g: &mut Gen| {
        let n = g.usize_in(2..80);
        let edges = g.edges(n, 300);
        let weighted = g.bool(0.4);
        // over-declare nodes sometimes: trailing isolated (empty-adjacency)
        // nodes must round-trip too
        let extra = g.usize_in(0..4);
        let mut b = GraphBuilder::new().with_num_nodes(n + extra);
        for (u, v) in edges {
            let w = if weighted { g.f32_in(0.1..4.0) } else { 1.0 };
            b.push_edge(u, v, w);
        }
        let graph = b.build();
        let page_size = *g.choose(&[16u32, 64, 256, 4096]);
        // budgets from "one page" to "everything resident"
        let cache = *g.choose(&[1usize, 128, 4096, 1 << 20]);
        assert_observation_equivalent(&graph, page_size, cache, &format!("case{}", g.case));
    });
}

#[test]
fn empty_adjacency_and_max_degree_nodes() {
    // a star: node 0 touches everyone (the max-degree record spans many
    // pages at page_size 16), plus isolated nodes past the star
    let mut b = GraphBuilder::new().with_num_nodes(70);
    for v in 1..64u32 {
        b.push_edge(0, v, 1.0);
    }
    let g = b.build();
    assert_eq!(g.degree(0), 63);
    assert_eq!(g.degree(69), 0);
    assert_observation_equivalent(&g, 16, 64, "star");
}

#[test]
fn all_isolated_and_empty_graphs() {
    // nodes but no edges
    let g = GraphBuilder::new().with_num_nodes(7).build();
    assert_observation_equivalent(&g, 64, 64, "isolated");
    // no nodes at all
    let g = GraphBuilder::new().build();
    assert_observation_equivalent(&g, 64, 64, "empty");
}

#[test]
fn labeled_graph_round_trips() {
    let g = generators::planted_partition(300, 4, 10.0, 0.1, 17);
    assert!(g.labels().is_some());
    assert_observation_equivalent(&g, 256, 2048, "labeled");
}

// ------------------------------------------------------------ fail loud --

#[test]
fn corrupted_header_and_truncation_fail_loud() {
    let g = generators::barabasi_albert(100, 3, 3);
    let path = tmp("corrupt.gvpk");
    graph::pack_graph(&g, &path, &PackOptions::default()).unwrap();
    let bytes = std::fs::read(&path).unwrap();

    // bad magic
    let mut bad = bytes.clone();
    bad[0] = b'X';
    let p = tmp("bad_magic.gvpk");
    std::fs::write(&p, &bad).unwrap();
    let err = PagedCsr::open(&p, 1 << 20).unwrap_err().to_string();
    assert!(err.contains("magic"), "{err}");

    // future version
    let mut bad = bytes.clone();
    bad[4] = 0xFF;
    let p = tmp("bad_version.gvpk");
    std::fs::write(&p, &bad).unwrap();
    let err = PagedCsr::open(&p, 1 << 20).unwrap_err().to_string();
    assert!(err.contains("version"), "{err}");

    // truncated payload (drop the last 100 bytes)
    let p = tmp("truncated.gvpk");
    std::fs::write(&p, &bytes[..bytes.len() - 100]).unwrap();
    let err = PagedCsr::open(&p, 1 << 20).unwrap_err().to_string();
    assert!(err.contains("truncated"), "{err}");

    // trailing garbage is as loud as truncation
    let mut bad = bytes.clone();
    bad.extend_from_slice(b"junk");
    let p = tmp("trailing.gvpk");
    std::fs::write(&p, &bad).unwrap();
    assert!(PagedCsr::open(&p, 1 << 20).is_err());

    // header intact but the degree ledger broken: bump one degree entry
    let mut bad = bytes;
    let degrees_pos = u64::from_le_bytes(bad[40..48].try_into().unwrap()) as usize;
    bad[degrees_pos] = bad[degrees_pos].wrapping_add(1);
    let p = tmp("bad_ledger.gvpk");
    std::fs::write(&p, &bad).unwrap();
    let err = PagedCsr::open(&p, 1 << 20).unwrap_err().to_string();
    assert!(err.contains("arc count"), "{err}");
}

#[test]
fn corrupt_page_panics_instead_of_training_on_garbage() {
    let g = generators::karate_club();
    let path = tmp("page_corrupt.gvpk");
    graph::pack_graph(&g, &path, &PackOptions::default()).unwrap();
    let mut bytes = std::fs::read(&path).unwrap();
    // node 0's record starts at pages_pos (offsets[0] == 0): setting its
    // last byte's continuation bit makes the final varint overrun the
    // record — open still succeeds (header is fine), the read must panic
    let pages_pos = u64::from_le_bytes(bytes[80..88].try_into().unwrap()) as usize;
    let offsets_pos = u64::from_le_bytes(bytes[32..40].try_into().unwrap()) as usize;
    let end0 =
        u64::from_le_bytes(bytes[offsets_pos + 8..offsets_pos + 16].try_into().unwrap()) as usize;
    bytes[pages_pos + end0 - 1] |= 0x80;
    std::fs::write(&path, &bytes).unwrap();
    let p = PagedCsr::open(&path, 1 << 20).unwrap();
    let panicked = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        let mut t = Vec::new();
        p.successors_into(0, &mut t);
    }));
    assert!(panicked.is_err(), "corrupt record must not decode silently");
}

#[test]
fn sidecar_sections_fail_as_loudly_as_the_header() {
    use graphvite::graph::ReorderKind;
    // weighted + BFS-reordered: the file carries every optional section
    // (labels aside) — perm, alias ledger, alias pages
    let mut b = GraphBuilder::new();
    for (u, v, w) in [(0, 1, 2.0), (1, 2, 0.5), (0, 2, 1.5), (2, 3, 1.25), (3, 4, 0.75)] {
        b.push_edge(u, v, w);
    }
    let g = b.build();
    assert!(!g.unit_weights());
    let path = tmp("sidecars.gvpk");
    graph::pack_store(
        &g,
        &path,
        &PackOptions { reorder: ReorderKind::Bfs, ..Default::default() },
    )
    .unwrap();
    let bytes = std::fs::read(&path).unwrap();
    PagedCsr::open(&path, 1 << 20).unwrap(); // the pristine file opens

    let perm_pos = u64::from_le_bytes(bytes[64..72].try_into().unwrap()) as usize;
    let alias_offsets_pos = u64::from_le_bytes(bytes[72..80].try_into().unwrap()) as usize;
    assert!(perm_pos != 0 && alias_offsets_pos != 0, "expected both sidecars present");

    // copy perm[1] over perm[0]: a duplicate external id is no bijection
    let mut bad = bytes.clone();
    bad.copy_within(perm_pos + 4..perm_pos + 8, perm_pos);
    let p = tmp("bad_perm.gvpk");
    std::fs::write(&p, &bad).unwrap();
    let err = PagedCsr::open(&p, 1 << 20).unwrap_err().to_string();
    assert!(err.contains("bijection"), "{err}");

    // bump an alias-ledger entry: it must disagree with the degree table
    let mut bad = bytes.clone();
    bad[alias_offsets_pos + 8] = bad[alias_offsets_pos + 8].wrapping_add(8);
    let p = tmp("bad_alias_ledger.gvpk");
    std::fs::write(&p, &bad).unwrap();
    let err = PagedCsr::open(&p, 1 << 20).unwrap_err().to_string();
    assert!(err.contains("alias ledger"), "{err}");

    // chop the tail of the alias pages: the length reconciliation trips
    let p = tmp("alias_truncated.gvpk");
    std::fs::write(&p, &bytes[..bytes.len() - 4]).unwrap();
    let err = PagedCsr::open(&p, 1 << 20).unwrap_err().to_string();
    assert!(err.contains("truncated"), "{err}");

    // an unknown flag bit is a newer format or corruption, never ignorable
    let mut bad = bytes.clone();
    bad[28] |= 0x10;
    let p = tmp("bad_flag.gvpk");
    std::fs::write(&p, &bad).unwrap();
    let err = PagedCsr::open(&p, 1 << 20).unwrap_err().to_string();
    assert!(err.contains("unknown flag"), "{err}");

    // clearing the alias flag on a weighted file contradicts unit-weights
    let mut bad = bytes.clone();
    bad[28] &= !0x08;
    let p = tmp("flag_disagree.gvpk");
    std::fs::write(&p, &bad).unwrap();
    let err = PagedCsr::open(&p, 1 << 20).unwrap_err().to_string();
    assert!(err.contains("alias-sidecar flag disagrees"), "{err}");
}

// ------------------------------------------------- end-to-end training --

fn train_cfg(seed: u64) -> TrainConfig {
    TrainConfig {
        dim: 8,
        epochs: 3,
        num_workers: 2,
        num_samplers: 2,
        episode_size: 2_000,
        batch_size: 64,
        backend: BackendKind::test_backend(),
        shuffle: ShuffleKind::Pseudo,
        seed,
        ..TrainConfig::default()
    }
}

/// The ISSUE acceptance assertion: same seed, same config — the packed
/// on-disk graph and the in-RAM loader produce bitwise-identical
/// embeddings, with the page cache held to a tiny configured budget the
/// whole time.
#[test]
fn packed_training_is_bitwise_identical_to_in_ram() {
    let g = generators::barabasi_albert(400, 4, 33);
    let path = tmp("train_unit.gvpk");
    graph::pack_graph(&g, &path, &PackOptions { page_size: 512, ..Default::default() }).unwrap();
    // 4 KiB budget on a multi-KiB payload: constant paging during training
    let paged = Arc::new(PagedCsr::open(&path, 4 * 1024).unwrap());

    let ram = Trainer::new(g, train_cfg(91)).unwrap().train().unwrap();
    let disk = Trainer::from_store(Arc::clone(&paged) as Arc<dyn GraphStore>, train_cfg(91))
        .unwrap()
        .train()
        .unwrap();

    assert_eq!(
        ram.embeddings.vertex_matrix(),
        disk.embeddings.vertex_matrix(),
        "vertex matrices diverged between loader and packed file"
    );
    assert_eq!(
        ram.embeddings.context_matrix(),
        disk.embeddings.context_matrix(),
        "context matrices diverged between loader and packed file"
    );
    assert_eq!(ram.stats.counters.samples_trained, disk.stats.counters.samples_trained);

    let s = paged.cache_stats();
    assert!(s.misses > 0, "training never touched the pages?");
    assert!(s.hits > 0, "no locality at all is suspicious: {s:?}");
    assert!(s.evictions > 0, "a 4 KiB budget must evict: {s:?}");
    assert!(s.resident_bytes <= s.budget_bytes, "cache over budget: {s:?}");
}

#[test]
fn packed_training_matches_on_weighted_graphs_too() {
    // weighted path: the walker materializes per-node alias tables from
    // streamed neighborhoods — table construction order and weight bits
    // must match the in-RAM build exactly
    let mut b = GraphBuilder::new();
    let mut rng = graphvite::util::rng::Rng::new(7);
    for _ in 0..900 {
        let u = rng.below_usize(250) as u32;
        let mut v = rng.below_usize(250) as u32;
        if u == v {
            v = (v + 1) % 250;
        }
        b.push_edge(u, v, ((u + v) % 7 + 1) as f32 * 0.5);
    }
    let g = b.build();
    assert!(!g.unit_weights());
    let path = tmp("train_weighted.gvpk");
    graph::pack_graph(&g, &path, &PackOptions { page_size: 256, ..Default::default() }).unwrap();
    let paged = Arc::new(PagedCsr::open(&path, 2 * 1024).unwrap());
    // v2 files page the alias tables instead of rebuilding them in RAM —
    // the bitwise identity below must hold *through the streamed path*
    assert!(
        paged.alias_tables_streamed(),
        "weighted packed graphs must stream their alias tables"
    );

    let ram = Trainer::new(g, train_cfg(55)).unwrap().train().unwrap();
    let disk = Trainer::from_store(paged as Arc<dyn GraphStore>, train_cfg(55))
        .unwrap()
        .train()
        .unwrap();
    assert_eq!(ram.embeddings.vertex_matrix(), disk.embeddings.vertex_matrix());
    assert_eq!(ram.embeddings.context_matrix(), disk.embeddings.context_matrix());
}

#[test]
fn concurrent_readers_agree_with_ram_under_eviction_pressure() {
    // the per-thread page-cursor fast path: many threads scan the same
    // tiny-budget store at once, each from a different starting offset so
    // their cursors chase different pages while the LRU recycles slots
    // underneath them. Every observation must still match the in-RAM
    // graph bit-for-bit — a cursor serving stale or recycled page bytes
    // would show up here as a wrong successor list.
    let g = Arc::new(generators::barabasi_albert(500, 4, 21));
    let path = tmp("concurrent.gvpk");
    graph::pack_graph(&g, &path, &PackOptions { page_size: 64, ..Default::default() }).unwrap();
    // 4 resident pages: constant eviction + slot recycling
    let p = Arc::new(PagedCsr::open(&path, 64 * 4).unwrap());

    let n = g.num_nodes() as u32;
    std::thread::scope(|scope| {
        for t in 0..8u32 {
            let (g, p) = (Arc::clone(&g), Arc::clone(&p));
            scope.spawn(move || {
                let (mut tg, mut w) = (Vec::new(), Vec::new());
                for round in 0..3u32 {
                    for i in 0..n {
                        // stagger the scans so threads disagree on pages
                        let v = (i + t * 61 + round * 17) % n;
                        p.successors_into(v, &mut tg);
                        assert_eq!(tg, g.neighbors(v), "thread {t} round {round} node {v}");
                        p.neighborhood_into(v, &mut tg, &mut w);
                        let got: Vec<u32> = w.iter().map(|x| x.to_bits()).collect();
                        let want: Vec<u32> =
                            g.neighbor_weights(v).iter().map(|x| x.to_bits()).collect();
                        assert_eq!(got, want, "thread {t} round {round} node {v} weights");
                    }
                }
            });
        }
    });

    let s = p.cache_stats();
    assert!(s.evictions > 0, "a 4-page budget must evict: {s:?}");
    assert!(s.cursor_hits > 0, "sequential scans must hit the thread cursors: {s:?}");
    assert!(s.resident_bytes <= s.budget_bytes, "cache over budget: {s:?}");
}

#[test]
fn partitioner_and_negative_sampler_agree_across_stores() {
    // the other two consumers of the GraphStore seam: identical
    // partitionings and identical negative-sampler tables (byte-level
    // weighted degrees) whichever store feeds them
    let g = generators::barabasi_albert(300, 3, 11);
    let path = tmp("parts.gvpk");
    graph::pack_graph(&g, &path, &PackOptions::default()).unwrap();
    let p = PagedCsr::open(&path, 1 << 16).unwrap();
    let ram_parts = Partitioner::degree_zigzag(&g, 4);
    let paged_parts = Partitioner::degree_zigzag(&p, 4);
    for v in 0..300u32 {
        assert_eq!(ram_parts.part_of(v), paged_parts.part_of(v));
        assert_eq!(ram_parts.local_row(v), paged_parts.local_row(v));
    }
}
