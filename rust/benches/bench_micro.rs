//! Micro-benchmarks of every substrate on the hot path. These are the
//! numbers the EXPERIMENTS.md §Perf table tracks; run with
//!
//!     cargo bench --bench bench_micro
//!
//! Scale knobs: GRAPHVITE_BENCH_FAST=1 shrinks iteration counts for CI.
//!
//! Like `bench_pipeline`, this target **self-records**: every run writes
//! `BENCH_micro_<scale>.json` next to this file (the benches/README
//! convention; the scale tag is the `GRAPHVITE_BENCH_SCALE` label — the
//! micro workloads themselves are fixed-size), so CI's scheduled bench
//! job can upload the raw lines as artifacts.

use graphvite::config::{BackendKind, TrainConfig};
use graphvite::coordinator::Trainer;
use graphvite::embedding::{EmbeddingStore, Matrix};
use graphvite::experiments::Scale;
use graphvite::gpu::{
    native_minibatch_step, simd_minibatch_step, Kernels, ScalarKernels, UnrolledKernels,
};
use graphvite::graph::generators;
use graphvite::partition::Partitioner;
use graphvite::pool::{shuffle, ShuffleKind};
use graphvite::sampling::{
    AliasTable, AugmentConfig, NegativeSampler, OnlineAugmenter, RandomWalker,
};
use graphvite::util::bench::{black_box, record_json, Bencher};
use graphvite::util::rng::Rng;

fn fast() -> bool {
    std::env::var("GRAPHVITE_BENCH_FAST").is_ok()
}

fn main() {
    let mut b = if fast() {
        Bencher::with_iters(1, 3)
    } else {
        Bencher::with_iters(3, 10)
    };

    println!("== sampling substrates ==");
    bench_rng(&mut b);
    bench_alias(&mut b);
    bench_augmentation(&mut b);
    bench_negative(&mut b);

    println!("== out-of-core graph (pack + paged reads) ==");
    bench_ondisk(&mut b);

    println!("== pool shuffles (Table 7 speed column) ==");
    bench_shuffles(&mut b);

    println!("== partition gather/scatter (episode transfers) ==");
    bench_gather_scatter(&mut b);

    println!("== dim kernels (scalar vs hand-unrolled f32x8) ==");
    bench_kernels(&mut b);

    println!("== device backends (per-chunk train step) ==");
    bench_minibatch_steps(&mut b);
    bench_hlo_step(&mut b);

    println!("== wire codec (net::compress pack/unpack) ==");
    bench_net(&mut b);

    println!("== serve (IVF ANN vs brute-force top-k) ==");
    bench_serve(&mut b);

    println!("== end-to-end trainer (native) ==");
    bench_trainer(&mut b);

    // self-record per the benches/README BENCH_*.json convention
    let scale = Scale::from_env().name();
    let path = format!("{}/benches/BENCH_micro_{scale}.json", env!("CARGO_MANIFEST_DIR"));
    record_json(&path, &format!("bench_micro scale={scale}"), &b.result_lines());
}

fn bench_rng(b: &mut Bencher) {
    let mut rng = Rng::new(1);
    const N: usize = 10_000_000;
    b.bench_items("rng.next_u64 x10M", N as f64, || {
        let mut acc = 0u64;
        for _ in 0..N {
            acc = acc.wrapping_add(rng.next_u64());
        }
        acc
    });
}

fn bench_alias(b: &mut Bencher) {
    let mut rng = Rng::new(2);
    let weights: Vec<f32> = (0..1_000_000).map(|i| ((i % 1000) + 1) as f32).collect();
    b.bench("alias.build 1M outcomes", || AliasTable::new(&weights));
    let t = AliasTable::new(&weights);
    const N: usize = 10_000_000;
    b.bench_items("alias.sample x10M", N as f64, || {
        let mut acc = 0u32;
        for _ in 0..N {
            acc = acc.wrapping_add(t.sample(&mut rng));
        }
        acc
    });
}

fn bench_augmentation(b: &mut Bencher) {
    let g = generators::barabasi_albert(100_000, 5, 3);
    let dep = OnlineAugmenter::departure_table(&g);
    let walker = RandomWalker::new(&g);
    let cfg = AugmentConfig { walk_length: 5, augmentation_distance: 2 };
    const N: usize = 1_000_000;
    b.bench_items("online_augmentation.fill 1M samples (1 thread)", N as f64, || {
        let mut aug = OnlineAugmenter::new(&walker, &dep, cfg, Rng::new(4));
        let mut out = Vec::with_capacity(N);
        aug.fill(&mut out, N);
        out.len()
    });
}

fn bench_negative(b: &mut Bencher) {
    let g = generators::barabasi_albert(100_000, 5, 5);
    let parts = Partitioner::degree_zigzag(&g, 4);
    let neg = NegativeSampler::new(&g, &parts);
    let mut rng = Rng::new(6);
    const N: usize = 10_000_000;
    b.bench_items("negative.sample_local x10M", N as f64, || {
        let mut acc = 0u32;
        for i in 0..N {
            acc = acc.wrapping_add(neg.sample_local(i % 4, &mut rng));
        }
        acc
    });
}

/// The packed on-disk graph path: pack throughput, the sequential arc
/// scan (page-friendly) and random successor reads (cache-hostile) — the
/// streaming costs training pays when the graph does not fit in RAM.
fn bench_ondisk(b: &mut Bencher) {
    use graphvite::graph::{pack_graph, GraphStore, PackOptions, PagedCsr, ReorderKind};
    let g = generators::barabasi_albert(100_000, 5, 21);
    let dir = std::env::temp_dir().join("graphvite_bench_ondisk");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("ba100k.gvpk");
    let arcs = g.num_arcs() as f64;
    b.bench_items("ondisk.pack 100k nodes (arcs/s)", arcs, || {
        pack_graph(&g, &path, &PackOptions::default()).unwrap().payload_bytes
    });
    let paged = PagedCsr::open(&path, 1 << 20).unwrap(); // 1 MiB cache: real paging
    b.bench_items("ondisk.scan paged 1MiB-cache (arcs/s)", arcs, || {
        let mut n = 0u64;
        paged.for_each_arc(&mut |_, _, _| n += 1);
        n
    });
    b.bench_items("ondisk.scan in-RAM (arcs/s)", arcs, || {
        let mut n = 0u64;
        GraphStore::for_each_arc(&g, &mut |_, _, _| n += 1);
        n
    });
    let mut rng = Rng::new(22);
    let mut t = Vec::new();
    let n = if fast() { 20_000 } else { 200_000 };
    b.bench_items(&format!("ondisk.successors random x{n} (paged)"), n as f64, || {
        let mut acc = 0usize;
        for _ in 0..n {
            paged.successors_into(rng.below_usize(100_000) as u32, &mut t);
            acc += t.len();
        }
        acc
    });
    let s = paged.cache_stats();
    println!(
        "ondisk page-cache: {} hits, {} misses, {} evictions ({} resident of {} budget)",
        s.hits, s.misses, s.evictions, s.resident_bytes, s.budget_bytes
    );

    // locality: BFS reordering vs input order under an identical tiny
    // cache, driven by the access pattern that matters — random walks
    let bfs_path = dir.join("ba100k_bfs.gvpk");
    b.bench_items("ondisk.reorder bfs repack 100k nodes (arcs/s)", arcs, || {
        pack_graph(
            &g,
            &bfs_path,
            &PackOptions { reorder: ReorderKind::Bfs, ..Default::default() },
        )
        .unwrap()
        .payload_bytes
    });
    let walks = if fast() { 2_000 } else { 20_000 };
    let mut rates: Vec<(&str, f64)> = Vec::new();
    for (name, p) in [("input-order", &path), ("bfs-order", &bfs_path)] {
        let walked = PagedCsr::open(p, 256 * 1024).unwrap(); // 256 KiB: heavy paging
        let walker = RandomWalker::new(&walked);
        let mut wrng = Rng::new(31);
        b.bench_items(&format!("ondisk.reorder walk5 x{walks} ({name})"), walks as f64, || {
            let mut acc = 0usize;
            for _ in 0..walks {
                acc += walker.walk(wrng.below_usize(100_000) as u32, 5, &mut wrng).len();
            }
            acc
        });
        let s = walked.cache_stats();
        let rate = s.hits as f64 / (s.hits + s.misses).max(1) as f64;
        println!(
            "ondisk.reorder {name}: hit rate {rate:.3} ({} hits, {} misses, {} evictions)",
            s.hits, s.misses, s.evictions
        );
        rates.push((name, rate));
    }
    println!(
        "ondisk.reorder locality delta: bfs {:.3} vs input {:.3}",
        rates[1].1, rates[0].1
    );
}

fn bench_shuffles(b: &mut Bencher) {
    let n = if fast() { 1_000_000 } else { 10_000_000 };
    let base: Vec<(u32, u32)> = (0..n)
        .map(|i| ((i / 4) as u32, (i as u32).wrapping_mul(2654435761)))
        .collect();
    for kind in [
        ShuffleKind::None,
        ShuffleKind::Random,
        ShuffleKind::IndexMapping,
        ShuffleKind::Pseudo,
    ] {
        let mut rng = Rng::new(7);
        b.bench_items(&format!("shuffle.{} {}M samples", kind.name(), n / 1_000_000), n as f64, || {
            let mut pool = base.clone();
            shuffle::shuffle(kind, &mut pool, 5, &mut rng);
            black_box(pool.len())
        });
    }
}

fn bench_gather_scatter(b: &mut Bencher) {
    let g = generators::barabasi_albert(100_000, 5, 8);
    let parts = Partitioner::degree_zigzag(&g, 4);
    let store = EmbeddingStore::init(100_000, 128, 9);
    let cap = parts.max_part_size();
    let mut buf = Vec::new();
    let rows = parts.part_size(0) as f64;
    b.bench_items("gather_partition 25k rows x d128", rows, || {
        store.gather_partition(&parts, 0, cap, Matrix::Vertex, &mut buf);
        buf.len()
    });
    let mut store2 = EmbeddingStore::init(100_000, 128, 10);
    store2.gather_partition(&parts, 0, cap, Matrix::Vertex, &mut buf);
    let data = buf.clone();
    b.bench_items("scatter_partition 25k rows x d128", rows, || {
        store2.scatter_partition(&parts, 0, Matrix::Vertex, &data);
        0
    });
}

/// The `dim`-wide inner loops in isolation — the scalar-vs-unrolled
/// speedup here is the headline number for the `simd` backend (the full
/// step adds gather/scatter memory traffic on top).
fn bench_kernels(b: &mut Bencher) {
    let d = 128;
    let mut rng = Rng::new(10);
    let x: Vec<f32> = (0..d).map(|_| rng.range_f32(-1.0, 1.0)).collect();
    let y: Vec<f32> = (0..d).map(|_| rng.range_f32(-1.0, 1.0)).collect();
    let n = if fast() { 100_000 } else { 1_000_000 };
    b.bench_items(&format!("kernel.dot d{d} scalar ({n} calls)"), n as f64, || {
        let mut acc = 0.0f32;
        for _ in 0..n {
            acc += ScalarKernels::dot(black_box(&x), black_box(&y));
        }
        acc
    });
    b.bench_items(&format!("kernel.dot d{d} f32x8  ({n} calls)"), n as f64, || {
        let mut acc = 0.0f32;
        for _ in 0..n {
            acc += UnrolledKernels::dot(black_box(&x), black_box(&y));
        }
        acc
    });
    let mut out = vec![0.0f32; d];
    b.bench_items(&format!("kernel.axpy d{d} scalar ({n} calls)"), n as f64, || {
        for _ in 0..n {
            ScalarKernels::axpy(black_box(&mut out[..]), 1e-6, black_box(&x));
        }
        out[0]
    });
    let mut out2 = vec![0.0f32; d];
    b.bench_items(&format!("kernel.axpy d{d} f32x8  ({n} calls)"), n as f64, || {
        for _ in 0..n {
            UnrolledKernels::axpy(black_box(&mut out2[..]), 1e-6, black_box(&x));
        }
        out2[0]
    });
}

/// Full mini-batch step, scalar vs unrolled, at an 8-aligned dim and at a
/// remainder-lane dim (d100 = 12 full lanes + 4-wide tail per row).
fn bench_minibatch_steps(b: &mut Bencher) {
    let p = 4096;
    let bsz = 256;
    let k = 1;
    for d in [64usize, 100] {
        let base: Vec<f32> = (0..p * d).map(|i| ((i % 97) as f32 - 48.0) / 100.0).collect();
        let mut rng = Rng::new(11);
        let pos_u: Vec<i32> = (0..bsz).map(|_| rng.below(p as u64) as i32).collect();
        let pos_v: Vec<i32> = (0..bsz).map(|_| rng.below(p as u64) as i32).collect();
        let neg_v: Vec<i32> = (0..bsz * k).map(|_| rng.below(p as u64) as i32).collect();

        let (mut vertex, mut context) = (base.clone(), base.clone());
        let (mut gu, mut gc) = (Vec::new(), Vec::new());
        b.bench_items(&format!("native_minibatch_step b256 d{d} k1 (samples/s)"), bsz as f64, || {
            native_minibatch_step(
                &mut vertex, &mut context, d, &pos_u, &pos_v, &neg_v, k, 0.001, 5.0, &mut gu,
                &mut gc,
            )
        });

        let (mut sv, mut sc) = (base.clone(), base);
        let (mut sgu, mut sgc) = (Vec::new(), Vec::new());
        b.bench_items(&format!("simd_minibatch_step   b256 d{d} k1 (samples/s)"), bsz as f64, || {
            simd_minibatch_step(
                &mut sv, &mut sc, d, &pos_u, &pos_v, &neg_v, k, 0.001, 5.0, &mut sgu, &mut sgc,
            )
        });
    }
}

#[cfg(not(feature = "pjrt"))]
fn bench_hlo_step(_b: &mut Bencher) {
    println!("bench hlo: built without the pjrt feature, skipping");
}

#[cfg(feature = "pjrt")]
fn bench_hlo_step(b: &mut Bencher) {
    use graphvite::runtime::{default_manifest, Device};

    let Ok(m) = default_manifest() else {
        println!("bench hlo: no artifacts, skipping");
        return;
    };
    let meta = m.find_train(4096, 64).expect("p4096 d64 artifact").clone();
    let dev = Device::load(&meta).expect("compile artifact");
    let (p, d, s, bsz, k) = (meta.p, meta.d, meta.s, meta.b, meta.k);
    let vertex: Vec<f32> = (0..p * d).map(|i| ((i % 97) as f32 - 48.0) / 100.0).collect();
    let context = vertex.clone();
    let mut rng = Rng::new(12);
    let pos_u: Vec<i32> = (0..s * bsz).map(|_| rng.below(p as u64) as i32).collect();
    let pos_v: Vec<i32> = (0..s * bsz).map(|_| rng.below(p as u64) as i32).collect();
    let neg_v: Vec<i32> = (0..s * bsz * k).map(|_| rng.below(p as u64) as i32).collect();
    let samples = (s * bsz) as f64;
    b.bench_items(
        &format!("hlo_train_step p{p} d{d} s{s} b{bsz} (samples/s, incl. transfers)"),
        samples,
        || {
            let (vl, cl) = dev.upload_partitions(&vertex, &context).unwrap();
            let (nv, nc, loss) = dev.train_step(vl, cl, &pos_u, &pos_v, &neg_v, 0.001).unwrap();
            let _ = dev.download_partitions(&nv, &nc).unwrap();
            loss
        },
    );
    // steady-state: keep literals device-side between steps (no host copy)
    b.bench_items(
        &format!("hlo_train_step p{p} d{d} chained (samples/s, no download)"),
        samples * 4.0,
        || {
            let (mut vl, mut cl) = dev.upload_partitions(&vertex, &context).unwrap();
            let mut last = 0f32;
            for _ in 0..4 {
                let (nv, nc, loss) = dev.train_step(vl, cl, &pos_u, &pos_v, &neg_v, 0.001).unwrap();
                vl = nv;
                cl = nc;
                last = loss;
            }
            last
        },
    );
}

/// The wire codec the socket transport runs every shipment through:
/// Gorilla-style XOR delta coding against the receiver-resident base
/// (`net::compress`). Throughput is per f32 both directions; the printed
/// byte counts are the delta-vs-raw sizes the transport ledger reports
/// as `wire_bytes_saved`. The synthetic shipment mimics one episode of
/// SGD: half the rows untouched (XOR-zero runs), half nudged slightly.
fn bench_net(b: &mut Bencher) {
    use graphvite::net::compress::{pack_f32s, unpack_f32s};
    use graphvite::net::Cursor;

    let rows = 4096usize;
    for d in [64usize, 128] {
        let n = rows * d;
        let mut rng = Rng::new(23);
        let base: Vec<f32> = (0..n).map(|_| rng.range_f32(-1.0, 1.0)).collect();
        let xs: Vec<f32> = base
            .iter()
            .enumerate()
            .map(|(i, &x)| if (i / d) % 2 == 0 { x } else { x + 1e-3 * x })
            .collect();

        let mut stored = Vec::new();
        b.bench_items(&format!("net.pack stored  p{rows} d{d} (f32/s)"), n as f64, || {
            stored.clear();
            pack_f32s(&mut stored, &xs, None, false).wire
        });
        let mut delta = Vec::new();
        b.bench_items(&format!("net.pack delta   p{rows} d{d} (f32/s)"), n as f64, || {
            delta.clear();
            pack_f32s(&mut delta, &xs, Some(&base), true).wire
        });
        let mut decoded = Vec::new();
        b.bench_items(&format!("net.unpack delta p{rows} d{d} (f32/s)"), n as f64, || {
            let mut c = Cursor::new(&delta);
            unpack_f32s(&mut c, Some(&base), &mut decoded).unwrap().raw
        });
        assert_eq!(decoded, xs, "codec must stay bit-exact");
        let raw = 4 * n as u64;
        println!(
            "net.bytes d{d}: raw {raw}, delta {} ({:.2}x smaller), stored {}",
            delta.len(),
            raw as f64 / delta.len() as f64,
            stored.len(),
        );
    }
}

/// The `graphvite serve` query path: IVF-flat probing must beat the exact
/// scan (the acceptance bar for shipping an ANN index at all), and the
/// index build itself is timed because hot reload pays it on every
/// checkpoint.
fn bench_serve(b: &mut Bencher) {
    use graphvite::serve::{AnnIndex, IndexConfig};

    let n = if fast() { 20_000 } else { 100_000 };
    let d = 64;
    let store = EmbeddingStore::init(n, d, 17);
    let cfg = IndexConfig::default();
    b.bench(&format!("serve.index_build {}k nodes d{d}", n / 1000), || {
        AnnIndex::build(&store, &cfg).nlist()
    });
    let idx = AnnIndex::build(&store, &cfg);
    let queries = if fast() { 200 } else { 2_000 };
    let mut rng = Rng::new(18);
    let ids: Vec<u32> = (0..queries).map(|_| rng.below(n as u64) as u32).collect();

    let mut brute = 0u64;
    b.bench_items(&format!("serve.brute_force top10 x{queries} (queries/s)"), queries as f64, || {
        brute = 0;
        for &v in &ids {
            let q = idx.vector(v).to_vec();
            brute += idx.brute_force(&q, 10).len() as u64;
        }
        brute
    });
    let mut ann = 0u64;
    b.bench_items(
        &format!("serve.ann top10 x{queries} nprobe={} (queries/s)", idx.nprobe()),
        queries as f64,
        || {
            ann = 0;
            for &v in &ids {
                ann += idx.search_node(v, 10, idx.nprobe()).len() as u64;
            }
            ann
        },
    );
    black_box((brute, ann));
}

fn bench_trainer(b: &mut Bencher) {
    let g = generators::barabasi_albert(20_000, 5, 13);
    let epochs = if fast() { 2 } else { 10 };
    let samples = (epochs * g.num_edges()) as f64;
    b.bench_items(
        &format!("trainer.native 4w 20k nodes {epochs} epochs (samples/s)"),
        samples,
        || {
            let cfg = TrainConfig {
                dim: 64,
                epochs,
                num_workers: 4,
                num_samplers: 4,
                episode_size: 50_000,
                backend: BackendKind::Native,
                ..TrainConfig::default()
            };
            let mut t = Trainer::new(g.clone(), cfg).unwrap();
            t.train().unwrap().stats.counters.samples_trained
        },
    );
}
