//! Downstream evaluation substrate: the tasks the paper measures
//! embedding quality with — multi-class node classification via
//! one-vs-rest logistic regression (Tables 4/6/7, Figs 4/5) and link
//! prediction AUC (Hyperlink-PLD, Fig 4).

pub mod classifier;
pub mod linkpred;
pub mod split;

pub use classifier::{LogisticOvR, NodeClassificationReport};
pub use linkpred::{auc_from_scores, graph_reconstruction_auc, link_prediction_auc, LinkSplit};
pub use split::train_test_split;
