//! Pure-rust SGNS trainer with mini-batch semantics matching the HLO
//! artifact bit-for-bit in structure (gather -> gradients at pre-update
//! values -> scatter-add), so the two backends can be cross-validated.
//!
//! The math is exactly Layer 1's:
//!     s = <u, v>;  g = weight * (sigmoid(s) - label)
//!     u -= lr * g * v;  v -= lr * g * u_old
//! with loss = weight * (softplus(s) - label * s).
//!
//! The mini-batch skeleton (index translation, gradient accumulation,
//! scatter-add, loss reduction) is written once, generic over a
//! [`Kernels`] implementation that supplies the three `dim`-wide inner
//! loops. [`ScalarKernels`] here is the straight-line reference; the
//! hand-unrolled f32x8 variant lives in [`crate::gpu::UnrolledKernels`]
//! and must agree with it within reassociation error (enforced by
//! `rust/tests/simd_kernels.rs`).

use crate::gpu::ChunkPlan;
use crate::metrics::Counters;

/// Stable softplus, matching the kernel's max(s,0)+log1p(exp(-|s|)).
#[inline]
fn softplus(s: f32) -> f32 {
    s.max(0.0) + (-s.abs()).exp().ln_1p()
}

#[inline]
fn sigmoid(s: f32) -> f32 {
    1.0 / (1.0 + (-s).exp())
}

/// The `dim`-wide inner loops of the SGNS mini-batch step. Everything a
/// backend spends its FLOPs on goes through these three operations, so a
/// [`minibatch_step`] instantiation is fully characterized by its
/// `Kernels` impl:
///
/// * [`ScalarKernels`] — sequential reference implementation.
/// * [`crate::gpu::UnrolledKernels`] — hand-unrolled 8-lane version.
///
/// `axpy` and `apply_zero` are element-wise and must be bit-identical
/// across implementations; only `dot` may reassociate its reduction (and
/// therefore differ by a few ULPs).
pub trait Kernels {
    /// Inner product `<a, b>`. Implementations may reassociate the sum.
    fn dot(a: &[f32], b: &[f32]) -> f32;

    /// `out[j] += g * x[j]` — gradient accumulation.
    fn axpy(out: &mut [f32], g: f32, x: &[f32]);

    /// `m[j] -= lr * g[j]; g[j] = 0.0` — fused SGD row update + gradient
    /// clear (the clear keeps the dense accumulator invariant of
    /// [`minibatch_step`]: every entry zero between calls).
    fn apply_zero(m: &mut [f32], g: &mut [f32], lr: f32);
}

/// Straight-line scalar kernels — the reference implementation every
/// other [`Kernels`] impl is property-tested against.
pub struct ScalarKernels;

impl Kernels for ScalarKernels {
    #[inline]
    fn dot(a: &[f32], b: &[f32]) -> f32 {
        a.iter().zip(b).map(|(x, y)| x * y).sum()
    }

    #[inline]
    fn axpy(out: &mut [f32], g: f32, x: &[f32]) {
        for (o, v) in out.iter_mut().zip(x) {
            *o += g * *v;
        }
    }

    #[inline]
    fn apply_zero(m: &mut [f32], g: &mut [f32], lr: f32) {
        for (mv, gv) in m.iter_mut().zip(g.iter_mut()) {
            *mv -= lr * *gv;
            *gv = 0.0;
        }
    }
}

/// One mini-batch step with the [`ScalarKernels`] reference inner loops —
/// the historical entry point, kept for benches and cross-validation
/// against the HLO artifact. See [`minibatch_step`] for the semantics.
#[allow(clippy::too_many_arguments)]
pub fn native_minibatch_step(
    vertex: &mut [f32],
    context: &mut [f32],
    dim: usize,
    pos_u: &[i32],
    pos_v: &[i32],
    neg_v: &[i32],
    k: usize,
    lr: f32,
    neg_weight: f32,
    grad_u_buf: &mut Vec<f32>,
    grad_c_buf: &mut Vec<f32>,
) -> f32 {
    minibatch_step::<ScalarKernels>(
        vertex, context, dim, pos_u, pos_v, neg_v, k, lr, neg_weight, grad_u_buf, grad_c_buf,
    )
}

/// One mini-batch step with gradient accumulation (the HLO scan body),
/// generic over the [`Kernels`] supplying the `dim`-wide inner loops.
///
/// `pos_u`/`pos_v` are `bsz` local rows; `neg_v` is `bsz * k` rows.
/// Gradients for the whole batch are computed against the pre-update
/// matrices, then applied with scatter-add — duplicate rows accumulate,
/// matching `jnp .at[].add` semantics. Returns the mean per-sample loss
/// (mean over the `bsz * (1+k)` pair rows, like the kernel's tile mean).
#[allow(clippy::too_many_arguments)]
pub fn minibatch_step<K: Kernels>(
    vertex: &mut [f32],
    context: &mut [f32],
    dim: usize,
    pos_u: &[i32],
    pos_v: &[i32],
    neg_v: &[i32],
    k: usize,
    lr: f32,
    neg_weight: f32,
    grad_u_buf: &mut Vec<f32>,
    grad_c_buf: &mut Vec<f32>,
) -> f32 {
    let bsz = pos_u.len();
    debug_assert_eq!(pos_v.len(), bsz);
    debug_assert_eq!(neg_v.len(), bsz * k);

    // Dense gradient accumulators over the partitions. INVARIANT: between
    // calls every entry is zero — `apply_sparse` re-zeroes exactly the
    // rows that accumulated (pos_u for grad_u; pos_v + neg_v for grad_c).
    // Zeroing the whole buffer per batch instead was the original hot
    // spot: a 2 x P x D memset per 256-sample batch dominated the step
    // (see EXPERIMENTS.md §Perf).
    if grad_u_buf.len() != vertex.len() {
        grad_u_buf.clear();
        grad_u_buf.resize(vertex.len(), 0.0);
    }
    if grad_c_buf.len() != context.len() {
        grad_c_buf.clear();
        grad_c_buf.resize(context.len(), 0.0);
    }

    let mut loss_sum = 0.0f64;
    for i in 0..bsz {
        let u = pos_u[i] as usize * dim;
        let urow = &vertex[u..u + dim];
        let gu = &mut grad_u_buf[u..u + dim];

        // positive pair
        let v = pos_v[i] as usize * dim;
        let vrow = &context[v..v + dim];
        let s = K::dot(urow, vrow);
        let g = sigmoid(s) - 1.0; // label=1, weight=1
        loss_sum += (softplus(s) - s) as f64;
        let gv = &mut grad_c_buf[v..v + dim];
        K::axpy(gu, g, vrow);
        K::axpy(gv, g, urow);

        // negatives (label=0, weight=neg_weight)
        for t in 0..k {
            let n = neg_v[i * k + t] as usize * dim;
            let nrow = &context[n..n + dim];
            let s = K::dot(urow, nrow);
            let g = neg_weight * sigmoid(s);
            loss_sum += (neg_weight * softplus(s)) as f64;
            let gn = &mut grad_c_buf[n..n + dim];
            K::axpy(gu, g, nrow);
            K::axpy(gn, g, urow);
        }
    }

    // scatter-add application (only touched rows are nonzero, but a dense
    // axpy over the partition is branch-free; see EXPERIMENTS.md §Perf for
    // the sparse-apply variant benchmarks)
    apply_sparse::<K>(vertex, grad_u_buf, pos_u, dim, lr);
    apply_sparse::<K>(context, grad_c_buf, pos_v, dim, lr);
    apply_sparse::<K>(context, grad_c_buf, neg_v, dim, lr);

    (loss_sum / (bsz * (1 + k)) as f64) as f32
}

/// Subtract lr * grad for each (deduplicated) touched row, then zero the
/// gradient rows so the buffers are clean for the next batch.
fn apply_sparse<K: Kernels>(mat: &mut [f32], grad: &mut [f32], rows: &[i32], dim: usize, lr: f32) {
    for &r in rows {
        let o = r as usize * dim;
        // a row can appear in several index lists / multiple times; after
        // the first application its grad is zeroed, making reapplication a
        // no-op — this implements "apply each accumulated row once".
        K::apply_zero(&mut mat[o..o + dim], &mut grad[o..o + dim], lr);
    }
}

/// Pure-rust device worker, generic over the [`Kernels`] its inner loops
/// run. One definition serves every streaming (non-batched-upload)
/// backend: [`NativeWorker`] and [`crate::gpu::SimdWorker`] are type
/// aliases of this struct, so they cannot drift apart in state, chunk
/// handling, or [`crate::gpu::Backend`] behavior.
pub struct Worker<K: Kernels> {
    pub dim: usize,
    pub batch_size: usize,
    pub negatives: usize,
    pub neg_weight: f32,
    grad_u: Vec<f32>,
    grad_c: Vec<f32>,
    // fn() -> K keeps auto traits (Send/Sync) independent of K.
    _kernels: std::marker::PhantomData<fn() -> K>,
}

/// Pure-rust device worker with the scalar reference kernels — the
/// default [`crate::gpu::Backend`].
pub type NativeWorker = Worker<ScalarKernels>;

impl<K: Kernels> Worker<K> {
    pub fn new(dim: usize, batch_size: usize, negatives: usize, neg_weight: f32) -> Self {
        Worker {
            dim,
            batch_size,
            negatives,
            neg_weight,
            grad_u: Vec::new(),
            grad_c: Vec::new(),
            _kernels: std::marker::PhantomData,
        }
    }

    /// Train `chunks` in place; returns the mean loss over chunks. (The
    /// trait-object path goes through [`crate::gpu::Backend`]; this
    /// slice-based entry point is kept for direct/bench callers.)
    pub fn train_chunks_in_place(
        &mut self,
        vertex: &mut [f32],
        context: &mut [f32],
        chunks: &[ChunkPlan],
        counters: &Counters,
    ) -> f32 {
        if chunks.is_empty() {
            return 0.0;
        }
        let mut loss_sum = 0.0f64;
        for ch in chunks {
            let loss = minibatch_step::<K>(
                vertex,
                context,
                self.dim,
                &ch.pos_u,
                &ch.pos_v,
                &ch.neg_v,
                self.negatives,
                ch.lr,
                self.neg_weight,
                &mut self.grad_u,
                &mut self.grad_c,
            );
            loss_sum += loss as f64;
            counters.add(&counters.device_steps, 1);
        }
        (loss_sum / chunks.len() as f64) as f32
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn setup(p: usize, dim: usize, seed: u64) -> (Vec<f32>, Vec<f32>) {
        let mut rng = Rng::new(seed);
        let v = (0..p * dim).map(|_| rng.range_f32(-0.1, 0.1)).collect();
        let c = (0..p * dim).map(|_| rng.range_f32(-0.1, 0.1)).collect();
        (v, c)
    }

    #[test]
    fn positive_pairs_attract() {
        let (mut v, mut c) = setup(4, 8, 1);
        let dot_before: f32 = v[0..8].iter().zip(&c[8..16]).map(|(a, b)| a * b).sum();
        let (mut gu, mut gc) = (Vec::new(), Vec::new());
        for _ in 0..50 {
            native_minibatch_step(
                &mut v, &mut c, 8, &[0], &[1], &[2], 1, 0.1, 5.0, &mut gu, &mut gc,
            );
        }
        let dot_after: f32 = v[0..8].iter().zip(&c[8..16]).map(|(a, b)| a * b).sum();
        assert!(dot_after > dot_before, "{dot_before} -> {dot_after}");
    }

    #[test]
    fn loss_decreases() {
        let (mut v, mut c) = setup(16, 8, 2);
        let (mut gu, mut gc) = (Vec::new(), Vec::new());
        let pos_u: Vec<i32> = (0..8).collect();
        let pos_v: Vec<i32> = (8..16).collect();
        let neg: Vec<i32> = (0..8).map(|i| (i + 4) % 16).collect();
        let first = native_minibatch_step(
            &mut v, &mut c, 8, &pos_u, &pos_v, &neg, 1, 0.2, 5.0, &mut gu, &mut gc,
        );
        let mut last = first;
        for _ in 0..30 {
            last = native_minibatch_step(
                &mut v, &mut c, 8, &pos_u, &pos_v, &neg, 1, 0.2, 5.0, &mut gu, &mut gc,
            );
        }
        assert!(last < first, "{first} -> {last}");
    }

    #[test]
    fn duplicate_rows_accumulate_once_applied() {
        // two positives hitting the same u row: grad must accumulate, and
        // the update must be applied exactly once
        let dim = 4;
        let (mut v, mut c) = setup(4, dim, 3);
        let v_orig = v.clone();
        let (mut gu, mut gc) = (Vec::new(), Vec::new());
        // batch: (0 -> 1) twice; k=1 negatives both row 2
        native_minibatch_step(
            &mut v, &mut c, dim, &[0, 0], &[1, 1], &[2, 2], 1, 0.1, 5.0, &mut gu, &mut gc,
        );
        let moved_twice: Vec<f32> = v[0..dim]
            .iter()
            .zip(&v_orig[0..dim])
            .map(|(a, b)| a - b)
            .collect();

        let (mut v2, mut c2) = setup(4, dim, 3);
        native_minibatch_step(
            &mut v2, &mut c2, dim, &[0], &[1], &[2], 1, 0.1, 5.0, &mut gu, &mut gc,
        );
        let moved_once: Vec<f32> = v2[0..dim]
            .iter()
            .zip(&v_orig[0..dim])
            .map(|(a, b)| a - b)
            .collect();
        for (t, o) in moved_twice.iter().zip(&moved_once) {
            assert!((t - 2.0 * o).abs() < 1e-5, "twice {t} vs once {o}");
        }
    }

    #[test]
    fn untouched_rows_unchanged() {
        let (mut v, mut c) = setup(8, 4, 4);
        let (v0, c0) = (v.clone(), c.clone());
        let (mut gu, mut gc) = (Vec::new(), Vec::new());
        native_minibatch_step(
            &mut v, &mut c, 4, &[0], &[1], &[2], 1, 0.1, 5.0, &mut gu, &mut gc,
        );
        // rows 3..8 untouched in both matrices
        assert_eq!(&v[3 * 4..], &v0[3 * 4..]);
        assert_eq!(&c[3 * 4..], &c0[3 * 4..]);
        // u row 0 changed in vertex only; context rows 1,2 changed
        assert_ne!(&v[0..4], &v0[0..4]);
        assert_eq!(&c[0..4], &c0[0..4]);
        assert_ne!(&c[4..8], &c0[4..8]);
    }

    #[test]
    fn zero_lr_identity() {
        let (mut v, mut c) = setup(8, 4, 5);
        let (v0, c0) = (v.clone(), c.clone());
        let (mut gu, mut gc) = (Vec::new(), Vec::new());
        native_minibatch_step(
            &mut v, &mut c, 4, &[0, 3], &[1, 2], &[2, 0], 1, 0.0, 5.0, &mut gu, &mut gc,
        );
        assert_eq!(v, v0);
        assert_eq!(c, c0);
    }
}
