//! DeepWalk baseline: materialize a corpus of truncated random walks
//! (gamma walks per node), then train skip-gram with a context window via
//! hogwild SGNS — the gensim-equivalent pipeline with walks stored in
//! memory (the paper's fastest DeepWalk setting). Training uses either
//! negative sampling (like the paper's own GPU port) or the original
//! hierarchical softmax ([`crate::baselines::hsoftmax`]) — the paper
//! credits the latter for DeepWalk's edge at tiny label fractions
//! (Table 4 discussion, §4.4).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use anyhow::Result;

use crate::baselines::hsoftmax::{hs_update, HuffmanTree};
use crate::baselines::line::sgns_update;
use crate::baselines::BaselineResult;
use crate::embedding::EmbeddingStore;
use crate::graph::Graph;
use crate::metrics::TrainStats;
use crate::sampling::{AliasTable, RandomWalker};
use crate::util::rng::Rng;
use crate::util::timer::Stopwatch;

/// DeepWalk configuration (defaults follow Perozzi et al. scaled down).
#[derive(Debug, Clone)]
pub struct DeepWalkConfig {
    pub dim: usize,
    /// Walks per node.
    pub walks_per_node: usize,
    /// Walk length in edges.
    pub walk_length: usize,
    /// Skip-gram window (DeepWalk default 10; we use the augmentation
    /// distance for comparability with GraphVite runs).
    pub window: usize,
    pub epochs: usize,
    pub lr: f32,
    pub negatives: usize,
    pub neg_weight: f32,
    pub threads: usize,
    /// Use hierarchical softmax instead of negative sampling (the
    /// original DeepWalk objective).
    pub hierarchical_softmax: bool,
    pub seed: u64,
}

impl Default for DeepWalkConfig {
    fn default() -> Self {
        DeepWalkConfig {
            dim: 64,
            walks_per_node: 10,
            walk_length: 40,
            window: 5,
            epochs: 1,
            lr: 0.025,
            negatives: 1,
            neg_weight: 5.0,
            threads: 4,
            hierarchical_softmax: false,
            seed: 42,
        }
    }
}

pub struct DeepWalkBaseline;

impl DeepWalkBaseline {
    pub fn train(graph: &Graph, cfg: &DeepWalkConfig) -> Result<BaselineResult> {
        // ---- preprocessing: generate + store the walk corpus ----
        let mut prep = Stopwatch::started();
        let walker = RandomWalker::new(graph);
        let n = graph.num_nodes();
        let base = Rng::new(cfg.seed);
        let corpus: Vec<Vec<u32>> = std::thread::scope(|s| {
            let per = n.div_ceil(cfg.threads);
            let handles: Vec<_> = (0..cfg.threads)
                .map(|t| {
                    let mut rng = base.split(0xD33 ^ t as u64);
                    let walker = &walker;
                    s.spawn(move || {
                        let lo = t * per;
                        let hi = ((t + 1) * per).min(n);
                        let mut out = Vec::with_capacity((hi - lo) * cfg.walks_per_node);
                        for v in lo..hi {
                            for _ in 0..cfg.walks_per_node {
                                out.push(walker.walk(v as u32, cfg.walk_length, &mut rng));
                            }
                        }
                        out
                    })
                })
                .collect();
            handles.into_iter().flat_map(|h| h.join().unwrap()).collect()
        });
        let neg_weights: Vec<f32> = (0..n as u32)
            .map(|v| graph.weighted_degree(v).max(1e-12).powf(0.75))
            .collect();
        let neg_table = AliasTable::new(&neg_weights);
        // Huffman tree over node frequencies (visit rate ~ degree); the
        // inner-node matrix replaces `context` under hierarchical softmax.
        let hs_tree = if cfg.hierarchical_softmax {
            let freqs: Vec<f32> =
                (0..n as u32).map(|v| graph.weighted_degree(v).max(1e-3)).collect();
            Some(HuffmanTree::build(&freqs))
        } else {
            None
        };
        prep.stop();

        // ---- training: skip-gram over the stored corpus ----
        let mut train_sw = Stopwatch::started();
        let init = EmbeddingStore::init(n, cfg.dim, cfg.seed);
        let vertex = Arc::new(HogwildVec::new(init.vertex_matrix().to_vec()));
        // under HS the "context" rows are the n-1 inner-node parameters,
        // padded to n rows so the store shape stays uniform
        let context = Arc::new(HogwildVec::new(init.context_matrix().to_vec()));
        let trained = Arc::new(AtomicU64::new(0));

        // estimate total pairs for lr decay
        let pairs_per_walk: usize = (0..=cfg.walk_length)
            .map(|i| (i + cfg.window).min(cfg.walk_length).saturating_sub(i))
            .sum();
        let total = (corpus.len() * pairs_per_walk * cfg.epochs) as u64;

        std::thread::scope(|s| {
            let per = corpus.len().div_ceil(cfg.threads);
            for t in 0..cfg.threads {
                let vertex = Arc::clone(&vertex);
                let context = Arc::clone(&context);
                let trained = Arc::clone(&trained);
                let mut rng = base.split(0xD30 ^ t as u64);
                let corpus = &corpus;
                let neg_table = &neg_table;
                let hs_tree = hs_tree.as_ref();
                s.spawn(move || {
                    // SAFETY: hogwild, see HogwildVec.
                    let v = unsafe { vertex.get() };
                    let c = unsafe { context.get() };
                    let mut hs_buf: Vec<f32> = Vec::new();
                    for _ in 0..cfg.epochs {
                        let lo = t * per;
                        let hi = ((t + 1) * per).min(corpus.len());
                        for walk in &corpus[lo..hi] {
                            for i in 0..walk.len() {
                                let upper = (i + cfg.window).min(walk.len() - 1);
                                for j in (i + 1)..=upper {
                                    let done = trained.fetch_add(1, Ordering::Relaxed);
                                    let lr = cfg.lr
                                        * (1.0 - done as f32 / total.max(1) as f32).max(1e-4);
                                    match hs_tree {
                                        Some(tree) => {
                                            hs_update(
                                                v,
                                                c,
                                                cfg.dim,
                                                tree,
                                                walk[i],
                                                walk[j],
                                                lr,
                                                &mut hs_buf,
                                            );
                                        }
                                        None => sgns_update(
                                            v,
                                            c,
                                            cfg.dim,
                                            walk[i],
                                            walk[j],
                                            neg_table,
                                            cfg.negatives,
                                            cfg.neg_weight,
                                            lr,
                                            &mut rng,
                                        ),
                                    }
                                }
                            }
                        }
                    }
                });
            }
        });
        train_sw.stop();

        let vertex = Arc::try_unwrap(vertex)
            .map_err(|_| anyhow::anyhow!("still shared"))?
            .into_inner();
        let context = Arc::try_unwrap(context)
            .map_err(|_| anyhow::anyhow!("still shared"))?
            .into_inner();
        let mut stats = TrainStats {
            train_secs: train_sw.secs(),
            preprocess_secs: prep.secs(),
            ..Default::default()
        };
        stats.counters.samples_trained = trained.load(Ordering::Relaxed);
        Ok(BaselineResult {
            embeddings: EmbeddingStore::from_raw(n, cfg.dim, vertex, context),
            stats,
        })
    }
}

/// Hogwild-shared Vec<f32> (same caveats as LINE's SharedMatrix).
struct HogwildVec(std::cell::UnsafeCell<Vec<f32>>);
unsafe impl Sync for HogwildVec {}

impl HogwildVec {
    fn new(v: Vec<f32>) -> Self {
        HogwildVec(std::cell::UnsafeCell::new(v))
    }

    #[allow(clippy::mut_from_ref)]
    unsafe fn get(&self) -> &mut [f32] {
        &mut *self.0.get()
    }

    fn into_inner(self) -> Vec<f32> {
        self.0.into_inner()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;

    #[test]
    fn deepwalk_trains() {
        let g = generators::karate_club();
        let cfg = DeepWalkConfig {
            dim: 8,
            walks_per_node: 5,
            walk_length: 10,
            window: 3,
            threads: 2,
            ..Default::default()
        };
        let r = DeepWalkBaseline::train(&g, &cfg).unwrap();
        assert_eq!(r.embeddings.num_nodes(), 34);
        assert!(r.stats.counters.samples_trained > 0);
        assert!(r.stats.preprocess_secs >= 0.0);
    }

    #[test]
    fn corpus_pairs_counted() {
        let g = generators::barabasi_albert(100, 2, 3);
        let cfg = DeepWalkConfig {
            dim: 8,
            walks_per_node: 2,
            walk_length: 8,
            window: 2,
            threads: 2,
            ..Default::default()
        };
        let r = DeepWalkBaseline::train(&g, &cfg).unwrap();
        // trained pairs should be close to the analytic estimate
        let pairs_per_walk: usize =
            (0..=8usize).map(|i| (i + 2).min(8).saturating_sub(i)).sum();
        let expect = (100 * 2 * pairs_per_walk) as u64;
        let got = r.stats.counters.samples_trained;
        assert!(got <= expect && got > expect / 2, "got {got} expect {expect}");
    }
}
