//! node2vec baseline (Grover & Leskovec, KDD'16): second-order biased
//! random walks + skip-gram training.
//!
//! The defining (and expensive) part is the walk bias: the probability of
//! stepping from `v` to `x`, having arrived from `t`, is proportional to
//!
//! ```text
//!   1/p   if x == t            (return)
//!   1     if dist(t, x) == 1   (stay near)
//!   1/q   otherwise            (explore outward)
//! ```
//!
//! The reference implementation precomputes one alias table **per
//! directed edge** (the transition distribution depends on the previous
//! node), which is exactly why the paper's Table 3 reports 25.9 *hours*
//! of preprocessing for node2vec on YouTube versus minutes for everyone
//! else. We reproduce that architecture faithfully — per-edge alias
//! tables built in parallel, counted as preprocessing time — so the
//! Table 3 shape (huge preprocessing, competitive training) emerges from
//! the same cause.


use anyhow::Result;

use crate::baselines::line::sgns_update;
use crate::baselines::BaselineResult;
use crate::embedding::EmbeddingStore;
use crate::graph::Graph;
use crate::metrics::TrainStats;
use crate::sampling::AliasTable;
use crate::util::rng::Rng;
use crate::util::timer::Stopwatch;

/// node2vec configuration (defaults follow the reference implementation).
#[derive(Debug, Clone)]
pub struct Node2VecConfig {
    pub dim: usize,
    /// Walks started per node.
    pub walks_per_node: usize,
    /// Walk length in edges.
    pub walk_length: usize,
    /// Skip-gram window.
    pub window: usize,
    /// Return parameter p (small p -> backtrack often).
    pub p: f32,
    /// In-out parameter q (small q -> explore outward, DFS-like).
    pub q: f32,
    pub lr: f32,
    pub negatives: usize,
    pub neg_weight: f32,
    pub threads: usize,
    pub seed: u64,
}

impl Default for Node2VecConfig {
    fn default() -> Self {
        Node2VecConfig {
            dim: 64,
            walks_per_node: 10,
            walk_length: 40,
            window: 5,
            p: 1.0,
            q: 0.5,
            lr: 0.025,
            negatives: 1,
            neg_weight: 5.0,
            threads: 4,
            seed: 42,
        }
    }
}

/// Per-edge transition tables: `table[edge_index(v, i)]` is the alias
/// table over `neighbors(v)` given that the walk arrived at `v` via its
/// `i`-th incident edge. Indexed by CSR offset, so lookup is O(deg).
struct EdgeTransitions {
    /// offsets[v] = start of v's slot range (one slot per incident edge).
    offsets: Vec<usize>,
    tables: Vec<Option<AliasTable>>,
}

impl EdgeTransitions {
    /// The node2vec preprocessing stage: one alias table per directed
    /// edge. Parallelized over source nodes.
    fn build(graph: &Graph, p: f32, q: f32, threads: usize) -> Self {
        let n = graph.num_nodes();
        let mut offsets = Vec::with_capacity(n + 1);
        let mut acc = 0usize;
        for v in 0..n as u32 {
            offsets.push(acc);
            acc += graph.degree(v);
        }
        offsets.push(acc);

        let chunk = n.div_ceil(threads.max(1));
        let mut tables: Vec<Option<AliasTable>> = Vec::with_capacity(acc);
        let parts: Vec<Vec<Option<AliasTable>>> = std::thread::scope(|s| {
            let offsets = &offsets;
            (0..threads.max(1))
                .map(|t| {
                    s.spawn(move || {
                        let lo = (t * chunk).min(n);
                        let hi = ((t + 1) * chunk).min(n);
                        let mut out =
                            Vec::with_capacity(offsets[hi] - offsets[lo]);
                        let mut weights: Vec<f32> = Vec::new();
                        for v in lo as u32..hi as u32 {
                            // previous node t = the neighbor the walk came from
                            for &prev in graph.neighbors(v) {
                                let nbrs = graph.neighbors(v);
                                if nbrs.len() < 2 {
                                    out.push(None); // deterministic step
                                    continue;
                                }
                                weights.clear();
                                weights.extend(nbrs.iter().map(|&x| {
                                    if x == prev {
                                        1.0 / p
                                    } else if graph.has_edge(prev, x) {
                                        1.0
                                    } else {
                                        1.0 / q
                                    }
                                }));
                                out.push(Some(AliasTable::new(&weights)));
                            }
                        }
                        out
                    })
                })
                .map(|h| h.join().unwrap())
                .collect()
        });
        for part in parts {
            tables.extend(part);
        }
        debug_assert_eq!(tables.len(), acc);
        EdgeTransitions { offsets, tables }
    }

    /// Sample the next node after stepping prev -> v.
    fn step(&self, graph: &Graph, prev: u32, v: u32, rng: &mut Rng) -> Option<u32> {
        let nbrs = graph.neighbors(v);
        match nbrs.len() {
            0 => None,
            1 => Some(nbrs[0]),
            _ => {
                // find which incident edge we came in on
                let slot = nbrs.iter().position(|&x| x == prev)?;
                let table = self.tables[self.offsets[v as usize] + slot]
                    .as_ref()
                    .expect("multi-neighbor node has a table");
                Some(nbrs[table.sample(rng) as usize])
            }
        }
    }

    /// Bytes held by the per-edge tables (the node2vec memory cost).
    fn bytes(&self) -> usize {
        self.tables
            .iter()
            .flatten()
            .map(|t| t.bytes())
            .sum::<usize>()
            + self.offsets.len() * std::mem::size_of::<usize>()
    }
}

/// The node2vec system.
pub struct Node2VecBaseline;

impl Node2VecBaseline {
    pub fn train(graph: &Graph, cfg: &Node2VecConfig) -> Result<BaselineResult> {
        anyhow::ensure!(cfg.p > 0.0 && cfg.q > 0.0, "p and q must be positive");
        // ---- preprocessing: per-edge alias tables + walk corpus ----
        let mut prep = Stopwatch::started();
        let trans = EdgeTransitions::build(graph, cfg.p, cfg.q, cfg.threads);
        let corpus = Self::walk_corpus(graph, cfg, &trans);
        prep.stop();

        // ---- skip-gram over the corpus (same trainer as DeepWalk) ----
        let mut train_sw = Stopwatch::started();
        let n = graph.num_nodes();
        let init = EmbeddingStore::init(n, cfg.dim, cfg.seed);
        let mut vertex = init.vertex_matrix().to_vec();
        let mut context = init.context_matrix().to_vec();
        let neg_weights: Vec<f32> = (0..n as u32)
            .map(|v| graph.weighted_degree(v).max(1e-12).powf(0.75))
            .collect();
        let neg_table = AliasTable::new(&neg_weights);

        let mut pairs: u64 = 0;
        let total_pairs: u64 = corpus
            .iter()
            .map(|w| {
                (0..w.len())
                    .map(|i| (i + cfg.window).min(w.len() - 1) - i)
                    .sum::<usize>() as u64
            })
            .sum();
        let mut rng = Rng::new(cfg.seed ^ 0x2755);
        for walk in &corpus {
            for i in 0..walk.len() {
                let upper = (i + cfg.window).min(walk.len() - 1);
                for j in (i + 1)..=upper {
                    if walk[i] == walk[j] {
                        pairs += 1;
                        continue;
                    }
                    let lr = cfg.lr * (1.0 - pairs as f32 / total_pairs as f32).max(1e-4);
                    sgns_update(
                        &mut vertex,
                        &mut context,
                        cfg.dim,
                        walk[i],
                        walk[j],
                        &neg_table,
                        cfg.negatives,
                        cfg.neg_weight,
                        lr,
                        &mut rng,
                    );
                    pairs += 1;
                }
            }
        }
        train_sw.stop();

        let mut stats = TrainStats {
            train_secs: train_sw.secs(),
            preprocess_secs: prep.secs(),
            ..Default::default()
        };
        stats.counters.samples_trained = pairs;
        stats.counters.bytes_to_device = trans.bytes() as u64; // memory cost proxy
        Ok(BaselineResult {
            embeddings: EmbeddingStore::from_raw(n, cfg.dim, vertex, context),
            stats,
        })
    }

    /// Generate `walks_per_node` second-order walks per node (parallel).
    fn walk_corpus(
        graph: &Graph,
        cfg: &Node2VecConfig,
        trans: &EdgeTransitions,
    ) -> Vec<Vec<u32>> {
        let n = graph.num_nodes();
        let threads = cfg.threads.max(1);
        let chunk = n.div_ceil(threads);
        std::thread::scope(|s| {
            (0..threads)
                .map(|t| {
                    let mut rng = Rng::new(cfg.seed).split(0x2712 ^ t as u64);
                    s.spawn(move || {
                        let lo = (t * chunk).min(n);
                        let hi = ((t + 1) * chunk).min(n);
                        let mut walks = Vec::with_capacity((hi - lo) * cfg.walks_per_node);
                        for v in lo as u32..hi as u32 {
                            for _ in 0..cfg.walks_per_node {
                                let mut walk = Vec::with_capacity(cfg.walk_length + 1);
                                walk.push(v);
                                // first step: uniform neighbor
                                let nbrs = graph.neighbors(v);
                                if nbrs.is_empty() {
                                    walks.push(walk);
                                    continue;
                                }
                                let mut cur = nbrs[rng.below_usize(nbrs.len())];
                                walk.push(cur);
                                let mut prev = v;
                                for _ in 1..cfg.walk_length {
                                    match trans.step(graph, prev, cur, &mut rng) {
                                        Some(next) => {
                                            prev = cur;
                                            cur = next;
                                            walk.push(cur);
                                        }
                                        None => break,
                                    }
                                }
                                walks.push(walk);
                            }
                        }
                        walks
                    })
                })
                .collect::<Vec<_>>()
                .into_iter()
                .flat_map(|h| h.join().unwrap())
                .collect()
        })
    }
}

/// Count how often a walk returns to the node it just left (used by the
/// p/q behaviour tests below and exposed for the ablation harness).
pub fn backtrack_fraction(walks: &[Vec<u32>]) -> f64 {
    let mut backtracks = 0usize;
    let mut steps = 0usize;
    for w in walks {
        for win in w.windows(3) {
            steps += 1;
            if win[0] == win[2] {
                backtracks += 1;
            }
        }
    }
    if steps == 0 {
        0.0
    } else {
        backtracks as f64 / steps as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;

    fn corpus_for(p: f32, q: f32, seed: u64) -> Vec<Vec<u32>> {
        let g = generators::barabasi_albert(300, 3, seed);
        let cfg = Node2VecConfig {
            p,
            q,
            walks_per_node: 4,
            walk_length: 20,
            threads: 2,
            ..Default::default()
        };
        let trans = EdgeTransitions::build(&g, p, q, 2);
        Node2VecBaseline::walk_corpus(&g, &cfg, &trans)
    }

    #[test]
    fn walks_stay_on_edges() {
        let g = generators::karate_club();
        let cfg =
            Node2VecConfig { walks_per_node: 3, walk_length: 15, threads: 2, ..Default::default() };
        let trans = EdgeTransitions::build(&g, cfg.p, cfg.q, 2);
        let corpus = Node2VecBaseline::walk_corpus(&g, &cfg, &trans);
        assert_eq!(corpus.len(), 34 * 3);
        for walk in &corpus {
            for w in walk.windows(2) {
                assert!(g.has_edge(w[0], w[1]), "{} -> {}", w[0], w[1]);
            }
        }
    }

    #[test]
    fn small_p_backtracks_more() {
        let bt_low_p = backtrack_fraction(&corpus_for(0.1, 1.0, 7));
        let bt_high_p = backtrack_fraction(&corpus_for(10.0, 1.0, 7));
        assert!(
            bt_low_p > 2.0 * bt_high_p,
            "p=0.1 backtrack {bt_low_p} vs p=10 {bt_high_p}"
        );
    }

    #[test]
    fn small_q_explores_farther() {
        // DFS-like (q small) walks touch more distinct nodes than
        // BFS-like (q large) walks of the same length.
        let distinct = |walks: &[Vec<u32>]| -> f64 {
            walks
                .iter()
                .map(|w| {
                    let mut s: Vec<u32> = w.clone();
                    s.sort_unstable();
                    s.dedup();
                    s.len() as f64 / w.len() as f64
                })
                .sum::<f64>()
                / walks.len() as f64
        };
        let dfs = distinct(&corpus_for(1.0, 0.1, 9));
        let bfs = distinct(&corpus_for(1.0, 10.0, 9));
        assert!(dfs > bfs, "dfs {dfs} <= bfs {bfs}");
    }

    #[test]
    fn trains_and_embeddings_finite() {
        let g = generators::barabasi_albert(200, 3, 11);
        let cfg = Node2VecConfig {
            dim: 16,
            walks_per_node: 3,
            walk_length: 10,
            threads: 2,
            ..Default::default()
        };
        let r = Node2VecBaseline::train(&g, &cfg).unwrap();
        assert_eq!(r.embeddings.num_nodes(), 200);
        assert!(r.embeddings.vertex_matrix().iter().all(|x| x.is_finite()));
        assert!(r.stats.counters.samples_trained > 0);
        assert!(r.stats.preprocess_secs >= 0.0);
    }

    #[test]
    fn preprocessing_memory_scales_with_edges() {
        let g1 = generators::barabasi_albert(200, 2, 13);
        let g2 = generators::barabasi_albert(200, 6, 13);
        let t1 = EdgeTransitions::build(&g1, 1.0, 0.5, 2);
        let t2 = EdgeTransitions::build(&g2, 1.0, 0.5, 2);
        assert!(t2.bytes() > 2 * t1.bytes());
    }

    #[test]
    fn rejects_nonpositive_pq() {
        let g = generators::karate_club();
        assert!(Node2VecBaseline::train(&g, &Node2VecConfig { p: 0.0, ..Default::default() })
            .is_err());
    }
}
