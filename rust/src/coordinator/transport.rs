//! The transport seam: how [`JobMsg`]s travel to device workers and
//! [`Reply`]s come back.
//!
//! The coordinator's planner ([`super::transfer::TransferEngine`]) is
//! delivery-agnostic: it decides *what* moves (versioned shipments,
//! residency keeps, evictions); a [`Transport`] decides *how*. Two
//! implementations ship:
//!
//! * [`LocalTransport`] — the in-process mpsc channels of PRs 1-6,
//!   bitwise-pinned behavior (one sender per worker thread, one shared
//!   result receiver).
//! * [`SocketTransport`] — the same protocol over length-prefixed TCP
//!   frames ([`crate::net`]), one stream per `graphvite worker` process.
//!   A handshake ships each worker its complete state — scaled config,
//!   RNG stream state, per-partition negative-sampling weights — so a
//!   loopback socket run is **bitwise-identical** to the local run
//!   (`rust/tests/transport.rs`).
//!
//! ```text
//!   worker                      coordinator
//!     │ ──── HELLO (magic, proto version) ────▶ │  validated field-by-field;
//!     │ ◀─── ASSIGN (fingerprint, rng, weights)─┤  bad peers get a reject
//!     │ ──── READY / READY-err ───────────────▶ │  frame, never a panic
//!     │                                         │
//!     │ ◀─── TRAIN (block, shipments) ──────────┤  ─┐ repeated per job;
//!     │ ──── RESULT / ERR ─────────────────────▶ │  ─┘ SYNC/SYNCED at fences
//!     │ ◀─── STOP ──────────────────────────────┤
//!     │ ──── BYE (payload-byte ledger) ────────▶ │  both sides' counts must
//!     │                                         │  agree — the wire ledger
//! ```
//!
//! **Overlapped sends (v3).** Each worker slot owns a dedicated writer
//! thread with a bounded send queue — the sending mirror of its reader
//! thread — so frame serialization, compression and socket writes
//! overlap worker compute instead of blocking the coordinator's
//! dispatch loop. Per-worker FIFO is preserved (one queue, one stream);
//! the group fence stays the only barrier, so pipelined socket runs
//! remain bitwise-identical to local runs.
//!
//! **Wire compression (v3).** When `TrainConfig::wire_compression` is
//! negotiated in the handshake, every f32 payload section crosses the
//! wire as a [`crate::net::compress`] packed section: delta-encoded
//! against the version of that `(matrix, partition)` the receiver
//! already holds (both ends keep a [`WireCache`] in lockstep, in either
//! direction), residuals Gorilla-XOR bit-packed, bit-exact on decode.
//! Workers that cannot compress are rejected with a pointed error.
//!
//! **Wire ledger.** Both ends count shipment payload bytes (down) and
//! result payload bytes (up) independently; the worker's counts travel in
//! its BYE and must equal the coordinator's per-connection counts, and
//! the transport totals must equal the transfer engine's
//! `bytes_to_device` / `bytes_from_device` counters — the PR-3 ledger,
//! asserted on both sides of the wire. v3 extends the ledger two-sided
//! per direction: raw payload bytes (what the transfer engine counts)
//! vs on-wire bytes (what the packed sections actually occupied), so
//! `wire_bytes_saved = raw - wire` is itself a balanced, asserted
//! quantity and the shutdown banner can print a compression ratio.
//!
//! **Failure discipline.** Every decode path returns a pointed error
//! (never panics); a worker-side job error travels back as an ERR frame
//! and surfaces exactly like the local path's `Result<Reply>` channel; a
//! closed connection is "worker N disconnected", not a hang. The
//! [`FlakyTransport`] test double wraps any transport with deterministic
//! seeded drops / holds (reorders) / duplicate delivery / injected
//! disconnects to prove those properties (`rust/tests/transport.rs`).
//!
//! `samples_trained` is counted coordinator-side on absorb (from
//! `JobResult::trained`), so ledgers are identical for local and remote
//! workers; per-device timing counters (`device_nanos`) remain
//! worker-local and are not part of the ledger.

use std::collections::{HashMap, VecDeque};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, ensure, Context, Result};

use crate::config::{BackendKind, TrainConfig};
use crate::embedding::Matrix;
use crate::metrics::Counters;
use crate::net::compress::PackedLens;
use crate::net::{self, Cursor, MAX_CONTROL_FRAME, MAX_DATA_FRAME};
use crate::sampling::NegativeSampler;
use crate::util::rng::{streams, Rng};

use super::worker::WorkerCore;
pub use super::worker::{
    Job, JobMsg, JobResult, Reply, ResidentPart, Shipment, SyncReply, Takeover,
};

/// Handshake magic: the first bytes a worker sends.
pub const HELLO_MAGIC: [u8; 4] = *b"GVWK";
/// Assignment magic: the first bytes of a coordinator's assignment body.
pub const ASSIGN_MAGIC: [u8; 4] = *b"GVAS";
/// Bumped on any wire-format change; both ends must match exactly.
/// v2: PING/PONG liveness frames, job takeover (fold) section, post-job
/// RNG state in results, and the rejoin generation counter in ASSIGN.
/// v3: wire-compression negotiation (HELLO capability byte, ASSIGN
/// flag), packed f32 payload sections ([`crate::net::compress`]), and
/// the extended BYE carrying on-wire byte counts per direction.
pub const PROTOCOL_VERSION: u32 = 3;

const MSG_TRAIN: u8 = 1;
const MSG_SYNC: u8 = 2;
const MSG_STOP: u8 = 3;
const MSG_PING: u8 = 4;
const MSG_RESULT: u8 = 17;
const MSG_SYNCED: u8 = 18;
const MSG_ERR: u8 = 19;
const MSG_BYE: u8 = 20;
const MSG_PONG: u8 = 21;

const ASSIGN_OK: u8 = 0;
const ASSIGN_REJECT: u8 = 1;
const READY_OK: u8 = 0;
const READY_ERR: u8 = 1;

/// How long each side waits for the other's handshake frames.
const HANDSHAKE_TIMEOUT: Duration = Duration::from_secs(30);
/// How long the coordinator waits for every BYE at shutdown.
const SHUTDOWN_TIMEOUT: Duration = Duration::from_secs(30);
/// Bad handshakes tolerated (port scanners, stale clients) before the
/// coordinator gives up waiting for a real worker.
const MAX_BAD_HANDSHAKES: usize = 64;

/// What a socket transport learned at shutdown: per-run wire totals,
/// already verified against every worker's BYE ledger.
/// [`super::Trainer`] re-asserts them against the transfer-engine
/// counters (`bytes_to_device` / `bytes_from_device`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TransportReport {
    pub workers: usize,
    /// Shipment payload bytes coordinator → workers (raw f32 bytes, the
    /// transfer-engine unit).
    pub bytes_up: u64,
    /// Result payload bytes workers → coordinator (raw f32 bytes).
    pub bytes_down: u64,
    /// On-wire bytes of the packed payload sections coordinator →
    /// workers. Equals `bytes_up` when compression is off.
    pub wire_up: u64,
    /// On-wire bytes of the packed payload sections workers →
    /// coordinator. Equals `bytes_down` when compression is off.
    pub wire_down: u64,
}

impl TransportReport {
    /// Raw-minus-wire bytes across both directions: what compression
    /// kept off the wire. Zero when `wire_compression` is off.
    pub fn wire_bytes_saved(&self) -> u64 {
        (self.bytes_up - self.wire_up) + (self.bytes_down - self.wire_down)
    }
}

/// Delivery mechanism between the coordinator and its device workers.
/// The episode runner drives exactly this surface, so every coordinator
/// behavior (pipelined dispatch, fences, residency sync, checkpoint) is
/// transport-agnostic.
pub trait Transport: Send {
    fn num_workers(&self) -> usize;
    /// Send one message to worker `worker`. Ordering per worker is
    /// guaranteed (FIFO channel / single TCP stream).
    fn send(&mut self, worker: usize, msg: JobMsg) -> Result<()>;
    /// Blocking receive of the next reply from any worker. Worker-side
    /// job errors surface here as `Err` (pointed, naming the worker).
    fn recv(&mut self) -> Result<Reply>;
    /// Non-blocking receive (the pipelined opportunistic drain).
    fn try_recv(&mut self) -> Result<Option<Reply>>;
    /// Stop all workers. Socket transports collect every worker's BYE
    /// ledger, verify it against their own per-connection counts and
    /// return the totals; the local transport returns `None`.
    fn shutdown(&mut self) -> Result<Option<TransportReport>>;

    // --- worker-failure recovery hooks (no-ops on transports without
    // --- failure detection; the episode runner only consults them when
    // --- `TrainConfig::recovery_enabled()`) ---

    /// Which worker slot this transport last declared dead (recv timeout
    /// naming a silent slot, connection loss, injected kill). `None` on
    /// transports that cannot attribute failures.
    fn failed_worker(&self) -> Option<usize> {
        None
    }

    /// Try to install a replacement worker for `slot` (the rejoin
    /// protocol): poll the still-open listener, handshake the first valid
    /// candidate with a RE-ASSIGN carrying `rng_state` and the slot's
    /// next generation, reject stragglers pointedly. `Ok(true)` = a
    /// replacement is live; `Ok(false)` = nobody dialed in (the caller
    /// backs off and retries, or folds the slot onto survivors).
    fn try_replace(&mut self, _slot: usize, _rng_state: [u64; 4]) -> Result<bool> {
        Ok(false)
    }

    /// Permanently retire `slot` (its journal was folded onto survivors):
    /// no further sends go to it and shutdown skips its ledger.
    fn mark_dead(&mut self, _slot: usize) {}
}

// ---------------------------------------------------------------------
// LocalTransport: the PR 1-6 in-process channels, verbatim.
// ---------------------------------------------------------------------

/// In-process delivery: one mpsc sender per worker thread, one shared
/// result receiver — exactly the channel topology prior PRs pinned
/// bitwise. Spawning the threads stays in [`super::worker::spawn_workers`];
/// this just owns the channel ends.
pub struct LocalTransport {
    job_txs: Vec<mpsc::Sender<JobMsg>>,
    result_rx: mpsc::Receiver<Result<Reply>>,
}

impl LocalTransport {
    pub fn new(
        job_txs: Vec<mpsc::Sender<JobMsg>>,
        result_rx: mpsc::Receiver<Result<Reply>>,
    ) -> Self {
        LocalTransport { job_txs, result_rx }
    }
}

impl Transport for LocalTransport {
    fn num_workers(&self) -> usize {
        self.job_txs.len()
    }

    fn send(&mut self, worker: usize, msg: JobMsg) -> Result<()> {
        self.job_txs[worker]
            .send(msg)
            .map_err(|_| anyhow!("worker {worker} channel closed"))
    }

    fn recv(&mut self) -> Result<Reply> {
        self.result_rx
            .recv()
            .map_err(|_| anyhow!("workers hung up"))?
    }

    fn try_recv(&mut self) -> Result<Option<Reply>> {
        match self.result_rx.try_recv() {
            Ok(reply) => reply.map(Some),
            Err(mpsc::TryRecvError::Empty) => Ok(None),
            Err(mpsc::TryRecvError::Disconnected) => Err(anyhow!("workers hung up")),
        }
    }

    fn shutdown(&mut self) -> Result<Option<TransportReport>> {
        for tx in &self.job_txs {
            // workers that already exited (error path) are fine to miss
            let _ = tx.send(JobMsg::Stop);
        }
        Ok(None)
    }
}

// ---------------------------------------------------------------------
// Wire compression context: the per-connection state behind the packed
// f32 sections of protocol v3.
// ---------------------------------------------------------------------

/// The last full f32 payload each side of one connection has seen for a
/// `(matrix, partition)` key — in *either* direction. Both ends update
/// it at encode/decode time, and frames on one TCP stream arrive in
/// send order, so the two caches stay in lockstep and a shipment can be
/// delta-encoded against "the version the receiver already holds".
/// Every delta section carries a fingerprint of its base, so lockstep
/// is verified, never assumed.
struct WireCache {
    map: HashMap<(u8, u32), Vec<f32>>,
}

/// One connection's compression context, shared by that connection's
/// writer and reader threads (clones share the cache). `compress` is
/// the handshake-negotiated setting: off, every section is stored raw
/// (mode byte + length) and the cache stays empty.
#[derive(Clone)]
pub struct WireCtx {
    compress: bool,
    cache: Arc<Mutex<WireCache>>,
}

impl WireCtx {
    pub fn new(compress: bool) -> Self {
        WireCtx { compress, cache: Arc::new(Mutex::new(WireCache { map: HashMap::new() })) }
    }

    /// Append one packed f32 section for `(matrix, pid)`, delta-encoding
    /// against the cached base when compression is on, then cache `xs`
    /// as the new base (the receiver decodes — and caches — the same
    /// values, keeping both ends in lockstep).
    fn pack(&self, out: &mut Vec<u8>, matrix: u8, pid: usize, xs: &[f32]) -> PackedLens {
        let mut cache = self.cache.lock().expect("wire cache poisoned");
        let base = if self.compress { cache.map.get(&(matrix, pid as u32)) } else { None };
        let lens = net::compress::pack_f32s(out, xs, base.map(Vec::as_slice), self.compress);
        if self.compress {
            cache.map.insert((matrix, pid as u32), xs.to_vec());
        }
        lens
    }

    /// Decode one packed f32 section for `(matrix, pid)` into a fresh
    /// vector, resolving delta sections against the cached base, then
    /// cache the reconstructed values as the new base.
    fn unpack(&self, c: &mut Cursor<'_>, matrix: u8, pid: usize) -> Result<(Vec<f32>, PackedLens)> {
        let mut cache = self.cache.lock().expect("wire cache poisoned");
        let mut out = Vec::new();
        let base = if self.compress { cache.map.get(&(matrix, pid as u32)) } else { None };
        let lens = net::compress::unpack_f32s(c, base.map(Vec::as_slice), &mut out)?;
        if self.compress {
            cache.map.insert((matrix, pid as u32), out.clone());
        }
        Ok((out, lens))
    }
}

// ---------------------------------------------------------------------
// Wire codec. Flat little-endian structs over crate::net frames; every
// decoder bounds-checks before allocating and rejects trailing bytes.
// ---------------------------------------------------------------------

fn put_str(out: &mut Vec<u8>, s: &str) {
    out.extend_from_slice(&(s.len() as u32).to_le_bytes());
    out.extend_from_slice(s.as_bytes());
}

fn get_str(c: &mut Cursor<'_>) -> Result<String> {
    let len = c.u32()? as usize;
    let bytes = c.bytes(len)?;
    Ok(String::from_utf8_lossy(bytes).into_owned())
}

fn put_shipment(
    out: &mut Vec<u8>,
    ship: &Shipment,
    matrix: u8,
    pid: usize,
    ctx: &WireCtx,
) -> PackedLens {
    let mut flags = 0u8;
    if ship.data.is_some() {
        flags |= 1;
    }
    if ship.keep {
        flags |= 2;
    }
    out.push(flags);
    out.extend_from_slice(&ship.src_version.to_le_bytes());
    match &ship.data {
        Some(data) => ctx.pack(out, matrix, pid, data),
        None => PackedLens::default(),
    }
}

fn get_shipment(
    c: &mut Cursor<'_>,
    matrix: u8,
    pid: usize,
    ctx: &WireCtx,
) -> Result<(Shipment, PackedLens)> {
    let flags = c.u8()?;
    ensure!(flags & !3 == 0, "unknown shipment flags {flags:#x}");
    let src_version = c.u64()?;
    let (data, lens) = if flags & 1 != 0 {
        let (buf, lens) = ctx.unpack(c, matrix, pid)?;
        (Some(buf), lens)
    } else {
        (None, PackedLens::default())
    };
    Ok((Shipment { data, src_version, keep: flags & 2 != 0 }, lens))
}

/// Encode one coordinator→worker message. Returns the frame payload and
/// the raw/on-wire byte counts of its packed f32 sections.
pub fn encode_job_msg(msg: &JobMsg, ctx: &WireCtx) -> (Vec<u8>, PackedLens) {
    let mut lens = PackedLens::default();
    let out = match msg {
        JobMsg::Train(job) => {
            let mut out = Vec::with_capacity(64 + job.block.len() * 8);
            out.push(MSG_TRAIN);
            out.extend_from_slice(&(job.vid as u32).to_le_bytes());
            out.extend_from_slice(&(job.cid as u32).to_le_bytes());
            out.extend_from_slice(&job.lr.to_le_bytes());
            out.extend_from_slice(&(job.block.len() as u32).to_le_bytes());
            for &(u, v) in &job.block {
                out.extend_from_slice(&u.to_le_bytes());
                out.extend_from_slice(&v.to_le_bytes());
            }
            lens += put_shipment(&mut out, &job.vertex, matrix_code(Matrix::Vertex), job.vid, ctx);
            lens += put_shipment(&mut out, &job.context, matrix_code(Matrix::Context), job.cid, ctx);
            match &job.takeover {
                None => out.push(0),
                Some(t) => {
                    out.push(1);
                    for w in t.rng {
                        out.extend_from_slice(&w.to_le_bytes());
                    }
                    out.extend_from_slice(&t.chunk_samples.to_le_bytes());
                }
            }
            out
        }
        JobMsg::Sync => vec![MSG_SYNC],
        JobMsg::Ping => vec![MSG_PING],
        JobMsg::Stop => vec![MSG_STOP],
    };
    (out, lens)
}

/// Decode one coordinator→worker message (fail-loud: truncation, unknown
/// tags/flags and trailing garbage are all pointed errors). Returns the
/// raw/on-wire byte counts of the packed f32 sections it consumed.
pub fn decode_job_msg(payload: &[u8], ctx: &WireCtx) -> Result<(JobMsg, PackedLens)> {
    let mut c = Cursor::new(payload);
    let mut lens = PackedLens::default();
    let msg = match c.u8()? {
        MSG_TRAIN => {
            let vid = c.u32()? as usize;
            let cid = c.u32()? as usize;
            let lr = c.f32()?;
            let n = c.u32()? as usize;
            c.expect_remaining(n * 8)?;
            let mut block = Vec::with_capacity(n);
            for _ in 0..n {
                block.push((c.i32()?, c.i32()?));
            }
            let (vertex, vl) = get_shipment(&mut c, matrix_code(Matrix::Vertex), vid, ctx)?;
            lens += vl;
            let (context, cl) = get_shipment(&mut c, matrix_code(Matrix::Context), cid, ctx)?;
            lens += cl;
            let takeover = match c.u8()? {
                0 => None,
                1 => {
                    let mut rng = [0u64; 4];
                    for w in &mut rng {
                        *w = c.u64()?;
                    }
                    Some(Takeover { rng, chunk_samples: c.u32()? })
                }
                f => bail!("unknown takeover flag {f}"),
            };
            JobMsg::Train(Job { vid, cid, block, vertex, context, lr, takeover })
        }
        MSG_SYNC => JobMsg::Sync,
        MSG_PING => JobMsg::Ping,
        MSG_STOP => JobMsg::Stop,
        tag => bail!("unknown job-message tag {tag}"),
    };
    c.finish()?;
    Ok((msg, lens))
}

/// Everything a worker sends up its stream. [`Reply`] is what the local
/// channel carries; ERR mirrors the local path's `Result<Reply>` errors;
/// BYE is the shutdown ledger answering STOP.
#[derive(Debug, Clone)]
pub enum WireReply {
    Reply(Reply),
    Err(String),
    Bye { received: u64, sent: u64, wire_received: u64, wire_sent: u64 },
}

/// Encode one worker→coordinator message. `JobResult::block` does not
/// cross the wire (the block is spent; only its allocation matters, and
/// each side recycles its own). Returns the frame payload and the
/// raw/on-wire byte counts of its packed f32 sections.
pub fn encode_wire_reply(reply: &WireReply, ctx: &WireCtx) -> (Vec<u8>, PackedLens) {
    let mut lens = PackedLens::default();
    let out = match reply {
        WireReply::Reply(Reply::Job(r)) => {
            let mut out = Vec::with_capacity(64);
            out.push(MSG_RESULT);
            out.extend_from_slice(&(r.vid as u32).to_le_bytes());
            out.extend_from_slice(&(r.cid as u32).to_le_bytes());
            out.extend_from_slice(&r.loss.to_le_bytes());
            out.extend_from_slice(&r.trained.to_le_bytes());
            for w in r.rng_state {
                out.extend_from_slice(&w.to_le_bytes());
            }
            for (opt, matrix, pid) in [
                (&r.vertex, matrix_code(Matrix::Vertex), r.vid),
                (&r.context, matrix_code(Matrix::Context), r.cid),
            ] {
                match opt {
                    Some(data) => {
                        out.push(1);
                        lens += ctx.pack(&mut out, matrix, pid, data);
                    }
                    None => out.push(0),
                }
            }
            out
        }
        WireReply::Reply(Reply::Synced(s)) => {
            let mut out = Vec::with_capacity(64);
            out.push(MSG_SYNCED);
            out.extend_from_slice(&(s.worker as u32).to_le_bytes());
            for w in s.rng_state {
                out.extend_from_slice(&w.to_le_bytes());
            }
            out.extend_from_slice(&(s.residents.len() as u32).to_le_bytes());
            for part in &s.residents {
                out.push(matrix_code(part.matrix));
                out.extend_from_slice(&(part.pid as u32).to_le_bytes());
                out.extend_from_slice(&part.version.to_le_bytes());
                lens += ctx.pack(&mut out, matrix_code(part.matrix), part.pid, &part.data);
            }
            out
        }
        WireReply::Err(msg) => {
            let mut out = vec![MSG_ERR];
            put_str(&mut out, msg);
            out
        }
        WireReply::Reply(Reply::Pong) => vec![MSG_PONG],
        WireReply::Bye { received, sent, wire_received, wire_sent } => {
            let mut out = vec![MSG_BYE];
            out.extend_from_slice(&received.to_le_bytes());
            out.extend_from_slice(&sent.to_le_bytes());
            out.extend_from_slice(&wire_received.to_le_bytes());
            out.extend_from_slice(&wire_sent.to_le_bytes());
            out
        }
    };
    (out, lens)
}

/// Decode one worker→coordinator message. Returns the raw/on-wire byte
/// counts of the packed f32 sections it consumed.
pub fn decode_wire_reply(payload: &[u8], ctx: &WireCtx) -> Result<(WireReply, PackedLens)> {
    let mut c = Cursor::new(payload);
    let mut lens = PackedLens::default();
    let reply = match c.u8()? {
        MSG_RESULT => {
            let vid = c.u32()? as usize;
            let cid = c.u32()? as usize;
            let loss = c.f32()?;
            let trained = c.u64()?;
            let mut rng_state = [0u64; 4];
            for w in &mut rng_state {
                *w = c.u64()?;
            }
            let mut opts = [None, None];
            for (opt, (matrix, pid)) in opts
                .iter_mut()
                .zip([(matrix_code(Matrix::Vertex), vid), (matrix_code(Matrix::Context), cid)])
            {
                match c.u8()? {
                    0 => {}
                    1 => {
                        let (buf, l) = ctx.unpack(&mut c, matrix, pid)?;
                        lens += l;
                        *opt = Some(buf);
                    }
                    f => bail!("unknown result-section flag {f}"),
                }
            }
            let [vertex, context] = opts;
            WireReply::Reply(Reply::Job(JobResult {
                worker: 0, // not a wire field; the reader thread stamps it
                vid,
                cid,
                vertex,
                context,
                block: Vec::new(),
                loss,
                trained,
                rng_state,
            }))
        }
        MSG_SYNCED => {
            let worker = c.u32()? as usize;
            let mut rng_state = [0u64; 4];
            for w in &mut rng_state {
                *w = c.u64()?;
            }
            let count = c.u32()? as usize;
            let mut residents = Vec::with_capacity(count.min(1024));
            for _ in 0..count {
                let matrix = matrix_from_code(c.u8()?)?;
                let pid = c.u32()? as usize;
                let version = c.u64()?;
                let (data, l) = ctx.unpack(&mut c, matrix_code(matrix), pid)?;
                lens += l;
                residents.push(ResidentPart { matrix, pid, version, data });
            }
            WireReply::Reply(Reply::Synced(SyncReply { worker, rng_state, residents }))
        }
        MSG_ERR => WireReply::Err(get_str(&mut c)?),
        MSG_PONG => WireReply::Reply(Reply::Pong),
        MSG_BYE => WireReply::Bye {
            received: c.u64()?,
            sent: c.u64()?,
            wire_received: c.u64()?,
            wire_sent: c.u64()?,
        },
        tag => bail!("unknown reply tag {tag}"),
    };
    c.finish()?;
    Ok((reply, lens))
}

fn matrix_code(m: Matrix) -> u8 {
    match m {
        Matrix::Vertex => 0,
        Matrix::Context => 1,
    }
}

fn matrix_from_code(code: u8) -> Result<Matrix> {
    match code {
        0 => Ok(Matrix::Vertex),
        1 => Ok(Matrix::Context),
        c => bail!("unknown matrix code {c}"),
    }
}

/// Shipment payload f32 bytes of a job — the "down" ledger unit, counted
/// identically by [`super::EpisodeRunner`]'s gather (`bytes_to_device`),
/// the sender, and the receiving worker.
pub fn job_payload_bytes(job: &Job) -> u64 {
    let v = job.vertex.data.as_ref().map_or(0, Vec::len);
    let c = job.context.data.as_ref().map_or(0, Vec::len);
    ((v + c) * 4) as u64
}

/// Result payload f32 bytes of a reply — the "up" ledger unit, counted
/// identically by the worker, the reader thread, and the coordinator's
/// absorb/sync scatters (`bytes_from_device`).
pub fn reply_payload_bytes(reply: &Reply) -> u64 {
    match reply {
        Reply::Job(r) => {
            let v = r.vertex.as_ref().map_or(0, Vec::len);
            let c = r.context.as_ref().map_or(0, Vec::len);
            ((v + c) * 4) as u64
        }
        Reply::Synced(s) => {
            (s.residents.iter().map(|p| p.data.len()).sum::<usize>() * 4) as u64
        }
        Reply::Pong => 0,
    }
}

// ---------------------------------------------------------------------
// Handshake messages.
// ---------------------------------------------------------------------

/// The worker's first frame: magic + protocol version + a capability
/// byte advertising wire-compression support (always on for this
/// build; [`encode_hello_with`] exists for tests of the negotiation).
pub fn encode_hello() -> Vec<u8> {
    encode_hello_with(true)
}

/// [`encode_hello`] with an explicit wire-compression capability.
pub fn encode_hello_with(compression: bool) -> Vec<u8> {
    let mut out = Vec::with_capacity(9);
    out.extend_from_slice(&HELLO_MAGIC);
    out.extend_from_slice(&PROTOCOL_VERSION.to_le_bytes());
    out.push(compression as u8);
    out
}

/// Validate a HELLO field by field (the `validate_resume` discipline:
/// each mismatch is a distinct pointed error naming both sides).
/// Returns whether the worker supports wire compression.
pub fn decode_hello(payload: &[u8]) -> Result<bool> {
    let mut c = Cursor::new(payload);
    let magic = c.bytes(4)?;
    ensure!(
        magic == HELLO_MAGIC,
        "bad handshake magic {magic:02x?} (expected {HELLO_MAGIC:02x?} / \"GVWK\") — \
         the peer is not a graphvite worker"
    );
    let version = c.u32()?;
    ensure!(
        version == PROTOCOL_VERSION,
        "worker speaks transport protocol v{version}, this coordinator speaks \
         v{PROTOCOL_VERSION} — mismatched graphvite builds"
    );
    let compression = match c.u8()? {
        0 => false,
        1 => true,
        f => bail!("unknown hello compression capability {f}"),
    };
    c.finish()?;
    Ok(compression)
}

/// Everything one remote worker needs to be bitwise-equivalent to an
/// in-process worker thread: the run fingerprint, its capacity-scaled
/// hyperparameters, its exact RNG stream state and the per-partition
/// negative-sampling weights (a remote worker has no graph to derive
/// them from).
#[derive(Debug, Clone)]
pub struct WorkerAssignment {
    pub worker_index: usize,
    pub num_workers: usize,
    pub partitions: usize,
    pub dim: usize,
    /// Base batch size; the worker multiplies by `capacity` (the same
    /// capacity-aware chunk sizing `spawn_workers` applies in-process).
    pub batch_size: usize,
    pub negatives: usize,
    pub capacity: usize,
    /// Residency-cache bound (`None` = unbounded, the homogeneous
    /// default). Wire sentinel: `u64::MAX`.
    pub cache_limit: Option<usize>,
    pub seed: u64,
    pub neg_weight: f32,
    pub backend: BackendKind,
    pub rng_state: [u64; 4],
    /// Rejoin generation of this slot: 0 for the run's original workers;
    /// a replacement accepted after a worker death gets the slot's next
    /// generation (RE-ASSIGN), so both ends can tell a fresh start from a
    /// mid-run rejoin and stale peers get a pointed reject.
    pub generation: u64,
    /// The negotiated wire-compression setting
    /// ([`TrainConfig::wire_compression`]): when true, every f32 payload
    /// section on this connection is a [`crate::net::compress`] packed
    /// section and both ends keep their wire caches in lockstep.
    pub wire_compression: bool,
    /// Per-partition deg^0.75 weights, bit-exact
    /// ([`NegativeSampler::partition_weights`]).
    pub neg_weights: Vec<Vec<f32>>,
}

/// Encode the coordinator's assignment (the OK arm of the ASSIGN slot).
pub fn encode_assign(a: &WorkerAssignment) -> Vec<u8> {
    let weight_bytes: usize = a.neg_weights.iter().map(|w| 4 + w.len() * 4).sum();
    let mut out = Vec::with_capacity(96 + weight_bytes);
    out.push(ASSIGN_OK);
    out.extend_from_slice(&ASSIGN_MAGIC);
    out.extend_from_slice(&PROTOCOL_VERSION.to_le_bytes());
    out.extend_from_slice(&(a.worker_index as u32).to_le_bytes());
    out.extend_from_slice(&(a.num_workers as u32).to_le_bytes());
    out.extend_from_slice(&(a.partitions as u32).to_le_bytes());
    out.extend_from_slice(&(a.dim as u32).to_le_bytes());
    out.extend_from_slice(&(a.batch_size as u32).to_le_bytes());
    out.extend_from_slice(&(a.negatives as u32).to_le_bytes());
    out.extend_from_slice(&(a.capacity as u32).to_le_bytes());
    out.extend_from_slice(&a.cache_limit.map_or(u64::MAX, |l| l as u64).to_le_bytes());
    out.extend_from_slice(&a.seed.to_le_bytes());
    out.extend_from_slice(&a.neg_weight.to_le_bytes());
    put_str(&mut out, a.backend.name());
    for w in a.rng_state {
        out.extend_from_slice(&w.to_le_bytes());
    }
    out.extend_from_slice(&a.generation.to_le_bytes());
    out.push(a.wire_compression as u8);
    for weights in &a.neg_weights {
        net::put_f32s(&mut out, weights);
    }
    out
}

/// The coordinator's answer to an invalid HELLO (the reject arm of the
/// ASSIGN slot) — so a mismatched worker gets a pointed message instead
/// of a dropped connection.
pub fn encode_reject(msg: &str) -> Vec<u8> {
    let mut out = vec![ASSIGN_REJECT];
    put_str(&mut out, msg);
    out
}

/// Decode and validate an assignment field by field, mirroring
/// `validate_resume`: every bad field is a distinct pointed error naming
/// both sides, so a fingerprint mismatch can never silently train.
pub fn decode_assign(payload: &[u8]) -> Result<WorkerAssignment> {
    let mut c = Cursor::new(payload);
    match c.u8()? {
        ASSIGN_OK => {}
        ASSIGN_REJECT => bail!("coordinator rejected this worker: {}", get_str(&mut c)?),
        tag => bail!("unknown assignment frame tag {tag}"),
    }
    let magic = c.bytes(4)?;
    ensure!(
        magic == ASSIGN_MAGIC,
        "bad assignment magic {magic:02x?} (expected {ASSIGN_MAGIC:02x?} / \"GVAS\") — \
         is the remote end a graphvite coordinator?"
    );
    let version = c.u32()?;
    ensure!(
        version == PROTOCOL_VERSION,
        "coordinator speaks transport protocol v{version}, this worker speaks \
         v{PROTOCOL_VERSION} — mismatched graphvite builds"
    );
    let worker_index = c.u32()? as usize;
    let num_workers = c.u32()? as usize;
    ensure!(num_workers >= 1, "assignment declares zero workers");
    ensure!(
        worker_index < num_workers,
        "assigned worker index {worker_index} out of range for {num_workers} workers"
    );
    let partitions = c.u32()? as usize;
    ensure!(partitions >= 1, "assignment declares zero partitions");
    let dim = c.u32()? as usize;
    ensure!(dim >= 1, "assignment declares dim 0");
    let batch_size = c.u32()? as usize;
    ensure!(batch_size >= 1, "assignment declares batch size 0");
    let negatives = c.u32()? as usize;
    ensure!(negatives >= 1, "assignment declares zero negatives per positive");
    let capacity = c.u32()? as usize;
    ensure!(capacity >= 1, "assignment declares capacity 0 for this worker");
    let cache_limit = match c.u64()? {
        u64::MAX => None,
        l => Some(l as usize),
    };
    let seed = c.u64()?;
    let neg_weight = c.f32()?;
    ensure!(neg_weight.is_finite(), "assignment negative weight {neg_weight} is not finite");
    let backend_name = get_str(&mut c)?;
    let backend = BackendKind::parse(&backend_name)
        .ok_or_else(|| anyhow!("assignment names unknown backend '{backend_name}'"))?;
    ensure!(
        backend != BackendKind::Pjrt,
        "remote workers cannot run the pjrt backend (HLO artifacts are host-local); \
         use native or simd for tcp worker mode"
    );
    let mut rng_state = [0u64; 4];
    for w in &mut rng_state {
        *w = c.u64()?;
    }
    ensure!(rng_state != [0u64; 4], "assignment carries an all-zero rng state");
    let generation = c.u64()?;
    let wire_compression = match c.u8()? {
        0 => false,
        1 => true,
        f => bail!("unknown assignment wire-compression flag {f}"),
    };
    let mut neg_weights = Vec::with_capacity(partitions);
    for _ in 0..partitions {
        let mut w = Vec::new();
        net::get_f32s(&mut c, &mut w)?;
        neg_weights.push(w);
    }
    c.finish()?;
    Ok(WorkerAssignment {
        worker_index,
        num_workers,
        partitions,
        dim,
        batch_size,
        negatives,
        capacity,
        cache_limit,
        seed,
        neg_weight,
        backend,
        rng_state,
        generation,
        wire_compression,
        neg_weights,
    })
}

/// The worker's post-construction acknowledgement: OK, or a pointed
/// rejection message (backend unavailable, invalid rng state, …).
pub fn encode_ready(err: Option<&str>) -> Vec<u8> {
    match err {
        None => vec![READY_OK],
        Some(msg) => {
            let mut out = vec![READY_ERR];
            put_str(&mut out, msg);
            out
        }
    }
}

/// Decode a READY frame: `None` = worker is ready, `Some(msg)` = the
/// worker rejected its assignment with that message.
pub fn decode_ready(payload: &[u8]) -> Result<Option<String>> {
    let mut c = Cursor::new(payload);
    let out = match c.u8()? {
        READY_OK => None,
        READY_ERR => Some(get_str(&mut c)?),
        tag => bail!("unknown ready tag {tag}"),
    };
    c.finish()?;
    Ok(out)
}

/// Build the per-worker assignments for a tcp run — the socket analogue
/// of [`super::worker::spawn_workers`]'s per-thread setup: identical
/// capacity scaling, identical cache limits, identical RNG stream
/// derivation (`streams::WORKER`), so worker `i` behind a socket is
/// bitwise the worker `i` thread.
pub fn make_assignments(
    cfg: &TrainConfig,
    partitions: usize,
    neg_weights: &[Vec<f32>],
    base_rng: &Rng,
    resume_rngs: Option<&[[u64; 4]]>,
) -> Result<Vec<WorkerAssignment>> {
    if let Some(states) = resume_rngs {
        ensure!(
            states.len() == cfg.num_workers,
            "checkpoint has {} worker rng states but the config declares {} workers",
            states.len(),
            cfg.num_workers
        );
    }
    let cache_limits = cfg.residency_limits();
    Ok((0..cfg.num_workers)
        .map(|i| WorkerAssignment {
            worker_index: i,
            num_workers: cfg.num_workers,
            partitions,
            dim: cfg.dim,
            batch_size: cfg.batch_size,
            negatives: cfg.negatives,
            capacity: cfg.worker_capacity(i),
            cache_limit: cache_limits.as_ref().map(|l| l[i]),
            seed: cfg.seed,
            neg_weight: cfg.neg_weight,
            backend: cfg.backend,
            rng_state: match resume_rngs {
                Some(states) => states[i],
                None => base_rng.stream(streams::WORKER, i as u64).state(),
            },
            generation: 0,
            wire_compression: cfg.wire_compression,
            neg_weights: neg_weights.to_vec(),
        })
        .collect())
}

// ---------------------------------------------------------------------
// SocketTransport: the coordinator side of the TCP protocol.
// ---------------------------------------------------------------------

/// One event off a reader thread. `gen` is the slot generation the
/// reader was spawned under; events from a replaced or retired reader
/// are stale and silently dropped by the receive loops, so a dying
/// connection can never be confused with its replacement.
struct SocketEvent {
    worker: usize,
    gen: u64,
    kind: SocketEventKind,
}

enum SocketEventKind {
    /// A decoded reply plus the on-wire bytes of its packed sections
    /// (carried so stale-dropped replies can be backed out of the wire
    /// ledger as well as the raw one).
    Reply(Reply, u64),
    WorkerErr(String),
    Bye { received: u64, sent: u64, wire_received: u64, wire_sent: u64 },
    Eof,
    ReadErr(String),
    /// The slot's writer thread failed to put a frame on the wire — the
    /// sending mirror of `ReadErr`.
    WriteErr(String),
}

/// Depth of each slot's bounded send queue. Deep enough to overlap
/// serialization/compression/writes with worker compute, shallow enough
/// that a stalled connection exerts backpressure on dispatch instead of
/// buffering a whole episode.
const WRITER_QUEUE_DEPTH: usize = 4;

/// A slot's dedicated writer thread — the sending mirror of its reader.
/// Dropping `tx` after queueing STOP and joining `join` is the flush
/// barrier: the loop drains every queued frame before exiting, so no
/// frame can be lost behind a STOP.
struct SlotWriter {
    tx: mpsc::SyncSender<JobMsg>,
    join: JoinHandle<()>,
}

/// TCP delivery: one stream per connected `graphvite worker`, a reader
/// thread per stream feeding one shared event channel (mirroring the
/// local transport's shared result channel), a writer thread per stream
/// draining a bounded send queue (so serialization, compression and
/// socket writes overlap dispatch), and a per-connection byte ledger —
/// raw and on-wire, both directions — verified against each worker's
/// BYE at shutdown.
pub struct SocketTransport {
    /// Kept open after the run starts when recovery is enabled, so a
    /// replacement `graphvite worker --connect` can rejoin a dead slot.
    listener: Option<TcpListener>,
    /// Per-slot assignment templates, reused (with a fresh RNG state and
    /// bumped generation) as the RE-ASSIGN for replacements.
    assignments: Vec<WorkerAssignment>,
    streams: Vec<TcpStream>,
    rx: mpsc::Receiver<SocketEvent>,
    tx: mpsc::Sender<SocketEvent>,
    readers: Vec<JoinHandle<()>>,
    /// Per-slot writer threads; `None` once a slot is folded or its
    /// writer has been retired mid-replacement.
    writers: Vec<Option<SlotWriter>>,
    /// Join handles of writers retired by `try_replace`/`mark_dead`;
    /// their streams are shut down so they exit promptly, and shutdown
    /// joins them before summing wire counters.
    retired_writers: Vec<JoinHandle<()>>,
    /// Shipment payload bytes sent per worker (main thread, counted at
    /// enqueue — the transfer-engine unit), current generation only.
    up_bytes: Vec<u64>,
    /// Result payload bytes received per worker (reader threads),
    /// current generation only.
    down_bytes: Vec<Arc<AtomicU64>>,
    /// On-wire bytes of packed sections written per worker (writer
    /// threads), current generation only.
    wire_up: Vec<Arc<AtomicU64>>,
    /// On-wire bytes of packed sections received per worker (reader
    /// threads), current generation only.
    wire_down: Vec<Arc<AtomicU64>>,
    /// Up-bytes of replaced/dead generations, retired out of the
    /// per-slot BYE asserts but still part of the run totals.
    retired_up: u64,
    /// Down-byte counters of retired readers (their threads may still be
    /// counting a final frame when retired, so the Arcs are summed at
    /// shutdown rather than snapshotted at replacement).
    retired_down: Vec<Arc<AtomicU64>>,
    /// Wire-byte counters of retired writers/readers, summed at
    /// shutdown for the same reason as `retired_down`.
    retired_wire_up: Vec<Arc<AtomicU64>>,
    retired_wire_down: Vec<Arc<AtomicU64>>,
    /// Result payload bytes of stale-dropped replies: counted by a
    /// reader at receive time but never scattered (their generation was
    /// retired or folded before the coordinator drained them), so they
    /// must be backed out of the run total to keep it equal to the
    /// transfer-engine ledger.
    stale_down: u64,
    /// On-wire bytes of stale-dropped replies, backed out of the wire
    /// total alongside `stale_down`.
    stale_wire_down: u64,
    /// Per-slot rejoin generation; reader events from older generations
    /// are stale and dropped.
    generation: Vec<u64>,
    /// Slots folded onto survivors: no sends, no BYE expected.
    dead: Vec<bool>,
    /// Last slot this transport declared dead ([`Transport::failed_worker`]).
    failed: Option<usize>,
    /// (vid, cid) of jobs sent but not yet answered, per slot — named in
    /// the recv-timeout error so "a worker is stalled" points at *which*.
    outstanding: Vec<Vec<(usize, usize)>>,
    /// Millis since `epoch` each worker was last heard from (any frame,
    /// including PONG); updated by reader threads.
    last_heard: Vec<Arc<AtomicU64>>,
    epoch: Instant,
    /// PING cadence while blocked in recv; `None` disables liveness
    /// probes (the pre-recovery behavior).
    heartbeat: Option<Duration>,
    /// Each live worker's BYE ledger: (received, sent, wire_received,
    /// wire_sent) as the worker counted them.
    byes: Vec<Option<(u64, u64, u64, u64)>>,
    /// `None` = block forever (local-mode semantics; TCP EOF still
    /// fails loud). `TrainConfig::worker_timeout_secs` sets it.
    recv_timeout: Option<Duration>,
}

impl SocketTransport {
    /// Accept and handshake `assignments.len()` workers on `listener`
    /// (arrival order assigns indices — any process can be any worker,
    /// the assignment carries that worker's complete state). Invalid
    /// peers get a reject frame and are dropped without disturbing the
    /// slot; the run only starts once every worker acknowledged READY.
    ///
    /// `heartbeat` enables PING probes while blocked in recv;
    /// `keep_listener` holds the listening socket open for the rejoin
    /// protocol (both wired from the recovery config keys).
    pub fn accept(
        listener: TcpListener,
        assignments: Vec<WorkerAssignment>,
        recv_timeout: Option<Duration>,
        heartbeat: Option<Duration>,
        keep_listener: bool,
    ) -> Result<Self> {
        let n = assignments.len();
        ensure!(n >= 1, "socket transport needs at least one worker");
        let addr = listener.local_addr().context("listener address")?;
        eprintln!("transport: listening on {addr}, waiting for {n} workers");
        let mut streams = Vec::with_capacity(n);
        let mut bad = 0usize;
        for (i, assign) in assignments.iter().enumerate() {
            loop {
                let (mut stream, peer) =
                    listener.accept().context("accepting worker connection")?;
                match handshake_worker(&mut stream, assign) {
                    Ok(()) => {
                        eprintln!("transport: worker {i} connected from {peer} (ready)");
                        streams.push(stream);
                        break;
                    }
                    Err(e) => {
                        eprintln!("transport: rejected connection from {peer}: {e:#}");
                        bad += 1;
                        ensure!(
                            bad <= MAX_BAD_HANDSHAKES,
                            "rejected {bad} handshakes while waiting for worker {i} — \
                             giving up (last: {e:#})"
                        );
                    }
                }
            }
        }
        eprintln!("transport: {n} workers connected, handshake complete");

        let listener = if keep_listener {
            listener
                .set_nonblocking(true)
                .context("keeping rejoin listener open (set_nonblocking)")?;
            Some(listener)
        } else {
            None
        };

        let epoch = Instant::now();
        let (tx, rx) = mpsc::channel();
        let mut readers = Vec::with_capacity(n);
        let mut down_bytes = Vec::with_capacity(n);
        let mut last_heard = Vec::with_capacity(n);
        let mut writers = Vec::with_capacity(n);
        let mut wire_up = Vec::with_capacity(n);
        let mut wire_down = Vec::with_capacity(n);
        for (i, stream) in streams.iter().enumerate() {
            // one compression context per connection, shared by its
            // writer (pack down) and reader (unpack up) — the two
            // directions keep a single cache in lockstep with the worker
            let ctx = WireCtx::new(assignments[i].wire_compression);
            let read_half = stream.try_clone().context("cloning worker stream")?;
            let reader_tx = tx.clone();
            let counter = Arc::new(AtomicU64::new(0));
            down_bytes.push(Arc::clone(&counter));
            let wire_rx_counter = Arc::new(AtomicU64::new(0));
            wire_down.push(Arc::clone(&wire_rx_counter));
            let heard = Arc::new(AtomicU64::new(0));
            last_heard.push(Arc::clone(&heard));
            let reader_ctx = ctx.clone();
            readers.push(
                std::thread::Builder::new()
                    .name(format!("transport-rx-{i}"))
                    .spawn(move || {
                        reader_loop(
                            i,
                            0,
                            read_half,
                            reader_tx,
                            reader_ctx,
                            counter,
                            wire_rx_counter,
                            heard,
                            epoch,
                        )
                    })
                    .context("spawning transport reader")?,
            );
            let write_half = stream.try_clone().context("cloning worker stream")?;
            let writer_tx = tx.clone();
            let wire_tx_counter = Arc::new(AtomicU64::new(0));
            wire_up.push(Arc::clone(&wire_tx_counter));
            let (job_tx, job_rx) = mpsc::sync_channel(WRITER_QUEUE_DEPTH);
            let join = std::thread::Builder::new()
                .name(format!("transport-tx-{i}"))
                .spawn(move || {
                    writer_loop(i, 0, write_half, job_rx, ctx, wire_tx_counter, writer_tx)
                })
                .context("spawning transport writer")?;
            writers.push(Some(SlotWriter { tx: job_tx, join }));
        }
        Ok(SocketTransport {
            listener,
            assignments,
            streams,
            rx,
            tx,
            readers,
            writers,
            retired_writers: Vec::new(),
            up_bytes: vec![0; n],
            down_bytes,
            wire_up,
            wire_down,
            retired_up: 0,
            retired_down: Vec::new(),
            retired_wire_up: Vec::new(),
            retired_wire_down: Vec::new(),
            stale_down: 0,
            stale_wire_down: 0,
            generation: vec![0; n],
            dead: vec![false; n],
            failed: None,
            outstanding: vec![Vec::new(); n],
            last_heard,
            epoch,
            heartbeat,
            byes: vec![None; n],
            recv_timeout,
        })
    }

    /// Events from replaced or folded generations must not be confused
    /// with the live slot (a dying connection's EOF arriving after its
    /// replacement handshook, a folded worker's stale reply).
    fn stale(&self, ev: &SocketEvent) -> bool {
        self.dead[ev.worker] || ev.gen != self.generation[ev.worker]
    }

    /// Drop a stale event, backing its reply payload (already counted by
    /// its reader thread) out of the down ledger — the coordinator never
    /// scatters it, so the transfer engine never counts it.
    fn drop_stale(&mut self, ev: SocketEvent) {
        if let SocketEventKind::Reply(ref reply, wire) = ev.kind {
            self.stale_down += reply_payload_bytes(reply);
            self.stale_wire_down += wire;
        }
    }

    fn map_event(&mut self, ev: SocketEvent) -> Result<Reply> {
        let i = ev.worker;
        match ev.kind {
            SocketEventKind::Reply(mut reply, _wire) => {
                if let Reply::Job(ref mut r) = reply {
                    self.outstanding[i].retain(|&(v, c)| (v, c) != (r.vid, r.cid));
                }
                Ok(reply)
            }
            SocketEventKind::WorkerErr(msg) => bail!("worker {i}: {msg}"),
            SocketEventKind::Bye { .. } => {
                bail!("worker {i} sent its shutdown ledger mid-run")
            }
            SocketEventKind::Eof => {
                self.failed = Some(i);
                bail!(
                    "worker {i} disconnected mid-run (connection closed without a \
                     shutdown ledger)"
                )
            }
            SocketEventKind::ReadErr(msg) => {
                self.failed = Some(i);
                bail!("worker {i} connection failed: {msg}")
            }
            SocketEventKind::WriteErr(msg) => {
                self.failed = Some(i);
                bail!("worker {i} connection failed while sending: {msg}")
            }
        }
    }

    /// Queue a liveness PING to every live worker's writer. A slot whose
    /// writer has exited (its queue hung up) is declared dead; a *full*
    /// queue is skipped — frames are moving, which is liveness enough.
    fn send_pings(&mut self) -> Result<()> {
        for i in 0..self.writers.len() {
            if self.dead[i] {
                continue;
            }
            let hung_up = match &self.writers[i] {
                Some(w) => {
                    matches!(w.tx.try_send(JobMsg::Ping), Err(mpsc::TrySendError::Disconnected(_)))
                }
                None => false, // mid-replacement; recv will surface its state
            };
            if hung_up {
                self.failed = Some(i);
                bail!("worker {i} connection failed while sending a liveness ping");
            }
        }
        Ok(())
    }

    /// Build the recv-timeout error: name the slot that has been silent
    /// longest and list its outstanding job ids, so "a worker is
    /// stalled" points at *which* worker and *what* it owes.
    fn timeout_error(&mut self, t: Duration) -> anyhow::Error {
        let now_ms = self.epoch.elapsed().as_millis() as u64;
        let quietest = |with_jobs: bool| {
            (0..self.streams.len())
                .filter(|&i| !self.dead[i])
                .filter(|&i| !with_jobs || !self.outstanding[i].is_empty())
                .min_by_key(|&i| self.last_heard[i].load(Ordering::Relaxed))
        };
        // prefer a slot that actually owes results; fall back to the
        // longest-silent live slot
        let suspect = quietest(true).or_else(|| quietest(false));
        match suspect {
            Some(i) => {
                self.failed = Some(i);
                let heard = self.last_heard[i].load(Ordering::Relaxed);
                let age = Duration::from_millis(now_ms.saturating_sub(heard));
                anyhow!(
                    "no worker result within {t:?} (worker_timeout_secs) — worker {i} \
                     went silent (last heard {age:?} ago) with {} outstanding job(s) \
                     {:?}",
                    self.outstanding[i].len(),
                    self.outstanding[i]
                )
            }
            None => anyhow!(
                "no worker result within {t:?} (worker_timeout_secs) — a worker is \
                 stalled or a message was lost"
            ),
        }
    }
}

#[allow(clippy::too_many_arguments)] // one call site, mirrors the slot state
fn reader_loop(
    worker: usize,
    gen: u64,
    mut stream: TcpStream,
    tx: mpsc::Sender<SocketEvent>,
    ctx: WireCtx,
    bytes: Arc<AtomicU64>,
    wire_bytes: Arc<AtomicU64>,
    heard: Arc<AtomicU64>,
    epoch: Instant,
) {
    let event = |kind| SocketEvent { worker, gen, kind };
    loop {
        let ev = match net::read_frame(&mut stream, MAX_DATA_FRAME) {
            Ok(Some(payload)) => {
                heard.store(epoch.elapsed().as_millis() as u64, Ordering::Relaxed);
                match decode_wire_reply(&payload, &ctx) {
                    Ok((WireReply::Reply(Reply::Pong), _)) => continue, // liveness only
                    Ok((WireReply::Reply(mut r), lens)) => {
                        // stamp identity from the connection, not the wire
                        if let Reply::Job(ref mut job) = r {
                            job.worker = worker;
                        }
                        bytes.fetch_add(reply_payload_bytes(&r), Ordering::Relaxed);
                        wire_bytes.fetch_add(lens.wire, Ordering::Relaxed);
                        event(SocketEventKind::Reply(r, lens.wire))
                    }
                    Ok((WireReply::Err(msg), _)) => event(SocketEventKind::WorkerErr(msg)),
                    Ok((WireReply::Bye { received, sent, wire_received, wire_sent }, _)) => {
                        let _ = tx.send(event(SocketEventKind::Bye {
                            received,
                            sent,
                            wire_received,
                            wire_sent,
                        }));
                        return;
                    }
                    Err(e) => {
                        let _ = tx.send(event(SocketEventKind::ReadErr(format!("{e:#}"))));
                        return;
                    }
                }
            }
            Ok(None) => {
                let _ = tx.send(event(SocketEventKind::Eof));
                return;
            }
            Err(e) => {
                let _ = tx.send(event(SocketEventKind::ReadErr(format!("{e:#}"))));
                return;
            }
        };
        if tx.send(ev).is_err() {
            return; // transport dropped
        }
    }
}

/// A slot's dedicated writer thread: drains the bounded send queue,
/// serializing (and compressing) each message and putting it on the
/// wire — off the dispatch thread, so shipments overlap worker compute.
/// Queue order is send order, preserving per-worker FIFO. Exits when
/// the queue hangs up (every queued frame written — the flush
/// guarantee) or on the first write error (surfaced as `WriteErr`;
/// senders then see a hung-up queue).
fn writer_loop(
    worker: usize,
    gen: u64,
    mut stream: TcpStream,
    rx: mpsc::Receiver<JobMsg>,
    ctx: WireCtx,
    wire_bytes: Arc<AtomicU64>,
    tx: mpsc::Sender<SocketEvent>,
) {
    while let Ok(msg) = rx.recv() {
        let (payload, lens) = encode_job_msg(&msg, &ctx);
        wire_bytes.fetch_add(lens.wire, Ordering::Relaxed);
        if let Err(e) = net::write_frame(&mut stream, &payload, MAX_DATA_FRAME) {
            let _ = tx.send(SocketEvent {
                worker,
                gen,
                kind: SocketEventKind::WriteErr(format!("{e:#}")),
            });
            return;
        }
    }
}

/// Coordinator side of one worker handshake. Pointed errors at every
/// step; an invalid HELLO additionally gets a reject frame so the peer
/// learns why.
fn handshake_worker(stream: &mut TcpStream, assign: &WorkerAssignment) -> Result<()> {
    let _ = stream.set_nodelay(true);
    stream
        .set_read_timeout(Some(HANDSHAKE_TIMEOUT))
        .context("setting handshake timeout")?;
    let hello = net::read_frame(stream, MAX_CONTROL_FRAME)
        .context("reading worker hello")?
        .ok_or_else(|| anyhow!("peer closed before sending a hello"))?;
    let supports_compression = match decode_hello(&hello) {
        Ok(s) => s,
        Err(e) => {
            let _ =
                net::write_frame(stream, &encode_reject(&format!("{e:#}")), MAX_CONTROL_FRAME);
            return Err(e);
        }
    };
    if assign.wire_compression && !supports_compression {
        let msg = format!(
            "this run requires wire compression (wire_compression = true) but worker {} \
             does not support it — upgrade the worker or start the coordinator with \
             --no-wire-compression",
            assign.worker_index
        );
        let _ = net::write_frame(stream, &encode_reject(&msg), MAX_CONTROL_FRAME);
        bail!("{msg}");
    }
    net::write_frame(stream, &encode_assign(assign), MAX_DATA_FRAME)
        .context("sending assignment")?;
    let ready = net::read_frame(stream, MAX_CONTROL_FRAME)
        .context("reading worker ready")?
        .ok_or_else(|| {
            anyhow!("worker {} closed before acknowledging its assignment", assign.worker_index)
        })?;
    if let Some(msg) = decode_ready(&ready)? {
        bail!("worker {} rejected the assignment: {msg}", assign.worker_index);
    }
    stream.set_read_timeout(None).context("clearing handshake timeout")?;
    Ok(())
}

impl Transport for SocketTransport {
    fn num_workers(&self) -> usize {
        self.streams.len()
    }

    fn send(&mut self, worker: usize, msg: JobMsg) -> Result<()> {
        ensure!(
            !self.dead[worker],
            "internal: send to worker {worker}, which was folded onto survivors"
        );
        // raw bytes are counted at enqueue on this thread (the
        // transfer-engine unit is timing-independent); the writer thread
        // counts the on-wire bytes when it serializes the frame
        if let JobMsg::Train(job) = &msg {
            self.up_bytes[worker] += job_payload_bytes(job);
            self.outstanding[worker].push((job.vid, job.cid));
        }
        let queued = match &self.writers[worker] {
            Some(w) => w.tx.send(msg).is_ok(),
            None => false,
        };
        if !queued {
            self.failed = Some(worker);
            bail!("sending to worker {worker}: connection failed (writer thread exited)");
        }
        Ok(())
    }

    fn recv(&mut self) -> Result<Reply> {
        let deadline = self.recv_timeout.map(|t| Instant::now() + t);
        loop {
            let wait = match (deadline, self.heartbeat) {
                (None, None) => {
                    // block forever (local-mode semantics; EOF fails loud)
                    let ev = self
                        .rx
                        .recv()
                        .map_err(|_| anyhow!("all worker connections closed"))?;
                    if self.stale(&ev) {
                        self.drop_stale(ev);
                        continue;
                    }
                    return self.map_event(ev);
                }
                (None, Some(h)) => h,
                (Some(d), None) => d.saturating_duration_since(Instant::now()),
                (Some(d), Some(h)) => h.min(d.saturating_duration_since(Instant::now())),
            };
            match self.rx.recv_timeout(wait) {
                Ok(ev) => {
                    if self.stale(&ev) {
                        self.drop_stale(ev);
                        continue;
                    }
                    return self.map_event(ev);
                }
                Err(mpsc::RecvTimeoutError::Timeout) => {
                    if let Some(d) = deadline {
                        if Instant::now() >= d {
                            let t = self.recv_timeout.expect("deadline implies timeout");
                            return Err(self.timeout_error(t));
                        }
                    }
                    // the slice expired before the deadline: probe
                    self.send_pings()?;
                }
                Err(mpsc::RecvTimeoutError::Disconnected) => {
                    bail!("all worker connections closed")
                }
            }
        }
    }

    fn try_recv(&mut self) -> Result<Option<Reply>> {
        loop {
            match self.rx.try_recv() {
                Ok(ev) => {
                    if self.stale(&ev) {
                        self.drop_stale(ev);
                        continue;
                    }
                    return self.map_event(ev).map(Some);
                }
                Err(mpsc::TryRecvError::Empty) => return Ok(None),
                Err(mpsc::TryRecvError::Disconnected) => {
                    return Err(anyhow!("all worker connections closed"))
                }
            }
        }
    }

    fn shutdown(&mut self) -> Result<Option<TransportReport>> {
        // Flush-then-BYE: STOP rides each live writer's queue *behind*
        // every outstanding frame; dropping the sender and joining the
        // writer then guarantees the whole queue — STOP included — is on
        // the wire before we wait for that worker's BYE. No frame can be
        // lost after STOP (asserted by a unit test below).
        for i in 0..self.writers.len() {
            if self.dead[i] {
                self.writers[i] = None; // folded: no Stop, no BYE owed
                continue;
            }
            if let Some(w) = &self.writers[i] {
                // a worker that already died surfaces as a missing BYE
                let _ = w.tx.send(JobMsg::Stop);
            }
        }
        for slot in self.writers.iter_mut() {
            if let Some(SlotWriter { tx, join }) = slot.take() {
                drop(tx); // hang up the queue: the writer drains and exits
                let _ = join.join();
            }
        }
        let live_missing = |byes: &[Option<(u64, u64, u64, u64)>], dead: &[bool]| -> Vec<usize> {
            (0..byes.len()).filter(|&i| !dead[i] && byes[i].is_none()).collect()
        };
        let deadline = Instant::now() + SHUTDOWN_TIMEOUT;
        while !live_missing(&self.byes, &self.dead).is_empty() {
            let remaining = deadline.saturating_duration_since(Instant::now());
            let missing = live_missing(&self.byes, &self.dead);
            ensure!(
                !remaining.is_zero(),
                "worker(s) {missing:?} sent no shutdown ledger within {SHUTDOWN_TIMEOUT:?}"
            );
            match self.rx.recv_timeout(remaining) {
                Ok(ev) => {
                    if self.stale(&ev) {
                        self.drop_stale(ev); // retired generations owe nothing
                        continue;
                    }
                    let i = ev.worker;
                    match ev.kind {
                        SocketEventKind::Bye { received, sent, wire_received, wire_sent } => {
                            ensure!(
                                self.byes[i].is_none(),
                                "worker {i} sent two shutdown ledgers"
                            );
                            self.byes[i] = Some((received, sent, wire_received, wire_sent));
                        }
                        SocketEventKind::Reply(..) => {
                            bail!(
                                "worker {i} sent a result during shutdown \
                                 (job still in flight?)"
                            )
                        }
                        SocketEventKind::WorkerErr(msg) => bail!("worker {i}: {msg}"),
                        SocketEventKind::Eof => {
                            bail!("worker {i} disconnected before sending its shutdown ledger")
                        }
                        SocketEventKind::ReadErr(msg) => {
                            bail!("worker {i} connection failed during shutdown: {msg}")
                        }
                        SocketEventKind::WriteErr(msg) => {
                            bail!("worker {i} connection failed during shutdown: {msg}")
                        }
                    }
                }
                Err(mpsc::RecvTimeoutError::Timeout) => bail!(
                    "worker(s) {missing:?} sent no shutdown ledger within {SHUTDOWN_TIMEOUT:?}"
                ),
                Err(mpsc::RecvTimeoutError::Disconnected) => bail!(
                    "reader threads exited before worker(s) {missing:?} sent their ledgers"
                ),
            }
        }
        // retired writers have shut-down streams, so they exit promptly;
        // join them before summing their wire counters
        for writer in self.retired_writers.drain(..) {
            let _ = writer.join();
        }
        for reader in self.readers.drain(..) {
            let _ = reader.join();
        }
        // Totals: live generations (BYE-verified) + folded slots' own
        // counts + retired (pre-replacement) generations, so the run
        // totals still equal the transfer-engine ledger after recovery.
        let (mut up, mut down) = (self.retired_up, 0u64);
        let (mut wire_up, mut wire_down) = (0u64, 0u64);
        for counter in &self.retired_down {
            down += counter.load(Ordering::Relaxed);
        }
        for counter in &self.retired_wire_up {
            wire_up += counter.load(Ordering::Relaxed);
        }
        for counter in &self.retired_wire_down {
            wire_down += counter.load(Ordering::Relaxed);
        }
        for (i, bye) in self.byes.iter().enumerate() {
            let slot_wire_up = self.wire_up[i].load(Ordering::Relaxed);
            let slot_wire_down = self.wire_down[i].load(Ordering::Relaxed);
            if self.dead[i] {
                up += self.up_bytes[i];
                down += self.down_bytes[i].load(Ordering::Relaxed);
                wire_up += slot_wire_up;
                wire_down += slot_wire_down;
                continue;
            }
            let (received, sent, wire_received, wire_sent) =
                bye.expect("loop above filled every live bye");
            ensure!(
                received == self.up_bytes[i],
                "wire ledger mismatch for worker {i}: coordinator shipped {} payload bytes \
                 but the worker received {received}",
                self.up_bytes[i]
            );
            ensure!(
                wire_received == slot_wire_up,
                "wire ledger mismatch for worker {i}: coordinator put {slot_wire_up} bytes \
                 on the wire but the worker counted {wire_received} arriving"
            );
            let local_down = self.down_bytes[i].load(Ordering::Relaxed);
            ensure!(
                sent == local_down,
                "wire ledger mismatch for worker {i}: worker sent {sent} payload bytes \
                 but the coordinator received {local_down}"
            );
            ensure!(
                wire_sent == slot_wire_down,
                "wire ledger mismatch for worker {i}: worker put {wire_sent} bytes on the \
                 wire but the coordinator counted {slot_wire_down} arriving"
            );
            up += received;
            down += sent;
            wire_up += slot_wire_up;
            wire_down += slot_wire_down;
        }
        // Replies dropped as stale were received (and counted by their
        // retired/folded reader) but never scattered; back them out so
        // the totals match the transfer-engine ledger exactly.
        ensure!(
            down >= self.stale_down,
            "internal: stale-dropped reply bytes ({}) exceed the received total ({down})",
            self.stale_down
        );
        down -= self.stale_down;
        ensure!(
            wire_down >= self.stale_wire_down,
            "internal: stale-dropped wire bytes ({}) exceed the on-wire total ({wire_down})",
            self.stale_wire_down
        );
        wire_down -= self.stale_wire_down;
        let n = self.streams.len();
        let report = TransportReport { workers: n, bytes_up: up, bytes_down: down, wire_up, wire_down };
        let wire_total = wire_up + wire_down;
        let ratio =
            if wire_total == 0 { 1.0 } else { (up + down) as f64 / wire_total as f64 };
        eprintln!(
            "transport: ledger balanced across {n} workers ({up} bytes up, {down} bytes \
             down; {wire_up} up / {wire_down} down on the wire, {} saved, compression \
             ratio {ratio:.2}x)",
            report.wire_bytes_saved(),
        );
        Ok(Some(report))
    }

    fn failed_worker(&self) -> Option<usize> {
        self.failed
    }

    fn try_replace(&mut self, slot: usize, rng_state: [u64; 4]) -> Result<bool> {
        let mut refilled = false;
        loop {
            let accepted = match &self.listener {
                None => return Ok(false), // rejoin listener not kept open
                Some(listener) => listener.accept(),
            };
            let (mut stream, peer) = match accepted {
                Ok(pair) => pair,
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                Err(e) => return Err(anyhow!(e).context("polling rejoin listener")),
            };
            // the listener is non-blocking; the handshake must not be
            stream
                .set_nonblocking(false)
                .context("switching rejoin candidate to blocking")?;
            if refilled {
                // second candidate for an already-refilled slot: reject
                // pointedly instead of silently dropping the connection
                let msg = format!(
                    "slot {slot} already refilled at generation {} — stale or \
                     duplicate worker",
                    self.generation[slot]
                );
                eprintln!("transport: rejected connection from {peer}: {msg}");
                let _ = net::read_frame(&mut stream, MAX_CONTROL_FRAME); // its HELLO
                let _ =
                    net::write_frame(&mut stream, &encode_reject(&msg), MAX_CONTROL_FRAME);
                continue;
            }
            let mut assign = self.assignments[slot].clone();
            assign.rng_state = rng_state;
            assign.generation = self.generation[slot] + 1;
            match handshake_worker(&mut stream, &assign) {
                Ok(()) => {
                    eprintln!(
                        "transport: worker {slot} replaced from {peer} \
                         (generation {})",
                        assign.generation
                    );
                    // retire the dead generation's ledger; the
                    // replacement's BYE covers only its own traffic
                    self.retired_up += self.up_bytes[slot];
                    self.up_bytes[slot] = 0;
                    self.retired_down.push(Arc::clone(&self.down_bytes[slot]));
                    self.retired_wire_up.push(Arc::clone(&self.wire_up[slot]));
                    self.retired_wire_down.push(Arc::clone(&self.wire_down[slot]));
                    // cut the dead generation's writer loose: shutting
                    // down its stream unblocks any stuck write, dropping
                    // its sender lets it drain and exit (joined at
                    // shutdown, before its wire counter is summed)
                    let _ = self.streams[slot].shutdown(std::net::Shutdown::Both);
                    if let Some(SlotWriter { tx, join }) = self.writers[slot].take() {
                        drop(tx);
                        self.retired_writers.push(join);
                    }
                    let counter = Arc::new(AtomicU64::new(0));
                    self.down_bytes[slot] = Arc::clone(&counter);
                    let wire_rx_counter = Arc::new(AtomicU64::new(0));
                    self.wire_down[slot] = Arc::clone(&wire_rx_counter);
                    let wire_tx_counter = Arc::new(AtomicU64::new(0));
                    self.wire_up[slot] = Arc::clone(&wire_tx_counter);
                    let heard = Arc::new(AtomicU64::new(
                        self.epoch.elapsed().as_millis() as u64,
                    ));
                    self.last_heard[slot] = Arc::clone(&heard);
                    self.generation[slot] = assign.generation;
                    self.outstanding[slot].clear();
                    // a fresh compression context: the replacement holds
                    // no cached partitions, so journal re-sends encode
                    // against its actual (empty) resident state, never
                    // the dead worker's
                    let ctx = WireCtx::new(assign.wire_compression);
                    let read_half =
                        stream.try_clone().context("cloning replacement stream")?;
                    let tx = self.tx.clone();
                    let (gen, epoch) = (assign.generation, self.epoch);
                    let reader_ctx = ctx.clone();
                    self.readers.push(
                        std::thread::Builder::new()
                            .name(format!("transport-rx-{slot}-g{gen}"))
                            .spawn(move || {
                                reader_loop(
                                    slot,
                                    gen,
                                    read_half,
                                    tx,
                                    reader_ctx,
                                    counter,
                                    wire_rx_counter,
                                    heard,
                                    epoch,
                                )
                            })
                            .context("spawning replacement reader")?,
                    );
                    let write_half =
                        stream.try_clone().context("cloning replacement stream")?;
                    let writer_tx = self.tx.clone();
                    let (job_tx, job_rx) = mpsc::sync_channel(WRITER_QUEUE_DEPTH);
                    let join = std::thread::Builder::new()
                        .name(format!("transport-tx-{slot}-g{gen}"))
                        .spawn(move || {
                            writer_loop(
                                slot,
                                gen,
                                write_half,
                                job_rx,
                                ctx,
                                wire_tx_counter,
                                writer_tx,
                            )
                        })
                        .context("spawning replacement writer")?;
                    self.writers[slot] = Some(SlotWriter { tx: job_tx, join });
                    self.streams[slot] = stream;
                    self.failed = None;
                    refilled = true;
                }
                Err(e) => {
                    eprintln!("transport: rejected connection from {peer}: {e:#}");
                }
            }
        }
        Ok(refilled)
    }

    fn mark_dead(&mut self, slot: usize) {
        self.dead[slot] = true;
        self.outstanding[slot].clear();
        if self.failed == Some(slot) {
            self.failed = None;
        }
        // closing our end unblocks the peer if it is somehow still
        // alive, and unblocks the slot's writer if it is stuck mid-write
        let _ = self.streams[slot].shutdown(std::net::Shutdown::Both);
        if let Some(SlotWriter { tx, join }) = self.writers[slot].take() {
            drop(tx);
            self.retired_writers.push(join);
        }
    }
}

// ---------------------------------------------------------------------
// Remote worker runtime: the `graphvite worker` process body.
// ---------------------------------------------------------------------

/// What [`run_worker`] did, for banners and tests.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct WorkerSummary {
    pub worker_index: usize,
    pub jobs: u64,
    pub bytes_received: u64,
    pub bytes_sent: u64,
    /// On-wire bytes of the packed sections behind `bytes_received`.
    pub wire_received: u64,
    /// On-wire bytes of the packed sections behind `bytes_sent`.
    pub wire_sent: u64,
}

/// Dial `addr` (retrying until `connect_timeout` — workers may start
/// before the coordinator listens), handshake, then serve jobs through
/// the same [`WorkerCore`] the in-process threads run, until STOP.
pub fn run_worker(addr: &str, connect_timeout: Duration) -> Result<WorkerSummary> {
    run_worker_with_fault(addr, connect_timeout, None)
}

/// [`run_worker`] with an injected fault: after answering
/// `die_after_jobs` training jobs the worker "crashes" — drops its
/// stream without a BYE, exactly what `kill -9` looks like from the
/// coordinator. Drives the in-process recovery tests; the CI drill
/// kills a real process instead.
pub fn run_worker_with_fault(
    addr: &str,
    connect_timeout: Duration,
    die_after_jobs: Option<u64>,
) -> Result<WorkerSummary> {
    let mut stream = connect_with_retry(addr, connect_timeout)?;
    let _ = stream.set_nodelay(true);
    net::write_frame(&mut stream, &encode_hello(), MAX_CONTROL_FRAME)
        .context("sending hello")?;
    stream
        .set_read_timeout(Some(HANDSHAKE_TIMEOUT))
        .context("setting handshake timeout")?;
    let frame = net::read_frame(&mut stream, MAX_DATA_FRAME)
        .context("reading assignment")?
        .ok_or_else(|| anyhow!("coordinator closed the connection during the handshake"))?;
    let assign = match decode_assign(&frame) {
        Ok(a) => a,
        Err(e) => {
            let _ = net::write_frame(
                &mut stream,
                &encode_ready(Some(&format!("{e:#}"))),
                MAX_CONTROL_FRAME,
            );
            return Err(e.context("validating coordinator assignment"));
        }
    };
    let built = build_core(&assign);
    let mut core = match built {
        Ok(core) => core,
        Err(e) => {
            let _ = net::write_frame(
                &mut stream,
                &encode_ready(Some(&format!("{e:#}"))),
                MAX_CONTROL_FRAME,
            );
            return Err(e);
        }
    };
    net::write_frame(&mut stream, &encode_ready(None), MAX_CONTROL_FRAME)
        .context("sending ready")?;
    stream.set_read_timeout(None).context("clearing handshake timeout")?;
    eprintln!(
        "worker: connected to {addr} as worker {}/{} (backend {}, dim {}, {} partitions, \
         capacity {})",
        assign.worker_index,
        assign.num_workers,
        assign.backend.name(),
        assign.dim,
        assign.partitions,
        assign.capacity,
    );
    if assign.generation > 0 {
        eprintln!(
            "worker: rejoined dead slot {} at generation {} — resuming its journaled work",
            assign.worker_index, assign.generation
        );
    }

    // the worker's end of the negotiated compression context: one cache
    // for both directions, kept in lockstep with the coordinator's
    let ctx = WireCtx::new(assign.wire_compression);
    let (mut received, mut sent, mut jobs) = (0u64, 0u64, 0u64);
    let (mut wire_received, mut wire_sent) = (0u64, 0u64);
    loop {
        let payload = net::read_frame(&mut stream, MAX_DATA_FRAME)
            .context("reading job")?
            .ok_or_else(|| {
                anyhow!("coordinator closed the connection without a stop message")
            })?;
        let (msg, lens) = decode_job_msg(&payload, &ctx)?;
        wire_received += lens.wire;
        let is_train = matches!(&msg, JobMsg::Train(_));
        if let JobMsg::Train(job) = &msg {
            received += job_payload_bytes(job);
            jobs += 1;
        }
        match core.handle(msg) {
            None => {
                let bye = WireReply::Bye { received, sent, wire_received, wire_sent };
                let (frame, _) = encode_wire_reply(&bye, &ctx);
                net::write_frame(&mut stream, &frame, MAX_CONTROL_FRAME)
                    .context("sending shutdown ledger")?;
                break;
            }
            Some(Ok(reply)) => {
                sent += reply_payload_bytes(&reply);
                let (frame, lens) = encode_wire_reply(&WireReply::Reply(reply), &ctx);
                wire_sent += lens.wire;
                net::write_frame(&mut stream, &frame, MAX_DATA_FRAME)
                    .context("sending result")?;
                if let Some(n) = die_after_jobs {
                    if is_train && jobs >= n {
                        // abrupt death: no BYE, the stream just closes —
                        // the coordinator sees EOF mid-run
                        bail!("worker: injected crash after {jobs} jobs (fault harness)");
                    }
                }
            }
            Some(Err(e)) => {
                // mirror the local loop: the error rides the reply
                // stream and the worker keeps serving
                let (frame, _) = encode_wire_reply(&WireReply::Err(format!("{e:#}")), &ctx);
                net::write_frame(&mut stream, &frame, MAX_DATA_FRAME)
                    .context("sending job error")?;
            }
        }
    }
    let wire_total = wire_received + wire_sent;
    let ratio = if wire_total == 0 {
        1.0
    } else {
        (received + sent) as f64 / wire_total as f64
    };
    eprintln!(
        "worker: ledger {received} bytes in ({wire_received} on the wire), {sent} bytes \
         out ({wire_sent} on the wire) over {jobs} jobs, compression ratio {ratio:.2}x — bye"
    );
    Ok(WorkerSummary {
        worker_index: assign.worker_index,
        jobs,
        bytes_received: received,
        bytes_sent: sent,
        wire_received,
        wire_sent,
    })
}

fn build_core(assign: &WorkerAssignment) -> Result<WorkerCore> {
    let rng = Rng::from_state(assign.rng_state)
        .map_err(|e| anyhow!("assignment rng state: {e}"))?;
    let neg = Arc::new(NegativeSampler::from_weights(&assign.neg_weights));
    let cfg = TrainConfig {
        backend: assign.backend,
        dim: assign.dim,
        // capacity-aware chunk sizing, exactly like spawn_workers
        batch_size: assign.batch_size * assign.capacity,
        negatives: assign.negatives,
        neg_weight: assign.neg_weight,
        num_workers: assign.num_workers,
        seed: assign.seed,
        ..TrainConfig::default()
    };
    WorkerCore::new(
        assign.worker_index,
        &cfg,
        assign.cache_limit,
        None,
        neg,
        Arc::new(Counters::default()),
        rng,
    )
}

/// First retry delay for a refused connection; doubles per attempt.
const CONNECT_BACKOFF_FLOOR: Duration = Duration::from_millis(100);
/// Backoff cap — retries keep this cadence until `timeout` expires.
const CONNECT_BACKOFF_CAP: Duration = Duration::from_secs(2);

/// Dial with capped exponential backoff (100ms doubling to 2s) until
/// `timeout`: a worker may start before the coordinator listens, or be
/// a replacement dialing a coordinator that is busy mid-group.
fn connect_with_retry(addr: &str, timeout: Duration) -> Result<TcpStream> {
    let start = Instant::now();
    let mut backoff = CONNECT_BACKOFF_FLOOR;
    loop {
        match TcpStream::connect(addr) {
            Ok(stream) => return Ok(stream),
            Err(e) => {
                if start.elapsed() >= timeout {
                    bail!("could not connect to coordinator at {addr} within {timeout:?}: {e}");
                }
                // never sleep past the deadline
                let remaining = timeout.saturating_sub(start.elapsed());
                std::thread::sleep(backoff.min(remaining));
                backoff = (backoff * 2).min(CONNECT_BACKOFF_CAP);
            }
        }
    }
}

// ---------------------------------------------------------------------
// FlakyTransport: deterministic fault injection around any transport.
// ---------------------------------------------------------------------

/// Seeded fault schedule for [`FlakyTransport`]. All probabilities are
/// per-mille per training reply (sync replies pass through untouched —
/// faults target the mid-episode window).
#[derive(Debug, Clone)]
pub struct FaultPlan {
    pub seed: u64,
    /// ‰ chance a training reply is silently discarded (the coordinator
    /// must then fail loud via `timeout`, never hang).
    pub drop_permille: u32,
    /// ‰ chance a training reply is delivered twice (the in-flight set
    /// rejects the duplicate with a pointed error).
    pub dup_permille: u32,
    /// ‰ chance a training reply is held back and delivered after the
    /// next one (reordering — absorb order is commutative, so the run
    /// must stay bitwise-identical).
    pub hold_permille: u32,
    /// Training replies delivered cleanly before faults arm (lets a
    /// checkpoint land before the injected failure).
    pub skip_first: u64,
    /// After this many sends, every further send/recv fails like a dead
    /// connection.
    pub disconnect_after_sends: Option<u64>,
    /// `(after_sends, slot)`: once that many messages have been sent,
    /// worker `slot` "dies" — further sends to it are silently
    /// swallowed, replies to jobs it owned are dropped, and the recv
    /// deadline surfaces a pointed error naming it. The in-process
    /// `kill -9`, driving the fold-onto-survivors recovery path.
    pub kill_worker: Option<(u64, usize)>,
    /// Deadline for [`Transport::recv`] — the no-hang guarantee when a
    /// reply was dropped.
    pub timeout: Duration,
}

impl Default for FaultPlan {
    fn default() -> Self {
        FaultPlan {
            seed: 0,
            drop_permille: 0,
            dup_permille: 0,
            hold_permille: 0,
            skip_first: 0,
            disconnect_after_sends: None,
            kill_worker: None,
            timeout: Duration::from_secs(2),
        }
    }
}

/// A held reply is released anyway once the inner transport has been
/// idle this long — a hold on the final in-flight reply must not
/// deadlock the fence.
const HOLD_GRACE: Duration = Duration::from_millis(20);

enum Fault {
    Deliver,
    Drop,
    Duplicate,
    Hold,
}

/// Fault-injection decorator over any [`Transport`]: deterministic
/// (seeded xoshiro) drops, duplicate delivery, holds (reorders) and
/// injected disconnects, with a recv deadline so injected loss turns
/// into a pointed error instead of a hang. Test-only by intent, wired
/// in through [`super::Trainer::set_transport_wrapper`].
pub struct FlakyTransport {
    inner: Box<dyn Transport>,
    plan: FaultPlan,
    rng: Rng,
    seen: u64,
    sends: u64,
    disconnected: bool,
    /// Slot killed by `plan.kill_worker`, once the trigger fires.
    killed: Option<usize>,
    /// Last slot declared dead ([`Transport::failed_worker`]).
    failed: Option<usize>,
    ready: VecDeque<Reply>,
    held: VecDeque<Reply>,
}

impl FlakyTransport {
    pub fn new(inner: Box<dyn Transport>, plan: FaultPlan) -> Self {
        let rng = Rng::new(plan.seed);
        FlakyTransport {
            inner,
            plan,
            rng,
            seen: 0,
            sends: 0,
            disconnected: false,
            killed: None,
            failed: None,
            ready: VecDeque::new(),
            held: VecDeque::new(),
        }
    }

    fn ensure_connected(&self) -> Result<()> {
        ensure!(
            !self.disconnected,
            "flaky transport: connection lost (injected disconnect after {} messages)",
            self.sends
        );
        Ok(())
    }

    fn roll(&mut self) -> Fault {
        let r = (self.rng.next_u64() % 1000) as u32;
        let p = &self.plan;
        if r < p.drop_permille {
            Fault::Drop
        } else if r < p.drop_permille + p.dup_permille {
            Fault::Duplicate
        } else if r < p.drop_permille + p.dup_permille + p.hold_permille {
            Fault::Hold
        } else {
            Fault::Deliver
        }
    }

    fn flush_held(&mut self) {
        while let Some(r) = self.held.pop_front() {
            self.ready.push_back(r);
        }
    }

    /// Apply the fault decision to one incoming reply; `Some` = deliver
    /// now (held replies queue up behind it).
    fn admit(&mut self, reply: Reply) -> Option<Reply> {
        if let Some(k) = self.killed {
            // anything the dead slot produced dies with it — replies are
            // filtered by *identity* (who trained it), so a job
            // re-dispatched to a survivor passes even though the dead
            // slot computed the same job earlier
            match &reply {
                Reply::Job(r) if r.worker == k => return None,
                Reply::Synced(s) if s.worker == k => return None,
                _ => {}
            }
        }
        if !matches!(reply, Reply::Job(_)) {
            return Some(reply); // fences pass through untouched
        }
        self.seen += 1;
        if self.seen <= self.plan.skip_first {
            self.flush_held();
            return Some(reply);
        }
        match self.roll() {
            Fault::Drop => None,
            Fault::Hold => {
                self.held.push_back(reply);
                None
            }
            Fault::Duplicate => {
                self.ready.push_back(reply.clone());
                self.flush_held();
                Some(reply)
            }
            Fault::Deliver => {
                self.flush_held();
                Some(reply)
            }
        }
    }
}

impl Transport for FlakyTransport {
    fn num_workers(&self) -> usize {
        self.inner.num_workers()
    }

    fn send(&mut self, worker: usize, msg: JobMsg) -> Result<()> {
        self.ensure_connected()?;
        if let Some(n) = self.plan.disconnect_after_sends {
            if self.sends >= n {
                self.disconnected = true;
                bail!(
                    "flaky transport: worker {worker} connection lost \
                     (injected disconnect after {n} messages)"
                );
            }
        }
        self.sends += 1;
        if let Some((after, slot)) = self.plan.kill_worker {
            if self.killed.is_none() && self.sends > after {
                self.killed = Some(slot);
            }
        }
        if self.killed == Some(worker) {
            return Ok(()); // swallowed: the dead worker never sees it
        }
        self.inner.send(worker, msg)
    }

    fn recv(&mut self) -> Result<Reply> {
        self.ensure_connected()?;
        if let Some(r) = self.ready.pop_front() {
            return Ok(r);
        }
        let deadline = Instant::now() + self.plan.timeout;
        let mut idle_since = Instant::now();
        loop {
            match self.inner.try_recv()? {
                Some(reply) => {
                    idle_since = Instant::now();
                    if let Some(r) = self.admit(reply) {
                        return Ok(r);
                    }
                }
                None => {
                    if !self.held.is_empty() && idle_since.elapsed() >= HOLD_GRACE {
                        return Ok(self.held.pop_front().expect("non-empty"));
                    }
                    if Instant::now() >= deadline {
                        if let Some(k) = self.killed {
                            self.failed = Some(k);
                            bail!(
                                "flaky transport: worker {k} killed (injected) — no reply \
                                 within {:?}, its outstanding jobs died with it",
                                self.plan.timeout
                            );
                        }
                        bail!(
                            "flaky transport: no worker reply within {:?} ({} held back) — \
                             a dropped message would hang the run, failing loud instead",
                            self.plan.timeout,
                            self.held.len()
                        );
                    }
                    std::thread::sleep(Duration::from_millis(1));
                }
            }
        }
    }

    fn try_recv(&mut self) -> Result<Option<Reply>> {
        self.ensure_connected()?;
        if let Some(r) = self.ready.pop_front() {
            return Ok(Some(r));
        }
        loop {
            match self.inner.try_recv()? {
                Some(reply) => {
                    if let Some(r) = self.admit(reply) {
                        return Ok(Some(r));
                    }
                }
                None => return Ok(None),
            }
        }
    }

    fn shutdown(&mut self) -> Result<Option<TransportReport>> {
        // no ensure_connected here: shutdown is cleanup. The "disconnect"
        // and the "kill" are injected — the inner transport is healthy
        // and must still deliver Stop to every worker (including the
        // simulated-dead one, whose thread is actually alive), or the
        // scope join would hang on workers blocked in recv.
        self.inner.shutdown()
    }

    fn failed_worker(&self) -> Option<usize> {
        self.failed.or_else(|| self.inner.failed_worker())
    }

    fn try_replace(&mut self, slot: usize, rng_state: [u64; 4]) -> Result<bool> {
        if self.killed == Some(slot) {
            // an injected death has no process to replace — the runner
            // must fold this slot onto the survivors
            return Ok(false);
        }
        self.inner.try_replace(slot, rng_state)
    }

    fn mark_dead(&mut self, slot: usize) {
        if self.killed == Some(slot) {
            self.failed = None;
            return; // simulated: the inner worker stays up for shutdown
        }
        self.inner.mark_dead(slot);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn bits(xs: &[f32]) -> Vec<u32> {
        xs.iter().map(|x| x.to_bits()).collect()
    }

    /// A fresh encode/decode context pair, like the two ends of one
    /// connection right after the handshake.
    fn ctx_pair(compress: bool) -> (WireCtx, WireCtx) {
        (WireCtx::new(compress), WireCtx::new(compress))
    }

    fn sample_job() -> Job {
        Job {
            vid: 3,
            cid: 7,
            block: vec![(0, 1), (5, -2), (9, 9)],
            vertex: Shipment {
                data: Some(vec![1.5, -0.0, 2.25e-3]),
                src_version: 4,
                keep: true,
            },
            context: Shipment { data: None, src_version: 9, keep: false },
            lr: 0.017,
            takeover: None,
        }
    }

    #[test]
    fn job_msg_roundtrip_bitwise() {
        for compress in [false, true] {
            let (enc, dec) = ctx_pair(compress);
            let msg = JobMsg::Train(sample_job());
            let (payload, el) = encode_job_msg(&msg, &enc);
            let (decoded, dl) = decode_job_msg(&payload, &dec).unwrap();
            assert_eq!(el.raw, 12, "one 3-f32 shipment");
            assert_eq!((el.raw, el.wire), (dl.raw, dl.wire), "both ends count alike");
            assert!(el.wire <= el.raw);
            let JobMsg::Train(job) = decoded else { panic!("wrong variant") };
            assert_eq!(job.vid, 3);
            assert_eq!(job.cid, 7);
            assert_eq!(job.lr.to_bits(), 0.017f32.to_bits());
            assert_eq!(job.block, vec![(0, 1), (5, -2), (9, 9)]);
            assert_eq!(
                bits(job.vertex.data.as_deref().unwrap()),
                bits(&[1.5, -0.0, 2.25e-3])
            );
            assert_eq!(job.vertex.src_version, 4);
            assert!(job.vertex.keep);
            assert!(job.context.data.is_none());
            assert_eq!(job.context.src_version, 9);
            assert!(!job.context.keep);
            assert_eq!(job.takeover, None);
            for msg in [JobMsg::Sync, JobMsg::Stop, JobMsg::Ping] {
                let (payload, l) = encode_job_msg(&msg, &enc);
                let (rt, _) = decode_job_msg(&payload, &dec).unwrap();
                assert_eq!(l, PackedLens::default(), "control frames carry no payload");
                assert!(matches!(
                    (&msg, &rt),
                    (JobMsg::Sync, JobMsg::Sync)
                        | (JobMsg::Stop, JobMsg::Stop)
                        | (JobMsg::Ping, JobMsg::Ping)
                ));
            }
        }
    }

    #[test]
    fn takeover_roundtrip_bitwise() {
        let (enc, dec) = ctx_pair(true);
        let mut job = sample_job();
        job.takeover = Some(Takeover { rng: [9, 8, 7, 6], chunk_samples: 4096 });
        let (payload, _) = encode_job_msg(&JobMsg::Train(job), &enc);
        let (rt, _) = decode_job_msg(&payload, &dec).unwrap();
        let JobMsg::Train(job) = rt else { panic!("wrong variant") };
        assert_eq!(job.takeover, Some(Takeover { rng: [9, 8, 7, 6], chunk_samples: 4096 }));
        // unknown takeover flag fails loud
        let (enc, dec) = ctx_pair(true);
        let (mut payload, _) = encode_job_msg(&JobMsg::Train(sample_job()), &enc);
        let last = payload.len() - 1;
        payload[last] = 7; // the takeover flag is the final byte of a plain job
        let err = decode_job_msg(&payload, &dec).unwrap_err();
        assert!(err.to_string().contains("takeover"), "{err}");
    }

    #[test]
    fn wire_reply_roundtrip_bitwise() {
        let (enc, dec) = ctx_pair(true);
        let reply = WireReply::Reply(Reply::Job(JobResult {
            worker: 9, // not a wire field: must NOT survive the roundtrip
            vid: 1,
            cid: 2,
            vertex: Some(vec![0.5, 1.5]),
            context: None,
            block: vec![(7, 7)], // must NOT survive the wire
            loss: 0.25,
            trained: 42,
            rng_state: [5, 6, 7, 8],
        }));
        let (payload, el) = encode_wire_reply(&reply, &enc);
        let (rt, dl) = decode_wire_reply(&payload, &dec).unwrap();
        assert_eq!(el.raw, 8, "two f32s, context elided");
        assert_eq!((el.raw, el.wire), (dl.raw, dl.wire));
        let WireReply::Reply(Reply::Job(r)) = rt else { panic!("wrong variant") };
        assert_eq!((r.vid, r.cid, r.trained), (1, 2, 42));
        assert_eq!(r.loss.to_bits(), 0.25f32.to_bits());
        assert_eq!(bits(r.vertex.as_deref().unwrap()), bits(&[0.5, 1.5]));
        assert!(r.context.is_none());
        assert!(r.block.is_empty(), "block allocation never crosses the wire");
        assert_eq!(r.rng_state, [5, 6, 7, 8], "post-job rng state rides the result");
        assert_eq!(r.worker, 0, "worker identity is stamped by the receiver, not the wire");

        let (payload, _) = encode_wire_reply(&WireReply::Reply(Reply::Pong), &enc);
        let pong = decode_wire_reply(&payload, &dec);
        assert!(matches!(pong.unwrap().0, WireReply::Reply(Reply::Pong)));
        assert_eq!(reply_payload_bytes(&Reply::Pong), 0, "pongs carry no payload");

        let synced = WireReply::Reply(Reply::Synced(SyncReply {
            worker: 1,
            rng_state: [1, 2, 3, 4],
            residents: vec![ResidentPart {
                matrix: Matrix::Context,
                pid: 3,
                version: 11,
                data: vec![9.0, -9.0],
            }],
        }));
        let (payload, _) = encode_wire_reply(&synced, &enc);
        let (rt, _) = decode_wire_reply(&payload, &dec).unwrap();
        let WireReply::Reply(Reply::Synced(s)) = rt else { panic!("wrong variant") };
        assert_eq!(s.worker, 1);
        assert_eq!(s.rng_state, [1, 2, 3, 4]);
        assert_eq!(s.residents.len(), 1);
        assert_eq!(s.residents[0].matrix, Matrix::Context);
        assert_eq!(s.residents[0].version, 11);
        assert_eq!(bits(&s.residents[0].data), bits(&[9.0, -9.0]));

        let err = WireReply::Err("residency cache over capacity".into());
        let (payload, _) = encode_wire_reply(&err, &enc);
        let WireReply::Err(msg) = decode_wire_reply(&payload, &dec).unwrap().0 else {
            panic!("wrong variant")
        };
        assert_eq!(msg, "residency cache over capacity");

        let bye =
            WireReply::Bye { received: 100, sent: 200, wire_received: 80, wire_sent: 150 };
        let (payload, _) = encode_wire_reply(&bye, &enc);
        let WireReply::Bye { received, sent, wire_received, wire_sent } =
            decode_wire_reply(&payload, &dec).unwrap().0
        else {
            panic!("wrong variant")
        };
        assert_eq!((received, sent, wire_received, wire_sent), (100, 200, 80, 150));
    }

    #[test]
    fn corrupt_messages_fail_loudly() {
        let (enc, _) = ctx_pair(true);
        // truncated frames at several depths (fresh decode context each
        // time: a truncated frame must fail, never poison a cache)
        let (full, _) = encode_job_msg(&JobMsg::Train(sample_job()), &enc);
        for cut in [1, 5, 12, full.len() - 1] {
            let dec = WireCtx::new(true);
            assert!(decode_job_msg(&full[..cut], &dec).is_err(), "cut at {cut}");
        }
        let dec = WireCtx::new(true);
        // trailing garbage
        let (mut msg, _) = encode_job_msg(&JobMsg::Sync, &enc);
        msg.push(0);
        assert!(decode_job_msg(&msg, &dec).is_err());
        let bye = WireReply::Bye { received: 1, sent: 2, wire_received: 1, wire_sent: 2 };
        let (mut bye, _) = encode_wire_reply(&bye, &enc);
        bye.push(9);
        assert!(decode_wire_reply(&bye, &dec).is_err());
        // unknown tags / flags / matrix codes
        assert!(decode_job_msg(&[99], &dec).is_err());
        assert!(decode_wire_reply(&[99], &dec).is_err());
        assert!(decode_wire_reply(&[], &dec).is_err());
        // block length that lies about the payload cannot over-allocate
        let mut lying = vec![MSG_TRAIN];
        lying.extend_from_slice(&1u32.to_le_bytes());
        lying.extend_from_slice(&1u32.to_le_bytes());
        lying.extend_from_slice(&0.1f32.to_le_bytes());
        lying.extend_from_slice(&u32::MAX.to_le_bytes()); // "4 billion pairs"
        assert!(decode_job_msg(&lying, &dec).is_err());
        // a delta section against a base the receiver does not hold
        // (diverged caches) is a pointed error, not garbage data
        let warm = WireCtx::new(true);
        let mut job = sample_job();
        job.vertex.data = Some(vec![1.0, 2.0, 3.0]);
        let (_, _) = encode_job_msg(&JobMsg::Train(job.clone()), &warm);
        job.vertex.data = Some(vec![1.0, 2.0, 3.5]); // near → delta mode
        let (delta_frame, _) = encode_job_msg(&JobMsg::Train(job), &warm);
        let cold = WireCtx::new(true);
        let err = decode_job_msg(&delta_frame, &cold).unwrap_err();
        assert!(err.to_string().contains("wire-cached base"), "{err}");
    }

    #[test]
    fn handshake_roundtrip_and_field_rejection() {
        assert!(decode_hello(&encode_hello()).unwrap(), "this build always compresses");
        assert!(!decode_hello(&encode_hello_with(false)).unwrap());
        // bad magic
        let mut hello = encode_hello();
        hello[0] = b'X';
        let err = decode_hello(&hello).unwrap_err();
        assert!(err.to_string().contains("magic"), "{err}");
        // bad version
        let mut hello = encode_hello();
        hello[4..8].copy_from_slice(&999u32.to_le_bytes());
        let err = decode_hello(&hello).unwrap_err();
        assert!(err.to_string().contains("protocol v999"), "{err}");
        // bad capability byte
        let mut hello = encode_hello();
        let last = hello.len() - 1;
        hello[last] = 9;
        let err = decode_hello(&hello).unwrap_err();
        assert!(err.to_string().contains("compression capability"), "{err}");
        // trailing garbage
        let mut hello = encode_hello();
        hello.push(0);
        assert!(decode_hello(&hello).is_err());
    }

    fn sample_assignment() -> WorkerAssignment {
        WorkerAssignment {
            worker_index: 1,
            num_workers: 2,
            partitions: 2,
            dim: 8,
            batch_size: 32,
            negatives: 5,
            capacity: 3,
            cache_limit: Some(6),
            seed: 77,
            neg_weight: 5.0,
            backend: BackendKind::Native,
            rng_state: [1, 2, 3, 4],
            generation: 0,
            wire_compression: true,
            neg_weights: vec![vec![1.0, 2.0], vec![0.5]],
        }
    }

    #[test]
    fn assignment_roundtrip_bitwise() {
        let a = sample_assignment();
        let rt = decode_assign(&encode_assign(&a)).unwrap();
        assert_eq!(rt.worker_index, 1);
        assert_eq!(rt.num_workers, 2);
        assert_eq!(rt.partitions, 2);
        assert_eq!((rt.dim, rt.batch_size, rt.negatives, rt.capacity), (8, 32, 5, 3));
        assert_eq!(rt.cache_limit, Some(6));
        assert_eq!(rt.seed, 77);
        assert_eq!(rt.backend, BackendKind::Native);
        assert_eq!(rt.rng_state, [1, 2, 3, 4]);
        assert_eq!(rt.generation, 0);
        assert!(rt.wire_compression);
        assert_eq!(rt.neg_weights.len(), 2);
        assert_eq!(bits(&rt.neg_weights[0]), bits(&[1.0, 2.0]));
        // unbounded cache limit uses the sentinel
        let rt = decode_assign(&encode_assign(&WorkerAssignment {
            cache_limit: None,
            ..a.clone()
        }))
        .unwrap();
        assert_eq!(rt.cache_limit, None);
        // the negotiated-off path survives the wire too
        let rt = decode_assign(&encode_assign(&WorkerAssignment {
            wire_compression: false,
            ..a.clone()
        }))
        .unwrap();
        assert!(!rt.wire_compression);
        // a RE-ASSIGN's rejoin generation survives the wire
        let rt =
            decode_assign(&encode_assign(&WorkerAssignment { generation: 3, ..a })).unwrap();
        assert_eq!(rt.generation, 3);
    }

    #[test]
    fn assignment_field_by_field_rejection() {
        let a = sample_assignment();
        // worker index out of range
        let bad = WorkerAssignment { worker_index: 2, ..a.clone() };
        let err = decode_assign(&encode_assign(&bad)).unwrap_err();
        assert!(err.to_string().contains("out of range"), "{err}");
        // pjrt is rejected for remote workers
        let bad = WorkerAssignment { backend: BackendKind::Pjrt, ..a.clone() };
        let err = decode_assign(&encode_assign(&bad)).unwrap_err();
        assert!(err.to_string().contains("pjrt"), "{err}");
        // all-zero rng state
        let bad = WorkerAssignment { rng_state: [0; 4], ..a.clone() };
        let err = decode_assign(&encode_assign(&bad)).unwrap_err();
        assert!(err.to_string().contains("rng"), "{err}");
        // zero dim
        let bad = WorkerAssignment { dim: 0, ..a.clone() };
        assert!(decode_assign(&encode_assign(&bad)).is_err());
        // reject frame surfaces the coordinator's message
        let err = decode_assign(&encode_reject("version skew")).unwrap_err();
        assert!(err.to_string().contains("version skew"), "{err}");
        // bad magic
        let mut enc = encode_assign(&a);
        enc[1] = b'X';
        let err = decode_assign(&enc).unwrap_err();
        assert!(err.to_string().contains("magic"), "{err}");
        // truncated weights
        let enc = encode_assign(&a);
        assert!(decode_assign(&enc[..enc.len() - 3]).is_err());
    }

    #[test]
    fn ready_roundtrip() {
        assert_eq!(decode_ready(&encode_ready(None)).unwrap(), None);
        assert_eq!(
            decode_ready(&encode_ready(Some("backend 'pjrt' not available"))).unwrap(),
            Some("backend 'pjrt' not available".into())
        );
        assert!(decode_ready(&[7]).is_err());
    }

    #[test]
    fn payload_byte_helpers_match() {
        let job = sample_job();
        assert_eq!(job_payload_bytes(&job), 12); // 3 f32s, context elided
        let reply = Reply::Job(JobResult {
            worker: 0,
            vid: 0,
            cid: 0,
            vertex: Some(vec![0.0; 5]),
            context: Some(vec![0.0; 2]),
            block: Vec::new(),
            loss: 0.0,
            trained: 0,
            rng_state: [1, 1, 1, 1],
        });
        assert_eq!(reply_payload_bytes(&reply), 28);
    }

    /// The shutdown ordering fix: hanging up a writer's queue must flush
    /// every frame already enqueued — including the trailing STOP —
    /// before the thread exits. A lost STOP would hang the worker; a
    /// lost job would corrupt the ledger.
    #[test]
    fn writer_drains_every_queued_frame_after_stop() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let client = TcpStream::connect(addr).unwrap();
        let (mut server, _) = listener.accept().unwrap();

        let (job_tx, job_rx) = mpsc::sync_channel::<JobMsg>(WRITER_QUEUE_DEPTH);
        let (ev_tx, ev_rx) = mpsc::channel();
        let ctx = WireCtx::new(true);
        let wire = Arc::new(AtomicU64::new(0));
        let writer = {
            let (ctx, wire) = (ctx.clone(), Arc::clone(&wire));
            std::thread::spawn(move || writer_loop(0, 0, client, job_rx, ctx, wire, ev_tx))
        };
        // 16 jobs through a depth-4 queue exercises backpressure while
        // the writer drains concurrently.
        for _ in 0..16 {
            job_tx.send(JobMsg::Train(sample_job())).unwrap();
        }
        job_tx.send(JobMsg::Stop).unwrap();
        drop(job_tx); // hang up — exactly what shutdown() does
        writer.join().unwrap();

        let dec = WireCtx::new(true);
        for i in 0..16 {
            let frame = net::read_frame(&mut server, MAX_DATA_FRAME).unwrap().unwrap();
            let (msg, _) = decode_job_msg(&frame, &dec).unwrap();
            assert!(matches!(msg, JobMsg::Train(_)), "frame {i} lost or reordered");
        }
        let frame = net::read_frame(&mut server, MAX_DATA_FRAME).unwrap().unwrap();
        let (msg, _) = decode_job_msg(&frame, &dec).unwrap();
        assert!(matches!(msg, JobMsg::Stop), "STOP must be the last frame out");
        assert!(ev_rx.try_recv().is_err(), "a clean drain reports no write error");
        assert!(wire.load(Ordering::Relaxed) > 0, "writer counts its wire bytes");
    }

    /// Both directions feed the same wire cache: after a result comes
    /// back, re-shipping that partition deltas against the rows the
    /// *result* carried — and stays bit-exact.
    #[test]
    fn repeat_shipments_shrink_on_the_wire_and_stay_bitwise() {
        let (coord, worker) = ctx_pair(true);
        let mut job = sample_job();
        job.vertex.data = Some(vec![1.0, 2.0, 3.0, 4.0]);
        let (payload, l1) = encode_job_msg(&JobMsg::Train(job.clone()), &coord);
        let (rt, _) = decode_job_msg(&payload, &worker).unwrap();
        let JobMsg::Train(rt) = rt else { panic!("wrong variant") };
        assert_eq!(
            bits(rt.vertex.data.as_deref().unwrap()),
            bits(&[1.0, 2.0, 3.0, 4.0])
        );
        // the worker returns slightly-evolved rows; decoding the result
        // moves BOTH ends' caches to the returned values
        let result = WireReply::Reply(Reply::Job(JobResult {
            worker: 0,
            vid: job.vid,
            cid: job.cid,
            vertex: Some(vec![1.0, 2.0, 3.0, 4.5]),
            context: None,
            block: Vec::new(),
            loss: 0.1,
            trained: 3,
            rng_state: [1, 2, 3, 4],
        }));
        let (payload, _) = encode_wire_reply(&result, &worker);
        decode_wire_reply(&payload, &coord).unwrap();
        // re-shipping near-identical rows now rides a small delta section
        job.vertex.data = Some(vec![1.0, 2.0, 3.0, 4.5]);
        let (payload, l2) = encode_job_msg(&JobMsg::Train(job), &coord);
        assert_eq!(l2.raw, l1.raw, "same four f32s of raw payload each time");
        assert!(l2.wire < l2.raw, "second shipment must delta: {l2:?}");
        let (rt, dl) = decode_job_msg(&payload, &worker).unwrap();
        assert_eq!((l2.raw, l2.wire), (dl.raw, dl.wire));
        let JobMsg::Train(rt) = rt else { panic!("wrong variant") };
        assert_eq!(
            bits(rt.vertex.data.as_deref().unwrap()),
            bits(&[1.0, 2.0, 3.0, 4.5])
        );
    }

    /// A v3 worker that cannot compress is turned away — with the same
    /// pointed message on both ends — when the run requires compression.
    #[test]
    fn handshake_rejects_workers_without_compression_support() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let client = std::thread::spawn(move || -> Result<String> {
            let mut stream = TcpStream::connect(addr)?;
            net::write_frame(&mut stream, &encode_hello_with(false), MAX_CONTROL_FRAME)?;
            let frame = net::read_frame(&mut stream, MAX_CONTROL_FRAME)?
                .ok_or_else(|| anyhow!("coordinator closed without a reject frame"))?;
            Ok(decode_assign(&frame).unwrap_err().to_string())
        });
        let (mut server, _) = listener.accept().unwrap();
        let err = handshake_worker(&mut server, &sample_assignment()).unwrap_err();
        assert!(err.to_string().contains("wire compression"), "{err}");
        let worker_saw = client.join().unwrap().unwrap();
        assert!(worker_saw.contains("wire compression"), "{worker_saw}");
        assert!(worker_saw.contains("--no-wire-compression"), "{worker_saw}");
    }
}
