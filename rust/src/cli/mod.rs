//! Hand-rolled CLI argument parser (clap is not in the offline crate
//! set). Supports subcommands, `--flag`, `--key value`, `--key=value`
//! and positional arguments, with generated usage text.

use std::collections::BTreeMap;

use anyhow::{bail, Result};

/// Parsed command line: subcommand, options, positionals.
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub command: String,
    opts: BTreeMap<String, String>,
    flags: Vec<String>,
    pub positional: Vec<String>,
}

/// Option names that never take a value. `--quiet graph.txt` is otherwise
/// ambiguous (flag + positional vs. `quiet=graph.txt`); a registry is the
/// only way to resolve it without clap-style declarative specs.
pub const KNOWN_FLAGS: &[&str] =
    &["help", "quiet", "version", "normalize", "no-color", "dry-run", "watch"];

impl Args {
    /// Parse from raw argv (excluding the program name), resolving flag vs.
    /// option via [`KNOWN_FLAGS`].
    pub fn parse(argv: &[String]) -> Result<Self> {
        Self::parse_with_flags(argv, KNOWN_FLAGS)
    }

    /// Parse with an explicit boolean-flag registry.
    pub fn parse_with_flags(argv: &[String], known_flags: &[&str]) -> Result<Self> {
        let mut out = Args::default();
        let mut it = argv.iter().peekable();
        if let Some(first) = it.peek() {
            if !first.starts_with('-') {
                out.command = it.next().unwrap().clone();
            }
        }
        while let Some(tok) = it.next() {
            if let Some(rest) = tok.strip_prefix("--") {
                if rest.is_empty() {
                    bail!("bare '--' not supported");
                }
                if let Some(eq) = rest.find('=') {
                    out.opts.insert(rest[..eq].to_string(), rest[eq + 1..].to_string());
                } else if known_flags.contains(&rest) {
                    out.flags.push(rest.to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    out.opts.insert(rest.to_string(), it.next().unwrap().clone());
                } else {
                    out.flags.push(rest.to_string());
                }
            } else {
                out.positional.push(tok.clone());
            }
        }
        Ok(out)
    }

    pub fn from_env() -> Result<Self> {
        let argv: Vec<String> = std::env::args().skip(1).collect();
        Self::parse(&argv)
    }

    /// String option.
    pub fn get(&self, key: &str) -> Option<&str> {
        self.opts.get(key).map(|s| s.as_str())
    }

    /// Typed option with default.
    pub fn get_parse<T: std::str::FromStr>(&self, key: &str, default: T) -> Result<T> {
        match self.opts.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{key}: cannot parse '{v}'")),
        }
    }

    /// Boolean flag (present / absent); `--key value` style also accepted
    /// with true/false.
    pub fn flag(&self, key: &str) -> bool {
        self.flags.iter().any(|f| f == key)
            || self
                .opts
                .get(key)
                .map(|v| v == "true" || v == "1")
                .unwrap_or(false)
    }

    /// Keys of unknown options (for strict validation).
    pub fn option_keys(&self) -> impl Iterator<Item = &str> {
        self.opts.keys().map(|s| s.as_str()).chain(self.flags.iter().map(|s| s.as_str()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &str) -> Vec<String> {
        s.split_whitespace().map(|t| t.to_string()).collect()
    }

    #[test]
    fn subcommand_opts_flags_positionals() {
        let a = Args::parse(&argv("train --dim 64 --backend=hlo --quiet graph.txt")).unwrap();
        assert_eq!(a.command, "train");
        assert_eq!(a.get("dim"), Some("64"));
        assert_eq!(a.get("backend"), Some("hlo"));
        assert!(a.flag("quiet"));
        assert_eq!(a.positional, vec!["graph.txt"]);
    }

    #[test]
    fn typed_parsing_with_default() {
        let a = Args::parse(&argv("x --epochs 7")).unwrap();
        assert_eq!(a.get_parse("epochs", 1usize).unwrap(), 7);
        assert_eq!(a.get_parse("dim", 64usize).unwrap(), 64);
        assert!(a.get_parse::<usize>("epochs", 0).is_ok());
        let b = Args::parse(&argv("x --epochs seven")).unwrap();
        assert!(b.get_parse::<usize>("epochs", 0).is_err());
    }

    #[test]
    fn flag_via_value() {
        let a = Args::parse(&argv("x --verbose true")).unwrap();
        assert!(a.flag("verbose"));
        let b = Args::parse(&argv("x --verbose false")).unwrap();
        assert!(!b.flag("verbose"));
    }

    #[test]
    fn no_subcommand() {
        let a = Args::parse(&argv("--help")).unwrap();
        assert_eq!(a.command, "");
        assert!(a.flag("help"));
    }
}
