//! Runtime-layer integration tests: AOT HLO artifacts loaded through PJRT
//! must agree numerically with the Python JAX reference and with the
//! native rust backend.
//!
//! Compiled only with `--features pjrt`; needs real PJRT bindings (not
//! the offline `xla` stub) plus the AOT artifacts at run time.
#![cfg(feature = "pjrt")]

use graphvite::gpu::native_minibatch_step;
use graphvite::runtime::{default_manifest, Device, KernelDevice};

/// Deterministic fixture; the reference numbers in
/// `train_artifact_matches_python_reference` were produced by running the
/// Layer-2 jax function on exactly these values (see
/// `python/tests/test_model.py::TestRustParityFixture`).
fn fixture(
    p: usize,
    d: usize,
    s: usize,
    b: usize,
    k: usize,
) -> (Vec<f32>, Vec<f32>, Vec<i32>, Vec<i32>, Vec<i32>) {
    let vertex: Vec<f32> = (0..p * d).map(|i| ((i % 97) as f32 - 48.0) / 100.0).collect();
    let context: Vec<f32> = (0..p * d).map(|i| ((i % 89) as f32 - 44.0) / 100.0).collect();
    let pos_u: Vec<i32> = (0..s * b).map(|i| (i % 100) as i32).collect();
    let pos_v: Vec<i32> = (0..s * b).map(|i| ((i * 7 + 3) % 100) as i32).collect();
    let neg_v: Vec<i32> = (0..s * b * k).map(|i| ((i * 13 + 5) % 100) as i32).collect();
    (vertex, context, pos_u, pos_v, neg_v)
}

#[test]
fn train_artifact_matches_python_reference() {
    let m = default_manifest().unwrap();
    let meta = m.find_train(100, 16).unwrap();
    assert_eq!((meta.p, meta.d, meta.b, meta.s, meta.k), (256, 16, 64, 4, 1));
    let dev = Device::load(meta).unwrap();
    let (vertex, context, pos_u, pos_v, neg_v) = fixture(meta.p, meta.d, meta.s, meta.b, meta.k);
    let (vl, cl) = dev.upload_partitions(&vertex, &context).unwrap();
    let (nv, nc, loss) = dev.train_step(vl, cl, &pos_u, &pos_v, &neg_v, 0.025).unwrap();
    let (vh, ch) = dev.download_partitions(&nv, &nc).unwrap();
    let dv: f32 = vh.iter().zip(&vertex).map(|(a, b)| (a - b).abs()).sum();
    let dc: f32 = ch.iter().zip(&context).map(|(a, b)| (a - b).abs()).sum();
    assert!((loss - 2.172836).abs() < 1e-3, "loss {loss}");
    assert!((dv - 53.03366).abs() < 0.05, "dv {dv}");
    assert!((dc - 59.299427).abs() < 0.05, "dc {dc}");
}

#[test]
fn train_artifact_matches_native_backend_step() {
    // One S*B-sample train step through the HLO path must equal S
    // sequential native mini-batch steps (identical batch semantics:
    // gather → gradient at pre-update values → scatter-add).
    let m = default_manifest().unwrap();
    let meta = m.find_train(100, 16).unwrap();
    let dev = Device::load(meta).unwrap();
    let (vertex, context, pos_u, pos_v, neg_v) = fixture(meta.p, meta.d, meta.s, meta.b, meta.k);
    let lr = 0.0125f32;

    let (vl, cl) = dev.upload_partitions(&vertex, &context).unwrap();
    let (nv, nc, _loss) = dev.train_step(vl, cl, &pos_u, &pos_v, &neg_v, lr).unwrap();
    let (vh, ch) = dev.download_partitions(&nv, &nc).unwrap();

    let mut v2 = vertex.clone();
    let mut c2 = context.clone();
    let (mut gu, mut gc) = (Vec::new(), Vec::new());
    for step in 0..meta.s {
        native_minibatch_step(
            &mut v2,
            &mut c2,
            meta.d,
            &pos_u[step * meta.b..(step + 1) * meta.b],
            &pos_v[step * meta.b..(step + 1) * meta.b],
            &neg_v[step * meta.b * meta.k..(step + 1) * meta.b * meta.k],
            meta.k,
            lr,
            5.0,
            &mut gu,
            &mut gc,
        );
    }
    let max_dv = vh.iter().zip(&v2).map(|(a, b)| (a - b).abs()).fold(0f32, f32::max);
    let max_dc = ch.iter().zip(&c2).map(|(a, b)| (a - b).abs()).fold(0f32, f32::max);
    assert!(max_dv < 2e-5, "vertex diverged: {max_dv}");
    assert!(max_dc < 2e-5, "context diverged: {max_dc}");
}

#[test]
fn kernel_artifact_runs_and_is_finite() {
    let m = default_manifest().unwrap();
    let meta = m.find_kernel(512, 64).expect("kernel_n512_d64 artifact");
    let dev = KernelDevice::load(meta).unwrap();
    let n = meta.n;
    let d = meta.d;
    let u: Vec<f32> = (0..n * d).map(|i| ((i % 31) as f32 - 15.0) / 20.0).collect();
    let v: Vec<f32> = (0..n * d).map(|i| ((i % 37) as f32 - 18.0) / 20.0).collect();
    let label: Vec<f32> = (0..n).map(|i| (i % 2) as f32).collect();
    let weight: Vec<f32> = label.iter().map(|&l| if l > 0.0 { 1.0 } else { 5.0 }).collect();
    let (gu, gv, loss) = dev.run(&u, &v, &label, &weight).unwrap();
    assert_eq!(gu.len(), n * d);
    assert_eq!(gv.len(), n * d);
    assert_eq!(loss.len(), n);
    assert!(loss.iter().all(|x| x.is_finite() && *x >= 0.0));
    assert!(gu.iter().chain(&gv).all(|x| x.is_finite()));
    // semantics: -grad_u attracts for label=1, repels for label=0
    for i in (1..n).step_by(101) {
        let dot: f32 = (0..d).map(|j| -gu[i * d + j] * v[i * d + j]).sum();
        if label[i] > 0.0 {
            assert!(dot > 0.0, "positive pair {i} not attracted");
        } else {
            assert!(dot < 0.0, "negative pair {i} not repelled");
        }
    }
}

#[test]
fn padded_rows_receive_no_gradient() {
    // Rows >= the real partition size must stay bit-identical through a
    // train step (the coordinator relies on this when padding partitions
    // up to the artifact capacity P).
    let m = default_manifest().unwrap();
    let meta = m.find_train(100, 16).unwrap();
    let dev = Device::load(meta).unwrap();
    let (vertex, context, pos_u, pos_v, neg_v) = fixture(meta.p, meta.d, meta.s, meta.b, meta.k);
    // all fixture indices are < 100, so rows 100..256 are padding
    let (vl, cl) = dev.upload_partitions(&vertex, &context).unwrap();
    let (nv, nc, _) = dev.train_step(vl, cl, &pos_u, &pos_v, &neg_v, 0.025).unwrap();
    let (vh, ch) = dev.download_partitions(&nv, &nc).unwrap();
    let pad_start = 100 * meta.d;
    assert_eq!(&vh[pad_start..], &vertex[pad_start..], "vertex padding touched");
    assert_eq!(&ch[pad_start..], &context[pad_start..], "context padding touched");
}

#[test]
fn manifest_selects_smallest_sufficient_capacity() {
    let m = default_manifest().unwrap();
    assert_eq!(m.find_train(100, 16).unwrap().p, 256);
    assert_eq!(m.find_train(256, 16).unwrap().p, 256);
    assert_eq!(m.find_train(257, 64).unwrap().p, 4096);
    assert_eq!(m.find_train(5000, 64).unwrap().p, 16384);
    assert!(m.find_train(100, 999).is_err(), "no artifact for dim 999");
}

#[test]
fn zero_lr_train_step_is_identity() {
    let m = default_manifest().unwrap();
    let meta = m.find_train(100, 16).unwrap();
    let dev = Device::load(meta).unwrap();
    let (vertex, context, pos_u, pos_v, neg_v) = fixture(meta.p, meta.d, meta.s, meta.b, meta.k);
    let (vl, cl) = dev.upload_partitions(&vertex, &context).unwrap();
    let (nv, nc, loss) = dev.train_step(vl, cl, &pos_u, &pos_v, &neg_v, 0.0).unwrap();
    let (vh, ch) = dev.download_partitions(&nv, &nc).unwrap();
    assert_eq!(vh, vertex);
    assert_eq!(ch, context);
    assert!(loss > 0.0, "loss should still be computed: {loss}");
}
