//! Deterministic end-to-end regression guard for the coordinator /
//! scheduler: the same seed trained with `num_workers = 1` and
//! `num_workers = 2` must both produce embeddings whose link-prediction
//! (graph-reconstruction) AUC clears a fixed floor, and the two runs must
//! agree on quality. Silent corruption anywhere in the pipeline — block
//! routing, orthogonal scheduling, partition gather/scatter, the
//! residency caches — collapses the AUC to ~0.5 and trips this test long
//! before it would show up in timing.
//!
//! The AUC floor is an *empirical* gate, so it is swept over PINNED seeds
//! via [`graphvite::util::gate::seed_sweep`] and asserted on the pass
//! rate (ROADMAP "Flaky-threshold audit"): corruption collapses every
//! seed, one unlucky seed may dip. The per-seed `gate-sweep` line lands
//! in CI logs and the uploaded gate-sweep artifact — the evidence trail
//! for tightening the floor later.
//!
//! Reconstruction (observed edges vs non-edges, see
//! `eval::graph_reconstruction_auc`) rather than a held-out split: pure
//! Barabási–Albert graphs have near-zero clustering, so held-out cosine
//! AUC sits at chance regardless of trainer health (see the workload
//! notes in `rust/examples/link_prediction.rs` and `experiments/fig4.rs`).
//!
//! The backend comes from `GRAPHVITE_TEST_BACKEND` (CI's backend matrix)
//! and defaults to `native`.

use graphvite::config::{BackendKind, TrainConfig};
use graphvite::coordinator::Trainer;
use graphvite::embedding::EmbeddingStore;
use graphvite::eval::graph_reconstruction_auc;
use graphvite::graph::{generators, Graph};
use graphvite::pool::ShuffleKind;
use graphvite::util::gate::seed_sweep;

fn train_auc(graph: &Graph, num_workers: usize, seed: u64) -> f64 {
    let cfg = TrainConfig {
        dim: 16,
        epochs: 150,
        num_workers,
        num_samplers: num_workers,
        episode_size: 4_000,
        batch_size: 128,
        backend: BackendKind::test_backend(),
        shuffle: ShuffleKind::Pseudo,
        seed,
        ..TrainConfig::default()
    };
    let mut trainer = Trainer::new(graph.clone(), cfg).unwrap();
    let r = trainer.train().unwrap();
    assert!(
        r.embeddings.vertex_matrix().iter().all(|x| x.is_finite()),
        "{num_workers}-worker run produced non-finite embeddings"
    );
    assert!(
        r.stats.counters.samples_trained >= 150 * graph.num_edges() as u64,
        "{num_workers}-worker run under-trained: {} samples",
        r.stats.counters.samples_trained
    );
    graph_reconstruction_auc(&r.embeddings, graph, 0xA0C ^ seed)
}

// A healthy run reconstructs trained edges at AUC well above 0.8 while
// any corruption collapses to ~0.5, so the floor only needs to split
// those regimes. Tightened 0.65 -> 0.70 on accumulated gate-sweep
// evidence: the observed per-seed minimum sits comfortably above 0.8,
// so 0.70 still leaves a wide noise margin while catching softer
// degradations than the original floor could.
const AUC_FLOOR: f64 = 0.70;

#[test]
fn worker_counts_clear_auc_floor_and_agree() {
    let graph = generators::barabasi_albert(600, 3, 42);
    // score per seed = the worse of the 1-worker and 2-worker AUCs, so a
    // collapse in either parallelism regime fails that seed
    let stats = seed_sweep(&[7, 8, 9], |seed| {
        let auc_1 = train_auc(&graph, 1, seed);
        let auc_2 = train_auc(&graph, 2, seed);
        // Parallel negative sampling over orthogonal blocks must not cost
        // quality (paper Table 6): same sample budget and seed, so the
        // two AUCs land in the same band. Hard (non-empirical) check.
        assert!(
            (auc_1 - auc_2).abs() < 0.15,
            "seed {seed}: worker counts disagree: 1w {auc_1} vs 2w {auc_2}"
        );
        auc_1.min(auc_2)
    });
    eprintln!("{}", stats.report("regression.reconstruction_auc", AUC_FLOOR));
    // at least 2 of the 3 pinned seeds must clear the floor
    assert!(stats.pass_rate(AUC_FLOOR) >= 2.0 / 3.0, "{:?}", stats.scores);
}

#[test]
fn untrained_embeddings_sit_at_chance() {
    // Sanity-check the metric itself: random init must NOT clear the
    // floor, otherwise the regression test can't detect corruption.
    let graph = generators::barabasi_albert(600, 3, 42);
    let store = EmbeddingStore::init(graph.num_nodes(), 16, 1);
    let auc = graph_reconstruction_auc(&store, &graph, 3);
    assert!(
        (auc - 0.5).abs() < 0.1,
        "untrained AUC {auc} should be near chance"
    );
}
