//! Table 1 — memory cost of node embedding on the paper's running
//! example (50M nodes / 1B edges scale-free network, d=128).

use anyhow::Result;

use crate::metrics::memory::MemoryModel;
use crate::util::human_bytes;

pub fn run() -> Result<()> {
    let m = MemoryModel::paper_example();
    let mut t = m.table();
    t.title = "Table 1 — memory cost (paper example: 5e7 nodes, 1e9 edges, d=128)".into();
    t.print();
    // the paper's point: per-GPU cost after n-way partitioning
    for parts in [1u64, 2, 4, 8] {
        println!(
            "per-GPU resident set with {parts} partitions: {}",
            human_bytes(m.per_gpu_bytes(parts))
        );
    }
    println!(
        "\npaper reference values: nodes 191 MB, edges 7.45 GB, augmented 373 GB, \
         vertex/context 23.8 GB each"
    );
    Ok(())
}

#[cfg(test)]
mod tests {
    #[test]
    fn runs() {
        super::run().unwrap();
    }
}
