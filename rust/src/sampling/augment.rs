//! Parallel online augmentation (paper §3.1, Algorithm 2).
//!
//! Instead of materializing the augmented network E' (which is 1–2 orders
//! of magnitude larger than E — Table 1's 373 GB), edge samples are
//! generated on the fly: draw a departure node with p ∝ degree, random-walk
//! from it, and emit every node pair within augmentation distance `s`
//! along the walk as a positive sample.
//!
//! Each sampler thread owns an independent [`OnlineAugmenter`] (separate
//! RNG stream + walk buffer), making the stage embarrassingly parallel —
//! exactly Algorithm 2's "allocated with an independent sample pool".

use crate::graph::GraphStore;
use crate::sampling::{AliasTable, RandomWalker, WalkScratch};
use crate::util::rng::Rng;

/// Tunables of the augmentation stage.
#[derive(Debug, Clone, Copy)]
pub struct AugmentConfig {
    /// Random-walk length in edges (paper: 5 on YouTube, 2 on the dense
    /// networks, 40 as the general default in §4.3).
    pub walk_length: usize,
    /// Augmentation distance `s`: pairs (walk[i], walk[j]) with
    /// 1 <= j - i <= s become positive samples.
    pub augmentation_distance: usize,
}

impl Default for AugmentConfig {
    fn default() -> Self {
        AugmentConfig { walk_length: 5, augmentation_distance: 2 }
    }
}

/// Per-thread online augmentation engine.
pub struct OnlineAugmenter<'g> {
    walker: &'g RandomWalker<'g>,
    departure: &'g AliasTable,
    config: AugmentConfig,
    rng: Rng,
    walk_buf: Vec<u32>,
    /// Per-thread scratch for the walker's streaming path — neighbor
    /// list plus streamed alias columns (untouched when the graph store
    /// is resident).
    nbr_scratch: WalkScratch,
}

impl<'g> OnlineAugmenter<'g> {
    /// `departure` must be an alias table over node degrees and `walker`
    /// a walk engine over the same graph — both shared, built once by the
    /// coordinator. (An earlier version built the walker here; on
    /// weighted graphs that constructs |V| per-node alias tables per
    /// sampler thread per pool and dominated the profile — see
    /// EXPERIMENTS.md §Perf.)
    pub fn new(
        walker: &'g RandomWalker<'g>,
        departure: &'g AliasTable,
        config: AugmentConfig,
        rng: Rng,
    ) -> Self {
        assert!(config.walk_length >= 1);
        assert!(config.augmentation_distance >= 1);
        OnlineAugmenter {
            walker,
            departure,
            config,
            rng,
            walk_buf: Vec::with_capacity(config.walk_length + 1),
            nbr_scratch: WalkScratch::new(),
        }
    }

    /// Build the shared departure-node distribution (p ∝ weighted degree).
    pub fn departure_table(graph: &dyn GraphStore) -> AliasTable {
        AliasTable::new(graph.weighted_degrees())
    }

    /// Run one walk and append its augmented edge samples to `out`.
    /// Returns the number of samples emitted.
    pub fn fill_from_one_walk(&mut self, out: &mut Vec<(u32, u32)>) -> usize {
        let start = self.departure.sample(&mut self.rng);
        let cfg = self.config;
        let len = self.walker.walk_into(
            start,
            cfg.walk_length,
            &mut self.rng,
            &mut self.walk_buf,
            &mut self.nbr_scratch,
        );
        let before = out.len();
        for i in 0..len {
            let upper = (i + cfg.augmentation_distance).min(len - 1);
            for j in (i + 1)..=upper {
                // a walk can revisit a node within the window (cycles);
                // (u, u) pairs carry no gradient signal, skip them
                if self.walk_buf[i] != self.walk_buf[j] {
                    out.push((self.walk_buf[i], self.walk_buf[j]));
                }
            }
        }
        out.len() - before
    }

    /// Emit samples until `out` reaches `target` length (Algorithm 2's
    /// "while pool is not full").
    pub fn fill(&mut self, out: &mut Vec<(u32, u32)>, target: usize) {
        while out.len() < target {
            let emitted = self.fill_from_one_walk(out);
            if emitted == 0 {
                // isolated departure node: keep going, another departure
                // will produce samples (graphs of interest are not all
                // isolated nodes — the departure table is degree-weighted
                // so isolated nodes have zero probability).
                continue;
            }
        }
        out.truncate(target);
    }

    /// Expected number of samples per walk: sum over positions of the
    /// clipped distance window. Exact for full-length walks.
    pub fn samples_per_walk(config: &AugmentConfig) -> usize {
        let l = config.walk_length + 1; // nodes in the walk
        let s = config.augmentation_distance;
        (0..l).map(|i| ((i + s).min(l - 1)).saturating_sub(i)).sum()
    }

    /// The augmentation ratio |E'| / |E| this config implies — the factor
    /// in Table 1's "augmented edges" row.
    pub fn augmentation_ratio(config: &AugmentConfig) -> f64 {
        Self::samples_per_walk(config) as f64 / config.walk_length as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;

    fn setup(cfg: AugmentConfig) -> (crate::graph::Graph, AliasTable) {
        let g = generators::karate_club();
        let t = OnlineAugmenter::departure_table(&g);
        let _ = cfg;
        (g, t)
    }

    // tests construct a walker in place of the coordinator's shared one
    macro_rules! walker {
        ($g:expr) => {
            RandomWalker::new(&$g)
        };
    }

    #[test]
    fn samples_are_within_distance() {
        let cfg = AugmentConfig { walk_length: 10, augmentation_distance: 3 };
        let (g, t) = setup(cfg);
        let w = walker!(g);
        let mut aug = OnlineAugmenter::new(&w, &t, cfg, Rng::new(1));
        let mut out = Vec::new();
        aug.fill(&mut out, 5_000);
        assert_eq!(out.len(), 5_000);
        // each sample must be a pair of nodes at walk distance <= 3; at
        // minimum both endpoints are valid node ids
        for &(u, v) in &out {
            assert!((u as usize) < g.num_nodes());
            assert!((v as usize) < g.num_nodes());
        }
    }

    #[test]
    fn distance_one_equals_walk_edges() {
        // s=1 emits exactly consecutive walk pairs => all true edges
        let cfg = AugmentConfig { walk_length: 8, augmentation_distance: 1 };
        let (g, t) = setup(cfg);
        let w = walker!(g);
        let mut aug = OnlineAugmenter::new(&w, &t, cfg, Rng::new(2));
        let mut out = Vec::new();
        aug.fill(&mut out, 2_000);
        for &(u, v) in &out {
            assert!(g.has_edge(u, v), "{u}->{v} must be a real edge at s=1");
        }
    }

    #[test]
    fn samples_per_walk_formula() {
        // walk of 4 edges (5 nodes), s=2: i=0:2, i=1:2, i=2:2, i=3:1, i=4:0 = 7
        let cfg = AugmentConfig { walk_length: 4, augmentation_distance: 2 };
        assert_eq!(OnlineAugmenter::samples_per_walk(&cfg), 7);
        // s=1: one pair per edge
        let cfg1 = AugmentConfig { walk_length: 4, augmentation_distance: 1 };
        assert_eq!(OnlineAugmenter::samples_per_walk(&cfg1), 4);
        assert!((OnlineAugmenter::augmentation_ratio(&cfg1) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn departure_is_degree_weighted() {
        let (g, t) = setup(AugmentConfig::default());
        let mut rng = Rng::new(3);
        let mut counts = vec![0usize; g.num_nodes()];
        const N: usize = 100_000;
        for _ in 0..N {
            counts[t.sample(&mut rng) as usize] += 1;
        }
        // node 33 has the highest degree (17) and must be sampled most
        let argmax = counts
            .iter()
            .enumerate()
            .max_by_key(|&(_, c)| *c)
            .unwrap()
            .0;
        assert_eq!(argmax, 33);
    }

    #[test]
    fn deterministic_given_seed() {
        let cfg = AugmentConfig::default();
        let (g, t) = setup(cfg);
        let w = walker!(g);
        let mut a = OnlineAugmenter::new(&w, &t, cfg, Rng::new(9));
        let mut b = OnlineAugmenter::new(&w, &t, cfg, Rng::new(9));
        let (mut oa, mut ob) = (Vec::new(), Vec::new());
        a.fill(&mut oa, 1000);
        b.fill(&mut ob, 1000);
        assert_eq!(oa, ob);
    }
}
