//! [`TrainConfigBuilder`]: layered construction of a [`TrainConfig`]
//! with per-field provenance.
//!
//! A config is assembled from three layers — defaults ← TOML ← CLI —
//! and every field remembers which layer last set it. Validation then
//! happens *once*, over the final value set, and a failed check reports
//! where the offending value came from: `worker_capacities has 1
//! entries but num_workers is 2 (worker_capacities from --capacities)`
//! reads very differently from `(worker_capacities from config.toml)`.
//!
//! The field set and the TOML keys are exactly [`TrainConfig`]'s — this
//! module adds bookkeeping, not surface.

use std::collections::BTreeMap;

use anyhow::{bail, Result};

use crate::graph::GraphFormat;
use crate::pool::ShuffleKind;

use super::{parse_toml, BackendKind, TomlValue, TrainConfig, WorkerMode};

/// Every TOML-keyed field of [`TrainConfig`], in declaration order.
/// `value_of`/`set_str` accept exactly these keys; the CLI spec's
/// round-trip test walks this list.
pub const KEYS: &[&str] = &[
    "dim",
    "epochs",
    "lr",
    "negatives",
    "neg_weight",
    "walk_length",
    "augmentation_distance",
    "num_workers",
    "worker_capacities",
    "num_partitions",
    "num_samplers",
    "episode_size",
    "shuffle",
    "backend",
    "collaboration",
    "online_augmentation",
    "fix_context",
    "pipeline_transfers",
    "residency",
    "graph_format",
    "graph_cache_bytes",
    "batch_size",
    "seed",
    "log_every",
    "workers",
    "worker_timeout_secs",
    "heartbeat_secs",
    "max_worker_retries",
    "rejoin_window_secs",
    "wire_compression",
];

/// Builder for [`TrainConfig`]: construction (layered, unvalidated)
/// split from validation ([`Self::build`]).
pub struct TrainConfigBuilder {
    cfg: TrainConfig,
    sources: BTreeMap<&'static str, String>,
}

impl Default for TrainConfigBuilder {
    fn default() -> Self {
        Self::new()
    }
}

impl TrainConfigBuilder {
    /// Start from [`TrainConfig::default`]; every field's provenance is
    /// `"default"` until a layer overrides it.
    pub fn new() -> Self {
        TrainConfigBuilder { cfg: TrainConfig::default(), sources: BTreeMap::new() }
    }

    /// Where `field`'s current value came from.
    pub fn source_of(&self, field: &str) -> &str {
        self.sources.get(field).map(String::as_str).unwrap_or("default")
    }

    /// Read access to the accumulated (unvalidated) config.
    pub fn config(&self) -> &TrainConfig {
        &self.cfg
    }

    /// Validate the accumulated config. A failed check names the field
    /// *and* the layer that set it.
    pub fn build(&self) -> Result<TrainConfig> {
        if let Err(e) = self.cfg.validate_fields() {
            bail!("{} ({} from {})", e.message, e.field, self.source_of(e.field));
        }
        Ok(self.cfg.clone())
    }

    /// Canonicalize a key (interned so provenance keys are `'static`).
    fn intern(key: &str) -> Result<&'static str> {
        KEYS.iter().find(|&&k| k == key).copied().ok_or_else(|| {
            anyhow::anyhow!("unknown config key '{key}' (expected one of: {})", KEYS.join(", "))
        })
    }

    /// Apply one TOML file's `[train]` table on top of the current
    /// layers, recording `origin` (e.g. the file name) as the source of
    /// every key it sets. Unknown keys are ignored (forward
    /// compatibility, matching the historical loader).
    pub fn apply_toml_str(&mut self, text: &str, origin: &str) -> Result<&mut Self> {
        let doc = parse_toml(text)?;
        let get = |key: &str| -> Option<&TomlValue> {
            doc.get(&format!("train.{key}")).or_else(|| doc.get(key))
        };
        let cfg = &mut self.cfg;
        let mut touched: Vec<&'static str> = Vec::new();
        macro_rules! set_num {
            ($field:ident, $key:expr, $ty:ty) => {
                if let Some(v) = get($key) {
                    cfg.$field = v
                        .as_f64()
                        .ok_or_else(|| anyhow::anyhow!(concat!($key, " must be a number")))?
                        as $ty;
                    touched.push($key);
                }
            };
        }
        set_num!(dim, "dim", usize);
        set_num!(epochs, "epochs", usize);
        set_num!(lr, "lr", f32);
        set_num!(negatives, "negatives", usize);
        set_num!(neg_weight, "neg_weight", f32);
        set_num!(walk_length, "walk_length", usize);
        set_num!(augmentation_distance, "augmentation_distance", usize);
        set_num!(num_workers, "num_workers", usize);
        set_num!(num_partitions, "num_partitions", usize);
        if let Some(v) = get("worker_capacities") {
            let arr = v.as_array().ok_or_else(|| {
                anyhow::anyhow!("worker_capacities must be an array of positive integers")
            })?;
            cfg.worker_capacities = arr
                .iter()
                .map(|e| {
                    e.as_i64().filter(|&c| c > 0).map(|c| c as usize).ok_or_else(|| {
                        anyhow::anyhow!(
                            "worker_capacities entries must be positive integers, got {e:?}"
                        )
                    })
                })
                .collect::<Result<Vec<_>>>()?;
            touched.push("worker_capacities");
        }
        set_num!(num_samplers, "num_samplers", usize);
        set_num!(episode_size, "episode_size", usize);
        set_num!(graph_cache_bytes, "graph_cache_bytes", usize);
        set_num!(batch_size, "batch_size", usize);
        set_num!(seed, "seed", u64);
        set_num!(log_every, "log_every", usize);
        set_num!(worker_timeout_secs, "worker_timeout_secs", u64);
        set_num!(heartbeat_secs, "heartbeat_secs", u64);
        set_num!(max_worker_retries, "max_worker_retries", u64);
        set_num!(rejoin_window_secs, "rejoin_window_secs", u64);
        if let Some(v) = get("workers") {
            let s = v.as_str().ok_or_else(|| anyhow::anyhow!("workers must be a string"))?;
            cfg.worker_mode = WorkerMode::parse(s)?;
            touched.push("workers");
        }
        if let Some(v) = get("shuffle") {
            let s = v.as_str().ok_or_else(|| anyhow::anyhow!("shuffle must be a string"))?;
            cfg.shuffle =
                ShuffleKind::parse(s).ok_or_else(|| anyhow::anyhow!("unknown shuffle '{s}'"))?;
            touched.push("shuffle");
        }
        if let Some(v) = get("backend") {
            let s = v.as_str().ok_or_else(|| anyhow::anyhow!("backend must be a string"))?;
            cfg.backend = BackendKind::parse(s).ok_or_else(|| {
                anyhow::anyhow!(
                    "unknown backend '{s}' (expected one of: {})",
                    BackendKind::names_joined()
                )
            })?;
            touched.push("backend");
        }
        if let Some(v) = get("graph_format") {
            let s =
                v.as_str().ok_or_else(|| anyhow::anyhow!("graph_format must be a string"))?;
            cfg.graph_format = GraphFormat::parse_or_err(s)?;
            touched.push("graph_format");
        }
        macro_rules! set_bool {
            ($field:ident, $key:expr) => {
                if let Some(v) = get($key) {
                    cfg.$field = v
                        .as_bool()
                        .ok_or_else(|| anyhow::anyhow!(concat!($key, " must be a bool")))?;
                    touched.push($key);
                }
            };
        }
        set_bool!(collaboration, "collaboration");
        set_bool!(online_augmentation, "online_augmentation");
        set_bool!(fix_context, "fix_context");
        set_bool!(pipeline_transfers, "pipeline_transfers");
        set_bool!(residency, "residency");
        set_bool!(wire_compression, "wire_compression");
        for key in touched {
            self.sources.insert(key, origin.to_string());
        }
        Ok(self)
    }

    /// Set one field from its CLI string spelling, recording `source`
    /// (the flag, e.g. `"--dim"`). The key set is [`KEYS`] — the same
    /// names the TOML layer uses.
    pub fn set_str(&mut self, key: &str, value: &str, source: &str) -> Result<&mut Self> {
        let key = Self::intern(key)?;
        let cfg = &mut self.cfg;
        macro_rules! num {
            ($ty:ty) => {
                value.parse::<$ty>().map_err(|_| {
                    anyhow::anyhow!("{key}: cannot parse '{value}' (from {source})")
                })?
            };
        }
        let parse_bool = || match value {
            "true" | "1" => Ok(true),
            "false" | "0" => Ok(false),
            _ => bail!("{key}: cannot parse '{value}' as a bool (from {source})"),
        };
        match key {
            "dim" => cfg.dim = num!(usize),
            "epochs" => cfg.epochs = num!(usize),
            "lr" => cfg.lr = num!(f32),
            "negatives" => cfg.negatives = num!(usize),
            "neg_weight" => cfg.neg_weight = num!(f32),
            "walk_length" => cfg.walk_length = num!(usize),
            "augmentation_distance" => cfg.augmentation_distance = num!(usize),
            "num_workers" => cfg.num_workers = num!(usize),
            "worker_capacities" => {
                cfg.worker_capacities = TrainConfig::parse_capacity_list(value)?
            }
            "num_partitions" => cfg.num_partitions = num!(usize),
            "num_samplers" => cfg.num_samplers = num!(usize),
            "episode_size" => cfg.episode_size = num!(usize),
            "shuffle" => {
                cfg.shuffle = ShuffleKind::parse(value)
                    .ok_or_else(|| anyhow::anyhow!("unknown shuffle '{value}' (from {source})"))?
            }
            "backend" => {
                cfg.backend = BackendKind::parse(value).ok_or_else(|| {
                    anyhow::anyhow!(
                        "unknown backend '{value}' (expected one of: {}; from {source})",
                        BackendKind::names_joined()
                    )
                })?
            }
            "collaboration" => cfg.collaboration = parse_bool()?,
            "online_augmentation" => cfg.online_augmentation = parse_bool()?,
            "fix_context" => cfg.fix_context = parse_bool()?,
            "pipeline_transfers" => cfg.pipeline_transfers = parse_bool()?,
            "residency" => cfg.residency = parse_bool()?,
            "graph_format" => cfg.graph_format = GraphFormat::parse_or_err(value)?,
            "graph_cache_bytes" => cfg.graph_cache_bytes = num!(usize),
            "batch_size" => cfg.batch_size = num!(usize),
            "seed" => cfg.seed = num!(u64),
            "log_every" => cfg.log_every = num!(usize),
            "workers" => cfg.worker_mode = WorkerMode::parse(value)?,
            "worker_timeout_secs" => cfg.worker_timeout_secs = num!(u64),
            "heartbeat_secs" => cfg.heartbeat_secs = num!(u64),
            "max_worker_retries" => cfg.max_worker_retries = num!(u64),
            "rejoin_window_secs" => cfg.rejoin_window_secs = num!(u64),
            "wire_compression" => cfg.wire_compression = parse_bool()?,
            _ => unreachable!("intern() vetted the key"),
        }
        self.sources.insert(key, source.to_string());
        Ok(self)
    }

    /// The current value of `key`, rendered in the spelling
    /// [`Self::set_str`] accepts — so `set_str(k, value_of(k))` is a
    /// fixpoint. This is what the CLI round-trip property test drives.
    pub fn value_of(&self, key: &str) -> Result<String> {
        let key = Self::intern(key)?;
        let cfg = &self.cfg;
        Ok(match key {
            "dim" => cfg.dim.to_string(),
            "epochs" => cfg.epochs.to_string(),
            "lr" => cfg.lr.to_string(),
            "negatives" => cfg.negatives.to_string(),
            "neg_weight" => cfg.neg_weight.to_string(),
            "walk_length" => cfg.walk_length.to_string(),
            "augmentation_distance" => cfg.augmentation_distance.to_string(),
            "num_workers" => cfg.num_workers.to_string(),
            "worker_capacities" => cfg
                .worker_capacities
                .iter()
                .map(|c| c.to_string())
                .collect::<Vec<_>>()
                .join(","),
            "num_partitions" => cfg.num_partitions.to_string(),
            "num_samplers" => cfg.num_samplers.to_string(),
            "episode_size" => cfg.episode_size.to_string(),
            "shuffle" => cfg.shuffle.name().to_string(),
            "backend" => cfg.backend.name().to_string(),
            "collaboration" => cfg.collaboration.to_string(),
            "online_augmentation" => cfg.online_augmentation.to_string(),
            "fix_context" => cfg.fix_context.to_string(),
            "pipeline_transfers" => cfg.pipeline_transfers.to_string(),
            "residency" => cfg.residency.to_string(),
            "graph_format" => cfg.graph_format.name().to_string(),
            "graph_cache_bytes" => cfg.graph_cache_bytes.to_string(),
            "batch_size" => cfg.batch_size.to_string(),
            "seed" => cfg.seed.to_string(),
            "log_every" => cfg.log_every.to_string(),
            "workers" => cfg.worker_mode.spelling(),
            "worker_timeout_secs" => cfg.worker_timeout_secs.to_string(),
            "heartbeat_secs" => cfg.heartbeat_secs.to_string(),
            "max_worker_retries" => cfg.max_worker_retries.to_string(),
            "rejoin_window_secs" => cfg.rejoin_window_secs.to_string(),
            "wire_compression" => cfg.wire_compression.to_string(),
            _ => unreachable!("intern() vetted the key"),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn layers_stack_and_track_provenance() {
        let mut b = TrainConfigBuilder::new();
        assert_eq!(b.source_of("dim"), "default");
        b.apply_toml_str("[train]\ndim = 32\nepochs = 3\n", "config.toml").unwrap();
        b.set_str("dim", "48", "--dim").unwrap();
        assert_eq!(b.source_of("dim"), "--dim", "CLI overrides TOML");
        assert_eq!(b.source_of("epochs"), "config.toml");
        assert_eq!(b.source_of("lr"), "default");
        let cfg = b.build().unwrap();
        assert_eq!(cfg.dim, 48);
        assert_eq!(cfg.epochs, 3);
    }

    #[test]
    fn validation_errors_name_the_layer() {
        // bad value from the CLI layer
        let mut b = TrainConfigBuilder::new();
        b.set_str("num_workers", "2", "--workers").unwrap();
        b.set_str("worker_capacities", "1", "--capacities").unwrap();
        let err = b.build().unwrap_err().to_string();
        assert!(err.contains("worker_capacities from --capacities"), "{err}");
        // the same bad value from a config file names the file instead
        let mut b = TrainConfigBuilder::new();
        b.apply_toml_str("num_workers = 2\nworker_capacities = [1]\n", "bad.toml").unwrap();
        let err = b.build().unwrap_err().to_string();
        assert!(err.contains("worker_capacities from bad.toml"), "{err}");
        // an invariant violated by untouched defaults says so
        let mut b = TrainConfigBuilder::new();
        b.set_str("dim", "0", "--dim").unwrap();
        let err = b.build().unwrap_err().to_string();
        assert!(err.contains("dim from --dim"), "{err}");
    }

    #[test]
    fn set_str_rejects_unknown_keys_and_bad_values() {
        let mut b = TrainConfigBuilder::new();
        let err = b.set_str("dimension", "64", "--dimension").unwrap_err().to_string();
        assert!(err.contains("unknown config key 'dimension'"), "{err}");
        let err = b.set_str("dim", "big", "--dim").unwrap_err().to_string();
        assert!(err.contains("'big'") && err.contains("--dim"), "{err}");
        let err = b.set_str("wire_compression", "maybe", "--wire-compression").unwrap_err();
        assert!(err.to_string().contains("bool"), "{err}");
    }

    #[test]
    fn every_key_round_trips_through_its_string_spelling() {
        // give list/mode keys non-default values so the spellings are
        // non-trivial, then check set_str(value_of(k)) is a fixpoint
        let mut b = TrainConfigBuilder::new();
        b.set_str("num_workers", "2", "t").unwrap();
        b.set_str("worker_capacities", "1,3", "t").unwrap();
        b.set_str("workers", "tcp://127.0.0.1:7077", "t").unwrap();
        b.set_str("wire_compression", "false", "t").unwrap();
        for &key in KEYS {
            let v = b.value_of(key).unwrap();
            let mut b2 = TrainConfigBuilder::new();
            if !v.is_empty() {
                b2.set_str(key, &v, "t").unwrap();
            }
            assert_eq!(b2.value_of(key).unwrap(), v, "key '{key}' drifts through {v:?}");
        }
    }

    #[test]
    fn wire_compression_defaults_on_and_parses() {
        assert!(TrainConfig::default().wire_compression);
        let cfg = TrainConfig::from_toml_str("[train]\nwire_compression = false\n").unwrap();
        assert!(!cfg.wire_compression);
        assert!(TrainConfig::from_toml_str("wire_compression = 3\n").is_err());
    }
}
