//! Sampling substrate: alias tables, random walks, GraphVite's parallel
//! online augmentation (paper §3.1) and the restricted negative sampler
//! (paper §3.2).

mod alias;
mod augment;
mod edge;
mod negative;
mod walk;

pub use alias::AliasTable;
pub use augment::{AugmentConfig, OnlineAugmenter};
pub use edge::EdgeSampler;
pub use negative::NegativeSampler;
pub use walk::RandomWalker;
