//! Experiment harnesses: one module per table/figure of the paper's
//! evaluation (the per-experiment index lives in DESIGN.md). Each
//! harness builds its workload, runs the systems, and prints a markdown
//! table matching the paper's layout. The `graphvite exp <name>` CLI and
//! `rust/benches/bench_*.rs` targets call into these.

pub mod fig4;
pub mod fig5;
pub mod fig6;
pub mod presets;
pub mod table1;
pub mod table3;
pub mod table4;
pub mod table5;
pub mod table6;
pub mod table7;
pub mod table8;

pub use presets::{classify, Scale, Workload};

use anyhow::Result;

/// Run an experiment by paper id. `scale` shrinks workloads for CI.
pub fn run(name: &str, scale: Scale) -> Result<()> {
    match name {
        "table1" => table1::run(),
        "table3" => table3::run(scale),
        "table4" => table4::run(scale),
        "table5" => table5::run(scale),
        "table6" => table6::run(scale),
        "table7" => table7::run(scale),
        "table8" => table8::run(scale),
        "fig4" => fig4::run(scale),
        "fig5" => fig5::run(scale),
        "fig6" => fig6::run(scale),
        "all" => {
            for n in [
                "table1", "table3", "table4", "table5", "table6", "table7", "table8",
                "fig4", "fig5", "fig6",
            ] {
                run(n, scale)?;
            }
            Ok(())
        }
        _ => anyhow::bail!(
            "unknown experiment '{name}' (try table1|table3|table4|table5|table6|table7|table8|fig4|fig5|fig6|all)"
        ),
    }
}
