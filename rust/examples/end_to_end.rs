//! End-to-end driver — proves all layers compose on a real workload.
//!
//! Builds the YouTube-substitute graph (scale-free + planted communities),
//! trains node embeddings through the **full three-layer path** (rust
//! coordinator → PJRT → AOT-compiled JAX scan → Pallas SGNS kernel) with
//! parallel online augmentation, pseudo shuffle, parallel negative
//! sampling over 4 simulated GPUs and the double-buffered collaboration
//! strategy; logs the loss curve; evaluates node classification and link
//! prediction; and runs the LINE baseline for the paper's headline
//! speed/quality comparison. Results are recorded in EXPERIMENTS.md.
//!
//!     cargo run --release --example end_to_end [nodes]

use graphvite::baselines::line::LineConfig;
use graphvite::baselines::LineBaseline;
use graphvite::coordinator::Trainer;
use graphvite::eval::{link_prediction_auc, LinkSplit};
use graphvite::experiments::classify;
use graphvite::prelude::*;
use graphvite::util::{human_bytes, human_secs};

fn main() -> anyhow::Result<()> {
    let nodes: usize = std::env::args()
        .nth(1)
        .map(|s| s.parse())
        .transpose()?
        .unwrap_or(10_000);
    let num_labels = 10;

    println!("=== GraphVite end-to-end driver ===");
    let graph = generators::youtube_like(nodes, num_labels, 0xCAFE);
    println!(
        "workload: youtube-like, {} nodes, {} edges, {} classes",
        graph.num_nodes(),
        graph.num_edges(),
        num_labels
    );

    // hold out edges for link prediction up front, train on the rest
    let split = LinkSplit::new(&graph, 0.005, 11);
    let train_graph = split.train_graph.clone();

    let config = TrainConfig {
        dim: 32,
        epochs: 200,
        num_workers: 4,
        num_samplers: 4,
        episode_size: (nodes / 2).max(4_000),
        backend: BackendKind::best_available(), // full L3→L2→L1 path under --features pjrt
        shuffle: ShuffleKind::Pseudo,
        collaboration: true,
        online_augmentation: true,
        fix_context: true,
        ..TrainConfig::default()
    };
    println!(
        "config: dim={} epochs={} workers={} samplers={} backend={}",
        config.dim,
        config.epochs,
        config.num_workers,
        config.num_samplers,
        config.backend.name()
    );

    // ---- train with performance-curve checkpoints (Fig 4 shape) ----
    let total_budget = (config.epochs * train_graph.num_edges()) as u64;
    let checkpoint_stride = total_budget / 12; // ~12 points on the curve
    let mut trainer = Trainer::new(train_graph.clone(), config)?;
    let mut curve: Vec<(u64, f64)> = Vec::new();
    let mut next_ckpt = checkpoint_stride;
    let mut cb = |done: u64, store: &graphvite::embedding::EmbeddingStore| {
        if done >= next_ckpt {
            next_ckpt += checkpoint_stride;
            let report = classify(store, &train_graph, 0.02, 13);
            curve.push((done, report.micro_f1));
        }
    };
    let result = trainer.train_with_callback(Some(&mut cb))?;
    let s = &result.stats;

    println!("\n--- training ---");
    println!(
        "GraphVite (4 workers): {} trained in {} ({:.2}M samples/s)",
        s.counters.samples_trained,
        human_secs(s.train_secs),
        s.throughput() / 1e6
    );
    println!(
        "bus transfers: {} up / {} down across {} episodes, {} device steps",
        human_bytes(s.counters.bytes_to_device),
        human_bytes(s.counters.bytes_from_device),
        s.counters.episodes,
        s.counters.device_steps
    );
    println!("loss curve (per-episode mean SGNS loss):");
    let stride = (s.loss_curve.len() / 10).max(1);
    for (i, l) in s.loss_curve.iter().enumerate().step_by(stride) {
        println!("  episode {i:>4}: {l:.4}");
    }
    println!("performance curve (micro-F1 @ 2% labels vs samples):");
    for (done, f1) in &curve {
        println!("  {done:>9} samples: micro-F1 {:.2}%", 100.0 * f1);
    }

    // ---- evaluation ----
    println!("\n--- evaluation ---");
    let report = classify(&result.embeddings, &train_graph, 0.02, 17);
    println!(
        "node classification @2% labels: micro-F1 {:.2}%  macro-F1 {:.2}%  (chance = {:.1}%)",
        100.0 * report.micro_f1,
        100.0 * report.macro_f1,
        100.0 / num_labels as f64
    );
    let auc = link_prediction_auc(&result.embeddings, &split);
    println!("link prediction AUC: {auc:.4}  (paper: 0.943 on Hyperlink-PLD)");

    // ---- LINE baseline (the paper's speed denominator) ----
    println!("\n--- LINE baseline (CPU hogwild) ---");
    let line_cfg = LineConfig {
        dim: 32,
        epochs: 200,
        threads: 8,
        ..LineConfig::default()
    };
    let line = LineBaseline::train(&train_graph, &line_cfg)?;
    let line_report = classify(&line.embeddings, &train_graph, 0.02, 17);
    println!(
        "LINE: trained in {} — micro-F1 {:.2}% macro-F1 {:.2}%",
        human_secs(line.stats.train_secs),
        100.0 * line_report.micro_f1,
        100.0 * line_report.macro_f1
    );
    println!(
        "GraphVite/LINE wall-clock ratio: {:.2}x (same sample budget; see EXPERIMENTS.md for context)",
        line.stats.train_secs / s.train_secs.max(1e-9)
    );

    // Sanity gates. AUC: held-out edges mix community edges (predictable
    // by cosine) with preferential-attachment edges (no homophily, ~0.5),
    // so the ceiling on this synthetic graph sits near ~0.75, not the
    // paper's 0.943 on the strongly local Hyperlink-PLD web graph.
    anyhow::ensure!(report.micro_f1 > 3.0 / num_labels as f64, "F1 below sanity line");
    anyhow::ensure!(auc > 0.6, "AUC below sanity line");
    println!("\nend_to_end OK");
    Ok(())
}
