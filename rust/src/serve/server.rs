//! The `graphvite serve` TCP server: accept loop, per-connection handler
//! threads, a shared read-locked [`AnnIndex`], and an optional hot-reload
//! watcher.
//!
//! Hot reload closes the train→serve loop: training rewrites the `.gvemb`
//! output atomically (tmp + rename) at every checkpoint, the watcher
//! polls the file's metadata, and on change rebuilds the index off the
//! lock and swaps it in under a short write lock — in-flight queries
//! finish on the old index, the next query sees the new generation. A
//! file that fails to load (e.g. a corrupt partial copy) is logged and
//! skipped; the server keeps answering from the previous index.

use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, RwLock};
use std::thread::JoinHandle;
use std::time::{Duration, SystemTime};

use anyhow::{Context, Result};

use crate::embedding::load_embeddings_auto;

use super::index::{AnnIndex, IndexConfig};
use super::protocol::{
    decode_request, encode_response, read_frame, write_frame, Request, Response,
};

/// Server options (`graphvite serve` flags map onto these).
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Bind address, e.g. `127.0.0.1:7654` (port 0 = ephemeral).
    pub addr: String,
    pub index: IndexConfig,
    /// Watch the embedding file and hot-reload on change.
    pub watch: bool,
    /// Watcher poll interval.
    pub poll_ms: u64,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            addr: "127.0.0.1:7654".to_string(),
            index: IndexConfig::default(),
            watch: false,
            poll_ms: 500,
        }
    }
}

/// The swappable serving state: index + reload generation.
struct Loaded {
    index: AnnIndex,
    generation: u64,
}

struct Shared {
    state: RwLock<Loaded>,
    shutdown: AtomicBool,
    default_nprobe: usize,
}

/// A running server. Bind with [`Server::start`]; block on
/// [`Server::run`] (the CLI path) or keep the handle and call
/// [`Server::shutdown`] (tests).
pub struct Server {
    addr: SocketAddr,
    shared: Arc<Shared>,
    accept: JoinHandle<()>,
    watcher: Option<JoinHandle<()>>,
}

impl Server {
    /// Load `path`, build the index, bind, and start accepting.
    pub fn start(path: &str, cfg: ServeConfig) -> Result<Server> {
        let store = load_embeddings_auto(path)?;
        let index = AnnIndex::build(&store, &cfg.index);
        eprintln!(
            "serve: loaded {} ({} nodes, dim {}), ivf nlist={} nprobe={}",
            path,
            index.num_nodes(),
            index.dim(),
            index.nlist(),
            index.nprobe()
        );
        let default_nprobe = index.nprobe();
        let shared = Arc::new(Shared {
            state: RwLock::new(Loaded { index, generation: 1 }),
            shutdown: AtomicBool::new(false),
            default_nprobe,
        });

        let listener = TcpListener::bind(&cfg.addr)
            .with_context(|| format!("bind {}", cfg.addr))?;
        let addr = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        eprintln!("serve: listening on {addr}");

        let accept = {
            let shared = Arc::clone(&shared);
            std::thread::spawn(move || accept_loop(listener, shared))
        };
        let watcher = if cfg.watch {
            let shared = Arc::clone(&shared);
            let path = PathBuf::from(path);
            let index_cfg = cfg.index.clone();
            let poll = Duration::from_millis(cfg.poll_ms.max(10));
            Some(std::thread::spawn(move || watch_loop(path, index_cfg, poll, shared)))
        } else {
            None
        };
        Ok(Server { addr, shared, accept, watcher })
    }

    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// Current hot-reload generation (1 = the initial load).
    pub fn generation(&self) -> u64 {
        self.shared.state.read().unwrap().generation
    }

    /// Block until shutdown is requested (the CLI foreground path).
    pub fn run(self) -> Result<()> {
        self.accept.join().map_err(|_| anyhow::anyhow!("accept loop panicked"))?;
        if let Some(w) = self.watcher {
            w.join().map_err(|_| anyhow::anyhow!("watcher panicked"))?;
        }
        Ok(())
    }

    /// Stop accepting and join the service threads (open connections are
    /// served until their peers hang up).
    pub fn shutdown(self) -> Result<()> {
        self.shared.shutdown.store(true, Ordering::SeqCst);
        self.run()
    }
}

fn accept_loop(listener: TcpListener, shared: Arc<Shared>) {
    loop {
        if shared.shutdown.load(Ordering::SeqCst) {
            return;
        }
        match listener.accept() {
            Ok((stream, peer)) => {
                let shared = Arc::clone(&shared);
                std::thread::spawn(move || {
                    if let Err(e) = handle_connection(stream, &shared) {
                        eprintln!("serve: connection {peer}: {e}");
                    }
                });
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(10));
            }
            Err(e) => {
                eprintln!("serve: accept error: {e}");
                return;
            }
        }
    }
}

fn handle_connection(stream: TcpStream, shared: &Shared) -> Result<()> {
    stream.set_nonblocking(false)?;
    let mut reader = std::io::BufReader::new(stream.try_clone()?);
    let mut writer = std::io::BufWriter::new(stream);
    while let Some(payload) = read_frame(&mut reader)? {
        // a malformed request answers with an error frame, not a drop —
        // the client sees *why* (fail loud on both sides of the wire)
        let resp = match decode_request(&payload) {
            Ok(req) => answer(&req, shared),
            Err(e) => Response::Error(format!("bad request: {e}")),
        };
        write_frame(&mut writer, &encode_response(&resp))?;
    }
    Ok(())
}

fn answer(req: &Request, shared: &Shared) -> Response {
    let state = shared.state.read().unwrap();
    match req {
        Request::Info => Response::Info {
            num_nodes: state.index.num_nodes() as u64,
            dim: state.index.dim() as u32,
            generation: state.generation,
        },
        Request::TopK { k, nodes } => {
            let n = state.index.num_nodes() as u32;
            if let Some(&bad) = nodes.iter().find(|&&v| v >= n) {
                return Response::Error(format!("node {bad} out of range (index has {n} nodes)"));
            }
            let results = nodes
                .iter()
                .map(|&v| state.index.search_node(v, *k, shared.default_nprobe))
                .collect();
            Response::TopK { results }
        }
    }
}

/// Poll the embedding file; on any metadata change, rebuild and swap.
fn watch_loop(path: PathBuf, cfg: IndexConfig, poll: Duration, shared: Arc<Shared>) {
    let fingerprint = |p: &PathBuf| -> Option<(u64, SystemTime)> {
        let meta = std::fs::metadata(p).ok()?;
        Some((meta.len(), meta.modified().ok()?))
    };
    let mut last = fingerprint(&path);
    while !shared.shutdown.load(Ordering::SeqCst) {
        std::thread::sleep(poll);
        let now = fingerprint(&path);
        if now.is_none() || now == last {
            continue;
        }
        // writes are atomic renames, so a changed fingerprint is a whole
        // new file — but a non-gvemb/corrupt file must not kill serving
        match load_embeddings_auto(&path) {
            Ok(store) => {
                let index = AnnIndex::build(&store, &cfg);
                let mut state = shared.state.write().unwrap();
                state.index = index;
                state.generation += 1;
                eprintln!(
                    "serve: hot-reloaded {} (generation {})",
                    path.display(),
                    state.generation
                );
            }
            Err(e) => {
                eprintln!("serve: reload of {} failed, keeping old index: {e}", path.display());
            }
        }
        last = now;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::embedding::{save_embeddings_gvemb, EmbeddingStore};
    use crate::serve::protocol::{decode_response, encode_request};

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("graphvite_serve");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    fn query(addr: SocketAddr, req: &Request) -> Response {
        let stream = TcpStream::connect(addr).unwrap();
        let mut reader = std::io::BufReader::new(stream.try_clone().unwrap());
        let mut writer = std::io::BufWriter::new(stream);
        write_frame(&mut writer, &encode_request(req)).unwrap();
        let payload = read_frame(&mut reader).unwrap().unwrap();
        decode_response(&payload, matches!(req, Request::TopK { .. })).unwrap()
    }

    #[test]
    fn end_to_end_topk_over_tcp() {
        let store = EmbeddingStore::init(200, 8, 11);
        let p = tmp("e2e.gvemb");
        save_embeddings_gvemb(&store, &p).unwrap();
        let cfg = ServeConfig { addr: "127.0.0.1:0".into(), ..Default::default() };
        let server = Server::start(p.to_str().unwrap(), cfg).unwrap();
        let addr = server.local_addr();

        match query(addr, &Request::Info) {
            Response::Info { num_nodes, dim, generation } => {
                assert_eq!((num_nodes, dim, generation), (200, 8, 1));
            }
            other => panic!("unexpected {other:?}"),
        }
        match query(addr, &Request::TopK { k: 5, nodes: vec![0, 7, 199] }) {
            Response::TopK { results } => {
                assert_eq!(results.len(), 3);
                for (qi, row) in results.iter().enumerate() {
                    assert_eq!(row.len(), 5, "query {qi}");
                    // ranked descending, self excluded
                    for w in row.windows(2) {
                        assert!(w[0].1 >= w[1].1);
                    }
                }
            }
            other => panic!("unexpected {other:?}"),
        }
        // out-of-range node answers with an error frame, not a hangup
        match query(addr, &Request::TopK { k: 3, nodes: vec![9999] }) {
            Response::Error(msg) => assert!(msg.contains("out of range"), "{msg}"),
            other => panic!("unexpected {other:?}"),
        }
        server.shutdown().unwrap();
    }

    #[test]
    fn hot_reload_swaps_generation() {
        let store = EmbeddingStore::init(64, 4, 1);
        let p = tmp("reload.gvemb");
        save_embeddings_gvemb(&store, &p).unwrap();
        let cfg = ServeConfig {
            addr: "127.0.0.1:0".into(),
            watch: true,
            poll_ms: 20,
            ..Default::default()
        };
        let server = Server::start(p.to_str().unwrap(), cfg).unwrap();
        // rewrite with different geometry; the watcher must pick it up
        let store2 = EmbeddingStore::init(100, 4, 2);
        // ensure the mtime fingerprint moves even on coarse filesystems
        std::thread::sleep(Duration::from_millis(50));
        save_embeddings_gvemb(&store2, &p).unwrap();
        let deadline = std::time::Instant::now() + Duration::from_secs(10);
        loop {
            match query(server.local_addr(), &Request::Info) {
                Response::Info { num_nodes, generation, .. } if generation >= 2 => {
                    assert_eq!(num_nodes, 100);
                    break;
                }
                _ if std::time::Instant::now() > deadline => panic!("no reload within 10s"),
                _ => std::thread::sleep(Duration::from_millis(20)),
            }
        }
        server.shutdown().unwrap();
    }
}
