//! Reimplementations of the systems GraphVite is compared against in
//! Table 3, built from scratch on the same substrates:
//!
//! * [`line`] — LINE: CPU hogwild ASGD over alias-sampled edges (the
//!   paper's "current fastest system" and the speedup denominator).
//! * [`deepwalk`] — DeepWalk: materialized random-walk corpus, then
//!   skip-gram-with-window training (walks stored in memory, the paper's
//!   "fastest setting" for DeepWalk).
//! * [`minibatch`] — the OpenNE-style mini-batch "GPU" system: model
//!   parameters live on the device and the *full matrices* round-trip the
//!   bus every batch — reproducing why Table 3's GPU row loses to CPUs.
//! * [`node2vec`] — second-order p/q-biased walks with per-edge alias
//!   preprocessing (the paper's 25.9-hour preprocessing row).

pub mod deepwalk;
pub mod hsoftmax;
pub mod line;
pub mod minibatch;
pub mod node2vec;

pub use deepwalk::DeepWalkBaseline;
pub use line::LineBaseline;
pub use minibatch::MinibatchGpuBaseline;
pub use node2vec::Node2VecBaseline;

use crate::embedding::EmbeddingStore;
use crate::metrics::TrainStats;

/// Common result shape for all baselines.
pub struct BaselineResult {
    pub embeddings: EmbeddingStore,
    pub stats: TrainStats,
}
