//! Regenerates paper Table 5 — GraphVite training time on the larger BA graphs, 1 vs 4 workers.
//!
//! Run with `cargo bench --bench bench_table5`; set
//! GRAPHVITE_BENCH_SCALE=tiny|small|full to change the workload size
//! (default tiny so `cargo bench` completes quickly; EXPERIMENTS.md
//! records the `small` runs).

fn main() {
    graphvite::experiments::run("table5", graphvite::experiments::Scale::from_env())
        .expect("table5 experiment");
}
