//! Degree statistics (used by DESIGN/EXPERIMENTS reporting and the
//! partitioner's sanity checks).

use super::GraphStore;

/// Summary statistics for a graph.
#[derive(Debug, Clone, PartialEq)]
pub struct GraphStats {
    pub num_nodes: usize,
    pub num_edges: usize,
    pub min_degree: usize,
    pub max_degree: usize,
    pub mean_degree: f64,
    /// Fraction of total degree held by the top 1% highest-degree nodes —
    /// a quick scale-freeness indicator.
    pub top1pct_degree_share: f64,
}

impl GraphStats {
    /// Works off any [`GraphStore`] — degrees are resident for both the
    /// in-RAM and the paged store, so this never touches successor pages.
    pub fn compute(g: &dyn GraphStore) -> Self {
        let n = g.num_nodes();
        let mut degrees: Vec<usize> = (0..n as u32).map(|v| g.degree(v)).collect();
        let total: usize = degrees.iter().sum();
        let min = degrees.iter().copied().min().unwrap_or(0);
        let max = degrees.iter().copied().max().unwrap_or(0);
        degrees.sort_unstable_by(|a, b| b.cmp(a));
        let top = (n / 100).max(1);
        let top_sum: usize = degrees[..top.min(n)].iter().sum();
        GraphStats {
            num_nodes: n,
            num_edges: g.num_edges(),
            min_degree: min,
            max_degree: max,
            mean_degree: total as f64 / n.max(1) as f64,
            top1pct_degree_share: if total > 0 { top_sum as f64 / total as f64 } else { 0.0 },
        }
    }
}

/// Log-binned degree histogram: (bin upper bound, count).
pub fn degree_histogram(g: &dyn GraphStore) -> Vec<(usize, usize)> {
    let mut bins: Vec<(usize, usize)> = Vec::new();
    let mut bound = 1usize;
    loop {
        bins.push((bound, 0));
        if bound > g.num_nodes() {
            break;
        }
        bound *= 2;
    }
    for v in 0..g.num_nodes() as u32 {
        let d = g.degree(v);
        let idx = (usize::BITS - d.leading_zeros()) as usize; // floor(log2(d)) + 1
        let last = bins.len() - 1;
        bins[idx.min(last)].1 += 1;
    }
    bins.retain(|&(_, c)| c > 0);
    bins
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;

    #[test]
    fn stats_on_karate() {
        let s = GraphStats::compute(&generators::karate_club());
        assert_eq!(s.num_nodes, 34);
        assert_eq!(s.num_edges, 78);
        assert_eq!(s.max_degree, 17); // node 33
        assert!((s.mean_degree - 2.0 * 78.0 / 34.0).abs() < 1e-9);
    }

    #[test]
    fn ba_is_more_skewed_than_er() {
        let ba = GraphStats::compute(&generators::barabasi_albert(2000, 3, 1));
        let er = GraphStats::compute(&generators::erdos_renyi(2000, 6000, 1));
        assert!(ba.top1pct_degree_share > er.top1pct_degree_share);
    }

    #[test]
    fn histogram_counts_all_nodes() {
        let g = generators::barabasi_albert(500, 2, 2);
        let h = degree_histogram(&g);
        let total: usize = h.iter().map(|&(_, c)| c).sum();
        assert_eq!(total, 500);
    }
}
