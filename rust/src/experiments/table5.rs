//! Table 5 — GraphVite training time on larger scale-free graphs with
//! 1 vs 4 workers. Shape: near-linear worker scaling, wall-clock growing
//! ~linearly with |E|.

use anyhow::Result;

use crate::config::TrainConfig;
use crate::coordinator::Trainer;
use crate::experiments::presets::{Scale, Workload};
use crate::util::bench::Table;
use crate::util::human_secs;

pub fn run(scale: Scale) -> Result<()> {
    // (name, nodes, edges-per-node) — shrunken Friendster-small /
    // Hyperlink-PLD / Friendster analogues.
    let datasets: Vec<(&str, usize, usize)> = match scale {
        Scale::Tiny => vec![("friendster-small-like", 5_000, 8), ("hyperlink-like", 10_000, 6)],
        Scale::Small => vec![
            ("friendster-small-like", 50_000, 12),
            ("hyperlink-like", 100_000, 8),
            ("friendster-like", 150_000, 14),
        ],
        Scale::Full => vec![
            ("friendster-small-like", 200_000, 14),
            ("hyperlink-like", 400_000, 8),
            ("friendster-like", 500_000, 14),
        ],
    };

    let mut table = Table::new(
        "Table 5 — GraphVite training time on larger graphs",
        &["dataset", "|V|", "|E|", "1 worker", "4 workers", "scaling"],
    );
    for (name, nodes, epn) in datasets {
        let graph = Workload::scale_free(nodes, epn, 0xF00 + nodes as u64);
        let mut times = Vec::new();
        for workers in [1usize, 4] {
            let cfg = TrainConfig {
                dim: 32,
                epochs: 4,
                num_workers: workers,
                num_samplers: workers + 1,
                episode_size: (nodes / 2).max(10_000),
                walk_length: 2, // paper: length 2 on the dense networks
                augmentation_distance: 2,
                batch_size: 512,
                ..TrainConfig::default()
            };
            let mut trainer = Trainer::new(graph.clone(), cfg)?;
            let r = trainer.train()?;
            times.push(r.stats.train_secs);
        }
        table.row(&[
            name.into(),
            format!("{nodes}"),
            format!("{}", graph.num_edges()),
            human_secs(times[0]),
            human_secs(times[1]),
            format!("{:.2}x", times[0] / times[1]),
        ]);
    }
    table.print();
    Ok(())
}

#[cfg(test)]
mod tests {
    // exercised via `graphvite exp table5 --scale tiny` in the bench suite
}
