//! # GraphVite (WWW'19) — CPU/"GPU" hybrid node-embedding system
//!
//! Reproduction of *GraphVite: A High-Performance CPU-GPU Hybrid System
//! for Node Embedding* (Zhu, Xu, Qu, Tang — WWW 2019) as a three-layer
//! Rust + JAX + Pallas stack:
//!
//! * **Layer 3 (this crate)** — the paper's system contribution: parallel
//!   online augmentation on CPU threads ([`sampling`]), the grid-partitioned
//!   sample pool with pseudo shuffle ([`pool`]), parallel negative sampling
//!   over orthogonal blocks ([`scheduler`], [`partition`]), and the
//!   double-buffered CPU/GPU collaboration strategy ([`coordinator`]).
//!   Graphs train from RAM or out-of-core: the sampling stack consumes
//!   the [`graph::GraphStore`] seam, served by the edge-list loader or by
//!   the packed on-disk reader [`graph::PagedCsr`] (`graphvite pack`).
//! * **Layer 2** — the SGNS train-block written in JAX
//!   (`python/compile/model.py`), AOT-lowered to HLO text at build time.
//! * **Layer 1** — the SGNS gradient hot-spot as a Pallas kernel
//!   (`python/compile/kernels/sgns.py`), inlined into the Layer-2 HLO.
//!
//! Device execution sits behind the [`gpu::Backend`] trait: the pure-rust
//! [`gpu::NativeWorker`] is the always-available default,
//! [`gpu::SimdWorker`] runs the same math through hand-unrolled f32x8
//! kernels (also always available — `backend = "simd"`), and with the
//! `pjrt` cargo feature the [`runtime`] module loads the HLO artifacts
//! through the PJRT C API (`xla` crate) so each simulated GPU worker
//! executes the compiled artifacts; Python never runs on the training
//! path. Build without features for a dependency-light binary
//! (`cargo build --release`), or with `--features pjrt` for the
//! three-layer path (see README "Building").
//!
//! A top-to-bottom map of the system — pipeline stages, thread topology,
//! the module ↔ paper-section table — lives in `ARCHITECTURE.md` at the
//! repository root.
//!
//! ## Quickstart
//!
//! ```no_run
//! use graphvite::prelude::*;
//!
//! let graph = generators::barabasi_albert(10_000, 5, 42);
//! let config = TrainConfig { dim: 32, epochs: 20, ..TrainConfig::default() };
//! let mut trainer = Trainer::new(graph, config).unwrap();
//! let result = trainer.train().unwrap();
//! println!("trained {} nodes in {:.2}s", result.embeddings.num_nodes(),
//!          result.stats.train_secs);
//! ```

pub mod baselines;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod embedding;
pub mod eval;
pub mod experiments;
pub mod gpu;
pub mod graph;
pub mod metrics;
pub mod net;
pub mod partition;
pub mod pool;
pub mod runtime;
pub mod sampling;
pub mod scheduler;
pub mod serve;
pub mod util;

/// Convenience re-exports for downstream users.
pub mod prelude {
    pub use crate::config::{BackendKind, TrainConfig};
    pub use crate::coordinator::{TrainCheckpoint, TrainResult, Trainer};
    pub use crate::embedding::EmbeddingStore;
    // pub use crate::eval::{classifier, linkpred}; // (enabled once eval lands)
    pub use crate::graph::{generators, Graph, GraphStore, PagedCsr};
    pub use crate::pool::ShuffleKind;
    pub use crate::util::rng::Rng;
}
