//! Regenerates paper Figure 5 — speed and F1 as a function of episode size.
//!
//! Run with `cargo bench --bench bench_fig5`; set
//! GRAPHVITE_BENCH_SCALE=tiny|small|full to change the workload size
//! (default tiny so `cargo bench` completes quickly; EXPERIMENTS.md
//! records the `small` runs).

fn main() {
    graphvite::experiments::run("fig5", graphvite::experiments::Scale::from_env())
        .expect("fig5 experiment");
}
