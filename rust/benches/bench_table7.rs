//! Regenerates paper Table 7 — F1 + training time for the four pool-shuffle algorithms.
//!
//! Run with `cargo bench --bench bench_table7`; set
//! GRAPHVITE_BENCH_SCALE=tiny|small|full to change the workload size
//! (default tiny so `cargo bench` completes quickly; EXPERIMENTS.md
//! records the `small` runs).

fn scale() -> graphvite::experiments::Scale {
    std::env::var("GRAPHVITE_BENCH_SCALE")
        .ok()
        .and_then(|s| graphvite::experiments::Scale::parse(&s))
        .unwrap_or(graphvite::experiments::Scale::Tiny)
}

fn main() {
    graphvite::experiments::run("table7", scale()).expect("table7 experiment");
}
