//! Locality-aware reordering suite. The contract under test: a
//! BFS-reordered pack is *exactly* the in-RAM [`relabel`] of the source
//! graph plus a stored permutation sidecar — so reordered paged training
//! is bitwise-identical to training the relabeled graph in RAM, and the
//! sidecar maps every trained row back to the external id the user fed
//! in, which is what lets `eval`/`serve` speak original ids.

use std::sync::Arc;

use graphvite::config::{BackendKind, TrainConfig};
use graphvite::coordinator::Trainer;
use graphvite::embedding::EmbeddingStore;
use graphvite::eval::{link_prediction_auc, LinkSplit};
use graphvite::graph::{
    self, bfs_order, generators, invert_order, relabel, Graph, GraphBuilder, GraphStore,
    PackOptions, PagedCsr, ReorderKind,
};
use graphvite::pool::ShuffleKind;
use graphvite::util::prop::{forall, Gen};
use graphvite::util::rng::Rng;

fn tmp(name: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join("graphvite_reorder_tests");
    std::fs::create_dir_all(&dir).unwrap();
    dir.join(name)
}

fn bfs_opts(page_size: u32) -> PackOptions {
    PackOptions { page_size, reorder: ReorderKind::Bfs, ..Default::default() }
}

/// A deterministic weighted multi-community graph (weights exercise the
/// alias sidecar alongside the perm sidecar).
fn weighted_graph(n: u32, edges: usize, seed: u64) -> Graph {
    let mut b = GraphBuilder::new().with_num_nodes(n as usize);
    let mut rng = Rng::new(seed);
    for _ in 0..edges {
        let u = rng.below_usize(n as usize) as u32;
        let mut v = rng.below_usize(n as usize) as u32;
        if u == v {
            v = (v + 1) % n;
        }
        b.push_edge(u, v, ((u + v) % 9 + 1) as f32 * 0.25);
    }
    b.build()
}

fn train_cfg(seed: u64) -> TrainConfig {
    TrainConfig {
        dim: 8,
        epochs: 3,
        num_workers: 2,
        num_samplers: 2,
        episode_size: 2_000,
        batch_size: 64,
        backend: BackendKind::test_backend(),
        shuffle: ShuffleKind::Pseudo,
        seed,
        ..TrainConfig::default()
    }
}

// ------------------------------------------------------- property tests --

#[test]
fn reordered_pack_is_the_relabeled_graph_plus_a_perm_sidecar() {
    forall("bfs pack == relabel + perm", 30, |gen: &mut Gen| {
        let n = gen.usize_in(2..60);
        let edges = gen.edges(n, 250);
        let weighted = gen.bool(0.5);
        let extra = gen.usize_in(0..3); // trailing isolated nodes
        let mut b = GraphBuilder::new().with_num_nodes(n + extra);
        for (u, v) in edges {
            let w = if weighted { gen.f32_in(0.1..4.0) } else { 1.0 };
            b.push_edge(u, v, w);
        }
        let g = b.build();

        let order = bfs_order(&g);
        let rg = relabel(&g, &order);
        let path = tmp(&format!("prop_{}.gvpk", gen.case));
        let page_size = *gen.choose(&[16u32, 64, 1024]);
        graph::pack_store(&g, &path, &bfs_opts(page_size)).unwrap();
        let p = PagedCsr::open(&path, 4096).unwrap();

        // the sidecar IS the order vector (no prior permutation to compose)
        assert_eq!(p.external_ids().unwrap(), order.as_slice(), "case {}", gen.case);

        // every observation matches the in-RAM relabel, weights to the bit
        assert_eq!(GraphStore::num_nodes(&p), rg.num_nodes());
        assert_eq!(GraphStore::num_arcs(&p), rg.num_arcs());
        assert_eq!(p.unit_weights(), rg.unit_weights());
        assert_eq!(GraphStore::labels(&p), rg.labels());
        let (mut t, mut w) = (Vec::new(), Vec::new());
        for v in 0..rg.num_nodes() as u32 {
            p.neighborhood_into(v, &mut t, &mut w);
            assert_eq!(t, rg.neighbors(v), "case {} successors({v})", gen.case);
            let got: Vec<u32> = w.iter().map(|x| x.to_bits()).collect();
            let want: Vec<u32> = rg.neighbor_weights(v).iter().map(|x| x.to_bits()).collect();
            assert_eq!(got, want, "case {} weights({v})", gen.case);
            assert_eq!(
                GraphStore::weighted_degree(&p, v).to_bits(),
                rg.weighted_degree(v).to_bits(),
                "case {} wdeg({v})",
                gen.case
            );
        }
    });
}

#[test]
fn permute_then_unpermute_embeddings_is_the_identity() {
    forall("unpermute inverts the row scatter", 30, |gen: &mut Gen| {
        let n = gen.usize_in(1..50);
        let d = gen.usize_in(1..6);
        let vertex = gen.vec_f32(n * d..n * d + 1, -2.0..2.0);
        let context = gen.vec_f32(n * d..n * d + 1, -2.0..2.0);
        let emb = EmbeddingStore::from_raw(n, d, vertex, context);
        // a random permutation as `external`: old id per internal row
        let mut external: Vec<u32> = (0..n as u32).collect();
        for i in (1..n).rev() {
            let j = gen.usize_in(0..i + 1);
            external.swap(i, j);
        }
        let scattered = emb.unpermuted(&external);
        for internal in 0..n as u32 {
            let ext = external[internal as usize];
            assert_eq!(
                scattered.vertex(ext),
                emb.vertex(internal),
                "case {} row {internal}",
                gen.case
            );
            assert_eq!(scattered.context(ext), emb.context(internal));
        }
        // scattering through the inverse lands every row back home
        let back = scattered.unpermuted(&invert_order(&external));
        assert_eq!(back.vertex_matrix(), emb.vertex_matrix());
        assert_eq!(back.context_matrix(), emb.context_matrix());
    });
}

#[test]
fn external_ids_compose_across_repacks() {
    // reorder a reordered pack: the stored sidecar must keep pointing at
    // the ORIGINAL ids (perm composition), not at the intermediate ones
    let g = weighted_graph(120, 500, 3);
    let p1 = tmp("compose_1.gvpk");
    graph::pack_store(&g, &p1, &bfs_opts(256)).unwrap();
    let paged1 = PagedCsr::open(&p1, 1 << 16).unwrap();
    let ext1 = paged1.external_ids().unwrap().to_vec();

    let p2 = tmp("compose_2.gvpk");
    graph::pack_store(&paged1, &p2, &bfs_opts(256)).unwrap();
    let paged2 = PagedCsr::open(&p2, 1 << 16).unwrap();
    let ext2 = paged2.external_ids().unwrap();

    // expected composition: new -> intermediate (bfs of paged1) -> original
    let order2 = bfs_order(&paged1);
    let want: Vec<u32> = order2.iter().map(|&mid| ext1[mid as usize]).collect();
    assert_eq!(ext2, want.as_slice());

    // still a bijection over the original id space, and the doubly
    // relabeled RAM graph agrees with the doubly reordered pack
    let mut seen = vec![false; ext2.len()];
    for &e in ext2 {
        assert!(!seen[e as usize]);
        seen[e as usize] = true;
    }
    let rg2 = relabel(&relabel(&g, &bfs_order(&g)), &order2);
    let (mut t, mut w) = (Vec::new(), Vec::new());
    for v in 0..rg2.num_nodes() as u32 {
        paged2.neighborhood_into(v, &mut t, &mut w);
        assert_eq!(t, rg2.neighbors(v), "successors({v})");
        let got: Vec<u32> = w.iter().map(|x| x.to_bits()).collect();
        let want: Vec<u32> = rg2.neighbor_weights(v).iter().map(|x| x.to_bits()).collect();
        assert_eq!(got, want, "weights({v})");
    }

    // repacking WITHOUT a reorder carries the sidecar through unchanged
    let p3 = tmp("compose_3.gvpk");
    graph::pack_store(&paged2, &p3, &PackOptions { page_size: 256, ..Default::default() })
        .unwrap();
    let paged3 = PagedCsr::open(&p3, 1 << 16).unwrap();
    assert_eq!(paged3.external_ids().unwrap(), ext2);
}

#[test]
fn pack_edge_list_reorder_matches_pack_store_byte_for_byte() {
    // the two reorder entry points — streaming from an edge list under a
    // memory budget vs packing an in-RAM store — must emit the same file
    let g = weighted_graph(150, 700, 11);
    let listing = tmp("reorder_equiv.txt");
    graph::save_edge_list(&g, &listing).unwrap();

    let from_list = tmp("reorder_from_list.gvpk");
    let opts = PackOptions { page_size: 512, mem_bytes: 4096, reorder: ReorderKind::Bfs };
    graph::pack_edge_list(&listing, &from_list, &opts).unwrap();

    let from_store = tmp("reorder_from_store.gvpk");
    graph::pack_store(&g, &from_store, &opts).unwrap();

    assert_eq!(
        std::fs::read(&from_list).unwrap(),
        std::fs::read(&from_store).unwrap(),
        "external reorder pack diverged from the in-RAM reorder pack"
    );
}

// ------------------------------------------------- end-to-end training --

#[test]
fn reordered_paged_training_is_bitwise_identical_to_relabeled_ram() {
    let g = weighted_graph(250, 900, 7);
    assert!(!g.unit_weights());
    let order = bfs_order(&g);
    let rg = relabel(&g, &order);

    let path = tmp("train_reordered.gvpk");
    graph::pack_store(&g, &path, &bfs_opts(256)).unwrap();
    let paged = Arc::new(PagedCsr::open(&path, 2 * 1024).unwrap());
    assert!(paged.alias_tables_streamed());

    let ram = Trainer::new(rg, train_cfg(55)).unwrap().train().unwrap();
    let disk = Trainer::from_store(Arc::clone(&paged) as Arc<dyn GraphStore>, train_cfg(55))
        .unwrap()
        .train()
        .unwrap();
    assert_eq!(ram.embeddings.vertex_matrix(), disk.embeddings.vertex_matrix());
    assert_eq!(ram.embeddings.context_matrix(), disk.embeddings.context_matrix());

    // the sidecar puts every trained row back on its original id
    let ext = paged.external_ids().unwrap();
    let unperm = disk.embeddings.unpermuted(ext);
    let inv = invert_order(&order);
    for old in 0..g.num_nodes() as u32 {
        assert_eq!(
            unperm.vertex(old),
            disk.embeddings.vertex(inv[old as usize]),
            "row of original node {old}"
        );
    }
}

#[test]
fn external_ids_round_trip_through_eval() {
    // the user's workflow: split + eval live in ORIGINAL id space; the
    // graph got reordered behind their back. Scoring the unpermuted
    // embeddings against the original-id split must agree with scoring
    // the internal embeddings against the internally relabeled split.
    let g = generators::barabasi_albert(250, 3, 9);
    let split = LinkSplit::new(&g, 0.1, 7);

    let order = bfs_order(&g);
    let inv = invert_order(&order);
    let path = tmp("eval_roundtrip.gvpk");
    graph::pack_store(&g, &path, &bfs_opts(512)).unwrap();
    let paged = Arc::new(PagedCsr::open(&path, 4 * 1024).unwrap());
    assert_eq!(paged.external_ids().unwrap(), order.as_slice());

    let disk = Trainer::from_store(Arc::clone(&paged) as Arc<dyn GraphStore>, train_cfg(21))
        .unwrap()
        .train()
        .unwrap();
    let unperm = disk.embeddings.unpermuted(paged.external_ids().unwrap());

    let map = |pairs: &[(u32, u32)]| -> Vec<(u32, u32)> {
        pairs.iter().map(|&(u, v)| (inv[u as usize], inv[v as usize])).collect()
    };
    let internal_split = LinkSplit {
        train_graph: relabel(&split.train_graph, &order),
        positives: map(&split.positives),
        negatives: map(&split.negatives),
    };

    let external_auc = link_prediction_auc(&unperm, &split);
    let internal_auc = link_prediction_auc(&disk.embeddings, &internal_split);
    assert!((0.0..=1.0).contains(&external_auc), "auc {external_auc}");
    // the feature rows are bit-identical up to permutation; only the f32
    // mean-centering accumulation order differs, so the two views of the
    // same evaluation agree to float noise
    assert!(
        (external_auc - internal_auc).abs() < 1e-6,
        "external-id eval {external_auc} != internal eval {internal_auc}"
    );
}
