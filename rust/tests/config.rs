//! Config round-trip coverage: TOML → `TrainConfig` → `validate` for
//! every `BackendKind` variant — driven off `BackendKind::ALL` so a new
//! backend is covered the moment it is added to the enum — plus an
//! end-to-end smoke train through the `simd` backend selected the way a
//! user would select it (config text, not code).

use graphvite::config::{BackendKind, TrainConfig};
use graphvite::coordinator::Trainer;
use graphvite::graph::generators;

#[test]
fn toml_roundtrip_every_backend() {
    for &b in BackendKind::ALL {
        let toml = format!("[train]\nbackend = \"{}\"\n", b.name());
        let res = TrainConfig::from_toml_str(&toml);
        if b.available() {
            let cfg = res.unwrap_or_else(|e| panic!("backend '{}' rejected: {e}", b.name()));
            assert_eq!(cfg.backend, b, "backend '{}'", b.name());
            cfg.validate()
                .unwrap_or_else(|e| panic!("backend '{}' failed validate: {e}", b.name()));
        } else {
            // only reachable for pjrt without the feature: the error must
            // tell the user exactly how to get the backend
            let err = res.expect_err("unavailable backend must be rejected").to_string();
            assert!(
                err.contains("--features pjrt"),
                "backend '{}': unhelpful error: {err}",
                b.name()
            );
            assert!(
                err.contains(b.name()),
                "backend '{}': error does not name the backend: {err}",
                b.name()
            );
        }
    }
}

#[test]
fn toml_roundtrip_every_alias() {
    for &b in BackendKind::ALL {
        for alias in b.aliases() {
            let toml = format!("backend = \"{alias}\"\n");
            match TrainConfig::from_toml_str(&toml) {
                Ok(cfg) => assert_eq!(cfg.backend, b, "alias '{alias}'"),
                // an unavailable aliased backend still fails with the
                // canonical feature hint, not an "unknown backend" error
                Err(e) => {
                    assert!(!b.available(), "alias '{alias}' rejected: {e}");
                    assert!(e.to_string().contains("--features pjrt"), "alias '{alias}': {e}");
                }
            }
        }
    }
}

#[cfg(not(feature = "pjrt"))]
#[test]
fn pjrt_unavailable_error_is_descriptive() {
    let err = TrainConfig::from_toml_str("backend = \"pjrt\"\n")
        .unwrap_err()
        .to_string();
    assert!(err.contains("pjrt"), "{err}");
    assert!(err.contains("--features pjrt"), "{err}");
    assert!(err.contains("native"), "should point at the always-available backends: {err}");
}

#[test]
fn unknown_backend_error_lists_choices() {
    let err = TrainConfig::from_toml_str("backend = \"cuda\"\n")
        .unwrap_err()
        .to_string();
    for &b in BackendKind::ALL {
        assert!(err.contains(b.name()), "'{err}' misses '{}'", b.name());
    }
}

/// The simd backend selected via config text trains end-to-end: the
/// coordinator path (partitioning, episode schedule, restricted
/// negatives) is backend-agnostic and the run must produce finite,
/// nontrivial embeddings.
#[test]
fn simd_backend_trains_end_to_end() {
    let cfg = TrainConfig::from_toml_str(
        r#"
        [train]
        backend = "simd"
        dim = 12
        epochs = 20
        num_workers = 2
        num_samplers = 2
        episode_size = 2000
        batch_size = 64
        seed = 9
        "#,
    )
    .unwrap();
    assert_eq!(cfg.backend, BackendKind::Simd);
    let graph = generators::barabasi_albert(500, 4, 9);
    let mut trainer = Trainer::new(graph, cfg).unwrap();
    let result = trainer.train().unwrap();
    assert!(result.stats.final_loss.is_finite());
    let v = result.embeddings.vertex_matrix();
    assert!(v.iter().all(|x| x.is_finite()));
    // training moved the embeddings off their init
    assert!(result.stats.counters.samples_trained > 0);
}
