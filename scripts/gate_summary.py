#!/usr/bin/env python3
"""Aggregate `gate-sweep` evidence lines into a markdown table.

Usage:
    python3 scripts/gate_summary.py gate-sweep.log [more.log ...] \
        >> "$GITHUB_STEP_SUMMARY"

Parses the one-line records the empirical quality gates print
(`rust/src/util/gate.rs`):

    gate-sweep <name>: floor <f> pass-rate <p> min <m> mean <mean> [seed ...]

and emits one markdown table row per gate, plus the per-seed tail for any
gate whose pass-rate dipped below 1.00.  Exits non-zero on malformed
input so a format drift in the gate reporter cannot silently blank the
summary, and on an empty input so a broken grep upstream is loud.
"""

import re
import sys

LINE = re.compile(
    r"gate-sweep\s+(?P<name>.+?):\s+floor\s+(?P<floor>[0-9.eE+-]+)\s+"
    r"pass-rate\s+(?P<rate>[0-9.]+)\s+min\s+(?P<min>[0-9.eE+-]+)\s+"
    r"mean\s+(?P<mean>[0-9.eE+-]+)\s+\[(?P<seeds>.*)\]"
)


def main(argv):
    if len(argv) < 2:
        print("usage: gate_summary.py GATE_LOG [...]", file=sys.stderr)
        return 2
    rows, bad = [], 0
    for path in argv[1:]:
        with open(path) as f:
            for raw in f:
                raw = raw.strip()
                if not raw or "gate-sweep" not in raw:
                    continue
                m = LINE.search(raw)
                if not m:
                    print(f"gate-summary: unparseable line: {raw}", file=sys.stderr)
                    bad += 1
                    continue
                rows.append(m.groupdict())
    if not rows:
        print("gate-summary: no gate-sweep lines found", file=sys.stderr)
        return 1

    print("### Empirical gate sweep")
    print()
    print("| gate | floor | pass-rate | min | mean |")
    print("|---|---|---|---|---|")
    for r in rows:
        flag = "" if float(r["rate"]) >= 1.0 else " ⚠️"
        print(
            f"| `{r['name']}` | {r['floor']} | {r['rate']}{flag} "
            f"| {r['min']} | {r['mean']} |"
        )
    dipped = [r for r in rows if float(r["rate"]) < 1.0]
    if dipped:
        print()
        print("Per-seed scores for gates below a 1.00 pass-rate:")
        print()
        for r in dipped:
            print(f"- `{r['name']}`: {r['seeds']}")
    return 1 if bad else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
