"""Layer-1 correctness: Pallas SGNS kernel vs pure-jnp oracle.

Hypothesis sweeps shapes/tiles; the oracle itself is cross-checked against
jax autodiff, so the chain is:  autodiff == ref == pallas.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.sgns import sgns_grad, DEFAULT_TILE
from compile.kernels.ref import sgns_grad_ref, sgns_loss_ref


def _rand(shape, seed, scale=1.0):
    return scale * jax.random.normal(jax.random.PRNGKey(seed), shape)


def _labels(n, seed):
    lab = (jax.random.uniform(jax.random.PRNGKey(seed), (n,)) < 0.5).astype(
        jnp.float32
    )
    weight = jnp.where(lab > 0, 1.0, 5.0)
    return lab, weight


class TestKernelVsRef:
    @pytest.mark.parametrize("n,d", [(64, 8), (256, 32), (512, 64), (1024, 128)])
    def test_matches_ref(self, n, d):
        u, v = _rand((n, d), 0), _rand((n, d), 1)
        lab, w = _labels(n, 2)
        gu, gv, loss = sgns_grad(u, v, lab, w)
        rgu, rgv, rloss = sgns_grad_ref(u, v, lab, w)
        np.testing.assert_allclose(gu, rgu, rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(gv, rgv, rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(loss, rloss, rtol=1e-5, atol=1e-6)

    @settings(max_examples=25, deadline=None)
    @given(
        log_tiles=st.integers(0, 4),
        tile=st.sampled_from([32, 64, 128, 256]),
        d=st.sampled_from([4, 16, 33, 64, 96, 128]),
        seed=st.integers(0, 2**16),
        scale=st.sampled_from([0.01, 1.0, 10.0]),
    )
    def test_hypothesis_shape_sweep(self, log_tiles, tile, d, seed, scale):
        """Shape/tile/scale sweep: pallas == ref for any divisible tiling."""
        n = tile * (2**log_tiles)
        u, v = _rand((n, d), seed, scale), _rand((n, d), seed + 1, scale)
        lab, w = _labels(n, seed + 2)
        gu, gv, loss = sgns_grad(u, v, lab, w, tile=tile)
        rgu, rgv, rloss = sgns_grad_ref(u, v, lab, w)
        # f32 sigmoid of large dot products (|s| ~ scale^2 * sqrt(d)) loses
        # relative precision; tolerance scales with the input magnitude.
        rtol = 1e-4 if scale <= 1.0 else 5e-3
        np.testing.assert_allclose(gu, rgu, rtol=rtol, atol=1e-6)
        np.testing.assert_allclose(gv, rgv, rtol=rtol, atol=1e-6)
        np.testing.assert_allclose(loss, rloss, rtol=rtol, atol=1e-6)

    def test_indivisible_tile_rejected(self):
        u, v = _rand((100, 8), 0), _rand((100, 8), 1)
        lab, w = _labels(100, 2)
        with pytest.raises(ValueError, match="not divisible"):
            sgns_grad(u, v, lab, w, tile=64)

    def test_default_tile_small_n(self):
        """N < DEFAULT_TILE falls back to a single whole-array tile."""
        n = DEFAULT_TILE // 4
        u, v = _rand((n, 8), 0), _rand((n, 8), 1)
        lab, w = _labels(n, 2)
        gu, _, _ = sgns_grad(u, v, lab, w)
        rgu, _, _ = sgns_grad_ref(u, v, lab, w)
        np.testing.assert_allclose(gu, rgu, rtol=1e-5, atol=1e-6)


class TestRefVsAutodiff:
    """The oracle's closed-form gradients must equal jax autodiff."""

    @pytest.mark.parametrize("n,d", [(64, 8), (256, 32)])
    def test_grad_u(self, n, d):
        u, v = _rand((n, d), 3), _rand((n, d), 4)
        lab, w = _labels(n, 5)
        gu, gv, _ = sgns_grad_ref(u, v, lab, w)
        g_auto_u = jax.grad(lambda x: sgns_loss_ref(x, v, lab, w).sum())(u)
        g_auto_v = jax.grad(lambda x: sgns_loss_ref(u, x, lab, w).sum())(v)
        np.testing.assert_allclose(gu, g_auto_u, rtol=1e-4, atol=1e-5)
        np.testing.assert_allclose(gv, g_auto_v, rtol=1e-4, atol=1e-5)


class TestNumericalStability:
    def test_large_dot_products(self):
        """|s| >> 0 must not produce inf/nan in loss or grads."""
        n, d = 64, 16
        u = jnp.ones((n, d)) * 50.0
        v = jnp.ones((n, d)) * 50.0  # s = 40000
        lab, w = _labels(n, 6)
        gu, gv, loss = sgns_grad(u, v, lab, w)
        assert np.isfinite(np.asarray(loss)).all()
        assert np.isfinite(np.asarray(gu)).all()
        assert np.isfinite(np.asarray(gv)).all()

    def test_negative_large_dot(self):
        n, d = 64, 16
        u = jnp.ones((n, d)) * 50.0
        v = jnp.ones((n, d)) * -50.0
        lab, w = _labels(n, 7)
        _, _, loss = sgns_grad(u, v, lab, w)
        assert np.isfinite(np.asarray(loss)).all()

    def test_zero_embeddings(self):
        """s=0: loss = weight*log(2), grad = weight*(0.5-label)*other."""
        n, d = 64, 16
        u = jnp.zeros((n, d))
        v = jnp.zeros((n, d))
        lab, w = _labels(n, 8)
        gu, gv, loss = sgns_grad(u, v, lab, w)
        np.testing.assert_allclose(loss, w * np.log(2.0), rtol=1e-5)
        np.testing.assert_allclose(gu, 0.0, atol=1e-7)


class TestSemantics:
    def test_positive_pair_gradient_attracts(self):
        """For label=1, -grad_u points toward v (dot(-gu, v) > 0)."""
        n, d = 64, 16
        u, v = _rand((n, d), 9), _rand((n, d), 10)
        lab = jnp.ones((n,))
        w = jnp.ones((n,))
        gu, _, _ = sgns_grad(u, v, lab, w)
        step_dir = -(gu * v).sum(-1)  # alignment of -grad with v
        assert np.all(np.asarray(step_dir) > 0)

    def test_negative_pair_gradient_repels(self):
        n, d = 64, 16
        u, v = _rand((n, d), 11), _rand((n, d), 12)
        lab = jnp.zeros((n,))
        w = jnp.ones((n,))
        gu, _, _ = sgns_grad(u, v, lab, w)
        step_dir = -(gu * v).sum(-1)
        assert np.all(np.asarray(step_dir) < 0)

    def test_weight_scales_gradient_linearly(self):
        n, d = 64, 16
        u, v = _rand((n, d), 13), _rand((n, d), 14)
        lab = jnp.zeros((n,))
        gu1, gv1, l1 = sgns_grad(u, v, lab, jnp.ones((n,)))
        gu5, gv5, l5 = sgns_grad(u, v, lab, jnp.full((n,), 5.0))
        np.testing.assert_allclose(5.0 * gu1, gu5, rtol=1e-5)
        np.testing.assert_allclose(5.0 * gv1, gv5, rtol=1e-5)
        np.testing.assert_allclose(5.0 * l1, l5, rtol=1e-5)

    def test_symmetry_u_v(self):
        """Swapping u/v swaps grad_u/grad_v (dot product is symmetric)."""
        n, d = 128, 32
        u, v = _rand((n, d), 15), _rand((n, d), 16)
        lab, w = _labels(n, 17)
        gu, gv, loss = sgns_grad(u, v, lab, w)
        gu2, gv2, loss2 = sgns_grad(v, u, lab, w)
        np.testing.assert_allclose(gu, gv2, rtol=1e-6)
        np.testing.assert_allclose(gv, gu2, rtol=1e-6)
        np.testing.assert_allclose(loss, loss2, rtol=1e-6)
