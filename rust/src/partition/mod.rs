//! Degree-guided grid partitioning (paper §4.3, Figure 3).
//!
//! Rows of `vertex` and `context` are split into `n` partitions. GraphVite
//! sorts nodes by degree and deals them into partitions in a zig-zag
//! (boustrophedon) pattern — 0,1,…,n-1,n-1,…,1,0,… — so every partition
//! receives the same number of nodes *and* a balanced share of high-degree
//! nodes (sample blocks then have roughly equal sizes, which keeps the
//! per-episode work of the n GPUs balanced).

use crate::graph::GraphStore;

/// A partitioning of node ids into `n` parts with local row indices.
#[derive(Debug, Clone)]
pub struct Partitioning {
    /// part_of[v] = partition id of node v.
    part_of: Vec<u16>,
    /// local_row[v] = row of node v inside its partition.
    local_row: Vec<u32>,
    /// nodes_of_part[p][r] = global node id at partition p, local row r.
    nodes_of_part: Vec<Vec<u32>>,
}

/// Partitioning strategies.
pub struct Partitioner;

impl Partitioner {
    /// The paper's degree-guided zig-zag strategy. Degrees are resident
    /// for every [`GraphStore`], so partitioning an out-of-core graph
    /// never touches successor pages.
    pub fn degree_zigzag(graph: &dyn GraphStore, num_parts: usize) -> Partitioning {
        assert!(num_parts >= 1);
        let n = graph.num_nodes();
        assert!(n >= num_parts, "fewer nodes than partitions");
        let mut order: Vec<u32> = (0..n as u32).collect();
        // sort by degree descending (stable tiebreak on id for determinism)
        order.sort_unstable_by(|&a, &b| {
            graph
                .degree(b)
                .cmp(&graph.degree(a))
                .then_with(|| a.cmp(&b))
        });
        Self::zigzag_assign(&order, n, num_parts)
    }

    /// Round-robin over raw node ids (ablation baseline: no degree guidance).
    pub fn round_robin(graph: &dyn GraphStore, num_parts: usize) -> Partitioning {
        let n = graph.num_nodes();
        let order: Vec<u32> = (0..n as u32).collect();
        Self::zigzag_assign(&order, n, num_parts)
    }

    fn zigzag_assign(order: &[u32], n: usize, num_parts: usize) -> Partitioning {
        let mut part_of = vec![0u16; n];
        let mut local_row = vec![0u32; n];
        let mut nodes_of_part: Vec<Vec<u32>> =
            vec![Vec::with_capacity(n / num_parts + 1); num_parts];
        for (i, &v) in order.iter().enumerate() {
            let round = i / num_parts;
            let pos = i % num_parts;
            let p = if round % 2 == 0 { pos } else { num_parts - 1 - pos };
            part_of[v as usize] = p as u16;
            local_row[v as usize] = nodes_of_part[p].len() as u32;
            nodes_of_part[p].push(v);
        }
        Partitioning { part_of, local_row, nodes_of_part }
    }
}

impl Partitioning {
    pub fn num_parts(&self) -> usize {
        self.nodes_of_part.len()
    }

    pub fn num_nodes(&self) -> usize {
        self.part_of.len()
    }

    /// Partition id of node `v`.
    #[inline]
    pub fn part_of(&self, v: u32) -> usize {
        self.part_of[v as usize] as usize
    }

    /// Local row of node `v` within its partition.
    #[inline]
    pub fn local_row(&self, v: u32) -> u32 {
        self.local_row[v as usize]
    }

    /// Global node ids of partition `p` in local-row order.
    #[inline]
    pub fn nodes_of_part(&self, p: usize) -> &[u32] {
        &self.nodes_of_part[p]
    }

    /// Number of rows in partition `p`.
    #[inline]
    pub fn part_size(&self, p: usize) -> usize {
        self.nodes_of_part[p].len()
    }

    /// Largest partition size (the row capacity a device must hold).
    pub fn max_part_size(&self) -> usize {
        self.nodes_of_part.iter().map(|v| v.len()).max().unwrap_or(0)
    }

    /// Sum of weighted degrees per partition (balance diagnostics).
    pub fn degree_loads(&self, graph: &dyn GraphStore) -> Vec<f64> {
        self.nodes_of_part
            .iter()
            .map(|nodes| {
                nodes
                    .iter()
                    .map(|&v| graph.weighted_degree(v) as f64)
                    .sum()
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;

    #[test]
    fn covers_every_node_exactly_once() {
        let g = generators::barabasi_albert(997, 3, 1); // prime count
        let parts = Partitioner::degree_zigzag(&g, 4);
        let mut seen = vec![false; 997];
        for p in 0..4 {
            for &v in parts.nodes_of_part(p) {
                assert!(!seen[v as usize], "node {v} assigned twice");
                seen[v as usize] = true;
                assert_eq!(parts.part_of(v), p);
            }
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn local_rows_are_dense_and_consistent() {
        let g = generators::barabasi_albert(500, 2, 2);
        let parts = Partitioner::degree_zigzag(&g, 3);
        for p in 0..3 {
            let nodes = parts.nodes_of_part(p);
            for (r, &v) in nodes.iter().enumerate() {
                assert_eq!(parts.local_row(v) as usize, r);
            }
        }
    }

    #[test]
    fn sizes_balanced_within_one() {
        let g = generators::barabasi_albert(1001, 2, 3);
        let parts = Partitioner::degree_zigzag(&g, 4);
        let sizes: Vec<usize> = (0..4).map(|p| parts.part_size(p)).collect();
        let min = *sizes.iter().min().unwrap();
        let max = *sizes.iter().max().unwrap();
        assert!(max - min <= 1, "sizes {sizes:?}");
    }

    #[test]
    fn zigzag_balances_degree_better_than_blocked() {
        // on a scale-free graph, degree loads under zig-zag should be
        // within ~25% of each other
        let g = generators::barabasi_albert(2000, 3, 4);
        let parts = Partitioner::degree_zigzag(&g, 4);
        let loads = parts.degree_loads(&g);
        let min = loads.iter().cloned().fold(f64::INFINITY, f64::min);
        let max = loads.iter().cloned().fold(0.0, f64::max);
        assert!(max / min < 1.25, "loads {loads:?}");
    }

    #[test]
    fn single_partition_is_identity_map() {
        let g = generators::karate_club();
        let parts = Partitioner::degree_zigzag(&g, 1);
        assert_eq!(parts.part_size(0), 34);
        for v in 0..34u32 {
            assert_eq!(parts.part_of(v), 0);
        }
    }

    #[test]
    fn round_robin_is_degree_blind() {
        let g = generators::karate_club();
        let parts = Partitioner::round_robin(&g, 2);
        // first zig: node 0 -> part 0, node 1 -> part 1; zag: 2 -> 1, 3 -> 0
        assert_eq!(parts.part_of(0), 0);
        assert_eq!(parts.part_of(1), 1);
        assert_eq!(parts.part_of(2), 1);
        assert_eq!(parts.part_of(3), 0);
    }
}
