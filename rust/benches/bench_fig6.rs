//! Regenerates paper Figure 6 — speedup vs number of CPU samplers and device workers.
//!
//! Run with `cargo bench --bench bench_fig6`; set
//! GRAPHVITE_BENCH_SCALE=tiny|small|full to change the workload size
//! (default tiny so `cargo bench` completes quickly; EXPERIMENTS.md
//! records the `small` runs).

fn main() {
    graphvite::experiments::run("fig6", graphvite::experiments::Scale::from_env())
        .expect("fig6 experiment");
}
