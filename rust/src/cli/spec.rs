//! Declarative flag specs — one table per subcommand.
//!
//! [`CommandSpec::parse`] replaces the old `KNOWN_FLAGS` registry and
//! its flag-vs-option guessing: a token is a switch or a value flag
//! because its spec entry says so, never because of what happens to
//! follow it on the command line. The same tables generate each
//! subcommand's `--help` screen, power "did you mean" suggestions for
//! typos, and declare which [`crate::config::TrainConfigBuilder`] key
//! each train flag feeds — so the parser, the help text and the config
//! layer cannot drift apart (a property test walks the bindings).

use anyhow::{anyhow, bail, Result};

use crate::config::TrainConfigBuilder;

use super::Args;

/// Whether a flag consumes a value token.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FlagKind {
    /// `--flag VALUE` / `--flag=VALUE`; the str is the help placeholder.
    Value(&'static str),
    /// Bare presence (`--watch`); never consumes the next token.
    Switch,
}

/// What a flag does to a [`TrainConfigBuilder`] (train only; every
/// other subcommand reads its flags directly).
#[derive(Debug, Clone, Copy)]
pub enum Binding {
    /// `--flag VALUE` sets this builder key to VALUE.
    Set(&'static str),
    /// The switch sets this builder key to the literal bool.
    SetBool(&'static str, bool),
    /// Not a config field (I/O paths, checkpoint cadence, ...).
    None,
}

/// One `--flag` a subcommand accepts.
pub struct FlagSpec {
    /// Name without the `--` prefix.
    pub name: &'static str,
    pub kind: FlagKind,
    /// One help line.
    pub help: &'static str,
    /// Config field this flag feeds, if any.
    pub binding: Binding,
}

/// One subcommand: its header line, usage line, and flag table.
pub struct CommandSpec {
    pub name: &'static str,
    /// One-line description for the subcommand header.
    pub about: &'static str,
    /// Usage line, e.g. `graphvite train [GRAPH] [options]`.
    pub usage: &'static str,
    pub flags: &'static [FlagSpec],
}

/// Every table ends with this so `--help` parses everywhere.
const HELP_FLAG: FlagSpec = FlagSpec {
    name: "help",
    kind: FlagKind::Switch,
    help: "print this help",
    binding: Binding::None,
};

const fn value(
    name: &'static str,
    placeholder: &'static str,
    help: &'static str,
) -> FlagSpec {
    FlagSpec { name, kind: FlagKind::Value(placeholder), help, binding: Binding::None }
}

const fn setting(
    name: &'static str,
    placeholder: &'static str,
    key: &'static str,
    help: &'static str,
) -> FlagSpec {
    FlagSpec { name, kind: FlagKind::Value(placeholder), help, binding: Binding::Set(key) }
}

const fn switch_bool(
    name: &'static str,
    key: &'static str,
    to: bool,
    help: &'static str,
) -> FlagSpec {
    FlagSpec { name, kind: FlagKind::Switch, help, binding: Binding::SetBool(key, to) }
}

pub static TRAIN: CommandSpec = CommandSpec {
    name: "train",
    about: "train node embeddings through the full hybrid system",
    usage: "graphvite train [GRAPH] [options]",
    flags: &[
        value("config", "FILE.toml", "load a [train] config table (flags override it)"),
        value("synthetic", "KIND", "ba | youtube | sbm | karate (instead of GRAPH)"),
        value("nodes", "N", "synthetic graph size [10000]"),
        value("edges-per-node", "M", "synthetic mean degree / 2 [5]"),
        value("labels", "K", "synthetic label count [10]"),
        value("mixing", "X", "sbm inter-community mixing [0.05]"),
        setting("dim", "D", "dim", "embedding dimension [64]"),
        setting("epochs", "E", "epochs", "|E| positive samples per epoch [10]"),
        setting("lr", "X", "lr", "initial learning rate [0.025]"),
        setting("negatives", "K", "negatives", "negative samples per positive [5]"),
        setting("neg-weight", "W", "neg_weight", "negative sample weight [5]"),
        setting("batch-size", "B", "batch_size", "samples per device batch [1024]"),
        setting("seed", "N", "seed", "run seed [42]"),
        setting("log-every", "N", "log_every", "progress cadence in episodes [10]"),
        setting("walk-length", "L", "walk_length", "random walk length in edges [5]"),
        setting("aug-distance", "S", "augmentation_distance", "augmentation distance [2]"),
        setting("workers", "N", "num_workers", "simulated GPUs [4]"),
        setting(
            "capacities",
            "LIST",
            "worker_capacities",
            "per-worker capacities, e.g. 2,1 (heterogeneous devices)",
        ),
        setting(
            "partitions",
            "N",
            "num_partitions",
            "matrix partitions (0 = workers; multiple of total capacity)",
        ),
        setting("samplers", "N", "num_samplers", "CPU sampler threads [4]"),
        setting("episode-size", "N", "episode_size", "samples per episode x workers [200000]"),
        setting("backend", "B", "backend", "device backend (see `graphvite help`) [native]"),
        setting("shuffle", "S", "shuffle", "none | random | index-mapping | pseudo [pseudo]"),
        setting("graph-format", "F", "graph_format", "how GRAPH is loaded [auto]"),
        setting(
            "graph-cache-bytes",
            "N",
            "graph_cache_bytes",
            "page-cache budget for packed graphs [64 MiB]",
        ),
        setting(
            "transport",
            "MODE",
            "workers",
            "local | tcp://HOST:PORT — where workers live [local]",
        ),
        setting(
            "worker-timeout-secs",
            "N",
            "worker_timeout_secs",
            "fail if a remote worker goes silent this long (0 = off) [0]",
        ),
        setting(
            "heartbeat-secs",
            "N",
            "heartbeat_secs",
            "PING idle tcp workers every N seconds (0 = off) [0]",
        ),
        setting(
            "max-worker-retries",
            "N",
            "max_worker_retries",
            "recover up to N worker failures by replay (0 = fail loud) [0]",
        ),
        setting(
            "rejoin-window-secs",
            "N",
            "rejoin_window_secs",
            "hold a dead slot open for a replacement (0 = fold now) [0]",
        ),
        switch_bool(
            "wire-compression",
            "wire_compression",
            true,
            "delta/XOR-compress tcp shipments (the default; lossless)",
        ),
        switch_bool(
            "no-wire-compression",
            "wire_compression",
            false,
            "ship raw f32 frames (wins if both compression flags given)",
        ),
        switch_bool("no-collaboration", "collaboration", false, "disable double-buffered pools"),
        switch_bool(
            "no-augmentation",
            "online_augmentation",
            false,
            "plain edge sampling, no online augmentation",
        ),
        switch_bool(
            "no-fix-context",
            "fix_context",
            false,
            "re-transfer context partitions every episode",
        ),
        switch_bool("no-pipeline", "pipeline_transfers", false, "serial wave dispatch"),
        switch_bool("no-residency", "residency", false, "re-ship partitions every episode"),
        value("fault-checkpoint", "FILE", "cut a .gvck at the last pool boundary on death"),
        value("output", "FILE", "save embeddings (format from the extension)"),
        value("output-format", "F", "binary | text | gvemb (overrides the extension)"),
        value("checkpoint", "FILE", "write a resumable .gvck at pool boundaries"),
        value("checkpoint-every", "K", "checkpoint every K-th pool boundary [1]"),
        value("resume", "FILE.gvck", "continue a checkpointed run (same graph/seed/epochs)"),
        value("stop-after-pools", "K", "end the run cleanly after K pool passes (0 = off)"),
        HELP_FLAG,
    ],
};

pub static PACK: CommandSpec = CommandSpec {
    name: "pack",
    about: "pack an edge list for out-of-core training",
    usage: "graphvite pack GRAPH.txt --out FILE.gvpk [options]",
    flags: &[
        value("out", "FILE.gvpk", "output path (required)"),
        value("page-size", "BYTES", "successor-page granularity [65536]"),
        value(
            "pack-mem-bytes",
            "N",
            "packing memory budget (external sort-merge) [268435456]",
        ),
        value("reorder", "KIND", "none | bfs: renumber nodes while packing [none]"),
        HELP_FLAG,
    ],
};

pub static REORDER: CommandSpec = CommandSpec {
    name: "reorder",
    about: "repack a graph under a locality-aware node permutation",
    usage: "graphvite reorder GRAPH --out FILE.gvpk [options]",
    flags: &[
        value("out", "FILE.gvpk", "output path (required)"),
        value("reorder", "KIND", "none | bfs: permutation to apply [bfs]"),
        value("page-size", "BYTES", "successor-page granularity [65536]"),
        value(
            "pack-mem-bytes",
            "N",
            "packing memory budget (external sort-merge) [268435456]",
        ),
        HELP_FLAG,
    ],
};

pub static GENERATE: CommandSpec = CommandSpec {
    name: "generate",
    about: "write a synthetic benchmark graph to an edge list",
    usage: "graphvite generate --kind KIND --out FILE [options]",
    flags: &[
        value("kind", "KIND", "ba | youtube | sbm | er [ba]"),
        value("nodes", "N", "graph size [10000]"),
        value("edges-per-node", "M", "mean degree / 2 [5]"),
        value("labels", "K", "label count (youtube/sbm) [10]"),
        value("mixing", "X", "sbm inter-community mixing [0.05]"),
        value("seed", "N", "generator seed [42]"),
        value("out", "FILE", "output edge-list path (required)"),
        HELP_FLAG,
    ],
};

pub static EVAL: CommandSpec = CommandSpec {
    name: "eval",
    about: "evaluate saved embeddings",
    usage: "graphvite eval TASK --embeddings F --graph G [options]",
    flags: &[
        value("embeddings", "FILE", "saved embeddings (required)"),
        value("graph", "FILE", "edge list the embeddings were trained on (required)"),
        value("train-frac", "X", "classify: labeled fraction [0.02]"),
        value("holdout", "X", "linkpred: held-out edge fraction [0.01]"),
        value("seed", "N", "evaluation split seed [7]"),
        HELP_FLAG,
    ],
};

pub static SERVE: CommandSpec = CommandSpec {
    name: "serve",
    about: "serve batched top-k queries over TCP",
    usage: "graphvite serve EMB [options]",
    flags: &[
        value("embeddings", "FILE", "embedding file (or pass it positionally)"),
        value("addr", "HOST:PORT", "bind address [127.0.0.1:7654]"),
        value("nlist", "N", "IVF inverted lists (0 = ~sqrt(n)) [0]"),
        value("nprobe", "N", "lists probed per query (0 = nlist/8) [0]"),
        value("seed", "N", "IVF clustering seed"),
        FlagSpec {
            name: "watch",
            kind: FlagKind::Switch,
            help: "hot-reload the embedding file when training rewrites it",
            binding: Binding::None,
        },
        value("poll-ms", "MS", "watcher poll interval [500]"),
        HELP_FLAG,
    ],
};

pub static WORKER: CommandSpec = CommandSpec {
    name: "worker",
    about: "host a training worker for a remote coordinator",
    usage: "graphvite worker --connect HOST:PORT [options]",
    flags: &[
        value("connect", "HOST:PORT", "coordinator address (or pass it positionally)"),
        value("connect-timeout-secs", "N", "give up connecting after N seconds [30]"),
        HELP_FLAG,
    ],
};

pub static EXP: CommandSpec = CommandSpec {
    name: "exp",
    about: "regenerate a paper table or figure",
    usage: "graphvite exp NAME [--scale S]",
    flags: &[value("scale", "S", "tiny | small | full [small]"), HELP_FLAG],
};

pub static STATS: CommandSpec = CommandSpec {
    name: "stats",
    about: "graph statistics and the Table-1 memory model",
    usage: "graphvite stats [GRAPH] [options]",
    flags: &[
        value("synthetic", "KIND", "ba | youtube | sbm | karate (instead of GRAPH)"),
        value("nodes", "N", "synthetic graph size [10000]"),
        value("edges-per-node", "M", "synthetic mean degree / 2 [5]"),
        value("labels", "K", "synthetic label count [10]"),
        value("mixing", "X", "sbm inter-community mixing [0.05]"),
        value("seed", "N", "synthetic generator seed [42]"),
        value("dim", "D", "memory-model embedding dimension [128]"),
        value("walk-length", "L", "memory-model walk length [5]"),
        value("aug-distance", "S", "memory-model augmentation distance [2]"),
        value("graph-format", "F", "how GRAPH is loaded [auto]"),
        value("graph-cache-bytes", "N", "page-cache budget for packed graphs [64 MiB]"),
        HELP_FLAG,
    ],
};

pub static ARTIFACTS: CommandSpec = CommandSpec {
    name: "artifacts",
    about: "list the AOT HLO artifacts the runtime can load",
    usage: "graphvite artifacts",
    flags: &[HELP_FLAG],
};

/// Every speced subcommand, in `graphvite help` order.
pub static COMMANDS: &[&CommandSpec] =
    &[&TRAIN, &PACK, &REORDER, &GENERATE, &EVAL, &SERVE, &WORKER, &EXP, &STATS, &ARTIFACTS];

/// Look up the spec for a subcommand name.
pub fn command_spec(name: &str) -> Option<&'static CommandSpec> {
    COMMANDS.iter().copied().find(|c| c.name == name)
}

impl CommandSpec {
    /// This command's entry for `name` (without the `--`).
    pub fn flag(&self, name: &str) -> Option<&'static FlagSpec> {
        self.flags.iter().find(|f| f.name == name)
    }

    /// Parse this command's arguments (argv *after* the subcommand
    /// token). Strict: unknown flags, switches given values, and value
    /// flags missing them are all pointed errors.
    pub fn parse(&self, argv: &[String]) -> Result<Args> {
        let mut out = Args { command: self.name.to_string(), ..Args::default() };
        let mut it = argv.iter().peekable();
        while let Some(tok) = it.next() {
            let Some(rest) = tok.strip_prefix("--") else {
                out.positional.push(tok.clone());
                continue;
            };
            if rest.is_empty() {
                bail!("bare '--' not supported");
            }
            let (name, inline) = match rest.find('=') {
                Some(eq) => (&rest[..eq], Some(rest[eq + 1..].to_string())),
                None => (rest, None),
            };
            let spec = self.flag(name).ok_or_else(|| self.unknown_flag(name))?;
            match (spec.kind, inline) {
                (FlagKind::Switch, None) => out.flags.push(spec.name.to_string()),
                (FlagKind::Switch, Some(v)) => {
                    bail!("--{name} is a switch and takes no value (got '{v}')")
                }
                (FlagKind::Value(_), Some(v)) => {
                    out.opts.insert(spec.name.to_string(), v);
                }
                (FlagKind::Value(ph), None) => match it.peek() {
                    Some(next) if !next.starts_with("--") => {
                        out.opts.insert(spec.name.to_string(), it.next().unwrap().clone());
                    }
                    _ => bail!(
                        "--{name} requires a value {ph} (see `graphvite {} --help`)",
                        self.name
                    ),
                },
            }
        }
        Ok(out)
    }

    fn unknown_flag(&self, name: &str) -> anyhow::Error {
        match suggest(name, self.flags) {
            Some(s) => {
                anyhow!("unknown flag --{name} for `graphvite {}` (did you mean --{s}?)", self.name)
            }
            None => anyhow!(
                "unknown flag --{name} for `graphvite {0}` (see `graphvite {0} --help`)",
                self.name
            ),
        }
    }

    /// The generated `--help` screen, one line per flag.
    pub fn help(&self) -> String {
        let mut out = format!(
            "graphvite {} — {}\n\nUSAGE:\n  {}\n\nOPTIONS:\n",
            self.name, self.about, self.usage
        );
        for f in self.flags {
            let head = match f.kind {
                FlagKind::Value(ph) => format!("--{} {}", f.name, ph),
                FlagKind::Switch => format!("--{}", f.name),
            };
            out.push_str(&format!("  {head:<26} {}\n", f.help));
        }
        out
    }

    /// Fold every config-bound flag in `args` into `b`, recording the
    /// flag spelling (`--dim`) as the field's provenance. Table order
    /// decides ties: `--no-wire-compression` is listed after
    /// `--wire-compression`, so off wins when both are given.
    pub fn apply_to_builder(&self, args: &Args, b: &mut TrainConfigBuilder) -> Result<()> {
        for f in self.flags {
            match f.binding {
                Binding::Set(key) => {
                    if let Some(v) = args.get(f.name) {
                        b.set_str(key, v, &format!("--{}", f.name))?;
                    }
                }
                Binding::SetBool(key, to) => {
                    if args.flag(f.name) {
                        b.set_str(key, if to { "true" } else { "false" }, &format!("--{}", f.name))?;
                    }
                }
                Binding::None => {}
            }
        }
        Ok(())
    }
}

/// Smallest-edit-distance candidate within distance 2, for "did you
/// mean" suggestions.
fn suggest(name: &str, flags: &[FlagSpec]) -> Option<&'static str> {
    flags
        .iter()
        .map(|f| (edit_distance(name, f.name), f.name))
        .filter(|&(d, _)| d <= 2)
        .min_by_key(|&(d, _)| d)
        .map(|(_, n)| n)
}

fn edit_distance(a: &str, b: &str) -> usize {
    let a: Vec<char> = a.chars().collect();
    let b: Vec<char> = b.chars().collect();
    let mut prev: Vec<usize> = (0..=b.len()).collect();
    for (i, &ca) in a.iter().enumerate() {
        let mut cur = vec![i + 1];
        for (j, &cb) in b.iter().enumerate() {
            let cost = usize::from(ca != cb);
            cur.push((prev[j] + cost).min(prev[j + 1] + 1).min(cur[j] + 1));
        }
        prev = cur;
    }
    prev[b.len()]
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &str) -> Vec<String> {
        s.split_whitespace().map(|t| t.to_string()).collect()
    }

    #[test]
    fn strict_parse_accepts_every_declared_form() {
        let a = TRAIN.parse(&argv("graph.txt --dim 64 --backend=hlo --no-pipeline")).unwrap();
        assert_eq!(a.command, "train");
        assert_eq!(a.get("dim"), Some("64"));
        assert_eq!(a.get("backend"), Some("hlo"));
        assert!(a.flag("no-pipeline"));
        assert_eq!(a.positional, vec!["graph.txt"]);
    }

    #[test]
    fn unknown_flags_suggest_a_fix() {
        let err = TRAIN.parse(&argv("--dmi 64")).unwrap_err().to_string();
        assert!(err.contains("unknown flag --dmi"), "{err}");
        assert!(err.contains("did you mean --dim?"), "{err}");
        // nothing within distance 2: plain pointer to --help instead
        let err = TRAIN.parse(&argv("--completely-wrong")).unwrap_err().to_string();
        assert!(err.contains("--help"), "{err}");
        assert!(!err.contains("did you mean"), "{err}");
    }

    #[test]
    fn missing_values_and_misused_switches_are_pointed() {
        let err = TRAIN.parse(&argv("--dim")).unwrap_err().to_string();
        assert!(err.contains("--dim requires a value D"), "{err}");
        // a following --flag is not silently eaten as the value
        let err = TRAIN.parse(&argv("--dim --epochs 3")).unwrap_err().to_string();
        assert!(err.contains("--dim requires a value"), "{err}");
        let err = TRAIN.parse(&argv("--no-pipeline=yes")).unwrap_err().to_string();
        assert!(err.contains("takes no value"), "{err}");
    }

    #[test]
    fn wire_compression_flags_reach_the_config() {
        let mut b = TrainConfigBuilder::new();
        let a = TRAIN.parse(&argv("--no-wire-compression")).unwrap();
        TRAIN.apply_to_builder(&a, &mut b).unwrap();
        assert!(!b.config().wire_compression);
        assert_eq!(b.source_of("wire_compression"), "--no-wire-compression");

        // both given: the off switch is later in the table and wins
        let mut b = TrainConfigBuilder::new();
        let a = TRAIN.parse(&argv("--wire-compression --no-wire-compression")).unwrap();
        TRAIN.apply_to_builder(&a, &mut b).unwrap();
        assert!(!b.config().wire_compression);
    }

    /// Every bound train flag round-trips CLI → config → CLI: parse
    /// `--flag <spelling>`, fold into a builder, and the builder renders
    /// the exact same spelling back. Run twice (defaults + a perturbed
    /// baseline) so list/mode/bool fields are exercised on non-trivial
    /// values too.
    #[test]
    fn every_flag_spec_entry_round_trips_cli_config_cli() {
        let mut perturbed = TrainConfigBuilder::new();
        for (k, v) in [
            ("num_workers", "2"),
            ("worker_capacities", "1,3"),
            ("workers", "tcp://127.0.0.1:7077"),
            ("backend", "simd"),
            ("shuffle", "none"),
            ("wire_compression", "false"),
        ] {
            perturbed.set_str(k, v, "baseline").unwrap();
        }
        for baseline in [TrainConfigBuilder::new(), perturbed] {
            for f in TRAIN.flags {
                match f.binding {
                    Binding::Set(key) => {
                        let v = baseline.value_of(key).unwrap();
                        if v.is_empty() {
                            continue; // e.g. an empty capacities list
                        }
                        let a = TRAIN.parse(&[format!("--{}", f.name), v.clone()]).unwrap();
                        let mut b = TrainConfigBuilder::new();
                        TRAIN.apply_to_builder(&a, &mut b).unwrap();
                        assert_eq!(
                            b.value_of(key).unwrap(),
                            v,
                            "--{} drifts through {v:?}",
                            f.name
                        );
                        assert_eq!(b.source_of(key), format!("--{}", f.name));
                    }
                    Binding::SetBool(key, to) => {
                        let a = TRAIN.parse(&[format!("--{}", f.name)]).unwrap();
                        let mut b = TrainConfigBuilder::new();
                        TRAIN.apply_to_builder(&a, &mut b).unwrap();
                        assert_eq!(b.value_of(key).unwrap(), to.to_string(), "--{}", f.name);
                    }
                    Binding::None => {
                        // must still parse in both spellings
                        match f.kind {
                            FlagKind::Value(_) => {
                                let a = TRAIN
                                    .parse(&[format!("--{}=x", f.name)])
                                    .unwrap();
                                assert_eq!(a.get(f.name), Some("x"));
                            }
                            FlagKind::Switch => {
                                let a = TRAIN.parse(&[format!("--{}", f.name)]).unwrap();
                                assert!(a.flag(f.name));
                            }
                        }
                    }
                }
            }
        }
    }

    /// Golden `--help` surfaces for the four speced daily-driver
    /// subcommands: exact header + usage lines, and the exact flag list
    /// in table order (extracted back out of the rendered screen).
    #[test]
    fn golden_help_screens() {
        let golden: &[(&CommandSpec, &str, &str, &[&str])] = &[
            (
                &TRAIN,
                "graphvite train — train node embeddings through the full hybrid system",
                "  graphvite train [GRAPH] [options]",
                &[
                    "config",
                    "synthetic",
                    "nodes",
                    "edges-per-node",
                    "labels",
                    "mixing",
                    "dim",
                    "epochs",
                    "lr",
                    "negatives",
                    "neg-weight",
                    "batch-size",
                    "seed",
                    "log-every",
                    "walk-length",
                    "aug-distance",
                    "workers",
                    "capacities",
                    "partitions",
                    "samplers",
                    "episode-size",
                    "backend",
                    "shuffle",
                    "graph-format",
                    "graph-cache-bytes",
                    "transport",
                    "worker-timeout-secs",
                    "heartbeat-secs",
                    "max-worker-retries",
                    "rejoin-window-secs",
                    "wire-compression",
                    "no-wire-compression",
                    "no-collaboration",
                    "no-augmentation",
                    "no-fix-context",
                    "no-pipeline",
                    "no-residency",
                    "fault-checkpoint",
                    "output",
                    "output-format",
                    "checkpoint",
                    "checkpoint-every",
                    "resume",
                    "stop-after-pools",
                    "help",
                ],
            ),
            (
                &PACK,
                "graphvite pack — pack an edge list for out-of-core training",
                "  graphvite pack GRAPH.txt --out FILE.gvpk [options]",
                &["out", "page-size", "pack-mem-bytes", "reorder", "help"],
            ),
            (
                &REORDER,
                "graphvite reorder — repack a graph under a locality-aware node permutation",
                "  graphvite reorder GRAPH --out FILE.gvpk [options]",
                &["out", "reorder", "page-size", "pack-mem-bytes", "help"],
            ),
            (
                &SERVE,
                "graphvite serve — serve batched top-k queries over TCP",
                "  graphvite serve EMB [options]",
                &["embeddings", "addr", "nlist", "nprobe", "seed", "watch", "poll-ms", "help"],
            ),
            (
                &WORKER,
                "graphvite worker — host a training worker for a remote coordinator",
                "  graphvite worker --connect HOST:PORT [options]",
                &["connect", "connect-timeout-secs", "help"],
            ),
        ];
        for &(spec, header, usage, flags) in golden {
            let help = spec.help();
            let lines: Vec<&str> = help.lines().collect();
            assert_eq!(lines[0], header);
            assert_eq!(lines[1], "");
            assert_eq!(lines[2], "USAGE:");
            assert_eq!(lines[3], usage);
            assert_eq!(lines[4], "");
            assert_eq!(lines[5], "OPTIONS:");
            let listed: Vec<&str> = lines[6..]
                .iter()
                .map(|l| {
                    let rest = l.strip_prefix("  --").expect("option lines start with --");
                    rest.split([' ', '=']).next().unwrap()
                })
                .collect();
            assert_eq!(listed, flags, "graphvite {} flag list drifted", spec.name);
            // every option line carries help text past the flag column
            for l in &lines[6..] {
                assert!(l.len() > 4 && !l.ends_with(' '), "bare help line: {l:?}");
            }
        }
    }

    #[test]
    fn every_subcommand_spec_is_well_formed() {
        for &cmd in COMMANDS {
            assert_eq!(command_spec(cmd.name).unwrap().name, cmd.name);
            // no duplicate flag names within a table
            for (i, f) in cmd.flags.iter().enumerate() {
                assert!(
                    cmd.flags[..i].iter().all(|g| g.name != f.name),
                    "duplicate --{} in {}",
                    f.name,
                    cmd.name
                );
                assert!(!f.help.is_empty());
            }
            // --help everywhere
            assert!(cmd.flag("help").is_some(), "{} lacks --help", cmd.name);
        }
        assert!(command_spec("no-such-command").is_none());
    }
}
