//! PJRT device layer (`pjrt` cargo feature): loads the AOT HLO-text
//! artifacts produced by `python/compile/aot.py` and executes them on a
//! per-worker CPU PJRT client. The only module that touches the `xla`
//! crate — see the design notes on [`crate::runtime`].

use anyhow::{Context, Result};
use xla::{Literal, PjRtClient, PjRtLoadedExecutable};

use super::ArtifactMeta;

/// Build an f32 literal of the given shape from a flat slice.
pub fn literal_f32(data: &[f32], dims: &[i64]) -> Result<Literal> {
    debug_assert_eq!(dims.iter().product::<i64>() as usize, data.len());
    Literal::vec1(data).reshape(dims).map_err(to_anyhow)
}

/// Build an i32 literal of the given shape from a flat slice.
pub fn literal_i32(data: &[i32], dims: &[i64]) -> Result<Literal> {
    debug_assert_eq!(dims.iter().product::<i64>() as usize, data.len());
    Literal::vec1(data).reshape(dims).map_err(to_anyhow)
}

/// A per-worker PJRT device: one CPU client + one compiled train step.
///
/// The "device memory" of this simulated GPU is the pair of partition
/// literals the caller keeps between [`Device::train_step`] calls.
pub struct Device {
    exe: PjRtLoadedExecutable,
    meta: ArtifactMeta,
}

impl Device {
    /// Compile the artifact on a fresh CPU client.
    pub fn load(meta: &ArtifactMeta) -> Result<Self> {
        let client = PjRtClient::cpu().map_err(to_anyhow).context("create PJRT CPU client")?;
        Self::load_with_client(meta, client)
    }

    /// Compile on an existing client (lets one worker own several
    /// executables — e.g. train variants of different capacities).
    pub fn load_with_client(meta: &ArtifactMeta, client: PjRtClient) -> Result<Self> {
        let proto = xla::HloModuleProto::from_text_file(
            meta.file
                .to_str()
                .ok_or_else(|| anyhow::anyhow!("non-utf8 artifact path"))?,
        )
        .map_err(to_anyhow)
        .with_context(|| format!("parse HLO text {}", meta.file.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client
            .compile(&comp)
            .map_err(to_anyhow)
            .with_context(|| format!("compile {}", meta.file.display()))?;
        Ok(Device { exe, meta: meta.clone() })
    }

    pub fn meta(&self) -> &ArtifactMeta {
        &self.meta
    }

    /// Wrap padded host partition matrices as the device-state literals.
    pub fn upload_partitions(&self, vertex: &[f32], context: &[f32]) -> Result<(Literal, Literal)> {
        let (p, d) = (self.meta.p as i64, self.meta.d as i64);
        Ok((literal_f32(vertex, &[p, d])?, literal_f32(context, &[p, d])?))
    }

    /// Download the state literals back into padded host matrices.
    pub fn download_partitions(
        &self,
        vertex: &Literal,
        context: &Literal,
    ) -> Result<(Vec<f32>, Vec<f32>)> {
        Ok((
            vertex.to_vec::<f32>().map_err(to_anyhow)?,
            context.to_vec::<f32>().map_err(to_anyhow)?,
        ))
    }

    /// One AOT train step over S x B positive samples.
    ///
    /// `vertex`/`context` are the current state literals (consumed);
    /// returns the updated state plus the mean SGNS loss. Index slices are
    /// partition-local rows sized exactly `s*b` / `s*b*k`.
    pub fn train_step(
        &self,
        vertex: Literal,
        context: Literal,
        pos_u: &[i32],
        pos_v: &[i32],
        neg_v: &[i32],
        lr: f32,
    ) -> Result<(Literal, Literal, f32)> {
        let m = &self.meta;
        debug_assert_eq!(pos_u.len(), m.s * m.b);
        debug_assert_eq!(pos_v.len(), m.s * m.b);
        debug_assert_eq!(neg_v.len(), m.s * m.b * m.k);
        let (s, b, k) = (m.s as i64, m.b as i64, m.k as i64);
        let pu = literal_i32(pos_u, &[s, b])?;
        let pv = literal_i32(pos_v, &[s, b])?;
        let nv = literal_i32(neg_v, &[s, b, k])?;
        let lr_lit = Literal::scalar(lr);
        let outs = self
            .exe
            .execute::<Literal>(&[vertex, context, pu, pv, nv, lr_lit])
            .map_err(to_anyhow)
            .context("execute train step")?;
        let result = outs
            .first()
            .and_then(|r| r.first())
            .ok_or_else(|| anyhow::anyhow!("no output buffer"))?
            .to_literal_sync()
            .map_err(to_anyhow)?;
        let (new_vertex, new_context, loss_lit) = result.to_tuple3().map_err(to_anyhow)?;
        let loss = loss_lit.get_first_element::<f32>().map_err(to_anyhow)?;
        Ok((new_vertex, new_context, loss))
    }

    /// Bytes transferred host<->device by one train step (both directions),
    /// for the metrics counters: partitions up+down, samples up.
    pub fn step_transfer_bytes(&self) -> u64 {
        let m = &self.meta;
        let mat = (m.p * m.d * 4) as u64;
        let samples = (m.s * m.b * (2 + m.k) * 4) as u64;
        2 * mat /* up */ + 2 * mat /* down */ + samples
    }
}

/// A compiled standalone Layer-1 kernel (micro-bench / parity tests).
pub struct KernelDevice {
    exe: PjRtLoadedExecutable,
    meta: ArtifactMeta,
}

impl KernelDevice {
    pub fn load(meta: &ArtifactMeta) -> Result<Self> {
        let client = PjRtClient::cpu().map_err(to_anyhow)?;
        let proto = xla::HloModuleProto::from_text_file(meta.file.to_str().unwrap())
            .map_err(to_anyhow)?;
        let exe = client
            .compile(&xla::XlaComputation::from_proto(&proto))
            .map_err(to_anyhow)?;
        Ok(KernelDevice { exe, meta: meta.clone() })
    }

    pub fn meta(&self) -> &ArtifactMeta {
        &self.meta
    }

    /// Run sgns_grad(u, v, label, weight) -> (grad_u, grad_v, loss).
    pub fn run(
        &self,
        u: &[f32],
        v: &[f32],
        label: &[f32],
        weight: &[f32],
    ) -> Result<(Vec<f32>, Vec<f32>, Vec<f32>)> {
        let (n, d) = (self.meta.n as i64, self.meta.d as i64);
        let args = [
            literal_f32(u, &[n, d])?,
            literal_f32(v, &[n, d])?,
            literal_f32(label, &[n])?,
            literal_f32(weight, &[n])?,
        ];
        let outs = self.exe.execute::<Literal>(&args).map_err(to_anyhow)?;
        let result = outs
            .first()
            .and_then(|r| r.first())
            .ok_or_else(|| anyhow::anyhow!("no output"))?
            .to_literal_sync()
            .map_err(to_anyhow)?;
        let (gu, gv, loss) = result.to_tuple3().map_err(to_anyhow)?;
        Ok((
            gu.to_vec::<f32>().map_err(to_anyhow)?,
            gv.to_vec::<f32>().map_err(to_anyhow)?,
            loss.to_vec::<f32>().map_err(to_anyhow)?,
        ))
    }
}

fn to_anyhow(e: xla::Error) -> anyhow::Error {
    anyhow::anyhow!("{e}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_builders_shape_check() {
        let l = literal_f32(&[1.0, 2.0, 3.0, 4.0], &[2, 2]).unwrap();
        assert_eq!(l.element_count(), 4);
        let i = literal_i32(&[1, 2, 3], &[3]).unwrap();
        assert_eq!(i.element_count(), 3);
    }
}
