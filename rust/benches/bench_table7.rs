//! Regenerates paper Table 7 — F1 + training time for the four pool-shuffle algorithms.
//!
//! Run with `cargo bench --bench bench_table7`; set
//! GRAPHVITE_BENCH_SCALE=tiny|small|full to change the workload size
//! (default tiny so `cargo bench` completes quickly; EXPERIMENTS.md
//! records the `small` runs).

fn main() {
    graphvite::experiments::run("table7", graphvite::experiments::Scale::from_env())
        .expect("table7 experiment");
}
