//! Hand-rolled CLI argument parser (clap is not in the offline crate
//! set). Each subcommand is described by a declarative [`spec::CommandSpec`]
//! table — flag names, switch-vs-value kinds, help lines, and (for
//! `train`) which [`crate::config::TrainConfigBuilder`] key each flag
//! feeds. Parsing is strict against the table: unknown flags get a
//! "did you mean" suggestion, value flags without a value are pointed
//! errors, and `--help` text is generated from the same table.

use std::collections::BTreeMap;

use anyhow::{bail, Result};

pub mod spec;

pub use spec::{command_spec, Binding, CommandSpec, FlagKind, FlagSpec};

/// Parsed command line: subcommand, options, positionals.
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub command: String,
    opts: BTreeMap<String, String>,
    flags: Vec<String>,
    pub positional: Vec<String>,
}

impl Args {
    /// Parse from raw argv (excluding the program name). A recognized
    /// subcommand parses strictly against its [`CommandSpec`]; anything
    /// else (no subcommand, or an unknown one the caller will reject)
    /// parses loosely so `graphvite --help` and the "unknown command"
    /// error path still work.
    pub fn parse(argv: &[String]) -> Result<Self> {
        let (command, rest) = match argv.first() {
            Some(first) if !first.starts_with('-') => (first.as_str(), &argv[1..]),
            _ => ("", argv),
        };
        match spec::command_spec(command) {
            Some(cs) => cs.parse(rest),
            None => Self::parse_loose(command, rest),
        }
    }

    /// Spec-less fallback: `--key=value` and `--key value` become
    /// options, a `--key` with no following value token is a switch.
    fn parse_loose(command: &str, argv: &[String]) -> Result<Self> {
        let mut out = Args { command: command.to_string(), ..Args::default() };
        let mut it = argv.iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(rest) = tok.strip_prefix("--") {
                if rest.is_empty() {
                    bail!("bare '--' not supported");
                }
                if let Some(eq) = rest.find('=') {
                    out.opts.insert(rest[..eq].to_string(), rest[eq + 1..].to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    out.opts.insert(rest.to_string(), it.next().unwrap().clone());
                } else {
                    out.flags.push(rest.to_string());
                }
            } else {
                out.positional.push(tok.clone());
            }
        }
        Ok(out)
    }

    pub fn from_env() -> Result<Self> {
        let argv: Vec<String> = std::env::args().skip(1).collect();
        Self::parse(&argv)
    }

    /// String option.
    pub fn get(&self, key: &str) -> Option<&str> {
        self.opts.get(key).map(|s| s.as_str())
    }

    /// Typed option with default.
    pub fn get_parse<T: std::str::FromStr>(&self, key: &str, default: T) -> Result<T> {
        match self.opts.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{key}: cannot parse '{v}'")),
        }
    }

    /// Boolean flag (present / absent); `--key value` style also accepted
    /// with true/false.
    pub fn flag(&self, key: &str) -> bool {
        self.flags.iter().any(|f| f == key)
            || self
                .opts
                .get(key)
                .map(|v| v == "true" || v == "1")
                .unwrap_or(false)
    }

    /// Keys of unknown options (for strict validation).
    pub fn option_keys(&self) -> impl Iterator<Item = &str> {
        self.opts.keys().map(|s| s.as_str()).chain(self.flags.iter().map(|s| s.as_str()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &str) -> Vec<String> {
        s.split_whitespace().map(|t| t.to_string()).collect()
    }

    #[test]
    fn speced_subcommands_parse_strictly() {
        let a = Args::parse(&argv("train --dim 64 --backend=hlo --no-pipeline graph.txt"))
            .unwrap();
        assert_eq!(a.command, "train");
        assert_eq!(a.get("dim"), Some("64"));
        assert_eq!(a.get("backend"), Some("hlo"));
        assert!(a.flag("no-pipeline"));
        assert_eq!(a.positional, vec!["graph.txt"]);
        // a typo is caught at parse time, not silently ignored
        let err = Args::parse(&argv("train --dmi 64")).unwrap_err().to_string();
        assert!(err.contains("did you mean --dim?"), "{err}");
    }

    #[test]
    fn typed_parsing_with_default() {
        let a = Args::parse(&argv("x --epochs 7")).unwrap();
        assert_eq!(a.get_parse("epochs", 1usize).unwrap(), 7);
        assert_eq!(a.get_parse("dim", 64usize).unwrap(), 64);
        assert!(a.get_parse::<usize>("epochs", 0).is_ok());
        let b = Args::parse(&argv("x --epochs seven")).unwrap();
        assert!(b.get_parse::<usize>("epochs", 0).is_err());
    }

    #[test]
    fn loose_flag_via_value() {
        let a = Args::parse(&argv("x --verbose true")).unwrap();
        assert!(a.flag("verbose"));
        let b = Args::parse(&argv("x --verbose false")).unwrap();
        assert!(!b.flag("verbose"));
    }

    #[test]
    fn no_subcommand() {
        let a = Args::parse(&argv("--help")).unwrap();
        assert_eq!(a.command, "");
        assert!(a.flag("help"));
    }
}
